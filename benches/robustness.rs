//! Bench: the numerical-health tier — what robustness costs.
//!
//! Three questions, answered on the dense k₁ Gram matrix `K̃ = K + σ_n²I`
//! at the paper's truth hyperparameters:
//!
//! * **jitter ladder overhead on clean data** — the escalation entry
//!   point ([`ProfiledEval::from_cov_with`], whose rung 0 is the
//!   recoverable factorisation) vs the pre-ladder arithmetic (plain
//!   `Chol::factor_owned_with` + solve). On a PD matrix the ladder takes
//!   zero rungs, so this measures pure bookkeeping (an `O(n)` saved
//!   diagonal) and must stay ≈ 1×.
//! * **LDLᵀ vs LLᵀ wall** — the diagonal-pivoted fallback factorisation
//!   ([`Ldlt::factor`]) against the blocked SIMD Cholesky. LDLᵀ is the
//!   last-rung diagnosis tool, not a hot path; this records how much
//!   slower the sequential reference loop is.
//! * **condition-estimate cost** — [`Chol::cond_1est`] (two Hager
//!   1-norm estimates, `O(n²)` per iteration) relative to the `O(n³)`
//!   factorisation it piggybacks on; the serving layer probes it on
//!   every cold refresh, so it must be a small fraction of the refresh.
//!
//! Appends a `robustness` section to **`BENCH_perf.json`** (merging with
//! whatever sections other benches wrote). Row schema:
//!
//! * `jitter_ladder`: `{n, threads, ladder_seconds, plain_seconds,
//!   overhead}` — `overhead = ladder/plain`;
//! * `ldlt`: `{n, threads, ldlt_seconds, llt_seconds, ratio}` —
//!   `ratio = ldlt/llt`;
//! * `cond_est`: `{n, threads, cond_seconds, factor_seconds, fraction}`
//!   — `fraction = cond/factor`.
//!
//! `cargo bench --bench robustness`; set `GPFAST_BENCH_QUICK=1` for the
//! ci.sh smoke run (small n).

use gpfast::gp::{assemble_cov_with, profiled::ProfiledEval};
use gpfast::kernels::{paper_k1, PaperK1};
use gpfast::linalg::{Chol, Ldlt};
use gpfast::runtime::ExecutionContext;
use gpfast::util::{timer::human_time, Json, Table, TimingStats};

fn main() {
    let ctx = ExecutionContext::from_env();
    let threads = ctx.threads();
    let quick = std::env::var("GPFAST_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let sizes: Vec<usize> = if quick { vec![128, 256] } else { vec![500, 1000, 1968] };
    println!("(thread budget: {threads}{})\n", if quick { ", quick mode" } else { "" });
    let mut rows: Vec<Json> = Vec::new();
    let theta = PaperK1::truth();
    let model = paper_k1(0.1);

    println!("== jitter-ladder overhead on clean (PD) data ==");
    let mut table = Table::new(vec!["n", "ladder", "plain", "overhead"]);
    for &n in &sizes {
        let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = t.iter().map(|&x| (x * 0.51).sin()).collect();
        let k = assemble_cov_with(&model, &t, &theta, &ctx);
        let reps = if n >= 1968 { 2 } else { 3 };
        // both closures clone the O(n²) covariance; the ladder path goes
        // through the full escalation entry point (rung 0 on PD data),
        // the plain path is the pre-ladder arithmetic
        let ladder = TimingStats::measure(1, reps, || {
            let ev = ProfiledEval::from_cov_with(k.clone(), &y, &ctx).unwrap();
            assert_eq!(ev.jitter, 0.0, "clean data took a ladder rung");
        });
        let plain = TimingStats::measure(1, reps, || {
            let ch = Chol::factor_owned_with(k.clone(), &ctx).unwrap();
            let _ = ch.solve(&y);
        });
        let overhead = ladder.min() / plain.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(ladder.min()),
            human_time(plain.min()),
            format!("{overhead:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "jitter_ladder".into()),
            ("n", n.into()),
            ("threads", threads.into()),
            ("ladder_seconds", ladder.min().into()),
            ("plain_seconds", plain.min().into()),
            ("overhead", overhead.into()),
        ]));
    }
    print!("{}", table.render());

    println!("\n== LDLᵀ fallback vs blocked LLᵀ ==");
    let mut table = Table::new(vec!["n", "ldlt", "llt", "ratio"]);
    for &n in &sizes {
        let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let k = assemble_cov_with(&model, &t, &theta, &ctx);
        let reps = if n >= 1968 { 2 } else { 3 };
        let ldlt = TimingStats::measure(1, reps, || {
            let f = Ldlt::factor(&k);
            assert!(f.min_d() > 0.0, "PD matrix judged indefinite");
        });
        let llt = TimingStats::measure(1, reps, || {
            let _ = Chol::factor_with(&k, &ctx).unwrap();
        });
        let ratio = ldlt.min() / llt.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(ldlt.min()),
            human_time(llt.min()),
            format!("{ratio:.1}x"),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "ldlt".into()),
            ("n", n.into()),
            ("threads", threads.into()),
            ("ldlt_seconds", ldlt.min().into()),
            ("llt_seconds", llt.min().into()),
            ("ratio", ratio.into()),
        ]));
    }
    print!("{}", table.render());

    println!("\n== condition estimate (Hager 1-norm) vs factorisation ==");
    let mut table = Table::new(vec!["n", "cond_1est", "factor", "fraction"]);
    for &n in &sizes {
        let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let k = assemble_cov_with(&model, &t, &theta, &ctx);
        let ch = Chol::factor_with(&k, &ctx).unwrap();
        let reps = if n >= 1968 { 2 } else { 3 };
        let cond = TimingStats::measure(1, reps, || {
            let c = ch.cond_1est();
            assert!(c.is_finite() && c >= 1.0, "bad condition estimate {c}");
        });
        let factor = TimingStats::measure(1, reps, || {
            let _ = Chol::factor_owned_with(k.clone(), &ctx).unwrap();
        });
        let fraction = cond.min() / factor.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(cond.min()),
            human_time(factor.min()),
            format!("{:.0}%", fraction * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "cond_est".into()),
            ("n", n.into()),
            ("threads", threads.into()),
            ("cond_seconds", cond.min().into()),
            ("factor_seconds", factor.min().into()),
            ("fraction", fraction.into()),
        ]));
    }
    print!("{}", table.render());

    // merge the robustness section into BENCH_perf.json
    let path = "BENCH_perf.json";
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    let mut sections = doc
        .get("sections")
        .and_then(|s| s.as_obj().cloned())
        .unwrap_or_default();
    sections.insert("robustness".to_string(), Json::Arr(rows));
    doc.insert("sections".to_string(), Json::Obj(sections));
    doc.insert("threads_available".to_string(), threads.into());
    match std::fs::write(path, Json::Obj(doc).pretty()) {
        Ok(()) => println!("\nrobustness section merged into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
