//! Bench: the serving layer — cached-factor batch prediction vs the cold
//! assemble+factor+predict path, and the `O(n²)` streaming observe
//! (factor extend + α refresh) vs a full `O(n³)` refactorisation.
//!
//! Appends a `serve` section to **`BENCH_perf.json`** (merging with the
//! sections `cargo bench --bench perf` wrote, if the file exists) so the
//! perf trajectory stays in one machine-readable document. Row schema:
//!
//! * `batch_predict`: `{n, q, threads, cached_seconds, cold_seconds,
//!   speedup}` — one q-point batch through the cached factor vs paying
//!   assembly + factorisation for the batch.
//! * `observe`: `{n, threads, extend_seconds, refactor_seconds, speedup}`
//!   — appending one observation via `Chol::extend` + α refresh vs
//!   refactorising the grown matrix from scratch.
//!
//! `cargo bench --bench serve`

use gpfast::gp::serve::Predictor;
use gpfast::gp::{assemble_cov_with, predict, profiled::ProfiledEval};
use gpfast::kernels::{paper_k1, PaperK1};
use gpfast::linalg::Chol;
use gpfast::runtime::ExecutionContext;
use gpfast::util::{timer::human_time, Json, Table, TimingStats};

fn main() {
    let ctx = ExecutionContext::from_env();
    let threads = ctx.threads();
    println!("(thread budget: {threads})\n");
    let mut rows: Vec<Json> = Vec::new();
    let theta = PaperK1::truth();

    println!("== cached-factor batch predict vs cold (k1, q = 256 queries) ==");
    let mut table = Table::new(vec!["n", "cached", "cold", "speedup"]);
    for &n in &[500usize, 1000, 1968] {
        let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = t.iter().map(|&x| (x * 0.51).sin()).collect();
        let q = 256usize;
        let t_star: Vec<f64> =
            (0..q).map(|i| 0.5 + (n as f64 - 1.0) * i as f64 / q as f64).collect();
        let model = paper_k1(0.1);
        let predictor = Predictor::fit(paper_k1(0.1), &t, &y, &theta, &ctx).unwrap();
        let reps = if n >= 1968 { 2 } else { 3 };
        let cached = TimingStats::measure(1, reps, || {
            let _ = predictor.predict_batch(&t_star, &ctx);
        });
        let cold = TimingStats::measure(0, reps, || {
            // what serving costs without the cache: re-assemble and
            // re-factorise for every batch
            let k = assemble_cov_with(&model, &t, &theta, &ctx);
            let ev = ProfiledEval::from_cov_with(k, &y, &ctx).unwrap();
            let _ = predict(&model, &t, &theta, &ev, &t_star);
        });
        let speedup = cold.min() / cached.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(cached.min()),
            human_time(cold.min()),
            format!("{speedup:.1}x"),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "batch_predict".into()),
            ("n", n.into()),
            ("q", q.into()),
            ("threads", threads.into()),
            ("cached_seconds", cached.min().into()),
            ("cold_seconds", cold.min().into()),
            ("speedup", speedup.into()),
        ]));
    }
    print!("{}", table.render());

    println!("\n== streaming observe: O(n²) extend vs O(n³) refactor ==");
    let mut table = Table::new(vec!["n", "extend+refresh", "refactor", "speedup"]);
    for &n in &[500usize, 1000, 1968] {
        let t: Vec<f64> = (1..=n + 1).map(|i| i as f64).collect();
        let y: Vec<f64> = t.iter().map(|&x| (x * 0.51).sin()).collect();
        let model = paper_k1(0.1);
        let k_grown = assemble_cov_with(&model, &t, &theta, &ctx);
        let mut k_base = gpfast::linalg::Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k_base[(i, j)] = k_grown[(i, j)];
            }
        }
        let base = Chol::factor_with(&k_base, &ctx).unwrap();
        let cross: Vec<f64> = (0..n).map(|i| k_grown[(n, i)]).collect();
        let diag = k_grown[(n, n)];
        let reps = if n >= 1968 { 2 } else { 3 };
        // both closures clone an O(n²) object; the refactor path then
        // pays O(n³) on top, the extend path only O(n²)
        let extend = TimingStats::measure(1, reps, || {
            let mut ch = base.clone();
            ch.extend(&cross, diag).unwrap();
            let _ = ch.solve(&y);
        });
        let refactor = TimingStats::measure(0, reps, || {
            let ch = Chol::factor_owned_with(k_grown.clone(), &ctx).unwrap();
            let _ = ch.solve(&y);
        });
        let speedup = refactor.min() / extend.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(extend.min()),
            human_time(refactor.min()),
            format!("{speedup:.1}x"),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "observe".into()),
            ("n", n.into()),
            ("threads", threads.into()),
            ("extend_seconds", extend.min().into()),
            ("refactor_seconds", refactor.min().into()),
            ("speedup", speedup.into()),
        ]));
    }
    print!("{}", table.render());

    // merge the serve section into BENCH_perf.json (keep perf's sections)
    let path = "BENCH_perf.json";
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    let mut sections = doc
        .get("sections")
        .and_then(|s| s.as_obj().cloned())
        .unwrap_or_default();
    sections.insert("serve".to_string(), Json::Arr(rows));
    doc.insert("sections".to_string(), Json::Obj(sections));
    doc.insert("threads_available".to_string(), threads.into());
    match std::fs::write(path, Json::Obj(doc).pretty()) {
        Ok(()) => println!("\nserve section merged into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
