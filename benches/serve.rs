//! Bench: the serving layer — cached-factor batch prediction vs the cold
//! assemble+factor+predict path, the `O(n²)` streaming observe
//! (factor extend + α refresh) vs a full `O(n³)` refactorisation, the
//! `O(n²)` sliding-window **evict** vs refactorising the shrunk window,
//! and the **persistence** restart (`TrainedModel` save/load) vs
//! retraining from scratch.
//!
//! Appends a `serve` section to **`BENCH_perf.json`** (merging with the
//! sections `cargo bench --bench perf` wrote, if the file exists) so the
//! perf trajectory stays in one machine-readable document. Row schema:
//!
//! * `batch_predict`: `{n, q, threads, cached_seconds, cold_seconds,
//!   speedup}` — one q-point batch through the cached factor vs paying
//!   assembly + factorisation for the batch.
//! * `observe`: `{n, threads, extend_seconds, refactor_seconds, speedup}`
//!   — appending one observation via `Chol::extend` + α refresh vs
//!   refactorising the grown matrix from scratch.
//! * `evict`: `{n, threads, evict_seconds, refactor_seconds, speedup}` —
//!   deleting the oldest observation via `Chol::shrink_front(1)` + α
//!   refresh vs refactorising the shrunk window from scratch.
//! * `persistence`: `{n, threads, artifact_bytes, save_seconds,
//!   load_seconds, retrain_seconds, speedup}` — restoring a serving
//!   session from a `TrainedModel` artifact (first prediction included)
//!   vs re-running training; `speedup = retrain/load`.
//!
//! `cargo bench --bench serve`; set `GPFAST_BENCH_QUICK=1` for the
//! ci.sh smoke run (small n, 1-restart retrain).

use gpfast::coordinator::{ModelSpec, PipelineConfig, ServeSession, Tournament};
use gpfast::gp::serve::Predictor;
use gpfast::gp::{assemble_cov_with, predict, profiled::ProfiledEval};
use gpfast::kernels::{paper_k1, PaperK1};
use gpfast::linalg::Chol;
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;
use gpfast::util::{timer::human_time, Json, Stopwatch, Table, TimingStats};

fn main() {
    let ctx = ExecutionContext::from_env();
    let threads = ctx.threads();
    let quick = std::env::var("GPFAST_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let sizes: Vec<usize> = if quick { vec![128, 256] } else { vec![500, 1000, 1968] };
    println!("(thread budget: {threads}{})\n", if quick { ", quick mode" } else { "" });
    let mut rows: Vec<Json> = Vec::new();
    let theta = PaperK1::truth();

    println!("== cached-factor batch predict vs cold (k1, q = 256 queries) ==");
    let mut table = Table::new(vec!["n", "cached", "cold", "speedup"]);
    for &n in &sizes {
        let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = t.iter().map(|&x| (x * 0.51).sin()).collect();
        let q = 256usize;
        let t_star: Vec<f64> =
            (0..q).map(|i| 0.5 + (n as f64 - 1.0) * i as f64 / q as f64).collect();
        let model = paper_k1(0.1);
        let predictor = Predictor::fit(paper_k1(0.1), &t, &y, &theta, &ctx).unwrap();
        let reps = if n >= 1968 { 2 } else { 3 };
        let cached = TimingStats::measure(1, reps, || {
            let _ = predictor.predict_batch(&t_star, &ctx);
        });
        let cold = TimingStats::measure(0, reps, || {
            // what serving costs without the cache: re-assemble and
            // re-factorise for every batch
            let k = assemble_cov_with(&model, &t, &theta, &ctx);
            let ev = ProfiledEval::from_cov_with(k, &y, &ctx).unwrap();
            let _ = predict(&model, &t, &theta, &ev, &t_star);
        });
        let speedup = cold.min() / cached.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(cached.min()),
            human_time(cold.min()),
            format!("{speedup:.1}x"),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "batch_predict".into()),
            ("n", n.into()),
            ("q", q.into()),
            ("threads", threads.into()),
            ("cached_seconds", cached.min().into()),
            ("cold_seconds", cold.min().into()),
            ("speedup", speedup.into()),
        ]));
    }
    print!("{}", table.render());

    println!("\n== streaming observe: O(n²) extend vs O(n³) refactor ==");
    let mut table = Table::new(vec!["n", "extend+refresh", "refactor", "speedup"]);
    for &n in &sizes {
        let t: Vec<f64> = (1..=n + 1).map(|i| i as f64).collect();
        let y: Vec<f64> = t.iter().map(|&x| (x * 0.51).sin()).collect();
        let model = paper_k1(0.1);
        let k_grown = assemble_cov_with(&model, &t, &theta, &ctx);
        let mut k_base = gpfast::linalg::Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k_base[(i, j)] = k_grown[(i, j)];
            }
        }
        let base = Chol::factor_with(&k_base, &ctx).unwrap();
        let cross: Vec<f64> = (0..n).map(|i| k_grown[(n, i)]).collect();
        let diag = k_grown[(n, n)];
        let reps = if n >= 1968 { 2 } else { 3 };
        // both closures clone an O(n²) object; the refactor path then
        // pays O(n³) on top, the extend path only O(n²)
        let extend = TimingStats::measure(1, reps, || {
            let mut ch = base.clone();
            ch.extend(&cross, diag).unwrap();
            let _ = ch.solve(&y);
        });
        let refactor = TimingStats::measure(0, reps, || {
            let ch = Chol::factor_owned_with(k_grown.clone(), &ctx).unwrap();
            let _ = ch.solve(&y);
        });
        let speedup = refactor.min() / extend.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(extend.min()),
            human_time(refactor.min()),
            format!("{speedup:.1}x"),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "observe".into()),
            ("n", n.into()),
            ("threads", threads.into()),
            ("extend_seconds", extend.min().into()),
            ("refactor_seconds", refactor.min().into()),
            ("speedup", speedup.into()),
        ]));
    }
    print!("{}", table.render());

    println!("\n== sliding-window evict: O(n²) shrink vs O(n³) refactor ==");
    let mut table = Table::new(vec!["n", "evict+refresh", "refactor", "speedup"]);
    for &n in &sizes {
        let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = t.iter().map(|&x| (x * 0.51).sin()).collect();
        let model = paper_k1(0.1);
        let k_full = assemble_cov_with(&model, &t, &theta, &ctx);
        let base = Chol::factor_with(&k_full, &ctx).unwrap();
        // the shrunk window the eviction produces: points 1..n
        let m = n - 1;
        let mut k_tail = gpfast::linalg::Matrix::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                k_tail[(i, j)] = k_full[(i + 1, j + 1)];
            }
        }
        let reps = if n >= 1968 { 2 } else { 3 };
        // both closures clone an O(n²) object; the refactor path then
        // pays O(n³) on top, the evict path only the O(n²) rank-1 sweep
        let evict = TimingStats::measure(1, reps, || {
            let mut ch = base.clone();
            ch.shrink_front(1);
            let _ = ch.solve(&y[1..]);
        });
        let refactor = TimingStats::measure(0, reps, || {
            let ch = Chol::factor_owned_with(k_tail.clone(), &ctx).unwrap();
            let _ = ch.solve(&y[1..]);
        });
        let speedup = refactor.min() / evict.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(evict.min()),
            human_time(refactor.min()),
            format!("{speedup:.1}x"),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "evict".into()),
            ("n", n.into()),
            ("threads", threads.into()),
            ("evict_seconds", evict.min().into()),
            ("refactor_seconds", refactor.min().into()),
            ("speedup", speedup.into()),
        ]));
    }
    print!("{}", table.render());

    println!("\n== persistence: save/load restart vs retraining ==");
    let mut table = Table::new(vec!["n", "save", "load+predict", "retrain", "speedup"]);
    {
        let n = if quick { 128 } else { 500 };
        let restarts = if quick { 1 } else { 2 };
        let data = gpfast::data::synthetic::table1_dataset(n, 0.1, 5);
        let mut cfg = PipelineConfig::fast();
        cfg.models = vec![ModelSpec::K1];
        cfg.train.multistart.restarts = restarts;
        cfg.workers = 1;
        cfg.exec = ctx.clone();
        let mut rng = Xoshiro256::seed_from_u64(9);
        // the cost persistence avoids: train (+ evidence) from scratch
        let sw = Stopwatch::start();
        let result = Tournament::new(cfg).run(&data, &mut rng).unwrap();
        let retrain_secs = sw.elapsed_secs();
        let tm = result.winner();
        let path = std::env::temp_dir()
            .join(format!("gpfast_bench_artifact_{}.bin", std::process::id()));
        let save = TimingStats::measure(1, 3, || {
            tm.save(&path, &data).unwrap();
        });
        let artifact_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let probe = [0.5 * n as f64];
        let load = TimingStats::measure(1, 3, || {
            let session =
                ServeSession::from_artifacts(&[&path], ctx.clone()).unwrap();
            let _ = session.predict(&probe);
        });
        let _ = std::fs::remove_file(&path);
        let speedup = retrain_secs / load.min();
        table.add_row(vec![
            format!("{n}"),
            human_time(save.min()),
            human_time(load.min()),
            human_time(retrain_secs),
            format!("{speedup:.0}x"),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "persistence".into()),
            ("n", n.into()),
            ("threads", threads.into()),
            ("artifact_bytes", (artifact_bytes as usize).into()),
            ("save_seconds", save.min().into()),
            ("load_seconds", load.min().into()),
            ("retrain_seconds", retrain_secs.into()),
            ("speedup", speedup.into()),
        ]));
    }
    print!("{}", table.render());

    // merge the serve section into BENCH_perf.json (keep perf's sections)
    let path = "BENCH_perf.json";
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    let mut sections = doc
        .get("sections")
        .and_then(|s| s.as_obj().cloned())
        .unwrap_or_default();
    sections.insert("serve".to_string(), Json::Arr(rows));
    doc.insert("sections".to_string(), Json::Obj(sections));
    doc.insert("threads_available".to_string(), threads.into());
    match std::fs::write(path, Json::Obj(doc).pretty()) {
        Ok(()) => println!("\nserve section merged into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
