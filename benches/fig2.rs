//! Bench: **Fig. 2** — quality of the Gaussian (Laplace) approximation to
//! the k₂ hyperparameter posterior at n = 300: per-parameter sampled
//! vs Hessian-predicted marginals, plus the evidence discrepancy (the
//! paper quotes ~10%, i.e. ~0.1 nat).
//!
//! `cargo bench --bench fig2` (`GPFAST_BENCH_FAST=1` → n=100, small nlive)

use gpfast::coordinator::{train_model, ModelSpec, TrainOptions};
use gpfast::data::synthetic::table1_dataset;
use gpfast::evidence::laplace_evidence;
use gpfast::nested::{nested_sample, NestedOptions};
use gpfast::priors::{BoxPrior, ScalePrior};
use gpfast::rng::Xoshiro256;
use gpfast::util::{Stopwatch, Table};

fn main() {
    let fast = std::env::var("GPFAST_BENCH_FAST").is_ok();
    let n = if fast { 100 } else { 300 };
    let nlive = if fast { 200 } else { 500 };
    let data = table1_dataset(n, 0.1, 20160125);
    let spec = ModelSpec::K2;
    let model = spec.build(0.1);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let scale = ScalePrior::default();

    let mut rng = Xoshiro256::seed_from_u64(2);
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 10;
    let exec = gpfast::runtime::ExecutionContext::from_env();
    let sw_fast = Stopwatch::start();
    let trained = train_model(&spec, 0.1, &data, &opts, 2, &exec, &mut rng).unwrap();
    let hess =
        gpfast::gp::profiled_hessian_with(&model, &data.t, &data.y, &trained.theta_hat, &exec)
            .unwrap();
    let lap =
        laplace_evidence(n, &prior, &scale, &trained.theta_hat, trained.lnp_peak, &hess).unwrap();
    let t_fast = sw_fast.elapsed_secs();

    let sw_ns = Stopwatch::start();
    let res = nested_sample(
        prior.dim() + 1,
        |u: &[f64]| {
            let lambda = scale.lambda_from_unit(u[0]);
            let theta = prior.from_unit_cube(&u[1..]);
            let mut full = vec![lambda];
            full.extend(theta);
            gpfast::gp::full_lnp(&model, &data.t, &data.y, &full).unwrap_or(f64::NEG_INFINITY)
        },
        &NestedOptions { nlive, ..Default::default() },
        &mut rng,
    )
    .unwrap();
    let t_ns = sw_ns.elapsed_secs();

    // weighted posterior moments
    let dim = prior.dim();
    let mut mean = vec![0.0; dim];
    for s in &res.samples {
        let w = s.ln_w.exp();
        for (d, v) in prior.from_unit_cube(&s.u[1..]).into_iter().enumerate() {
            mean[d] += w * v;
        }
    }
    let mut var = vec![0.0; dim];
    for s in &res.samples {
        let w = s.ln_w.exp();
        for (d, v) in prior.from_unit_cube(&s.u[1..]).into_iter().enumerate() {
            var[d] += w * (v - mean[d]) * (v - mean[d]);
        }
    }

    println!("== Fig. 2: posterior vs Laplace Gaussian (k2, n = {n}) ==\n");
    let names = model.kernel.names();
    let mut table =
        Table::new(vec!["param", "post mean", "post sd", "θ̂ (laplace)", "σ (laplace)", "sd ratio"]);
    for d in 0..dim {
        let sd = var[d].sqrt();
        table.add_row(vec![
            names[d].clone(),
            format!("{:.4}", mean[d]),
            format!("{sd:.4}"),
            format!("{:.4}", trained.theta_hat[d]),
            format!("{:.4}", lap.sigma[d]),
            format!("{:.2}", lap.sigma[d] / sd.max(1e-12)),
        ]);
    }
    print!("{}", table.render());
    println!("\nlnZ_laplace = {:.3}   lnZ_nested = {:.3} ± {:.3}   |Δ| = {:.3} nats",
        lap.ln_z, res.ln_z, res.ln_z_err, (lap.ln_z - res.ln_z).abs());
    println!("(paper: Hessian-integral error ≈ 10% ≈ 0.1 nat at n = 300)");
    println!("\nfast path: {t_fast:.1}s   nested: {t_ns:.1}s   evals: {} vs {}",
        trained.n_evals, res.n_evals);
}
