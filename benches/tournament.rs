//! Bench: the model-comparison tournament — roster wall-clock vs serial
//! per-model training, and the warm-start evaluation savings.
//!
//! * **roster-of-3** (`k1`, `wendland-se`, `k2`): the two lineage roots
//!   train concurrently under a split budget, then `k2` trains
//!   warm-started from `k1`'s peak — wall-clock is compared against
//!   training the same three models one after another with the full
//!   budget each (the pre-tournament workflow);
//! * **warm-start savings**: profiled-likelihood evaluations recorded by
//!   the warm-started `k2` vs a cold multistart of the same model.
//!
//! Merges a `tournament` section into **`BENCH_perf.json`** (same
//! per-section row convention as `perf`/`serve`):
//! `{n, threads, restarts, tournament_seconds, serial_seconds, speedup,
//!   warm_evals, cold_evals, eval_savings}`.
//!
//! `cargo bench --bench tournament`; set `GPFAST_BENCH_QUICK=1` for the
//! ci.sh smoke sizes.

use gpfast::coordinator::{train_model, ModelSpec, PipelineConfig, Tournament, TrainOptions};
use gpfast::data::synthetic::table1_dataset;
use gpfast::optimize::MultistartOptions;
use gpfast::priors::{BoxPrior, ScalePrior};
use gpfast::rng::Xoshiro256;
use gpfast::util::{timer::human_time, Json, Stopwatch, Table};

fn main() {
    let quick = std::env::var("GPFAST_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let sizes: &[(usize, usize)] =
        if quick { &[(48, 2)] } else { &[(100, 4), (200, 6)] };
    let roster = vec![ModelSpec::K1, ModelSpec::WendlandSe, ModelSpec::K2];
    println!(
        "(machine parallelism: {avail}; roster: k1 + wendland-se + k2{})\n",
        if quick { "; QUICK smoke sizes" } else { "" }
    );

    let mut t = Table::new(vec![
        "n", "restarts", "tournament", "serial", "speedup", "k2 warm evals", "k2 cold evals",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for &(n, restarts) in sizes {
        let data = table1_dataset(n, 0.1, 20160125);
        let mut cfg = PipelineConfig::paper_synthetic();
        cfg.models = roster.clone();
        cfg.train.multistart.restarts = restarts;
        cfg.workers = avail;

        // --- the tournament: lineage-scheduled, shared budget
        let mut rng = Xoshiro256::seed_from_u64(1);
        let sw = Stopwatch::start();
        let result = Tournament::new(cfg.clone()).run(&data, &mut rng).expect("tournament");
        let tournament_secs = sw.elapsed_secs();
        let warm_evals = result.model("k2").expect("k2 trained").train.n_evals;

        // --- the pre-tournament workflow: each model trained serially
        // with the full budget (cold starts throughout), followed by its
        // Laplace evidence — the same per-model work the tournament's
        // wall-clock includes, so the speedup isolates the scheduling win
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(1);
        let sw = Stopwatch::start();
        let mut cold_evals = 0usize;
        for spec in &roster {
            let res = train_model(spec, cfg.sigma_n, &data, &opts, cfg.workers, &cfg.exec, &mut rng)
                .expect("serial train");
            if *spec == ModelSpec::K2 {
                cold_evals = res.n_evals;
            }
            let model = spec.build(cfg.sigma_n);
            let prior = BoxPrior::for_model(&model, &data.span().unwrap());
            let hess = gpfast::gp::profiled_hessian_with(
                &model,
                &data.t,
                &data.y,
                &res.theta_hat,
                &cfg.exec,
            )
            .expect("serial hessian");
            let _ev = gpfast::evidence::laplace_evidence(
                data.len(),
                &prior,
                &ScalePrior::default(),
                &res.theta_hat,
                res.lnp_peak,
                &hess,
            )
            .expect("serial evidence");
        }
        let serial_secs = sw.elapsed_secs();

        let speedup = serial_secs / tournament_secs;
        t.add_row(vec![
            format!("{n}"),
            format!("{restarts}"),
            human_time(tournament_secs),
            human_time(serial_secs),
            format!("{speedup:.2}x"),
            format!("{warm_evals}"),
            format!("{cold_evals}"),
        ]);
        rows.push(Json::obj(vec![
            ("n", n.into()),
            ("threads", avail.into()),
            ("restarts", restarts.into()),
            ("tournament_seconds", tournament_secs.into()),
            ("serial_seconds", serial_secs.into()),
            ("speedup", speedup.into()),
            ("warm_evals", warm_evals.into()),
            ("cold_evals", cold_evals.into()),
            (
                "eval_savings",
                (1.0 - warm_evals as f64 / cold_evals.max(1) as f64).into(),
            ),
        ]));
    }
    print!("{}", t.render());
    println!(
        "\n(serial trains the roster one model at a time with the full budget and cold \
         starts — the tournament's win is model-level concurrency plus warm-started \
         children doing fewer profiled-likelihood evaluations)"
    );

    // merge the tournament section into BENCH_perf.json, preserving the
    // sections other benches wrote
    let path = "BENCH_perf.json";
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    let mut sections =
        doc.get("sections").and_then(|s| s.as_obj().cloned()).unwrap_or_default();
    sections.insert("tournament".to_string(), Json::Arr(rows));
    doc.insert("sections".to_string(), Json::Obj(sections));
    doc.insert("threads_available".to_string(), avail.into());
    match std::fs::write(path, Json::Obj(doc).pretty()) {
        Ok(()) => println!("machine-readable results merged into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
