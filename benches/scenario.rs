//! Bench: the scenario tier — ARD multi-dimensional inputs and
//! heteroscedastic per-point noise.
//!
//! Appends a `scenario` section to **`BENCH_perf.json`** (merging with
//! the sections other benches wrote). Row schema:
//!
//! * `d_sweep`: `{d, n, threads, assemble_seconds, eval_seconds,
//!   train_seconds, lnp, n_evals}` — wall-clock of the n×d covariance
//!   assembly, one profiled `eval_nd_with`, and a full multistart train
//!   of `se-ard<d>` on a heteroscedastic dataset whose first d columns
//!   come from the synthetic ARD truth. The d = 1 row is the scalar
//!   baseline the nd layout must not regress.
//! * `ard_gap`: `{n, threads, ln_z_iso, ln_z_ard, ln_b, winner,
//!   tournament_seconds}` — the evidence gap between the isotropic-in-d
//!   parent and its warm-started SE-ARD child on ARD-generated d = 3
//!   data: the scenario tier's headline accuracy claim.
//!
//! `cargo bench --bench scenario`; set `GPFAST_BENCH_QUICK=1` for the
//! ci.sh smoke run (smaller n, fewer restarts, d ∈ {1, 3}).

use gpfast::coordinator::{train_model, ModelSpec, PipelineConfig, Tournament, TrainOptions};
use gpfast::data::synthetic::ard3_dataset;
use gpfast::data::Dataset;
use gpfast::gp::{assemble_cov_nd_with, profiled};
use gpfast::priors::BoxPrior;
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;
use gpfast::util::{timer::human_time, Json, Stopwatch, Table};

/// First-d-columns slice of the synthetic d = 3 ARD dataset, keeping the
/// heteroscedastic noise schedule: the d-sweep measures the input-layout
/// cost, so every row shares the same grid, targets and noise.
fn ard_dataset_d(n: usize, d: usize, seed: u64) -> Dataset {
    let base = ard3_dataset(n, 0.1, true, seed);
    if d == 3 {
        return base;
    }
    let mut data = Dataset::new(base.t.clone(), base.y.clone(), format!("ard-d{d}"));
    if d > 1 {
        data = data.with_extra_cols(base.extra[..d - 1].to_vec()).expect("extra cols");
    }
    data.with_noise(base.noise.clone().expect("hetero base")).expect("noise")
}

fn main() {
    let ctx = ExecutionContext::from_env();
    let threads = ctx.threads();
    let quick = std::env::var("GPFAST_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    println!("(thread budget: {threads}{})\n", if quick { ", quick mode" } else { "" });
    let mut rows: Vec<Json> = Vec::new();

    // --- d-sweep: assembly + eval + train wall-clock over input dims
    let n = if quick { 48 } else { 128 };
    let dims: &[usize] = if quick { &[1, 3] } else { &[1, 2, 3] };
    let restarts = if quick { 2 } else { 4 };
    println!("== d-sweep: n×d assembly + profiled eval + se-ard<d> train (n = {n}) ==");
    let mut table =
        Table::new(vec!["d", "assemble", "eval", "train", "lnp", "evals"]);
    for &d in dims {
        let data = ard_dataset_d(n, d, 11);
        assert_eq!(data.d(), d);
        let spec = ModelSpec::SeArd(d as u8);
        let model = spec.build(0.1);
        let prior = BoxPrior::for_model(&model, &data.span().expect("span"));
        let theta0: Vec<f64> =
            prior.bounds.iter().map(|(lo, hi)| 0.5 * (lo + hi)).collect();
        let cols = data.input_cols();
        let noise = data.noise.as_deref();

        let reps = if quick { 8 } else { 20 };
        let sw = Stopwatch::start();
        for _ in 0..reps {
            let k = assemble_cov_nd_with(&model, &cols, noise, &theta0, &ctx);
            assert!(k[(0, 0)].is_finite());
        }
        let assemble_seconds = sw.elapsed_secs() / reps as f64;

        let sw = Stopwatch::start();
        let ev = profiled::eval_nd_with(&model, &cols, noise, &data.y, &theta0, &ctx)
            .expect("profiled eval");
        let eval_seconds = sw.elapsed_secs();
        assert!(ev.lnp.is_finite(), "d = {d}: non-finite lnp");

        let mut opts = TrainOptions::default();
        opts.multistart.restarts = restarts;
        let mut rng = Xoshiro256::seed_from_u64(29 + d as u64);
        let sw = Stopwatch::start();
        let trained =
            train_model(&spec, 0.1, &data, &opts, 2, &ctx, &mut rng).expect("train");
        let train_seconds = sw.elapsed_secs();
        assert!(trained.lnp_peak.is_finite(), "d = {d}: non-finite peak");

        table.add_row(vec![
            format!("{d}"),
            human_time(assemble_seconds),
            human_time(eval_seconds),
            human_time(train_seconds),
            format!("{:.2}", trained.lnp_peak),
            format!("{}", trained.n_evals),
        ]);
        rows.push(Json::obj(vec![
            ("kind", "d_sweep".into()),
            ("d", d.into()),
            ("n", n.into()),
            ("threads", threads.into()),
            ("assemble_seconds", assemble_seconds.into()),
            ("eval_seconds", eval_seconds.into()),
            ("train_seconds", train_seconds.into()),
            ("lnp", trained.lnp_peak.into()),
            ("n_evals", trained.n_evals.into()),
        ]));
    }
    print!("{}", table.render());

    // --- ARD vs isotropic evidence gap on ARD-generated data
    let gap_n = if quick { 40 } else { 96 };
    println!("\n== ARD-vs-isotropic evidence gap on ARD-truth data (n = {gap_n}, d = 3) ==");
    let data = ard3_dataset(gap_n, 0.1, true, 13);
    let mut cfg = PipelineConfig::fast();
    cfg.models = vec![ModelSpec::SeIso(3), ModelSpec::SeArd(3)];
    cfg.sigma_n = 0.1;
    cfg.train.multistart.restarts = restarts;
    cfg.exec = ctx.clone();
    let mut rng = Xoshiro256::seed_from_u64(37);
    let sw = Stopwatch::start();
    let result = Tournament::new(cfg).run(&data, &mut rng).expect("tournament");
    let tournament_seconds = sw.elapsed_secs();
    let iso = result.model("se-iso3").expect("iso entrant");
    let ard = result.model("se-ard3").expect("ard entrant");
    let ln_b = ard.evidence.ln_z - iso.evidence.ln_z;
    assert!(
        iso.evidence.ln_z.is_finite() && ard.evidence.ln_z.is_finite(),
        "non-finite evidence in the gap tournament"
    );
    assert!(ard.warm_started, "se-ard3 must warm-start from the isotropic parent");
    println!(
        "ln Z(se-ard3) = {:.2}, ln Z(se-iso3) = {:.2}, ln B = {:.2}, winner = {} ({})",
        ard.evidence.ln_z,
        iso.evidence.ln_z,
        ln_b,
        result.winner().name(),
        human_time(tournament_seconds)
    );
    rows.push(Json::obj(vec![
        ("kind", "ard_gap".into()),
        ("n", gap_n.into()),
        ("threads", threads.into()),
        ("ln_z_iso", iso.evidence.ln_z.into()),
        ("ln_z_ard", ard.evidence.ln_z.into()),
        ("ln_b", ln_b.into()),
        ("winner", result.winner().name().into()),
        ("tournament_seconds", tournament_seconds.into()),
    ]));

    // merge the scenario section into BENCH_perf.json (keep other sections)
    let path = "BENCH_perf.json";
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    let mut sections = doc
        .get("sections")
        .and_then(|s| s.as_obj().cloned())
        .unwrap_or_default();
    sections.insert("scenario".to_string(), Json::Arr(rows));
    doc.insert("sections".to_string(), Json::Obj(sections));
    doc.insert("threads_available".to_string(), threads.into());
    match std::fs::write(path, Json::Obj(doc).pretty()) {
        Ok(()) => println!("\nscenario section merged into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
