//! Bench: the approximate-inference tier's accuracy-vs-cost panel —
//! Chalupka, Williams & Murray (2013) style, on the paper's synthetic k₂
//! truth.
//!
//! For each training size the exact `k2` and its approximations `sod-k2`
//! and `fitc-k2` are trained under an identical small optimiser budget
//! (1 restart, capped CG iterations), then scored on a held-out split
//! (every 6th point) with:
//!
//! * **SMSE** — mean squared error over the variance of the test
//!   targets (0 = perfect, 1 = predicting the mean);
//! * **MSLL** — mean standardised log loss: the negative predictive log
//!   density per test point minus the same under the trivial Gaussian
//!   fitted to the training targets (0 = no better than trivial,
//!   more negative = better-calibrated);
//! * **train wall-clock** per method.
//!
//! Exact training is `O(n³)` per evaluation, so in full mode it runs for
//! real only at the smallest size; at larger sizes its cost is estimated
//! as (one timed analytic value+gradient evaluation) × (the evaluation
//! count of the real run), its θ̂ transferred, and the row marked
//! `train_estimated` — logged, never silent. The approximate backends
//! always train for real: that gap is the point of the panel.
//!
//! Appends an `approx` section to **`BENCH_perf.json`** (merging with
//! other benches' sections). Row schema: `{method, n_train, n_test,
//! threads, n_evals, train_seconds, train_estimated, smse, msll}`.
//!
//! `cargo bench --bench approx`; set `GPFAST_BENCH_QUICK=1` for the
//! ci.sh smoke run (small n, everything real).

use gpfast::coordinator::{train_model, ModelSpec, TrainOptions};
use gpfast::data::synthetic::table1_dataset;
use gpfast::data::Dataset;
use gpfast::gp::serve::Predictor;
use gpfast::gp::{approx, profiled};
use gpfast::kernels::SYNTHETIC_SIGMA_N;
use gpfast::optimize::{CgOptions, MultistartOptions};
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;
use gpfast::util::{timer::human_time, Json, Table, TimingStats};

/// Optimiser budget shared by every method: what the panel times.
fn budget() -> TrainOptions {
    TrainOptions {
        multistart: MultistartOptions {
            restarts: 1,
            cg: CgOptions { max_iters: 15, ..Default::default() },
            ..Default::default()
        },
        extra_starts: Vec::new(),
    }
}

/// Split every 6th point into the held-out set.
fn split(full: &Dataset) -> (Dataset, Vec<f64>, Vec<f64>) {
    let mut tt = Vec::new();
    let mut ty = Vec::new();
    let mut ht = Vec::new();
    let mut hy = Vec::new();
    for i in 0..full.len() {
        if i % 6 == 5 {
            ht.push(full.t[i]);
            hy.push(full.y[i]);
        } else {
            tt.push(full.t[i]);
            ty.push(full.y[i]);
        }
    }
    (Dataset::new(tt, ty, format!("{}-train", full.label)), ht, hy)
}

/// SMSE and MSLL of a predictor on the held-out split, standardised
/// against the trivial Gaussian fitted to the training targets.
fn score(
    pred: &Predictor,
    train_y: &[f64],
    ht: &[f64],
    hy: &[f64],
    ctx: &ExecutionContext,
) -> (f64, f64) {
    let p = pred.predict_batch(ht, ctx);
    let nt = hy.len() as f64;
    let m0 = train_y.iter().sum::<f64>() / train_y.len() as f64;
    let v0 = train_y.iter().map(|v| (v - m0) * (v - m0)).sum::<f64>()
        / train_y.len() as f64;
    let var_test = {
        let m = hy.iter().sum::<f64>() / nt;
        hy.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / nt
    };
    let ln_2pi = (2.0 * std::f64::consts::PI).ln();
    let mut mse = 0.0;
    let mut nll = 0.0;
    let mut nll0 = 0.0;
    for i in 0..hy.len() {
        let d = hy[i] - p.mean[i];
        let v = (p.sd[i] * p.sd[i]).max(1e-300);
        mse += d * d;
        nll += 0.5 * ((v.ln() + ln_2pi) + d * d / v);
        let d0 = hy[i] - m0;
        nll0 += 0.5 * ((v0.ln() + ln_2pi) + d0 * d0 / v0);
    }
    (mse / nt / var_test, (nll - nll0) / nt)
}

fn main() {
    let ctx = ExecutionContext::from_env();
    let threads = ctx.threads();
    let quick = std::env::var("GPFAST_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    // total sizes; training sets are 5/6 of these (1 000 … 10 000 full)
    let totals: Vec<usize> = if quick { vec![240, 480] } else { vec![1200, 3840, 12000] };
    // exact k2 trains for real up to this total; beyond, estimated
    let exact_real_cap = if quick { usize::MAX } else { 1200 };
    let specs = [ModelSpec::K2, ModelSpec::SodK2, ModelSpec::FitcK2];
    println!(
        "== approx tier: accuracy vs training cost (threads {threads}{}) ==\n",
        if quick { ", quick mode" } else { "" }
    );
    let mut table =
        Table::new(vec!["n_train", "method", "evals", "train", "smse", "msll"]);
    let mut rows: Vec<Json> = Vec::new();
    // the real exact run everything larger extrapolates from
    let mut exact_ref: Option<(Vec<f64>, usize)> = None; // (theta_hat, n_evals)
    for &n_tot in &totals {
        let full = table1_dataset(n_tot, 0.1, 42);
        let (train, ht, hy) = split(&full);
        let n = train.len();
        for spec in &specs {
            let name = spec.name();
            let mut rng = Xoshiro256::seed_from_u64(1000 + n_tot as u64);
            let run_real = spec.approx().is_some() || n_tot <= exact_real_cap;
            let (theta, peak, n_evals, secs, estimated) = if run_real {
                let t0 = std::time::Instant::now();
                let res = train_model(spec, SYNTHETIC_SIGMA_N, &train, &budget(), 1, &ctx, &mut rng)
                    .expect("training failed");
                let secs = t0.elapsed().as_secs_f64();
                if *spec == ModelSpec::K2 {
                    exact_ref = Some((res.theta_hat.clone(), res.n_evals));
                }
                (res.theta_hat, res.peak_eval, res.n_evals, secs, false)
            } else {
                // transfer θ̂ from the real exact run, time one analytic
                // value+gradient evaluation, scale by its eval count
                let (theta, ref_evals) =
                    exact_ref.clone().expect("exact reference run missing");
                let model = spec.build(SYNTHETIC_SIGMA_N);
                let mut peak = None;
                let stats = TimingStats::measure(0, 1, || {
                    let (ev, _) =
                        profiled::eval_grad_with(&model, &train.t, &train.y, &theta, &ctx)
                            .expect("exact evaluation failed");
                    peak = Some(ev);
                });
                let secs = stats.min() * ref_evals as f64;
                println!(
                    "(exact k2 at n = {n}: estimated {} from one evaluation × {ref_evals} evals)",
                    human_time(secs)
                );
                (theta, peak.unwrap(), ref_evals, secs, true)
            };
            // spec-aware serving pair: full data for exact, the reduced
            // set for the approximations
            let (ts, ys) = match spec.approx() {
                None => (train.t.clone(), train.y.clone()),
                Some(kind) => approx::serve_parts(kind, &train.t, &train.y, &peak),
            };
            let model = spec.build(SYNTHETIC_SIGMA_N);
            let pred = Predictor::from_eval(model, ts, ys, theta, peak);
            let (smse, msll) = score(&pred, &train.y, &ht, &hy, &ctx);
            table.add_row(vec![
                format!("{n}"),
                format!("{name}{}", if estimated { "*" } else { "" }),
                format!("{n_evals}"),
                human_time(secs),
                format!("{smse:.4}"),
                format!("{msll:+.3}"),
            ]);
            rows.push(Json::obj(vec![
                ("method", name.into()),
                ("n_train", n.into()),
                ("n_test", hy.len().into()),
                ("threads", threads.into()),
                ("n_evals", n_evals.into()),
                ("train_seconds", secs.into()),
                ("train_estimated", usize::from(estimated).into()),
                ("smse", smse.into()),
                ("msll", msll.into()),
            ]));
        }
    }
    print!("{}", table.render());
    println!("(* exact cost estimated: one timed evaluation × the real run's eval count)");

    // merge the approx section into BENCH_perf.json
    let path = "BENCH_perf.json";
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    let mut sections = doc
        .get("sections")
        .and_then(|s| s.as_obj().cloned())
        .unwrap_or_default();
    sections.insert("approx".to_string(), Json::Arr(rows));
    doc.insert("sections".to_string(), Json::Obj(sections));
    doc.insert("threads_available".to_string(), threads.into());
    match std::fs::write(path, Json::Obj(doc).pretty()) {
        Ok(()) => println!("\napprox section merged into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
