//! Bench: layer-by-layer hot-path profile — the measurement harness
//! behind EXPERIMENTS.md §Perf, now serial *and* parallel.
//!
//! * L3 Cholesky GFLOP/s (the O(n³) hot path, n³/3 flops) across thread
//!   counts — the `ExecutionContext` scaling table
//! * L3 covariance assembly pair-rate (native per-pair kernel) across
//!   thread counts
//! * L3 O(n²) gradient-contraction rates (eq. 2.17 given the factor)
//! * end-to-end profiled eval+gradient cost at the paper's sizes,
//!   1 thread vs the full budget
//!
//! Besides the human tables, writes **`BENCH_perf.json`** (schema:
//! `{threads_available, sections: {cholesky|assembly|gradient|end_to_end:
//! [{n, threads, seconds, gflops|mpairs|speedup…}]}}`) so future PRs can
//! track the perf trajectory mechanically.
//!
//! `cargo bench --bench perf`

use gpfast::gp::profiled::ProfiledEval;
use gpfast::kernels::{paper_k2, PaperK2};
use gpfast::linalg::{Chol, Matrix};
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;
use gpfast::util::{timer::human_time, Json, Table, TimingStats};

fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
    // diagonally dominant random symmetric matrix (cheap to build)
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal() * 0.01;
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
        m[(i, i)] = 2.0;
    }
    m
}

/// Thread counts to sweep: 1, 2, 4 capped at the machine's parallelism
/// (oversubscribed rows would masquerade as scaling data in
/// BENCH_perf.json), plus the full machine if it has more cores.
fn thread_counts() -> Vec<usize> {
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut ts: Vec<usize> = [1usize, 2, 4].into_iter().filter(|&t| t <= avail).collect();
    if !ts.contains(&avail) {
        ts.push(avail);
    }
    ts
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(1);
    let threads = thread_counts();
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!("(machine parallelism: {avail}; sweeping threads {threads:?})\n");
    let mut j_chol: Vec<Json> = Vec::new();
    let mut j_asm: Vec<Json> = Vec::new();
    let mut j_grad: Vec<Json> = Vec::new();
    let mut j_e2e: Vec<Json> = Vec::new();

    println!("== L3 Cholesky (blocked, f64) ==");
    let mut t = Table::new(vec!["n", "threads", "time (min)", "GFLOP/s", "speedup"]);
    for &n in &[300usize, 600, 1000, 1968] {
        let k = random_spd(n, &mut rng);
        let reps = if n >= 1968 { 2 } else { 3 };
        let mut serial_secs = f64::NAN;
        for &nt in &threads {
            let ctx = ExecutionContext::new(nt);
            let stats = TimingStats::measure(1, reps, || {
                let _ = Chol::factor_with(&k, &ctx).unwrap();
            });
            let secs = stats.min();
            if nt == 1 {
                serial_secs = secs;
            }
            let gflops = (n as f64).powi(3) / 3.0 / secs / 1e9;
            let speedup = serial_secs / secs;
            t.add_row(vec![
                format!("{n}"),
                format!("{nt}"),
                human_time(secs),
                format!("{gflops:.2}"),
                format!("{speedup:.2}x"),
            ]);
            j_chol.push(Json::obj(vec![
                ("n", n.into()),
                ("threads", nt.into()),
                ("seconds", secs.into()),
                ("gflops", gflops.into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    print!("{}", t.render());

    println!("\n== L3 covariance assembly (native k2: value+grads per pair) ==");
    let model = paper_k2(0.1);
    let theta = PaperK2::truth();
    let mut t = Table::new(vec!["n", "threads", "time (min)", "Mpairs/s", "speedup"]);
    for &n in &[300usize, 1000, 1968] {
        let ts: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let reps = if n >= 1968 { 2 } else { 3 };
        let mut serial_secs = f64::NAN;
        for &nt in &threads {
            let ctx = ExecutionContext::new(nt);
            let stats = TimingStats::measure(1, reps, || {
                let _ = gpfast::gp::assemble_cov_grads_with(&model, &ts, &theta, &ctx);
            });
            let secs = stats.min();
            if nt == 1 {
                serial_secs = secs;
            }
            let rate = (n * n) as f64 / 2.0 / secs / 1e6;
            let speedup = serial_secs / secs;
            t.add_row(vec![
                format!("{n}"),
                format!("{nt}"),
                human_time(secs),
                format!("{rate:.1}"),
                format!("{speedup:.2}x"),
            ]);
            j_asm.push(Json::obj(vec![
                ("n", n.into()),
                ("threads", nt.into()),
                ("seconds", secs.into()),
                ("mpairs", rate.into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    print!("{}", t.render());

    println!("\n== L3 gradient contractions (eq. 2.17, given factor + W) ==");
    let mut t = Table::new(vec!["n", "threads", "time (min)", "speedup"]);
    for &n in &[1000usize, 1968] {
        let ts: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = ts.iter().map(|&x| (x * 0.51).sin()).collect();
        let setup_ctx = ExecutionContext::from_env();
        let (k, grads) = gpfast::gp::assemble_cov_grads_with(&model, &ts, &theta, &setup_ctx);
        let ev = ProfiledEval::from_cov_with(k, &y, &setup_ctx).unwrap();
        let w = ev.inverse_with(&setup_ctx);
        let mut serial_secs = f64::NAN;
        for &nt in &threads {
            let ctx = ExecutionContext::new(nt);
            let stats = TimingStats::measure(1, 3, || {
                let _ = ev.gradient_with(&grads, &w, &ctx);
            });
            let secs = stats.min();
            if nt == 1 {
                serial_secs = secs;
            }
            let speedup = serial_secs / secs;
            t.add_row(vec![
                format!("{n}"),
                format!("{nt}"),
                human_time(secs),
                format!("{speedup:.2}x"),
            ]);
            j_grad.push(Json::obj(vec![
                ("n", n.into()),
                ("threads", nt.into()),
                ("seconds", secs.into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    print!("{}", t.render());

    println!("\n== end-to-end profiled lnP + gradient (eqs. 2.16–2.17) ==");
    let full = *threads.last().unwrap();
    let mut t = Table::new(vec![
        "n".to_string(),
        "eval+grad (1t)".to_string(),
        format!("eval+grad ({full}t)"),
        "speedup".to_string(),
    ]);
    for &n in &[328usize, 1000, 1968] {
        let ts: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = ts.iter().map(|&x| (x * 0.51).sin()).collect();
        let reps = if n >= 1000 { 2 } else { 3 };
        let seq = ExecutionContext::seq();
        let par = ExecutionContext::new(full);
        let g1 = TimingStats::measure(1, reps, || {
            let _ = gpfast::gp::profiled::eval_grad_with(&model, &ts, &y, &theta, &seq).unwrap();
        });
        let gp = TimingStats::measure(1, reps, || {
            let _ = gpfast::gp::profiled::eval_grad_with(&model, &ts, &y, &theta, &par).unwrap();
        });
        let speedup = g1.min() / gp.min();
        t.add_row(vec![
            format!("{n}"),
            human_time(g1.min()),
            human_time(gp.min()),
            format!("{speedup:.2}x"),
        ]);
        // uniform per-section schema: one {n, threads, seconds, speedup}
        // entry per measured configuration
        j_e2e.push(Json::obj(vec![
            ("n", n.into()),
            ("threads", 1usize.into()),
            ("seconds", g1.min().into()),
            ("speedup", 1.0.into()),
        ]));
        j_e2e.push(Json::obj(vec![
            ("n", n.into()),
            ("threads", full.into()),
            ("seconds", gp.min().into()),
            ("speedup", speedup.into()),
        ]));
    }
    print!("{}", t.render());
    println!("\n(paper's yardstick: ~10 s per evaluation at n = 1968 on their machine)");

    let doc = Json::obj(vec![
        ("bench", "perf".into()),
        ("threads_available", avail.into()),
        (
            "sections",
            Json::obj(vec![
                ("cholesky", Json::Arr(j_chol)),
                ("assembly", Json::Arr(j_asm)),
                ("gradient", Json::Arr(j_grad)),
                ("end_to_end", Json::Arr(j_e2e)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_perf.json", doc.pretty()) {
        Ok(()) => println!("machine-readable results written to BENCH_perf.json"),
        Err(e) => eprintln!("could not write BENCH_perf.json: {e}"),
    }
}
