//! Bench: layer-by-layer hot-path profile — the measurement harness
//! behind EXPERIMENTS.md §Perf, now serial *and* parallel.
//!
//! * register micro-kernel GEMM/SYRK GFLOP/s vs a naive scalar triple
//!   loop (the pre-micro-kernel baseline), single thread
//! * L3 Cholesky GFLOP/s (the O(n³) hot path, n³/3 flops) across thread
//!   counts — the `ExecutionContext` scaling table
//! * L3 covariance assembly pair-rate (native per-pair kernel) across
//!   thread counts
//! * L3 O(n²) gradient-contraction rates (eq. 2.17 given the factor)
//! * end-to-end profiled eval+gradient cost at the paper's sizes,
//!   1 thread vs the full budget
//!
//! Besides the human tables, writes **`BENCH_perf.json`** (schema:
//! `{threads_available, sections: {gemm|syrk|cholesky|assembly|gradient|
//! end_to_end: [{n, threads, seconds, gflops|mpairs|speedup…}]}}`) so
//! future PRs can track the perf trajectory mechanically.
//!
//! `cargo bench --bench perf`
//!
//! Set `GPFAST_BENCH_QUICK=1` for the ci.sh smoke run: small sizes, the
//! heavyweight gradient/end-to-end sections skipped, but the gemm/syrk
//! sections always populated so the trajectory file stays comparable.

use gpfast::gp::profiled::ProfiledEval;
use gpfast::kernels::{paper_k2, PaperK2};
use gpfast::linalg::micro::{self, Clip};
use gpfast::linalg::{Chol, Matrix};
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;
use gpfast::util::{timer::human_time, Json, Table, TimingStats};

fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
    // diagonally dominant random symmetric matrix (cheap to build)
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal() * 0.01;
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
        m[(i, i)] = 2.0;
    }
    m
}

/// Thread counts to sweep: 1, 2, 4 capped at the machine's parallelism
/// (oversubscribed rows would masquerade as scaling data in
/// BENCH_perf.json), plus the full machine if it has more cores.
fn thread_counts() -> Vec<usize> {
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut ts: Vec<usize> = [1usize, 2, 4].into_iter().filter(|&t| t <= avail).collect();
    if !ts.contains(&avail) {
        ts.push(avail);
    }
    ts
}

/// Naive scalar i-k-j GEMM — the shape of the pre-micro-kernel matmul.
fn naive_gemm(c: &mut [f64], n: usize, a: &[f64], b: &[f64]) {
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                c[i * n + j] += aik * b[k * n + j];
            }
        }
    }
}

/// Naive scalar lower-triangle SYRK `C −= P·Pᵀ` — the shape of the
/// pre-micro-kernel trailing update.
fn naive_syrk(c: &mut [f64], n: usize, k: usize, p: &[f64]) {
    for i in 0..n {
        for j in 0..=i {
            let mut acc = 0.0;
            for kk in 0..k {
                acc += p[i * k + kk] * p[j * k + kk];
            }
            c[i * n + j] -= acc;
        }
    }
}

fn main() {
    let quick = std::env::var("GPFAST_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let mut rng = Xoshiro256::seed_from_u64(1);
    let threads = thread_counts();
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "(machine parallelism: {avail}; sweeping threads {threads:?}{})\n",
        if quick { "; QUICK smoke sizes" } else { "" }
    );
    let mut j_gemm: Vec<Json> = Vec::new();
    let mut j_syrk: Vec<Json> = Vec::new();
    let mut j_chol: Vec<Json> = Vec::new();
    let mut j_asm: Vec<Json> = Vec::new();
    let mut j_grad: Vec<Json> = Vec::new();
    let mut j_e2e: Vec<Json> = Vec::new();

    println!("== register micro-kernel GEMM vs naive scalar (1 thread) ==");
    let gemm_sizes: &[usize] = if quick { &[160, 256] } else { &[256, 512, 1024, 1968] };
    let mut t = Table::new(vec!["n", "micro", "GFLOP/s", "naive", "GFLOP/s", "speedup"]);
    for &n in gemm_sizes {
        let mut a = Matrix::zeros(n, n);
        let mut b = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.normal();
                b[(i, j)] = rng.normal();
            }
        }
        let flops = 2.0 * (n as f64).powi(3);
        let reps = if n >= 1024 { 2 } else { 3 };
        let micro_stats = TimingStats::measure(1, reps, || {
            let _ = a.matmul(&b); // seq context → single-thread micro GEMM
        });
        // same warmup policy as the micro side so the recorded speedup
        // compares warm runs to warm runs (the naive side is merely
        // capped at one timed rep at large n — it is slow)
        let naive_stats = TimingStats::measure(1, if n >= 1024 { 1 } else { reps }, || {
            let mut c = vec![0.0; n * n];
            naive_gemm(&mut c, n, a.as_slice(), b.as_slice());
            std::hint::black_box(&c);
        });
        let (ms, ns) = (micro_stats.min(), naive_stats.min());
        let (mg, ng) = (flops / ms / 1e9, flops / ns / 1e9);
        t.add_row(vec![
            format!("{n}"),
            human_time(ms),
            format!("{mg:.2}"),
            human_time(ns),
            format!("{ng:.2}"),
            format!("{:.2}x", ns / ms),
        ]);
        j_gemm.push(Json::obj(vec![
            ("n", n.into()),
            ("threads", 1usize.into()),
            ("seconds", ms.into()),
            ("gflops", mg.into()),
            ("naive_seconds", ns.into()),
            ("naive_gflops", ng.into()),
            ("speedup", (ns / ms).into()),
        ]));
    }
    print!("{}", t.render());

    println!("\n== register micro-kernel SYRK (lower, k=64 panel) vs naive scalar ==");
    let mut t = Table::new(vec!["n", "micro", "GFLOP/s", "naive", "GFLOP/s", "speedup"]);
    for &n in gemm_sizes {
        let kdim = 64usize; // the Cholesky panel width NB
        let p: Vec<f64> = (0..n * kdim).map(|_| rng.normal()).collect();
        let flops = (n * (n + 1)) as f64 * kdim as f64; // 2·k·n(n+1)/2
        let reps = if n >= 1024 { 2 } else { 3 };
        let micro_stats = TimingStats::measure(1, reps, || {
            let mut c = vec![0.0; n * n];
            micro::gemm_nt(&mut c, n, n, n, kdim, &p, kdim, &p, kdim, -1.0, Clip::Lower(0));
            std::hint::black_box(&c);
        });
        let naive_stats = TimingStats::measure(1, if n >= 1024 { 1 } else { reps }, || {
            let mut c = vec![0.0; n * n];
            naive_syrk(&mut c, n, kdim, &p);
            std::hint::black_box(&c);
        });
        let (ms, ns) = (micro_stats.min(), naive_stats.min());
        let (mg, ng) = (flops / ms / 1e9, flops / ns / 1e9);
        t.add_row(vec![
            format!("{n}"),
            human_time(ms),
            format!("{mg:.2}"),
            human_time(ns),
            format!("{ng:.2}"),
            format!("{:.2}x", ns / ms),
        ]);
        j_syrk.push(Json::obj(vec![
            ("n", n.into()),
            ("threads", 1usize.into()),
            ("seconds", ms.into()),
            ("gflops", mg.into()),
            ("naive_seconds", ns.into()),
            ("naive_gflops", ng.into()),
            ("speedup", (ns / ms).into()),
        ]));
    }
    print!("{}", t.render());

    println!("\n== L3 Cholesky (blocked, f64) ==");
    let chol_sizes: &[usize] = if quick { &[256] } else { &[300, 600, 1000, 1968] };
    let mut t = Table::new(vec!["n", "threads", "time (min)", "GFLOP/s", "speedup"]);
    for &n in chol_sizes {
        let k = random_spd(n, &mut rng);
        let reps = if n >= 1968 { 2 } else { 3 };
        let mut serial_secs = f64::NAN;
        for &nt in &threads {
            let ctx = ExecutionContext::new(nt);
            let stats = TimingStats::measure(1, reps, || {
                let _ = Chol::factor_with(&k, &ctx).unwrap();
            });
            let secs = stats.min();
            if nt == 1 {
                serial_secs = secs;
            }
            let gflops = (n as f64).powi(3) / 3.0 / secs / 1e9;
            let speedup = serial_secs / secs;
            t.add_row(vec![
                format!("{n}"),
                format!("{nt}"),
                human_time(secs),
                format!("{gflops:.2}"),
                format!("{speedup:.2}x"),
            ]);
            j_chol.push(Json::obj(vec![
                ("n", n.into()),
                ("threads", nt.into()),
                ("seconds", secs.into()),
                ("gflops", gflops.into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    print!("{}", t.render());

    println!("\n== L3 covariance assembly (native k2: value+grads per pair) ==");
    let model = paper_k2(0.1);
    let theta = PaperK2::truth();
    let asm_sizes: &[usize] = if quick { &[256] } else { &[300, 1000, 1968] };
    let mut t = Table::new(vec!["n", "threads", "time (min)", "Mpairs/s", "speedup"]);
    for &n in asm_sizes {
        let ts: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let reps = if n >= 1968 { 2 } else { 3 };
        let mut serial_secs = f64::NAN;
        for &nt in &threads {
            let ctx = ExecutionContext::new(nt);
            let stats = TimingStats::measure(1, reps, || {
                let _ = gpfast::gp::assemble_cov_grads_with(&model, &ts, &theta, &ctx);
            });
            let secs = stats.min();
            if nt == 1 {
                serial_secs = secs;
            }
            let rate = (n * n) as f64 / 2.0 / secs / 1e6;
            let speedup = serial_secs / secs;
            t.add_row(vec![
                format!("{n}"),
                format!("{nt}"),
                human_time(secs),
                format!("{rate:.1}"),
                format!("{speedup:.2}x"),
            ]);
            j_asm.push(Json::obj(vec![
                ("n", n.into()),
                ("threads", nt.into()),
                ("seconds", secs.into()),
                ("mpairs", rate.into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    print!("{}", t.render());

    println!("\n== L3 gradient contractions (eq. 2.17, given factor + W) ==");
    let grad_sizes: &[usize] = if quick { &[] } else { &[1000, 1968] };
    let mut t = Table::new(vec!["n", "threads", "time (min)", "speedup"]);
    for &n in grad_sizes {
        let ts: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = ts.iter().map(|&x| (x * 0.51).sin()).collect();
        let setup_ctx = ExecutionContext::from_env();
        let (k, grads) = gpfast::gp::assemble_cov_grads_with(&model, &ts, &theta, &setup_ctx);
        let ev = ProfiledEval::from_cov_with(k, &y, &setup_ctx).unwrap();
        let w = ev.inverse_with(&setup_ctx);
        let mut serial_secs = f64::NAN;
        for &nt in &threads {
            let ctx = ExecutionContext::new(nt);
            let stats = TimingStats::measure(1, 3, || {
                let _ = ev.gradient_with(&grads, &w, &ctx);
            });
            let secs = stats.min();
            if nt == 1 {
                serial_secs = secs;
            }
            let speedup = serial_secs / secs;
            t.add_row(vec![
                format!("{n}"),
                format!("{nt}"),
                human_time(secs),
                format!("{speedup:.2}x"),
            ]);
            j_grad.push(Json::obj(vec![
                ("n", n.into()),
                ("threads", nt.into()),
                ("seconds", secs.into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    print!("{}", t.render());

    println!("\n== end-to-end profiled lnP + gradient (eqs. 2.16–2.17) ==");
    let full = *threads.last().unwrap();
    let mut t = Table::new(vec![
        "n".to_string(),
        "eval+grad (1t)".to_string(),
        format!("eval+grad ({full}t)"),
        "speedup".to_string(),
    ]);
    let e2e_sizes: &[usize] = if quick { &[] } else { &[328, 1000, 1968] };
    for &n in e2e_sizes {
        let ts: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = ts.iter().map(|&x| (x * 0.51).sin()).collect();
        let reps = if n >= 1000 { 2 } else { 3 };
        let seq = ExecutionContext::seq();
        let par = ExecutionContext::new(full);
        let g1 = TimingStats::measure(1, reps, || {
            let _ = gpfast::gp::profiled::eval_grad_with(&model, &ts, &y, &theta, &seq).unwrap();
        });
        let gp = TimingStats::measure(1, reps, || {
            let _ = gpfast::gp::profiled::eval_grad_with(&model, &ts, &y, &theta, &par).unwrap();
        });
        let speedup = g1.min() / gp.min();
        t.add_row(vec![
            format!("{n}"),
            human_time(g1.min()),
            human_time(gp.min()),
            format!("{speedup:.2}x"),
        ]);
        // uniform per-section schema: one {n, threads, seconds, speedup}
        // entry per measured configuration
        j_e2e.push(Json::obj(vec![
            ("n", n.into()),
            ("threads", 1usize.into()),
            ("seconds", g1.min().into()),
            ("speedup", 1.0.into()),
        ]));
        j_e2e.push(Json::obj(vec![
            ("n", n.into()),
            ("threads", full.into()),
            ("seconds", gp.min().into()),
            ("speedup", speedup.into()),
        ]));
    }
    print!("{}", t.render());
    println!("\n(paper's yardstick: ~10 s per evaluation at n = 1968 on their machine)");

    // merge into BENCH_perf.json: only overwrite the sections this run
    // actually measured, so the quick smoke doesn't clobber the `serve`
    // section or a prior full-size sweep's gradient/end-to-end rows
    let path = "BENCH_perf.json";
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    let mut sections = doc
        .get("sections")
        .and_then(|s| s.as_obj().cloned())
        .unwrap_or_default();
    for (name, rows) in [
        ("gemm", j_gemm),
        ("syrk", j_syrk),
        ("cholesky", j_chol),
        ("assembly", j_asm),
        ("gradient", j_grad),
        ("end_to_end", j_e2e),
    ] {
        if !rows.is_empty() {
            sections.insert(name.to_string(), Json::Arr(rows));
        }
    }
    doc.insert("bench".to_string(), "perf".into());
    doc.insert("sections".to_string(), Json::Obj(sections));
    doc.insert("threads_available".to_string(), avail.into());
    match std::fs::write(path, Json::Obj(doc).pretty()) {
        Ok(()) => println!("machine-readable results merged into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
