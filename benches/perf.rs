//! Bench: layer-by-layer hot-path profile — the measurement harness
//! behind EXPERIMENTS.md §Perf.
//!
//! * L3 Cholesky GFLOP/s (the O(n³) hot path, n³/3 flops)
//! * L3 covariance assembly pair-rate (native per-pair kernel)
//! * L3 O(n²) contraction rates (gradient eq. 2.17 given the factor)
//! * end-to-end profiled eval+gradient cost at the paper's sizes
//!
//! `cargo bench --bench perf`

use gpfast::kernels::{paper_k2, PaperK2};
use gpfast::linalg::{Chol, Matrix};
use gpfast::rng::Xoshiro256;
use gpfast::util::{timer::human_time, Table, TimingStats};

fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
    // diagonally dominant random symmetric matrix (cheap to build)
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal() * 0.01;
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
        m[(i, i)] = 2.0;
    }
    m
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(1);

    println!("== L3 Cholesky (blocked, f64, single core) ==");
    let mut t = Table::new(vec!["n", "time (min)", "GFLOP/s"]);
    for &n in &[300usize, 600, 1000, 1968] {
        let k = random_spd(n, &mut rng);
        let reps = if n >= 1968 { 3 } else { 5 };
        let stats = TimingStats::measure(1, reps, || {
            let _ = Chol::factor(&k).unwrap();
        });
        let gflops = (n as f64).powi(3) / 3.0 / stats.min() / 1e9;
        t.add_row(vec![format!("{n}"), human_time(stats.min()), format!("{gflops:.2}")]);
    }
    print!("{}", t.render());

    println!("\n== L3 covariance assembly (native k2: value+grads per pair) ==");
    let model = paper_k2(0.1);
    let theta = PaperK2::truth();
    let mut t = Table::new(vec!["n", "time (min)", "Mpairs/s"]);
    for &n in &[300usize, 1000, 1968] {
        let ts: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let reps = if n >= 1968 { 3 } else { 5 };
        let stats = TimingStats::measure(1, reps, || {
            let _ = gpfast::gp::assemble_cov_grads(&model, &ts, &theta);
        });
        let rate = (n * n) as f64 / 2.0 / stats.min() / 1e6;
        t.add_row(vec![format!("{n}"), human_time(stats.min()), format!("{rate:.1}")]);
    }
    print!("{}", t.render());

    println!("\n== end-to-end profiled lnP + gradient (eqs. 2.16–2.17) ==");
    let mut t = Table::new(vec!["n", "eval+grad", "eval only"]);
    for &n in &[100usize, 300, 328, 1000, 1968] {
        let ts: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let y: Vec<f64> = ts.iter().map(|&x| (x * 0.51).sin()).collect();
        let reps = if n >= 1000 { 3 } else { 5 };
        let g = TimingStats::measure(1, reps, || {
            let _ = gpfast::gp::profiled::eval_grad(&model, &ts, &y, &theta).unwrap();
        });
        let v = TimingStats::measure(1, reps, || {
            let _ = gpfast::gp::profiled::eval(&model, &ts, &y, &theta).unwrap();
        });
        t.add_row(vec![
            format!("{n}"),
            human_time(g.min()),
            human_time(v.min()),
        ]);
    }
    print!("{}", t.render());
    println!("\n(paper's yardstick: ~10 s per evaluation at n = 1968 on their machine)");
}
