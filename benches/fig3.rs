//! Bench: **Fig. 3 / §3(b)** — the tidal experiment. Trains k₁ and k₂ on
//! the simulated Woods-Hole series at both paper sizes, reporting
//! recovered timescales (hours ± σ), log Bayes factors, per-evaluation
//! cost (the paper quotes ~10 s/eval at n = 1968 on their hardware), and
//! the week-scale interpolant agreement of the figure's inset.
//!
//! `cargo bench --bench fig3` (`GPFAST_BENCH_FAST=1` → n = 328 only)

use gpfast::coordinator::{ComparisonPipeline, PipelineConfig};
use gpfast::data::tidal;
use gpfast::kernels::TIDAL_SIGMA_N;
use gpfast::rng::Xoshiro256;
use gpfast::util::{Stopwatch, Table, TimingStats};

fn main() {
    let fast = std::env::var("GPFAST_BENCH_FAST").is_ok();
    let full = tidal::generate_tidal(&tidal::TidalConfig::six_lunar_months(20160125));
    let small = full.head(tidal::TidalConfig::LUNAR_MONTH_N).demean();
    let large = full.demean();
    let datasets = if fast { vec![small] } else { vec![small, large] };

    for data in datasets {
        println!("== Fig. 3 / §3(b): {} (n = {}) ==", data.label, data.len());

        // per-evaluation cost at this size (the paper's ~10 s yardstick)
        let model = gpfast::kernels::paper_k2(TIDAL_SIGMA_N);
        let theta0 = vec![5.5, 2.5, 0.0, 3.2, 0.0];
        let cost = TimingStats::measure(1, 3, || {
            let _ = gpfast::gp::profiled::eval_grad(&model, &data.t, &data.y, &theta0);
        });
        println!("one lnP+gradient evaluation: {}", cost.summary());

        let mut cfg = PipelineConfig::paper_synthetic();
        cfg.sigma_n = TIDAL_SIGMA_N;
        let mut rng = Xoshiro256::seed_from_u64(7);
        let sw = Stopwatch::start();
        let report = ComparisonPipeline::new(cfg).run(&data, &mut rng).expect("pipeline");
        println!("training both models: {:.1} s total", sw.elapsed_secs());

        let mut table = Table::new(vec!["model", "param", "T (hours)", "σ_T", "lnZ_est"]);
        for m in &report.models {
            for ((name, th), sg) in m.param_names.iter().zip(&m.theta_hat).zip(&m.sigma) {
                if name.starts_with("phi") && name != "phi0" {
                    let t_h = th.exp();
                    table.add_row(vec![
                        m.name.clone(),
                        name.clone(),
                        format!("{t_h:.2}"),
                        format!("{:.2}", t_h * sg),
                        format!("{:.1}", m.ln_z),
                    ]);
                }
            }
        }
        print!("{}", table.render());
        if let Some(lnb) = report.ln_bayes("k2", "k1") {
            println!("ln B(k2 over k1) = {lnb:.1}");
        }
        println!("paper: T1 = 12.8±0.2 h (k1), T1 = 12.44±0.07 h & T2 = 24.3±1.0 h (k2), lnB = 57.8 @ n=328");
        println!("       T1 = 12.80±0.11 h (k1), T1 = 12.40±0.03 h & T2 = 23.3±0.3 h (k2), lnB = 538 @ n=1968\n");
    }
}
