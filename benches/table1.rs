//! Bench: regenerate **Table 1** — Laplace ln Z_est vs nested ln Z_num
//! for k₁ and k₂ on k₂-drawn synthetic data at n ∈ {30, 100, 300}.
//!
//! `cargo bench --bench table1` (set `GPFAST_BENCH_FAST=1` to shrink).
//!
//! Expected *shape* versus the paper: ln B grows with n and favours k₂
//! by n = 100+; est and num agree within a few σ except possibly the
//! (k₂, n = 30) case, which the paper itself flags as a Laplace failure
//! (multimodal/degenerate posterior).

use gpfast::coordinator::{ComparisonPipeline, PipelineConfig};
use gpfast::data::synthetic::table1_dataset;
use gpfast::nested::NestedOptions;
use gpfast::rng::Xoshiro256;
use gpfast::util::{Stopwatch, Table};

fn main() {
    let fast = std::env::var("GPFAST_BENCH_FAST").is_ok();
    let sizes: &[usize] = if fast { &[30, 100] } else { &[30, 100, 300] };
    let nlive = if fast { 150 } else { 400 };

    println!("== Table 1: Laplace vs nested-sampling hyperevidence ==\n");
    let mut table = Table::new(vec![
        "n", "lnZ_est^k1", "lnZ_num^k1", "lnZ_est^k2", "lnZ_num^k2", "lnB_est", "lnB_num",
        "t_fast", "t_nested",
    ]);
    for &n in sizes {
        let data = table1_dataset(n, 0.1, 20160125);
        let mut cfg = PipelineConfig::paper_synthetic();
        cfg.run_nested = true;
        cfg.nested = NestedOptions { nlive, ..Default::default() };
        let mut rng = Xoshiro256::seed_from_u64(n as u64);
        let sw = Stopwatch::start();
        let report = ComparisonPipeline::new(cfg).run(&data, &mut rng).expect("pipeline");
        let _total = sw.elapsed_secs();
        let k1 = report.model("k1").unwrap();
        let k2 = report.model("k2").unwrap();
        let (n1, n2) = (k1.nested.as_ref().unwrap(), k2.nested.as_ref().unwrap());
        let t_fast = k1.wall_secs + k2.wall_secs - n1.wall_secs - n2.wall_secs;
        let flag = |s: bool| if s { "*" } else { "" };
        table.add_row(vec![
            format!("{n}"),
            format!("{:.2}{}", k1.ln_z, flag(k1.suspect)),
            format!("{:.2} ± {:.2}", n1.ln_z, n1.ln_z_err),
            format!("{:.2}{}", k2.ln_z, flag(k2.suspect)),
            format!("{:.2} ± {:.2}", n2.ln_z, n2.ln_z_err),
            format!("{:.2}", k2.ln_z - k1.ln_z),
            format!(
                "{:.2} ± {:.2}",
                n2.ln_z - n1.ln_z,
                (n1.ln_z_err.powi(2) + n2.ln_z_err.powi(2)).sqrt()
            ),
            format!("{t_fast:.1}s"),
            format!("{:.1}s", n1.wall_secs + n2.wall_secs),
        ]);
    }
    print!("{}", table.render());
    println!("\n(* = Laplace flagged SUSPECT — the paper's bold-faced (k2, n=30) analogue)");
    println!("paper values: lnB_num = 0.14±0.12 (n=30), 0.95±0.15 (n=100), 9.76±0.17 (n=300)");
}
