//! Bench: the multi-tenant serving fleet — Zipf-distributed predict
//! traffic from 10k+ sessions funnelled through a bounded LRU of
//! hydrated factors (capacity ≪ session count), plus the cross-session
//! batch scheduler and a per-`n` breakdown of what a cold hydration
//! actually costs.
//!
//! Appends a `fleet` section to **`BENCH_perf.json`** (merging with the
//! sections other benches wrote). Row schema:
//!
//! * `workload`: `{n, sessions, capacity, requests, threads, seconds,
//!   sessions_per_sec, hit_rate, hydration_rate, hit_p50_us, hit_p99_us,
//!   cold_p50_us, cold_p99_us, p50_us, p99_us, hydrations, evictions,
//!   persisted}` — one `Fleet::predict` per request over a Zipf(s=1.1)
//!   session stream; requests are bucketed **hot** (session resident
//!   before the call) vs **cold** (the call pays hydration). The bench
//!   asserts the tentpole's economics in-process: hot p50 strictly
//!   below cold p50.
//! * `batch`: `{n, sessions, capacity, requests, threads, seconds,
//!   requests_per_sec}` — the same traffic shape submitted as one
//!   [`Fleet::run_batch`] call per chunk, so per-session groups share a
//!   multi-RHS solve and the wave drains concurrently.
//! * `hydrate_split`: `{n, threads, artifact_bytes, parse_us, adopt_us,
//!   hydrations}` — a capacity-1 fleet thrashing between two sessions so
//!   every lookup hydrates; the fleet's phase timers split the cost into
//!   artifact **parse** (bytes → `TrainedModel`) vs factor **adopt**
//!   (`TrainedModel` → live session). These numbers scope the zero-copy
//!   artifact roadmap item.
//!
//! `cargo bench --bench fleet`; set `GPFAST_BENCH_QUICK=1` for the
//! ci.sh smoke run (smaller n and request counts — still ≥ 10k
//! sessions, the point of the exercise).

use gpfast::coordinator::{
    ArtifactStore, Fleet, MemoryStore, ModelSpec, PredictRequest, TrainResult, TrainedModel,
    ZipfWorkload,
};
use gpfast::data::synthetic::table1_dataset;
use gpfast::data::Dataset;
use gpfast::evidence::LaplaceEvidence;
use gpfast::gp::profiled;
use gpfast::linalg::Matrix;
use gpfast::priors::BoxPrior;
use gpfast::runtime::ExecutionContext;
use gpfast::util::{timer::human_time, Json, Stopwatch, Table};

/// Deterministic artifact without running the optimiser: one profiled
/// evaluation at the prior mid-point (the persistence-suite recipe —
/// fleet traffic is about serving, not about training quality).
fn make_artifact(spec: ModelSpec, data: &Dataset) -> TrainedModel {
    let sigma_n = 0.1;
    let model = spec.build(sigma_n);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let mut theta: Vec<f64> = prior.bounds.iter().map(|(lo, hi)| 0.5 * (lo + hi)).collect();
    prior.project(&mut theta);
    let ev = profiled::eval(&model, &data.t, &data.y, &theta).expect("mid-prior eval");
    let m = model.dim();
    TrainedModel {
        spec,
        sigma_n,
        param_names: model.kernel.names(),
        train: TrainResult {
            theta_hat: theta,
            lnp_peak: ev.lnp,
            sigma_f_hat2: ev.sigma_f_hat2,
            jitter: ev.jitter,
            peak_eval: ev,
            converged: true,
            n_evals: 0,
            n_modes: 1,
            restart_values: Vec::new(),
        },
        evidence: LaplaceEvidence {
            ln_z: -10.0,
            ln_p_peak: -10.0,
            ln_det_h: 0.0,
            ln_volume: 0.0,
            marg_const: 0.0,
            sigma: vec![0.0; m],
            covariance: Matrix::zeros(m, m),
            suspect: false,
        },
        nested: None,
        warm_started: false,
        restarts: 0,
        wall_secs: 0.0,
    }
}

fn session_id(rank: usize) -> String {
    format!("s{rank:05}")
}

/// p-th percentile of an already-sorted latency list (µs).
fn pct(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * p).round() as usize;
    sorted_us[idx]
}

fn main() {
    let ctx = ExecutionContext::from_env();
    let threads = ctx.threads();
    let quick = std::env::var("GPFAST_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    println!("(thread budget: {threads}{})\n", if quick { ", quick mode" } else { "" });
    let mut rows: Vec<Json> = Vec::new();

    // one trained artifact shared (byte-wise) by every cold session: the
    // fleet's cache behaviour depends on ids and sizes, not on which
    // model each tenant happens to own
    let n = if quick { 24 } else { 48 };
    let sessions = if quick { 10_000 } else { 20_000 };
    let capacity = if quick { 64 } else { 128 };
    let requests = if quick { 1_500 } else { 8_000 };
    let data = table1_dataset(n, 0.1, 5);
    let blob = make_artifact(ModelSpec::K1, &data).to_bytes(&data).expect("encode");
    println!(
        "== Zipf workload: {sessions} sessions × {} B artifacts, LRU capacity {capacity} ==",
        blob.len()
    );
    let sw = Stopwatch::start();
    let mut store = MemoryStore::new();
    for rank in 0..sessions {
        store.put(&session_id(rank), vec![blob.clone()]).expect("seed store");
    }
    println!(
        "store seeded: {} sessions, {:.1} MiB cold tier, {}",
        store.len().unwrap(),
        store.total_bytes().unwrap() as f64 / (1024.0 * 1024.0),
        human_time(sw.elapsed_secs())
    );

    // --- per-request predicts through the LRU, hot/cold bucketed
    let mut fleet = Fleet::new(store, capacity, ctx.clone());
    let mut zipf = ZipfWorkload::new(sessions, 1.1, 0x5eed_f1ee);
    let q = 8usize;
    let span = data.t[data.t.len() - 1] - data.t[0];
    let t_star: Vec<f64> =
        (0..q).map(|i| data.t[0] + span * (i as f64 + 0.5) / q as f64).collect();
    let mut hot_us: Vec<f64> = Vec::new();
    let mut cold_us: Vec<f64> = Vec::new();
    let sw = Stopwatch::start();
    for _ in 0..requests {
        let id = session_id(zipf.next_session());
        let resident = fleet.is_resident(&id);
        let one = Stopwatch::start();
        let _ = fleet.predict(&id, &t_star).expect("fleet predict");
        let us = one.elapsed_secs() * 1e6;
        if resident {
            hot_us.push(us);
        } else {
            cold_us.push(us);
        }
    }
    let seconds = sw.elapsed_secs();
    let stats = fleet.stats();
    let mut all_us: Vec<f64> = hot_us.iter().chain(&cold_us).copied().collect();
    hot_us.sort_by(f64::total_cmp);
    cold_us.sort_by(f64::total_cmp);
    all_us.sort_by(f64::total_cmp);
    assert!(
        !hot_us.is_empty() && !cold_us.is_empty(),
        "workload must exercise both hot and cold paths (hot {}, cold {})",
        hot_us.len(),
        cold_us.len()
    );
    let hit_p50 = pct(&hot_us, 0.50);
    let cold_p50 = pct(&cold_us, 0.50);
    assert!(
        hit_p50 < cold_p50,
        "cache economics inverted: hot p50 {hit_p50:.1}µs ≥ cold p50 {cold_p50:.1}µs"
    );
    let mut table = Table::new(vec!["metric", "value"]);
    table.add_row(vec!["sessions/sec".into(), format!("{:.0}", requests as f64 / seconds)]);
    table.add_row(vec!["hit rate".into(), format!("{:.3}", stats.hit_rate())]);
    table.add_row(vec!["hydration rate".into(), format!("{:.3}", stats.hydration_rate())]);
    table.add_row(vec![
        "hot p50 / p99".into(),
        format!("{:.1}µs / {:.1}µs", hit_p50, pct(&hot_us, 0.99)),
    ]);
    table.add_row(vec![
        "cold p50 / p99".into(),
        format!("{:.1}µs / {:.1}µs", cold_p50, pct(&cold_us, 0.99)),
    ]);
    table.add_row(vec![
        "hydrations / evictions / persisted".into(),
        format!("{} / {} / {}", stats.hydrations, stats.evictions, stats.persisted),
    ]);
    print!("{}", table.render());
    rows.push(Json::obj(vec![
        ("kind", "workload".into()),
        ("n", n.into()),
        ("sessions", sessions.into()),
        ("capacity", capacity.into()),
        ("requests", requests.into()),
        ("threads", threads.into()),
        ("seconds", seconds.into()),
        ("sessions_per_sec", (requests as f64 / seconds).into()),
        ("hit_rate", stats.hit_rate().into()),
        ("hydration_rate", stats.hydration_rate().into()),
        ("hit_p50_us", hit_p50.into()),
        ("hit_p99_us", pct(&hot_us, 0.99).into()),
        ("cold_p50_us", cold_p50.into()),
        ("cold_p99_us", pct(&cold_us, 0.99).into()),
        ("p50_us", pct(&all_us, 0.50).into()),
        ("p99_us", pct(&all_us, 0.99).into()),
        ("hydrations", (stats.hydrations as usize).into()),
        ("evictions", (stats.evictions as usize).into()),
        ("persisted", (stats.persisted as usize).into()),
    ]));

    // --- the same traffic shape as scheduler batches
    println!("\n== batch scheduler: run_batch over the same Zipf stream ==");
    let batch_requests = if quick { 1_024 } else { 4_096 };
    let chunk = 256usize;
    let mut zipf = ZipfWorkload::new(sessions, 1.1, 0xba7c_4);
    let reqs: Vec<PredictRequest> = (0..batch_requests)
        .map(|_| PredictRequest {
            session_id: session_id(zipf.next_session()),
            t_star: t_star.clone(),
        })
        .collect();
    let sw = Stopwatch::start();
    for chunk_reqs in reqs.chunks(chunk) {
        let preds = fleet.run_batch(chunk_reqs).expect("run_batch");
        assert_eq!(preds.len(), chunk_reqs.len());
    }
    let batch_seconds = sw.elapsed_secs();
    println!(
        "{batch_requests} requests in {} ({:.0} requests/sec, chunks of {chunk})",
        human_time(batch_seconds),
        batch_requests as f64 / batch_seconds
    );
    rows.push(Json::obj(vec![
        ("kind", "batch".into()),
        ("n", n.into()),
        ("sessions", sessions.into()),
        ("capacity", capacity.into()),
        ("requests", batch_requests.into()),
        ("threads", threads.into()),
        ("seconds", batch_seconds.into()),
        ("requests_per_sec", (batch_requests as f64 / batch_seconds).into()),
    ]));

    // --- what one hydration costs, split parse vs view vs adopt, per n
    // and per artifact version: v3 pays the field-stream parse, v4 pays
    // only zero-copy view establishment (checksum + validation) before
    // the same O(n²) adoption
    println!("\n== hydration cost split: parse vs zero-copy view vs factor adoption ==");
    let mut table =
        Table::new(vec!["n", "ver", "artifact", "parse", "view", "adopt", "hydrations"]);
    let split_sizes: Vec<usize> = if quick { vec![24, 48] } else { vec![64, 128, 256] };
    for &sn in &split_sizes {
        let sdata = table1_dataset(sn, 0.1, 5);
        let tm = make_artifact(ModelSpec::K1, &sdata);
        let blob_v3 = tm.to_bytes(&sdata).expect("encode v3");
        let blob_v4 = tm.to_bytes_v4(&sdata, None).expect("encode v4");
        let blob_v4c = tm.to_bytes_v4(&sdata, Some(1e-3)).expect("encode v4 compressed");
        assert!(
            blob_v4c.len() <= blob_v4.len(),
            "compression must never grow the artifact ({} > {} B at n={sn})",
            blob_v4c.len(),
            blob_v4.len()
        );
        for (version, sblob) in [(3usize, &blob_v3), (4usize, &blob_v4)] {
            let mut sstore = MemoryStore::new();
            sstore.put("thrash-a", vec![sblob.clone()]).unwrap();
            sstore.put("thrash-b", vec![sblob.clone()]).unwrap();
            // capacity 1 + alternating tenants = every lookup hydrates
            let mut thrash = Fleet::new(sstore, 1, ctx.clone());
            let probe = [sdata.t[0] + 0.25 * (sdata.t[sn - 1] - sdata.t[0])];
            let reps = if quick { 20 } else { 40 };
            for _ in 0..reps {
                let _ = thrash.predict("thrash-a", &probe).expect("thrash predict");
                let _ = thrash.predict("thrash-b", &probe).expect("thrash predict");
            }
            let st = thrash.stats();
            assert_eq!(st.hydrations, 2 * reps as u64, "thrash must hydrate every lookup");
            if version == 4 {
                assert_eq!(
                    st.hydrate_parse_secs, 0.0,
                    "v4 hydration must not touch the field-stream parser"
                );
            } else {
                assert_eq!(st.hydrate_view_secs, 0.0, "v3 hydration has no view phase");
            }
            let per = 1e6 / st.hydrations as f64;
            let parse_us = st.hydrate_parse_secs * per;
            let view_us = st.hydrate_view_secs * per;
            let adopt_us = st.hydrate_adopt_secs * per;
            table.add_row(vec![
                format!("{sn}"),
                format!("v{version}"),
                format!("{} B", sblob.len()),
                format!("{parse_us:.1}µs"),
                format!("{view_us:.1}µs"),
                format!("{adopt_us:.1}µs"),
                format!("{}", st.hydrations),
            ]);
            rows.push(Json::obj(vec![
                ("kind", "hydrate_split".into()),
                ("n", sn.into()),
                ("version", version.into()),
                ("threads", threads.into()),
                ("artifact_bytes", sblob.len().into()),
                ("parse_us", parse_us.into()),
                ("view_us", view_us.into()),
                ("adopt_us", adopt_us.into()),
                ("hydrations", (st.hydrations as usize).into()),
            ]));
        }
        rows.push(Json::obj(vec![
            ("kind", "artifact_format".into()),
            ("n", sn.into()),
            ("threads", threads.into()),
            ("v3_bytes", blob_v3.len().into()),
            ("v4_bytes", blob_v4.len().into()),
            ("v4_compressed_bytes", blob_v4c.len().into()),
            (
                "compression_ratio",
                (blob_v4c.len() as f64 / blob_v4.len() as f64).into(),
            ),
        ]));
        println!(
            "n={sn}: v3 {} B, v4 {} B, v4+spectral(1e-3) {} B (ratio {:.3})",
            blob_v3.len(),
            blob_v4.len(),
            blob_v4c.len(),
            blob_v4c.len() as f64 / blob_v4.len() as f64
        );
    }
    print!("{}", table.render());

    // merge the fleet section into BENCH_perf.json (keep other sections)
    let path = "BENCH_perf.json";
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_obj().cloned())
        .unwrap_or_default();
    let mut sections = doc
        .get("sections")
        .and_then(|s| s.as_obj().cloned())
        .unwrap_or_default();
    sections.insert("fleet".to_string(), Json::Arr(rows));
    doc.insert("sections".to_string(), Json::Obj(sections));
    doc.insert("threads_available".to_string(), threads.into());
    match std::fs::write(path, Json::Obj(doc).pretty()) {
        Ok(()) => println!("\nfleet section merged into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
