//! Bench: ablations of the paper's design choices (DESIGN.md experiment
//! index):
//!
//! 1. **σ_f profiling** (§2(b)) — optimise lnP_max over (m−1) parameters
//!    vs the full lnP over m parameters: dimensionality reduction saves
//!    evaluations.
//! 2. **Analytic gradient** (§2(a)) — CG with eq.-2.17 gradients vs
//!    derivative-free Nelder–Mead: the gradient is almost free, so
//!    gradient search wins on likelihood-evaluation counts.
//! 3. **Toeplitz structure** (§3(b) fn. 7) — Levinson–Durbin O(n²) solve
//!    vs Cholesky O(n³) on the regular tidal grid: the speed-up the
//!    authors deliberately left on the table for generality.
//! 4. **Backend** — native rust assembly vs the AOT XLA artifact
//!    (requires `make artifacts`): same matrices, different engines.
//!
//! `cargo bench --bench ablations`

use gpfast::data::synthetic::table1_dataset;
use gpfast::kernels::{paper_k1, PaperK1};
use gpfast::linalg::{Chol, ToeplitzSolver};
use gpfast::optimize::{
    maximise_cg, maximise_neldermead, CgOptions, FnObjective, NmOptions,
};
use gpfast::priors::BoxPrior;
use gpfast::rng::Xoshiro256;
#[cfg(feature = "xla")]
use gpfast::runtime::{Backend, NativeBackend, XlaBackend};
use gpfast::util::{timer::human_time, Table, TimingStats};

fn main() {
    ablation_profiling();
    ablation_gradient();
    ablation_toeplitz();
    ablation_backend();
}

/// 1. σ_f profiling: evals to reach the same peak.
fn ablation_profiling() {
    println!("== ablation 1: σ_f profiled out (eq. 2.16) vs explicit (eq. 2.5) ==\n");
    let data = table1_dataset(100, 0.1, 20160125);
    let model = paper_k1(0.1);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let cg = CgOptions::default();
    let mut table = Table::new(vec!["objective", "dim", "evals", "peak lnP"]);
    // profiled: 3 parameters
    let mut rng = Xoshiro256::seed_from_u64(5);
    let start = prior.sample(&mut rng);
    let mut obj = FnObjective::new(
        3,
        |th: &[f64]| {
            Ok(gpfast::gp::profiled::eval(&model, &data.t, &data.y, th)
                .map_or(f64::NEG_INFINITY, |e| e.lnp))
        },
        |th: &[f64]| match gpfast::gp::profiled::eval_grad(&model, &data.t, &data.y, th) {
            Ok((e, g)) => Ok((e.lnp, g)),
            Err(_) => Ok((f64::NEG_INFINITY, vec![0.0; 3])),
        },
    );
    let out = maximise_cg(&mut obj, &prior, &start, &cg).unwrap();
    table.add_row(vec![
        "profiled lnP_max".to_string(),
        "3".to_string(),
        format!("{}", obj.evals()),
        format!("{:.3}", out.value),
    ]);
    // explicit σ_f: 4 parameters (λ prepended)
    let mut full_prior = prior.clone();
    full_prior.bounds.insert(0, (-6.9, 6.9)); // λ = ln σ_f
    let mut full_start = vec![0.0];
    full_start.extend(start.iter().copied());
    let mut obj_full = FnObjective::new(
        4,
        |th: &[f64]| {
            Ok(gpfast::gp::full_lnp(&model, &data.t, &data.y, th)
                .unwrap_or(f64::NEG_INFINITY))
        },
        |th: &[f64]| match gpfast::gp::full_lnp_grad(&model, &data.t, &data.y, th) {
            Ok(v) => Ok(v),
            Err(_) => Ok((f64::NEG_INFINITY, vec![0.0; 4])),
        },
    );
    let out_full = maximise_cg(&mut obj_full, &full_prior, &full_start, &cg).unwrap();
    table.add_row(vec![
        "full lnP(σ_f, ϑ)".to_string(),
        "4".to_string(),
        format!("{}", obj_full.evals()),
        format!("{:.3}", out_full.value),
    ]);
    print!("{}", table.render());
    println!("(same peak expected: profiling is exact, eq. 2.15–2.16)\n");
}

/// 2. gradient vs derivative-free.
fn ablation_gradient() {
    println!("== ablation 2: CG + analytic gradient vs Nelder–Mead ==\n");
    let data = table1_dataset(100, 0.1, 20160125);
    let model = paper_k1(0.1);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let mut rng = Xoshiro256::seed_from_u64(6);
    let start = prior.sample(&mut rng);
    let value = |th: &[f64]| {
        gpfast::gp::profiled::eval(&model, &data.t, &data.y, th)
            .map_or(f64::NEG_INFINITY, |e| e.lnp)
    };
    let mut cg_obj = FnObjective::new(
        3,
        |th: &[f64]| Ok(value(th)),
        |th: &[f64]| match gpfast::gp::profiled::eval_grad(&model, &data.t, &data.y, th) {
            Ok((e, g)) => Ok((e.lnp, g)),
            Err(_) => Ok((f64::NEG_INFINITY, vec![0.0; 3])),
        },
    );
    let cg_out = maximise_cg(&mut cg_obj, &prior, &start, &CgOptions::default()).unwrap();
    let mut nm_obj = FnObjective::new(
        3,
        |th: &[f64]| Ok(value(th)),
        |_: &[f64]| unreachable!(),
    );
    let (nm_x, nm_f) =
        maximise_neldermead(&mut nm_obj, &prior, &start, &NmOptions::default()).unwrap();
    let mut table = Table::new(vec!["method", "evals", "peak lnP"]);
    table.add_row(vec![
        "CG + analytic grad (eq. 2.17)".to_string(),
        format!("{}", cg_obj.evals()),
        format!("{:.3}", cg_out.value),
    ]);
    table.add_row(vec![
        "Nelder–Mead (no gradient)".to_string(),
        format!("{}", nm_obj.evals()),
        format!("{:.3}", nm_f),
    ]);
    print!("{}", table.render());
    let _ = nm_x;
    println!("(the gradient costs ~nothing once lnP is evaluated — §2(a))\n");
}

/// 3. Toeplitz vs Cholesky on a regular grid.
fn ablation_toeplitz() {
    println!("== ablation 3: Toeplitz (Levinson O(n²)) vs Cholesky O(n³) ==\n");
    let model = paper_k1(0.01);
    let theta = PaperK1::truth();
    let mut table = Table::new(vec!["n", "cholesky", "toeplitz", "speedup", "|Δlogdet|"]);
    for &n in &[328usize, 1000, 1968] {
        let t: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let k = gpfast::gp::assemble_cov(&model, &t, &theta);
        let col: Vec<f64> = (0..n).map(|i| k[(i, 0)]).collect();
        let b: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.37).sin()).collect();
        let chol_t = TimingStats::measure(1, 3, || {
            let ch = Chol::factor(&k).unwrap();
            let _ = ch.solve(&b);
        });
        let toep_t = TimingStats::measure(1, 3, || {
            let ts = ToeplitzSolver::new(&col).unwrap();
            let _ = ts.solve(&b);
        });
        let ld_c = Chol::factor(&k).unwrap().logdet();
        let ld_t = ToeplitzSolver::new(&col).unwrap().logdet();
        table.add_row(vec![
            format!("{n}"),
            human_time(chol_t.min()),
            human_time(toep_t.min()),
            format!("{:.1}x", chol_t.min() / toep_t.min()),
            format!("{:.2e}", (ld_c - ld_t).abs()),
        ]);
    }
    print!("{}", table.render());
    println!("(§3(b) fn. 7: the paper skipped this so its code stays general)\n");
}

/// 4. native vs XLA-artifact assembly (needs the `xla` feature).
#[cfg(not(feature = "xla"))]
fn ablation_backend() {
    println!("== ablation 4: covariance assembly backend (native vs XLA AOT) ==\n");
    println!("(skipped: built without the `xla` feature)\n");
}

/// 4. native vs XLA-artifact assembly.
#[cfg(feature = "xla")]
fn ablation_backend() {
    println!("== ablation 4: covariance assembly backend (native vs XLA AOT) ==\n");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let model = paper_k1(0.1);
    let theta = PaperK1::truth();
    let mut table = Table::new(vec!["n", "native", "xla artifact", "max |Δ|"]);
    let mut xla = match XlaBackend::load(&dir) {
        Ok(b) => b,
        Err(e) => {
            println!("(skipped: {e})\n");
            return;
        }
    };
    let mut native = NativeBackend::new();
    for &n in &[30usize, 100, 300, 1968] {
        if !xla.accelerates(&model, n) {
            continue;
        }
        let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        // warm both paths (XLA compiles on first call)
        let (kx, _) = xla.cov_and_grads(&model, &t, &theta).unwrap();
        let (kn, _) = native.cov_and_grads(&model, &t, &theta).unwrap();
        let tn = TimingStats::measure(1, if n > 500 { 3 } else { 10 }, || {
            let _ = native.cov_and_grads(&model, &t, &theta).unwrap();
        });
        let tx = TimingStats::measure(1, if n > 500 { 3 } else { 10 }, || {
            let _ = xla.cov_and_grads(&model, &t, &theta).unwrap();
        });
        table.add_row(vec![
            format!("{n}"),
            human_time(tn.min()),
            human_time(tx.min()),
            format!("{:.1e}", kx.max_abs_diff(&kn)),
        ]);
    }
    print!("{}", table.render());
    println!("(identical matrices; interpret-mode Pallas on CPU is the correctness path,");
    println!(" real-TPU projections are in EXPERIMENTS.md §Perf)");
}
