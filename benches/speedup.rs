//! Bench: the paper's headline **speed-up claim** (§3(a)):
//!
//! > "The ln Z_num values … required between 20,000 and 50,000 likelihood
//! > evaluations. The maximisation routines typically took fewer than 100
//! > likelihood evaluations to find the peak … After these duplicate runs
//! > are accounted for, the speed-up factor … was between 20 and 50."
//!
//! Measures, per (model, n): optimiser evals per restart, total fast-path
//! evals (all restarts + the one Hessian evaluation), nested-sampling
//! evals, and the resulting speed-up in both eval counts and wall-clock.
//!
//! `cargo bench --bench speedup` (`GPFAST_BENCH_FAST=1` shrinks)

use gpfast::coordinator::{train_model, ModelSpec, TrainOptions};
use gpfast::data::synthetic::table1_dataset;
use gpfast::nested::{nested_sample, NestedOptions};
use gpfast::priors::{BoxPrior, ScalePrior};
use gpfast::rng::Xoshiro256;
use gpfast::util::{Stopwatch, Table};

fn main() {
    let fast = std::env::var("GPFAST_BENCH_FAST").is_ok();
    let sizes: &[usize] = if fast { &[30, 100] } else { &[30, 100, 300] };
    let nlive = if fast { 150 } else { 400 };

    println!("== §3(a) speed-up: Laplace fast path vs nested sampling ==\n");
    let mut table = Table::new(vec![
        "model", "n", "evals/restart", "fast evals", "nested evals", "speedup(evals)",
        "fast s", "nested s", "speedup(wall)",
    ]);
    for &n in sizes {
        let data = table1_dataset(n, 0.1, 20160125);
        for spec in [ModelSpec::K1, ModelSpec::K2] {
            let model = spec.build(0.1);
            let prior = BoxPrior::for_model(&model, &data.span().unwrap());
            let scale = ScalePrior::default();
            let mut rng = Xoshiro256::seed_from_u64(n as u64 + 1);
            let mut opts = TrainOptions::default();
            opts.multistart.restarts = 10;

            let exec = gpfast::runtime::ExecutionContext::from_env();
            let sw = Stopwatch::start();
            let trained = train_model(&spec, 0.1, &data, &opts, 1, &exec, &mut rng).unwrap();
            // the "+1" evaluation of the Hessian (paper: "one additional
            // evaluation to calculate the Hessian and hence ln Z_est")
            let _h = gpfast::gp::profiled_hessian_with(
                &model,
                &data.t,
                &data.y,
                &trained.theta_hat,
                &exec,
            )
            .unwrap();
            let t_fast = sw.elapsed_secs();
            let fast_evals = trained.n_evals + 1;

            let sw = Stopwatch::start();
            let res = nested_sample(
                prior.dim() + 1,
                |u: &[f64]| {
                    let lambda = scale.lambda_from_unit(u[0]);
                    let theta = prior.from_unit_cube(&u[1..]);
                    let mut full = vec![lambda];
                    full.extend(theta);
                    gpfast::gp::full_lnp(&model, &data.t, &data.y, &full)
                        .unwrap_or(f64::NEG_INFINITY)
                },
                &NestedOptions { nlive, ..Default::default() },
                &mut rng,
            )
            .unwrap();
            let t_nested = sw.elapsed_secs();

            table.add_row(vec![
                model.name.clone(),
                format!("{n}"),
                format!("{}", trained.n_evals / 10),
                format!("{fast_evals}"),
                format!("{}", res.n_evals),
                format!("{:.0}x", res.n_evals as f64 / fast_evals as f64),
                format!("{t_fast:.1}"),
                format!("{t_nested:.1}"),
                format!("{:.0}x", t_nested / t_fast.max(1e-9)),
            ]);
        }
    }
    print!("{}", table.render());
    println!("\npaper: 20,000–50,000 nested evals; <100 optimiser evals/run; ~10 restarts;");
    println!("       net speed-up 20–50× after restart accounting.");
}
