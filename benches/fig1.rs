//! Bench: **Fig. 1** — GP realisation sampling. Regenerates the figure's
//! data (CSV) and measures the cost of realisation drawing (covariance
//! assembly + Cholesky + MVN sample) across sizes, which is the same
//! kernel-assembly + factorisation path the training loop pays per
//! evaluation.
//!
//! `cargo bench --bench fig1`

use gpfast::data::csv;
use gpfast::gp::draw_realisation;
use gpfast::kernels::{paper_k1, paper_k2, PaperK1, PaperK2};
use gpfast::rng::Xoshiro256;
use gpfast::util::{timer::human_time, Table, TimingStats};
use std::path::Path;

fn main() {
    // 1. the figure's data
    let n = 100;
    let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
    let mut rng = Xoshiro256::seed_from_u64(20160125);
    let k1 = paper_k1(0.1);
    let k2 = paper_k2(0.1);
    let y1 = draw_realisation(&k1, 1.0, &PaperK1::truth(), &t, &mut rng).unwrap();
    let y2 = draw_realisation(&k2, 1.0, &PaperK2::truth(), &t, &mut rng).unwrap();
    csv::write_columns(Path::new("fig1_realisations.csv"), &["t", "k1", "k2"], &[&t, &y1, &y2])
        .unwrap();
    println!("fig1_realisations.csv written (t = 1..100, paper truth hyperparameters)\n");

    // 2. sampling cost scaling (assembly + Cholesky dominate: O(n³))
    println!("== realisation cost vs n (k2) ==");
    let mut table = Table::new(vec!["n", "mean", "min", "GFLOP/s (chol est)"]);
    for &n in &[100usize, 300, 600, 1000] {
        let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let mut rng = Xoshiro256::seed_from_u64(n as u64);
        let stats = TimingStats::measure(1, if n <= 300 { 10 } else { 3 }, || {
            let _ = draw_realisation(&k2, 1.0, &PaperK2::truth(), &t, &mut rng).unwrap();
        });
        // Cholesky flops ≈ n³/3
        let gflops = (n as f64).powi(3) / 3.0 / stats.min() / 1e9;
        table.add_row(vec![
            format!("{n}"),
            human_time(stats.mean()),
            human_time(stats.min()),
            format!("{gflops:.2}"),
        ]);
    }
    print!("{}", table.render());
}
