//! Fig. 1 — realisations of the k₁ and k₂ GPs at t = 1…100 with the
//! paper's truth hyperparameters, written as CSV and sketched as an
//! ASCII strip chart so the periodic structure is visible in a terminal.
//!
//! ```sh
//! cargo run --release --example gp_realisations
//! ```

use gpfast::data::csv;
use gpfast::gp::draw_realisation;
use gpfast::kernels::{paper_k1, paper_k2, PaperK1, PaperK2};
use gpfast::rng::Xoshiro256;
use std::path::Path;

fn ascii_plot(label: &str, y: &[f64]) {
    const ROWS: usize = 11;
    let (lo, hi) = y.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
        (l.min(v), h.max(v))
    });
    let mut grid = vec![vec![' '; y.len()]; ROWS];
    for (x, &v) in y.iter().enumerate() {
        let r = ((hi - v) / (hi - lo).max(1e-12) * (ROWS - 1) as f64).round() as usize;
        grid[r.min(ROWS - 1)][x] = '*';
    }
    println!("{label}  [{lo:.2}, {hi:.2}]");
    for row in grid {
        println!("  |{}|", row.into_iter().collect::<String>());
    }
}

fn main() -> gpfast::Result<()> {
    let n = 100;
    let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
    let mut rng = Xoshiro256::seed_from_u64(20160125);

    let k1 = paper_k1(0.1);
    let k2 = paper_k2(0.1);
    let y1 = draw_realisation(&k1, 1.0, &PaperK1::truth(), &t, &mut rng)?;
    let y2 = draw_realisation(&k2, 1.0, &PaperK2::truth(), &t, &mut rng)?;

    println!("Fig. 1 reproduction — GP realisations at the paper's truth hyperparameters");
    println!("k1: σ_f=1, φ0=3.5 (T0≈33), φ1=1.5 (T1≈4.5), ξ1=0");
    ascii_plot("k1 realisation", &y1);
    println!("\nk2: k1 plus a second periodic component (φ2=2.5 → T2≈12.2, ξ2=0)");
    ascii_plot("k2 realisation", &y2);

    // the lengthscale markers of Fig. 1
    println!("\nlengthscales (horizontal-bar markers in the paper's figure):");
    println!("  T0 = e^3.5 = {:.1}", (3.5f64).exp());
    println!("  T1 = e^1.5 = {:.2}", (1.5f64).exp());
    println!("  T2 = e^2.5 = {:.2}", (2.5f64).exp());

    let out = "realisations.csv";
    csv::write_columns(Path::new(out), &["t", "k1", "k2"], &[&t, &y1, &y2])?;
    println!("\nCSV written to {out}");
    Ok(())
}
