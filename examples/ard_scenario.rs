//! Scenario-tier walkthrough — ARD on d = 3 inputs with heteroscedastic
//! noise, end to end:
//!
//! 1. draw a synthetic dataset from the SE-ARD truth (three input
//!    columns with very different relevance, per-point noise levels);
//! 2. run an evidence tournament between the isotropic-in-d parent
//!    (`se-iso3`) and its ARD children (`se-ard3`, `m32-ard3`) — the
//!    children warm-start from the parent's fitted length-scale;
//! 3. report the recovered per-dimension length-scales against the
//!    generating truth and the ARD-vs-isotropic evidence gap;
//! 4. serve the winner: row predictions, streaming `observe_row` with
//!    per-point σ, and a retrain over the heteroscedastic window.
//!
//! ```sh
//! cargo run --release --example ard_scenario            # full
//! cargo run --release --example ard_scenario -- --fast  # quick pass
//! ```

use gpfast::coordinator::{
    ModelSpec, PipelineConfig, ServeSession, Tournament, TrainOptions,
};
use gpfast::data::synthetic::{ard3_dataset, ard3_truth};
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;
use gpfast::util::Table;

fn main() -> gpfast::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let n = if fast { 48 } else { 120 };
    let data = ard3_dataset(n, 0.1, true, 20160401);
    println!(
        "dataset: n = {}, d = {}, heteroscedastic = {}",
        data.len(),
        data.d(),
        data.is_heteroscedastic()
    );

    // ---- tournament: isotropic parent vs ARD children
    let mut cfg = PipelineConfig::fast();
    cfg.models =
        vec![ModelSpec::SeIso(3), ModelSpec::SeArd(3), ModelSpec::M32Ard(3)];
    cfg.sigma_n = 0.1;
    cfg.train.multistart.restarts = if fast { 3 } else { 6 };
    cfg.exec = ExecutionContext::from_env();
    let mut rng = Xoshiro256::seed_from_u64(7);
    let result = Tournament::new(cfg).run(&data, &mut rng)?;

    let truth = ard3_truth();
    let mut table =
        Table::new(vec!["model", "ln Z", "warm", "L1", "L2", "L3", "truth L"]);
    for tm in &result.models {
        let th = &tm.train.theta_hat;
        // the tied parent has one shared φ; ARD children carry one per dim
        let ls: Vec<f64> =
            (0..3).map(|j| th[j.min(th.len() - 1)].exp()).collect();
        table.add_row(vec![
            tm.name().to_string(),
            format!("{:.2}", tm.evidence.ln_z),
            if tm.warm_started { "yes".into() } else { "no".into() },
            format!("{:.2}", ls[0]),
            format!("{:.2}", ls[1]),
            format!("{:.2}", ls[2]),
            format!(
                "{:.2}/{:.2}/{:.2}",
                truth[0].exp(),
                truth[1].exp(),
                truth[2].exp()
            ),
        ]);
    }
    println!("{}", table.render());
    if let (Some(ard), Some(iso)) =
        (result.model("se-ard3"), result.model("se-iso3"))
    {
        println!(
            "ARD vs isotropic evidence gap: ln B = {:.2}",
            ard.evidence.ln_z - iso.evidence.ln_z
        );
    }

    // ---- serve the winner on row queries
    let mut session =
        ServeSession::from_tournament(&result.models, &data, ExecutionContext::from_env())?;
    println!("serving: {} (d = {})", session.spec().name(), data.d());
    let q1 = vec![0.5 + n as f64, 2.5 + n as f64];
    let q2 = vec![3.0, 5.5];
    let q3 = vec![1.0, 2.5];
    let q: Vec<&[f64]> = vec![&q1, &q2, &q3];
    let pred = session.predict_rows(&q);
    for i in 0..q1.len() {
        println!(
            "  f({:.1}, {:.1}, {:.1}) = {:+.4} ± {:.4}",
            q1[i], q2[i], q3[i], pred.mean[i], pred.sd[i]
        );
    }

    // ---- stream heteroscedastic observations and retrain
    for i in 0..q1.len() {
        let row = [q1[i], q2[i], q3[i]];
        session.observe_row(&row, pred.mean[i], Some(0.12))?;
    }
    println!("absorbed {} rows, n_train = {}", q1.len(), session.stats().n_train);
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 2;
    let outcome = session.retrain(&opts, 1, &mut rng)?;
    println!(
        "retrain over the heteroscedastic window: n = {}, winner = {}",
        outcome.window_n, outcome.winner
    );
    Ok(())
}
