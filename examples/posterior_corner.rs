//! Fig. 2 — the k₂ hyperparameter posterior on the largest synthetic
//! dataset (n = 300): nested-sampling corner samples versus the
//! Hessian-based Gaussian approximation.
//!
//! The paper's point: the posterior is well approximated by a single
//! Gaussian mode, the 1-D normal overlays (black curves in their figure)
//! match the sampled marginals, and integrating that Gaussian (the
//! Laplace evidence) errs by only ~10%. We print, per hyperparameter,
//! the sampled posterior mean/sd against the Laplace (θ̂, √(H⁻¹)_ii) and
//! a standardised |Δmean|/σ distance.
//!
//! ```sh
//! cargo run --release --example posterior_corner            # full nlive
//! cargo run --release --example posterior_corner -- --fast
//! ```

use gpfast::coordinator::{train_model, ModelSpec, TrainOptions};
use gpfast::data::{csv, synthetic::table1_dataset};
use gpfast::evidence::laplace_evidence;
use gpfast::nested::{nested_sample, NestedOptions};
use gpfast::priors::{BoxPrior, ScalePrior};
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;
use gpfast::util::Table;
use std::path::Path;

fn main() -> gpfast::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let n = if fast { 100 } else { 300 };
    let data = table1_dataset(n, 0.1, 20160125);
    let spec = ModelSpec::K2;
    let model = spec.build(0.1);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let scale = ScalePrior::default();

    // 1. fast path: train + Hessian + Laplace
    let mut rng = Xoshiro256::seed_from_u64(2);
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 10;
    let exec = ExecutionContext::from_env();
    let trained = train_model(&spec, 0.1, &data, &opts, 2, &exec, &mut rng)?;
    let hess =
        gpfast::gp::profiled_hessian_with(&model, &data.t, &data.y, &trained.theta_hat, &exec)?;
    let lap = laplace_evidence(n, &prior, &scale, &trained.theta_hat, trained.lnp_peak, &hess)?;

    // 2. nested-sampling posterior over (λ, ϑ)
    let nlive = if fast { 200 } else { 500 };
    let res = nested_sample(
        prior.dim() + 1,
        |u: &[f64]| {
            let lambda = scale.lambda_from_unit(u[0]);
            let theta = prior.from_unit_cube(&u[1..]);
            let mut full = vec![lambda];
            full.extend(theta);
            gpfast::gp::full_lnp(&model, &data.t, &data.y, &full).unwrap_or(f64::NEG_INFINITY)
        },
        &NestedOptions { nlive, ..Default::default() },
        &mut rng,
    )?;

    // 3. compare marginals
    let names = model.kernel.names();
    let dim = prior.dim();
    let mut mean = vec![0.0; dim];
    let mut var = vec![0.0; dim];
    for s in &res.samples {
        let w = s.ln_w.exp();
        let theta = prior.from_unit_cube(&s.u[1..]);
        for d in 0..dim {
            mean[d] += w * theta[d];
        }
    }
    for s in &res.samples {
        let w = s.ln_w.exp();
        let theta = prior.from_unit_cube(&s.u[1..]);
        for d in 0..dim {
            var[d] += w * (theta[d] - mean[d]) * (theta[d] - mean[d]);
        }
    }

    println!("Fig. 2 reproduction — k2 posterior on n = {n} synthetic data\n");
    let mut table = Table::new(vec![
        "param", "sampled mean", "sampled sd", "laplace mean", "laplace sd", "|Δμ|/σ",
    ]);
    for d in 0..dim {
        let sd = var[d].sqrt();
        let dev = (mean[d] - trained.theta_hat[d]).abs() / sd.max(1e-12);
        table.add_row(vec![
            names[d].clone(),
            format!("{:.4}", mean[d]),
            format!("{sd:.4}"),
            format!("{:.4}", trained.theta_hat[d]),
            format!("{:.4}", lap.sigma[d]),
            format!("{dev:.2}"),
        ]);
    }
    print!("{}", table.render());
    println!("\nevidence check (the paper's ~10% = ~0.1 nat agreement):");
    println!("  lnZ_laplace = {:.3}{}", lap.ln_z, if lap.suspect { " (SUSPECT)" } else { "" });
    println!("  lnZ_nested  = {:.3} ± {:.3}", res.ln_z, res.ln_z_err);
    println!("  |Δ| = {:.3}", (lap.ln_z - res.ln_z).abs());

    // 4. corner CSV: weighted samples in physical coordinates
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); dim + 2];
    for s in &res.samples {
        cols[0].push(s.ln_w);
        cols[1].push(scale.lambda_from_unit(s.u[0]));
        let theta = prior.from_unit_cube(&s.u[1..]);
        for (d, v) in theta.into_iter().enumerate() {
            cols[d + 2].push(v);
        }
    }
    let mut colnames = vec!["ln_w".to_string(), "ln_sigma_f".to_string()];
    colnames.extend(names);
    let name_refs: Vec<&str> = colnames.iter().map(String::as_str).collect();
    let col_refs: Vec<&[f64]> = cols.iter().map(Vec::as_slice).collect();
    let out = "corner_samples.csv";
    csv::write_columns(Path::new(out), &name_refs, &col_refs)?;
    println!("\nweighted posterior samples written to {out} ({} rows)", res.samples.len());
    Ok(())
}
