//! The paper's §3(b) real-data experiment on the simulated Woods-Hole
//! tidal series (see DESIGN.md §Substitutions):
//!
//! * small set (one lunar month, n = 328) and large set (six lunar
//!   months, n = 1968), 2-hour cadence, σ_n = 10⁻²;
//! * trains k₁ (one periodic timescale) and k₂ (two), reports the
//!   recovered timescales **in hours** with inverse-Hessian error bars —
//!   the paper finds T₁ ≈ 12.4 h (the M2 tide) and T₂ ≈ 24 h (diurnal);
//! * reports the k₂-over-k₁ log Bayes factor (paper: 57.8 small, 538
//!   large) and writes both interpolants over a week (Fig. 3 inset).
//!
//! ```sh
//! cargo run --release --example tidal_analysis            # both sizes
//! cargo run --release --example tidal_analysis -- --fast  # n = 328 only
//! ```

use gpfast::coordinator::{
    train_model, ComparisonPipeline, ModelReport, ModelSpec, PipelineConfig,
};
use gpfast::data::{csv, tidal};
use gpfast::kernels::TIDAL_SIGMA_N;
use gpfast::priors::{BoxPrior, ScalePrior};
use gpfast::rng::Xoshiro256;
use gpfast::util::Stopwatch;
use std::path::Path;

/// Train one model on the large dataset warm-started from its small-set
/// peak (the timescales are physical — they do not move between subsets),
/// with a single polish restart. This is how a practitioner scales the
/// paper's workflow to the n = 1968 set without paying 10 cold restarts
/// at ~8 s/evaluation.
fn train_large_warm(
    spec: &ModelSpec,
    data: &gpfast::data::Dataset,
    warm: &[f64],
    rng: &mut Xoshiro256,
) -> gpfast::Result<ModelReport> {
    let sw = Stopwatch::start();
    let model = spec.build(TIDAL_SIGMA_N);
    let prior = BoxPrior::for_model(&model, &data.span().unwrap());
    let mut opts = gpfast::coordinator::TrainOptions::default();
    opts.multistart.restarts = 1;
    opts.extra_starts = vec![warm.to_vec()];
    let exec = gpfast::runtime::ExecutionContext::from_env();
    let trained = train_model(spec, TIDAL_SIGMA_N, data, &opts, 1, &exec, rng)?;
    let hess =
        gpfast::gp::profiled_hessian_with(&model, &data.t, &data.y, &trained.theta_hat, &exec)?;
    let ev = gpfast::evidence::laplace_evidence(
        data.len(),
        &prior,
        &ScalePrior::default(),
        &trained.theta_hat,
        trained.lnp_peak,
        &hess,
    )?;
    Ok(ModelReport {
        name: model.name.clone(),
        param_names: model.kernel.names(),
        theta_hat: trained.theta_hat,
        sigma: ev.sigma,
        lnp_peak: trained.lnp_peak,
        sigma_f_hat: trained.sigma_f_hat2.sqrt(),
        ln_z: ev.ln_z,
        ln_b: 0.0, // filled in by ComparisonReport::ranked
        suspect: ev.suspect || !trained.converged,
        warm_started: true, // seeded from the small-set peak
        n_evals: trained.n_evals,
        n_modes: trained.n_modes,
        restarts: 2,
        wall_secs: sw.elapsed_secs(),
        nested: None,
    })
}

fn main() -> gpfast::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let full = tidal::generate_tidal(&tidal::TidalConfig::six_lunar_months(20160125));
    let small = full.head(tidal::TidalConfig::LUNAR_MONTH_N).demean();
    let large = full.demean();

    // --- small set: the full multistart pipeline (paper §3(b), n = 328)
    let mut small_peaks: Vec<(String, Vec<f64>)> = Vec::new();
    let mut reports = Vec::new();
    {
        let data = &small;
        println!("=== {} (n = {}) ===", data.label, data.len());
        let mut cfg = PipelineConfig::paper_synthetic();
        cfg.sigma_n = TIDAL_SIGMA_N;
        let mut rng = Xoshiro256::seed_from_u64(7);
        let sw = Stopwatch::start();
        let report = ComparisonPipeline::new(cfg).run(data, &mut rng)?;
        print!("{}", report.render());
        println!("wall: {:.1} s", sw.elapsed_secs());
        for m in &report.models {
            small_peaks.push((m.name.clone(), m.theta_hat.clone()));
        }
        reports.push((small.clone(), report));
    }

    // --- large set: warm-started polish (skipped with --fast)
    if !fast {
        let data = &large;
        println!("\n=== {} (n = {}) — warm-started from the n=328 peaks ===",
            data.label, data.len());
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut models = Vec::new();
        for spec in [ModelSpec::K1, ModelSpec::K2] {
            let name = if spec == ModelSpec::K1 { "k1" } else { "k2" };
            let warm = &small_peaks.iter().find(|(n, _)| n == name).unwrap().1;
            eprintln!("training {name} on n = {} ...", data.len());
            models.push(train_large_warm(&spec, data, warm, &mut rng)?);
        }
        let report = gpfast::coordinator::ComparisonReport::ranked(
            data.label.clone(),
            data.len(),
            models,
        );
        print!("{}", report.render());
        reports.push((large.clone(), report));
    }

    for (data, report) in &reports {
        println!("\n--- timescales for {} (n = {}) ---", data.label, data.len());

        // report timescales in hours (T = e^phi; times are in hours)
        for m in &report.models {
            println!("  {}:", m.name);
            for ((name, th), sg) in m.param_names.iter().zip(&m.theta_hat).zip(&m.sigma) {
                if name.starts_with("phi") && name != "phi0" {
                    let t_h = th.exp();
                    // δT = T·δφ (first order)
                    println!(
                        "    {} -> T = {:.2} ± {:.2} hours",
                        name,
                        t_h,
                        t_h * sg
                    );
                }
            }
        }
        if let Some(lnb) = report.ln_bayes("k2", "k1") {
            println!(
                "  ln B(k2 over k1) = {:.1}   [paper: 57.8 @ n=328, 538 @ n=1968]",
                lnb
            );
        }

        // Fig. 3 inset: both interpolants over the first week, 15-min grid
        let week_h = 7.0 * 24.0;
        let n_star = 4 * 7 * 24;
        let t_star: Vec<f64> =
            (0..n_star).map(|i| week_h * i as f64 / (n_star - 1) as f64).collect();
        let mut cols: Vec<Vec<f64>> = vec![t_star.clone()];
        let mut names = vec!["t_hours".to_string()];
        for m in &report.models {
            let spec = gpfast::coordinator::ModelSpec::parse(&m.name)?;
            let model = spec.build(TIDAL_SIGMA_N);
            let ev = gpfast::gp::profiled::eval(&model, &data.t, &data.y, &m.theta_hat)?;
            let pred = gpfast::gp::predict(&model, &data.t, &m.theta_hat, &ev, &t_star);
            cols.push(pred.mean);
            names.push(format!("mean_{}", m.name));
        }
        if cols.len() == 3 {
            let rms: f64 = (cols[1]
                .iter()
                .zip(&cols[2])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                / n_star as f64)
                .sqrt();
            let scale =
                (data.y.iter().map(|v| v * v).sum::<f64>() / data.len() as f64).sqrt();
            println!(
                "  interpolant RMS(k1 − k2) over one week = {:.4} ({:.1}% of signal) — \
                 paper: 'identical on this timescale'",
                rms,
                100.0 * rms / scale
            );
        }
        let out = format!("tidal_interpolants_n{}.csv", data.len());
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let col_refs: Vec<&[f64]> = cols.iter().map(Vec::as_slice).collect();
        csv::write_columns(Path::new(&out), &name_refs, &col_refs)?;
        println!("  interpolants written to {out}\n");
    }
    Ok(())
}
