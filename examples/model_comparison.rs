//! **End-to-end driver** — the paper's Table-1 experiment, in full:
//!
//! for n ∈ {30, 100, 300}, draw data from the k₂ truth, train k₁ and k₂
//! by multistart CG (profiled hyperlikelihood + analytic gradient),
//! estimate ln Z by the Laplace approximation (analytic Hessian), verify
//! with the nested-sampling baseline, and print the table in the paper's
//! layout together with the achieved speed-up.
//!
//! ```sh
//! cargo run --release --example model_comparison            # full (minutes)
//! cargo run --release --example model_comparison -- --fast  # quick pass
//! ```
//!
//! Results are also appended as JSON for EXPERIMENTS.md tooling.

use gpfast::coordinator::{ComparisonPipeline, PipelineConfig};
use gpfast::data::synthetic::table1_dataset;
use gpfast::nested::NestedOptions;
use gpfast::rng::Xoshiro256;
use gpfast::util::{Json, Stopwatch, Table};

fn main() -> gpfast::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let sizes: &[usize] = if fast { &[30, 100] } else { &[30, 100, 300] };
    let nlive = if fast { 150 } else { 400 };

    let mut table = Table::new(vec![
        "n", "lnZ_est k1", "lnZ_num k1", "lnZ_est k2", "lnZ_num k2", "lnB_est", "lnB_num",
        "speedup",
    ]);
    let mut json_rows = Vec::new();

    for &n in sizes {
        eprintln!("running n = {n} ...");
        let data = table1_dataset(n, 0.1, 20160125);
        let mut cfg = PipelineConfig::paper_synthetic();
        cfg.run_nested = true;
        cfg.nested = NestedOptions { nlive, ..Default::default() };
        let mut rng = Xoshiro256::seed_from_u64(n as u64);
        let sw = Stopwatch::start();
        let report = ComparisonPipeline::new(cfg).run(&data, &mut rng)?;
        let wall = sw.elapsed_secs();

        let k1 = report.model("k1").unwrap();
        let k2 = report.model("k2").unwrap();
        let (n1, n2) = (k1.nested.as_ref().unwrap(), k2.nested.as_ref().unwrap());
        let lnb_est = k2.ln_z - k1.ln_z;
        let lnb_num = n2.ln_z - n1.ln_z;
        let lnb_num_err = (n1.ln_z_err.powi(2) + n2.ln_z_err.powi(2)).sqrt();
        // the paper's speed-up metric: likelihood evaluations, nested vs
        // fast path (per model, aggregated)
        let fast_evals = (k1.n_evals + k2.n_evals) as f64;
        let nested_evals = (n1.n_evals + n2.n_evals) as f64;
        let speedup = nested_evals / fast_evals;

        let flag = |m: &gpfast::coordinator::ModelReport| if m.suspect { "*" } else { "" };
        table.add_row(vec![
            format!("{n}"),
            format!("{:.2}{}", k1.ln_z, flag(k1)),
            format!("{:.2} ± {:.2}", n1.ln_z, n1.ln_z_err),
            format!("{:.2}{}", k2.ln_z, flag(k2)),
            format!("{:.2} ± {:.2}", n2.ln_z, n2.ln_z_err),
            format!("{lnb_est:.2}"),
            format!("{lnb_num:.2} ± {lnb_num_err:.2}"),
            format!("{speedup:.0}x"),
        ]);
        json_rows.push(Json::obj(vec![
            ("n", n.into()),
            ("ln_z_est_k1", k1.ln_z.into()),
            ("ln_z_num_k1", n1.ln_z.into()),
            ("ln_z_num_k1_err", n1.ln_z_err.into()),
            ("ln_z_est_k2", k2.ln_z.into()),
            ("ln_z_num_k2", n2.ln_z.into()),
            ("ln_z_num_k2_err", n2.ln_z_err.into()),
            ("ln_b_est", lnb_est.into()),
            ("ln_b_num", lnb_num.into()),
            ("k1_suspect", k1.suspect.into()),
            ("k2_suspect", k2.suspect.into()),
            ("fast_evals", (k1.n_evals + k2.n_evals).into()),
            ("nested_evals", (n1.n_evals + n2.n_evals).into()),
            ("speedup_evals", speedup.into()),
            ("wall_secs", wall.into()),
        ]));
    }

    println!("\nTable 1 reproduction (data drawn from k2; * = Laplace flagged SUSPECT)");
    print!("{}", table.render());
    println!("\npaper's qualitative checks:");
    println!("  - lnB grows with n and favours k2 at n >= 100");
    println!("  - est vs num agree except possibly the smallest-n k2 case");
    println!("  - speed-up in the paper: 20-50x after restart accounting");

    let out = "table1_results.json";
    std::fs::write(out, Json::Arr(json_rows).pretty())?;
    println!("\nJSON written to {out}");
    Ok(())
}
