//! Streaming serving demo: replay the Woods-Hole tidal series as an
//! arriving stream.
//!
//! 1. **Train** k₁ on the first lunar month (n = 328, the paper's small
//!    set) with multistart CG;
//! 2. **Serve** day-ahead forecasts from a [`ServeSession`] — the factor
//!    from training is cached, each batch costs `O(q n²)`;
//! 3. **Stream** two more weeks of observations in day-sized batches
//!    through a bounded-memory `WindowPolicy`: every append extends the
//!    factor in `O(n²)`, past the window cap the oldest point is evicted
//!    in `O(n²)`, and a periodic cold refresh washes out rounding drift
//!    — predictions stay available between batches;
//! 4. **Verify**: after the stream, the served predictions are compared
//!    against a from-scratch refit of the **live window** at the same
//!    hyperparameters — they must agree to 1e-8 (the issue's acceptance
//!    bar), while the incremental path does orders of magnitude less
//!    work;
//! 5. **Persist & restart**: the trained artifact saved at step 1 is
//!    reloaded into a fresh `ServeSession`, which reaches its first
//!    prediction bit-identically and with zero likelihood evaluations —
//!    the `O(n²)` serving-process restart.
//!
//! ```sh
//! cargo run --release --example streaming_tidal
//! GPFAST_THREADS=4 cargo run --release --example streaming_tidal
//! ```

use gpfast::coordinator::{
    ModelSpec, PipelineConfig, Roster, ServeSession, Tournament, TrainOptions, WindowPolicy,
};
use gpfast::data::tidal::{generate_tidal, TidalConfig};
use gpfast::gp::profiled::ProfiledEval;
use gpfast::priors::ScalePrior;
use gpfast::rng::Xoshiro256;
use gpfast::runtime::ExecutionContext;
use gpfast::util::Stopwatch;

/// Noise level for the serving demo. The §3(b) reproduction uses the
/// paper's σ_n = 10⁻² (see `tidal_analysis.rs`); here a 5% fractional
/// error keeps κ(K̃) ~ 10⁴ so the streamed-vs-refit 1e-8 check sits far
/// above the conditioning floor — the serving machinery is identical.
const SIGMA_N: f64 = 0.05;

fn main() -> gpfast::Result<()> {
    let exec = ExecutionContext::from_env();
    let full = generate_tidal(&TidalConfig::six_lunar_months(20160125)).demean();
    let n0 = TidalConfig::LUNAR_MONTH_N; // 328: the paper's first month
    let stream_days = 14;
    let per_day = (24.0 / 2.0) as usize; // 2-hour cadence → 12 points/day
    let history = full.head(n0);

    // --- 1. train on the first lunar month: a roster-of-one tournament
    // (same multistart and RNG stream as the old standalone path, so a
    // single-model roster reproduces the pre-roster run exactly)
    println!("training k1 on the first lunar month (n = {n0}) ...");
    let mut opts = TrainOptions::default();
    opts.multistart.restarts = 3;
    // physically-informed warm start: T0 ≈ 90 h window, T1 = 12.42 h (M2)
    opts.extra_starts = vec![vec![4.5, 12.42f64.ln(), 0.0]];
    let mut rng = Xoshiro256::seed_from_u64(1);
    let sw = Stopwatch::start();
    let config = PipelineConfig {
        models: Roster::parse("k1")?.specs().to_vec(),
        sigma_n: SIGMA_N,
        train: opts,
        scale_prior: ScalePrior::default(),
        run_nested: false,
        nested: Default::default(),
        workers: 2,
        exec: exec.clone(),
    };
    let result = Tournament::new(config).run(&history, &mut rng)?;
    let trained = result.winner().train.clone();
    // the router adopts every artifact's cached factor; with a roster of
    // one it routes every query to that model, bit-identically to the
    // old single-predictor session. (The tournament also attaches the
    // Laplace evidence — one extra analytic-Hessian evaluation — which
    // the old train-only path skipped; the wall-clock below includes it.)
    // persist the artifact now — step 5 restarts a serving process from
    // this file without retraining
    let artifact_path =
        std::env::temp_dir().join(format!("streaming_tidal_{}.gpfm", std::process::id()));
    result.winner().save(&artifact_path, &history)?;
    // bounded memory: cap the factor at n0 + 100 points (the two-week
    // stream overflows this, so evictions genuinely happen) and
    // cold-refresh every 48 evictions
    let mut session = ServeSession::from_tournament(&result.models, &history, exec.clone())?
        .with_window(WindowPolicy { max_points: n0 + 100, refresh_every: 48 });
    let train_secs = sw.elapsed_secs();
    println!(
        "trained (+evidence) in {:.1} s: lnP = {:.2}, T1 = {:.2} h, σ̂_f = {:.3}, lnZ = {:.2}",
        train_secs,
        trained.lnp_peak,
        trained.theta_hat[1].exp(),
        trained.sigma_f_hat2.sqrt(),
        result.winner().ln_z()
    );

    // --- 2 & 3. stream two weeks, serving a day-ahead forecast daily
    let mut m = n0;
    let mut extend_secs = 0.0;
    for day in 0..stream_days {
        let hi = (m + per_day).min(full.len());
        let sw = Stopwatch::start();
        session.observe_batch(&full.t[m..hi], &full.y[m..hi])?;
        extend_secs += sw.elapsed_secs();
        m = hi;
        // forecast the *next* day on a 30-minute grid
        let t_star: Vec<f64> = (0..48).map(|i| full.t[m - 1] + 0.5 * (i + 1) as f64).collect();
        let pred = session.predict(&t_star);
        // one-line daily digest: predictive envelope of the coming day
        let (mut lo, mut hi_v) = (f64::INFINITY, f64::NEG_INFINITY);
        for v in &pred.mean {
            lo = lo.min(*v);
            hi_v = hi_v.max(*v);
        }
        println!(
            "day {:2}: window n = {}, forecast range [{:+.3}, {:+.3}] m, mean sd {:.4}",
            day + 1,
            session.stats().n_train,
            lo,
            hi_v,
            pred.sd.iter().sum::<f64>() / pred.sd.len() as f64
        );
    }
    let stats = session.stats();
    println!(
        "\nstreamed {} observations in {:.3} s of factor work (n: {} → {}, \
         {} evicted, {} cold refreshes); {} query points served",
        stats.observations_appended,
        extend_secs,
        n0,
        stats.n_train,
        stats.observations_evicted,
        session.refreshes(),
        stats.queries_served
    );

    // --- 4. verify against a from-scratch refit of the *live window*
    // at the same θ̂ (the window slid past the oldest points, so the
    // refit uses exactly the data the session still holds)
    let t_star: Vec<f64> = (0..96).map(|i| full.t[m - 1] + 0.25 * (i + 1) as f64).collect();
    let served = session.predict(&t_star);
    let (wt, wy) = (
        session.predictor().t().to_vec(),
        session.predictor().y().to_vec(),
    );
    let sw = Stopwatch::start();
    let model = ModelSpec::K1.build(SIGMA_N);
    let k = gpfast::gp::assemble_cov_with(&model, &wt, &trained.theta_hat, &exec);
    let ev = ProfiledEval::from_cov_with(k, &wy, &exec)?;
    let refit = gpfast::gp::predict(&model, &wt, &trained.theta_hat, &ev, &t_star);
    let refit_secs = sw.elapsed_secs();
    let mut max_mean = 0.0f64;
    let mut max_sd = 0.0f64;
    for i in 0..t_star.len() {
        max_mean = max_mean.max((served.mean[i] - refit.mean[i]).abs());
        max_sd = max_sd.max((served.sd[i] - refit.sd[i]).abs());
    }
    println!(
        "from-scratch refit of the {} -point window: {:.3} s (streamed factor work was {:.3} s)",
        wt.len(),
        refit_secs,
        extend_secs
    );
    println!("max |Δmean| = {max_mean:.3e}, max |Δsd| = {max_sd:.3e} vs refit");
    assert!(
        max_mean < 1e-8 && max_sd < 1e-8,
        "windowed streaming must match a from-scratch refit of the live window to 1e-8"
    );
    println!("OK: windowed streaming ≡ refit to 1e-8, with no O(n³) work in the loop");

    // --- 5. persist & restart: reload the trained artifact from disk
    // and reach the first prediction with zero likelihood evaluations
    let evals_before = gpfast::gp::profiled_eval_count();
    let sw = Stopwatch::start();
    let restored = ServeSession::from_artifacts(&[&artifact_path], exec.clone())?;
    let probe: Vec<f64> = (0..48).map(|i| full.t[n0 - 1] + 0.5 * (i + 1) as f64).collect();
    let from_disk = restored.predict(&probe);
    let restart_secs = sw.elapsed_secs();
    let evals = gpfast::gp::profiled_eval_count() - evals_before;
    // reference: a fresh in-memory session over the same artifact
    let fresh = ServeSession::from_tournament(&result.models, &history, exec.clone())?;
    let in_memory = fresh.predict(&probe);
    assert_eq!(from_disk.mean, in_memory.mean, "restored serving must be bit-identical");
    assert_eq!(from_disk.sd, in_memory.sd);
    assert_eq!(evals, 0, "restart-from-artifact must not evaluate the likelihood");
    println!(
        "OK: serving restart from {} in {:.3} s, bit-identical, {} likelihood evals \
         (the training it skipped took {:.1} s)",
        artifact_path.display(),
        restart_secs,
        evals,
        train_secs
    );
    let _ = std::fs::remove_file(&artifact_path);
    Ok(())
}
