//! Quickstart: train the paper's two covariance functions on a small
//! synthetic dataset and compare them by Laplace hyperevidence.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use gpfast::coordinator::{ComparisonPipeline, PipelineConfig};
use gpfast::data::synthetic::table1_dataset;
use gpfast::rng::Xoshiro256;

fn main() -> gpfast::Result<()> {
    // 1. data: 100 points drawn from the k2 truth (σ_f = 1, σ_n = 0.1)
    let data = table1_dataset(100, 0.1, 20160125);
    println!("dataset: {} (n = {})\n", data.label, data.len());

    // 2. train k1 and k2 with multistart conjugate gradient and rank by
    //    the Laplace hyperevidence (paper eqs. 2.13–2.19)
    let mut pipeline = ComparisonPipeline::new(PipelineConfig::paper_synthetic());
    let mut rng = Xoshiro256::seed_from_u64(1);
    let report = pipeline.run(&data, &mut rng)?;
    print!("{}", report.render());

    // 3. inspect the winner's hyperparameters with inverse-Hessian errors
    let best = &report.models[0];
    println!("\nbest model: {}", best.name);
    for ((name, th), sg) in best.param_names.iter().zip(&best.theta_hat).zip(&best.sigma) {
        println!("  {name:6} = {th:8.4} ± {sg:.4}");
    }
    println!("  σ_f    = {:8.4}", best.sigma_f_hat);
    Ok(())
}
