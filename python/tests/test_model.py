"""L2 graph correctness: scan-Cholesky and the full profiled
hyperlikelihood versus numpy LAPACK oracles."""

import numpy as np
import pytest

from compile import model as m
from compile.kernels import ref


def spd(n, seed):
    rng = np.random.RandomState(seed)
    a = rng.randn(n, n)
    return a @ a.T + n * np.eye(n)


@pytest.mark.parametrize("n", [3, 10, 40, 65])
def test_cholesky_scan_matches_numpy(n):
    k = spd(n, n)
    l_scan = np.array(m.cholesky_scan(k))
    l_np = np.linalg.cholesky(k)
    np.testing.assert_allclose(l_scan, l_np, atol=1e-10, rtol=1e-10)


def test_solve_lower_scan_matches_numpy():
    n = 30
    l = np.linalg.cholesky(spd(n, 5))
    rng = np.random.RandomState(6)
    y = rng.randn(n)
    w_scan = np.array(m.solve_lower_scan(l, y))
    w_np = np.linalg.solve(l, y)
    np.testing.assert_allclose(w_scan, w_np, atol=1e-10, rtol=1e-10)


@pytest.mark.parametrize("model", ["k1", "k2"])
@pytest.mark.parametrize("n", [20, 50])
def test_full_lnp_matches_numpy_oracle(model, n):
    """lnP_max (eq. 2.16) against a from-scratch numpy computation."""
    rng = np.random.RandomState(n + (0 if model == "k1" else 1))
    t = np.arange(1.0, n + 1.0)
    y = rng.randn(n)
    if model == "k1":
        theta = np.array([3.5, 1.5, 0.0])
    else:
        theta = np.array([3.5, 1.5, 0.0, 2.5, 0.0])
    sn = 0.1
    lnp, s2, logdet = m.full_lnp(model, t, y, theta, sn)
    # numpy oracle
    k = np.array(ref.MODELS[model]["cov"](t, theta, sn))
    l = np.linalg.cholesky(k)
    w = np.linalg.solve(l, y)
    s2_np = w @ w / n
    logdet_np = 2.0 * np.sum(np.log(np.diag(l)))
    lnp_np = -0.5 * n * (np.log(2 * np.pi * np.e) + np.log(s2_np)) - 0.5 * logdet_np
    assert abs(float(s2) - s2_np) < 1e-10 * s2_np
    assert abs(float(logdet) - logdet_np) < 1e-9 * abs(logdet_np)
    assert abs(float(lnp) - lnp_np) < 1e-9 * abs(lnp_np)


def test_full_lnp_sigma_profile_identity():
    """sigma_hat2 maximises eq. (2.14): perturbing it lowers the likelihood."""
    n = 30
    rng = np.random.RandomState(2)
    t = np.arange(1.0, n + 1.0)
    y = rng.randn(n)
    theta = np.array([3.5, 1.5, 0.0])
    lnp, s2, logdet = (float(x) for x in m.full_lnp("k1", t, y, theta, 0.1))

    def lnp_at(s):
        quad = n * s2 / s
        return -0.5 * (quad + n * np.log(2 * np.pi * s) + logdet)

    assert abs(lnp_at(s2) - lnp) < 1e-9 * abs(lnp)
    assert lnp_at(s2 * 1.1) < lnp
    assert lnp_at(s2 * 0.9) < lnp


def test_aot_lowering_has_no_custom_calls():
    """The artifacts must be pure HLO (the 0.5.1 PJRT client rejects
    typed-FFI custom calls) — this is the platform constraint that shaped
    the whole L2/L3 split, so guard it."""
    from compile import aot

    for model in ("k1", "k2"):
        text = aot.lower_cov(model, 16, grads=True)
        assert "custom-call" not in text, f"{model} cov_grads has a custom call"
    text = aot.lower_full_lnp("k1", 16)
    assert "custom-call" not in text, "full_lnp has a custom call"
