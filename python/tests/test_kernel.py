"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Sweeps shapes (including non-tile-multiples), models, and random
hyperparameter draws (a hypothesis-style randomised sweep with a fixed
seed), asserting allclose at f64 tolerances.
"""

import numpy as np
import pytest

from compile.kernels import cov, ref

RNG = np.random.RandomState(20160125)


def random_theta(model, rng):
    if model == "k1":
        return np.array(
            [rng.uniform(0.5, 4.0), rng.uniform(0.3, 3.0), rng.uniform(-0.45, 0.45)]
        )
    return np.array(
        [
            rng.uniform(0.5, 4.0),
            rng.uniform(0.3, 2.0),
            rng.uniform(-0.45, 0.45),
            rng.uniform(2.0, 4.0),
            rng.uniform(-0.45, 0.45),
        ]
    )


@pytest.mark.parametrize("model", ["k1", "k2"])
@pytest.mark.parametrize("n", [7, 30, 64, 65, 100, 130])
def test_cov_and_grads_match_ref(model, n):
    """K and all dK planes match the oracle across shapes incl. padding."""
    rng = np.random.RandomState(n)
    t = np.sort(rng.uniform(0.0, 120.0, size=n))
    theta = random_theta(model, rng)
    sn = 0.1
    k_ref, dk_ref = ref.MODELS[model]["cov_grads"](t, theta, sn)
    k_p, dk_p = cov.cov_and_grads_pallas(model, t, theta, sn)
    np.testing.assert_allclose(np.array(k_p), np.array(k_ref), atol=1e-13, rtol=1e-12)
    np.testing.assert_allclose(np.array(dk_p), np.array(dk_ref), atol=1e-13, rtol=1e-12)


@pytest.mark.parametrize("model", ["k1", "k2"])
def test_cov_only_matches(model):
    rng = np.random.RandomState(3)
    t = np.arange(1.0, 101.0)
    theta = random_theta(model, rng)
    k_ref = ref.MODELS[model]["cov"](t, theta, 0.05)
    k_p = cov.cov_pallas(model, t, theta, 0.05)
    np.testing.assert_allclose(np.array(k_p), np.array(k_ref), atol=1e-13, rtol=1e-12)


@pytest.mark.parametrize("model", ["k1", "k2"])
def test_random_sweep(model):
    """Hypothesis-style sweep: 20 random (shape, theta, sigma_n) cases."""
    for case in range(20):
        rng = np.random.RandomState(1000 + case)
        n = int(rng.randint(5, 90))
        # irregular sampling, sometimes clustered
        t = np.sort(rng.exponential(2.0, size=n).cumsum())
        theta = random_theta(model, rng)
        sn = float(rng.uniform(0.001, 0.5))
        k_ref, dk_ref = ref.MODELS[model]["cov_grads"](t, theta, sn)
        k_p, dk_p = cov.cov_and_grads_pallas(model, t, theta, sn)
        np.testing.assert_allclose(
            np.array(k_p), np.array(k_ref), atol=1e-12, rtol=1e-11,
            err_msg=f"case {case} n={n}",
        )
        np.testing.assert_allclose(
            np.array(dk_p), np.array(dk_ref), atol=1e-12, rtol=1e-11,
            err_msg=f"case {case} n={n}",
        )


def test_noise_only_on_diagonal():
    t = np.arange(1.0, 41.0)
    theta = np.array([3.5, 1.5, 0.0])
    k0 = np.array(cov.cov_pallas("k1", t, theta, 0.0))
    k1 = np.array(cov.cov_pallas("k1", t, theta, 0.3))
    diff = k1 - k0
    off = diff - np.diag(np.diag(diff))
    assert np.abs(off).max() < 1e-15
    np.testing.assert_allclose(np.diag(diff), 0.09, atol=1e-14)


def test_compact_support_zeroes_long_lags():
    # T0 = e^0 = 1 with unit spacing: everything off-diagonal is outside
    # the Wendland support
    t = np.arange(0.0, 50.0)
    theta = np.array([0.0, 1.5, 0.0])
    k = np.array(cov.cov_pallas("k1", t, theta, 0.0))
    off = k - np.diag(np.diag(k))
    assert np.abs(off).max() == 0.0


def test_grads_match_finite_differences():
    """Analytic dK from the kernel vs central differences of the oracle."""
    rng = np.random.RandomState(9)
    t = np.sort(rng.uniform(0.0, 60.0, size=25))
    theta = random_theta("k2", rng)
    _, dk = cov.cov_and_grads_pallas("k2", t, theta, 0.1)
    dk = np.array(dk)
    h = 1e-6
    for a in range(5):
        tp, tm = theta.copy(), theta.copy()
        tp[a] += h
        tm[a] -= h
        fd = (
            np.array(ref.cov_k2(t, tp, 0.1)) - np.array(ref.cov_k2(t, tm, 0.1))
        ) / (2 * h)
        np.testing.assert_allclose(dk[a], fd, atol=1e-6, rtol=1e-5)
