#!/usr/bin/env python3
"""High-precision reference values for rust/tests/golden_values.rs.

Recomputes the profiled hyperlikelihood (eq. 2.16), sigma_f_hat^2
(eq. 2.15), the Cholesky log-determinant, and the Laplace evidence
(eq. 2.13) for fixed small configurations in 60-digit mpmath arithmetic,
independently of the rust implementation.  The printed constants are
hard-coded into the rust test with a 1e-8 relative tolerance: the rust
f64 pipeline agrees with the infinite-precision value to ~1e-12 on these
well-conditioned cases, so any future regression beyond rounding noise
trips the test.

Conventions mirrored from the rust crate (rust/src/kernels, rust/src/gp):
  wendland_c(tau) = (1-tau)^6 (35 tau^2 + 18 tau + 3)/3   for tau < 1
  periodic(dt; phi, xi) = exp(-(2/l^2) sin^2(pi dt / e^phi)),
      l = exp(mu + sqrt(2) sigma_l erfinv(2 xi)), mu = 1, sigma_l = 2
  k1 = wendland(|dt| e^-phi0) * periodic(dt; phi1, xi1)
  k2 = k1 * periodic(dt; phi2, xi2)
  K = k(ti - tj) + sigma_n^2 delta_ij          (sigma_f = 1 units)
  sigma_hat^2 = y^T K^-1 y / n
  lnP_max = -(n/2) ln(2 pi e sigma_hat^2) - (1/2) ln det K
  ln Z = marg(n) + lnP_max - ln V_theta + (m/2) ln 2pi - (1/2) ln|det H|,
      H = -d^2 lnP_max / dtheta^2 (here via high-precision central FD)
      marg(n) = -ln ln(sig_hi/sig_lo) - ln 2
                + (n/2)(ln 2 + 1 - ln n) + lgamma(n/2)

All run configurations use xi = 0 exactly, where erfinv(0) = 0 in every
implementation, so no erfinv approximation error enters the comparison.
"""

import mpmath as mp

mp.mp.dps = 60

MU_L = mp.mpf(1)
SIGMA_L = mp.mpf(2)


def wendland_c(tau):
    if tau >= 1:
        return mp.mpf(0)
    om = 1 - tau
    return om**6 * (35 * tau * tau + 18 * tau + 3) / 3


def periodic(dt, phi, xi):
    l = mp.e ** (MU_L + mp.sqrt(2) * SIGMA_L * mp.erfinv(2 * xi))
    s = mp.sin(mp.pi * dt / mp.e**phi)
    return mp.e ** (-(2 / l**2) * s * s)


def k1(dt, th):
    return wendland_c(abs(dt) * mp.e ** (-th[0])) * periodic(dt, th[1], th[2])


def k2(dt, th):
    return k1(dt, th[:3]) * periodic(dt, th[3], th[4])


def chol(a):
    n = a.rows
    l = mp.zeros(n, n)
    for j in range(n):
        d = a[j, j] - mp.fsum(l[j, k] ** 2 for k in range(j))
        assert d > 0, "not PD"
        l[j, j] = mp.sqrt(d)
        for i in range(j + 1, n):
            s = a[i, j] - mp.fsum(l[i, k] * l[j, k] for k in range(j))
            l[i, j] = s / l[j, j]
    return l


def solve_chol(l, b):
    n = l.rows
    x = [mp.mpf(bi) for bi in b]
    for i in range(n):
        x[i] = (x[i] - mp.fsum(l[i, k] * x[k] for k in range(i))) / l[i, i]
    for i in reversed(range(n)):
        x[i] = (x[i] - mp.fsum(l[k, i] * x[k] for k in range(i + 1, n))) / l[i, i]
    return x


def profiled(kernel, t, y, th, sigma_n):
    n = len(t)
    a = mp.zeros(n, n)
    for i in range(n):
        for j in range(n):
            a[i, j] = kernel(t[i] - t[j], th)
        a[i, i] += mp.mpf(sigma_n) ** 2
    l = chol(a)
    logdet = 2 * mp.fsum(mp.log(l[i, i]) for i in range(n))
    alpha = solve_chol(l, y)
    s2 = mp.fsum(yi * ai for yi, ai in zip(y, alpha)) / n
    lnp = -mp.mpf(n) / 2 * (mp.log(2 * mp.pi * mp.e) + mp.log(s2)) - logdet / 2
    return lnp, s2, logdet


def marg_constant(n, lo, hi):
    ln_c = -mp.log(mp.log(mp.mpf(hi) / mp.mpf(lo)))
    nf = mp.mpf(n)
    return (
        ln_c
        - mp.log(2)
        + nf / 2 * (mp.log(2) + 1 - mp.log(nf))
        + mp.loggamma(nf / 2)
    )


def fd_hessian(f, th, h=mp.mpf("1e-8")):
    m = len(th)
    hess = mp.zeros(m, m)
    f0 = f(th)
    for a in range(m):
        tp = list(th); tp[a] += h
        tm = list(th); tm[a] -= h
        hess[a, a] = -(f(tp) - 2 * f0 + f(tm)) / h**2
        for b in range(a + 1, m):
            tpp = list(th); tpp[a] += h; tpp[b] += h
            tpm = list(th); tpm[a] += h; tpm[b] -= h
            tmp = list(th); tmp[a] -= h; tmp[b] += h
            tmm = list(th); tmm[a] -= h; tmm[b] -= h
            v = -(f(tpp) - f(tpm) - f(tmp) + f(tmm)) / (4 * h**2)
            hess[a, b] = v
            hess[b, a] = v
    return hess


def show(tag, value):
    print(f"{tag} = {mp.nstr(value, 20)}")


# --- case 1: compact support shorter than the grid spacing -> K diagonal
t = [mp.mpf(10 * i) for i in range(20)]
y = [mp.sin(mp.mpf("0.6") * ti) for ti in t]
th = [mp.log(5), mp.mpf(1), mp.mpf(0)]
lnp, s2, logdet = profiled(k1, t, y, th, mp.mpf("0.1"))
print("== case 1: diagonal limit (k1, n=20, spacing 10, T0=5) ==")
show("lnp   ", lnp)
show("s2    ", s2)
show("logdet", logdet)

# --- case 2: dense k1, n=24, grid 1..24
t = [mp.mpf(i) for i in range(1, 25)]
y = [mp.sin(mp.mpf("0.6") * ti) + mp.mpf("0.3") * mp.cos(mp.mpf("1.7") * ti) for ti in t]
th2 = [mp.mpf("2.5"), mp.mpf("1.5"), mp.mpf(0)]
lnp, s2, logdet = profiled(k1, t, y, th2, mp.mpf("0.1"))
print("\n== case 2: dense k1 (n=24, t=1..24) ==")
show("lnp   ", lnp)
show("s2    ", s2)
show("logdet", logdet)

# Laplace evidence at this theta (not a peak; formula evaluates anyway)
n = 24
hess = fd_hessian(lambda th_: profiled(k1, t, y, th_, mp.mpf("0.1"))[0], th2)
det_h = mp.det(hess)
marg = marg_constant(n, "1e-3", "1e3")
hi_phi = mp.log(23)
ln_vol = 2 * mp.log(hi_phi) + mp.log(1 - mp.mpf(2) * mp.mpf("1e-6"))
ln_z = marg + lnp - ln_vol + mp.mpf(3) / 2 * mp.log(2 * mp.pi) - mp.log(abs(det_h)) / 2
show("det H ", det_h)
show("marg  ", marg)
show("ln_vol", ln_vol)
show("ln_z  ", ln_z)

# --- case 3: dense k2, n=18, grid 1..18, paper truth theta
t = [mp.mpf(i) for i in range(1, 19)]
y = [mp.sin(mp.mpf("0.6") * ti) + mp.mpf("0.3") * mp.cos(mp.mpf("1.7") * ti) for ti in t]
th3 = [mp.mpf("3.5"), mp.mpf("1.5"), mp.mpf(0), mp.mpf("2.5"), mp.mpf(0)]
lnp, s2, logdet = profiled(k2, t, y, th3, mp.mpf("0.1"))
print("\n== case 3: dense k2 (n=18, t=1..18, truth theta) ==")
show("lnp   ", lnp)
show("s2    ", s2)
show("logdet", logdet)

# --- case 4: symmetric-eigensolver reference — the k1 Gram matrix
# K~ = K + sigma_n^2 I on the dense case-2 configuration at n=64.
# Pins the tridiagonalize + implicit-shift QL path of
# rust/src/linalg/eigen.rs (sym_eigenvalues_with) to infinite-precision
# eigenvalues: extreme and median eigenvalues, the trace, and the
# log-determinant (sum of eigenvalue logs, cross-checkable against the
# Cholesky logdet).
n = 64
t = [mp.mpf(i) for i in range(1, n + 1)]
a = mp.zeros(n, n)
for i in range(n):
    for j in range(n):
        a[i, j] = k1(t[i] - t[j], th2)
    a[i, i] += mp.mpf("0.1") ** 2
evs = sorted(mp.eigsy(a, eigvals_only=True))
print("\n== case 4: k1 Gram eigenvalues (n=64, t=1..64, theta=[2.5,1.5,0]) ==")
show("lam_min", evs[0])
show("lam_1  ", evs[1])
show("lam_mid", evs[31])
show("lam_sub", evs[62])
show("lam_max", evs[63])
show("trace  ", mp.fsum(evs))
show("logdet ", mp.fsum(mp.log(e) for e in evs))

# --- case 5: Levinson (Toeplitz) reference — the same n=64 k1 Gram is
# Toeplitz by construction on the uniform grid t=1..64, so the
# rust/src/linalg/toeplitz.rs solver must reproduce the dense solve and
# log-determinant exactly. Pins selected components of K~^-1 y for the
# case-2 data function, the quadratic form y^T K~^-1 y, and the
# log-determinant (identical to the case-4 eigenvalue/Cholesky value).
y = [mp.sin(mp.mpf("0.6") * ti) + mp.mpf("0.3") * mp.cos(mp.mpf("1.7") * ti) for ti in t]
l = chol(a)
x = solve_chol(l, y)
print("\n== case 5: Toeplitz/Levinson solve (n=64, t=1..64, theta=[2.5,1.5,0]) ==")
show("x[0]   ", x[0])
show("x[1]   ", x[1])
show("x[31]  ", x[31])
show("x[63]  ", x[63])
show("ytKinvy", mp.fsum(yi * xi for yi, xi in zip(y, x)))
show("logdet ", 2 * mp.fsum(mp.log(l[i, i]) for i in range(64)))

# --- case 6: heteroscedastic SE-ARD profiled likelihood (d=3, n=16).
# Pins the scenario tier's n x d assembly + per-point-noise diagonal:
#   K~_ij = exp(-1/2 sum_a e^{-2 phi_a} dx_a^2) + sigma_i^2 delta_ij
# with deterministic integer-derived input columns (exact in f64) and a
# cycling 4-level noise schedule. Mirrors rust's
# gp::profiled::eval_nd_with on a configuration no fast path can reach.


def se_ard(dx, th):
    r2 = mp.fsum(mp.e ** (-2 * p) * d * d for p, d in zip(th, dx))
    return mp.e ** (-r2 / 2)


n = 16
x1 = [mp.mpf(i) for i in range(1, n + 1)]
x2 = [mp.mpf((7 * i) % 16) / 2 for i in range(1, n + 1)]
x3 = [mp.mpf((3 * i) % 5) / 4 for i in range(1, n + 1)]
y = [
    mp.sin(mp.mpf("0.6") * a) + mp.mpf("0.3") * mp.cos(mp.mpf("1.7") * b)
    - mp.mpf("0.2") * c
    for a, b, c in zip(x1, x2, x3)
]
sig = [mp.mpf("0.05") * (1 + (i % 4)) for i in range(1, n + 1)]
th6 = [mp.mpf("0.5"), mp.mpf(0), mp.mpf("-0.3")]
a = mp.zeros(n, n)
for i in range(n):
    for j in range(n):
        a[i, j] = se_ard(
            (x1[i] - x1[j], x2[i] - x2[j], x3[i] - x3[j]), th6
        )
    a[i, i] += sig[i] ** 2
l = chol(a)
logdet = 2 * mp.fsum(mp.log(l[i, i]) for i in range(n))
alpha = solve_chol(l, y)
s2 = mp.fsum(yi * ai for yi, ai in zip(y, alpha)) / n
lnp = -mp.mpf(n) / 2 * (mp.log(2 * mp.pi * mp.e) + mp.log(s2)) - logdet / 2
print("\n== case 6: heteroscedastic SE-ARD (d=3, n=16, theta=[0.5,0,-0.3]) ==")
show("lnp   ", lnp)
show("s2    ", s2)
show("logdet", logdet)
