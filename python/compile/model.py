"""L2 — the jax compute graphs that get AOT-lowered to HLO text.

Two graph families per covariance model:

* ``cov_and_grads`` / ``cov`` — the O(n^2 m) covariance(+derivative)
  assembly, delegated to the L1 Pallas kernel (``kernels/cov.py``). These
  are the request-path artifacts: the rust coordinator feeds them
  ``(t, theta, sigma_n)`` and owns the O(n^3) Cholesky natively.

* ``full_lnp`` — the *entire* profiled hyperlikelihood ln P_max
  (paper eq. 2.16) in one graph, including a **scan-based Cholesky and
  forward substitution written in pure jax**. jax's own
  ``jnp.linalg.cholesky`` lowers to ``lapack_*_ffi`` typed-FFI custom
  calls that the image's PJRT client rejects (see DESIGN.md), so the
  factorisation here is a ``fori_loop`` over columns — plain HLO
  while/dot ops that any PJRT backend executes. Used for
  cross-validation and the backend ablation, not the hot path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import cov as covk
from .kernels import ref

jax.config.update("jax_enable_x64", True)

LN_2PI_E = 2.8378770664093453


def cholesky_scan(k):
    """Column-by-column Cholesky as a fori_loop (no LAPACK custom call).

    Equivalent to ``jnp.linalg.cholesky`` for SPD input; each iteration
    does one length-n masked dot and one n-vector matvec, so the lowered
    HLO is a while loop over n with O(n^2) work per step.
    """
    k = jnp.asarray(k)
    n = k.shape[0]
    idx = jnp.arange(n)

    def body(j, l):
        mask = idx < j
        row_j = jnp.where(mask, l[j, :], 0.0)
        d = jnp.sqrt(k[j, j] - jnp.dot(row_j, row_j))
        col = (k[:, j] - l @ row_j) / d
        col = jnp.where(idx > j, col, 0.0)
        l = l.at[:, j].set(col)
        return l.at[j, j].set(d)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(k))


def solve_lower_scan(l, y):
    """Forward substitution ``L w = y`` as a fori_loop."""
    l = jnp.asarray(l)
    y = jnp.asarray(y)
    n = y.shape[0]
    idx = jnp.arange(n)

    def body(i, w):
        row = jnp.where(idx < i, l[i, :], 0.0)
        wi = (y[i] - jnp.dot(row, w)) / l[i, i]
        return w.at[i].set(wi)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(y))


@functools.partial(jax.jit, static_argnames=("model",))
def full_lnp(model, t, y, theta, sigma_n):
    """Profiled hyperlikelihood (eq. 2.16): returns (lnP, sigma_hat2, logdet).

    sigma_hat2 = y^T K^-1 y / n = |L^-1 y|^2 / n   (eq. 2.15)
    lnP_max    = -(n/2) ln(2 pi e sigma_hat2) - 0.5 ln det K
    """
    k = covk.cov_pallas(model, t, theta, sigma_n)
    l = cholesky_scan(k)
    w = solve_lower_scan(l, y)
    n = y.shape[0]
    sigma_hat2 = jnp.dot(w, w) / n
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))
    lnp = -0.5 * n * (LN_2PI_E + jnp.log(sigma_hat2)) - 0.5 * logdet
    return lnp, sigma_hat2, logdet


# re-exports used by aot.py / tests
cov_pallas = covk.cov_pallas
cov_and_grads_pallas = covk.cov_and_grads_pallas
MODELS = ref.MODELS
