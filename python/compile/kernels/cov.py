"""L1 — the Pallas covariance-assembly kernel.

The paper's released code evaluates the O(n^2) covariance matrix on a GPU
(one CUDA thread per entry). The TPU re-think (DESIGN.md
section Hardware-Adaptation): one Pallas *grid cell* per (TI x TJ) tile,
with BlockSpec streaming the two `t` tile slabs HBM->VMEM, and the kernel
emitting the covariance tile **and all m hyperparameter-derivative
tiles** fused, so the shared transcendentals (sin, exp, the Wendland
polynomial) are computed once per pair.

Everything pair-independent (the erfinv-based smoothness transform,
exp(-phi) scalings) is precomputed *outside* the kernel and passed in as
a small parameter vector — the kernel body is pure VPU math.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom calls; interpret-mode lowers the kernel into plain HLO that both
jax and the rust runtime can execute. Real-TPU tiling estimates live in
EXPERIMENTS.md section Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

jax.config.update("jax_enable_x64", True)

# 64x64 f64 tiles: 2 x 0.5 KiB input slabs, (m+1) x 32 KiB output tiles.
# On a real TPU one would use 128x128 (VMEM budget table in
# EXPERIMENTS.md); 64 keeps interpret-mode padding waste low at the
# paper's n = 30..328 sizes.
TILE = 64


def _num_params(model):
    return {"k1": 5, "k2": 8}[model]


def pack_params(model, theta, sigma_n):
    """Precompute the pair-independent scalars the kernel needs.

    k1: [inv_t0, pi_inv_t1, c_l1, dxi1, sn2]
    k2: [inv_t0, pi_inv_t1, c_l1, dxi1, pi_inv_t2, c_l2, dxi2, sn2]

    where c_l = 2/l^2 and dxi = 2*c_l*d(ln l)/dxi (so that
    d(ln P)/dxi = dxi * sin^2 a).
    """
    theta = jnp.asarray(theta, jnp.float64)
    inv_t0 = jnp.exp(-theta[0])

    def periodic(phi, xi):
        l = ref.l_of_xi(xi)
        c_l = 2.0 / (l * l)
        return jnp.exp(-phi) * jnp.pi, c_l, 2.0 * c_l * ref.dl_dxi_over_l(xi)

    sn2 = jnp.asarray(sigma_n, jnp.float64) ** 2
    if model == "k1":
        a1, c1, d1 = periodic(theta[1], theta[2])
        return jnp.stack([inv_t0, a1, c1, d1, sn2])
    elif model == "k2":
        a1, c1, d1 = periodic(theta[1], theta[2])
        a2, c2, d2 = periodic(theta[3], theta[4])
        return jnp.stack([inv_t0, a1, c1, d1, a2, c2, d2, sn2])
    raise ValueError(f"unknown model {model}")


def _kernel_body(model, n, ti_ref, tj_ref, p_ref, k_ref, dk_ref):
    """One (TI x TJ) tile: covariance + all derivative planes."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    ti = ti_ref[...]
    tj = tj_ref[...]
    p = p_ref[...]
    dt = ti[:, None] - tj[None, :]

    # Wendland psi_{3,2} factor and its tau-derivative
    tau = jnp.abs(dt) * p[0]
    om = jnp.maximum(1.0 - tau, 0.0)
    om2 = om * om
    om4 = om2 * om2
    c = om4 * om2 * (35.0 * tau * tau + 18.0 * tau + 3.0) / 3.0
    c1 = -(56.0 / 3.0) * tau * (5.0 * tau + 1.0) * om4 * om

    def periodic(a_scale, c_l, dxi):
        a = dt * a_scale
        s = jnp.sin(a)
        s2 = s * s
        sin2a = jnp.sin(2.0 * a)
        val = jnp.exp(-c_l * s2)
        return val, c_l * a * sin2a, dxi * s2

    # global indices for the noise diagonal
    rows = i * TILE + jax.lax.broadcasted_iota(jnp.int32, (TILE, TILE), 0)
    cols = j * TILE + jax.lax.broadcasted_iota(jnp.int32, (TILE, TILE), 1)
    sn2 = p[_num_params(model) - 1]
    diag = jnp.where(rows == cols, sn2, 0.0)

    if model == "k1":
        p1, g_phi1, g_xi1 = periodic(p[1], p[2], p[3])
        smooth = c * p1
        k_ref[...] = smooth + diag
        dk_ref[0, :, :] = -tau * c1 * p1
        dk_ref[1, :, :] = smooth * g_phi1
        dk_ref[2, :, :] = smooth * g_xi1
    else:  # k2
        p1, g_phi1, g_xi1 = periodic(p[1], p[2], p[3])
        p2, g_phi2, g_xi2 = periodic(p[4], p[5], p[6])
        p12 = p1 * p2
        smooth = c * p12
        k_ref[...] = smooth + diag
        dk_ref[0, :, :] = -tau * c1 * p12
        dk_ref[1, :, :] = smooth * g_phi1
        dk_ref[2, :, :] = smooth * g_xi1
        dk_ref[3, :, :] = smooth * g_phi2
        dk_ref[4, :, :] = smooth * g_xi2
    del n  # shape is static; kept for signature clarity


@functools.partial(jax.jit, static_argnames=("model",))
def cov_and_grads_pallas(model, t, theta, sigma_n):
    """(K[n,n], dK[m,n,n]) assembled by the Pallas tile kernel."""
    n = t.shape[0]
    m = ref.MODELS[model]["m"]
    params = pack_params(model, theta, sigma_n)
    grid = (pl.cdiv(n, TILE), pl.cdiv(n, TILE))
    kernel = functools.partial(_kernel_body, model, n)
    k, dk = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i, j: (i,)),           # t rows
            pl.BlockSpec((TILE,), lambda i, j: (j,)),           # t cols
            pl.BlockSpec((_num_params(model),), lambda i, j: (0,)),  # params
        ],
        out_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
            pl.BlockSpec((m, TILE, TILE), lambda i, j: (0, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), jnp.float64),
            jax.ShapeDtypeStruct((m, n, n), jnp.float64),
        ],
        interpret=True,
    )(t, t, params)
    return k, dk


def _kernel_body_cov(model, ti_ref, tj_ref, p_ref, k_ref):
    """Value-only tile (line-search evaluations need no derivatives)."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    dt = ti_ref[...][:, None] - tj_ref[...][None, :]
    p = p_ref[...]
    tau = jnp.abs(dt) * p[0]
    om = jnp.maximum(1.0 - tau, 0.0)
    om2 = om * om
    c = om2 * om2 * om2 * (35.0 * tau * tau + 18.0 * tau + 3.0) / 3.0
    rows = i * TILE + jax.lax.broadcasted_iota(jnp.int32, (TILE, TILE), 0)
    cols = j * TILE + jax.lax.broadcasted_iota(jnp.int32, (TILE, TILE), 1)
    sn2 = p[_num_params(model) - 1]
    diag = jnp.where(rows == cols, sn2, 0.0)
    val = c * jnp.exp(-p[2] * jnp.sin(dt * p[1]) ** 2)
    if model == "k2":
        val = val * jnp.exp(-p[5] * jnp.sin(dt * p[4]) ** 2)
    k_ref[...] = val + diag


@functools.partial(jax.jit, static_argnames=("model",))
def cov_pallas(model, t, theta, sigma_n):
    """K[n,n] only (used on value-only line-search evaluations)."""
    n = t.shape[0]
    params = pack_params(model, theta, sigma_n)
    grid = (pl.cdiv(n, TILE), pl.cdiv(n, TILE))
    kernel = functools.partial(_kernel_body_cov, model)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i, j: (i,)),
            pl.BlockSpec((TILE,), lambda i, j: (j,)),
            pl.BlockSpec((_num_params(model),), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float64),
        interpret=True,
    )(t, t, params)
