"""Pure-jnp oracle for the paper's covariance functions.

This is the **correctness reference** for the L1 Pallas kernel
(``cov.py``): plain vectorised jnp, no Pallas, no cleverness. It mirrors
the rust ``kernels::paper`` implementation (same flat-prior coordinates,
same Wendland-psi_{3,2} erratum fix — see DESIGN.md).

Parameter layout (sigma_f profiled out, noise sigma_n passed separately):

* k1: theta = [phi0, phi1, xi1]                  (m = 3)
* k2: theta = [phi0, phi1, xi1, phi2, xi2]       (m = 5)

with T_j = exp(phi_j) and l_j = exp(mu + sqrt(2)*sigma_l*erfinv(2*xi_j)),
mu = 1, sigma_l = 2 (paper section 3).
"""

import jax
import jax.numpy as jnp
from jax.scipy.special import erfinv

jax.config.update("jax_enable_x64", True)

MU_L = 1.0
SIGMA_L = 2.0


def wendland_c(tau):
    """Wendland psi_{3,2}: (1-tau)^6 (35 tau^2 + 18 tau + 3)/3 on [0, 1)."""
    om = jnp.maximum(1.0 - tau, 0.0)
    return om**6 * (35.0 * tau**2 + 18.0 * tau + 3.0) / 3.0


def wendland_c1(tau):
    """C'(tau) = -(56/3) tau (5 tau + 1) (1-tau)^5."""
    om = jnp.maximum(1.0 - tau, 0.0)
    return -(56.0 / 3.0) * tau * (5.0 * tau + 1.0) * om**5


def l_of_xi(xi):
    """The flat->physical smoothness transform, paper eq. (3.5)."""
    return jnp.exp(MU_L + jnp.sqrt(2.0) * SIGMA_L * erfinv(2.0 * xi))


def dl_dxi_over_l(xi):
    """d(ln l)/d xi = sigma_l * sqrt(2 pi) * exp(erfinv(2 xi)^2)."""
    w = erfinv(2.0 * xi)
    return SIGMA_L * jnp.sqrt(2.0 * jnp.pi) * jnp.exp(w * w)


def _periodic_parts(dt, phi, xi):
    """Value and log-derivatives of one periodic factor at lags dt."""
    a = jnp.pi * dt * jnp.exp(-phi)
    s = jnp.sin(a)
    s2 = s * s
    sin2a = jnp.sin(2.0 * a)
    l = l_of_xi(xi)
    c_l = 2.0 / (l * l)
    val = jnp.exp(-c_l * s2)
    dlog_phi = c_l * a * sin2a
    dlog_xi = 2.0 * c_l * s2 * dl_dxi_over_l(xi)
    return val, dlog_phi, dlog_xi


def cov_k1(t, theta, sigma_n):
    """K tilde for k1 (sigma_f = 1 units), noise on the diagonal."""
    dt = t[:, None] - t[None, :]
    tau = jnp.abs(dt) * jnp.exp(-theta[0])
    c = wendland_c(tau)
    p1, _, _ = _periodic_parts(dt, theta[1], theta[2])
    n = t.shape[0]
    return c * p1 + (sigma_n**2) * jnp.eye(n)


def cov_k2(t, theta, sigma_n):
    """K tilde for k2."""
    dt = t[:, None] - t[None, :]
    tau = jnp.abs(dt) * jnp.exp(-theta[0])
    c = wendland_c(tau)
    p1, _, _ = _periodic_parts(dt, theta[1], theta[2])
    p2, _, _ = _periodic_parts(dt, theta[3], theta[4])
    n = t.shape[0]
    return c * p1 * p2 + (sigma_n**2) * jnp.eye(n)


def cov_and_grads_k1(t, theta, sigma_n):
    """(K[n,n], dK[3,n,n]) for k1 — analytic derivatives."""
    dt = t[:, None] - t[None, :]
    tau = jnp.abs(dt) * jnp.exp(-theta[0])
    c = wendland_c(tau)
    c1 = wendland_c1(tau)
    p1, dlp1_phi, dlp1_xi = _periodic_parts(dt, theta[1], theta[2])
    smooth = c * p1
    n = t.shape[0]
    k = smooth + (sigma_n**2) * jnp.eye(n)
    dk = jnp.stack(
        [
            -tau * c1 * p1,        # d/dphi0 (C' chain rule, dtau/dphi0 = -tau)
            smooth * dlp1_phi,     # d/dphi1
            smooth * dlp1_xi,      # d/dxi1
        ]
    )
    return k, dk


def cov_and_grads_k2(t, theta, sigma_n):
    """(K[n,n], dK[5,n,n]) for k2."""
    dt = t[:, None] - t[None, :]
    tau = jnp.abs(dt) * jnp.exp(-theta[0])
    c = wendland_c(tau)
    c1 = wendland_c1(tau)
    p1, dlp1_phi, dlp1_xi = _periodic_parts(dt, theta[1], theta[2])
    p2, dlp2_phi, dlp2_xi = _periodic_parts(dt, theta[3], theta[4])
    smooth = c * p1 * p2
    n = t.shape[0]
    k = smooth + (sigma_n**2) * jnp.eye(n)
    dk = jnp.stack(
        [
            -tau * c1 * p1 * p2,
            smooth * dlp1_phi,
            smooth * dlp1_xi,
            smooth * dlp2_phi,
            smooth * dlp2_xi,
        ]
    )
    return k, dk


MODELS = {
    "k1": {"m": 3, "cov": cov_k1, "cov_grads": cov_and_grads_k1},
    "k2": {"m": 5, "cov": cov_k2, "cov_grads": cov_and_grads_k2},
}
