"""AOT lowering: jax graphs -> HLO **text** artifacts + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md and DESIGN.md).

Artifact calling conventions (all f64, ``return_tuple=True``):

* ``cov``:       (t[n], theta[m], sigma_n[]) -> (K[n,n],)
* ``cov_grads``: (t[n], theta[m], sigma_n[]) -> (K[n,n], dK[m,n,n])
* ``full_lnp``:  (t[n], y[n], theta[m], sigma_n[]) -> (lnP, sigma2, logdet)

Usage: ``python -m compile.aot --out ../artifacts [--sizes 30,100,...]``
Run from the ``python/`` directory (the Makefile does).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model as m  # noqa: E402

# the paper's experiment sizes: Table 1 (30/100/300) + tidal (328/1968)
COV_SIZES = (30, 100, 300, 328, 1968)
# full-graph artifacts carry an O(n^3) while-loop; cap the size
FULL_SIZES = (30, 100, 300, 328)
MODELS = ("k1", "k2")


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cov(model, n, grads):
    mdim = m.MODELS[model]["m"]
    t_spec = jax.ShapeDtypeStruct((n,), jnp.float64)
    th_spec = jax.ShapeDtypeStruct((mdim,), jnp.float64)
    sn_spec = jax.ShapeDtypeStruct((), jnp.float64)
    if grads:
        fn = lambda t, th, sn: m.cov_and_grads_pallas(model, t, th, sn)  # noqa: E731
    else:
        fn = lambda t, th, sn: (m.cov_pallas(model, t, th, sn),)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(t_spec, th_spec, sn_spec))


def lower_full_lnp(model, n):
    mdim = m.MODELS[model]["m"]
    t_spec = jax.ShapeDtypeStruct((n,), jnp.float64)
    y_spec = jax.ShapeDtypeStruct((n,), jnp.float64)
    th_spec = jax.ShapeDtypeStruct((mdim,), jnp.float64)
    sn_spec = jax.ShapeDtypeStruct((), jnp.float64)
    fn = lambda t, y, th, sn: m.full_lnp(model, t, y, th, sn)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(t_spec, y_spec, th_spec, sn_spec))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(str(s) for s in COV_SIZES))
    ap.add_argument("--full-sizes", default=",".join(str(s) for s in FULL_SIZES))
    ap.add_argument("--models", default=",".join(MODELS))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    sizes = [int(s) for s in args.sizes.split(",") if s]
    full_sizes = [int(s) for s in args.full_sizes.split(",") if s]
    models = [s for s in args.models.split(",") if s]

    entries = []

    def emit(kind, model, n, text):
        name = f"{kind}_{model}_n{n}.hlo.txt"
        path = os.path.join(args.out, name)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "model": model,
                "n": n,
                "m": m.MODELS[model]["m"],
                "kind": kind,
                "path": name,
                # sigma_n is a runtime input, not baked into the artifact
                "sigma_n": -1.0,
            }
        )
        print(f"  wrote {name} ({len(text) / 1024:.0f} KiB)")

    for model in models:
        for n in sizes:
            print(f"lowering {model} n={n} ...")
            emit("cov", model, n, lower_cov(model, n, grads=False))
            emit("cov_grads", model, n, lower_cov(model, n, grads=True))
        for n in full_sizes:
            print(f"lowering full_lnp {model} n={n} ...")
            emit("full_lnp", model, n, lower_full_lnp(model, n))

    manifest = {"version": 1, "dtype": "f64", "artifacts": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} artifacts -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
