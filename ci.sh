#!/usr/bin/env bash
# Pre-merge gate for gpfast — run from the repo root before every merge:
#
#   ./ci.sh
#
# Mirrors the tier-1 verify in ROADMAP.md (release build + tests) and adds
# the formatting check. Benches/examples compile as part of `cargo test`'s
# target graph; `cargo bench --bench perf` is the perf-tracking run and is
# deliberately not part of the gate (wall-clock heavy).
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check (advisory) =="
    # Advisory until the pre-manifest tree is formatted wholesale: report
    # drift without failing the gate, so the gate stays usable on images
    # whose rustfmt disagrees with the seed style.
    cargo fmt --check || echo "WARNING: formatting drift (non-blocking)"
else
    echo "rustfmt unavailable; skipping fmt check"
fi

echo "ci.sh: all gates passed"
