#!/usr/bin/env bash
# Pre-merge gate for gpfast — run from the repo root before every merge:
#
#   ./ci.sh
#
# Mirrors the tier-1 verify in ROADMAP.md (release build + tests) and adds
# the formatting check. The test suite runs TWICE: once with
# GPFAST_THREADS=1 (every ExecutionContext::from_env() path serial) and
# once with the machine's full parallelism, so serial/parallel divergence
# — the bit-identity contract of runtime::exec — is caught pre-merge even
# in tests that take their thread budget from the environment.
# Benches/examples compile as part of `cargo test`'s target graph;
# `cargo bench --bench perf` / `--bench serve` are the perf-tracking runs
# and are deliberately not part of the gate (wall-clock heavy).
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (GPFAST_THREADS=1) =="
GPFAST_THREADS=1 cargo test -q

echo "== cargo test -q (GPFAST_THREADS=max) =="
GPFAST_THREADS="$(nproc 2>/dev/null || echo 4)" cargo test -q

echo "== serving-lifecycle soak (quick mode, both thread settings) =="
# The full suite above already includes soak_serving; these explicit runs
# keep the windowed evict/refresh/retrain gate visible and guarantee the
# soak's serial-vs-threaded bit-identity is exercised even if the suite
# list changes. (The #[ignore]d long-haul variant stays manual:
# `cargo test --release -- --ignored`.)
GPFAST_THREADS=1 cargo test -q --test soak_serving
GPFAST_THREADS="$(nproc 2>/dev/null || echo 4)" cargo test -q --test soak_serving

echo "== fault-injection recovery soak (quick mode, both thread settings) =="
# The numerical-health gate: a FaultPlan-corrupted stream (near-dups,
# huge outliers, non-finite points) through the windowed router must
# never panic, never serve a non-finite value, and recover via
# quarantine → retrain re-entry. (The #[ignore]d long-haul variant stays
# manual: `cargo test --release -- --ignored`.)
GPFAST_THREADS=1 cargo test -q --test soak_faults
GPFAST_THREADS="$(nproc 2>/dev/null || echo 4)" cargo test -q --test soak_faults

echo "== quick-bench smoke: micro-kernel gflops + tournament + serve + robustness recorded in BENCH_perf.json =="
# Small-n sweeps of the perf, tournament, serve and robustness benches so
# the BENCH_perf.json trajectory is refreshed on every gate run; the
# full-size sweeps stay manual `cargo bench --bench <name>`.
GPFAST_BENCH_QUICK=1 cargo bench --bench perf
GPFAST_BENCH_QUICK=1 cargo bench --bench tournament
GPFAST_BENCH_QUICK=1 cargo bench --bench serve
GPFAST_BENCH_QUICK=1 cargo bench --bench robustness

echo "== approx-tier accuracy-vs-cost panel (quick mode, both thread settings) =="
# The Chalupka-style SoD/FITC panel; run under both thread budgets so the
# approx section is refreshed by a serial and a parallel sweep (the
# second run's rows are the ones that land in BENCH_perf.json).
GPFAST_THREADS=1 GPFAST_BENCH_QUICK=1 cargo bench --bench approx
GPFAST_THREADS="$(nproc 2>/dev/null || echo 4)" GPFAST_BENCH_QUICK=1 cargo bench --bench approx

echo "== multi-tenant fleet workload (quick mode, both thread settings) =="
# 10k Zipf-traffic sessions through the bounded LRU + batch scheduler;
# the bench asserts hot-p50 < cold-p50 in-process, and the JSON gate
# below checks the fleet section landed with sane numbers. Run serial
# and max-threads so the scheduler's split/drain path is exercised both
# ways (the second run's rows are the ones that land in BENCH_perf.json).
GPFAST_THREADS=1 GPFAST_BENCH_QUICK=1 cargo bench --bench fleet
GPFAST_THREADS="$(nproc 2>/dev/null || echo 4)" GPFAST_BENCH_QUICK=1 cargo bench --bench fleet

echo "== scenario tier: ARD d-sweep + heteroscedastic evidence gap (quick mode, both thread settings) =="
# The scenario tier's bench: n×d assembly/eval/train wall over input
# dims, and the ARD-vs-isotropic ln Z gap on ARD-generated data (the
# bench asserts warm-start lineage and finite evidence in-process; the
# JSON gate below checks the section landed with sane numbers).
GPFAST_THREADS=1 GPFAST_BENCH_QUICK=1 cargo bench --bench scenario
GPFAST_THREADS="$(nproc 2>/dev/null || echo 4)" GPFAST_BENCH_QUICK=1 cargo bench --bench scenario
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'EOF'
import json, sys
doc = json.load(open("BENCH_perf.json"))
for name in ("gemm", "syrk"):
    rows = doc.get("sections", {}).get(name, [])
    if not rows or not all("gflops" in r for r in rows):
        sys.exit(f"FAIL: BENCH_perf.json section {name!r} is empty or missing gflops")
rows = doc.get("sections", {}).get("tournament", [])
if not rows or not all("tournament_seconds" in r and "warm_evals" in r for r in rows):
    sys.exit("FAIL: BENCH_perf.json section 'tournament' is empty or missing fields")
rows = doc.get("sections", {}).get("serve", [])
kinds = {r.get("kind") for r in rows}
for want in ("batch_predict", "observe", "evict", "persistence"):
    if want not in kinds:
        sys.exit(f"FAIL: BENCH_perf.json serve section is missing {want!r} rows")
if not all("evict_seconds" in r for r in rows if r.get("kind") == "evict"):
    sys.exit("FAIL: serve/evict rows missing evict_seconds")
if not all("load_seconds" in r and "retrain_seconds" in r
           for r in rows if r.get("kind") == "persistence"):
    sys.exit("FAIL: serve/persistence rows missing load/retrain fields")
rows = doc.get("sections", {}).get("robustness", [])
kinds = {r.get("kind") for r in rows}
for want in ("jitter_ladder", "ldlt", "cond_est"):
    if want not in kinds:
        sys.exit(f"FAIL: BENCH_perf.json robustness section is missing {want!r} rows")
if not all("overhead" in r for r in rows if r.get("kind") == "jitter_ladder"):
    sys.exit("FAIL: robustness/jitter_ladder rows missing overhead")
if not all("cond_seconds" in r for r in rows if r.get("kind") == "cond_est"):
    sys.exit("FAIL: robustness/cond_est rows missing cond_seconds")
rows = doc.get("sections", {}).get("approx", [])
methods = {r.get("method") for r in rows}
for want in ("k2", "sod-k2", "fitc-k2"):
    if want not in methods:
        sys.exit(f"FAIL: BENCH_perf.json approx section is missing {want!r} rows")
if not all("smse" in r and "msll" in r and "train_seconds" in r for r in rows):
    sys.exit("FAIL: approx rows missing smse/msll/train_seconds")
rows = doc.get("sections", {}).get("fleet", [])
kinds = {r.get("kind") for r in rows}
for want in ("workload", "batch", "hydrate_split", "artifact_format"):
    if want not in kinds:
        sys.exit(f"FAIL: BENCH_perf.json fleet section is missing {want!r} rows")
import math
for r in rows:
    if r.get("kind") != "workload":
        continue
    if r.get("sessions", 0) < 10000:
        sys.exit("FAIL: fleet workload must drive >= 10k sessions")
    for f in ("sessions_per_sec", "p99_us", "hit_rate", "hydration_rate"):
        v = r.get(f)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            sys.exit(f"FAIL: fleet workload field {f!r} not finite/positive: {v!r}")
    if not r.get("hit_p50_us", 0) < r.get("cold_p50_us", 0):
        sys.exit("FAIL: fleet cache economics inverted (hit p50 >= cold p50)")
splits = [r for r in rows if r.get("kind") == "hydrate_split"]
if not all("parse_us" in r and "view_us" in r and "adopt_us" in r for r in splits):
    sys.exit("FAIL: fleet/hydrate_split rows missing parse_us/view_us/adopt_us")
split_versions = {r.get("version") for r in splits}
if not {3, 4} <= split_versions:
    sys.exit(f"FAIL: fleet/hydrate_split must cover versions 3 and 4, got {split_versions}")
if not all(r.get("parse_us") == 0 for r in splits if r.get("version") == 4):
    sys.exit("FAIL: v4 hydrate_split rows must not touch the field-stream parser")
if not all(r.get("view_us") == 0 for r in splits if r.get("version") == 3):
    sys.exit("FAIL: v3 hydrate_split rows must have no view phase")
for r in rows:
    if r.get("kind") != "artifact_format":
        continue
    for f in ("v3_bytes", "v4_bytes", "v4_compressed_bytes"):
        if not isinstance(r.get(f), (int, float)) or r.get(f) <= 0:
            sys.exit(f"FAIL: fleet/artifact_format field {f!r} not positive: {r.get(f)!r}")
    ratio = r.get("compression_ratio")
    if not isinstance(ratio, (int, float)) or not math.isfinite(ratio) or not 0 < ratio <= 1:
        sys.exit(f"FAIL: fleet/artifact_format compression_ratio out of (0, 1]: {ratio!r}")
rows = doc.get("sections", {}).get("scenario", [])
kinds = {r.get("kind") for r in rows}
for want in ("d_sweep", "ard_gap"):
    if want not in kinds:
        sys.exit(f"FAIL: BENCH_perf.json scenario section is missing {want!r} rows")
sweep = [r for r in rows if r.get("kind") == "d_sweep"]
if {r.get("d") for r in sweep} < {1, 3}:
    sys.exit("FAIL: scenario/d_sweep must cover d = 1 and d = 3")
for r in sweep:
    for f in ("assemble_seconds", "eval_seconds", "train_seconds"):
        v = r.get(f)
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            sys.exit(f"FAIL: scenario/d_sweep field {f!r} not finite/positive: {v!r}")
    if not math.isfinite(r.get("lnp", math.nan)):
        sys.exit("FAIL: scenario/d_sweep lnp not finite")
for r in rows:
    if r.get("kind") != "ard_gap":
        continue
    for f in ("ln_z_iso", "ln_z_ard", "ln_b"):
        if not math.isfinite(r.get(f, math.nan)):
            sys.exit(f"FAIL: scenario/ard_gap field {f!r} not finite")
print("BENCH_perf.json gemm/syrk/tournament/serve/robustness/approx/fleet/scenario sections populated")
EOF
else
    # fallback: naive_gflops only appears in gemm/syrk rows (2 rows each
    # in quick mode), so a populated run has at least 4 of them; the
    # tournament section carries at least one wall-clock row; the serve
    # section carries evict and persistence rows
    [ "$(grep -c '"naive_gflops"' BENCH_perf.json)" -ge 4 ] \
        || { echo "FAIL: BENCH_perf.json gemm/syrk sections not populated"; exit 1; }
    [ "$(grep -c '"tournament_seconds"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json tournament section not populated"; exit 1; }
    [ "$(grep -c '"evict_seconds"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json serve/evict rows not populated"; exit 1; }
    [ "$(grep -c '"load_seconds"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json serve/persistence rows not populated"; exit 1; }
    [ "$(grep -c '"ladder_seconds"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json robustness/jitter_ladder rows not populated"; exit 1; }
    [ "$(grep -c '"ldlt_seconds"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json robustness/ldlt rows not populated"; exit 1; }
    [ "$(grep -c '"cond_seconds"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json robustness/cond_est rows not populated"; exit 1; }
    [ "$(grep -c '"smse"' BENCH_perf.json)" -ge 3 ] \
        || { echo "FAIL: BENCH_perf.json approx rows not populated"; exit 1; }
    [ "$(grep -c '"msll"' BENCH_perf.json)" -ge 3 ] \
        || { echo "FAIL: BENCH_perf.json approx rows not populated (msll)"; exit 1; }
    [ "$(grep -c '"sessions_per_sec"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json fleet workload rows not populated"; exit 1; }
    [ "$(grep -c '"parse_us"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json fleet hydrate_split rows not populated"; exit 1; }
    [ "$(grep -c '"view_us"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json fleet hydrate_split view rows not populated"; exit 1; }
    [ "$(grep -c '"compression_ratio"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json fleet artifact_format rows not populated"; exit 1; }
    [ "$(grep -c '"assemble_seconds"' BENCH_perf.json)" -ge 2 ] \
        || { echo "FAIL: BENCH_perf.json scenario d_sweep rows not populated"; exit 1; }
    [ "$(grep -c '"ln_z_ard"' BENCH_perf.json)" -ge 1 ] \
        || { echo "FAIL: BENCH_perf.json scenario ard_gap row not populated"; exit 1; }
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check (advisory) =="
    # Advisory until the pre-manifest tree is formatted wholesale: report
    # drift without failing the gate, so the gate stays usable on images
    # whose rustfmt disagrees with the seed style.
    cargo fmt --check || echo "WARNING: formatting drift (non-blocking)"
else
    echo "rustfmt unavailable; skipping fmt check"
fi

echo "ci.sh: all gates passed"
