#!/usr/bin/env python3
"""Regenerate the committed golden artifact fixtures.

Writes rust/tests/data/golden_v2.gpfast and golden_v3.gpfast: tiny,
fully deterministic k1 artifacts in the version-2 (trailer-less) and
version-3 (CRC32-trailed) field-stream formats, encoded by this script
rather than by the crate so the *format* is pinned independently of the
Rust encoder. rust/tests/persistence.rs loads them and asserts a
bit-exact hydrate; if this script and the decoder ever disagree, that
test fails.

Pure stdlib; zlib.crc32 is the same IEEE polynomial as the crate's
hand-rolled crc32.
"""
import math
import struct
import zlib
from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "rust" / "tests" / "data"

N = 8
T = [float(i + 1) for i in range(N)]
Y = [math.sin(0.7 * t) + 0.05 * t for t in T]
SIGMA_N = 0.1
THETA = [0.4, 1.3, 2.0]            # k1: phi0, phi1, xi1
PARAMS = ["phi0", "phi1", "xi1"]


def spd_kernel():
    k = [[math.exp(-0.5 * (T[i] - T[j]) ** 2 / 4.0) for j in range(N)] for i in range(N)]
    for i in range(N):
        k[i][i] += SIGMA_N * SIGMA_N + 0.1
    return k


def cholesky(k):
    l = [[0.0] * N for _ in range(N)]
    for i in range(N):
        for j in range(i + 1):
            s = k[i][j] - sum(l[i][p] * l[j][p] for p in range(j))
            l[i][j] = math.sqrt(s) if i == j else s / l[j][j]
    return l


def solve_chol(l, b):
    z = [0.0] * N
    for i in range(N):
        z[i] = (b[i] - sum(l[i][j] * z[j] for j in range(i))) / l[i][i]
    x = [0.0] * N
    for i in reversed(range(N)):
        x[i] = (z[i] - sum(l[j][i] * x[j] for j in range(i + 1, N))) / l[i][i]
    return x


class W:
    def __init__(self):
        self.buf = bytearray()

    def u8(self, v): self.buf += struct.pack("<B", v)
    def u32(self, v): self.buf += struct.pack("<I", v)
    def u64(self, v): self.buf += struct.pack("<Q", v)
    def f64(self, v): self.buf += struct.pack("<d", v)

    def s(self, text):
        raw = text.encode()
        self.u32(len(raw))
        self.buf += raw

    def f64s(self, xs):
        for x in xs:
            self.f64(x)

    def vec(self, xs):
        self.u64(len(xs))
        self.f64s(xs)

    def matrix(self, rows):
        self.u64(len(rows))
        self.u64(len(rows[0]) if rows else 0)
        for r in rows:
            self.f64s(r)


def encode(version):
    k = spd_kernel()
    l = cholesky(k)
    alpha = solve_chol(l, Y)
    logdet = 2.0 * sum(math.log(l[i][i]) for i in range(N))
    lnp = -0.5 * N * math.log(2.0 * math.pi) - 0.5 * logdet \
        - 0.5 * sum(a * y for a, y in zip(alpha, Y))
    w = W()
    w.buf += b"GPFASTMD"
    w.u32(version)
    # dataset
    w.s("golden-fixture")
    w.u64(N)
    w.f64s(T)
    w.f64s(Y)
    # spec
    w.s("k1")
    w.f64(SIGMA_N)
    w.u32(len(PARAMS))
    for p in PARAMS:
        w.s(p)
    # train result
    w.vec(THETA)
    w.f64(lnp)                     # lnp_peak
    w.f64(1.25)                    # sigma_f_hat2
    w.u8(1)                        # converged
    w.u64(42)                      # n_evals
    w.u64(1)                       # n_modes
    w.vec([lnp, lnp - 0.5])        # restart_values
    w.f64(0.0)                     # jitter
    # peak evaluation
    w.f64(lnp)
    w.f64(1.25)
    w.vec(alpha)
    w.u64(N)
    w.f64(logdet)
    for i in range(N):
        w.f64s(l[i][: i + 1])
    # evidence
    w.f64(lnp - 3.0)               # ln_z
    w.f64(lnp)                     # ln_p_peak
    w.f64(1.5)                     # ln_det_h
    w.f64(-2.0)                    # ln_volume
    w.f64(0.25)                    # marg_const
    w.vec([0.1, 0.2, 0.3])         # sigma
    w.matrix([[1.0 if i == j else 0.0 for j in range(3)] for i in range(3)])
    w.u8(0)                        # suspect
    # nested flag, warm_started, restarts, wall_secs
    w.u8(0)
    w.u8(0)
    w.u64(3)
    w.f64(0.125)
    if version == 3:
        w.u32(zlib.crc32(bytes(w.buf)) & 0xFFFFFFFF)
    return bytes(w.buf)


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    for version in (2, 3):
        path = OUT / f"golden_v{version}.gpfast"
        blob = encode(version)
        path.write_bytes(blob)
        print(f"wrote {path} ({len(blob)} bytes)")


if __name__ == "__main__":
    main()
