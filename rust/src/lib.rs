//! # gpfast — fast training of Gaussian processes on large data sets
//!
//! A production-grade reproduction of Moore, Chua, Berry & Gair,
//! *"Fast methods for training Gaussian processes on large data sets"*,
//! Royal Society Open Science **3**:160125 (2016).
//!
//! The library implements the paper's three accelerations for the GP
//! training (hyperparameter-learning) stage:
//!
//! 1. analytic **gradient** (eq. 2.7) and **Hessian** (eq. 2.9) of the
//!    log-hyperlikelihood, evaluated in `O(n² m)` once the `O(n³)`
//!    Cholesky factorisation is paid;
//! 2. **partial analytic maximisation / marginalisation** of the
//!    hyperlikelihood over the overall scale hyperparameter `σ_f`
//!    (eqs. 2.14–2.19), removing one dimension from the numerical
//!    optimisation;
//! 3. the **Laplace approximation to the hyperevidence** (eq. 2.13) in
//!    flat-prior coordinates (eqs. 3.4–3.5) for fast Bayesian model
//!    comparison between covariance functions, benchmarked against a
//!    nested-sampling baseline (the paper's MULTINEST comparator).
//!
//! ## Architecture
//!
//! The crate is the **layer-3 coordinator** of a three-layer stack:
//! a Pallas kernel (layer 1) and a JAX compute graph (layer 2) are
//! AOT-lowered at build time (`make artifacts`) to HLO text which the
//! [`runtime`] module loads and executes through the PJRT C API (behind
//! the `xla` cargo feature); Python is never on the request path. A
//! pure-rust [`runtime::NativeBackend`] implements the same interface so
//! the whole system also runs without artifacts, and the two are
//! cross-checked in the test suite.
//!
//! ### Three-level performance architecture
//!
//! Every `O(n³)`/`O(n² m)` hot path — the blocked Cholesky, the
//! covariance/derivative assembly, the explicit inverse, the multi-RHS
//! solves and the gradient/Hessian contractions — runs through three
//! nested levels:
//!
//! 1. **threads** — [`runtime::ExecutionContext`], a cheap cloneable
//!    thread-budget handle over scoped std threads (no rayon),
//!    partitions output row tiles across workers. The `*_with(…, ctx)`
//!    entry points take the context; the plain-named functions are the
//!    serial specialisations. Thread count comes from the
//!    `GPFAST_THREADS` env var, the `[runtime] threads` config key, or
//!    the machine default.
//! 2. **cache blocks** — each worker's dense kernel walks `KC×NC` /
//!    `MC×KC` panels packed into contiguous scratch ([`linalg::micro`]),
//!    so the innermost loops stream L1/L2-resident data. Pack panels and
//!    the TRSM mirror live in a **per-thread scratch arena** — a warm
//!    thread performs zero heap allocations per GEMM/TRSM call
//!    (asserted with a counting allocator in `rust/tests/alloc_reuse.rs`).
//! 3. **register tiles** — an `MR×NR` block of the output is held in
//!    `f64::mul_add` FMA accumulators for the whole panel depth
//!    (the build sets `-C target-cpu=native` in `.cargo/config.toml` so
//!    these lower to hardware FMA).
//!
//! **Oversubscription rule:** nested layers *split* the budget — when the
//! multistart coordinator fans `w` restarts across its worker pool, each
//! restart's linalg receives `ctx.split(w)` threads, so outer × inner
//! parallelism never exceeds the configured budget (see
//! [`runtime::exec`]).
//!
//! **Canonical accumulation order:** every output entry owns a private
//! FMA accumulator chain whose summation order is fixed by the global
//! block grids alone (`KC` depth chunks, `TB` solve blocks) — never by
//! the thread count, the row partition, or the batch size. Factors,
//! assembled matrices, likelihoods and gradients are therefore
//! bit-identical for any thread count — asserted in
//! `rust/tests/parallel_equivalence.rs` and `rust/tests/micro_kernels.rs`.
//! (Different *builds* — e.g. different target CPUs — may round
//! differently; the golden-value suite pins absolute accuracy at 1e-8
//! against 60-digit mpmath references.)
//!
//! ### Model lifecycle: tournament → `TrainedModel` → router
//!
//! The paper's headline contribution — fast Bayesian model comparison
//! between covariance functions — is one pipeline keyed on the
//! [`coordinator::TrainedModel`] artifact (spec + [`coordinator::TrainResult`]
//! with its adoptable peak factor + Laplace evidence with σ error bars):
//!
//! * **Roster & lineage** — [`coordinator::Roster`] parses the kernel
//!   list from config/CLI; each [`coordinator::ModelSpec`] declares a
//!   warm-start parent (k₁→k₂→k₃, wendland-se→wendland-m32/m52) whose
//!   trained peak seeds the child's multistart by parameter name.
//! * **Tournament scheduling** — [`coordinator::Tournament`] trains the
//!   roster in lineage **generations**: parents before warm-started
//!   children; models within a generation train concurrently, each under
//!   `exec.split(g)` of the shared budget (the borrowed-slots rule
//!   across *models*, not just restarts). Warm starts *replace* random
//!   restarts, so children record fewer profiled-likelihood
//!   evaluations. All RNG draws happen at schedule time in roster order:
//!   tournaments are deterministic, and a roster-of-one is bit-identical
//!   to the old standalone training path.
//! * **Ranking** — every entrant gets its Laplace evidence (eq. 2.13);
//!   [`coordinator::ComparisonReport`] ranks by ln Z with per-row ln B
//!   and the Table-2 θ̂ ± σ error-bar block.
//!
//! ### Serving layer (streaming prediction engine + multi-model router)
//!
//! Training pays `O(n³)` once; serving must not. [`gp::serve::Predictor`]
//! caches the trained state — ϑ̂, the Cholesky factor, `α = K̃⁻¹y`, σ̂_f² —
//! and answers **batched** predictive-mean/variance queries (eq. 2.1) in
//! `O(q n²)`: one parallel cross-covariance assembly plus one multi-RHS
//! triangular solve per batch, never refactorising. New observations
//! stream in through `O(n²)` factor maintenance in [`linalg`]:
//! [`linalg::Chol::extend`] (bordered factorisation) and
//! [`linalg::Chol::rank1_update`] / [`linalg::Chol::rank1_downdate`]
//! (LINPACK-style sweeps).
//!
//! [`coordinator::ServeSession`] is a **router over N cached
//! predictors**, built from a tournament (`from_tournament`), a single
//! training run (`from_training` / `train_and_serve`), or persisted
//! artifacts on disk (`from_artifacts`): queries go to the evidence
//! winner by default (bit-identical to single-model serving), or to the
//! roster under evidence-weighted model averaging
//! ([`coordinator::RouteMode`]); streamed `observe`s fan out to every
//! live factor; each appended point is first scored with each model's
//! log predictive density and a windowed per-model drift monitor
//! **flags retraining** when the log-score degrades past a threshold
//! ([`coordinator::ServeSession::needs_retrain`]).
//!
//! The session runs a **self-healing bounded-memory lifecycle** —
//! *grow → evict → refresh → retrain → quarantine* (state machine in
//! [`coordinator::serve`]):
//!
//! * **grow** — `O(n²)` factor extension per absorbed point;
//! * **evict** — with a [`coordinator::WindowPolicy`] attached, points
//!   past `max_points` delete the oldest observation from every slot via
//!   the bordered-complement restore ([`linalg::Chol::remove_row`] /
//!   [`linalg::Chol::shrink_front`]: the deleted column seeds a rank-1
//!   update sweep on the trailing block), so memory is hard-bounded;
//! * **refresh** — every `refresh_every` evictions the factors are
//!   refactorised cold from the live window (committed per slot on
//!   success), washing out accumulated rank-1 rounding drift; each
//!   refreshed factor's spectral conditioning is probed and compared
//!   against the session's condition limit;
//! * **retrain** — when drift or a health latch fires,
//!   [`coordinator::ServeSession::retrain`] reruns training on the
//!   window (warm-started from the incumbent ϑ̂), recomputes each Laplace
//!   evidence and **hot-swaps** slots, ranking and drift baselines
//!   without dropping the session;
//! * **quarantine** — a slot whose factor maintenance becomes
//!   unrecoverable is frozen at its last good factor and **routed
//!   around** (Winner falls to the next-ranked healthy slot, Averaged
//!   renormalises) instead of dropping the session; a successful retrain
//!   **re-enters** it.
//!
//! ### Numerical-health tier
//!
//! Robustness machinery keeping the pipeline alive on ill-conditioned
//! or corrupted inputs, with zero cost on the clean path:
//!
//! * **non-finite rejection at the data boundary** — [`data::Dataset`],
//!   the CSV loader and [`coordinator::ServeSession::observe`] all
//!   reject NaN/∞ inputs before any factor is touched;
//! * **jitter-escalation ladder** — when `K̃` fails to factorise,
//!   [`gp::profiled`] retries with geometrically escalating diagonal
//!   jitter (relative to the mean diagonal), recording the applied
//!   jitter into the evaluation, the [`coordinator::TrainResult`], the
//!   persisted artifact and the comparison report; the last rung runs an
//!   **LDLᵀ diagnosis** ([`linalg::Ldlt`]: diagonal-pivoted, indefinite-
//!   safe — logdet via |D| and inertia counts) to calibrate the final
//!   repair. A clean factorisation takes rung 0 with the *exact* old
//!   arithmetic — bit-identical, recorded jitter 0. Failed proposals get
//!   a finite penalised objective instead of aborting the optimiser.
//! * **spectral diagnostics** — [`linalg::sym_eigenvalues`] (Householder
//!   tridiagonalisation + implicit-shift QL, pinned to 60-digit mpmath
//!   goldens at n = 64) and a Hager-style 1-norm condition estimator
//!   ([`linalg::Chol::cond_1est`], `O(n²)`) wired into the serving
//!   refresh: estimates past the session's limit latch **degraded** →
//!   `needs_retrain`. Per-slot health (condition estimate, applied
//!   jitter, downdate-failure / refresh counters, quarantine state) is
//!   reported by [`coordinator::ServeSession::health`].
//! * **fault injection** — [`coordinator::FaultPlan`] deterministically
//!   corrupts an observation stream (near-duplicates, huge outliers,
//!   non-finite values) for the recovery soak
//!   (`rust/tests/soak_faults.rs`): never panic, never serve a
//!   non-finite value, quarantine → retrain → re-entry, and the
//!   clean-data control arm bit-identical with zero recorded jitter.
//!
//! ### Approximate-inference tier (SoD + FITC + Toeplitz fast path)
//!
//! [`gp::approx`] breaks the `O(n³)` wall with two sparse backends that
//! are first-class roster entrants — `sod-k2` and `fitc-k2`
//! ([`coordinator::ModelSpec::SodK2`] / [`coordinator::ModelSpec::FitcK2`],
//! both warm-started from exact `k2`) — so the tournament ranks *exact
//! vs approximate* on the same Laplace ln Z scale:
//!
//! * **Subset of data** — the exact profiled machinery on a
//!   deterministic stride subset of `m = Θ(√n)` points (`O(m³)` per
//!   training evaluation); its evidence surrogate completes the subset
//!   likelihood with the predictive log-density of every held-out point
//!   (`O(n m²)`).
//! * **FITC** — `m = Θ(√n)` inducing points on a uniform grid; the
//!   Woodbury/determinant-lemma forms evaluate the profiled likelihood
//!   in `O(n m²)` without materialising anything `n × n`, and the
//!   uniform grid makes the inducing Gram Toeplitz (Levinson solves).
//!   Serving goes through an `m × m` effective model whose exact-GP
//!   predictor equations reproduce FITC exactly.
//!
//! Both persist through the same versioned artifact (the factor
//! dimension is the spec-determined [`coordinator::ModelSpec::factor_dim`])
//! and serve through the same router — save → load → predict is
//! bit-identical (`rust/tests/approx.rs`). Training gradients are
//! central differences of the approximate objectives; every ranking
//! sort in the optimizer/evidence stack orders NaN-safely
//! ([`util::order`]: non-finite objectives rank last instead of
//! panicking). The accuracy-vs-cost panel (`benches/approx.rs`, Chalupka
//! et al. 2013 style) records hold-out SMSE/MSLL vs training wall-clock
//! per method into `BENCH_perf.json`.
//!
//! Independently of the sparse backends,
//! [`gp::profiled::eval_value_with`] detects **exactly uniform time
//! grids** (bitwise-equal consecutive steps — the paper's §3(b)
//! footnote 7) and routes value-only likelihood evaluations through the
//! Levinson `O(n²)` solve+logdet of [`linalg::ToeplitzSolver`], falling
//! back to the dense Cholesky off-grid; the hit counter
//! [`gp::profiled::toeplitz_hit_count`] makes the routing observable and
//! the golden suite pins the Levinson solve against 60-digit mpmath.
//!
//! ### Scenario tier (ARD multi-dimensional inputs + heteroscedastic noise)
//!
//! The input side of the stack generalises from a scalar time axis to an
//! **n×d column layout** with per-point noise, additively — the 1-D
//! homoscedastic path is untouched and stays bit-identical:
//!
//! * **data** — [`data::Dataset`] carries `extra` input columns 1..d
//!   (`with_extra_cols`) and an optional per-point noise vector
//!   (`with_noise`); the CSV loader reads multi-column files (d = 1
//!   keeps the old two-column layout) and `Dataset::span` pools the
//!   per-dimension sampling geometry ([`kernels::DataSpan::from_columns`],
//!   every column must be non-degenerate on its own). Degenerate grids —
//!   fewer than two points, or all points coincident — surface as
//!   recoverable errors, not panics (reachable from streaming duplicate
//!   timestamps; regression-tested in `rust/tests/soak_faults.rs`).
//! * **kernels** — [`kernels::ArdKernel`] implements SE/Matérn-3/2/5/2
//!   over the weighted distance `r² = Σ_j e^{−2φ_j} Δx_j²` with analytic
//!   per-dimension gradients and Hessians; the **tied** variant shares
//!   one φ across dimensions (the isotropic-in-d parent). Registry
//!   entrants `se-iso<d>` / `se-ard<d>` / `m32-ard<d>` / `m52-ard<d>`
//!   (d ∈ 1..=8) join the warm-start lineage: the ARD children seed
//!   dimension 0 from the tied parent's fitted length-scale by the
//!   shared `phiARD0` parameter name.
//! * **likelihood** — [`gp::profiled`]'s `*_nd_with` entry points accept
//!   the column layout plus an optional noise vector (`K̃_ii = k̃(0) +
//!   σ_n,i²` — noise is *data*, not a hyperparameter, so the profiled
//!   σ_f machinery is unchanged); with `d == 1` and no noise they
//!   delegate to the scalar chain, bit-identically. The Toeplitz fast
//!   path is **structurally unreachable** under non-constant noise (a
//!   heteroscedastic diagonal breaks the constant-diagonal Toeplitz
//!   form even on a uniform grid).
//! * **serving** — [`gp::serve::Predictor`] caches the input block and
//!   answers row queries (`predict_rows`) and heteroscedastic streaming
//!   (`observe_row` on [`coordinator::ServeSession`], per-point σ
//!   required iff the session is heteroscedastic); retrain carries the
//!   extras + noise through the window. Artifacts (v3 and v4) append an
//!   optional input block that is **absent** — byte-identical encodings
//!   — for 1-D homoscedastic data.
//!
//! The heteroscedastic profiled likelihood is pinned against a 60-digit
//! mpmath reference (`rust/tests/golden_values.rs` case 6); ARD kernel
//! properties sweep d ∈ {1,2,3,5} (`rust/tests/kernel_properties.rs`);
//! `benches/scenario.rs` records the d-sweep assembly/train wall and the
//! ARD-vs-isotropic evidence gap into `BENCH_perf.json`, and
//! `examples/ard_scenario.rs` is the end-to-end walkthrough.
//!
//! **Persistence** closes the loop: [`coordinator::TrainedModel`]
//! `save`/`load` write a versioned little-endian binary (spec + data +
//! ϑ̂ + packed factor with its maintained logdet + α + evidence + a
//! CRC32 integrity trailer since format v3, v2 still readable; no
//! external deps) that restores **bit-identically**, so a serving
//! process restarts in `O(n²)` — zero likelihood evaluations before its
//! first prediction, asserted via the per-thread
//! [`gp::profiled::CounterSnapshot`] deltas. CLI:
//! `gpfast train --save-model m.gpfm` / `gpfast serve --load-model
//! m.gpfm`.
//!
//! ### Zero-copy artifact format v4
//!
//! Formats v2/v3 are flat field streams: hydration re-parses and copies
//! every f64. Format v4 ([`coordinator::artifact_v4`]) is a fixed
//! 64-byte header + meta stream + **8-byte-aligned block section** whose
//! large payloads (`t`, `y`, `α`, factor) can be reinterpreted in place:
//!
//! ```text
//!   [ 0..64)  header: magic │ version=4 │ flags │ n │ chol_dim │ rank
//!             │ logdet │ meta_len │ blocks_off  (all fixed offsets)
//!   [64..64+meta_len)        v3-style meta field stream (small)
//!   [… ..blocks_off)         zero padding to the next 8-byte boundary
//!   [blocks_off..len-4)      t[n] │ y[n] │ α[d] │ factor payload
//!   [len-4..len)             CRC32 over everything preceding
//! ```
//!
//! **Alignment contract:** `blocks_off = align8(64 + meta_len)`, the
//! padding must be all-zero, and every block is a plain little-endian
//! f64 array — so on a little-endian host an 8-aligned buffer (the
//! [`coordinator::AlignedBlob`] mmap/heap wrapper the fleet's
//! `ArtifactStore::get_view` returns) hydrates through
//! [`coordinator::ArtifactView::parse`] with **zero numeric copies**:
//! parse verifies the CRC and the layout, and the O(n²) cost collapses
//! into the single `adopt` memcpy of the factor. Misaligned or
//! big-endian buffers take a checked fallback copy — never UB. The
//! factor payload is either the packed lower triangle (`d(d+1)/2`
//! doubles, bit-identical restore) or, behind the header's compressed
//! flag, a truncated spectral form `K̃ ≈ V_r Λ_r V_rᵀ + diag`
//! ([`linalg::spectral_truncate`], rank picked by a relative
//! tail-energy tolerance) storing `r(n+1) + n` doubles. Compression is
//! worth it when the kernel is smooth enough that `r ≪ n/2` at an
//! acceptable tolerance: means stay bit-identical (α is stored exactly),
//! variances carry an `O(tol)` perturbation, and hydration pays an
//! `O(r n²)` reconstruction + one `O(n³)/3` re-factorisation — encoders
//! fall back to the packed triangle whenever truncation would not
//! shrink the artifact. v2/v3 stay readable (and v3 stays the default
//! write format); readers auto-detect per blob.
//!
//! ### Fleet layer (multi-tenant serving at cache-bounded memory)
//!
//! One [`coordinator::ServeSession`] holds `O(n²)` of factors; a serving
//! process with tens of thousands of tenants cannot keep them all hot.
//! [`coordinator::Fleet`] stacks four stages between a request and a
//! factor:
//!
//! ```text
//!   ArtifactStore (cold: CRC32-checked blobs, Memory/Disk backends)
//!        │ get_view → (v4: view │ v2/v3: parse) → adopt
//!        ▼                                ▲ dirty write-back on evict
//!   LRU of ≤ capacity hydrated residents ─┘
//!        │ group per session, waves of ≤ capacity
//!        ▼
//!   batch scheduler — ExecutionContext::split per wave, no
//!        │             oversubscription, deterministic arrival order
//!        ▼
//!   ServeSession::predict_with  (cached-factor O(q n²) batch predict)
//! ```
//!
//! Hydration is the artifact path — zero likelihood evaluations — and a
//! dirty resident (post-`observe`/`retrain`) is re-serialised via
//! [`coordinator::ServeSession::to_artifact_bytes`] before its factors
//! drop, so cache pressure never loses an observation. Cache decisions
//! run sequentially on the caller's thread; only wave drains fan out —
//! predictions, eviction order and final store bytes are bit-identical
//! for any thread budget (`rust/tests/fleet.rs`). [`coordinator::FleetStats`]
//! exposes hit/hydration rates and the hydrate wall-clock split into
//! v2/v3 field-stream **parse** vs v4 zero-copy **view** vs factor
//! **adopt**; `benches/fleet.rs` drives a 10k-session Zipf workload
//! through capacity ≪ sessions into the `fleet` section of
//! `BENCH_perf.json` (per-version hydrate splits + v3/v4/compressed
//! artifact sizes). CLI: `gpfast fleet --sessions 10000 --capacity 64
//! --artifact-version 4 [--compress-tol 1e-3]`.
//!
//! `examples/streaming_tidal.rs` replays the tidal series as an arriving
//! stream through a window policy and verifies windowed serving ≡
//! from-scratch refit of the live window to 1e-8, then restarts serving
//! from the saved artifact; `rust/tests/soak_serving.rs` is the
//! long-haul soak (3× window capacity, per-step cold-refit invariants,
//! drift-injected retrain recovery) and `rust/tests/soak_faults.rs` the
//! fault-injected recovery soak.
//!
//! ## Quick start
//!
//! ```
//! use gpfast::coordinator::{ComparisonPipeline, PipelineConfig};
//! use gpfast::data::synthetic::table1_dataset;
//! use gpfast::rng::Xoshiro256;
//!
//! // 40 points drawn from the paper's k2 truth (σ_f = 1, σ_n = 0.1)
//! let data = table1_dataset(40, 0.1, 7);
//! let mut rng = Xoshiro256::seed_from_u64(7);
//! let mut pipeline = ComparisonPipeline::new(PipelineConfig::fast());
//! let report = pipeline.run(&data, &mut rng).unwrap();
//! assert_eq!(report.models.len(), 2); // k1 and k2 Laplace evidences
//! println!("{}", report.render());
//! ```

pub mod math;
pub mod rng;
pub mod linalg;
pub mod kernels;
pub mod gp;
pub mod priors;
pub mod optimize;
pub mod evidence;
pub mod nested;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod config;
pub mod util;
pub mod propcheck;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
