//! Small utilities: JSON, CLI parsing, report tables, timers.

pub mod json;
pub mod cli;
pub mod order;
pub mod table;
pub mod timer;

pub use json::Json;
pub use order::{asc_nan_last, desc_nan_last};
pub use cli::Args;
pub use table::Table;
pub use timer::{Stopwatch, TimingStats};
