//! Shared NaN-safe `f64` orderings.
//!
//! Every ranking in the pipeline — restart peaks, evidence ln Z, simplex
//! vertices, eigenvalues, timing medians — used to call
//! `partial_cmp().unwrap()` (a panic on the first NaN) or
//! `unwrap_or(Equal)` (input-order-dependent, so two rankings over the
//! same values could disagree). Both are replaced by the two total
//! orders here, built on [`f64::total_cmp`]:
//!
//! * finite values compare exactly as `partial_cmp` would;
//! * **every NaN sorts last** in either direction, so a poisoned
//!   objective value or non-finite ln Z can never win a ranking or
//!   panic a train;
//! * NaNs order among themselves by their `total_cmp` bit pattern, so
//!   the result is deterministic and input-order-independent even when
//!   several rankings see the same degenerate values.
//!
//! (`total_cmp` additionally distinguishes `-0.0 < +0.0`; `partial_cmp`
//! called them equal. Ranked quantities here are likelihoods, ln Z and
//! wall-clock times, where a signed-zero tie is not a reachable case.)

use std::cmp::Ordering;

/// Ascending total order with NaN last: `-∞ < … < +∞ < NaN`.
pub fn asc_nan_last(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        // both-NaN: total_cmp keeps the order deterministic
        _ => a.total_cmp(&b),
    }
}

/// Descending total order with NaN last: `+∞ > … > -∞ > NaN`.
///
/// The shared comparator behind every evidence/peak ranking
/// (`sort_by(|a, b| desc_nan_last(a.key, b.key))` puts the best value
/// first and anything non-finite-in-the-NaN-sense at the bottom).
pub fn desc_nan_last(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (true, true) => a.total_cmp(&b),
        (false, false) => b.total_cmp(&a),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finite_values_match_partial_cmp() {
        let vals = [-3.5, -0.0, 0.0, 1.0, f64::INFINITY, f64::NEG_INFINITY];
        for &a in &vals {
            for &b in &vals {
                if a != b {
                    assert_eq!(asc_nan_last(a, b), a.partial_cmp(&b).unwrap(), "{a} vs {b}");
                    assert_eq!(desc_nan_last(a, b), b.partial_cmp(&a).unwrap(), "{a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn nan_sorts_last_both_directions() {
        let mut v = vec![1.0, f64::NAN, -2.0, 3.0];
        v.sort_by(|a, b| asc_nan_last(*a, *b));
        assert_eq!(&v[..3], &[-2.0, 1.0, 3.0]);
        assert!(v[3].is_nan());
        let mut v = vec![1.0, f64::NAN, -2.0, 3.0];
        v.sort_by(|a, b| desc_nan_last(*a, *b));
        assert_eq!(&v[..3], &[3.0, 1.0, -2.0]);
        assert!(v[3].is_nan());
    }

    #[test]
    fn deterministic_on_all_nan_input() {
        let a = f64::from_bits(f64::NAN.to_bits());
        let b = f64::from_bits(f64::NAN.to_bits() | 1);
        assert_eq!(desc_nan_last(a, b), desc_nan_last(a, b));
        assert_ne!(desc_nan_last(a, b), desc_nan_last(b, a));
    }
}
