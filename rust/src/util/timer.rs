//! Wall-clock timing for the bench harness (no `criterion` offline).

use std::time::{Duration, Instant};

/// A running stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Summary statistics over repeated timing samples.
#[derive(Debug, Clone)]
pub struct TimingStats {
    pub samples: Vec<f64>,
}

impl TimingStats {
    /// Time `f` for `iters` iterations after `warmup` discarded runs.
    pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Self {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let sw = Stopwatch::start();
            f();
            samples.push(sw.elapsed_secs());
        }
        Self { samples }
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn median(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| super::order::asc_nan_last(*a, *b));
        let n = s.len();
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    /// Human-friendly one-liner: `mean ± std (min)` with unit scaling.
    pub fn summary(&self) -> String {
        format!(
            "{} ± {} (min {})",
            human_time(self.mean()),
            human_time(self.std()),
            human_time(self.min())
        )
    }
}

/// Seconds → "1.23 s" / "4.56 ms" / "7.89 µs".
pub fn human_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = TimingStats { samples: vec![1.0, 2.0, 3.0, 4.0] };
        assert!((s.mean() - 2.5).abs() < 1e-15);
        assert!((s.median() - 2.5).abs() < 1e-15);
        assert_eq!(s.min(), 1.0);
        let sd = s.std();
        assert!((sd - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn measure_counts_iterations() {
        let mut count = 0usize;
        let s = TimingStats::measure(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.samples.len(), 5);
        assert!(s.samples.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(1.5), "1.500 s");
        assert_eq!(human_time(0.0015), "1.500 ms");
        assert_eq!(human_time(0.0000015), "1.500 µs");
        assert!(human_time(5e-10).ends_with("ns"));
    }
}
