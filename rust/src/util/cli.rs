//! Tiny command-line argument parser (no `clap` offline).
//!
//! Grammar: `binary [subcommand] [--flag] [--key value | --key=value] [positional…]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token, if any (the subcommand).
    pub command: Option<String>,
    /// `--key value` and `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` tokens.
    pub flags: Vec<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let tokens: Vec<String> = tokens.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(rest) = t.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    args.options.insert(rest.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> crate::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} expects a number, got '{s}': {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> crate::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} expects an integer, got '{s}': {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> crate::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} expects an integer, got '{s}': {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --model k2 --n=300 --seed 7 data.csv");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("k2"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 300);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(a.positional, vec!["data.csv"]);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse("compare --fast --backend native --verbose");
        assert!(a.flag("fast"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("backend"), Some("native"));
        assert!(!a.flag("backend"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("x --quiet");
        assert!(a.flag("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("run --n abc");
        assert!(a.get_usize("n", 5).is_err());
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_or("missing", "d"), "d");
    }
}
