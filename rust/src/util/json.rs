//! Minimal JSON value type with serialiser and parser.
//!
//! No `serde` is available offline; the crate needs JSON for the artifact
//! `manifest.json` (written by the python compile path) and for run
//! reports emitted by the coordinator. Supports the full JSON grammar
//! minus unicode escapes beyond BMP `\uXXXX`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// serialisation — reports must diff cleanly between runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> crate::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    /// Serialise compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialise with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    // ---- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Builder: array of numbers.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x:?}"); // shortest round-trip repr
        }
    } else {
        // JSON has no NaN/Inf; serialise as null (documented lossy case)
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> crate::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(c),
            "expected '{}' at byte {}, found {:?}",
            c as char,
            self.i,
            self.peek().map(|b| b as char)
        );
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, other.map(|b| b as char)),
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected ',' or ']' at byte {}, got {:?}", self.i, other.map(|b| b as char)),
            }
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            anyhow::ensure!(self.i + 4 < self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let dumped = v.dump();
        let reparsed = Json::parse(&dumped).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn float_roundtrip_exact() {
        let x = 0.123456789012345678;
        let v = Json::Num(x);
        let back = Json::parse(&v.dump()).unwrap();
        assert_eq!(back.as_f64(), Some(x));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::Str("line1\nline2\t\"quoted\"\\".to_string());
        let back = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, back);
        let u = Json::parse(r#""é""#).unwrap();
        assert_eq!(u.as_str(), Some("é"));
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![
            ("name", "run1".into()),
            ("values", Json::nums(&[1.0, 2.5, -3.0])),
            ("nested", Json::obj(vec![("ok", true.into())])),
        ]);
        let p = v.pretty();
        assert!(p.contains('\n'));
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).pretty(), "[]");
    }

    #[test]
    fn non_finite_serialises_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }
}
