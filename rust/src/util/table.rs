//! Fixed-width text-table renderer — the benches print the paper's tables
//! in the same row/column layout the paper reports.

/// A simple left/right-aligned text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn add_row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with a header rule; numeric-looking cells right-aligned.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let pad = width[i] - c.chars().count();
                if looks_numeric(c) {
                    for _ in 0..pad {
                        out.push(' ');
                    }
                    out.push_str(c);
                } else {
                    out.push_str(c);
                    for _ in 0..pad {
                        out.push(' ');
                    }
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &width, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &width, &mut out);
        }
        out
    }
}

fn looks_numeric(s: &str) -> bool {
    let t = s.trim_start_matches(['-', '+']);
    !t.is_empty() && t.chars().next().is_some_and(|c| c.is_ascii_digit())
}

/// Format `value ± error` the way the paper's Table 1 does.
pub fn pm(value: f64, err: f64) -> String {
    format!("{value:.2} ± {err:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["n", "lnZ_est", "model"]);
        t.add_row(vec!["30".to_string(), "-17.77".to_string(), "k1".to_string()]);
        t.add_row(vec!["300".to_string(), "-49.94".to_string(), "k2".to_string()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same visual width for the data rows
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[1].starts_with('-'));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.add_row(vec!["1"]);
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(-17.87, 0.08), "-17.87 ± 0.08");
    }
}
