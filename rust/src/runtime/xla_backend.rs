//! The PJRT-backed XLA backend: loads the HLO-text artifacts written by
//! `python/compile/aot.py`, compiles them once per (model, n, kind), and
//! executes them for covariance assembly on the request path.
//!
//! Artifact calling conventions (all f64, lowered with `return_tuple`):
//!
//! * `cov`:       `(t[n], θ[m]) → (K[n,n],)`
//! * `cov_grads`: `(t[n], θ[m]) → (K[n,n], dK[m,n,n])`
//! * `full_lnp`:  `(t[n], y[n], θ[m]) → (lnP_max, σ̂_f², ln det K̃)` — the
//!   entire profiled hyperlikelihood (eq. 2.16) including a scan-based
//!   Cholesky, proving the whole L2 graph AOTs without LAPACK custom
//!   calls. Used for cross-validation and the backend ablation.
//!
//! Compiled executables are cached; missing artifacts fall back to the
//! native backend (count reported in metrics) unless `strict` is set.

use std::collections::HashMap;
use std::path::Path;

use crate::kernels::CovarianceModel;
use crate::linalg::Matrix;

use super::{Backend, Manifest, NativeBackend};

/// AOT-artifact backend over the PJRT CPU client.
pub struct XlaBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<(String, usize, &'static str), xla::PjRtLoadedExecutable>,
    fallback: NativeBackend,
    /// If true, a missing artifact is an error instead of a fallback.
    pub strict: bool,
    /// Requests served by XLA artifacts.
    pub n_xla: usize,
    /// Requests served by the native fallback.
    pub n_fallback: usize,
}

impl XlaBackend {
    /// Load the manifest and start a PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self {
            client,
            manifest,
            cache: HashMap::new(),
            fallback: NativeBackend::new(),
            strict: false,
            n_xla: 0,
            n_fallback: 0,
        })
    }

    /// Number of artifacts available.
    pub fn artifact_count(&self) -> usize {
        self.manifest.entries.len()
    }

    fn executable(
        &mut self,
        model: &str,
        n: usize,
        kind: &'static str,
    ) -> crate::Result<Option<&xla::PjRtLoadedExecutable>> {
        let key = (model.to_string(), n, kind);
        if !self.cache.contains_key(&key) {
            let Some(entry) = self.manifest.find(model, n, kind) else {
                return Ok(None);
            };
            let path = self.manifest.resolve(entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(self.cache.get(&key))
    }

    fn run(
        &mut self,
        model: &str,
        n: usize,
        kind: &'static str,
        inputs: &[xla::Literal],
    ) -> crate::Result<Option<xla::Literal>> {
        // (borrow dance: compile first, then take the reference)
        if self.executable(model, n, kind)?.is_none() {
            return Ok(None);
        }
        let key = (model.to_string(), n, kind);
        let exe = self.cache.get(&key).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {kind} for {model} n={n}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e}"))?;
        Ok(Some(lit))
    }

    /// Run the `full_lnp` artifact if present:
    /// returns `(lnP_max, σ̂_f², ln det K̃)`.
    pub fn full_lnp(
        &mut self,
        model: &CovarianceModel,
        t: &[f64],
        y: &[f64],
        theta: &[f64],
    ) -> crate::Result<Option<(f64, f64, f64)>> {
        let n = t.len();
        let inputs = [
            xla::Literal::vec1(t),
            xla::Literal::vec1(y),
            xla::Literal::vec1(theta),
            xla::Literal::scalar(model.sigma_n),
        ];
        match self.run(&model.name, n, "full_lnp", &inputs)? {
            None => Ok(None),
            Some(lit) => {
                let (a, b, c) = lit
                    .to_tuple3()
                    .map_err(|e| anyhow::anyhow!("full_lnp output: {e}"))?;
                let lnp = a.to_vec::<f64>()?[0];
                let s2 = b.to_vec::<f64>()?[0];
                let logdet = c.to_vec::<f64>()?[0];
                self.n_xla += 1;
                Ok(Some((lnp, s2, logdet)))
            }
        }
    }

    fn missing(&mut self, model: &str, n: usize, kind: &str) -> crate::Result<()> {
        anyhow::ensure!(
            !self.strict,
            "no '{kind}' artifact for model '{model}' at n={n} (strict mode)"
        );
        self.n_fallback += 1;
        Ok(())
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &str {
        "xla"
    }

    fn cov(
        &mut self,
        model: &CovarianceModel,
        t: &[f64],
        theta: &[f64],
    ) -> crate::Result<Matrix> {
        let n = t.len();
        let inputs = [
            xla::Literal::vec1(t),
            xla::Literal::vec1(theta),
            xla::Literal::scalar(model.sigma_n),
        ];
        match self.run(&model.name, n, "cov", &inputs)? {
            Some(lit) => {
                let k = lit.to_tuple1().map_err(|e| anyhow::anyhow!("cov output: {e}"))?;
                let flat = k.to_vec::<f64>()?;
                anyhow::ensure!(flat.len() == n * n, "cov artifact shape mismatch");
                self.n_xla += 1;
                Ok(Matrix::from_vec(n, n, flat))
            }
            None => {
                self.missing(&model.name, n, "cov")?;
                self.fallback.cov(model, t, theta)
            }
        }
    }

    fn cov_and_grads(
        &mut self,
        model: &CovarianceModel,
        t: &[f64],
        theta: &[f64],
    ) -> crate::Result<(Matrix, Vec<Matrix>)> {
        let n = t.len();
        let m = model.dim();
        let inputs = [
            xla::Literal::vec1(t),
            xla::Literal::vec1(theta),
            xla::Literal::scalar(model.sigma_n),
        ];
        match self.run(&model.name, n, "cov_grads", &inputs)? {
            Some(lit) => {
                let (k_lit, dk_lit) =
                    lit.to_tuple2().map_err(|e| anyhow::anyhow!("cov_grads output: {e}"))?;
                let k_flat = k_lit.to_vec::<f64>()?;
                let dk_flat = dk_lit.to_vec::<f64>()?;
                anyhow::ensure!(k_flat.len() == n * n, "K shape mismatch");
                anyhow::ensure!(dk_flat.len() == m * n * n, "dK shape mismatch");
                let k = Matrix::from_vec(n, n, k_flat);
                let grads: Vec<Matrix> = (0..m)
                    .map(|a| {
                        Matrix::from_vec(n, n, dk_flat[a * n * n..(a + 1) * n * n].to_vec())
                    })
                    .collect();
                self.n_xla += 1;
                Ok((k, grads))
            }
            None => {
                self.missing(&model.name, n, "cov_grads")?;
                self.fallback.cov_and_grads(model, t, theta)
            }
        }
    }

    fn accelerates(&self, model: &CovarianceModel, n: usize) -> bool {
        self.manifest.find(&model.name, n, "cov_grads").is_some()
    }
}
