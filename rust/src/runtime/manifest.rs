//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! (writer) and the rust runtime (reader).
//!
//! ```json
//! {
//!   "version": 1,
//!   "dtype": "f64",
//!   "artifacts": [
//!     {"model": "k1", "n": 100, "m": 3, "kind": "cov_grads",
//!      "path": "cov_grads_k1_n100.hlo.txt", "sigma_n": 0.1},
//!     {"model": "k1", "n": 100, "m": 3, "kind": "full_lnp",
//!      "path": "full_lnp_k1_n100.hlo.txt", "sigma_n": 0.1}
//!   ]
//! }
//! ```
//!
//! `cov_grads` artifacts map `(t[n], θ[m]) → (K[n,n], dK[m,n,n])`;
//! `full_lnp` artifacts map `(t[n], y[n], θ[m]) → (lnP_max, σ̂_f²)` with the
//! whole profiled likelihood (scan-Cholesky included) lowered to HLO.

use std::path::{Path, PathBuf};

use crate::util::Json;

/// One AOT-compiled computation.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub model: String,
    pub n: usize,
    pub m: usize,
    pub kind: String,
    pub path: PathBuf,
    pub sigma_n: f64,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from (paths resolve against it).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> crate::Result<Self> {
        let v = Json::parse(text)?;
        let version = v.get("version").and_then(Json::as_usize).unwrap_or(0);
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let dtype = v.get("dtype").and_then(Json::as_str).unwrap_or("?");
        anyhow::ensure!(dtype == "f64", "runtime requires f64 artifacts, got {dtype}");
        let arr = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts' array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let field = |k: &str| {
                e.get(k).ok_or_else(|| anyhow::anyhow!("artifact {i} missing field '{k}'"))
            };
            entries.push(ArtifactEntry {
                model: field("model")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("artifact {i}: model must be a string"))?
                    .to_string(),
                n: field("n")?.as_usize().ok_or_else(|| anyhow::anyhow!("bad n"))?,
                m: field("m")?.as_usize().ok_or_else(|| anyhow::anyhow!("bad m"))?,
                kind: field("kind")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("bad kind"))?
                    .to_string(),
                path: PathBuf::from(
                    field("path")?.as_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
                ),
                sigma_n: field("sigma_n")?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("bad sigma_n"))?,
            });
        }
        Ok(Self { entries, dir: dir.to_path_buf() })
    }

    /// Find an artifact for (model, n, kind).
    pub fn find(&self, model: &str, n: usize, kind: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.model == model && e.n == n && e.kind == kind)
    }

    /// Absolute path of an entry.
    pub fn resolve(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "dtype": "f64",
        "artifacts": [
            {"model": "k1", "n": 100, "m": 3, "kind": "cov_grads",
             "path": "cov_grads_k1_n100.hlo.txt", "sigma_n": 0.1},
            {"model": "k2", "n": 300, "m": 5, "kind": "full_lnp",
             "path": "full_lnp_k2_n300.hlo.txt", "sigma_n": 0.1}
        ]
    }"#;

    #[test]
    fn parses_and_finds() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find("k1", 100, "cov_grads").unwrap();
        assert_eq!(e.m, 3);
        assert_eq!(m.resolve(e), PathBuf::from("/tmp/a/cov_grads_k1_n100.hlo.txt"));
        assert!(m.find("k1", 101, "cov_grads").is_none());
        assert!(m.find("k3", 100, "cov_grads").is_none());
    }

    #[test]
    fn rejects_wrong_version_or_dtype() {
        assert!(Manifest::parse(r#"{"version": 2, "dtype": "f64", "artifacts": []}"#,
            Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"version": 1, "dtype": "f32", "artifacts": []}"#,
            Path::new(".")).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"version": 1, "dtype": "f64",
                      "artifacts": [{"model": "k1", "n": 10}]}"#;
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }
}
