//! The pure-rust backend: per-pair kernel evaluation via
//! [`crate::gp::assemble`], parallelised over the matrix's row tiles
//! through the backend's [`ExecutionContext`].

use crate::kernels::CovarianceModel;
use crate::linalg::Matrix;

use super::{Backend, ExecutionContext};

/// Always-available native backend.
#[derive(Default)]
pub struct NativeBackend {
    /// Thread budget for assembly (defaults to [`ExecutionContext::from_env`]).
    pub ctx: ExecutionContext,
    /// Number of assemblies served (metrics).
    pub n_cov: usize,
    pub n_cov_grads: usize,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Backend with an explicit execution context (e.g. `seq` inside an
    /// already-parallel outer layer — see the oversubscription rule in
    /// [`crate::runtime::exec`]).
    pub fn with_context(ctx: ExecutionContext) -> Self {
        Self { ctx, n_cov: 0, n_cov_grads: 0 }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn cov(
        &mut self,
        model: &CovarianceModel,
        t: &[f64],
        theta: &[f64],
    ) -> crate::Result<Matrix> {
        self.n_cov += 1;
        Ok(crate::gp::assemble::assemble_cov_with(model, t, theta, &self.ctx))
    }

    fn cov_and_grads(
        &mut self,
        model: &CovarianceModel,
        t: &[f64],
        theta: &[f64],
    ) -> crate::Result<(Matrix, Vec<Matrix>)> {
        self.n_cov_grads += 1;
        Ok(crate::gp::assemble::assemble_cov_grads_with(model, t, theta, &self.ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{paper_k1, PaperK1};

    #[test]
    fn matches_direct_assembly_and_counts() {
        let model = paper_k1(0.1);
        let t: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut b = NativeBackend::new();
        let k = b.cov(&model, &t, &PaperK1::truth()).unwrap();
        let want = crate::gp::assemble_cov(&model, &t, &PaperK1::truth());
        assert_eq!(k.max_abs_diff(&want), 0.0);
        let (_, grads) = b.cov_and_grads(&model, &t, &PaperK1::truth()).unwrap();
        assert_eq!(grads.len(), 3);
        assert_eq!(b.n_cov, 1);
        assert_eq!(b.n_cov_grads, 1);
        assert!(!b.accelerates(&model, 10));
    }

    #[test]
    fn explicit_context_matches_default() {
        let model = paper_k1(0.1);
        let t: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let mut par = NativeBackend::with_context(ExecutionContext::new(4));
        let mut seq = NativeBackend::with_context(ExecutionContext::seq());
        let kp = par.cov(&model, &t, &PaperK1::truth()).unwrap();
        let ks = seq.cov(&model, &t, &PaperK1::truth()).unwrap();
        assert_eq!(kp.max_abs_diff(&ks), 0.0);
    }
}
