//! The pure-rust backend: per-pair kernel evaluation via
//! [`crate::gp::assemble`].

use crate::kernels::CovarianceModel;
use crate::linalg::Matrix;

use super::Backend;

/// Always-available native backend.
#[derive(Default)]
pub struct NativeBackend {
    /// Number of assemblies served (metrics).
    pub n_cov: usize,
    pub n_cov_grads: usize,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn cov(
        &mut self,
        model: &CovarianceModel,
        t: &[f64],
        theta: &[f64],
    ) -> crate::Result<Matrix> {
        self.n_cov += 1;
        Ok(crate::gp::assemble_cov(model, t, theta))
    }

    fn cov_and_grads(
        &mut self,
        model: &CovarianceModel,
        t: &[f64],
        theta: &[f64],
    ) -> crate::Result<(Matrix, Vec<Matrix>)> {
        self.n_cov_grads += 1;
        Ok(crate::gp::assemble_cov_grads(model, t, theta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{paper_k1, PaperK1};

    #[test]
    fn matches_direct_assembly_and_counts() {
        let model = paper_k1(0.1);
        let t: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut b = NativeBackend::new();
        let k = b.cov(&model, &t, &PaperK1::truth()).unwrap();
        let want = crate::gp::assemble_cov(&model, &t, &PaperK1::truth());
        assert_eq!(k.max_abs_diff(&want), 0.0);
        let (_, grads) = b.cov_and_grads(&model, &t, &PaperK1::truth()).unwrap();
        assert_eq!(grads.len(), 3);
        assert_eq!(b.n_cov, 1);
        assert_eq!(b.n_cov_grads, 1);
        assert!(!b.accelerates(&model, 10));
    }
}
