//! Execution backends: where the `O(n² m)` covariance assembly runs.
//!
//! * [`NativeBackend`] — pure-rust per-pair evaluation
//!   ([`crate::gp::assemble`]); always available, the correctness
//!   reference.
//! * [`XlaBackend`] — loads the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO **text**; see DESIGN.md for why text,
//!   not serialised protos) through the PJRT C API and executes them on
//!   the CPU plugin. The artifacts contain the L1 Pallas covariance
//!   kernel lowered inside the L2 jax graph. Python is never on this
//!   path — the rust binary is self-contained once `artifacts/` exists.
//!
//! Both produce identical matrices (cross-checked in
//! `rust/tests/backend_agreement.rs`), so every experiment can run with
//! `--backend native` or `--backend xla`.

pub mod exec;
mod manifest;
mod native;
#[cfg(feature = "xla")]
mod xla_backend;

pub use exec::ExecutionContext;
pub use manifest::{ArtifactEntry, Manifest};
pub use native::NativeBackend;
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

use crate::kernels::CovarianceModel;
use crate::linalg::Matrix;

/// A source of assembled covariance matrices.
///
/// Deliberately **not** `Send`: the PJRT client wraps raw C pointers.
/// Worker threads construct their own (native) backends; the XLA backend
/// lives on the coordinator thread.
pub trait Backend {
    /// Short display name ("native", "xla").
    fn name(&self) -> &str;

    /// Assemble `K̃(ϑ)` for the model at inputs `t`.
    fn cov(&mut self, model: &CovarianceModel, t: &[f64], theta: &[f64])
        -> crate::Result<Matrix>;

    /// Assemble `K̃` and all `∂K̃/∂ϑ_a` in one call.
    fn cov_and_grads(
        &mut self,
        model: &CovarianceModel,
        t: &[f64],
        theta: &[f64],
    ) -> crate::Result<(Matrix, Vec<Matrix>)>;

    /// Does this backend have a fast path for (model, n)? Used by the
    /// coordinator to report which layer actually served a request.
    fn accelerates(&self, _model: &CovarianceModel, _n: usize) -> bool {
        false
    }
}

/// Select a backend by name. `"xla"` requires `artifacts_dir`; `"auto"`
/// tries XLA and falls back to native.
pub fn select_backend(
    name: &str,
    artifacts_dir: Option<&std::path::Path>,
) -> crate::Result<Box<dyn Backend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::new())),
        #[cfg(feature = "xla")]
        "xla" => {
            let dir = artifacts_dir
                .ok_or_else(|| anyhow::anyhow!("--backend xla needs an artifacts dir"))?;
            Ok(Box::new(XlaBackend::load(dir)?))
        }
        #[cfg(not(feature = "xla"))]
        "xla" => anyhow::bail!(
            "this build has no XLA backend: the `xla` cargo feature gates code that \
             also needs the external PJRT FFI crate, which the offline image does not \
             ship (see [features] in Cargo.toml); use --backend native"
        ),
        "auto" => match artifacts_dir {
            #[cfg(feature = "xla")]
            Some(dir) if dir.join("manifest.json").exists() => {
                Ok(Box::new(XlaBackend::load(dir)?))
            }
            _ => Ok(Box::new(NativeBackend::new())),
        },
        other => anyhow::bail!("unknown backend '{other}' (native|xla|auto)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_native() {
        let b = select_backend("native", None).unwrap();
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn select_unknown_fails() {
        assert!(select_backend("cuda", None).is_err());
    }

    #[test]
    fn auto_without_artifacts_is_native() {
        let b = select_backend("auto", Some(std::path::Path::new("/nonexistent"))).unwrap();
        assert_eq!(b.name(), "native");
    }
}
