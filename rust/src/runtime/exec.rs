//! The parallel execution layer: a cheap, cloneable thread-budget handle
//! plus scoped-thread fan-out, shared by every hot path in the crate.
//!
//! No `rayon`/`tokio` in the offline image, so the crate carries its own
//! primitives on `std::thread::scope`:
//!
//! * [`ExecutionContext`] — *how many threads may this call use?* It is a
//!   plain budget (no persistent pool handle is needed: scoped threads
//!   borrow stack data safely and the spawn cost — tens of µs — is
//!   negligible against the `O(n³)`/`O(n² m)` regions it parallelises).
//! * [`ExecutionContext::run_jobs`] — run a small vector of closures, one
//!   scoped thread each (first job runs on the caller's thread). Callers
//!   build **at most `threads()` jobs**; partition helpers below do the
//!   chunk arithmetic.
//!
//! ## Oversubscription rule (nested parallelism)
//!
//! Outer fan-out (multistart restarts over the
//! [`crate::coordinator::WorkerPool`]) and inner linalg parallelism must
//! not multiply. The discipline is *borrowed slots*: a layer that fans out
//! `w` ways hands each child `ctx.split(w)` — an integer division of the
//! budget — so the total live-thread count never exceeds the configured
//! budget. A context with one thread (`seq`) executes everything inline,
//! with zero allocation or synchronisation.
//!
//! ## Determinism
//!
//! Every parallel kernel in the crate partitions *output* rows across
//! jobs and keeps the per-element arithmetic order identical to the
//! serial code; reductions go through per-row buffers summed in row
//! order, or per-chunk partials folded in chunk order. Cholesky factors,
//! assembled covariances and gradients are **bit-identical** for any
//! thread count; see `rust/tests/parallel_equivalence.rs`.
//!
//! Thread count resolution: explicit [`ExecutionContext::new`] >
//! `GPFAST_THREADS` env var > `std::thread::available_parallelism()`.

/// Cloneable handle carrying the thread budget for one call tree.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExecutionContext {
    threads: usize,
}

impl Default for ExecutionContext {
    fn default() -> Self {
        Self::from_env()
    }
}

impl ExecutionContext {
    /// Single-threaded context: every `run_jobs` executes inline.
    pub fn seq() -> Self {
        Self { threads: 1 }
    }

    /// Context with an explicit thread budget (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// Budget from the environment: `GPFAST_THREADS` if set and positive,
    /// else the machine's available parallelism. A set-but-invalid value
    /// (non-numeric, 0, negative) warns on stderr before falling back, so
    /// a typo can't silently grab every core.
    pub fn from_env() -> Self {
        let threads = match std::env::var("GPFAST_THREADS") {
            Ok(raw) => match raw.trim().parse::<usize>() {
                Ok(t) if t > 0 => Some(t),
                _ => {
                    eprintln!(
                        "gpfast: ignoring invalid GPFAST_THREADS={raw:?} \
                         (want a positive integer); using machine parallelism"
                    );
                    None
                }
            },
            Err(_) => None,
        };
        let threads = threads.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        });
        Self::new(threads)
    }

    /// The thread budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when everything runs inline on the caller's thread.
    pub fn is_seq(&self) -> bool {
        self.threads == 1
    }

    /// Borrow at most `n` of this context's slots (never grows the budget).
    pub fn with_threads(&self, n: usize) -> Self {
        Self::new(n.min(self.threads))
    }

    /// The budget each of `ways` concurrent children may use — the
    /// oversubscription rule for nested parallelism.
    pub fn split(&self, ways: usize) -> Self {
        Self::new(self.threads / ways.max(1))
    }

    /// Run `jobs`, each exactly once, on up to `jobs.len()` scoped
    /// threads (the first job runs on the calling thread). With a `seq`
    /// context or ≤ 1 job, runs everything inline in order. Panics in any
    /// job propagate to the caller.
    ///
    /// Contract: callers submit at most [`Self::threads`] jobs; use the
    /// partition helpers to size chunks.
    pub fn run_jobs<F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send,
    {
        if self.threads <= 1 || jobs.len() <= 1 {
            for job in jobs {
                job();
            }
            return;
        }
        std::thread::scope(|scope| {
            let mut iter = jobs.into_iter();
            let first = iter.next().expect("non-empty checked above");
            let handles: Vec<_> = iter.map(|job| scope.spawn(job)).collect();
            first();
            for handle in handles {
                if let Err(payload) = handle.join() {
                    std::panic::resume_unwind(payload);
                }
            }
        });
    }

    /// [`Self::run_jobs`] for jobs that *return* values: results come
    /// back in **submission order** regardless of completion order (job 0
    /// runs on the calling thread, the rest on scoped threads), so a
    /// caller fanning work out across sessions gets a deterministic
    /// result vector to reassemble from. Panics in any job propagate.
    pub fn run_jobs_collect<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        if self.threads <= 1 || jobs.len() <= 1 {
            return jobs.into_iter().map(|job| job()).collect();
        }
        std::thread::scope(|scope| {
            let mut iter = jobs.into_iter();
            let first = iter.next().expect("non-empty checked above");
            let handles: Vec<_> = iter.map(|job| scope.spawn(job)).collect();
            let mut out = Vec::with_capacity(handles.len() + 1);
            out.push(first());
            for handle in handles {
                match handle.join() {
                    Ok(v) => out.push(v),
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
            out
        })
    }
}

/// Below this many `f64` elements of touched data an `O(q·n)`-shaped
/// batch kernel stays on the calling thread — the spawn cost of a scoped
/// dispatch outweighs the work. Shared by the serving-layer stages
/// (cross-covariance assembly, multi-RHS TRSM, variances) so one retune
/// moves them in lockstep.
pub const PAR_MIN_WORK: usize = 32_768;

/// Even partition of `lo..hi` into at most `k` non-empty chunks:
/// ascending bounds starting at `lo` and ending at `hi`.
pub fn even_bounds(lo: usize, hi: usize, k: usize) -> Vec<usize> {
    let n = hi - lo;
    let k = k.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(k + 1);
    for i in 0..=k {
        bounds.push(lo + i * n / k);
    }
    bounds.dedup();
    bounds
}

/// Partition of `lo..hi` into at most `k` non-empty chunks of roughly
/// equal **total weight**, for triangular workloads where per-index cost
/// varies (e.g. row `i` of a trailing update costs `∝ i`).
pub fn weighted_bounds<W: Fn(usize) -> f64>(lo: usize, hi: usize, k: usize, weight: W) -> Vec<usize> {
    let n = hi - lo;
    let k = k.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(k + 1);
    bounds.push(lo);
    if k > 1 {
        let total: f64 = (lo..hi).map(&weight).sum();
        let mut acc = 0.0;
        for i in lo..hi {
            acc += weight(i);
            let cuts = bounds.len() - 1;
            if cuts + 1 < k && i + 1 < hi && acc >= total * (cuts + 1) as f64 / k as f64 {
                bounds.push(i + 1);
            }
        }
    }
    bounds.push(hi);
    bounds
}

/// Split the storage of rows `bounds[0]..bounds[last]` (row-major, `cols`
/// columns, `data` starting at row `bounds[0]`) into one mutable slice per
/// consecutive bound pair. The disjointness that makes row-parallel
/// kernels safe is enforced by the borrow checker, not by `unsafe`.
pub fn split_rows_mut<'a, T>(data: &'a mut [T], cols: usize, bounds: &[usize]) -> Vec<&'a mut [T]> {
    let mut chunks = Vec::with_capacity(bounds.len().saturating_sub(1));
    let mut rest = data;
    for w in bounds.windows(2) {
        let len = (w[1] - w[0]) * cols;
        let taken = rest;
        let (head, tail) = taken.split_at_mut(len);
        chunks.push(head);
        rest = tail;
    }
    chunks
}

/// The repeated chunking dance of every row-parallel kernel in one call:
/// split the row-major storage `data` (rows `bounds[0]..bounds[last]`,
/// `cols` columns) along `bounds`, and run `f(chunk, r0, r1)` for each
/// chunk on the context's threads. `f` sees the *global* row range
/// `r0..r1` its chunk covers; `chunk` starts at row `r0`.
///
/// Callers keep choosing their own partition ([`even_bounds`] or
/// [`weighted_bounds`]) — only the split→zip→run_jobs boilerplate is
/// collapsed. Per-chunk arithmetic order is whatever `f` does, so a site
/// ported onto this helper is bit-identical to its hand-rolled original.
pub fn for_row_chunks<T, F>(
    data: &mut [T],
    cols: usize,
    bounds: &[usize],
    ctx: &ExecutionContext,
    f: F,
) where
    T: Send,
    F: Fn(&mut [T], usize, usize) + Sync,
{
    let chunks = split_rows_mut(data, cols, bounds);
    let f = &f;
    let mut job_fns = Vec::with_capacity(chunks.len());
    for (chunk, w) in chunks.into_iter().zip(bounds.windows(2)) {
        let (r0, r1) = (w[0], w[1]);
        job_fns.push(move || f(chunk, r0, r1));
    }
    ctx.run_jobs(job_fns);
}

/// Multi-buffer variant of [`for_row_chunks`]: several parallel row-major
/// buffers — each with its own column count but all starting at row
/// `bounds[0]` — are chunked along the **same** row bounds, and `f`
/// receives one chunk per buffer (in input order) plus the global row
/// range. This is the shape of kernels that fill a value matrix and its
/// derivative matrices in one sweep (`assemble_cov_grads_with`) or that
/// solve matrix rows while packing them into a scratch panel (the blocked
/// Cholesky's TRSM).
pub fn for_row_chunks_multi<'a, T, F>(
    buffers: Vec<(&'a mut [T], usize)>,
    bounds: &[usize],
    ctx: &ExecutionContext,
    f: F,
) where
    T: Send,
    F: Fn(Vec<&'a mut [T]>, usize, usize) + Sync,
{
    let n_chunks = bounds.len().saturating_sub(1);
    let n_buffers = buffers.len();
    let mut per_chunk: Vec<Vec<&'a mut [T]>> =
        (0..n_chunks).map(|_| Vec::with_capacity(n_buffers)).collect();
    for (data, cols) in buffers {
        for (ci, chunk) in split_rows_mut(data, cols, bounds).into_iter().enumerate() {
            per_chunk[ci].push(chunk);
        }
    }
    let f = &f;
    let mut job_fns = Vec::with_capacity(n_chunks);
    for (chunks, w) in per_chunk.into_iter().zip(bounds.windows(2)) {
        let (r0, r1) = (w[0], w[1]);
        job_fns.push(move || f(chunks, r0, r1));
    }
    ctx.run_jobs(job_fns);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn budget_clamps_and_splits() {
        assert_eq!(ExecutionContext::new(0).threads(), 1);
        assert!(ExecutionContext::seq().is_seq());
        let ctx = ExecutionContext::new(8);
        assert_eq!(ctx.split(3).threads(), 2);
        assert_eq!(ctx.split(100).threads(), 1);
        assert_eq!(ctx.with_threads(99).threads(), 8);
        assert_eq!(ctx.with_threads(2).threads(), 2);
    }

    #[test]
    fn run_jobs_runs_each_exactly_once() {
        for threads in [1usize, 2, 4] {
            let ctx = ExecutionContext::new(threads);
            let counter = AtomicUsize::new(0);
            let jobs: Vec<_> = (0..threads)
                .map(|_| {
                    let c = &counter;
                    move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect();
            ctx.run_jobs(jobs);
            assert_eq!(counter.load(Ordering::SeqCst), threads);
        }
    }

    #[test]
    fn run_jobs_collect_preserves_submission_order() {
        for threads in [1usize, 2, 4, 7] {
            let ctx = ExecutionContext::new(threads);
            let jobs: Vec<_> = (0..5usize).map(|i| move || i * 10).collect();
            assert_eq!(ctx.run_jobs_collect(jobs), vec![0, 10, 20, 30, 40]);
        }
        // empty and singleton inputs stay inline
        let ctx = ExecutionContext::new(4);
        let empty: Vec<fn() -> usize> = Vec::new();
        assert!(ctx.run_jobs_collect(empty).is_empty());
        assert_eq!(ctx.run_jobs_collect(vec![|| 7usize]), vec![7]);
    }

    #[test]
    fn run_jobs_borrows_disjoint_chunks() {
        let ctx = ExecutionContext::new(4);
        let mut data = vec![0.0f64; 100];
        let bounds = even_bounds(0, 100, 4);
        let chunks = split_rows_mut(&mut data, 1, &bounds);
        let mut jobs = Vec::new();
        for (chunk, w) in chunks.into_iter().zip(bounds.windows(2)) {
            let r0 = w[0];
            jobs.push(move || {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = (r0 + i) as f64;
                }
            });
        }
        ctx.run_jobs(jobs);
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i as f64);
        }
    }

    #[test]
    fn even_bounds_cover_range() {
        for (lo, hi, k) in [(0usize, 10usize, 3usize), (5, 6, 4), (0, 0, 2), (2, 100, 7)] {
            let b = even_bounds(lo, hi, k);
            assert_eq!(*b.first().unwrap(), lo);
            assert_eq!(*b.last().unwrap(), hi.max(lo));
            for w in b.windows(2) {
                assert!(w[0] < w[1], "empty chunk in {b:?}");
            }
            assert!(b.len() <= k + 1);
        }
    }

    #[test]
    fn weighted_bounds_balance_triangular_cost() {
        // weight(i) = i + 1 over 0..100 split 4 ways: each chunk's total
        // weight should be within 2× of the ideal quarter.
        let b = weighted_bounds(0, 100, 4, |i| (i + 1) as f64);
        assert_eq!(*b.first().unwrap(), 0);
        assert_eq!(*b.last().unwrap(), 100);
        let total: f64 = (0..100).map(|i| (i + 1) as f64).sum();
        for w in b.windows(2) {
            let chunk: f64 = (w[0]..w[1]).map(|i| (i + 1) as f64).sum();
            assert!(chunk < total / 2.0, "chunk {w:?} holds {chunk} of {total}");
        }
        // first chunk (cheap rows) must hold more rows than the last
        assert!(b[1] - b[0] > 100 - b[b.len() - 2]);
    }

    #[test]
    fn for_row_chunks_partitions_exactly_once() {
        // every cell written exactly once, with the correct global row
        // index, for even and weighted partitions and any thread count
        for threads in [1usize, 2, 4, 7] {
            let ctx = ExecutionContext::new(threads);
            for (lo, hi) in [(0usize, 13usize), (3, 29), (5, 6), (0, 1)] {
                let cols = 3;
                let mut data = vec![-1.0f64; (hi - lo) * cols];
                let bounds = weighted_bounds(lo, hi, threads, |i| (i + 1) as f64);
                for_row_chunks(&mut data, cols, &bounds, &ctx, |chunk, r0, r1| {
                    assert_eq!(chunk.len(), (r1 - r0) * cols);
                    for r in r0..r1 {
                        for c in 0..cols {
                            let cell = &mut chunk[(r - r0) * cols + c];
                            assert_eq!(*cell, -1.0, "row {r} written twice");
                            *cell = (r * cols + c) as f64;
                        }
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, (lo * cols + i) as f64, "cell {i} wrong/unwritten");
                }
            }
        }
    }

    #[test]
    fn for_row_chunks_multi_keeps_buffers_in_lockstep() {
        // two buffers with different column counts, chunked on the same
        // bounds: every cell of both written exactly once with its global
        // row index visible to the job
        for threads in [1usize, 3] {
            let ctx = ExecutionContext::new(threads);
            let (lo, hi) = (2usize, 17usize);
            let (ca, cb) = (4usize, 2usize);
            let mut a = vec![-1.0f64; (hi - lo) * ca];
            let mut b = vec![-1.0f64; (hi - lo) * cb];
            let bounds = even_bounds(lo, hi, threads);
            for_row_chunks_multi(
                vec![(&mut a[..], ca), (&mut b[..], cb)],
                &bounds,
                &ctx,
                |chunks, r0, r1| {
                    let mut it = chunks.into_iter();
                    let ac = it.next().unwrap();
                    let bc = it.next().unwrap();
                    assert!(it.next().is_none());
                    assert_eq!(ac.len(), (r1 - r0) * ca);
                    assert_eq!(bc.len(), (r1 - r0) * cb);
                    for r in r0..r1 {
                        for c in 0..ca {
                            ac[(r - r0) * ca + c] = (r * ca + c) as f64;
                        }
                        for c in 0..cb {
                            bc[(r - r0) * cb + c] = (r * cb + c) as f64;
                        }
                    }
                },
            );
            for (i, v) in a.iter().enumerate() {
                assert_eq!(*v, (lo * ca + i) as f64, "a[{i}] threads={threads}");
            }
            for (i, v) in b.iter().enumerate() {
                assert_eq!(*v, (lo * cb + i) as f64, "b[{i}] threads={threads}");
            }
        }
    }

    #[test]
    fn weighted_bounds_degenerate() {
        assert_eq!(weighted_bounds(3, 4, 8, |_| 1.0), vec![3, 4]);
        let b = weighted_bounds(0, 5, 1, |i| i as f64);
        assert_eq!(b, vec![0, 5]);
    }
}
