//! Distribution helpers layered on [`Xoshiro256`].

use super::Xoshiro256;
use crate::linalg::{Chol, Matrix};

/// A scalar normal distribution `N(mean, sd²)`.
#[derive(Clone, Copy, Debug)]
pub struct Normal {
    pub mean: f64,
    pub sd: f64,
}

impl Normal {
    pub fn new(mean: f64, sd: f64) -> Self {
        assert!(sd >= 0.0, "standard deviation must be non-negative");
        Self { mean, sd }
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        self.mean + self.sd * rng.normal()
    }

    /// Log-density at `x`.
    pub fn log_pdf(&self, x: f64) -> f64 {
        let z = (x - self.mean) / self.sd;
        -0.5 * z * z - self.sd.ln() - 0.5 * crate::math::LN_2PI
    }
}

/// A multivariate normal `N(mean, Σ)` sampled through the Cholesky factor
/// of Σ — this is how GP realisations (paper Fig. 1) are drawn.
pub struct MultivariateNormal {
    mean: Vec<f64>,
    chol: Chol,
}

impl MultivariateNormal {
    /// Construct from a mean vector and covariance matrix.
    ///
    /// Fails if `cov` is not (numerically) positive definite.
    pub fn new(mean: Vec<f64>, cov: &Matrix) -> crate::Result<Self> {
        anyhow::ensure!(
            cov.rows() == mean.len() && cov.cols() == mean.len(),
            "covariance shape {}x{} does not match mean length {}",
            cov.rows(),
            cov.cols(),
            mean.len()
        );
        let chol = Chol::factor(cov)?;
        Ok(Self { mean, chol })
    }

    /// Dimension of the distribution.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Draw one sample: `mean + L z`, `z ~ N(0, I)`.
    pub fn sample(&self, rng: &mut Xoshiro256) -> Vec<f64> {
        let n = self.dim();
        let mut z = vec![0.0; n];
        rng.fill_normal(&mut z);
        let mut out = self.mean.clone();
        // out += L z (L lower triangular)
        let l = self.chol.factor_matrix();
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..=i {
                acc += l[(i, j)] * z[j];
            }
            out[i] += acc;
        }
        out
    }

    /// Log-density at `x`.
    pub fn log_pdf(&self, x: &[f64]) -> f64 {
        let n = self.dim();
        let dx: Vec<f64> = x.iter().zip(&self.mean).map(|(a, b)| a - b).collect();
        let alpha = self.chol.solve(&dx);
        let quad: f64 = dx.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        -0.5 * (quad + self.chol.logdet() + n as f64 * crate::math::LN_2PI)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_sample_moments() {
        let mut rng = Xoshiro256::seed_from_u64(100);
        let d = Normal::new(3.0, 2.0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.03, "mean {mean}");
        assert!((var - 4.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn normal_log_pdf_matches_closed_form() {
        let d = Normal::new(0.0, 1.0);
        // standard normal at 0: -0.5 ln 2π
        assert!((d.log_pdf(0.0) + 0.5 * crate::math::LN_2PI).abs() < 1e-15);
    }

    #[test]
    fn mvn_sample_covariance_recovers_sigma() {
        let mut rng = Xoshiro256::seed_from_u64(21);
        // Σ = [[2, 0.6], [0.6, 1]]
        let cov = Matrix::from_rows(&[&[2.0, 0.6], &[0.6, 1.0]]);
        let mvn = MultivariateNormal::new(vec![1.0, -2.0], &cov).unwrap();
        let n = 100_000;
        let mut m = [0.0; 2];
        let mut c = [[0.0; 2]; 2];
        let samples: Vec<Vec<f64>> = (0..n).map(|_| mvn.sample(&mut rng)).collect();
        for s in &samples {
            m[0] += s[0];
            m[1] += s[1];
        }
        m[0] /= n as f64;
        m[1] /= n as f64;
        for s in &samples {
            let d0 = s[0] - m[0];
            let d1 = s[1] - m[1];
            c[0][0] += d0 * d0;
            c[0][1] += d0 * d1;
            c[1][1] += d1 * d1;
        }
        for row in &mut c {
            for v in row.iter_mut() {
                *v /= n as f64;
            }
        }
        assert!((m[0] - 1.0).abs() < 0.02);
        assert!((m[1] + 2.0).abs() < 0.02);
        assert!((c[0][0] - 2.0).abs() < 0.05, "c00 {}", c[0][0]);
        assert!((c[0][1] - 0.6).abs() < 0.03, "c01 {}", c[0][1]);
        assert!((c[1][1] - 1.0).abs() < 0.03, "c11 {}", c[1][1]);
    }

    #[test]
    fn mvn_log_pdf_vs_independent_product() {
        // diagonal Σ → log pdf must equal sum of 1-D log pdfs
        let cov = Matrix::diag(&[4.0, 9.0]);
        let mvn = MultivariateNormal::new(vec![0.5, -0.5], &cov).unwrap();
        let x = [1.0, 2.0];
        let want = Normal::new(0.5, 2.0).log_pdf(1.0) + Normal::new(-0.5, 3.0).log_pdf(2.0);
        assert!((mvn.log_pdf(&x) - want).abs() < 1e-12);
    }

    #[test]
    fn mvn_rejects_non_psd() {
        let cov = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // indefinite
        assert!(MultivariateNormal::new(vec![0.0, 0.0], &cov).is_err());
    }
}
