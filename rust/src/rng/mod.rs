//! Pseudo-random number generation substrate.
//!
//! The build environment has no `rand` crate, so the crate carries its own
//! generator: **xoshiro256++** (Blackman & Vigna) seeded through
//! **splitmix64**, plus the distributions the paper's experiments need —
//! uniforms, Box–Muller normals, and multivariate normals through a
//! Cholesky factor (used to draw GP realisations, Fig. 1).

mod distributions;

pub use distributions::{MultivariateNormal, Normal};

/// xoshiro256++ — fast, high-quality 64-bit PRNG with 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// splitmix64 step — used to expand a single u64 seed into PRNG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Xoshiro256 {
    /// Seed deterministically from a single `u64` via splitmix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // all-zero state is the one forbidden state; splitmix64 of any seed
        // cannot produce it across 4 consecutive outputs, but guard anyway.
        if s == [0, 0, 0, 0] {
            Self { s: [1, 2, 3, 4] }
        } else {
            Self { s }
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick: unbiased enough for simulation workloads
        // (bias < 2^-64), and branch-free.
        let x = self.next_u64() as u128;
        ((x * n as u128) >> 64) as usize
    }

    /// Standard normal deviate (Box–Muller, cached second value).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        // Box–Muller without caching: simple, branch-predictable, and the
        // hot paths batch through `Normal`/`MultivariateNormal` anyway.
        loop {
            let u1 = self.uniform();
            if u1 > 0.0 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        let two_pi = 2.0 * std::f64::consts::PI;
        let mut i = 0;
        while i + 1 < out.len() {
            let u1 = loop {
                let u = self.uniform();
                if u > 0.0 {
                    break u;
                }
            };
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (two_pi * u2).sin_cos();
            out[i] = r * c;
            out[i + 1] = r * s;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.normal();
        }
    }

    /// Split off an independent stream (jump-free: reseed through splitmix
    /// of the current state — adequate for embarrassingly parallel workers).
    pub fn split(&mut self) -> Xoshiro256 {
        Xoshiro256::seed_from_u64(self.next_u64())
    }

    /// Random permutation index shuffle (Fisher–Yates) of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            idx.swap(i, j);
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_construction() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_moments() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.uniform();
            m1 += u;
            m2 += u * u;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!((m1 - 0.5).abs() < 3e-3, "mean {m1}");
        assert!((m2 - 1.0 / 3.0).abs() < 3e-3, "E[x²] {m2}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 200_000usize;
        let mut xs = vec![0.0; n];
        r.fill_normal(&mut xs);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let skew = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!(skew.abs() < 0.03, "skew {skew}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent_enough() {
        let mut root = Xoshiro256::seed_from_u64(1234);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
