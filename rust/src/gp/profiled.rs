//! The σ_f-profiled hyperlikelihood — paper §2(b).
//!
//! For the scaled covariance `K = σ_f² K̃(ϑ)`, the hyperlikelihood
//! (eq. 2.14) has a unique analytic maximum over σ_f² at
//! `σ̂_f² = yᵀK̃⁻¹y / n` (eq. 2.15), where it takes the value
//!
//! `ln P_max(ϑ) = −(n/2) ln(2πe σ̂_f²) − ½ ln det K̃`   (eq. 2.16)
//!
//! with gradient (eq. 2.17) and Hessian (eq. 2.19). Marginalising σ_f over
//! a Jeffreys prior instead of maximising gives the same function of ϑ up
//! to the additive constant of eq. (2.18) ([`marg_constant`]), so both
//! share gradients and Hessians.
//!
//! Every `*_with` entry point threads an [`ExecutionContext`] through the
//! assembly, Cholesky, inverse and `O(n²)` contraction stages; the
//! plain-named functions are the serial specialisations. Evaluations and
//! gradients are bit-identical across thread counts (contractions reduce
//! through per-row buffers summed in row order).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::kernels::CovarianceModel;
use crate::linalg::{dot, Chol, Ldlt, Matrix};
use crate::math::{lgamma, LN_2PI_E};
use crate::runtime::exec::{even_bounds, for_row_chunks, ExecutionContext};

use super::assemble::{
    assemble_cov_grads_nd_with, assemble_cov_grads_with, assemble_cov_nd_with, assemble_cov_with,
    hessian_contractions_nd_with, hessian_contractions_with,
};

/// Process-global count of profiled-likelihood evaluations (every
/// factor-producing evaluation flows through
/// [`ProfiledEval::from_cov_with`], the single choke point of both
/// backends). Monotonic; used by tests and the `serve --load-model` CLI
/// to *prove* a restart-from-artifact path reached its first prediction
/// without paying any likelihood evaluation. Note it is shared by every
/// thread in the process — delta-based assertions must not run
/// concurrently with other evaluating work.
static EVAL_COUNT: AtomicU64 = AtomicU64::new(0);

/// Current value of the process-global evaluation counter.
pub fn eval_count() -> u64 {
    EVAL_COUNT.load(Ordering::Relaxed)
}

/// Process-global count of value-only likelihood evaluations served by
/// the Toeplitz/Levinson uniform-grid fast path of
/// [`eval_value_with`] (each also counts in [`eval_count`]). Tests use
/// deltas of this to prove the `O(n²)` route actually engaged.
static TOEPLITZ_HITS: AtomicU64 = AtomicU64::new(0);

/// Current value of the Toeplitz fast-path counter.
pub fn toeplitz_hit_count() -> u64 {
    TOEPLITZ_HITS.load(Ordering::Relaxed)
}

thread_local! {
    // Thread-local shadows of the process-global counters, incremented at
    // the same two choke points. These back [`CounterSnapshot`], whose
    // deltas see only the *calling thread's* evaluations — so tests can
    // assert "this code path performed zero evaluations" without
    // serialising against every other test thread in the process.
    static LOCAL_EVALS: Cell<u64> = Cell::new(0);
    static LOCAL_TOEPLITZ_HITS: Cell<u64> = Cell::new(0);
}

/// A point-in-time capture of the *calling thread's* evaluation counters.
///
/// [`CounterSnapshot::take`] then [`CounterSnapshot::delta`] measures how
/// many profiled-likelihood evaluations (and Toeplitz fast-path hits)
/// this thread performed in between — immune to concurrent activity on
/// other threads, unlike deltas of the process-global [`eval_count`].
/// This is what lets the persistence/fleet suites assert **zero-eval**
/// artifact hydration while the rest of the test binary trains models in
/// parallel.
///
/// Caveat: work fanned out to [`ExecutionContext`] worker threads is
/// counted on *those* threads, so a positive-delta assertion must run the
/// evaluating code on the snapshot's thread (e.g. under a sequential
/// context). Zero-delta assertions don't care: a path that evaluates
/// nothing evaluates nothing on every thread.
#[derive(Clone, Copy, Debug)]
pub struct CounterSnapshot {
    evals: u64,
    toeplitz_hits: u64,
}

/// Counter movement since a [`CounterSnapshot`] was taken, on the taking
/// thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CounterDelta {
    /// Profiled-likelihood evaluations by this thread since the snapshot.
    pub evals: u64,
    /// Toeplitz fast-path value evaluations by this thread since the
    /// snapshot (the fast path advances both counters, so each hit also
    /// counts in `evals`).
    pub toeplitz_hits: u64,
}

impl CounterSnapshot {
    /// Capture the calling thread's current counter values.
    pub fn take() -> Self {
        Self {
            evals: LOCAL_EVALS.with(|c| c.get()),
            toeplitz_hits: LOCAL_TOEPLITZ_HITS.with(|c| c.get()),
        }
    }

    /// Counters accumulated by the calling thread since this snapshot.
    pub fn delta(&self) -> CounterDelta {
        let now = Self::take();
        CounterDelta {
            evals: now.evals - self.evals,
            toeplitz_hits: now.toeplitz_hits - self.toeplitz_hits,
        }
    }
}

/// The per-ϑ products of one profiled-hyperlikelihood evaluation.
///
/// `Clone` is an `O(n²)` factor copy — the training→serving handoff uses
/// it so a [`crate::gp::serve::Predictor`] can adopt a peak evaluation
/// without re-paying the `O(n³)` factorisation.
#[derive(Clone, Debug)]
pub struct ProfiledEval {
    /// `ln P_max(ϑ)` — eq. (2.16).
    pub lnp: f64,
    /// `σ̂_f²` — eq. (2.15).
    pub sigma_f_hat2: f64,
    /// Cholesky factor of `K̃`.
    pub chol: Chol,
    /// `α = K̃⁻¹ y`.
    pub alpha: Vec<f64>,
    /// Diagonal jitter the escalation ladder had to add before `K̃`
    /// factorised (absolute units of the covariance diagonal). `0.0` on
    /// the clean path — asserted by the robustness soak to prove the
    /// ladder costs nothing when `K̃` is healthy.
    pub jitter: f64,
}

/// Number of geometrically-spaced jittered retries after the clean
/// attempt, before the LDLᵀ-calibrated last rung.
const JITTER_RUNGS: usize = 5;
/// Relative size of the first rung's jitter: `1e-10 · tr(K̃)/n`.
const JITTER_REL0: f64 = 1e-10;
/// Geometric growth between rungs (1e-10 → 1e-2 relative over 5 rungs).
const JITTER_GROWTH: f64 = 100.0;

/// Fill `out[i] = f(i)` for `i` in `0..out.len()`, row-parallel. The
/// caller reduces `out` serially in index order, so any reduction built
/// on top matches its serial double loop bit-for-bit.
fn row_map_with<F>(out: &mut [f64], ctx: &ExecutionContext, f: F)
where
    F: Fn(usize) -> f64 + Sync,
{
    let n = out.len();
    let jobs = ctx.threads().min((n / 64).max(1));
    let bounds = even_bounds(0, n, jobs);
    for_row_chunks(out, 1, &bounds, ctx, |chunk, r0, r1| {
        for i in r0..r1 {
            chunk[i - r0] = f(i);
        }
    });
}

/// The eq.-2.17 ingredients for one derivative matrix:
/// `q = αᵀ(∂K̃)α` and `tr = Tr(W ∂K̃)`.
pub(crate) fn quad_and_trace_with(
    dk: &Matrix,
    alpha: &[f64],
    w: &Matrix,
    ctx: &ExecutionContext,
) -> (f64, f64) {
    let n = alpha.len();
    let mut vbuf = vec![0.0; n];
    row_map_with(&mut vbuf, ctx, |i| dot(dk.row(i), alpha));
    let q = dot(alpha, &vbuf);
    let mut trbuf = vec![0.0; n];
    row_map_with(&mut trbuf, ctx, |i| dot(w.row(i), dk.row(i)));
    let mut tr = 0.0;
    for v in &trbuf {
        tr += v;
    }
    (q, tr)
}

impl ProfiledEval {
    /// Evaluate from an already-assembled covariance (consumed), serial.
    ///
    /// This is the entry point used by both backends: the native path
    /// assembles `K̃` with [`super::assemble_cov`], the XLA path receives
    /// it from the AOT artifact.
    pub fn from_cov(k: Matrix, y: &[f64]) -> crate::Result<Self> {
        Self::from_cov_with(k, y, &ExecutionContext::seq())
    }

    /// Evaluate from an assembled covariance with a parallel Cholesky.
    ///
    /// This is the single factor-producing choke point of both backends,
    /// and it carries the **jitter-escalation ladder** of the numerical
    /// health tier: a clean first attempt (bit-identical to the
    /// pre-ladder arithmetic, zero extra allocation), then
    /// [`JITTER_RUNGS`] geometrically growing diagonal jitters, and as a
    /// last rung an LDLᵀ diagnosis of the unjittered matrix whose inertia
    /// and minimum pivot calibrate one final repair. The jitter that made
    /// the factorisation succeed is recorded in [`ProfiledEval::jitter`]
    /// (`0.0` on the clean path) and propagated into
    /// `TrainResult`/`TrainedModel`/reports. `k` must carry full
    /// symmetric storage (both triangles), which every assembly path
    /// produces — the retry rungs repair the clobbered lower triangle
    /// from the untouched upper one.
    pub fn from_cov_with(k: Matrix, y: &[f64], ctx: &ExecutionContext) -> crate::Result<Self> {
        EVAL_COUNT.fetch_add(1, Ordering::Relaxed);
        LOCAL_EVALS.with(|c| c.set(c.get() + 1));
        let n = y.len();
        anyhow::ensure!(k.rows() == n, "covariance/data size mismatch");
        let (chol, jitter) = factor_with_escalation(k, ctx)?;
        let alpha = chol.solve(y);
        let sigma_f_hat2 = dot(y, &alpha) / n as f64;
        anyhow::ensure!(
            sigma_f_hat2 > 0.0 && sigma_f_hat2.is_finite(),
            "degenerate σ̂_f² = {sigma_f_hat2}"
        );
        let lnp = -0.5 * (n as f64) * (LN_2PI_E + sigma_f_hat2.ln()) - 0.5 * chol.logdet();
        Ok(Self { lnp, sigma_f_hat2, chol, alpha, jitter })
    }

    /// Gradient of `ln P_max` (eq. 2.17) given the assembled `∂K̃/∂ϑ_a`,
    /// serial.
    ///
    /// `∂_a ln P_max = (1/2σ̂_f²) αᵀ(∂_aK̃)α − ½ Tr(K̃⁻¹ ∂_aK̃)`.
    ///
    /// The trace needs `W = K̃⁻¹`, which costs one extra `O(n³)` pass; pass
    /// the cached inverse in if you already have it.
    pub fn gradient(&self, grads: &[Matrix], w: &Matrix) -> Vec<f64> {
        self.gradient_with(grads, w, &ExecutionContext::seq())
    }

    /// Gradient with the per-ϑ `O(n²)` contractions row-parallel.
    pub fn gradient_with(&self, grads: &[Matrix], w: &Matrix, ctx: &ExecutionContext) -> Vec<f64> {
        let mut out = Vec::with_capacity(grads.len());
        for dk in grads {
            let (q, tr) = quad_and_trace_with(dk, &self.alpha, w, ctx);
            out.push(0.5 * q / self.sigma_f_hat2 - 0.5 * tr);
        }
        out
    }

    /// `W = K̃⁻¹` (an `O(n³)` densification of the Cholesky factor).
    pub fn inverse(&self) -> Matrix {
        self.chol.inverse()
    }

    /// `W = K̃⁻¹` with both inversion stages row-parallel.
    pub fn inverse_with(&self, ctx: &ExecutionContext) -> Matrix {
        self.chol.inverse_with(ctx)
    }
}

/// Factor `K̃ = LLᵀ` under the bounded jitter-escalation ladder.
///
/// Returns the factor and the diagonal jitter that was needed (`0.0` when
/// the clean attempt succeeds). The failed attempts cost no reassembly:
/// the blocked factorisation writes only the diagonal and strict lower
/// triangle, so each rung restores the lower triangle from the untouched
/// upper one and the saved `O(n)` diagonal, then retries in place.
pub(crate) fn factor_with_escalation(
    k: Matrix,
    ctx: &ExecutionContext,
) -> crate::Result<(Chol, f64)> {
    let n = k.rows();
    let diag: Vec<f64> = (0..n).map(|i| k[(i, i)]).collect();
    // covariance diagonals are positive; the ladder scales relative to
    // their mean so rungs are unit-free
    let scale = if n == 0 {
        f64::MIN_POSITIVE
    } else {
        (diag.iter().sum::<f64>() / n as f64).abs().max(f64::MIN_POSITIVE)
    };
    // rung 0: today's exact arithmetic — the clean path is bit-identical
    // to a ladderless build
    let mut m = match Chol::factor_owned_recoverable_with(k, ctx) {
        Ok(c) => return Ok((c, 0.0)),
        Err((m, _)) => m,
    };
    let repair = |m: &mut Matrix, jit: f64| {
        m.mirror_upper_to_lower();
        for (i, &d) in diag.iter().enumerate() {
            m[(i, i)] = d + jit;
        }
    };
    // geometric rungs
    let mut rel = JITTER_REL0;
    let mut last_err = None;
    for _ in 0..JITTER_RUNGS {
        let jit = rel * scale;
        repair(&mut m, jit);
        match Chol::factor_owned_recoverable_with(m, ctx) {
            Ok(c) => return Ok((c, jit)),
            Err((mm, e)) => {
                m = mm;
                last_err = Some(e);
            }
        }
        rel *= JITTER_GROWTH;
    }
    // last rung: LDLᵀ on the unjittered matrix is total — its inertia
    // says how indefinite K̃ really is, and its most negative pivot
    // calibrates a final spectrum-shifting repair
    repair(&mut m, 0.0);
    let ldlt = Ldlt::factor(&m);
    let inertia = ldlt.inertia();
    let min_d = ldlt.min_d();
    let jit = 2.0 * (-min_d).max(0.0) + 1e-8 * scale;
    repair(&mut m, jit);
    match Chol::factor_owned_recoverable_with(m, ctx) {
        Ok(c) => Ok((c, jit)),
        Err((_, e)) => Err(anyhow::anyhow!(
            "covariance stayed non-PD through the jitter ladder \
             (LDLᵀ inertia +{}/−{}/0:{}, min pivot {:.3e}, final jitter {:.3e}): {}",
            inertia.positive,
            inertia.negative,
            inertia.zero,
            min_d,
            jit,
            last_err.map_or_else(|| e.to_string(), |le| le.to_string())
        )),
    }
}

/// Evaluate `ln P_max` natively (assemble + factor), serial.
pub fn eval(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
) -> crate::Result<ProfiledEval> {
    eval_with(model, t, y, theta, &ExecutionContext::seq())
}

/// Evaluate `ln P_max` with parallel assembly and factorisation.
pub fn eval_with(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<ProfiledEval> {
    let k = assemble_cov_with(model, t, theta, ctx);
    ProfiledEval::from_cov_with(k, y, ctx)
}

/// Bitwise-uniform time-grid detection: returns the common step when
/// every consecutive difference `t[i+1] − t[i]` is the **same f64 bit
/// pattern** (and positive), `None` otherwise. Exact-difference equality
/// (rather than a tolerance) keeps the gate conservative: only grids the
/// generators produced by repeated addition of one step — the synthetic
/// `t = 1..n` integer grids and the tidal `t_k = k·cadence` grid — take
/// the structured route, and an off-by-an-ulp grid falls back to dense.
pub(crate) fn uniform_grid_step(t: &[f64]) -> Option<f64> {
    if t.len() < 2 {
        return None;
    }
    let dt = t[1] - t[0];
    if !(dt > 0.0) || !dt.is_finite() {
        return None;
    }
    let bits = dt.to_bits();
    if t.windows(2).all(|w| (w[1] - w[0]).to_bits() == bits) {
        Some(dt)
    } else {
        None
    }
}

/// `ln P_max` through the Levinson fast path for a uniform grid with
/// step `dt`: the Gram matrix `K̃_ij = k̃((i−j)·dt) + σ_n²δ_ij` is
/// symmetric Toeplitz, so one `O(n)` first-column assembly plus an
/// `O(n²)` Levinson recursion replaces the `O(n²)` dense assembly and
/// `O(n³)` Cholesky. Returns `None` when Levinson hits a non-PD order
/// or a degenerate σ̂_f² — the caller falls back to the dense path and
/// its jitter ladder.
fn toeplitz_lnp(model: &CovarianceModel, y: &[f64], theta: &[f64], dt: f64) -> Option<f64> {
    let n = y.len();
    let mut prep = model.kernel.prepare(theta);
    let mut r = Vec::with_capacity(n);
    r.push(prep.value(0.0) + model.noise_variance());
    for k in 1..n {
        r.push(prep.value(k as f64 * dt));
    }
    let solver = crate::linalg::ToeplitzSolver::new(&r).ok()?;
    let x = solver.solve(y);
    let sigma_f_hat2 = dot(y, &x) / n as f64;
    if !(sigma_f_hat2 > 0.0 && sigma_f_hat2.is_finite()) {
        return None;
    }
    let lnp = -0.5 * (n as f64) * (LN_2PI_E + sigma_f_hat2.ln()) - 0.5 * solver.logdet();
    if !lnp.is_finite() {
        return None;
    }
    EVAL_COUNT.fetch_add(1, Ordering::Relaxed);
    TOEPLITZ_HITS.fetch_add(1, Ordering::Relaxed);
    LOCAL_EVALS.with(|c| c.set(c.get() + 1));
    LOCAL_TOEPLITZ_HITS.with(|c| c.set(c.get() + 1));
    Some(lnp)
}

/// Value-only `ln P_max`, serial budget.
pub fn eval_value(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
) -> crate::Result<f64> {
    eval_value_with(model, t, y, theta, &ExecutionContext::seq())
}

/// Value-only `ln P_max` with the uniform-grid **Toeplitz fast path**:
/// when [`uniform_grid_step`] detects a bitwise-uniform grid, the value
/// is computed through the `O(n²)` Levinson recursion
/// ([`crate::linalg::ToeplitzSolver`]) instead of the dense
/// assembly + `O(n³)` Cholesky; anything else (off-grid inputs, a
/// Levinson non-PD failure) falls back to [`eval_with`].
///
/// This entry point deliberately does **not** replace
/// [`ProfiledEval::from_cov_with`]: a `ProfiledEval` carries the dense
/// factor and `α` that prediction/serving adopt, which the Levinson
/// recursion never materialises — and the CG training path consumes
/// only gradients ([`eval_grad_with`]), so the fast path slots into the
/// *value-only* consumers (the gradient-free optimiser, the
/// approximate-inference tier's inner solves, likelihood scans) without
/// perturbing the CG training trajectory anywhere.
pub fn eval_value_with(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<f64> {
    if let Some(dt) = uniform_grid_step(t) {
        if let Some(lnp) = toeplitz_lnp(model, y, theta, dt) {
            return Ok(lnp);
        }
    }
    eval_with(model, t, y, theta, ctx).map(|e| e.lnp)
}

/// Evaluate `ln P_max` and its gradient natively, serial.
pub fn eval_grad(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
) -> crate::Result<(ProfiledEval, Vec<f64>)> {
    eval_grad_with(model, t, y, theta, &ExecutionContext::seq())
}

/// Evaluate `ln P_max` and its gradient with every `O(n³)`/`O(n²)` stage
/// parallel: assembly, Cholesky, the explicit inverse and the per-ϑ
/// contractions.
pub fn eval_grad_with(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<(ProfiledEval, Vec<f64>)> {
    let (k, grads) = assemble_cov_grads_with(model, t, theta, ctx);
    let ev = ProfiledEval::from_cov_with(k, y, ctx)?;
    let w = ev.inverse_with(ctx);
    let g = ev.gradient_with(&grads, &w, ctx);
    Ok((ev, g))
}

/// Evaluate `ln P_max` on an n×d input block with an optional per-point
/// noise vector (heteroscedastic diagonal `K̃_ii += σ_n,i²` replacing the
/// model's scalar σ_n²). With `x.len() == 1` and no noise this **is**
/// [`eval_with`] — same call chain, bit-identical.
pub fn eval_nd_with(
    model: &CovarianceModel,
    x: &[&[f64]],
    noise: Option<&[f64]>,
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<ProfiledEval> {
    if x.len() == 1 && noise.is_none() {
        return eval_with(model, x[0], y, theta, ctx);
    }
    let k = assemble_cov_nd_with(model, x, noise, theta, ctx);
    ProfiledEval::from_cov_with(k, y, ctx)
}

/// Value-only `ln P_max` on an n×d input block with optional per-point
/// noise. The Toeplitz/Levinson fast path is only reachable through the
/// scalar delegation (`d == 1`, no noise): a heteroscedastic diagonal
/// breaks the constant-diagonal Toeplitz structure even on a uniform
/// grid, so non-constant noise *structurally* bypasses the fast path —
/// [`toeplitz_hit_count`] stays flat.
pub fn eval_value_nd_with(
    model: &CovarianceModel,
    x: &[&[f64]],
    noise: Option<&[f64]>,
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<f64> {
    if x.len() == 1 && noise.is_none() {
        return eval_value_with(model, x[0], y, theta, ctx);
    }
    eval_nd_with(model, x, noise, y, theta, ctx).map(|e| e.lnp)
}

/// Evaluate `ln P_max` and its gradient on an n×d input block with
/// optional per-point noise. The noise vector is data, not a
/// hyperparameter: `∂K̃/∂ϑ_a` is unchanged by it, so eq. (2.17) applies
/// verbatim with the heteroscedastic factor.
pub fn eval_grad_nd_with(
    model: &CovarianceModel,
    x: &[&[f64]],
    noise: Option<&[f64]>,
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<(ProfiledEval, Vec<f64>)> {
    if x.len() == 1 && noise.is_none() {
        return eval_grad_with(model, x[0], y, theta, ctx);
    }
    let (k, grads) = assemble_cov_grads_nd_with(model, x, noise, theta, ctx);
    let ev = ProfiledEval::from_cov_with(k, y, ctx)?;
    let w = ev.inverse_with(ctx);
    let g = ev.gradient_with(&grads, &w, ctx);
    Ok((ev, g))
}

/// The Hessian `H = −∂²ln P_max/∂ϑ∂ϑ'` at (or near) the peak — eq. (2.19),
/// serial.
///
/// `∂_a∂_b ln P_max = q_a q_b/(2nσ̂⁴) − (2 v_aᵀW v_b − A_ab)/(2σ̂²)
///                    + ½Tr(W∂_aK̃ W∂_bK̃) − ½B_ab`
/// with `q_a = αᵀ∂_aK̃α`, `v_a = ∂_aK̃ α`, `A_ab = αᵀ∂²K̃α`,
/// `B_ab = Tr(W ∂²K̃)`.
///
/// Cost: the `m` products `W·∂_aK̃` dominate at `O(m n³)`; evaluated once
/// at the peak (the paper: "one additional evaluation to calculate the
/// Hessian").
pub fn profiled_hessian(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
) -> crate::Result<Matrix> {
    profiled_hessian_with(model, t, y, theta, &ExecutionContext::seq())
}

/// Hessian with the dominant `W·∂_aK̃` products row-parallel and the
/// `(a,b)` trace pairs distributed over the context.
pub fn profiled_hessian_with(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<Matrix> {
    let m = model.dim();
    let n = y.len();
    let (k, grads) = assemble_cov_grads_with(model, t, theta, ctx);
    let ev = ProfiledEval::from_cov_with(k, y, ctx)?;
    let w = ev.inverse_with(ctx);
    let s2 = ev.sigma_f_hat2;

    // v_a = ∂K α, q_a = αᵀ v_a, and the W-products M_a = W ∂K
    // (the transposes let the trace pairs run on contiguous row dots)
    let mut v = Vec::with_capacity(m);
    let mut q = Vec::with_capacity(m);
    let mut wm = Vec::with_capacity(m);
    for dk in &grads {
        let va = dk.matvec(&ev.alpha);
        q.push(dot(&ev.alpha, &va));
        v.push(va);
        wm.push(w.matmul_with(dk, ctx));
    }
    let wmt: Vec<Matrix> = wm.iter().map(|ma| ma.transpose()).collect();
    let (a_c, b_c) = hessian_contractions_with(model, t, theta, &ev.alpha, &w, ctx);

    let d2 = pairwise_d2_with(n, m, &w, &wm, &wmt, &v, ctx);
    let mut h = Matrix::zeros(m, m);
    let mut idx = 0;
    for a in 0..m {
        for b in a..m {
            let (tr_ab, vwv) = d2[idx];
            idx += 1;
            let val = q[a] * q[b] / (2.0 * n as f64 * s2 * s2)
                - (2.0 * vwv - a_c[(a, b)]) / (2.0 * s2)
                + 0.5 * tr_ab
                - 0.5 * b_c[(a, b)];
            h[(a, b)] = -val;
            h[(b, a)] = -val;
        }
    }
    Ok(h)
}

/// For each Hessian pair `(a, b)` with `b ≥ a`, compute
/// `Tr(M_a M_b)` and `v_aᵀ W v_b` — `O(n²)` each — with the pairs
/// distributed over the context's threads ([`for_row_chunks`] over the
/// pair list). The trace pairs read `M_b` through its pre-transposed
/// copy `wmt[b]`, so every inner product is a contiguous row dot instead
/// of a full-stride column walk.
pub(crate) fn pairwise_d2_with(
    n: usize,
    m: usize,
    w: &Matrix,
    wm: &[Matrix],
    wmt: &[Matrix],
    v: &[Vec<f64>],
    ctx: &ExecutionContext,
) -> Vec<(f64, f64)> {
    let pairs: Vec<(usize, usize)> =
        (0..m).flat_map(|a| (a..m).map(move |b| (a, b))).collect();
    let n_pairs = pairs.len();
    let mut out = vec![(0.0, 0.0); n_pairs];
    // the m products W·v_b once up front — every pair reads them, so
    // recomputing the O(n²) matvec per pair would cost m(m+1)/2 sweeps
    let wv: Vec<Vec<f64>> = v.iter().map(|vb| w.matvec(vb)).collect();
    let jobs = ctx.threads().min(n_pairs.max(1));
    let bounds = even_bounds(0, n_pairs, jobs);
    let pairs_ref = &pairs;
    let wv_ref = &wv;
    for_row_chunks(&mut out, 1, &bounds, ctx, |chunk, p0, p1| {
        for p in p0..p1 {
            let (a, b) = pairs_ref[p];
            // Tr(M_a M_b) = Σ_i ⟨row_i(M_a), row_i(M_bᵀ)⟩
            let mut tr_ab = 0.0;
            for i in 0..n {
                tr_ab += dot(wm[a].row(i), wmt[b].row(i));
            }
            // v_aᵀ W v_b
            let vwv = dot(&v[a], &wv_ref[b]);
            chunk[p - p0] = (tr_ab, vwv);
        }
    });
    out
}

/// Eq.-2.19 Hessian on an n×d input block with optional per-point noise.
/// Σ_n is ϑ-independent, so the second-derivative contractions are those
/// of the noiseless kernel — only the factor and `W = K̃⁻¹` see the
/// heteroscedastic diagonal.
pub fn profiled_hessian_nd_with(
    model: &CovarianceModel,
    x: &[&[f64]],
    noise: Option<&[f64]>,
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<Matrix> {
    if x.len() == 1 && noise.is_none() {
        return profiled_hessian_with(model, x[0], y, theta, ctx);
    }
    let m = model.dim();
    let n = y.len();
    let (k, grads) = assemble_cov_grads_nd_with(model, x, noise, theta, ctx);
    let ev = ProfiledEval::from_cov_with(k, y, ctx)?;
    let w = ev.inverse_with(ctx);
    let s2 = ev.sigma_f_hat2;

    let mut v = Vec::with_capacity(m);
    let mut q = Vec::with_capacity(m);
    let mut wm = Vec::with_capacity(m);
    for dk in &grads {
        let va = dk.matvec(&ev.alpha);
        q.push(dot(&ev.alpha, &va));
        v.push(va);
        wm.push(w.matmul_with(dk, ctx));
    }
    let wmt: Vec<Matrix> = wm.iter().map(|ma| ma.transpose()).collect();
    let (a_c, b_c) = hessian_contractions_nd_with(model, x, theta, &ev.alpha, &w, ctx);

    let d2 = pairwise_d2_with(n, m, &w, &wm, &wmt, &v, ctx);
    let mut h = Matrix::zeros(m, m);
    let mut idx = 0;
    for a in 0..m {
        for b in a..m {
            let (tr_ab, vwv) = d2[idx];
            idx += 1;
            let val = q[a] * q[b] / (2.0 * n as f64 * s2 * s2)
                - (2.0 * vwv - a_c[(a, b)]) / (2.0 * s2)
                + 0.5 * tr_ab
                - 0.5 * b_c[(a, b)];
            h[(a, b)] = -val;
            h[(b, a)] = -val;
        }
    }
    Ok(h)
}

/// The additive constant converting `ln P_max` into the σ_f-marginalised
/// `ln P_marg` (eq. 2.18) under a **truncated** Jeffreys prior
/// `P(σ_f) = c/σ_f`, `σ_f ∈ (σ_lo, σ_hi)`, `c = 1/ln(σ_hi/σ_lo)`:
///
/// `ln[ (c/2) (2e/n)^{n/2} Γ(n/2) ]`.
///
/// The truncation bounds are part of the model-comparison prior volume;
/// they cancel in Bayes factors between models fitted to the same data.
pub fn marg_constant(n: usize, sigma_lo: f64, sigma_hi: f64) -> f64 {
    assert!(sigma_hi > sigma_lo && sigma_lo > 0.0);
    let nf = n as f64;
    let ln_c = -(sigma_hi / sigma_lo).ln().ln();
    ln_c - std::f64::consts::LN_2 + 0.5 * nf * (std::f64::consts::LN_2 + 1.0 - nf.ln())
        + lgamma(0.5 * nf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::draw_gp_dataset;
    use crate::kernels::{paper_k1, PaperK1};
    use crate::rng::Xoshiro256;

    fn small_problem() -> (crate::kernels::CovarianceModel, Vec<f64>, Vec<f64>) {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(99);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 25, &mut rng);
        (model, data.t, data.y)
    }

    /// ln P_max must equal ln P(σ̂_f) computed through the *unprofiled*
    /// eq. (2.14) — the analytic maximisation identity.
    #[test]
    fn profiled_equals_full_at_sigma_hat() {
        let (model, t, y) = small_problem();
        let theta = PaperK1::truth();
        let ev = eval(&model, &t, &y, &theta).unwrap();
        let n = y.len() as f64;
        // eq. 2.14 at σ_f² = σ̂_f²
        let quad = n; // yᵀK⁻¹y/σ̂² = n by definition of σ̂²
        let lnp_full = -0.5 * quad
            - 0.5 * ev.chol.logdet()
            - 0.5 * n * (crate::math::LN_2PI + ev.sigma_f_hat2.ln());
        assert!(
            (ev.lnp - lnp_full).abs() < 1e-10 * ev.lnp.abs(),
            "{} vs {lnp_full}",
            ev.lnp
        );
    }

    /// σ̂_f² is the true maximiser: nudging σ_f² in eq. (2.14) must lower
    /// the likelihood on both sides.
    #[test]
    fn sigma_hat_is_the_maximiser() {
        let (model, t, y) = small_problem();
        let ev = eval(&model, &t, &y, &PaperK1::truth()).unwrap();
        let n = y.len() as f64;
        let lnp_at = |s2: f64| {
            let quad = n * ev.sigma_f_hat2 / s2;
            -0.5 * quad - 0.5 * ev.chol.logdet() - 0.5 * n * (crate::math::LN_2PI + s2.ln())
        };
        let peak = lnp_at(ev.sigma_f_hat2);
        assert!((peak - ev.lnp).abs() < 1e-9 * peak.abs());
        assert!(lnp_at(ev.sigma_f_hat2 * 1.05) < peak);
        assert!(lnp_at(ev.sigma_f_hat2 * 0.95) < peak);
    }

    #[test]
    fn gradient_matches_fd() {
        let (model, t, y) = small_problem();
        let theta = PaperK1::truth();
        let (_, g) = eval_grad(&model, &t, &y, &theta).unwrap();
        for a in 0..3 {
            let h = 1e-6;
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let fp = eval(&model, &t, &y, &tp).unwrap().lnp;
            let fm = eval(&model, &t, &y, &tm).unwrap().lnp;
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                crate::math::rel_diff(g[a], fd) < 1e-5,
                "grad[{a}]: analytic {} vs FD {fd}",
                g[a]
            );
        }
    }

    #[test]
    fn parallel_eval_grad_is_bit_identical() {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(77);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 120, &mut rng);
        let theta = PaperK1::truth();
        let (ev_s, g_s) = eval_grad(&model, &data.t, &data.y, &theta).unwrap();
        for threads in [2usize, 4] {
            let ctx = ExecutionContext::new(threads);
            let (ev_p, g_p) = eval_grad_with(&model, &data.t, &data.y, &theta, &ctx).unwrap();
            assert_eq!(ev_p.lnp, ev_s.lnp, "threads={threads}");
            assert_eq!(ev_p.sigma_f_hat2, ev_s.sigma_f_hat2);
            assert_eq!(g_p, g_s, "threads={threads}");
        }
    }

    #[test]
    fn hessian_matches_fd_of_gradient() {
        let (model, t, y) = small_problem();
        let theta = PaperK1::truth();
        let hess = profiled_hessian(&model, &t, &y, &theta).unwrap();
        for a in 0..3 {
            let h = 1e-5;
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let (_, gp) = eval_grad(&model, &t, &y, &tp).unwrap();
            let (_, gm) = eval_grad(&model, &t, &y, &tm).unwrap();
            for b in 0..3 {
                let fd = -(gp[b] - gm[b]) / (2.0 * h); // H = −∂∂lnP
                assert!(
                    crate::math::rel_diff(hess[(a, b)], fd) < 1e-4,
                    "H[{a},{b}]: analytic {} vs FD {fd}",
                    hess[(a, b)]
                );
            }
        }
    }

    /// Uniform grids take the Levinson route and agree with the dense
    /// Cholesky path well inside the 1e-8 equivalence budget.
    #[test]
    fn toeplitz_fast_path_matches_dense_value() {
        let (model, t, y) = small_problem();
        let theta = PaperK1::truth();
        assert!(uniform_grid_step(&t).is_some(), "Fig.-1 grid must be uniform");
        let dense = eval(&model, &t, &y, &theta).unwrap().lnp;
        let before = toeplitz_hit_count();
        let fast = eval_value(&model, &t, &y, &theta).unwrap();
        // counter is process-global and only ever incremented, so a
        // strict increase is race-safe under parallel test execution
        assert!(toeplitz_hit_count() > before, "fast path did not engage");
        assert!(
            (fast - dense).abs() < 1e-8 * dense.abs().max(1.0),
            "{fast} vs {dense}"
        );
    }

    /// Breaking the grid by one point must fall back to the dense path
    /// bit-for-bit.
    #[test]
    fn off_grid_value_falls_back_to_dense() {
        let (model, mut t, y) = small_problem();
        t[3] += 0.25; // still ascending, no longer uniform
        assert!(uniform_grid_step(&t).is_none());
        let theta = PaperK1::truth();
        let dense = eval(&model, &t, &y, &theta).unwrap().lnp;
        let v = eval_value(&model, &t, &y, &theta).unwrap();
        assert_eq!(v, dense);
    }

    /// d = 1 with a *constant* per-point noise vector must reproduce the
    /// scalar-σ_n evaluation bitwise (diagonal entries are the same
    /// `s·s` product), and the no-noise nd call is the scalar call.
    #[test]
    fn nd_eval_d1_matches_scalar() {
        let (model, t, y) = small_problem();
        let theta = PaperK1::truth();
        let ctx = ExecutionContext::seq();
        let (ev_s, g_s) = eval_grad_with(&model, &t, &y, &theta, &ctx).unwrap();
        let (ev_n, g_n) =
            eval_grad_nd_with(&model, &[&t], None, &y, &theta, &ctx).unwrap();
        assert_eq!(ev_n.lnp, ev_s.lnp);
        assert_eq!(g_n, g_s);
        // constant noise vector == scalar σ_n on the diagonal, bitwise
        let noise = vec![model.sigma_n; y.len()];
        let (ev_c, g_c) =
            eval_grad_nd_with(&model, &[&t], Some(&noise), &y, &theta, &ctx).unwrap();
        assert_eq!(ev_c.lnp, ev_s.lnp);
        assert_eq!(ev_c.sigma_f_hat2, ev_s.sigma_f_hat2);
        assert_eq!(g_c, g_s);
        let h_s = profiled_hessian_with(&model, &t, &y, &theta, &ctx).unwrap();
        let h_c =
            profiled_hessian_nd_with(&model, &[&t], Some(&noise), &y, &theta, &ctx).unwrap();
        assert_eq!(h_c.max_abs_diff(&h_s), 0.0);
    }

    /// Regression guard for the scenario tier's Toeplitz contract: a
    /// bitwise-uniform grid with *non-constant* per-point noise must NOT
    /// engage the Levinson fast path — the heteroscedastic diagonal
    /// breaks the Toeplitz structure. Thread-local counters make the
    /// zero-hit assertion immune to parallel tests.
    #[test]
    fn toeplitz_stays_cold_under_heteroscedastic_noise() {
        let (model, t, y) = small_problem();
        let theta = PaperK1::truth();
        assert!(uniform_grid_step(&t).is_some());
        let ctx = ExecutionContext::seq();
        // sanity: without noise the same grid DOES hit the fast path
        let snap = CounterSnapshot::take();
        eval_value_nd_with(&model, &[&t], None, &y, &theta, &ctx).unwrap();
        assert_eq!(snap.delta().toeplitz_hits, 1, "no-noise path should hit");
        // non-constant noise: dense route, zero fast-path hits
        let noise: Vec<f64> = (0..y.len()).map(|i| 0.05 + 0.01 * i as f64).collect();
        let snap = CounterSnapshot::take();
        let lnp = eval_value_nd_with(&model, &[&t], Some(&noise), &y, &theta, &ctx).unwrap();
        let d = snap.delta();
        assert_eq!(d.toeplitz_hits, 0, "hetero noise must bypass Toeplitz");
        assert_eq!(d.evals, 1, "dense route still counts one evaluation");
        assert!(lnp.is_finite());
    }

    /// Heteroscedastic gradient and Hessian against finite differences on
    /// a d = 2 ARD problem — the nd analytic chain end to end.
    #[test]
    fn nd_heteroscedastic_grad_and_hessian_match_fd() {
        let kernel = crate::kernels::ArdKernel::m32(2);
        let model = CovarianceModel::new("m32-ard2", Box::new(kernel), 0.1);
        let n = 20;
        let mut rng = Xoshiro256::seed_from_u64(4242);
        let t: Vec<f64> = (0..n).map(|i| i as f64 * 0.5).collect();
        let x2: Vec<f64> = (0..n).map(|_| rng.uniform_in(-3.0, 3.0)).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let noise: Vec<f64> = (0..n).map(|_| 0.05 + 0.2 * rng.uniform()).collect();
        let x: Vec<&[f64]> = vec![&t, &x2];
        let theta = vec![0.4, -0.3];
        let ctx = ExecutionContext::seq();
        let (_, g) = eval_grad_nd_with(&model, &x, Some(&noise), &y, &theta, &ctx).unwrap();
        let hess =
            profiled_hessian_nd_with(&model, &x, Some(&noise), &y, &theta, &ctx).unwrap();
        let h = 1e-5;
        for a in 0..2 {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let fp = eval_value_nd_with(&model, &x, Some(&noise), &y, &tp, &ctx).unwrap();
            let fm = eval_value_nd_with(&model, &x, Some(&noise), &y, &tm, &ctx).unwrap();
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                crate::math::rel_diff(g[a], fd) < 1e-4,
                "grad[{a}]: analytic {} vs FD {fd}",
                g[a]
            );
            let (_, gp) = eval_grad_nd_with(&model, &x, Some(&noise), &y, &tp, &ctx).unwrap();
            let (_, gm) = eval_grad_nd_with(&model, &x, Some(&noise), &y, &tm, &ctx).unwrap();
            for b in 0..2 {
                let fd = -(gp[b] - gm[b]) / (2.0 * h);
                assert!(
                    crate::math::rel_diff(hess[(a, b)], fd) < 1e-3,
                    "H[{a},{b}]: analytic {} vs FD {fd}",
                    hess[(a, b)]
                );
            }
        }
    }

    #[test]
    fn marg_constant_small_n_exact() {
        // n = 2: ln[(c/2)(2e/2)^1 Γ(1)] = ln(c/2) + 1
        let c = 1.0 / (1e3f64 / 1e-3).ln();
        let want = (c / 2.0).ln() + 1.0;
        let got = marg_constant(2, 1e-3, 1e3);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }
}
