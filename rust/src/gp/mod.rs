//! Gaussian-process hyperlikelihood machinery — the paper's §2.
//!
//! * [`assemble`] — O(n²·m) covariance/derivative matrix assembly from a
//!   [`crate::kernels::CovarianceModel`] (the native twin of the L1
//!   Pallas kernel; the XLA backend produces the same matrices from AOT
//!   artifacts).
//! * [`profiled`] — the σ_f-profiled hyperlikelihood ln P_max (eq. 2.16),
//!   its gradient (eq. 2.17) and Hessian (eq. 2.19), plus the
//!   marginalisation constant of eq. (2.18). This is the training
//!   objective used throughout the paper.
//! * [`full`] — the un-profiled hyperlikelihood (eq. 2.5) with σ_f as an
//!   explicit coordinate `λ = ln σ_f`, gradient (eq. 2.7) and Hessian
//!   (eq. 2.9). Used by the nested-sampling baseline and the σ_f-profiling
//!   ablation.
//! * [`predict`] — the predictive distribution (eq. 2.1).
//! * [`serve`] — the streaming prediction engine: cached-factor batched
//!   serving of eq. (2.1) with `O(n²)` observation appends
//!   ([`crate::linalg::Chol::extend`]) — no per-query refactorisation.
//! * [`sample`] — GP realisation sampling (Fig. 1).
//! * [`approx`] — the approximate-inference tier (§3(b) alternatives the
//!   paper surveys): subset-of-data and FITC sparse backends whose
//!   `O(nm²)` training objectives slot into the same optimizer, evidence
//!   and serving stack as the exact `O(n³)` path.

pub mod approx;
pub mod assemble;
pub mod profiled;
pub mod full;
pub mod predict;
pub mod serve;
pub mod sample;

pub use assemble::{
    assemble_cov, assemble_cov_grads, assemble_cov_grads_nd_with, assemble_cov_grads_with,
    assemble_cov_nd_with, assemble_cov_with, hessian_contractions, hessian_contractions_nd_with,
    hessian_contractions_with, MAX_INPUT_DIM,
};
pub use full::{
    full_hessian, full_hessian_with, full_lnp, full_lnp_grad, full_lnp_grad_with, full_lnp_with,
};
pub use predict::predict;
pub use approx::ApproxKind;
pub use profiled::{
    eval_count as profiled_eval_count, marg_constant, profiled_hessian, profiled_hessian_nd_with,
    profiled_hessian_with, toeplitz_hit_count, CounterDelta, CounterSnapshot, ProfiledEval,
};
pub use sample::draw_realisation;
pub use serve::{Predictor, ServeStats};
