//! The GP predictive distribution — eq. (2.1):
//!
//! `ȳ(x*) = k*ᵀ K⁻¹ y`,  `σ_y²(x*) = k** − k*ᵀ K⁻¹ k*`.
//!
//! In σ_f-profiled form: `K = σ̂_f² K̃`, `k* = σ̂_f² k̃*`, so the mean is
//! `k̃*ᵀ K̃⁻¹ y` (σ̂_f² cancels) and the variance is
//! `σ̂_f² (k̃** − k̃*ᵀ K̃⁻¹ k̃*)`. The cross-covariance `k̃*` carries **no**
//! noise term (the prediction is of the latent function, which the paper's
//! Fig. 3 interpolants plot); `k̃** = k̃(0)`.

use crate::kernels::CovarianceModel;
use crate::linalg::{dot, Matrix};
use crate::runtime::ExecutionContext;

use super::profiled::ProfiledEval;

/// Predictive mean and standard deviation at each point of `t_star`.
pub struct Prediction {
    pub mean: Vec<f64>,
    pub sd: Vec<f64>,
}

/// Predict at new inputs from a trained evaluation (peak ϑ̂, eq. 2.6).
///
/// All query rows go through one blocked multi-RHS TRSM
/// ([`crate::linalg::Chol::half_solve_rows_with`]) — the same kernel the
/// serving layer's `predict_batch` uses, with per-row arithmetic
/// independent of the batch size, so pointwise and batched predictions
/// agree **bitwise** (asserted in `rust/tests/serving.rs`).
pub fn predict(
    model: &CovarianceModel,
    t: &[f64],
    theta: &[f64],
    ev: &ProfiledEval,
    t_star: &[f64],
) -> Prediction {
    let n = t.len();
    let q = t_star.len();
    let mut prep = model.kernel.prepare(theta);
    let k_ss = prep.value(0.0);
    let mut mean = vec![0.0; q];
    let mut sd = vec![0.0; q];
    if q == 0 {
        return Prediction { mean, sd };
    }
    // Process the queries in fixed-size row blocks: per-row arithmetic
    // is batch-size independent (the bitwise contract above), so
    // blocking changes nothing numerically while keeping the scratch at
    // O(PB·n) for arbitrarily large query grids.
    const PB: usize = 512;
    let mut r0 = 0;
    while r0 < q {
        let r1 = (r0 + PB).min(q);
        let qb = r1 - r0;
        // cross-covariance rows fused with the means K*α …
        let mut work = Matrix::zeros(qb, n);
        for r in 0..qb {
            let row = work.row_mut(r);
            let ts = t_star[r0 + r];
            for (i, &ti) in t.iter().enumerate() {
                row[i] = prep.value(ts - ti);
            }
            mean[r0 + r] = dot(row, &ev.alpha);
        }
        // … one multi-RHS TRSM w = L⁻¹k* …
        ev.chol.half_solve_rows_with(&mut work, &ExecutionContext::seq());
        // … and the variances σ̂_f²(k̃** − ‖w‖²)
        for r in 0..qb {
            let wrow = work.row(r);
            let var = ev.sigma_f_hat2 * (k_ss - dot(wrow, wrow));
            sd[r0 + r] = var.max(0.0).sqrt();
        }
        r0 = r1;
    }
    Prediction { mean, sd }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::draw_gp_dataset;
    use crate::gp::profiled::eval;
    use crate::kernels::{paper_k1, PaperK1};
    use crate::rng::Xoshiro256;

    #[test]
    fn interpolates_training_points_at_low_noise() {
        let model = paper_k1(1e-4);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 30, &mut rng);
        let ev = eval(&model, &data.t, &data.y, &PaperK1::truth()).unwrap();
        let pred = predict(&model, &data.t, &PaperK1::truth(), &ev, &data.t);
        for i in 0..data.t.len() {
            assert!(
                (pred.mean[i] - data.y[i]).abs() < 1e-3,
                "point {i}: {} vs {}",
                pred.mean[i],
                data.y[i]
            );
            // predictive sd at a training point ≈ noise level — tiny
            assert!(pred.sd[i] < 0.05);
        }
    }

    #[test]
    fn reverts_to_prior_far_from_data() {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(4);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 30, &mut rng);
        let ev = eval(&model, &data.t, &data.y, &PaperK1::truth()).unwrap();
        // T0 = e^3.5 ≈ 33; far beyond compact support the mean → 0 and the
        // sd → σ̂_f (the prior marginal sd)
        let far = vec![data.t.last().unwrap() + 500.0];
        let pred = predict(&model, &data.t, &PaperK1::truth(), &ev, &far);
        assert!(pred.mean[0].abs() < 1e-12);
        assert!((pred.sd[0] - ev.sigma_f_hat2.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn variance_shrinks_near_data() {
        let model = paper_k1(0.01);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 40, &mut rng);
        let ev = eval(&model, &data.t, &data.y, &PaperK1::truth()).unwrap();
        let near = vec![data.t[10] + 0.25];
        let far = vec![data.t.last().unwrap() + 20.0];
        let p_near = predict(&model, &data.t, &PaperK1::truth(), &ev, &near);
        let p_far = predict(&model, &data.t, &PaperK1::truth(), &ev, &far);
        assert!(p_near.sd[0] < p_far.sd[0]);
    }
}
