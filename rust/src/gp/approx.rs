//! Approximate-inference tier: subset-of-data and FITC sparse backends.
//!
//! The paper trains exact GPs and pays the `O(n³)` Cholesky per
//! evaluation (§2); its §3(b) survey points at the sparse-approximation
//! literature (Quiñonero-Candela & Rasmussen 2005; Chalupka, Williams &
//! Murray 2013) as the way past that wall. This module implements the
//! two cheapest entries of Chalupka's accuracy-vs-cost panel so they can
//! compete in the model tournament against the exact backends:
//!
//! * **Subset of data (SoD)** — run the exact profiled machinery of
//!   [`super::profiled`] on a deterministic stride subset of `m = Θ(√n)`
//!   points. Training costs `O(m³)` per evaluation; the n-scale evidence
//!   surrogate ([`lnp_evidence_with`]) scores the held-out points under
//!   the subset posterior in `O(n m²)`.
//! * **FITC** (fully independent training conditional) — `m = Θ(√n)`
//!   inducing points on a uniform grid spanning the inputs. The training
//!   covariance is `Q̃ + diag(Λ)` with `Q̃ = C̃_nm T⁻¹ C̃_mn` and
//!   `Λ_i = k̃(0) − q̃_ii + σ_n²`; the profiled likelihood, its
//!   determinant and quadratic form all go through the Woodbury /
//!   determinant-lemma forms in `O(n m²)` — never materialising an
//!   `n × n` matrix. The uniform inducing grid makes `T = C̃_mm`
//!   symmetric Toeplitz, so its solves run through the Levinson
//!   recursion ([`crate::linalg::ToeplitzSolver::solve_mat`]).
//!
//! Both backends profile σ_f out exactly as the dense path does
//! (eq. 2.15–2.16 applied to their own `K̃`): `σ̂_f² = yᵀK̃⁻¹y/n` and
//! `ln P_max = −(n/2) ln(2πe σ̂_f²) − ½ ln det K̃`.
//!
//! **Serving without new machinery.** Each backend hands the unmodified
//! [`super::serve::Predictor`] a *reduced dataset* plus a
//! [`ProfiledEval`]-shaped peak ([`peak_eval_with`] / [`serve_parts`]):
//! SoD serves the exact GP on its subset; FITC serves through an
//! effective inducing-point model `K_eff = T + T P⁻¹ T` (where
//! `P = C̃_mn Λ⁻¹ C̃_nm`), whose inverse telescopes to
//! `K_eff⁻¹ = T⁻¹ − Σ_m⁻¹` with `Σ_m = T + P`. With
//! `α_u = Σ_m⁻¹ C̃_mn Λ⁻¹ y` stored as the predictor's `α` and
//! pseudo-targets `y_u = K_eff α_u`, the predictor's standard equations
//! reproduce FITC exactly: the mean `c_*ᵀ α_u` is the FITC mean, and the
//! variance `σ̂²(k̃(0) + σ_n² − c_*ᵀ K_eff⁻¹ c_*)` expands to the FITC
//! predictive variance `σ̂²(λ_* + c_*ᵀ Σ_m⁻¹ c_*)`.
//!
//! Everything here is deterministic: the subset stride, the inducing
//! grid, and all reductions (serial loops or the bit-identical parallel
//! kernels of [`crate::linalg`]), so approx-backed tournaments keep the
//! crate's bitwise thread-count invariance.

use crate::kernels::CovarianceModel;
use crate::linalg::{dot, Chol, Matrix, ToeplitzSolver};
use crate::math::{LN_2PI, LN_2PI_E};
use crate::runtime::ExecutionContext;

use super::profiled::{eval_with, factor_with_escalation, ProfiledEval};

/// Which sparse approximation a model spec runs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApproxKind {
    /// Subset of data: the exact machinery on a deterministic stride
    /// subset of `m = sod_m(n)` points.
    Sod,
    /// Fully independent training conditional on a uniform grid of
    /// `m = fitc_m(n)` inducing points.
    Fitc,
}

impl ApproxKind {
    /// Dimension of the reduced factor this backend trains and serves
    /// with — a pure function of `n` so artifacts validate without
    /// storing it.
    pub fn factor_dim(self, n: usize) -> usize {
        match self {
            ApproxKind::Sod => sod_m(n),
            ApproxKind::Fitc => fitc_m(n),
        }
    }
}

/// SoD subset size: `⌈4√n⌉` clamped to `[min(8, n), n]`. The 4√n rule
/// keeps the subset Cholesky at `O(64 n^{3/2})` — subcubic — while
/// Chalupka's panels show SoD needs a generous subset to stay on the
/// accuracy frontier.
pub fn sod_m(n: usize) -> usize {
    let m = (4.0 * (n as f64).sqrt()).ceil() as usize;
    m.clamp(8.min(n), n)
}

/// FITC inducing-set size: `⌈2√n⌉` clamped to `[min(4, n), n]` — FITC
/// extracts more per point than SoD (every datum contributes through Λ),
/// so it runs with half the budget.
pub fn fitc_m(n: usize) -> usize {
    let m = (2.0 * (n as f64).sqrt()).ceil() as usize;
    m.clamp(4.min(n), n)
}

/// Deterministic stride subset: `i_k = ⌊k·n/m⌋` for `k = 0..m`.
/// Strictly increasing whenever `m ≤ n` (consecutive values differ by at
/// least `⌊n/m⌋ ≥ 1`), always starts at the first point.
pub fn sod_indices(n: usize, m: usize) -> Vec<usize> {
    assert!(0 < m && m <= n, "subset size {m} out of range for n = {n}");
    (0..m).map(|k| k * n / m).collect()
}

fn sod_subset(t: &[f64], y: &[f64], m: usize) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
    let idx = sod_indices(t.len(), m);
    let ts = idx.iter().map(|&i| t[i]).collect();
    let ys = idx.iter().map(|&i| y[i]).collect();
    (ts, ys, idx)
}

/// Uniform inducing grid: `m` points `u_j = t_min + j·du` spanning
/// `[t_min, t_max]`, plus the step `du`. Deterministic in the input
/// data; `du = 0` only in the degenerate single-point cases.
pub fn inducing_grid(t: &[f64], m: usize) -> (Vec<f64>, f64) {
    assert!(m > 0 && !t.is_empty());
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in t {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if m == 1 {
        return (vec![0.5 * (lo + hi)], 0.0);
    }
    let du = (hi - lo) / (m - 1) as f64;
    ((0..m).map(|j| (j as f64).mul_add(du, lo)).collect(), du)
}

/// Build the Levinson factorisation of the inducing Gram
/// `T = C̃_mm (+ τI)` under a small jitter ladder: smooth kernels make
/// uniform-grid Grams notoriously ill-conditioned, and the Levinson
/// recursion has no pivoting to hide behind. A clean attempt first, then
/// geometric rungs `τ = 10^{−10}·r₀ → 1·r₀`. Returns the solver and the
/// jitter that succeeded (`0.0` on the clean path); the jittered `τ` is
/// *part of the model* from then on — `dense()` and `logdet()` see it,
/// so the likelihood stays exactly self-consistent.
fn toeplitz_with_ladder(r: &[f64]) -> crate::Result<(ToeplitzSolver, f64)> {
    if let Ok(ts) = ToeplitzSolver::new(r) {
        return Ok((ts, 0.0));
    }
    let mut rr = r.to_vec();
    let mut rel = 1e-10;
    for _ in 0..6 {
        let tau = rel * r[0];
        rr[0] = r[0] + tau;
        if let Ok(ts) = ToeplitzSolver::new(&rr) {
            return Ok((ts, tau));
        }
        rel *= 100.0;
    }
    anyhow::bail!("inducing Gram stayed non-PD through the Toeplitz jitter ladder")
}

/// Everything one FITC likelihood evaluation produces. Sizes: `tm`/`sig`
/// are `m × m`, `p` is `m × m`, nothing is `n × n`.
struct FitcEval {
    /// n-scale profiled `ln P_max` of the FITC covariance.
    lnp: f64,
    /// `σ̂_f² = yᵀK̃_fitc⁻¹y / n`.
    sigma_f_hat2: f64,
    /// Inducing grid.
    u: Vec<f64>,
    /// Jitter on the inducing Gram diagonal (`0.0` on the clean path).
    tau: f64,
    /// Levinson factorisation of `T = C̃_mm + τI`.
    tm: ToeplitzSolver,
    /// Cholesky of `Σ_m = T + P`.
    sig: Chol,
    /// Jitter the `Σ_m` factorisation needed.
    sig_jitter: f64,
    /// `P = C̃_mn Λ⁻¹ C̃_nm`.
    p: Matrix,
    /// `α_u = Σ_m⁻¹ C̃_mn Λ⁻¹ y` — the serving weight vector.
    alpha_u: Vec<f64>,
}

/// One FITC profiled-likelihood evaluation in `O(n m²)`.
fn fitc_eval(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<FitcEval> {
    let n = y.len();
    anyhow::ensure!(n == t.len() && n > 0, "data size mismatch");
    let m = fitc_m(n);
    let (u, du) = inducing_grid(t, m);
    let mut prep = model.kernel.prepare(theta);
    // T = C̃_mm over the uniform grid, assembled from exact integer
    // multiples of the step so it is Toeplitz by construction
    let r: Vec<f64> = (0..m).map(|j| prep.value(j as f64 * du)).collect();
    let (tm, tau) = toeplitz_with_ladder(&r)?;
    // cross-covariances C̃_nm, row i = c_i = [k̃(t_i − u_j)]_j
    let mut cnm = Matrix::zeros(n, m);
    for i in 0..n {
        let row = cnm.row_mut(i);
        for (j, &uj) in u.iter().enumerate() {
            row[j] = prep.value(t[i] - uj);
        }
    }
    // q̃_ii = c_iᵀ T⁻¹ c_i through the multi-RHS Levinson solve, then the
    // FITC residual variances Λ_i = k̃(0) − q̃_ii + σ_n² (clamped at the
    // noise floor: rounding can push k̃(0) − q̃_ii a hair negative)
    let x = tm.solve_mat(&cnm);
    let k0 = prep.value(0.0);
    let s_n2 = model.noise_variance();
    let mut lam = Vec::with_capacity(n);
    let mut ln_lam = 0.0;
    for i in 0..n {
        let q_ii = dot(cnm.row(i), x.row(i));
        let li = (k0 - q_ii).max(0.0) + s_n2;
        anyhow::ensure!(
            li > 0.0 && li.is_finite(),
            "degenerate FITC residual variance Λ[{i}] = {li:e}"
        );
        ln_lam += li.ln();
        lam.push(li);
    }
    // P = C̃_mn Λ⁻¹ C̃_nm = BᵀB with B = Λ^{−1/2} C̃_nm (the matmul is the
    // crate's bit-identical parallel kernel); z = C̃_mn Λ⁻¹ y
    let mut b = cnm.clone();
    let mut yl = vec![0.0; n];
    let mut s_yy = 0.0;
    for i in 0..n {
        let s = 1.0 / lam[i].sqrt();
        for v in b.row_mut(i) {
            *v *= s;
        }
        yl[i] = y[i] / lam[i];
        s_yy += y[i] * yl[i];
    }
    let p = b.transpose().matmul_with(&b, ctx);
    let z = cnm.matvec_t(&yl);
    // Σ_m = T + P, through the shared escalation ladder
    let mut sm = p.clone();
    for i in 0..m {
        let row = sm.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v += r[(i as isize - j as isize).unsigned_abs()];
        }
        row[i] += tau;
    }
    let (sig, sig_jitter) = factor_with_escalation(sm, ctx)?;
    let alpha_u = sig.solve(&z);
    // Woodbury quadratic form and determinant lemma:
    //   yᵀK̃⁻¹y = yᵀΛ⁻¹y − zᵀΣ_m⁻¹z
    //   ln det K̃ = Σ ln Λ_i + ln det Σ_m − ln det T
    let quad = s_yy - dot(&z, &alpha_u);
    anyhow::ensure!(
        quad > 0.0 && quad.is_finite(),
        "degenerate FITC quadratic form yᵀK̃⁻¹y = {quad:e}"
    );
    let sigma_f_hat2 = quad / n as f64;
    let logdet = ln_lam + sig.logdet() - tm.logdet();
    let lnp = -0.5 * (n as f64) * (LN_2PI_E + sigma_f_hat2.ln()) - 0.5 * logdet;
    anyhow::ensure!(lnp.is_finite(), "non-finite FITC ln P_max");
    Ok(FitcEval { lnp, sigma_f_hat2, u, tau, tm, sig, sig_jitter, p, alpha_u })
}

/// The SoD peak: the exact profiled evaluation on the stride subset.
fn sod_peak(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<ProfiledEval> {
    let (ts, ys, _) = sod_subset(t, y, sod_m(t.len()));
    eval_with(model, &ts, &ys, theta, ctx)
}

/// The FITC peak as a [`ProfiledEval`] over the **effective inducing
/// model** `K_eff = T + T P⁻¹ T`, whose exact-GP predictor on the
/// inducing grid reproduces the FITC predictive equations (module docs).
/// `lnp`/`σ̂_f²` are the n-scale FITC values; `chol` is the `m × m`
/// factor of `K_eff`; `alpha` is `α_u`; `jitter` records the largest
/// diagonal repair any stage needed.
fn fitc_peak(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<ProfiledEval> {
    let fe = fitc_eval(model, t, y, theta, ctx)?;
    let m = fe.u.len();
    let (pch, p_jitter) = factor_with_escalation(fe.p, ctx)?;
    let tdense = fe.tm.dense();
    // W: row j = L_p⁻¹ t_j (T's rows are its columns), so
    // (W Wᵀ)_jk = t_jᵀ P⁻¹ t_k = (T P⁻¹ T)_jk
    let mut w = tdense.clone();
    pch.half_solve_rows_with(&mut w, ctx);
    let mut keff = Matrix::zeros(m, m);
    for j in 0..m {
        for k in j..m {
            let v = tdense[(j, k)] + dot(w.row(j), w.row(k));
            keff[(j, k)] = v;
            keff[(k, j)] = v;
        }
    }
    let (leff, keff_jitter) = factor_with_escalation(keff, ctx)?;
    let jitter = fe.tau.max(fe.sig_jitter).max(p_jitter).max(keff_jitter);
    Ok(ProfiledEval {
        lnp: fe.lnp,
        sigma_f_hat2: fe.sigma_f_hat2,
        chol: leff,
        alpha: fe.alpha_u,
        jitter,
    })
}

/// The training-objective value the optimiser maximises: SoD climbs its
/// subset-scale `ln P_max` (`O(m³)` per call), FITC its n-scale FITC
/// `ln P_max` (`O(n m²)` per call).
pub fn train_value_with(
    kind: ApproxKind,
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<f64> {
    match kind {
        ApproxKind::Sod => sod_peak(model, t, y, theta, ctx).map(|e| e.lnp),
        ApproxKind::Fitc => fitc_eval(model, t, y, theta, ctx).map(|e| e.lnp),
    }
}

/// Relative step for the central-difference training gradient
/// (first-derivative optimum `h ≈ ε^{1/3}`).
const FD_GRAD_STEP: f64 = 1e-5;
/// Relative step for the central-difference evidence Hessian
/// (second-derivative optimum `h ≈ ε^{1/4}`).
const FD_HESS_STEP: f64 = 1e-3;

/// Value and central-difference gradient of [`train_value_with`] —
/// `2·dim + 1` value evaluations. The approximate likelihoods have no
/// assembled `∂K̃` matrices to contract (their covariances exist only in
/// factored form), so the CG optimiser runs them on finite differences;
/// at `O(n m²)` per value this is still far below one exact `O(n³)`
/// gradient.
pub fn train_grad_with(
    kind: ApproxKind,
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<(f64, Vec<f64>)> {
    let f0 = train_value_with(kind, model, t, y, theta, ctx)?;
    let mut g = Vec::with_capacity(theta.len());
    for a in 0..theta.len() {
        let h = FD_GRAD_STEP * theta[a].abs().max(1.0);
        let mut tp = theta.to_vec();
        let mut tm = theta.to_vec();
        tp[a] += h;
        tm[a] -= h;
        let fp = train_value_with(kind, model, t, y, &tp, ctx)?;
        let fm = train_value_with(kind, model, t, y, &tm, ctx)?;
        g.push((fp - fm) / (2.0 * h));
    }
    Ok((f0, g))
}

/// The reduced peak evaluation that trains, persists and serves: the
/// subset [`ProfiledEval`] for SoD, the `K_eff` evaluation for FITC.
/// Its `chol.dim()` equals [`ApproxKind::factor_dim`] of `n`.
pub fn peak_eval_with(
    kind: ApproxKind,
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<ProfiledEval> {
    match kind {
        ApproxKind::Sod => sod_peak(model, t, y, theta, ctx),
        ApproxKind::Fitc => fitc_peak(model, t, y, theta, ctx),
    }
}

/// The n-scale log-likelihood surrogate that enters the Laplace
/// evidence, so approximate entrants compete with exact ones on the
/// same `ln Z` scale. FITC's training objective already is an n-point
/// likelihood; SoD's subset value is m-scale, so it is completed with
/// the predictive log-density of every held-out point under the subset
/// posterior (`O(n m²)`) — the standard SoD evidence surrogate.
pub fn lnp_evidence_with(
    kind: ApproxKind,
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<f64> {
    match kind {
        ApproxKind::Fitc => fitc_eval(model, t, y, theta, ctx).map(|e| e.lnp),
        ApproxKind::Sod => {
            let n = t.len();
            let m = sod_m(n);
            let (ts, ys, idx) = sod_subset(t, y, m);
            let ev = eval_with(model, &ts, &ys, theta, ctx)?;
            let mut prep = model.kernel.prepare(theta);
            let k0 = prep.value(0.0);
            let s_n2 = model.noise_variance();
            let s2 = ev.sigma_f_hat2;
            let mut in_subset = vec![false; n];
            for &i in &idx {
                in_subset[i] = true;
            }
            let mut lnp = ev.lnp;
            let mut c = vec![0.0; m];
            for i in 0..n {
                if in_subset[i] {
                    continue;
                }
                for (j, &tj) in ts.iter().enumerate() {
                    c[j] = prep.value(t[i] - tj);
                }
                let w = ev.chol.half_solve(&c);
                let mean = dot(&c, &ev.alpha);
                let var = (s2 * (k0 + s_n2 - dot(&w, &w))).max(1e-300);
                let d = y[i] - mean;
                lnp += -0.5 * (d * d / var + var.ln() + LN_2PI);
            }
            Ok(lnp)
        }
    }
}

/// Central-difference Hessian `H = −∂² ln P/∂ϑ∂ϑ'` of
/// [`lnp_evidence_with`] at the peak — the approximate tier's
/// counterpart of [`super::profiled::profiled_hessian_with`], feeding
/// [`crate::evidence::laplace_evidence`] (which tolerates an indefinite
/// FD Hessian by flagging the evidence suspect rather than failing).
/// `2d² + 1` value evaluations for `d` hyperparameters.
pub fn evidence_hessian_with(
    kind: ApproxKind,
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<Matrix> {
    let d = theta.len();
    let f = |th: &[f64]| lnp_evidence_with(kind, model, t, y, th, ctx);
    let f0 = f(theta)?;
    let h: Vec<f64> = theta.iter().map(|&v| FD_HESS_STEP * v.abs().max(1.0)).collect();
    let mut hess = Matrix::zeros(d, d);
    for a in 0..d {
        let mut tp = theta.to_vec();
        let mut tm = theta.to_vec();
        tp[a] += h[a];
        tm[a] -= h[a];
        hess[(a, a)] = -((f(&tp)? - 2.0 * f0 + f(&tm)?) / (h[a] * h[a]));
    }
    for a in 0..d {
        for b in (a + 1)..d {
            let mut tpp = theta.to_vec();
            let mut tpm = theta.to_vec();
            let mut tmp = theta.to_vec();
            let mut tmm = theta.to_vec();
            tpp[a] += h[a];
            tpp[b] += h[b];
            tpm[a] += h[a];
            tpm[b] -= h[b];
            tmp[a] -= h[a];
            tmp[b] += h[b];
            tmm[a] -= h[a];
            tmm[b] -= h[b];
            let v = -((f(&tpp)? - f(&tpm)? - f(&tmp)? + f(&tmm)?) / (4.0 * h[a] * h[b]));
            hess[(a, b)] = v;
            hess[(b, a)] = v;
        }
    }
    Ok(hess)
}

/// The reduced dataset a [`super::serve::Predictor`] pairs with
/// [`peak_eval_with`]'s evaluation: the stride subset for SoD; the
/// inducing grid with pseudo-targets `y_u = K_eff α_u = L(Lᵀα)` for
/// FITC. Both are pure functions of the full data and the stored
/// evaluation, so a save → load → serve round trip reconstructs them
/// bit-identically.
pub fn serve_parts(
    kind: ApproxKind,
    t: &[f64],
    y: &[f64],
    ev: &ProfiledEval,
) -> (Vec<f64>, Vec<f64>) {
    let m = ev.chol.dim();
    match kind {
        ApproxKind::Sod => {
            let (ts, ys, _) = sod_subset(t, y, m);
            (ts, ys)
        }
        ApproxKind::Fitc => {
            let (u, _) = inducing_grid(t, m);
            let l = ev.chol.factor_matrix();
            let y_pseudo = l.matvec(&l.matvec_t(&ev.alpha));
            (u, y_pseudo)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::draw_gp_dataset;
    use crate::kernels::{paper_k1, PaperK1};
    use crate::rng::Xoshiro256;

    fn problem(n: usize) -> (CovarianceModel, Vec<f64>, Vec<f64>) {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(2024);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), n, &mut rng);
        (model, data.t, data.y)
    }

    #[test]
    fn stride_indices_are_strictly_increasing_and_start_at_zero() {
        for &(n, m) in &[(10usize, 3usize), (25, 20), (1968, 178), (7, 7)] {
            let idx = sod_indices(n, m);
            assert_eq!(idx.len(), m);
            assert_eq!(idx[0], 0);
            for w in idx.windows(2) {
                assert!(w[0] < w[1], "n={n} m={m}: {idx:?}");
            }
            assert!(*idx.last().unwrap() < n);
        }
    }

    #[test]
    fn size_rules_clamp_to_n() {
        assert_eq!(sod_m(4), 4);
        assert_eq!(fitc_m(3), 3);
        assert!(sod_m(10_000) <= 10_000);
        // subcubic regime: Θ(√n) budgets
        assert_eq!(sod_m(10_000), 400);
        assert_eq!(fitc_m(10_000), 200);
    }

    /// With `m = n` the stride subset is the identity, so the SoD
    /// training value IS the exact profiled likelihood — bitwise.
    #[test]
    fn sod_with_full_subset_is_exact() {
        let (model, t, y) = problem(16);
        assert_eq!(sod_m(16), 16);
        let theta = PaperK1::truth();
        let ctx = ExecutionContext::seq();
        let v = train_value_with(ApproxKind::Sod, &model, &t, &y, &theta, &ctx).unwrap();
        let exact = eval_with(&model, &t, &y, &theta, &ctx).unwrap().lnp;
        assert_eq!(v, exact);
        // ... and with no held-out points the evidence surrogate is the
        // same number
        let e = lnp_evidence_with(ApproxKind::Sod, &model, &t, &y, &theta, &ctx).unwrap();
        assert_eq!(e, exact);
    }

    /// With `m = n` on a uniform grid the inducing points coincide with
    /// the data bitwise (`1 + j·1.0`), `Q̃` telescopes to the exact Gram
    /// and `Λ` to the noise floor, so FITC must agree with the dense
    /// likelihood to rounding.
    #[test]
    fn fitc_with_inducing_grid_on_the_data_is_exact() {
        let (model, t, y) = problem(5);
        assert_eq!(fitc_m(5), 5);
        let theta = PaperK1::truth();
        let ctx = ExecutionContext::seq();
        let v = train_value_with(ApproxKind::Fitc, &model, &t, &y, &theta, &ctx).unwrap();
        let exact = eval_with(&model, &t, &y, &theta, &ctx).unwrap().lnp;
        assert!(
            (v - exact).abs() < 1e-6 * exact.abs().max(1.0),
            "fitc {v} vs exact {exact}"
        );
    }

    /// The FD training gradient must match the analytic gradient where
    /// the two objectives coincide (SoD at full subset).
    #[test]
    fn fd_gradient_matches_analytic_on_full_subset() {
        let (model, t, y) = problem(16);
        let theta = PaperK1::truth();
        let ctx = ExecutionContext::seq();
        let (v, g) = train_grad_with(ApproxKind::Sod, &model, &t, &y, &theta, &ctx).unwrap();
        let (ev, ga) = super::super::profiled::eval_grad_with(&model, &t, &y, &theta, &ctx).unwrap();
        assert_eq!(v, ev.lnp);
        for a in 0..theta.len() {
            assert!(
                (g[a] - ga[a]).abs() < 1e-4 * ga[a].abs().max(1.0),
                "grad[{a}]: fd {} vs analytic {}",
                g[a],
                ga[a]
            );
        }
    }

    /// The pseudo-targets are defined as `y_u = K_eff α_u`, so solving
    /// them back through the stored factor must recover `α_u` — the
    /// invariant that makes `Predictor::from_eval` adopt the FITC peak
    /// without recomputing anything.
    #[test]
    fn fitc_pseudo_targets_are_consistent_with_alpha() {
        let (model, t, y) = problem(60);
        let theta = PaperK1::truth();
        let ctx = ExecutionContext::seq();
        let ev = peak_eval_with(ApproxKind::Fitc, &model, &t, &y, &theta, &ctx).unwrap();
        assert_eq!(ev.chol.dim(), fitc_m(60));
        let (u, y_pseudo) = serve_parts(ApproxKind::Fitc, &t, &y, &ev);
        assert_eq!(u.len(), y_pseudo.len());
        let back = ev.chol.solve(&y_pseudo);
        for (a, b) in back.iter().zip(&ev.alpha) {
            assert!((a - b).abs() < 1e-8 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    /// Approx peaks must be deterministic across thread counts like
    /// everything else in the crate.
    #[test]
    fn approx_values_are_bit_identical_across_thread_counts() {
        let (model, t, y) = problem(80);
        let theta = PaperK1::truth();
        let seq = ExecutionContext::seq();
        for kind in [ApproxKind::Sod, ApproxKind::Fitc] {
            let v1 = train_value_with(kind, &model, &t, &y, &theta, &seq).unwrap();
            let e1 = lnp_evidence_with(kind, &model, &t, &y, &theta, &seq).unwrap();
            for threads in [2usize, 4] {
                let ctx = ExecutionContext::new(threads);
                let v = train_value_with(kind, &model, &t, &y, &theta, &ctx).unwrap();
                let e = lnp_evidence_with(kind, &model, &t, &y, &theta, &ctx).unwrap();
                assert_eq!(v, v1, "{kind:?} value, threads={threads}");
                assert_eq!(e, e1, "{kind:?} evidence, threads={threads}");
            }
        }
    }
}
