//! The streaming prediction engine — cached-factor batch serving.
//!
//! Training (the rest of this crate) pays `O(n³)` once to locate ϑ̂; the
//! naive serving story then re-assembles and re-factorises `K̃` on *every*
//! predict call. At the ROADMAP's traffic target that is the bottleneck:
//! the factor never changes between queries. [`Predictor`] therefore owns
//! the trained state — hyperparameters ϑ̂, the Cholesky factor `L`, the
//! weight vector `α = K̃⁻¹y` and `σ̂_f²` — and answers **batched**
//! mean/variance queries (eq. 2.1) without ever re-factorising:
//!
//! 1. one row-parallel assembly of the cross-covariance block `K*`
//!    (`q×n`, one row per query) fused with the means `K* α`;
//! 2. one multi-RHS TRSM `W = L⁻¹ K*ᵀ` ([`Chol::half_solve_rows_with`]);
//! 3. the variances `σ̂_f² (k̃** − ‖w‖²)`, row-parallel.
//!
//! Total: `O(q n²)` for a `q`-point batch instead of `O(n³ + q n²)`.
//!
//! New observations stream in through [`Predictor::observe`] /
//! [`Predictor::observe_batch`]: the factor is *extended* in `O(n²)` via
//! the bordered factorisation ([`Chol::extend`]) and `α`, `σ̂_f²` are
//! refreshed with two triangular solves — no `O(n³)` refactorisation.
//! After any number of appends the served predictions match a
//! from-scratch refit at the same ϑ̂ to better than 1e-8 (asserted in
//! `rust/tests/serving.rs` and `examples/streaming_tidal.rs`).
//!
//! Results are bit-identical to [`super::predict::predict`] for any
//! batch size and thread count: both paths share the blocked multi-RHS
//! TRSM ([`Chol::half_solve_rows_with`]), whose per-row arithmetic is
//! fixed by the `linalg::micro` block grids alone — independent of how
//! the rows are batched or partitioned across workers.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::kernels::CovarianceModel;
use crate::linalg::{dot, Chol, Matrix};
use crate::math::LN_2PI_E;
use crate::runtime::exec::{
    even_bounds, for_row_chunks, for_row_chunks_multi, ExecutionContext, PAR_MIN_WORK,
};

use super::assemble::{assemble_cov_nd_with, assemble_cov_with, MAX_INPUT_DIM};
use super::predict::Prediction;
use super::profiled::ProfiledEval;

/// Serving counters (monotonic over the predictor's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Current training-set size `n` behind the cached factor.
    pub n_train: usize,
    /// Query points served across all batches.
    pub queries_served: usize,
    /// Observations appended via the `O(n²)` factor extension.
    pub observations_appended: usize,
    /// Observations deleted via the `O(n²)` factor shrink
    /// ([`Predictor::evict`] / [`Predictor::evict_front`]).
    pub observations_evicted: usize,
}

/// One scored candidate observation: the drift log-score, the
/// bordered-factorisation pivot, and (privately) the triangular solve
/// `w = L⁻¹k*` that [`Predictor::observe_scored`] reuses. Produced by
/// [`Predictor::score_observation`]; only valid against the factor state
/// it was scored on (absorption checks the dimension).
#[derive(Clone, Debug)]
pub struct ScoredObservation {
    /// Log predictive density `ln N(y | μ, σ² + σ̂_f²σ_n²)`.
    pub score: f64,
    /// Schur-complement pivot `d` of the would-be extension (`≤ 0` ⇒
    /// the append would make `K̃` non-PD).
    pub pivot: f64,
    w: Vec<f64>,
}

/// A trained GP wired for serving: cached factor, cached `α`, batched
/// queries, `O(n²)` streaming appends. See the module docs.
pub struct Predictor {
    model: CovarianceModel,
    theta: Vec<f64>,
    t: Vec<f64>,
    /// Input columns 1..d (empty for classic 1-D sessions — every scalar
    /// method requires this empty, keeping the pre-scenario paths
    /// untouched).
    extra: Vec<Vec<f64>>,
    /// Per-point noise σ_n,i behind the factor's diagonal (`None` ⇒ the
    /// model's scalar σ_n everywhere).
    noise: Option<Vec<f64>>,
    y: Vec<f64>,
    chol: Chol,
    alpha: Vec<f64>,
    sigma_f_hat2: f64,
    /// Jitter the escalation ladder applied when the cached factor was
    /// produced (`0.0` for a clean factorisation; updated on every
    /// refit/adopt).
    jitter: f64,
    queries: AtomicUsize,
    observations: AtomicUsize,
    evictions: AtomicUsize,
}

impl Predictor {
    /// Assemble and factor once, then serve from the cache. Use
    /// [`Predictor::from_eval`] when training already produced the
    /// factorisation (no extra `O(n³)` work).
    pub fn fit(
        model: CovarianceModel,
        t: &[f64],
        y: &[f64],
        theta: &[f64],
        ctx: &ExecutionContext,
    ) -> crate::Result<Self> {
        let k = assemble_cov_with(&model, t, theta, ctx);
        let ev = ProfiledEval::from_cov_with(k, y, ctx)?;
        Ok(Self::from_eval(model, t.to_vec(), y.to_vec(), theta.to_vec(), ev))
    }

    /// [`Predictor::fit`] on an n×d input block with optional per-point
    /// noise — assemble through the nd path (which delegates bitwise to
    /// the scalar assembly when `x.len() == 1` and no noise), factor
    /// once, serve from the cache.
    pub fn fit_nd(
        model: CovarianceModel,
        x: &[&[f64]],
        noise: Option<&[f64]>,
        y: &[f64],
        theta: &[f64],
        ctx: &ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!x.is_empty(), "need at least one input column");
        let k = assemble_cov_nd_with(&model, x, noise, theta, ctx);
        let ev = ProfiledEval::from_cov_with(k, y, ctx)?;
        let extra: Vec<Vec<f64>> = x[1..].iter().map(|c| c.to_vec()).collect();
        Ok(Self::from_eval_nd(
            model,
            x[0].to_vec(),
            extra,
            noise.map(|s| s.to_vec()),
            y.to_vec(),
            theta.to_vec(),
            ev,
        ))
    }

    /// Adopt a training-time evaluation (peak ϑ̂, eq. 2.6) without
    /// refactorising: the [`ProfiledEval`]'s factor and `α` *are* the
    /// serving cache.
    pub fn from_eval(
        model: CovarianceModel,
        t: Vec<f64>,
        y: Vec<f64>,
        theta: Vec<f64>,
        ev: ProfiledEval,
    ) -> Self {
        assert_eq!(t.len(), y.len(), "t/y length mismatch");
        assert_eq!(ev.chol.dim(), t.len(), "factor/data size mismatch");
        assert_eq!(theta.len(), model.dim(), "theta/model dim mismatch");
        Self {
            model,
            theta,
            t,
            extra: Vec::new(),
            noise: None,
            y,
            chol: ev.chol,
            alpha: ev.alpha,
            sigma_f_hat2: ev.sigma_f_hat2,
            jitter: ev.jitter,
            queries: AtomicUsize::new(0),
            observations: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    /// [`Predictor::from_eval`] for an n×d input block with an optional
    /// per-point noise vector — the evaluation must have been produced by
    /// the nd likelihood ([`super::profiled::eval_nd_with`]) on exactly
    /// these inputs. With `extra` empty and no noise this is
    /// [`Predictor::from_eval`].
    pub fn from_eval_nd(
        model: CovarianceModel,
        t: Vec<f64>,
        extra: Vec<Vec<f64>>,
        noise: Option<Vec<f64>>,
        y: Vec<f64>,
        theta: Vec<f64>,
        ev: ProfiledEval,
    ) -> Self {
        assert!(1 + extra.len() <= MAX_INPUT_DIM, "input dim {} > max", 1 + extra.len());
        for col in &extra {
            assert_eq!(col.len(), t.len(), "input column length mismatch");
        }
        if let Some(s) = &noise {
            assert_eq!(s.len(), t.len(), "noise length mismatch");
        }
        let mut p = Self::from_eval(model, t, y, theta, ev);
        p.extra = extra;
        p.noise = noise;
        p
    }

    /// Attach nd state (input columns 1..d, per-point noise) to a
    /// predictor hydrated through a scalar-shaped path — the artifact
    /// readers use this, since the factor itself is layout-agnostic.
    pub fn attach_input_block(&mut self, extra: Vec<Vec<f64>>, noise: Option<Vec<f64>>) {
        assert!(1 + extra.len() <= MAX_INPUT_DIM, "input dim {} > max", 1 + extra.len());
        for col in &extra {
            assert_eq!(col.len(), self.t.len(), "input column length mismatch");
        }
        if let Some(s) = &noise {
            assert_eq!(s.len(), self.t.len(), "noise length mismatch");
        }
        self.extra = extra;
        self.noise = noise;
    }

    /// Adopt a predictor straight from **borrowed artifact-view parts**
    /// — the zero-copy hydration path of the v4 format
    /// ([`crate::coordinator::artifact_v4`]). Every numeric block is
    /// copied exactly once, from the (possibly memory-mapped) view into
    /// this predictor's own storage: the packed lower triangle scatters
    /// directly into the dense factor via
    /// [`Chol::from_packed_lower`], with **no intermediate `Vec`s** (the
    /// v3 reader allocates one per factor row). Serves the same bits as
    /// [`Predictor::from_eval`] on equal inputs.
    #[allow(clippy::too_many_arguments)]
    pub fn from_view_parts(
        model: CovarianceModel,
        t: &[f64],
        y: &[f64],
        theta: &[f64],
        packed_l: &[f64],
        logdet: f64,
        alpha: &[f64],
        sigma_f_hat2: f64,
        jitter: f64,
    ) -> Self {
        let n = t.len();
        assert_eq!(n, y.len(), "t/y length mismatch");
        assert_eq!(packed_l.len(), n * (n + 1) / 2, "factor/data size mismatch");
        assert_eq!(alpha.len(), n, "alpha/data size mismatch");
        assert_eq!(theta.len(), model.dim(), "theta/model dim mismatch");
        Self {
            model,
            theta: theta.to_vec(),
            t: t.to_vec(),
            extra: Vec::new(),
            noise: None,
            y: y.to_vec(),
            chol: Chol::from_packed_lower(packed_l, n, logdet),
            alpha: alpha.to_vec(),
            sigma_f_hat2,
            jitter,
            queries: AtomicUsize::new(0),
            observations: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    /// Current training-set size behind the factor.
    pub fn n(&self) -> usize {
        self.t.len()
    }

    /// The hyperparameters the predictor serves with.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// The input (time) points currently behind the factor, in
    /// absorption order — training data first, streamed appends after,
    /// minus anything evicted. The serving window a retrain trains on.
    pub fn t(&self) -> &[f64] {
        &self.t
    }

    /// The output values paired with [`Predictor::t`].
    pub fn y(&self) -> &[f64] {
        &self.y
    }

    /// Number of input dimensions d (≥ 1).
    pub fn d(&self) -> usize {
        1 + self.extra.len()
    }

    /// Input columns 1..d behind the factor (empty for 1-D sessions).
    pub fn extra(&self) -> &[Vec<f64>] {
        &self.extra
    }

    /// Per-point noise σ_n,i behind the factor (`None` ⇒ homoscedastic).
    pub fn noise(&self) -> Option<&[f64]> {
        self.noise.as_deref()
    }

    /// All d input columns, `t` first — the layout the nd likelihood
    /// entry points consume.
    pub fn input_cols(&self) -> Vec<&[f64]> {
        let mut cols: Vec<&[f64]> = Vec::with_capacity(self.d());
        cols.push(&self.t);
        for c in &self.extra {
            cols.push(c);
        }
        cols
    }

    /// The covariance model the predictor serves with.
    pub fn model(&self) -> &CovarianceModel {
        &self.model
    }

    /// The live cached factor (for soak tests and persistence — callers
    /// must not rely on the garbage upper triangle).
    /// Jitter applied when the cached factor was produced (`0.0` on the
    /// clean path) — the per-slot factor-health report reads this.
    pub fn jitter(&self) -> f64 {
        self.jitter
    }

    pub fn chol(&self) -> &Chol {
        &self.chol
    }

    /// The maintained weight vector `α = K̃⁻¹y` at the current data —
    /// alongside [`Predictor::chol`] this is everything a live session
    /// needs to re-serialise itself as a fresh artifact (fleet eviction).
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// `σ̂_f²` at the current data (refreshed on every observe).
    pub fn sigma_f_hat2(&self) -> f64 {
        self.sigma_f_hat2
    }

    /// `ln P_max(ϑ̂)` at the current data (eq. 2.16), recomputed from the
    /// maintained log-determinant — `O(1)`.
    pub fn lnp(&self) -> f64 {
        let n = self.t.len() as f64;
        -0.5 * n * (LN_2PI_E + self.sigma_f_hat2.ln()) - 0.5 * self.chol.logdet()
    }

    /// Serving counters.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            n_train: self.t.len(),
            queries_served: self.queries.load(Ordering::Relaxed),
            observations_appended: self.observations.load(Ordering::Relaxed),
            observations_evicted: self.evictions.load(Ordering::Relaxed),
        }
    }

    /// Carry another predictor's lifetime counters over (the
    /// retrain-in-place hot swap replaces the predictor object but the
    /// serving session — and its monotonic stats — lives on).
    pub(crate) fn carry_counters_from(&self, old: &Predictor) {
        self.queries.store(old.queries.load(Ordering::Relaxed), Ordering::Relaxed);
        self.observations.store(old.observations.load(Ordering::Relaxed), Ordering::Relaxed);
        self.evictions.store(old.evictions.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Serve one batch of query points: predictive mean and sd at each
    /// element of `t_star`, through the cached factor (see module docs;
    /// never refactorises).
    pub fn predict_batch(&self, t_star: &[f64], ctx: &ExecutionContext) -> Prediction {
        assert!(
            self.extra.is_empty(),
            "scalar predict_batch on a {}-dim predictor — use predict_rows",
            self.d()
        );
        let q = t_star.len();
        let n = self.t.len();
        let mut mean = vec![0.0; q];
        let mut sd = vec![0.0; q];
        if q == 0 {
            return Prediction { mean, sd };
        }
        self.queries.fetch_add(q, Ordering::Relaxed);
        let jobs = if q * n < PAR_MIN_WORK { 1 } else { ctx.threads().min(q) };
        let bounds = even_bounds(0, q, jobs);
        // 1. cross-covariance rows fused with the means K*α (the work
        // matrix and the mean vector chunk along the same row bounds)
        let mut work = Matrix::zeros(q, n);
        {
            let (model, theta, t, alpha) = (&self.model, &self.theta, &self.t, &self.alpha);
            for_row_chunks_multi(
                vec![(work.as_mut_slice(), n), (&mut mean[..], 1)],
                &bounds,
                ctx,
                |chunks, r0, r1| {
                    let mut it = chunks.into_iter();
                    let wchunk = it.next().expect("cross-covariance chunk");
                    let mchunk = it.next().expect("mean chunk");
                    let mut prep = model.kernel.prepare(theta);
                    for r in r0..r1 {
                        let row = &mut wchunk[(r - r0) * n..(r - r0 + 1) * n];
                        let ts = t_star[r];
                        for (i, &ti) in t.iter().enumerate() {
                            row[i] = prep.value(ts - ti);
                        }
                        mchunk[r - r0] = dot(row, alpha);
                    }
                },
            );
        }
        // 2. one multi-RHS TRSM: every row w = L⁻¹ k*
        self.chol.half_solve_rows_with(&mut work, ctx);
        // 3. variances σ̂_f² (k̃** − wᵀw), row-parallel
        let k_ss = self.model.kernel.prepare(&self.theta).value(0.0);
        let s2 = self.sigma_f_hat2;
        let work_ref = &work;
        for_row_chunks(&mut sd, 1, &bounds, ctx, |chunk, r0, r1| {
            for r in r0..r1 {
                let w = work_ref.row(r);
                let var = s2 * (k_ss - dot(w, w));
                chunk[r - r0] = var.max(0.0).sqrt();
            }
        });
        Prediction { mean, sd }
    }

    /// Serve one batch of d-dimensional query points (`x_star` is d
    /// columns, each of length q — the same column layout as
    /// [`Predictor::input_cols`]): eq. (2.1) through the cached factor,
    /// never refactorising. For a 1-D predictor this delegates to
    /// [`Predictor::predict_batch`] (bit-identical; the per-point noise
    /// never enters the latent predictive variance).
    pub fn predict_rows(&self, x_star: &[&[f64]], ctx: &ExecutionContext) -> Prediction {
        assert_eq!(x_star.len(), self.d(), "query dim {} vs predictor d {}", x_star.len(), self.d());
        if self.extra.is_empty() {
            return self.predict_batch(x_star[0], ctx);
        }
        let q = x_star[0].len();
        for c in x_star {
            assert_eq!(c.len(), q, "ragged query columns");
        }
        let n = self.t.len();
        let d = self.d();
        let mut mean = vec![0.0; q];
        let mut sd = vec![0.0; q];
        if q == 0 {
            return Prediction { mean, sd };
        }
        self.queries.fetch_add(q, Ordering::Relaxed);
        let jobs = if q * n < PAR_MIN_WORK { 1 } else { ctx.threads().min(q) };
        let bounds = even_bounds(0, q, jobs);
        let mut work = Matrix::zeros(q, n);
        {
            let (model, theta, alpha) = (&self.model, &self.theta, &self.alpha);
            let cols = self.input_cols();
            let cols_ref = &cols;
            for_row_chunks_multi(
                vec![(work.as_mut_slice(), n), (&mut mean[..], 1)],
                &bounds,
                ctx,
                |chunks, r0, r1| {
                    let mut it = chunks.into_iter();
                    let wchunk = it.next().expect("cross-covariance chunk");
                    let mchunk = it.next().expect("mean chunk");
                    let mut prep = model.kernel.prepare(theta);
                    let mut dx = [0.0f64; MAX_INPUT_DIM];
                    for r in r0..r1 {
                        let row = &mut wchunk[(r - r0) * n..(r - r0 + 1) * n];
                        for i in 0..n {
                            for (j, col) in cols_ref.iter().enumerate() {
                                dx[j] = x_star[j][r] - col[i];
                            }
                            row[i] = prep.value_nd(&dx[..d]);
                        }
                        mchunk[r - r0] = dot(row, alpha);
                    }
                },
            );
        }
        self.chol.half_solve_rows_with(&mut work, ctx);
        let zero = [0.0f64; MAX_INPUT_DIM];
        let k_ss = self.model.kernel.prepare(&self.theta).value_nd(&zero[..d]);
        let s2 = self.sigma_f_hat2;
        let work_ref = &work;
        for_row_chunks(&mut sd, 1, &bounds, ctx, |chunk, r0, r1| {
            for r in r0..r1 {
                let w = work_ref.row(r);
                let var = s2 * (k_ss - dot(w, w));
                chunk[r - r0] = var.max(0.0).sqrt();
            }
        });
        Prediction { mean, sd }
    }

    /// Log predictive density of a single would-be observation under the
    /// **current** state: `ln N(y | μ(t), σ²(t) + σ̂_f²·σ_n²)` — the
    /// latent predictive variance plus the model's (scaled) noise floor.
    /// `O(n²)` (one triangular solve); does not mutate the cache and does
    /// not count as a served query. This is the per-appended-point
    /// log-score the serving router's drift monitor tracks.
    pub fn log_predictive(&self, t_new: f64, y_new: f64) -> f64 {
        self.log_predictive_and_pivot(t_new, y_new).0
    }

    /// [`Predictor::log_predictive`] plus the bordered-factorisation
    /// pivot the matching [`Predictor::observe`] would take:
    /// `d = k̃(0) + σ_n² − wᵀw` with `w = L⁻¹k*` — computed with exactly
    /// the arithmetic of [`Chol::extend`], so `d > 0` (and finite) iff
    /// the factor extension at `t_new` will succeed. The multi-model
    /// router checks every model's pivot **before** mutating any factor,
    /// making a fan-out append all-or-nothing.
    pub fn log_predictive_and_pivot(&self, t_new: f64, y_new: f64) -> (f64, f64) {
        let s = self.score_observation(t_new, y_new);
        (s.score, s.pivot)
    }

    /// Score a candidate observation and keep the triangular solve for
    /// reuse: the returned [`ScoredObservation`] carries the drift
    /// log-score, the extension pivot, and `w = L⁻¹k*` — so a
    /// [`Predictor::observe_scored`] absorption right after pays **one**
    /// `O(n²)` solve per point instead of two (score, then extend).
    pub fn score_observation(&self, t_new: f64, y_new: f64) -> ScoredObservation {
        assert!(
            self.extra.is_empty() && self.noise.is_none(),
            "scalar score_observation on an nd/heteroscedastic predictor — \
             use score_observation_row"
        );
        let mut prep = self.model.kernel.prepare(&self.theta);
        let k: Vec<f64> = self.t.iter().map(|&ti| prep.value(ti - t_new)).collect();
        let mean = dot(&k, &self.alpha);
        let w = self.chol.half_solve(&k);
        let d = prep.value(0.0) + self.model.noise_variance() - dot(&w, &w);
        let var = (self.sigma_f_hat2 * d).max(1e-300);
        let score =
            -0.5 * ((y_new - mean) * (y_new - mean) / var + var.ln() + crate::math::LN_2PI);
        ScoredObservation { score, pivot: d, w }
    }

    /// [`Predictor::score_observation`] for a d-dimensional candidate
    /// row. A heteroscedastic predictor requires the new point's own σ_n
    /// (`sigma_n_new`); a homoscedastic one requires `None` (the model's
    /// scalar σ_n applies) — mixing the two is an error, not a silent
    /// noise-floor change.
    pub fn score_observation_row(
        &self,
        x_new: &[f64],
        y_new: f64,
        sigma_n_new: Option<f64>,
    ) -> crate::Result<ScoredObservation> {
        anyhow::ensure!(
            x_new.len() == self.d(),
            "observation dim {} vs predictor d {}",
            x_new.len(),
            self.d()
        );
        anyhow::ensure!(
            x_new.iter().all(|v| v.is_finite()) && y_new.is_finite(),
            "non-finite observation rejected at the data boundary"
        );
        anyhow::ensure!(
            self.noise.is_some() == sigma_n_new.is_some(),
            "noise contract mismatch: predictor {} but observation σ_n is {:?}",
            if self.noise.is_some() { "is heteroscedastic" } else { "is homoscedastic" },
            sigma_n_new
        );
        let noise_var = match sigma_n_new {
            Some(s) => {
                anyhow::ensure!(s.is_finite() && s >= 0.0, "bad observation σ_n = {s}");
                s * s
            }
            None => self.model.noise_variance(),
        };
        let d = self.d();
        let cols = self.input_cols();
        let mut prep = self.model.kernel.prepare(&self.theta);
        let mut dx = [0.0f64; MAX_INPUT_DIM];
        let mut k = Vec::with_capacity(self.t.len());
        for i in 0..self.t.len() {
            for (j, col) in cols.iter().enumerate() {
                dx[j] = col[i] - x_new[j];
            }
            k.push(prep.value_nd(&dx[..d]));
        }
        let mean = dot(&k, &self.alpha);
        let w = self.chol.half_solve(&k);
        let zero = [0.0f64; MAX_INPUT_DIM];
        let pivot = prep.value_nd(&zero[..d]) + noise_var - dot(&w, &w);
        let var = (self.sigma_f_hat2 * pivot).max(1e-300);
        let score =
            -0.5 * ((y_new - mean) * (y_new - mean) / var + var.ln() + crate::math::LN_2PI);
        Ok(ScoredObservation { score, pivot, w })
    }

    /// Absorb an observation whose solve was already done by
    /// [`Predictor::score_observation`] **against the current factor**:
    /// the border row is written straight from the scored `w`
    /// ([`Chol::extend_solved`]), then `α`/`σ̂_f²` refresh as in
    /// [`Predictor::observe`]. Errors if the factor grew since scoring
    /// (the solve would be stale) or the pivot is not positive.
    pub fn observe_scored(
        &mut self,
        t_new: f64,
        y_new: f64,
        scored: ScoredObservation,
    ) -> crate::Result<()> {
        self.observe_scored_deferred(t_new, y_new, scored)?;
        self.refresh();
        Ok(())
    }

    /// [`Predictor::observe_scored`] **without** the `α`/`σ̂_f²` refresh —
    /// the serving router's windowed absorb path, which may evict right
    /// after the extend and would otherwise pay the `O(n²)` refresh
    /// twice per point. The caller must run [`Predictor::refresh_cache`]
    /// (or adopt a cold refit) before the predictor serves again.
    pub(crate) fn observe_scored_deferred(
        &mut self,
        t_new: f64,
        y_new: f64,
        scored: ScoredObservation,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            self.extra.is_empty() && self.noise.is_none(),
            "scalar observe on an nd/heteroscedastic predictor — use the row variants"
        );
        anyhow::ensure!(
            t_new.is_finite() && y_new.is_finite(),
            "non-finite observation (t = {t_new}, y = {y_new}) rejected at the data boundary"
        );
        anyhow::ensure!(
            scored.w.len() == self.t.len(),
            "scored observation is stale: solved against n = {}, factor has n = {}",
            scored.w.len(),
            self.t.len()
        );
        self.chol
            .extend_solved(&scored.w, scored.pivot)
            .map_err(|e| anyhow::anyhow!("observe(t={t_new}) makes K̃ non-PD: {e}"))?;
        self.t.push(t_new);
        self.y.push(y_new);
        self.observations.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Absorb one d-dimensional observation already scored by
    /// [`Predictor::score_observation_row`] **without** the `α`/`σ̂_f²`
    /// refresh — the windowed absorb path's row twin of
    /// [`Predictor::observe_scored_deferred`]. The caller must refresh
    /// (or adopt a cold refit) before serving.
    pub(crate) fn observe_scored_row_deferred(
        &mut self,
        x_new: &[f64],
        y_new: f64,
        sigma_n_new: Option<f64>,
        scored: ScoredObservation,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            x_new.len() == self.d(),
            "observation dim {} vs predictor d {}",
            x_new.len(),
            self.d()
        );
        anyhow::ensure!(
            x_new.iter().all(|v| v.is_finite()) && y_new.is_finite(),
            "non-finite observation rejected at the data boundary"
        );
        anyhow::ensure!(
            self.noise.is_some() == sigma_n_new.is_some(),
            "noise contract mismatch on absorb"
        );
        anyhow::ensure!(
            scored.w.len() == self.t.len(),
            "scored observation is stale: solved against n = {}, factor has n = {}",
            scored.w.len(),
            self.t.len()
        );
        self.chol
            .extend_solved(&scored.w, scored.pivot)
            .map_err(|e| anyhow::anyhow!("observe(t={}) makes K̃ non-PD: {e}", x_new[0]))?;
        self.t.push(x_new[0]);
        for (j, col) in self.extra.iter_mut().enumerate() {
            col.push(x_new[j + 1]);
        }
        if let (Some(noise), Some(s)) = (&mut self.noise, sigma_n_new) {
            noise.push(s);
        }
        self.y.push(y_new);
        self.observations.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Append one d-dimensional observation in `O(n²)` (score + bordered
    /// factor extension + `α`/`σ̂_f²` refresh). The row twin of
    /// [`Predictor::observe`]; see [`Predictor::score_observation_row`]
    /// for the σ_n contract.
    pub fn observe_row(
        &mut self,
        x_new: &[f64],
        y_new: f64,
        sigma_n_new: Option<f64>,
    ) -> crate::Result<()> {
        let scored = self.score_observation_row(x_new, y_new, sigma_n_new)?;
        self.observe_scored_row_deferred(x_new, y_new, sigma_n_new, scored)?;
        self.refresh();
        Ok(())
    }

    /// Append one observation in `O(n²)`: extend the factor by the
    /// bordered-factorisation row ([`Chol::extend`]) and refresh `α` and
    /// `σ̂_f²` with two triangular solves. No refactorisation.
    pub fn observe(&mut self, t_new: f64, y_new: f64) -> crate::Result<()> {
        anyhow::ensure!(
            t_new.is_finite() && y_new.is_finite(),
            "non-finite observation (t = {t_new}, y = {y_new}) rejected at the data boundary"
        );
        self.append(t_new, y_new)?;
        self.refresh();
        Ok(())
    }

    /// Append a batch of observations (each factor extension is `O(n²)`),
    /// refreshing `α`/`σ̂_f²` once at the end.
    ///
    /// On a mid-batch failure the points already appended are kept and
    /// `α`/`σ̂_f²` are refreshed before the error propagates, so the
    /// predictor stays serviceable: the successfully absorbed prefix is
    /// fully incorporated, the failing point (and the rest of the batch)
    /// is not.
    pub fn observe_batch(&mut self, t_new: &[f64], y_new: &[f64]) -> crate::Result<()> {
        anyhow::ensure!(t_new.len() == y_new.len(), "t/y batch length mismatch");
        for (i, (&tn, &yn)) in t_new.iter().zip(y_new).enumerate() {
            anyhow::ensure!(
                tn.is_finite() && yn.is_finite(),
                "non-finite observation in batch at index {i} (t = {tn}, y = {yn}) \
                 rejected at the data boundary"
            );
        }
        let mut failure = None;
        let mut appended = 0usize;
        for (&tn, &yn) in t_new.iter().zip(y_new) {
            match self.append(tn, yn) {
                Ok(()) => appended += 1,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if appended > 0 {
            self.refresh();
        }
        match failure {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn append(&mut self, t_new: f64, y_new: f64) -> crate::Result<()> {
        anyhow::ensure!(
            self.extra.is_empty() && self.noise.is_none(),
            "scalar observe on an nd/heteroscedastic predictor — use observe_row"
        );
        let mut prep = self.model.kernel.prepare(&self.theta);
        // assembly convention: lag = existing − new (the new point is the
        // trailing row of the grown matrix); kernels are even in the lag
        let cross: Vec<f64> = self.t.iter().map(|&ti| prep.value(ti - t_new)).collect();
        let diag = prep.value(0.0) + self.model.noise_variance();
        self.chol
            .extend(&cross, diag)
            .map_err(|e| anyhow::anyhow!("observe(t={t_new}) makes K̃ non-PD: {e}"))?;
        self.t.push(t_new);
        self.y.push(y_new);
        self.observations.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Delete observation `i` (by absorption order) in `O(n²)`: the
    /// factor shrinks via the bordered-complement restore
    /// ([`Chol::remove_row`]) and `α`/`σ̂_f²` refresh with two triangular
    /// solves — the sliding-window eviction primitive. Infallible except
    /// for the guards (the deletion itself is a rank-1 *update*, which
    /// cannot fail); at least one observation must remain.
    pub fn evict(&mut self, i: usize) -> crate::Result<()> {
        anyhow::ensure!(i < self.t.len(), "evict({i}) out of range for n = {}", self.t.len());
        anyhow::ensure!(self.t.len() > 1, "cannot evict the last observation");
        self.chol.remove_row(i);
        self.t.remove(i);
        for col in &mut self.extra {
            col.remove(i);
        }
        if let Some(noise) = &mut self.noise {
            noise.remove(i);
        }
        self.y.remove(i);
        self.evictions.fetch_add(1, Ordering::Relaxed);
        self.refresh();
        Ok(())
    }

    /// Recompute the serving cache (`α`, `σ̂_f²`) after a sequence of
    /// deferred mutations.
    pub(crate) fn refresh_cache(&mut self) {
        self.refresh();
    }

    /// Delete the `k` oldest observations in one `O(k n²)` factor shrink
    /// ([`Chol::shrink_front`]) with a single `α`/`σ̂_f²` refresh at the
    /// end. At least one observation must remain.
    pub fn evict_front(&mut self, k: usize) -> crate::Result<()> {
        self.evict_front_deferred(k)?;
        if k > 0 {
            self.refresh();
        }
        Ok(())
    }

    /// [`Predictor::evict_front`] without the `α`/`σ̂_f²` refresh (see
    /// [`Predictor::observe_scored_deferred`] for the contract) — the
    /// window-enforcement path, which refreshes once after the whole
    /// grow-then-shrink step.
    pub(crate) fn evict_front_deferred(&mut self, k: usize) -> crate::Result<()> {
        if k == 0 {
            return Ok(());
        }
        anyhow::ensure!(
            k < self.t.len(),
            "evict_front({k}) would leave no observations (n = {})",
            self.t.len()
        );
        self.chol.shrink_front(k);
        self.t.drain(..k);
        for col in &mut self.extra {
            col.drain(..k);
        }
        if let Some(noise) = &mut self.noise {
            noise.drain(..k);
        }
        self.y.drain(..k);
        self.evictions.fetch_add(k, Ordering::Relaxed);
        Ok(())
    }

    /// Cold re-evaluation of the **current** window at the cached ϑ̂:
    /// re-assemble `K̃` and refactorise from scratch (`O(n³)`), without
    /// touching the live state. The periodic window refresh uses this to
    /// wash out accumulated `O(n²)`-maintenance rounding drift — compute
    /// first, then commit via [`Predictor::adopt_eval`], so a multi-model
    /// refresh can be all-or-nothing.
    pub fn refit_eval(&self, ctx: &ExecutionContext) -> crate::Result<ProfiledEval> {
        // nd assembly delegates to the scalar path when d == 1 and the
        // noise is the model's scalar σ_n — bit-identical to the
        // pre-scenario refit
        let cols = self.input_cols();
        let k = assemble_cov_nd_with(&self.model, &cols, self.noise.as_deref(), &self.theta, ctx);
        ProfiledEval::from_cov_with(k, &self.y, ctx)
    }

    /// Swap in a freshly computed evaluation of the current window (from
    /// [`Predictor::refit_eval`]): replaces the factor, `α` and `σ̂_f²`.
    pub fn adopt_eval(&mut self, ev: ProfiledEval) {
        assert_eq!(ev.chol.dim(), self.t.len(), "refreshed factor/data size mismatch");
        self.chol = ev.chol;
        self.alpha = ev.alpha;
        self.sigma_f_hat2 = ev.sigma_f_hat2;
        self.jitter = ev.jitter;
    }

    /// Recompute `α = K̃⁻¹y` and `σ̂_f² = yᵀα/n` from the current factor
    /// (`O(n²)`; eq. 2.15).
    fn refresh(&mut self) {
        self.alpha = self.chol.solve(&self.y);
        self.sigma_f_hat2 = dot(&self.y, &self.alpha) / self.y.len() as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::draw_gp_dataset;
    use crate::gp::{predict, profiled};
    use crate::kernels::{paper_k1, PaperK1};
    use crate::rng::Xoshiro256;

    fn trained_predictor(n: usize, seed: u64) -> (Predictor, Vec<f64>, Vec<f64>) {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), n, &mut rng);
        let ev = profiled::eval(&model, &data.t, &data.y, &PaperK1::truth()).unwrap();
        let p = Predictor::from_eval(
            paper_k1(0.1),
            data.t.clone(),
            data.y.clone(),
            PaperK1::truth(),
            ev,
        );
        (p, data.t, data.y)
    }

    #[test]
    fn batch_matches_pointwise_predict_bitwise() {
        let (p, t, y) = trained_predictor(40, 9);
        let model = paper_k1(0.1);
        let ev = profiled::eval(&model, &t, &y, &PaperK1::truth()).unwrap();
        let t_star: Vec<f64> = (0..25).map(|i| 0.5 + 1.7 * i as f64).collect();
        let reference = predict::predict(&model, &t, &PaperK1::truth(), &ev, &t_star);
        let served = p.predict_batch(&t_star, &ExecutionContext::seq());
        assert_eq!(served.mean, reference.mean, "serial batch mean must be bit-identical");
        assert_eq!(served.sd, reference.sd, "serial batch sd must be bit-identical");
    }

    #[test]
    fn batch_is_bit_identical_across_threads() {
        let (p, _, _) = trained_predictor(150, 11);
        let t_star: Vec<f64> = (0..400).map(|i| 0.13 + 0.37 * i as f64).collect();
        let serial = p.predict_batch(&t_star, &ExecutionContext::seq());
        for threads in [2usize, 4] {
            let par = p.predict_batch(&t_star, &ExecutionContext::new(threads));
            assert_eq!(par.mean, serial.mean, "threads={threads}");
            assert_eq!(par.sd, serial.sd, "threads={threads}");
        }
    }

    #[test]
    fn observe_matches_cold_refit() {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(13);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 45, &mut rng);
        let (head_t, tail_t) = data.t.split_at(30);
        let (head_y, tail_y) = data.y.split_at(30);
        let mut p = Predictor::fit(
            paper_k1(0.1),
            head_t,
            head_y,
            &PaperK1::truth(),
            &ExecutionContext::seq(),
        )
        .unwrap();
        p.observe_batch(tail_t, tail_y).unwrap();
        // cold refit on the full 45 points at the same θ
        let ev = profiled::eval(&model, &data.t, &data.y, &PaperK1::truth()).unwrap();
        assert!(
            (p.sigma_f_hat2() - ev.sigma_f_hat2).abs() < 1e-10 * ev.sigma_f_hat2,
            "σ̂² {} vs {}",
            p.sigma_f_hat2(),
            ev.sigma_f_hat2
        );
        assert!((p.lnp() - ev.lnp).abs() < 1e-8 * ev.lnp.abs(), "{} vs {}", p.lnp(), ev.lnp);
        let t_star: Vec<f64> = (0..60).map(|i| 0.4 + 0.75 * i as f64).collect();
        let cold = predict::predict(&model, &data.t, &PaperK1::truth(), &ev, &t_star);
        let served = p.predict_batch(&t_star, &ExecutionContext::seq());
        for i in 0..t_star.len() {
            assert!(
                (served.mean[i] - cold.mean[i]).abs() < 1e-8,
                "mean[{i}]: {} vs {}",
                served.mean[i],
                cold.mean[i]
            );
            assert!(
                (served.sd[i] - cold.sd[i]).abs() < 1e-8,
                "sd[{i}]: {} vs {}",
                served.sd[i],
                cold.sd[i]
            );
        }
    }

    #[test]
    fn stats_count_queries_and_observations() {
        let (mut p, _, _) = trained_predictor(20, 17);
        assert_eq!(p.stats(), ServeStats { n_train: 20, ..Default::default() });
        let _ = p.predict_batch(&[1.0, 2.0, 3.0], &ExecutionContext::seq());
        p.observe(21.5, 0.3).unwrap();
        let _ = p.predict_batch(&[4.0], &ExecutionContext::seq());
        let s = p.stats();
        assert_eq!(s.n_train, 21);
        assert_eq!(s.queries_served, 4);
        assert_eq!(s.observations_appended, 1);
    }

    #[test]
    fn failed_mid_batch_observe_leaves_predictor_serviceable() {
        let (mut p, _, _) = trained_predictor(25, 23);
        // a NaN input time makes Chol::extend fail deterministically
        // (non-finite Schur complement) before any state is mutated
        let err = p.observe_batch(&[26.0, f64::NAN, 27.0], &[0.1, 0.2, 0.3]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("non-PD"), "unexpected error: {msg}");
        // the successfully appended prefix (26.0) is fully incorporated…
        let s = p.stats();
        assert_eq!(s.n_train, 26);
        assert_eq!(s.observations_appended, 1);
        assert!(p.sigma_f_hat2().is_finite());
        // …and serving still works: α matches the grown factor
        let out = p.predict_batch(&[25.5, 26.5], &ExecutionContext::seq());
        assert!(out.mean.iter().all(|v| v.is_finite()));
        assert!(out.sd.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn observe_scored_is_bitwise_identical_to_observe() {
        // the scored path reuses the pivot check's solve; the absorbed
        // state must match a plain observe exactly
        let (mut a, t, _) = trained_predictor(35, 41);
        let (mut b, _, _) = trained_predictor(35, 41);
        let (tn, yn) = (t[t.len() - 1] + 0.75, 0.42);
        a.observe(tn, yn).unwrap();
        let s = b.score_observation(tn, yn);
        assert!(s.pivot > 0.0);
        b.observe_scored(tn, yn, s).unwrap();
        assert_eq!(a.lnp(), b.lnp());
        assert_eq!(a.sigma_f_hat2(), b.sigma_f_hat2());
        let q = [tn + 0.3, tn + 1.1];
        let pa = a.predict_batch(&q, &ExecutionContext::seq());
        let pb = b.predict_batch(&q, &ExecutionContext::seq());
        assert_eq!(pa.mean, pb.mean);
        assert_eq!(pa.sd, pb.sd);
        // a stale scored solve (factor grew since scoring) is rejected
        let stale = b.score_observation(tn + 2.0, 0.1);
        b.observe(tn + 1.5, 0.2).unwrap();
        assert!(b.observe_scored(tn + 2.0, 0.1, stale).is_err());
    }

    #[test]
    fn log_predictive_prefers_plausible_observations() {
        let (p, t, _) = trained_predictor(40, 31);
        let t_new = t[t.len() - 1] + 0.5;
        let pred = p.predict_batch(&[t_new], &ExecutionContext::seq());
        let good = p.log_predictive(t_new, pred.mean[0]);
        let bad = p.log_predictive(t_new, pred.mean[0] + 10.0 * pred.sd[0].max(0.1));
        assert!(good.is_finite() && bad.is_finite());
        assert!(good > bad, "at-mean score {good} must beat 10σ-off score {bad}");
        // scoring mutates nothing
        assert_eq!(p.stats().queries_served, 1); // only the predict above
    }

    #[test]
    fn evict_matches_cold_fit_on_reduced_data() {
        let (mut p, t, y) = trained_predictor(30, 47);
        // evict the oldest point and an interior point
        p.evict(0).unwrap();
        p.evict(10).unwrap();
        let mut kept_t: Vec<f64> = t[1..].to_vec();
        let mut kept_y: Vec<f64> = y[1..].to_vec();
        kept_t.remove(10);
        kept_y.remove(10);
        assert_eq!(p.t(), kept_t.as_slice());
        assert_eq!(p.y(), kept_y.as_slice());
        let cold = Predictor::fit(
            paper_k1(0.1),
            &kept_t,
            &kept_y,
            &PaperK1::truth(),
            &ExecutionContext::seq(),
        )
        .unwrap();
        assert!(
            (p.sigma_f_hat2() - cold.sigma_f_hat2()).abs() < 1e-10 * cold.sigma_f_hat2(),
            "σ̂² {} vs cold {}",
            p.sigma_f_hat2(),
            cold.sigma_f_hat2()
        );
        assert!((p.lnp() - cold.lnp()).abs() < 1e-8 * cold.lnp().abs());
        let q: Vec<f64> = (0..12).map(|i| 0.7 + 2.3 * i as f64).collect();
        let a = p.predict_batch(&q, &ExecutionContext::seq());
        let b = cold.predict_batch(&q, &ExecutionContext::seq());
        for i in 0..q.len() {
            assert!((a.mean[i] - b.mean[i]).abs() < 1e-8, "mean[{i}]");
            assert!((a.sd[i] - b.sd[i]).abs() < 1e-8, "sd[{i}]");
        }
        let s = p.stats();
        assert_eq!(s.n_train, 28);
        assert_eq!(s.observations_evicted, 2);
        // guards: out-of-range and last-observation evictions are errors
        assert!(p.evict(28).is_err());
        assert!(p.evict_front(28).is_err());
        // evict_front matches repeated evict(0) to rounding
        let (mut a, _, _) = trained_predictor(25, 53);
        let (mut b, _, _) = trained_predictor(25, 53);
        a.evict_front(5).unwrap();
        for _ in 0..5 {
            b.evict(0).unwrap();
        }
        assert_eq!(a.n(), b.n());
        assert!((a.lnp() - b.lnp()).abs() < 1e-9 * b.lnp().abs());
        assert_eq!(a.stats().observations_evicted, 5);
    }

    #[test]
    fn refit_eval_washes_out_maintenance_drift() {
        let (mut p, t, _) = trained_predictor(30, 59);
        // grow and shrink a few times, then refresh from scratch
        for j in 0..4 {
            p.observe(t[t.len() - 1] + 1.0 + j as f64, 0.1 * j as f64).unwrap();
        }
        p.evict_front(4).unwrap();
        let ev = p.refit_eval(&ExecutionContext::seq()).unwrap();
        p.adopt_eval(ev);
        // the refreshed state is exactly a cold fit of the live window
        let (wt, wy) = (p.t().to_vec(), p.y().to_vec());
        let cold =
            Predictor::fit(paper_k1(0.1), &wt, &wy, &PaperK1::truth(), &ExecutionContext::seq())
                .unwrap();
        assert_eq!(p.sigma_f_hat2(), cold.sigma_f_hat2());
        assert_eq!(p.lnp(), cold.lnp());
        let q = [3.3, 17.9];
        let a = p.predict_batch(&q, &ExecutionContext::seq());
        let b = cold.predict_batch(&q, &ExecutionContext::seq());
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.sd, b.sd);
    }

    #[test]
    fn nd_predictor_streams_and_matches_cold_refit() {
        // d = 2 heteroscedastic session: fit, stream row appends, evict,
        // and check the maintained state against a cold refit
        let n = 24;
        let mut rng = Xoshiro256::seed_from_u64(314);
        let t: Vec<f64> = (0..n).map(|i| i as f64 * 0.7).collect();
        let x2: Vec<f64> = (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let noise: Vec<f64> = (0..n).map(|_| 0.05 + 0.15 * rng.uniform()).collect();
        let theta = vec![0.3, -0.2];
        let ctx = ExecutionContext::seq();
        let mut p = Predictor::fit_nd(
            CovarianceModel::new("se-ard2", Box::new(crate::kernels::ArdKernel::se(2)), 0.1),
            &[&t, &x2],
            Some(&noise),
            &y,
            &theta,
            &ctx,
        )
        .unwrap();
        assert_eq!(p.d(), 2);
        // scalar entry points must refuse the nd session cleanly
        assert!(p.observe(99.0, 0.1).is_err());
        // stream three row appends (hetero ⇒ per-point σ required)
        for j in 0..3 {
            let xr = [t[n - 1] + 1.0 + j as f64, 0.3 * j as f64];
            assert!(p.observe_row(&xr, 0.2, None).is_err(), "missing σ_n must error");
            p.observe_row(&xr, 0.2, Some(0.1)).unwrap();
        }
        p.evict(0).unwrap();
        p.evict_front(2).unwrap();
        assert_eq!(p.n(), n); // +3 −3
        assert_eq!(p.extra()[0].len(), n);
        assert_eq!(p.noise().unwrap().len(), n);
        // maintained state vs cold refit of the live window
        let ev = p.refit_eval(&ctx).unwrap();
        assert!(
            (p.sigma_f_hat2() - ev.sigma_f_hat2).abs() < 1e-8 * ev.sigma_f_hat2,
            "σ̂² {} vs cold {}",
            p.sigma_f_hat2(),
            ev.sigma_f_hat2
        );
        assert!((p.lnp() - ev.lnp).abs() < 1e-7 * ev.lnp.abs(), "{} vs {}", p.lnp(), ev.lnp);
        // predict_rows serves finite numbers and counts queries
        let q1: Vec<f64> = vec![2.0, 9.5];
        let q2: Vec<f64> = vec![0.1, -0.4];
        let out = p.predict_rows(&[&q1, &q2], &ctx);
        assert!(out.mean.iter().all(|v| v.is_finite()));
        assert!(out.sd.iter().all(|v| v.is_finite() && *v >= 0.0));
        // thread-count bit-identity of the nd batch
        let par = p.predict_rows(&[&q1, &q2], &ExecutionContext::new(4));
        assert_eq!(par.mean, out.mean);
        assert_eq!(par.sd, out.sd);
    }

    #[test]
    fn predict_rows_delegates_for_1d() {
        let (p, _, _) = trained_predictor(30, 71);
        let q: Vec<f64> = (0..9).map(|i| 0.3 + 2.1 * i as f64).collect();
        let a = p.predict_batch(&q, &ExecutionContext::seq());
        let b = p.predict_rows(&[&q], &ExecutionContext::seq());
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.sd, b.sd);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let (p, _, _) = trained_predictor(15, 19);
        let out = p.predict_batch(&[], &ExecutionContext::new(4));
        assert!(out.mean.is_empty() && out.sd.is_empty());
        assert_eq!(p.stats().queries_served, 0);
    }
}
