//! The un-profiled hyperlikelihood with σ_f explicit — eqs. (2.5), (2.7),
//! (2.9) — parametrised by `θ = [λ, ϑ…]` with `λ = ln σ_f` (the flat
//! coordinate of the Jeffreys prior on a scale parameter).
//!
//! With `K = e^{2λ} K̃(ϑ)` and `Q = yᵀK̃⁻¹y`:
//!
//! `ln P = −½ [e^{−2λ} Q + 2nλ + ln det K̃ + n ln 2π]`
//! `∂ln P/∂λ   = e^{−2λ} Q − n`
//! `∂ln P/∂ϑ_a = ½ e^{−2λ} q_a − ½ Tr(W ∂_aK̃)`
//!
//! Used by the nested-sampling baseline (each live point carries its own
//! σ_f) and by the σ_f-profiling ablation benchmark. Shares the parallel
//! contraction kernels of [`super::profiled`]; `*_with` variants thread
//! an [`ExecutionContext`] through every stage.

use crate::kernels::CovarianceModel;
use crate::linalg::Matrix;
use crate::math::LN_2PI;
use crate::runtime::ExecutionContext;

use super::assemble::{assemble_cov_grads_with, hessian_contractions_with};
use super::profiled::{pairwise_d2_with, quad_and_trace_with, ProfiledEval};

/// `ln P(y | x, [λ, ϑ])` — eq. (2.5), serial.
pub fn full_lnp(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
) -> crate::Result<f64> {
    full_lnp_with(model, t, y, theta_full, &ExecutionContext::seq())
}

/// `ln P` with parallel assembly and factorisation.
pub fn full_lnp_with(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<f64> {
    let (lambda, theta) = split(model, theta_full)?;
    let ev = super::profiled::eval_with(model, t, y, theta, ctx)?;
    Ok(lnp_from_eval(&ev, y.len(), lambda))
}

/// `ln P` and its gradient `[∂λ, ∂ϑ…]` — eq. (2.7) in (λ, ϑ) coordinates,
/// serial.
pub fn full_lnp_grad(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
) -> crate::Result<(f64, Vec<f64>)> {
    full_lnp_grad_with(model, t, y, theta_full, &ExecutionContext::seq())
}

/// `ln P` and gradient with every stage parallel.
pub fn full_lnp_grad_with(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<(f64, Vec<f64>)> {
    let (lambda, theta) = split(model, theta_full)?;
    let n = y.len();
    let (k, grads) = assemble_cov_grads_with(model, t, theta, ctx);
    let ev = ProfiledEval::from_cov_with(k, y, ctx)?;
    let w = ev.inverse_with(ctx);
    let e2 = (-2.0 * lambda).exp();
    let q_total = n as f64 * ev.sigma_f_hat2; // yᵀK̃⁻¹y
    let mut g = Vec::with_capacity(model.dim() + 1);
    g.push(e2 * q_total - n as f64);
    for dk in &grads {
        let (qa, tr) = quad_and_trace_with(dk, &ev.alpha, &w, ctx);
        g.push(0.5 * e2 * qa - 0.5 * tr);
    }
    Ok((lnp_from_eval(&ev, n, lambda), g))
}

/// Hessian `H = −∂²ln P/∂θ∂θ'` in (λ, ϑ) coordinates — eq. (2.9) plus the
/// λ row/column (serial):
///
/// `∂²ln P/∂λ²      = −2 e^{−2λ} Q`
/// `∂²ln P/∂λ∂ϑ_a   = −e^{−2λ} q_a`
/// `∂²ln P/∂ϑ_a∂ϑ_b = −½e^{−2λ}(2v_aᵀWv_b − A_ab) + ½Tr(W∂_aK̃W∂_bK̃) − ½B_ab`
pub fn full_hessian(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
) -> crate::Result<Matrix> {
    full_hessian_with(model, t, y, theta_full, &ExecutionContext::seq())
}

/// Hessian with the `W·∂K̃` products and trace pairs parallel.
pub fn full_hessian_with(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
    ctx: &ExecutionContext,
) -> crate::Result<Matrix> {
    let (lambda, theta) = split(model, theta_full)?;
    let m = model.dim();
    let n = y.len();
    let (k, grads) = assemble_cov_grads_with(model, t, theta, ctx);
    let ev = ProfiledEval::from_cov_with(k, y, ctx)?;
    let w = ev.inverse_with(ctx);
    let e2 = (-2.0 * lambda).exp();
    let q_total = n as f64 * ev.sigma_f_hat2;

    let mut v = Vec::with_capacity(m);
    let mut q = Vec::with_capacity(m);
    let mut wm = Vec::with_capacity(m);
    for dk in &grads {
        let va = dk.matvec(&ev.alpha);
        q.push(crate::linalg::dot(&ev.alpha, &va));
        v.push(va);
        wm.push(w.matmul_with(dk, ctx));
    }
    let wmt: Vec<Matrix> = wm.iter().map(|ma| ma.transpose()).collect();
    let (a_c, b_c) = hessian_contractions_with(model, t, theta, &ev.alpha, &w, ctx);

    let mut h = Matrix::zeros(m + 1, m + 1);
    h[(0, 0)] = 2.0 * e2 * q_total; // −∂²/∂λ²
    for a in 0..m {
        let val = e2 * q[a]; // −∂²/∂λ∂ϑ_a
        h[(0, a + 1)] = val;
        h[(a + 1, 0)] = val;
    }
    let d2 = pairwise_d2_with(n, m, &w, &wm, &wmt, &v, ctx);
    let mut idx = 0;
    for a in 0..m {
        for b in a..m {
            let (tr_ab, vwv) = d2[idx];
            idx += 1;
            let val = -0.5 * e2 * (2.0 * vwv - a_c[(a, b)]) + 0.5 * tr_ab - 0.5 * b_c[(a, b)];
            h[(a + 1, b + 1)] = -val;
            h[(b + 1, a + 1)] = -val;
        }
    }
    Ok(h)
}

fn lnp_from_eval(ev: &ProfiledEval, n: usize, lambda: f64) -> f64 {
    let nf = n as f64;
    let q = nf * ev.sigma_f_hat2;
    -0.5 * ((-2.0 * lambda).exp() * q + 2.0 * nf * lambda + ev.chol.logdet() + nf * LN_2PI)
}

fn split<'a>(model: &CovarianceModel, theta_full: &'a [f64]) -> crate::Result<(f64, &'a [f64])> {
    anyhow::ensure!(
        theta_full.len() == model.dim() + 1,
        "expected {} parameters ([ln σ_f, ϑ…]), got {}",
        model.dim() + 1,
        theta_full.len()
    );
    Ok((theta_full[0], &theta_full[1..]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::draw_gp_dataset;
    use crate::kernels::{paper_k1, PaperK1};
    use crate::rng::Xoshiro256;

    fn problem() -> (crate::kernels::CovarianceModel, Vec<f64>, Vec<f64>, Vec<f64>) {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 20, &mut rng);
        let mut theta_full = vec![0.2]; // λ = ln σ_f
        theta_full.extend(PaperK1::truth());
        (model, data.t, data.y, theta_full)
    }

    /// At λ = ½ ln σ̂_f², the full likelihood equals the profiled one.
    #[test]
    fn full_at_sigma_hat_equals_profiled() {
        let (model, t, y, _) = problem();
        let ev = super::super::profiled::eval(&model, &t, &y, &PaperK1::truth()).unwrap();
        let mut tf = vec![0.5 * ev.sigma_f_hat2.ln()];
        tf.extend(PaperK1::truth());
        let lnp = full_lnp(&model, &t, &y, &tf).unwrap();
        assert!((lnp - ev.lnp).abs() < 1e-9 * ev.lnp.abs(), "{lnp} vs {}", ev.lnp);
        // and the λ-gradient vanishes there
        let (_, g) = full_lnp_grad(&model, &t, &y, &tf).unwrap();
        assert!(g[0].abs() < 1e-8, "∂λ at σ̂: {}", g[0]);
    }

    #[test]
    fn gradient_matches_fd() {
        let (model, t, y, tf) = problem();
        let (_, g) = full_lnp_grad(&model, &t, &y, &tf).unwrap();
        for a in 0..tf.len() {
            let h = 1e-6;
            let mut tp = tf.clone();
            let mut tm = tf.clone();
            tp[a] += h;
            tm[a] -= h;
            let fp = full_lnp(&model, &t, &y, &tp).unwrap();
            let fm = full_lnp(&model, &t, &y, &tm).unwrap();
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                crate::math::rel_diff(g[a], fd) < 1e-5,
                "grad[{a}]: {} vs {fd}",
                g[a]
            );
        }
    }

    #[test]
    fn parallel_full_matches_serial() {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(11);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 100, &mut rng);
        let mut tf = vec![0.1];
        tf.extend(PaperK1::truth());
        let (lnp_s, g_s) = full_lnp_grad(&model, &data.t, &data.y, &tf).unwrap();
        let ctx = ExecutionContext::new(4);
        let (lnp_p, g_p) = full_lnp_grad_with(&model, &data.t, &data.y, &tf, &ctx).unwrap();
        assert_eq!(lnp_p, lnp_s);
        assert_eq!(g_p, g_s);
    }

    #[test]
    fn hessian_matches_fd_of_gradient() {
        let (model, t, y, tf) = problem();
        let hess = full_hessian(&model, &t, &y, &tf).unwrap();
        let mdim = tf.len();
        for a in 0..mdim {
            let h = 1e-5;
            let mut tp = tf.clone();
            let mut tm = tf.clone();
            tp[a] += h;
            tm[a] -= h;
            let (_, gp) = full_lnp_grad(&model, &t, &y, &tp).unwrap();
            let (_, gm) = full_lnp_grad(&model, &t, &y, &tm).unwrap();
            for b in 0..mdim {
                let fd = -(gp[b] - gm[b]) / (2.0 * h);
                assert!(
                    crate::math::rel_diff(hess[(a, b)], fd) < 1e-4,
                    "H[{a},{b}]: {} vs {fd}",
                    hess[(a, b)]
                );
            }
        }
    }
}
