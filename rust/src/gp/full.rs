//! The un-profiled hyperlikelihood with σ_f explicit — eqs. (2.5), (2.7),
//! (2.9) — parametrised by `θ = [λ, ϑ…]` with `λ = ln σ_f` (the flat
//! coordinate of the Jeffreys prior on a scale parameter).
//!
//! With `K = e^{2λ} K̃(ϑ)` and `Q = yᵀK̃⁻¹y`:
//!
//! `ln P = −½ [e^{−2λ} Q + 2nλ + ln det K̃ + n ln 2π]`
//! `∂ln P/∂λ   = e^{−2λ} Q − n`
//! `∂ln P/∂ϑ_a = ½ e^{−2λ} q_a − ½ Tr(W ∂_aK̃)`
//!
//! Used by the nested-sampling baseline (each live point carries its own
//! σ_f) and by the σ_f-profiling ablation benchmark.

use crate::kernels::CovarianceModel;
use crate::linalg::{dot, Matrix};
use crate::math::LN_2PI;

use super::assemble::{assemble_cov_grads, hessian_contractions};
use super::profiled::ProfiledEval;

/// `ln P(y | x, [λ, ϑ])` — eq. (2.5).
pub fn full_lnp(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
) -> crate::Result<f64> {
    let (lambda, theta) = split(model, theta_full)?;
    let ev = super::profiled::eval(model, t, y, theta)?;
    Ok(lnp_from_eval(&ev, y.len(), lambda))
}

/// `ln P` and its gradient `[∂λ, ∂ϑ…]` — eq. (2.7) in (λ, ϑ) coordinates.
pub fn full_lnp_grad(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
) -> crate::Result<(f64, Vec<f64>)> {
    let (lambda, theta) = split(model, theta_full)?;
    let n = y.len();
    let (k, grads) = assemble_cov_grads(model, t, theta);
    let ev = ProfiledEval::from_cov(k, y)?;
    let w = ev.inverse();
    let e2 = (-2.0 * lambda).exp();
    let q_total = n as f64 * ev.sigma_f_hat2; // yᵀK̃⁻¹y
    let mut g = Vec::with_capacity(model.dim() + 1);
    g.push(e2 * q_total - n as f64);
    for dk in &grads {
        let va = dk.matvec(&ev.alpha);
        let qa = dot(&ev.alpha, &va);
        let mut tr = 0.0;
        for i in 0..n {
            tr += dot(w.row(i), dk.row(i));
        }
        g.push(0.5 * e2 * qa - 0.5 * tr);
    }
    Ok((lnp_from_eval(&ev, n, lambda), g))
}

/// Hessian `H = −∂²ln P/∂θ∂θ'` in (λ, ϑ) coordinates — eq. (2.9) plus the
/// λ row/column:
///
/// `∂²ln P/∂λ²      = −2 e^{−2λ} Q`
/// `∂²ln P/∂λ∂ϑ_a   = −e^{−2λ} q_a`
/// `∂²ln P/∂ϑ_a∂ϑ_b = −½e^{−2λ}(2v_aᵀWv_b − A_ab) + ½Tr(W∂_aK̃W∂_bK̃) − ½B_ab`
pub fn full_hessian(
    model: &CovarianceModel,
    t: &[f64],
    y: &[f64],
    theta_full: &[f64],
) -> crate::Result<Matrix> {
    let (lambda, theta) = split(model, theta_full)?;
    let m = model.dim();
    let n = y.len();
    let (k, grads) = assemble_cov_grads(model, t, theta);
    let ev = ProfiledEval::from_cov(k, y)?;
    let w = ev.inverse();
    let e2 = (-2.0 * lambda).exp();
    let q_total = n as f64 * ev.sigma_f_hat2;

    let mut v = Vec::with_capacity(m);
    let mut q = Vec::with_capacity(m);
    let mut wm = Vec::with_capacity(m);
    for dk in &grads {
        let va = dk.matvec(&ev.alpha);
        q.push(dot(&ev.alpha, &va));
        v.push(va);
        wm.push(w.matmul(dk));
    }
    let (a_c, b_c) = hessian_contractions(model, t, theta, &ev.alpha, &w);

    let mut h = Matrix::zeros(m + 1, m + 1);
    h[(0, 0)] = 2.0 * e2 * q_total; // −∂²/∂λ²
    for a in 0..m {
        let val = e2 * q[a]; // −∂²/∂λ∂ϑ_a
        h[(0, a + 1)] = val;
        h[(a + 1, 0)] = val;
    }
    for a in 0..m {
        for b in a..m {
            let mut tr_ab = 0.0;
            for i in 0..n {
                let ra = wm[a].row(i);
                for (j, raj) in ra.iter().enumerate() {
                    tr_ab += raj * wm[b][(j, i)];
                }
            }
            let wv_b = w.matvec(&v[b]);
            let vwv = dot(&v[a], &wv_b);
            let d2 = -0.5 * e2 * (2.0 * vwv - a_c[(a, b)]) + 0.5 * tr_ab - 0.5 * b_c[(a, b)];
            h[(a + 1, b + 1)] = -d2;
            h[(b + 1, a + 1)] = -d2;
        }
    }
    Ok(h)
}

fn lnp_from_eval(ev: &ProfiledEval, n: usize, lambda: f64) -> f64 {
    let nf = n as f64;
    let q = nf * ev.sigma_f_hat2;
    -0.5 * ((-2.0 * lambda).exp() * q + 2.0 * nf * lambda + ev.chol.logdet() + nf * LN_2PI)
}

fn split<'a>(model: &CovarianceModel, theta_full: &'a [f64]) -> crate::Result<(f64, &'a [f64])> {
    anyhow::ensure!(
        theta_full.len() == model.dim() + 1,
        "expected {} parameters ([ln σ_f, ϑ…]), got {}",
        model.dim() + 1,
        theta_full.len()
    );
    Ok((theta_full[0], &theta_full[1..]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::draw_gp_dataset;
    use crate::kernels::{paper_k1, PaperK1};
    use crate::rng::Xoshiro256;

    fn problem() -> (crate::kernels::CovarianceModel, Vec<f64>, Vec<f64>, Vec<f64>) {
        let model = paper_k1(0.1);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let data = draw_gp_dataset(&model, 1.0, &PaperK1::truth(), 20, &mut rng);
        let mut theta_full = vec![0.2]; // λ = ln σ_f
        theta_full.extend(PaperK1::truth());
        (model, data.t, data.y, theta_full)
    }

    /// At λ = ½ ln σ̂_f², the full likelihood equals the profiled one.
    #[test]
    fn full_at_sigma_hat_equals_profiled() {
        let (model, t, y, _) = problem();
        let ev = super::super::profiled::eval(&model, &t, &y, &PaperK1::truth()).unwrap();
        let mut tf = vec![0.5 * ev.sigma_f_hat2.ln()];
        tf.extend(PaperK1::truth());
        let lnp = full_lnp(&model, &t, &y, &tf).unwrap();
        assert!((lnp - ev.lnp).abs() < 1e-9 * ev.lnp.abs(), "{lnp} vs {}", ev.lnp);
        // and the λ-gradient vanishes there
        let (_, g) = full_lnp_grad(&model, &t, &y, &tf).unwrap();
        assert!(g[0].abs() < 1e-8, "∂λ at σ̂: {}", g[0]);
    }

    #[test]
    fn gradient_matches_fd() {
        let (model, t, y, tf) = problem();
        let (_, g) = full_lnp_grad(&model, &t, &y, &tf).unwrap();
        for a in 0..tf.len() {
            let h = 1e-6;
            let mut tp = tf.clone();
            let mut tm = tf.clone();
            tp[a] += h;
            tm[a] -= h;
            let fp = full_lnp(&model, &t, &y, &tp).unwrap();
            let fm = full_lnp(&model, &t, &y, &tm).unwrap();
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                crate::math::rel_diff(g[a], fd) < 1e-5,
                "grad[{a}]: {} vs {fd}",
                g[a]
            );
        }
    }

    #[test]
    fn hessian_matches_fd_of_gradient() {
        let (model, t, y, tf) = problem();
        let hess = full_hessian(&model, &t, &y, &tf).unwrap();
        let mdim = tf.len();
        for a in 0..mdim {
            let h = 1e-5;
            let mut tp = tf.clone();
            let mut tm = tf.clone();
            tp[a] += h;
            tm[a] -= h;
            let (_, gp) = full_lnp_grad(&model, &t, &y, &tp).unwrap();
            let (_, gm) = full_lnp_grad(&model, &t, &y, &tm).unwrap();
            for b in 0..mdim {
                let fd = -(gp[b] - gm[b]) / (2.0 * h);
                assert!(
                    crate::math::rel_diff(hess[(a, b)], fd) < 1e-4,
                    "H[{a},{b}]: {} vs {fd}",
                    hess[(a, b)]
                );
            }
        }
    }
}
