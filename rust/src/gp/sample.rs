//! GP realisation sampling (paper Fig. 1): draw `y ~ N(0, σ_f² K̃(ϑ))`
//! over an input grid, noise included.

use crate::kernels::CovarianceModel;
use crate::rng::{MultivariateNormal, Xoshiro256};
use crate::runtime::exec::ExecutionContext;

use super::assemble::{assemble_cov, assemble_cov_nd_with};

/// Draw one realisation of the GP (including the σ_n measurement noise)
/// at the inputs `t`.
pub fn draw_realisation(
    model: &CovarianceModel,
    sigma_f: f64,
    theta: &[f64],
    t: &[f64],
    rng: &mut Xoshiro256,
) -> crate::Result<Vec<f64>> {
    let mut k = assemble_cov(model, t, theta);
    let s2 = sigma_f * sigma_f;
    for v in k.as_mut_slice() {
        *v *= s2;
    }
    let mvn = MultivariateNormal::new(vec![0.0; t.len()], &k)?;
    Ok(mvn.sample(rng))
}

/// Draw one realisation of the GP over an n×d input block (`x` is d
/// columns), with either the model's scalar σ_n or a per-point noise
/// vector on the diagonal. The d = 1 homoscedastic case matches
/// [`draw_realisation`] bitwise (the nd assembly delegates).
pub fn draw_realisation_nd(
    model: &CovarianceModel,
    sigma_f: f64,
    theta: &[f64],
    x: &[&[f64]],
    noise: Option<&[f64]>,
    rng: &mut Xoshiro256,
) -> crate::Result<Vec<f64>> {
    anyhow::ensure!(!x.is_empty(), "need at least one input column");
    let mut k = assemble_cov_nd_with(model, x, noise, theta, &ExecutionContext::seq());
    let s2 = sigma_f * sigma_f;
    for v in k.as_mut_slice() {
        *v *= s2;
    }
    let mvn = MultivariateNormal::new(vec![0.0; x[0].len()], &k)?;
    Ok(mvn.sample(rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{paper_k1, paper_k2, PaperK1, PaperK2};

    #[test]
    fn realisation_has_unit_scale_statistics() {
        // With σ_f = 1 the marginal variance of each sample point is
        // k(0) + σ_n² ≈ 1.01; average over many draws must agree.
        let model = paper_k1(0.1);
        let t: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let mut rng = Xoshiro256::seed_from_u64(42);
        let mut acc = 0.0;
        let reps = 200;
        for _ in 0..reps {
            let y = draw_realisation(&model, 1.0, &PaperK1::truth(), &t, &mut rng).unwrap();
            acc += y.iter().map(|v| v * v).sum::<f64>() / t.len() as f64;
        }
        let var = acc / reps as f64;
        assert!((var - 1.01).abs() < 0.15, "marginal variance {var}");
    }

    #[test]
    fn sigma_f_scales_amplitude() {
        let model = paper_k2(0.1);
        let t: Vec<f64> = (0..40).map(|i| i as f64).collect();
        let mut rng_a = Xoshiro256::seed_from_u64(1);
        let mut rng_b = Xoshiro256::seed_from_u64(1);
        let y1 = draw_realisation(&model, 1.0, &PaperK2::truth(), &t, &mut rng_a).unwrap();
        let y3 = draw_realisation(&model, 3.0, &PaperK2::truth(), &t, &mut rng_b).unwrap();
        for i in 0..t.len() {
            assert!((3.0 * y1[i] - y3[i]).abs() < 1e-9, "same seed → 3× amplitude");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let model = paper_k1(0.1);
        let t: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let a = draw_realisation(&model, 1.0, &PaperK1::truth(), &t,
            &mut Xoshiro256::seed_from_u64(9)).unwrap();
        let b = draw_realisation(&model, 1.0, &PaperK1::truth(), &t,
            &mut Xoshiro256::seed_from_u64(9)).unwrap();
        assert_eq!(a, b);
    }
}
