//! Covariance-matrix and derivative-matrix assembly.
//!
//! The `O(n² m)` matrix-entry computation is the paper's device-offloaded
//! hot spot (their released code does it on a GPU; our L1 Pallas kernel
//! does it on the accelerator via the XLA backend when compiled in).
//! This module is the **native** implementation: it exploits symmetry
//! (upper triangle computed, mirrored) and streams per-pair kernel
//! Hessians into `m×m` contractions so second-derivative matrices are
//! never materialised.
//!
//! With a multi-thread [`ExecutionContext`], the pair loops are
//! partitioned over row tiles weighted by their pair count (`n − i` pairs
//! in row `i`); every worker binds its own prepared kernel and writes
//! only its own rows, so assembled matrices are bit-identical to the
//! serial ones. The Hessian contractions reduce per-tile `m×m` partials
//! in tile order (deterministic for a fixed thread count).

use crate::kernels::CovarianceModel;
use crate::linalg::Matrix;
use crate::runtime::exec::{
    for_row_chunks, for_row_chunks_multi, weighted_bounds, ExecutionContext,
};

/// Below this `n` a parallel dispatch costs more than the pair loop.
const PAR_MIN_N: usize = 64;

fn assembly_jobs(n: usize, ctx: &ExecutionContext) -> usize {
    if n < PAR_MIN_N {
        1
    } else {
        ctx.threads().min(n)
    }
}

/// Assemble `K̃ = k̃(t_i − t_j) + σ_n² δ_ij` (σ_f = 1 units), serial.
pub fn assemble_cov(model: &CovarianceModel, t: &[f64], theta: &[f64]) -> Matrix {
    assemble_cov_with(model, t, theta, &ExecutionContext::seq())
}

/// Assemble `K̃` with the row tiles of the upper triangle distributed
/// over the context's threads.
pub fn assemble_cov_with(
    model: &CovarianceModel,
    t: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> Matrix {
    let n = t.len();
    let mut k = Matrix::zeros(n, n);
    let jobs = assembly_jobs(n, ctx);
    let bounds = weighted_bounds(0, n, jobs, |i| (n - i) as f64);
    for_row_chunks(k.as_mut_slice(), n, &bounds, ctx, |chunk, r0, r1| {
        let mut prep = model.kernel.prepare(theta);
        let diag = prep.value(0.0) + model.noise_variance();
        for i in r0..r1 {
            let row = &mut chunk[(i - r0) * n..(i - r0 + 1) * n];
            row[i] = diag;
            for j in (i + 1)..n {
                row[j] = prep.value(t[i] - t[j]);
            }
        }
    });
    k.mirror_upper_to_lower();
    k
}

/// Assemble `K̃` and all `∂K̃/∂ϑ_a` in one pass over the pairs
/// (the shared transcendental subexpressions are computed once), serial.
pub fn assemble_cov_grads(
    model: &CovarianceModel,
    t: &[f64],
    theta: &[f64],
) -> (Matrix, Vec<Matrix>) {
    assemble_cov_grads_with(model, t, theta, &ExecutionContext::seq())
}

/// Assemble `K̃` and all `∂K̃/∂ϑ_a`, row-tile parallel: each worker fills
/// its rows of the value matrix *and* of every derivative matrix from a
/// single pair sweep.
pub fn assemble_cov_grads_with(
    model: &CovarianceModel,
    t: &[f64],
    theta: &[f64],
    ctx: &ExecutionContext,
) -> (Matrix, Vec<Matrix>) {
    let n = t.len();
    let m = model.dim();
    let mut k = Matrix::zeros(n, n);
    let mut grads = vec![Matrix::zeros(n, n); m];
    let jobs = assembly_jobs(n, ctx);
    let bounds = weighted_bounds(0, n, jobs, |i| (n - i) as f64);
    {
        // the value matrix and every derivative matrix chunk along the
        // same row bounds, so one pair sweep fills all m+1 of them
        let mut buffers: Vec<(&mut [f64], usize)> = Vec::with_capacity(m + 1);
        buffers.push((k.as_mut_slice(), n));
        for g in grads.iter_mut() {
            buffers.push((g.as_mut_slice(), n));
        }
        for_row_chunks_multi(buffers, &bounds, ctx, |chunks, r0, r1| {
            let mut it = chunks.into_iter();
            let k_chunk = it.next().expect("value-matrix chunk");
            let mut g_chunk: Vec<&mut [f64]> = it.collect();
            let mut prep = model.kernel.prepare(theta);
            let mut g = vec![0.0; m];
            // diagonal: dt = 0, same for every row
            let vd = prep.value_grad(0.0, &mut g);
            let diag = vd + model.noise_variance();
            let g_diag = g.clone();
            // fill the upper-triangle rows with contiguous writes;
            // mirroring happens in a cache-blocked pass afterwards —
            // writing (j,i) inside the pair loop strides a full row
            // per store and collapses throughput ~8× at n ≈ 2000
            // (EXPERIMENTS.md §Perf).
            for i in r0..r1 {
                let base = (i - r0) * n;
                k_chunk[base + i] = diag;
                for (a, gm) in g_chunk.iter_mut().enumerate() {
                    gm[base + i] = g_diag[a];
                }
                for j in (i + 1)..n {
                    let v = prep.value_grad(t[i] - t[j], &mut g);
                    k_chunk[base + j] = v;
                    for (a, gm) in g_chunk.iter_mut().enumerate() {
                        gm[base + j] = g[a];
                    }
                }
            }
        });
    }
    k.mirror_upper_to_lower();
    for gmat in &mut grads {
        gmat.mirror_upper_to_lower();
    }
    (k, grads)
}

/// Maximum number of input dimensions the nd assembly supports (the
/// per-pair separation is built in a stack buffer of this size).
pub const MAX_INPUT_DIM: usize = 8;

#[inline]
fn noise_var_at(model: &CovarianceModel, noise: Option<&[f64]>, i: usize) -> f64 {
    match noise {
        Some(s) => s[i] * s[i],
        None => model.noise_variance(),
    }
}

/// Assemble `K̃ = k̃(x_i − x_j) + σ_n,i² δ_ij` from a d-column input
/// layout (`x[0]` is the time/first axis) with an optional per-point
/// noise vector (heteroscedastic diagonal, σ_f = 1 units).
///
/// On `d = 1` homoscedastic inputs this *delegates* to
/// [`assemble_cov_with`] — bit-identical to the pre-scenario path. The
/// d-dim sweep reuses the same weighted row-tile partition, so nd
/// matrices are likewise bit-identical across thread counts.
pub fn assemble_cov_nd_with(
    model: &CovarianceModel,
    x: &[&[f64]],
    noise: Option<&[f64]>,
    theta: &[f64],
    ctx: &ExecutionContext,
) -> Matrix {
    let d = x.len();
    if d == 1 && noise.is_none() {
        return assemble_cov_with(model, x[0], theta, ctx);
    }
    assert!(d >= 1 && d <= MAX_INPUT_DIM, "unsupported input dimension {d}");
    let n = x[0].len();
    assert!(x.iter().all(|c| c.len() == n), "ragged input columns");
    if let Some(s) = noise {
        assert_eq!(s.len(), n, "noise length mismatch");
    }
    let mut k = Matrix::zeros(n, n);
    let jobs = assembly_jobs(n, ctx);
    let bounds = weighted_bounds(0, n, jobs, |i| (n - i) as f64);
    for_row_chunks(k.as_mut_slice(), n, &bounds, ctx, |chunk, r0, r1| {
        let mut prep = model.kernel.prepare(theta);
        let zeros = [0.0; MAX_INPUT_DIM];
        let k0 = prep.value_nd(&zeros[..d]);
        let mut dx = [0.0; MAX_INPUT_DIM];
        for i in r0..r1 {
            let row = &mut chunk[(i - r0) * n..(i - r0 + 1) * n];
            row[i] = k0 + noise_var_at(model, noise, i);
            for j in (i + 1)..n {
                for (a, col) in x.iter().enumerate() {
                    dx[a] = col[i] - col[j];
                }
                row[j] = prep.value_nd(&dx[..d]);
            }
        }
    });
    k.mirror_upper_to_lower();
    k
}

/// Assemble `K̃` and all `∂K̃/∂ϑ_a` from a d-column input layout with an
/// optional per-point noise vector. `d = 1` homoscedastic delegates to
/// [`assemble_cov_grads_with`]. The noise is *not* learned, so the
/// derivative matrices carry no diagonal noise term — same contract as
/// the scalar σ_n path.
pub fn assemble_cov_grads_nd_with(
    model: &CovarianceModel,
    x: &[&[f64]],
    noise: Option<&[f64]>,
    theta: &[f64],
    ctx: &ExecutionContext,
) -> (Matrix, Vec<Matrix>) {
    let d = x.len();
    if d == 1 && noise.is_none() {
        return assemble_cov_grads_with(model, x[0], theta, ctx);
    }
    assert!(d >= 1 && d <= MAX_INPUT_DIM, "unsupported input dimension {d}");
    let n = x[0].len();
    assert!(x.iter().all(|c| c.len() == n), "ragged input columns");
    if let Some(s) = noise {
        assert_eq!(s.len(), n, "noise length mismatch");
    }
    let m = model.dim();
    let mut k = Matrix::zeros(n, n);
    let mut grads = vec![Matrix::zeros(n, n); m];
    let jobs = assembly_jobs(n, ctx);
    let bounds = weighted_bounds(0, n, jobs, |i| (n - i) as f64);
    {
        let mut buffers: Vec<(&mut [f64], usize)> = Vec::with_capacity(m + 1);
        buffers.push((k.as_mut_slice(), n));
        for g in grads.iter_mut() {
            buffers.push((g.as_mut_slice(), n));
        }
        for_row_chunks_multi(buffers, &bounds, ctx, |chunks, r0, r1| {
            let mut it = chunks.into_iter();
            let k_chunk = it.next().expect("value-matrix chunk");
            let mut g_chunk: Vec<&mut [f64]> = it.collect();
            let mut prep = model.kernel.prepare(theta);
            let mut g = vec![0.0; m];
            let zeros = [0.0; MAX_INPUT_DIM];
            let k0 = prep.value_grad_nd(&zeros[..d], &mut g);
            let g_diag = g.clone();
            let mut dx = [0.0; MAX_INPUT_DIM];
            for i in r0..r1 {
                let base = (i - r0) * n;
                k_chunk[base + i] = k0 + noise_var_at(model, noise, i);
                for (a, gm) in g_chunk.iter_mut().enumerate() {
                    gm[base + i] = g_diag[a];
                }
                for j in (i + 1)..n {
                    for (a, col) in x.iter().enumerate() {
                        dx[a] = col[i] - col[j];
                    }
                    let v = prep.value_grad_nd(&dx[..d], &mut g);
                    k_chunk[base + j] = v;
                    for (a, gm) in g_chunk.iter_mut().enumerate() {
                        gm[base + j] = g[a];
                    }
                }
            }
        });
    }
    k.mirror_upper_to_lower();
    for gmat in &mut grads {
        gmat.mirror_upper_to_lower();
    }
    (k, grads)
}

/// Hessian pair-contractions (see [`hessian_contractions_with`]) from a
/// d-column input layout. The diagonal noise never enters `∂²K̃`, so no
/// noise argument is needed; `d = 1` delegates to the scalar sweep.
pub fn hessian_contractions_nd_with(
    model: &CovarianceModel,
    x: &[&[f64]],
    theta: &[f64],
    alpha: &[f64],
    w: &Matrix,
    ctx: &ExecutionContext,
) -> (Matrix, Matrix) {
    let d = x.len();
    if d == 1 {
        return hessian_contractions_with(model, x[0], theta, alpha, w, ctx);
    }
    assert!(d >= 1 && d <= MAX_INPUT_DIM, "unsupported input dimension {d}");
    let n = x[0].len();
    assert!(x.iter().all(|c| c.len() == n), "ragged input columns");
    let m = model.dim();
    assert_eq!(alpha.len(), n);
    assert_eq!((w.rows(), w.cols()), (n, n));
    let mut a_c = Matrix::zeros(m, m);
    let mut b_c = Matrix::zeros(m, m);
    {
        let mut prep = model.kernel.prepare(theta);
        let mut g = vec![0.0; m];
        let mut h = vec![0.0; m * m];
        let zeros = [0.0; MAX_INPUT_DIM];
        prep.value_grad_hess_nd(&zeros[..d], &mut g, &mut h);
        let diag_alpha: f64 = alpha.iter().map(|x| x * x).sum();
        let diag_w: f64 = (0..n).map(|i| w[(i, i)]).sum();
        for a in 0..m {
            for b in 0..m {
                a_c[(a, b)] += diag_alpha * h[a * m + b];
                b_c[(a, b)] += diag_w * h[a * m + b];
            }
        }
    }
    let jobs = assembly_jobs(n, ctx);
    let bounds = weighted_bounds(0, n, jobs, |i| (n - i) as f64);
    let n_chunks = bounds.len() - 1;
    let mut partials: Vec<(Vec<f64>, Vec<f64>)> =
        (0..n_chunks).map(|_| (vec![0.0; m * m], vec![0.0; m * m])).collect();
    let mut job_fns = Vec::with_capacity(n_chunks);
    for (slot, wnd) in partials.iter_mut().zip(bounds.windows(2)) {
        let (r0, r1) = (wnd[0], wnd[1]);
        job_fns.push(move || {
            let (a_part, b_part) = slot;
            let mut prep = model.kernel.prepare(theta);
            let mut g = vec![0.0; m];
            let mut h = vec![0.0; m * m];
            let mut dx = [0.0; MAX_INPUT_DIM];
            for i in r0..r1 {
                for j in (i + 1)..n {
                    for (a, col) in x.iter().enumerate() {
                        dx[a] = col[i] - col[j];
                    }
                    prep.value_grad_hess_nd(&dx[..d], &mut g, &mut h);
                    let wa = 2.0 * alpha[i] * alpha[j];
                    let ww = 2.0 * w[(i, j)];
                    for a in 0..m {
                        for b in a..m {
                            let hv = h[a * m + b];
                            a_part[a * m + b] += wa * hv;
                            b_part[a * m + b] += ww * hv;
                        }
                    }
                }
            }
        });
    }
    ctx.run_jobs(job_fns);
    for (a_part, b_part) in &partials {
        for a in 0..m {
            for b in a..m {
                a_c[(a, b)] += a_part[a * m + b];
                b_c[(a, b)] += b_part[a * m + b];
            }
        }
    }
    for a in 0..m {
        for b in 0..a {
            a_c[(a, b)] = a_c[(b, a)];
            b_c[(a, b)] = b_c[(b, a)];
        }
    }
    (a_c, b_c)
}

/// Stream the per-pair kernel Hessians `∂²k̃/∂ϑ_a∂ϑ_b (t_i − t_j)` into the
/// two contractions the profiled Hessian (eq. 2.19) needs (serial):
///
/// * `A_ab = αᵀ (∂²K̃/∂ϑ_a∂ϑ_b) α`
/// * `B_ab = Tr(W · ∂²K̃/∂ϑ_a∂ϑ_b)`
///
/// where `α = K̃⁻¹y` and `W = K̃⁻¹`. Memory: `O(m²)`, never `O(n² m²)`.
pub fn hessian_contractions(
    model: &CovarianceModel,
    t: &[f64],
    theta: &[f64],
    alpha: &[f64],
    w: &Matrix,
) -> (Matrix, Matrix) {
    hessian_contractions_with(model, t, theta, alpha, w, &ExecutionContext::seq())
}

/// Hessian contractions with the pair sweep partitioned over row tiles;
/// each worker accumulates private `m×m` partials which are folded in
/// tile order (per-thread-count deterministic, equal to serial to
/// rounding).
///
/// Deliberately **not** ported to the `linalg::micro` GEMM engine: the
/// sweep is transcendental-bound, not FLOP-bound. Each of the n(n+1)/2
/// pairs evaluates `value_grad_hess` — sin/cos/exp chains for every
/// periodic factor — and those dominate the `O(m²)` multiply-adds per
/// pair by an order of magnitude, so a register-tiled contraction would
/// shave only the minority term while forcing the `m²` derivative
/// matrices to be materialised at `O(n² m²)` memory. The thread-level
/// row-tile split above is the right (and sufficient) lever.
pub fn hessian_contractions_with(
    model: &CovarianceModel,
    t: &[f64],
    theta: &[f64],
    alpha: &[f64],
    w: &Matrix,
    ctx: &ExecutionContext,
) -> (Matrix, Matrix) {
    let n = t.len();
    let m = model.dim();
    assert_eq!(alpha.len(), n);
    assert_eq!((w.rows(), w.cols()), (n, n));
    let mut a_c = Matrix::zeros(m, m);
    let mut b_c = Matrix::zeros(m, m);
    // diagonal pairs (dt = 0): weight 1 each
    {
        let mut prep = model.kernel.prepare(theta);
        let mut g = vec![0.0; m];
        let mut h = vec![0.0; m * m];
        prep.value_grad_hess(0.0, &mut g, &mut h);
        let diag_alpha: f64 = alpha.iter().map(|x| x * x).sum();
        let diag_w: f64 = (0..n).map(|i| w[(i, i)]).sum();
        for a in 0..m {
            for b in 0..m {
                a_c[(a, b)] += diag_alpha * h[a * m + b];
                b_c[(a, b)] += diag_w * h[a * m + b];
            }
        }
    }
    // off-diagonal pairs: weight 2 (symmetry), row tiles in parallel
    let jobs = assembly_jobs(n, ctx);
    let bounds = weighted_bounds(0, n, jobs, |i| (n - i) as f64);
    let n_chunks = bounds.len() - 1;
    let mut partials: Vec<(Vec<f64>, Vec<f64>)> =
        (0..n_chunks).map(|_| (vec![0.0; m * m], vec![0.0; m * m])).collect();
    let mut job_fns = Vec::with_capacity(n_chunks);
    for (slot, wnd) in partials.iter_mut().zip(bounds.windows(2)) {
        let (r0, r1) = (wnd[0], wnd[1]);
        job_fns.push(move || {
            let (a_part, b_part) = slot;
            let mut prep = model.kernel.prepare(theta);
            let mut g = vec![0.0; m];
            let mut h = vec![0.0; m * m];
            for i in r0..r1 {
                for j in (i + 1)..n {
                    prep.value_grad_hess(t[i] - t[j], &mut g, &mut h);
                    let wa = 2.0 * alpha[i] * alpha[j];
                    let ww = 2.0 * w[(i, j)];
                    for a in 0..m {
                        for b in a..m {
                            let hv = h[a * m + b];
                            a_part[a * m + b] += wa * hv;
                            b_part[a * m + b] += ww * hv;
                        }
                    }
                }
            }
        });
    }
    ctx.run_jobs(job_fns);
    for (a_part, b_part) in &partials {
        for a in 0..m {
            for b in a..m {
                a_c[(a, b)] += a_part[a * m + b];
                b_c[(a, b)] += b_part[a * m + b];
            }
        }
    }
    // mirror the upper triangles
    for a in 0..m {
        for b in 0..a {
            a_c[(a, b)] = a_c[(b, a)];
            b_c[(a, b)] = b_c[(b, a)];
        }
    }
    (a_c, b_c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{paper_k1, PaperK1};
    use crate::linalg::Chol;

    fn grid(n: usize) -> Vec<f64> {
        (0..n).map(|i| 1.0 + i as f64).collect()
    }

    #[test]
    fn cov_is_symmetric_with_noise_diag() {
        let model = paper_k1(0.1);
        let t = grid(40);
        let k = assemble_cov(&model, &t, &PaperK1::truth());
        for i in 0..40 {
            for j in 0..40 {
                assert_eq!(k[(i, j)], k[(j, i)]);
            }
        }
        // diagonal = k(0) + σn² = 1 + 0.01
        assert!((k[(0, 0)] - 1.01).abs() < 1e-12);
    }

    #[test]
    fn cov_is_positive_definite_at_truth() {
        let model = paper_k1(0.1);
        let t = grid(60);
        let k = assemble_cov(&model, &t, &PaperK1::truth());
        assert!(Chol::factor(&k).is_ok());
    }

    #[test]
    fn parallel_assembly_is_bit_identical() {
        let model = paper_k1(0.1);
        // straddle the PAR_MIN_N dispatch cutoff
        for n in [40usize, 63, 64, 65, 130] {
            let t = grid(n);
            let theta = PaperK1::truth();
            let k_s = assemble_cov(&model, &t, &theta);
            let (kg_s, g_s) = assemble_cov_grads(&model, &t, &theta);
            for threads in [2usize, 4] {
                let ctx = ExecutionContext::new(threads);
                let k_p = assemble_cov_with(&model, &t, &theta, &ctx);
                assert_eq!(k_p.max_abs_diff(&k_s), 0.0, "n={n} threads={threads}");
                let (kg_p, g_p) = assemble_cov_grads_with(&model, &t, &theta, &ctx);
                assert_eq!(kg_p.max_abs_diff(&kg_s), 0.0);
                for (a, (gp, gs)) in g_p.iter().zip(&g_s).enumerate() {
                    assert_eq!(gp.max_abs_diff(gs), 0.0, "n={n} grad[{a}]");
                }
            }
        }
    }

    #[test]
    fn grads_match_fd_of_cov() {
        let model = paper_k1(0.1);
        let t = grid(12);
        let theta = PaperK1::truth();
        let (_, grads) = assemble_cov_grads(&model, &t, &theta);
        for a in 0..3 {
            let h = 1e-6;
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let kp = assemble_cov(&model, &t, &tp);
            let km = assemble_cov(&model, &t, &tm);
            for i in 0..12 {
                for j in 0..12 {
                    let fd = (kp[(i, j)] - km[(i, j)]) / (2.0 * h);
                    assert!(
                        (grads[a][(i, j)] - fd).abs() < 1e-6 * fd.abs().max(1e-4),
                        "a={a} ({i},{j}): {} vs {fd}",
                        grads[a][(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn hessian_contractions_match_dense_reference() {
        // brute-force reference: assemble all ∂²K matrices by FD of grads,
        // contract densely, compare.
        let model = paper_k1(0.1);
        let t = grid(10);
        let theta = PaperK1::truth();
        let n = t.len();
        let m = 3;
        let alpha: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut w = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                w[(i, j)] = 1.0 / (1.0 + (i as f64 - j as f64).abs());
            }
        }
        let (a_c, b_c) = hessian_contractions(&model, &t, &theta, &alpha, &w);
        // dense reference via per-pair kernel hessian
        let mut prep = model.kernel.prepare(&theta);
        let mut g = vec![0.0; m];
        let mut hbuf = vec![0.0; m * m];
        let mut a_ref = Matrix::zeros(m, m);
        let mut b_ref = Matrix::zeros(m, m);
        for i in 0..n {
            for j in 0..n {
                prep.value_grad_hess(t[i] - t[j], &mut g, &mut hbuf);
                for a in 0..m {
                    for b in 0..m {
                        a_ref[(a, b)] += alpha[i] * alpha[j] * hbuf[a * m + b];
                        b_ref[(a, b)] += w[(i, j)] * hbuf[a * m + b];
                    }
                }
            }
        }
        assert!(a_c.max_abs_diff(&a_ref) < 1e-10, "A: {}", a_c.max_abs_diff(&a_ref));
        assert!(b_c.max_abs_diff(&b_ref) < 1e-10, "B: {}", b_c.max_abs_diff(&b_ref));
    }

    #[test]
    fn nd_assembly_d1_constant_noise_matches_scalar_bitwise() {
        // per-point noise vector filled with the model's σ_n must give
        // exactly the scalar-path matrix (same float ops on the diagonal)
        let model = paper_k1(0.1);
        let t = grid(50);
        let theta = PaperK1::truth();
        let noise = vec![0.1; t.len()];
        let ctx = ExecutionContext::seq();
        let k_s = assemble_cov(&model, &t, &theta);
        let k_nd = assemble_cov_nd_with(&model, &[&t], Some(&noise), &theta, &ctx);
        assert_eq!(k_nd.max_abs_diff(&k_s), 0.0);
        let (kg_s, g_s) = assemble_cov_grads(&model, &t, &theta);
        let (kg_nd, g_nd) = assemble_cov_grads_nd_with(&model, &[&t], Some(&noise), &theta, &ctx);
        assert_eq!(kg_nd.max_abs_diff(&kg_s), 0.0);
        for (gp, gs) in g_nd.iter().zip(&g_s) {
            assert_eq!(gp.max_abs_diff(gs), 0.0);
        }
    }

    #[test]
    fn nd_parallel_assembly_is_bit_identical() {
        use crate::kernels::{ArdKernel, CovarianceModel};
        let model = CovarianceModel::new("se-ard3", Box::new(ArdKernel::se(3)), 0.1);
        for n in [40usize, 90] {
            let cols: Vec<Vec<f64>> = (0..3)
                .map(|a| (0..n).map(|i| ((i * 7 + a * 3) % 23) as f64 * 0.31 + i as f64 * 0.01).collect())
                .collect();
            let x: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
            let noise: Vec<f64> = (0..n).map(|i| 0.05 + 0.001 * i as f64).collect();
            let theta = [0.3, 0.0, -0.3];
            let seq = ExecutionContext::seq();
            let k_s = assemble_cov_nd_with(&model, &x, Some(&noise), &theta, &seq);
            let (kg_s, g_s) = assemble_cov_grads_nd_with(&model, &x, Some(&noise), &theta, &seq);
            assert_eq!(k_s.max_abs_diff(&kg_s), 0.0, "value matrix differs between entry points");
            for threads in [2usize, 4] {
                let ctx = ExecutionContext::new(threads);
                let k_p = assemble_cov_nd_with(&model, &x, Some(&noise), &theta, &ctx);
                assert_eq!(k_p.max_abs_diff(&k_s), 0.0, "n={n} threads={threads}");
                let (kg_p, g_p) = assemble_cov_grads_nd_with(&model, &x, Some(&noise), &theta, &ctx);
                assert_eq!(kg_p.max_abs_diff(&kg_s), 0.0);
                for (a, (gp, gs)) in g_p.iter().zip(&g_s).enumerate() {
                    assert_eq!(gp.max_abs_diff(gs), 0.0, "n={n} grad[{a}]");
                }
            }
        }
    }

    #[test]
    fn nd_grads_match_fd_and_heteroscedastic_diagonal() {
        use crate::kernels::{ArdKernel, CovarianceModel};
        let model = CovarianceModel::new("m52-ard2", Box::new(ArdKernel::m52(2)), 0.2);
        let n = 12;
        let c0: Vec<f64> = (0..n).map(|i| i as f64 * 0.9).collect();
        let c1: Vec<f64> = (0..n).map(|i| ((i * 5) % 7) as f64 * 0.6).collect();
        let x: Vec<&[f64]> = vec![&c0, &c1];
        let noise: Vec<f64> = (0..n).map(|i| 0.1 + 0.02 * i as f64).collect();
        let theta = [0.2, -0.1];
        let ctx = ExecutionContext::seq();
        let (k, grads) = assemble_cov_grads_nd_with(&model, &x, Some(&noise), &theta, &ctx);
        for i in 0..n {
            let expect = 1.0 + noise[i] * noise[i]; // k(0) = 1 for ARD Matérn
            assert!((k[(i, i)] - expect).abs() < 1e-14, "diag[{i}]");
        }
        for a in 0..2 {
            let h = 1e-6;
            let mut tp = theta;
            let mut tm = theta;
            tp[a] += h;
            tm[a] -= h;
            let kp = assemble_cov_nd_with(&model, &x, Some(&noise), &tp, &ctx);
            let km = assemble_cov_nd_with(&model, &x, Some(&noise), &tm, &ctx);
            for i in 0..n {
                for j in 0..n {
                    let fd = (kp[(i, j)] - km[(i, j)]) / (2.0 * h);
                    assert!(
                        (grads[a][(i, j)] - fd).abs() < 1e-6 * fd.abs().max(1e-4),
                        "a={a} ({i},{j}): {} vs {fd}",
                        grads[a][(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_contractions_match_serial_to_rounding() {
        let model = paper_k1(0.1);
        let n = 90;
        let t = grid(n);
        let theta = PaperK1::truth();
        let alpha: Vec<f64> = (0..n).map(|i| (i as f64 * 0.51).cos()).collect();
        let mut w = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                w[(i, j)] = 1.0 / (1.0 + (i as f64 - j as f64).abs());
            }
        }
        let (a_s, b_s) = hessian_contractions(&model, &t, &theta, &alpha, &w);
        for threads in [2usize, 4] {
            let ctx = ExecutionContext::new(threads);
            let (a_p, b_p) = hessian_contractions_with(&model, &t, &theta, &alpha, &w, &ctx);
            let scale = a_s.fro_norm().max(1.0);
            assert!(a_p.max_abs_diff(&a_s) < 1e-12 * scale, "A threads={threads}");
            let scale = b_s.fro_norm().max(1.0);
            assert!(b_p.max_abs_diff(&b_s) < 1e-12 * scale, "B threads={threads}");
        }
    }
}
