//! Covariance-matrix and derivative-matrix assembly.
//!
//! The `O(n² m)` matrix-entry computation is the paper's device-offloaded
//! hot spot (their released code does it on a GPU; our L1 Pallas kernel
//! does it on the accelerator via the [`crate::runtime::XlaBackend`]).
//! This module is the **native** implementation: it exploits symmetry
//! (upper triangle computed, mirrored) and streams per-pair kernel
//! Hessians into `m×m` contractions so second-derivative matrices are
//! never materialised.

use crate::kernels::CovarianceModel;
use crate::linalg::Matrix;

/// Assemble `K̃ = k̃(t_i − t_j) + σ_n² δ_ij` (σ_f = 1 units).
pub fn assemble_cov(model: &CovarianceModel, t: &[f64], theta: &[f64]) -> Matrix {
    let n = t.len();
    let mut prep = model.kernel.prepare(theta);
    let mut k = Matrix::zeros(n, n);
    let diag = prep.value(0.0) + model.noise_variance();
    for i in 0..n {
        k[(i, i)] = diag;
        for j in (i + 1)..n {
            k[(i, j)] = prep.value(t[i] - t[j]);
        }
    }
    mirror_upper(&mut k);
    k
}

/// Assemble `K̃` and all `∂K̃/∂ϑ_a` in one pass over the pairs
/// (the shared transcendental subexpressions are computed once).
pub fn assemble_cov_grads(
    model: &CovarianceModel,
    t: &[f64],
    theta: &[f64],
) -> (Matrix, Vec<Matrix>) {
    let n = t.len();
    let m = model.dim();
    let mut prep = model.kernel.prepare(theta);
    let mut k = Matrix::zeros(n, n);
    let mut grads = vec![Matrix::zeros(n, n); m];
    let mut g = vec![0.0; m];
    // diagonal: dt = 0
    let vd = prep.value_grad(0.0, &mut g);
    for i in 0..n {
        k[(i, i)] = vd + model.noise_variance();
        for (a, ga) in g.iter().enumerate() {
            grads[a][(i, i)] = *ga;
        }
    }
    // fill the upper triangles with contiguous row writes, then mirror in
    // a cache-blocked pass — writing (j,i) inside the pair loop strides a
    // full row per store and collapses throughput ~8× at n ≈ 2000
    // (EXPERIMENTS.md §Perf).
    for i in 0..n {
        for j in (i + 1)..n {
            let v = prep.value_grad(t[i] - t[j], &mut g);
            k[(i, j)] = v;
            for (a, ga) in g.iter().enumerate() {
                grads[a][(i, j)] = *ga;
            }
        }
    }
    mirror_upper(&mut k);
    for gmat in &mut grads {
        mirror_upper(gmat);
    }
    (k, grads)
}

/// Copy the strict upper triangle onto the lower one, in `B×B` blocks so
/// both source rows and destination rows stay cache-resident.
pub(crate) fn mirror_upper(m: &mut Matrix) {
    const B: usize = 64;
    let n = m.rows();
    let data = m.as_mut_slice();
    let mut bi = 0;
    while bi < n {
        let i_end = (bi + B).min(n);
        let mut bj = bi;
        while bj < n {
            let j_end = (bj + B).min(n);
            for i in bi..i_end {
                let j0 = bj.max(i + 1);
                for j in j0..j_end {
                    data[j * n + i] = data[i * n + j];
                }
            }
            bj += B;
        }
        bi += B;
    }
}

/// Stream the per-pair kernel Hessians `∂²k̃/∂ϑ_a∂ϑ_b (t_i − t_j)` into the
/// two contractions the profiled Hessian (eq. 2.19) needs:
///
/// * `A_ab = αᵀ (∂²K̃/∂ϑ_a∂ϑ_b) α`
/// * `B_ab = Tr(W · ∂²K̃/∂ϑ_a∂ϑ_b)`
///
/// where `α = K̃⁻¹y` and `W = K̃⁻¹`. Memory: `O(m²)`, never `O(n² m²)`.
pub fn hessian_contractions(
    model: &CovarianceModel,
    t: &[f64],
    theta: &[f64],
    alpha: &[f64],
    w: &Matrix,
) -> (Matrix, Matrix) {
    let n = t.len();
    let m = model.dim();
    assert_eq!(alpha.len(), n);
    assert_eq!((w.rows(), w.cols()), (n, n));
    let mut prep = model.kernel.prepare(theta);
    let mut g = vec![0.0; m];
    let mut h = vec![0.0; m * m];
    let mut a_c = Matrix::zeros(m, m);
    let mut b_c = Matrix::zeros(m, m);
    // diagonal pairs (dt = 0): weight 1 each
    prep.value_grad_hess(0.0, &mut g, &mut h);
    let diag_alpha: f64 = alpha.iter().map(|x| x * x).sum();
    let diag_w: f64 = (0..n).map(|i| w[(i, i)]).sum();
    for a in 0..m {
        for b in 0..m {
            a_c[(a, b)] += diag_alpha * h[a * m + b];
            b_c[(a, b)] += diag_w * h[a * m + b];
        }
    }
    // off-diagonal pairs: weight 2 (symmetry)
    for i in 0..n {
        for j in (i + 1)..n {
            prep.value_grad_hess(t[i] - t[j], &mut g, &mut h);
            let wa = 2.0 * alpha[i] * alpha[j];
            let ww = 2.0 * w[(i, j)];
            for a in 0..m {
                for b in a..m {
                    let hv = h[a * m + b];
                    a_c[(a, b)] += wa * hv;
                    b_c[(a, b)] += ww * hv;
                }
            }
        }
    }
    // mirror the upper triangles
    for a in 0..m {
        for b in 0..a {
            a_c[(a, b)] = a_c[(b, a)];
            b_c[(a, b)] = b_c[(b, a)];
        }
    }
    (a_c, b_c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{paper_k1, PaperK1};
    use crate::linalg::Chol;

    fn grid(n: usize) -> Vec<f64> {
        (0..n).map(|i| 1.0 + i as f64).collect()
    }

    #[test]
    fn cov_is_symmetric_with_noise_diag() {
        let model = paper_k1(0.1);
        let t = grid(40);
        let k = assemble_cov(&model, &t, &PaperK1::truth());
        for i in 0..40 {
            for j in 0..40 {
                assert_eq!(k[(i, j)], k[(j, i)]);
            }
        }
        // diagonal = k(0) + σn² = 1 + 0.01
        assert!((k[(0, 0)] - 1.01).abs() < 1e-12);
    }

    #[test]
    fn cov_is_positive_definite_at_truth() {
        let model = paper_k1(0.1);
        let t = grid(60);
        let k = assemble_cov(&model, &t, &PaperK1::truth());
        assert!(Chol::factor(&k).is_ok());
    }

    #[test]
    fn grads_match_fd_of_cov() {
        let model = paper_k1(0.1);
        let t = grid(12);
        let theta = PaperK1::truth();
        let (_, grads) = assemble_cov_grads(&model, &t, &theta);
        for a in 0..3 {
            let h = 1e-6;
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[a] += h;
            tm[a] -= h;
            let kp = assemble_cov(&model, &t, &tp);
            let km = assemble_cov(&model, &t, &tm);
            for i in 0..12 {
                for j in 0..12 {
                    let fd = (kp[(i, j)] - km[(i, j)]) / (2.0 * h);
                    assert!(
                        (grads[a][(i, j)] - fd).abs() < 1e-6 * fd.abs().max(1e-4),
                        "a={a} ({i},{j}): {} vs {fd}",
                        grads[a][(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn hessian_contractions_match_dense_reference() {
        // brute-force reference: assemble all ∂²K matrices by FD of grads,
        // contract densely, compare.
        let model = paper_k1(0.1);
        let t = grid(10);
        let theta = PaperK1::truth();
        let n = t.len();
        let m = 3;
        let alpha: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut w = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                w[(i, j)] = 1.0 / (1.0 + (i as f64 - j as f64).abs());
            }
        }
        let (a_c, b_c) = hessian_contractions(&model, &t, &theta, &alpha, &w);
        // dense reference via per-pair kernel hessian
        let mut prep = model.kernel.prepare(&theta);
        let mut g = vec![0.0; m];
        let mut hbuf = vec![0.0; m * m];
        let mut a_ref = Matrix::zeros(m, m);
        let mut b_ref = Matrix::zeros(m, m);
        for i in 0..n {
            for j in 0..n {
                prep.value_grad_hess(t[i] - t[j], &mut g, &mut hbuf);
                for a in 0..m {
                    for b in 0..m {
                        a_ref[(a, b)] += alpha[i] * alpha[j] * hbuf[a * m + b];
                        b_ref[(a, b)] += w[(i, j)] * hbuf[a * m + b];
                    }
                }
            }
        }
        assert!(a_c.max_abs_diff(&a_ref) < 1e-10, "A: {}", a_c.max_abs_diff(&a_ref));
        assert!(b_c.max_abs_diff(&b_ref) < 1e-10, "B: {}", b_c.max_abs_diff(&b_ref));
    }
}
