//! `gpfast` — command-line driver for the GP fast-training system.
//!
//! Subcommands:
//!
//! * `compare`  — train every configured model on a dataset and rank by
//!   Laplace hyperevidence (optionally verifying with nested sampling);
//!   the paper's Table-1 workflow.
//! * `train`    — train a single model, print θ̂ ± σ and ln P peak.
//! * `nested`   — run only the nested-sampling baseline.
//! * `synth`    — emit a synthetic Table-1 dataset as CSV.
//! * `tidal`    — emit the simulated Woods-Hole tidal series as CSV.
//! * `realise`  — draw GP realisations (Fig. 1) as CSV.
//! * `predict`  — train then interpolate onto a finer grid (Fig. 3).
//! * `fleet`    — multi-tenant serving demo: train once, seed a
//!   disk-backed artifact store with many cold sessions, drive
//!   Zipf-distributed predict traffic through the LRU fleet, and persist
//!   a mutated session back on shutdown.
//! * `info`     — backend/artifact status.
//!
//! Common flags: `--config <toml>`, `--backend native|xla|auto`,
//! `--seed N`, `--data <csv>`, `--out <path>`.

use std::path::{Path, PathBuf};

use gpfast::config::RunConfig;
use gpfast::coordinator::{train_model, ModelSpec, Tournament};
use gpfast::data::{csv, synthetic, tidal, Dataset};
use gpfast::nested::{nested_sample, NestedOptions};
use gpfast::priors::{BoxPrior, ScalePrior};
use gpfast::rng::Xoshiro256;
use gpfast::runtime::select_backend;
use gpfast::util::{Args, Stopwatch};

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> gpfast::Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    // CLI overrides
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(m) = args.get("models") {
        cfg.models = m.split(',').map(String::from).collect();
    }
    cfg.sigma_n = args.get_f64("sigma-n", cfg.sigma_n)?;
    cfg.restarts = args.get_usize("restarts", cfg.restarts)?;
    if args.flag("nested") {
        cfg.run_nested = true;
    }

    match args.command.as_deref() {
        Some("compare") => cmd_compare(args, &cfg),
        Some("train") => cmd_train(args, &cfg),
        Some("serve") => cmd_serve(args, &cfg),
        Some("nested") => cmd_nested(args, &cfg),
        Some("synth") => cmd_synth(args, &cfg),
        Some("tidal") => cmd_tidal(args, &cfg),
        Some("realise") => cmd_realise(args, &cfg),
        Some("predict") => cmd_predict(args, &cfg),
        Some("fleet") => cmd_fleet(args, &cfg),
        Some("info") => cmd_info(args, &cfg),
        Some(other) => anyhow::bail!(
            "unknown subcommand '{other}' (try: compare, train, serve, fleet, nested, synth, tidal, realise, predict, info)"
        ),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "gpfast — fast GP training (Moore et al., RSOS 2016 reproduction)

usage: gpfast <compare|train|serve|fleet|nested|synth|tidal|realise|predict|info> [flags]

flags:
  --config <file.toml>     load run configuration
  --data <file.csv>        dataset (else synthetic --n points)
  --n <N>                  synthetic dataset size [100]
  --models k1,k2,…         roster (k1|k2|k3|wendland-se|wendland-m32|wendland-m52|sod-k2|fitc-k2)
  --model k2               single model (train/nested)
  --backend native|xla|auto
  --restarts <N>           multistart restarts [10]
  --nested                 verify compare with nested sampling
  --seed <N>               RNG seed
  --out <path>             output file (csv/json)
  --save-model <path>      train: persist the TrainedModel artifact
  --load-model <p1[,p2…]>  serve: restart from persisted artifacts (O(n²))
  --route winner|averaged  serve: routing policy [winner]
  --n-star <N>             serve: prediction grid size [256]
  --sessions <N>           fleet: cold sessions to seed [64]
  --capacity <N>           fleet: LRU capacity (hot sessions) [8]
  --requests <N>           fleet: Zipf predict requests to drive [512]
  --store <dir>            fleet: artifact store directory [tmp]
  --artifact-version 3|4   fleet: artifact write-back format [3]
  --compress-tol <tol>     fleet: v4 spectral factor compression, tol in [0,1)";

/// Load `--data` CSV, else synthesise a Table-1 dataset of `--n` points.
fn load_dataset(args: &Args, cfg: &RunConfig) -> gpfast::Result<Dataset> {
    match args.get("data") {
        Some(path) => csv::read_dataset(Path::new(path)),
        None => {
            let n = args.get_usize("n", 100)?;
            Ok(synthetic::table1_dataset(n, cfg.sigma_n, cfg.seed))
        }
    }
}

fn cmd_compare(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let data = load_dataset(args, cfg)?;
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let tournament = Tournament::new(cfg.pipeline()?);
    let sw = Stopwatch::start();
    let result = tournament.run(&data, &mut rng)?;
    print!("{}", result.report.render());
    if result.models.len() >= 2 {
        println!(
            "serving: router would serve '{}' (evidence winner)",
            result.winner().name()
        );
    }
    println!("total wall time: {:.2} s", sw.elapsed_secs());
    if let Some(out) = args.get("out") {
        std::fs::write(out, result.report.to_json().pretty())?;
        println!("report written to {out}");
    }
    Ok(())
}

fn cmd_train(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let data = load_dataset(args, cfg)?;
    let spec = ModelSpec::parse(&args.get_or("model", "k2"))?;
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let pipe = cfg.pipeline()?;
    let restarts = pipe.train.multistart.restarts;
    let sw = Stopwatch::start();
    // a tournament-of-one: same multistart, same RNG stream, and the
    // TrainedModel artifact carries the evidence alongside the peak
    let result = Tournament::single(spec, pipe).run(&data, &mut rng)?;
    let tm = result.winner();
    let (res, ev) = (&tm.train, &tm.evidence);
    println!("model {} on {} (n = {})", tm.name(), data.label, data.len());
    for ((name, th), sg) in tm.param_names.iter().zip(&res.theta_hat).zip(&ev.sigma) {
        println!("  {name:8} = {th:9.4} ± {sg:.4}");
    }
    println!("  sigma_f  = {:9.4}", res.sigma_f_hat2.sqrt());
    println!("  lnP_peak = {:9.3}", res.lnp_peak);
    println!("  lnZ_est  = {:9.3}{}", ev.ln_z, if ev.suspect { "  (SUSPECT)" } else { "" });
    println!(
        "  evals    = {} across {} restarts ({} modes)",
        res.n_evals, restarts, res.n_modes
    );
    println!("  wall     = {:.2} s", sw.elapsed_secs());
    if let Some(path) = args.get("save-model") {
        tm.save(Path::new(path), &data)?;
        println!("  artifact = {path} (serve it with: gpfast serve --load-model {path})");
    }
    Ok(())
}

/// Restart serving from persisted artifacts: every factor comes back
/// bit-identically from disk in `O(n²)`, so the session reaches its
/// first prediction with **zero** profiled-likelihood evaluations — the
/// counter delta is printed (and asserted in `rust/tests/persistence.rs`).
fn cmd_serve(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let spec_list = args.get("load-model").ok_or_else(|| {
        anyhow::anyhow!("serve requires --load-model <artifact[,artifact…]> (see: train --save-model)")
    })?;
    let paths: Vec<PathBuf> =
        spec_list.split(',').filter(|s| !s.is_empty()).map(PathBuf::from).collect();
    let evals_before = gpfast::gp::profiled_eval_count();
    let sw = Stopwatch::start();
    let mut session = gpfast::coordinator::ServeSession::from_artifacts(&paths, cfg.exec())?
        .with_cond_limit(cfg.cond_limit());
    if let Some(policy) = cfg.window_policy() {
        session = session.with_window(policy);
    }
    match args.get("route").unwrap_or("winner") {
        "winner" => {}
        "averaged" => session = session.with_route(gpfast::coordinator::RouteMode::Averaged),
        other => anyhow::bail!("--route expects winner|averaged, got '{other}'"),
    }
    let n = session.stats().n_train;
    println!("serving {} model(s) restored from disk (n = {n}):", session.n_models());
    for ((name, w), h) in
        session.model_names().iter().zip(session.weights()).zip(session.health())
    {
        println!(
            "  {name:14} posterior weight {w:.4}  cond ~{:.1e}  jitter {:.1e}{}{}",
            h.cond_est,
            h.jitter,
            if h.degraded { "  DEGRADED" } else { "" },
            if h.quarantined { "  QUARANTINED" } else { "" },
        );
    }
    if let Some(policy) = session.window() {
        println!(
            "  window: max {} points, cold refresh every {} evictions",
            policy.max_points, policy.refresh_every
        );
    }
    // first prediction: a grid over the restored training span (the
    // artifact loader guarantees a non-empty dataset)
    let n_star = args.get_usize("n-star", 256)?;
    let t = session.predictor().t();
    anyhow::ensure!(!t.is_empty(), "restored session has no training points");
    let (t0, t1) = (t[0], *t.last().unwrap());
    let t_star: Vec<f64> = (0..n_star)
        .map(|i| t0 + (t1 - t0) * i as f64 / (n_star.max(2) - 1) as f64)
        .collect();
    let pred = session.predict(&t_star);
    let evals = gpfast::gp::profiled_eval_count() - evals_before;
    println!(
        "restored + served {} predictions in {:.3} s with {} likelihood evaluations",
        n_star,
        sw.elapsed_secs(),
        evals
    );
    if let Some(out) = args.get("out") {
        csv::write_columns(
            Path::new(out),
            &["t", "mean", "sd"],
            &[&t_star, &pred.mean, &pred.sd],
        )?;
        println!("predictions written to {out}");
    }
    Ok(())
}

fn cmd_nested(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let data = load_dataset(args, cfg)?;
    let spec = ModelSpec::parse(&args.get_or("model", "k2"))?;
    let model = spec.build(cfg.sigma_n);
    let prior = BoxPrior::for_model(&model, &data.span()?);
    let scale = ScalePrior::default();
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let opts = NestedOptions { nlive: cfg.nlive, ..Default::default() };
    let exec = cfg.exec();
    let sw = Stopwatch::start();
    let res = nested_sample(
        prior.dim() + 1,
        |u: &[f64]| {
            let lambda = scale.lambda_from_unit(u[0]);
            let theta = prior.from_unit_cube(&u[1..]);
            let mut full = vec![lambda];
            full.extend(theta);
            gpfast::gp::full_lnp_with(&model, &data.t, &data.y, &full, &exec)
                .unwrap_or(f64::NEG_INFINITY)
        },
        &opts,
        &mut rng,
    )?;
    println!("nested sampling: model {} on {} (n = {})", model.name, data.label, data.len());
    println!("  lnZ_num = {:.3} ± {:.3}", res.ln_z, res.ln_z_err);
    println!(
        "  evals   = {}  iters = {}  H = {:.2} nats",
        res.n_evals, res.n_iters, res.information
    );
    println!("  wall    = {:.2} s", sw.elapsed_secs());
    if let Some(out) = args.get("out") {
        // posterior samples for corner plots
        let mut cols: Vec<Vec<f64>> = vec![Vec::new(); prior.dim() + 2];
        for s in &res.samples {
            cols[0].push(s.ln_w);
            cols[1].push(scale.lambda_from_unit(s.u[0]));
            for (d, v) in prior.from_unit_cube(&s.u[1..]).into_iter().enumerate() {
                cols[d + 2].push(v);
            }
        }
        let mut names = vec!["ln_w".to_string(), "lambda".to_string()];
        names.extend(model.kernel.names());
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let col_refs: Vec<&[f64]> = cols.iter().map(Vec::as_slice).collect();
        csv::write_columns(Path::new(out), &name_refs, &col_refs)?;
        println!("posterior samples written to {out}");
    }
    Ok(())
}

fn cmd_synth(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let n = args.get_usize("n", 100)?;
    let data = synthetic::table1_dataset(n, cfg.sigma_n, cfg.seed);
    let out = PathBuf::from(args.get_or("out", "synthetic.csv"));
    csv::write_dataset(&out, &data)?;
    println!("wrote {} points to {}", data.len(), out.display());
    Ok(())
}

fn cmd_tidal(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let mut tcfg = tidal::TidalConfig::six_lunar_months(cfg.seed);
    tcfg.n = args.get_usize("n", tcfg.n)?;
    let data = tidal::generate_tidal(&tcfg);
    let out = PathBuf::from(args.get_or("out", "tidal.csv"));
    csv::write_dataset(&out, &data)?;
    println!("wrote {} points to {}", data.len(), out.display());
    Ok(())
}

fn cmd_realise(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let n = args.get_usize("n", 100)?;
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
    let k1 = gpfast::kernels::paper_k1(cfg.sigma_n);
    let k2 = gpfast::kernels::paper_k2(cfg.sigma_n);
    let y1 =
        gpfast::gp::draw_realisation(&k1, 1.0, &gpfast::kernels::PaperK1::truth(), &t, &mut rng)?;
    let y2 =
        gpfast::gp::draw_realisation(&k2, 1.0, &gpfast::kernels::PaperK2::truth(), &t, &mut rng)?;
    let out = PathBuf::from(args.get_or("out", "realisations.csv"));
    csv::write_columns(&out, &["t", "k1", "k2"], &[&t, &y1, &y2])?;
    println!("wrote Fig.-1 style realisations to {}", out.display());
    Ok(())
}

fn cmd_predict(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let data = load_dataset(args, cfg)?;
    let spec = ModelSpec::parse(&args.get_or("model", "k2"))?;
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let pipe = cfg.pipeline()?;
    let res =
        train_model(&spec, cfg.sigma_n, &data, &pipe.train, pipe.workers, &pipe.exec, &mut rng)?;
    let model = spec.build(cfg.sigma_n);
    let ev = gpfast::gp::profiled::eval_with(&model, &data.t, &data.y, &res.theta_hat, &pipe.exec)?;
    let factor = args.get_usize("refine", 4)?;
    let n_star = data.len() * factor;
    let (t0, t1) = (data.t[0], *data.t.last().unwrap());
    let t_star: Vec<f64> =
        (0..n_star).map(|i| t0 + (t1 - t0) * i as f64 / (n_star - 1) as f64).collect();
    let pred = gpfast::gp::predict(&model, &data.t, &res.theta_hat, &ev, &t_star);
    let out = PathBuf::from(args.get_or("out", "interpolant.csv"));
    csv::write_columns(&out, &["t", "mean", "sd"], &[&t_star, &pred.mean, &pred.sd])?;
    println!("wrote interpolant ({} points) to {}", n_star, out.display());
    Ok(())
}

/// Multi-tenant lifecycle demo: one trained artifact seeds `--sessions`
/// cold sessions in a disk-backed store, Zipf traffic drives hydrations
/// and evictions through a `--capacity`-bounded LRU, and a mutated
/// session is persisted back on clean shutdown. Hot (cache-hit) predict
/// latency is reported separately from cold (hydrate + predict).
fn cmd_fleet(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    use gpfast::coordinator::{DiskStore, Fleet, ZipfWorkload};

    let n_sessions = args.get_usize("sessions", 64)?;
    let capacity = args.get_usize("capacity", 8)?;
    let n_requests = args.get_usize("requests", 512)?;
    anyhow::ensure!(n_sessions >= 1 && n_requests >= 1, "fleet needs ≥1 session and request");
    let data = load_dataset(args, cfg)?;
    let spec = ModelSpec::parse(&args.get_or("model", "k1"))?;
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let sw = Stopwatch::start();
    let result = Tournament::single(spec, cfg.pipeline()?).run(&data, &mut rng)?;
    let tm = result.winner();
    println!(
        "trained {} on n = {} in {:.2} s (lnZ = {:.2})",
        tm.name(),
        data.len(),
        sw.elapsed_secs(),
        tm.ln_z()
    );

    let artifact_version = args.get_u64("artifact-version", 3)? as u32;
    let compress_tol = match args.get("compress-tol") {
        Some(s) => Some(
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--compress-tol expects a number, got '{s}'"))?,
        ),
        None => None,
    };
    let default_store = std::env::temp_dir().join(format!("gpfast_fleet_{}", std::process::id()));
    let store_dir = PathBuf::from(args.get_or("store", &default_store.to_string_lossy()));
    let mut fleet = Fleet::new(DiskStore::new(&store_dir)?, capacity, cfg.exec());
    fleet.set_artifact_format(artifact_version, compress_tol)?;
    for i in 0..n_sessions {
        fleet.put_artifacts(&format!("s{i:05}"), std::slice::from_ref(tm), &data)?;
    }
    println!(
        "seeded {} cold sessions (v{} artifacts{}, {} KiB = {} bytes) in {}",
        n_sessions,
        artifact_version,
        match compress_tol {
            Some(tol) => format!(", spectral tol {tol:.1e}"),
            None => String::new(),
        },
        fleet.store().total_bytes()? / 1024,
        fleet.store().total_bytes()?,
        store_dir.display()
    );

    let mut zipf = ZipfWorkload::new(n_sessions, 1.1, cfg.seed ^ 0x9e37_79b9_7f4a_7c15);
    let (t0, t1) = (data.t[0], *data.t.last().unwrap());
    let q = 8usize;
    let t_star: Vec<f64> =
        (0..q).map(|i| t0 + (t1 - t0) * (i as f64 + 0.5) / q as f64).collect();
    let mut hot_us: Vec<f64> = Vec::new();
    let mut cold_us: Vec<f64> = Vec::new();
    let sw = Stopwatch::start();
    for _ in 0..n_requests {
        let id = format!("s{:05}", zipf.next_session());
        let was_resident = fleet.is_resident(&id);
        let t = Stopwatch::start();
        fleet.predict(&id, &t_star)?;
        let us = t.elapsed_secs() * 1e6;
        if was_resident {
            hot_us.push(us);
        } else {
            cold_us.push(us);
        }
    }
    let secs = sw.elapsed_secs();
    let stats = fleet.stats();
    println!(
        "drove {} requests ({} query points each) in {:.2} s — {:.0} sessions/sec",
        n_requests,
        q,
        secs,
        n_requests as f64 / secs
    );
    println!(
        "  capacity {:4}  resident {:4}  hit rate {:5.1}%  hydration rate {:5.1}%",
        fleet.capacity(),
        fleet.resident_count(),
        100.0 * stats.hit_rate(),
        100.0 * stats.hydration_rate()
    );
    println!(
        "  hydrations {}  evictions {}  persisted {}",
        stats.hydrations, stats.evictions, stats.persisted
    );
    println!(
        "  hot  predict p50 {:8.0} µs   p99 {:8.0} µs   ({} samples)",
        percentile_us(&mut hot_us, 0.50),
        percentile_us(&mut hot_us, 0.99),
        hot_us.len()
    );
    println!(
        "  cold hydrate+predict p50 {:8.0} µs   p99 {:8.0} µs   ({} samples)",
        percentile_us(&mut cold_us, 0.50),
        percentile_us(&mut cold_us, 0.99),
        cold_us.len()
    );
    println!(
        "  hydrate wall split (total): parse {:.1} ms, view {:.1} ms, factor adoption {:.1} ms",
        stats.hydrate_parse_secs * 1e3,
        stats.hydrate_view_secs * 1e3,
        stats.hydrate_adopt_secs * 1e3
    );
    if stats.hydrations > 0 {
        let per = 1e6 / stats.hydrations as f64;
        println!(
            "  hydrate wall split (per session): parse {:.0} µs, view {:.0} µs, adoption {:.0} µs",
            stats.hydrate_parse_secs * per,
            stats.hydrate_view_secs * per,
            stats.hydrate_adopt_secs * per
        );
    }

    // mutate the hottest session, then shut down cleanly: eviction
    // persists the dirty session's *current* factors back to the store
    let hot = "s00000";
    let bytes_before = fleet.store().total_bytes()?;
    fleet.observe(hot, t1 + 1.0, 0.0)?;
    fleet.evict_all()?;
    println!(
        "observed 1 point into {hot}; shutdown persisted it back ({bytes_before} → {} store bytes)",
        fleet.store().total_bytes()?
    );
    Ok(())
}

/// In-place-sorting percentile helper (`0.0` for an empty sample set).
fn percentile_us(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(f64::total_cmp);
    let idx = ((xs.len() - 1) as f64 * p).round() as usize;
    xs[idx.min(xs.len() - 1)]
}

fn cmd_info(args: &Args, cfg: &RunConfig) -> gpfast::Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", &cfg.artifacts_dir));
    println!("gpfast — backend info");
    println!("  requested backend: {}", cfg.backend);
    match select_backend(&cfg.backend, Some(&dir)) {
        Ok(b) => println!("  resolved backend:  {}", b.name()),
        Err(e) => println!("  backend error:     {e}"),
    }
    match gpfast::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("  artifacts ({}):", m.entries.len());
            for e in &m.entries {
                println!(
                    "    {:10} {:10} n={:<5} m={} σn={}",
                    e.kind, e.model, e.n, e.m, e.sigma_n
                );
            }
        }
        Err(e) => println!("  no artifact manifest: {e}"),
    }
    Ok(())
}
