//! ARD (automatic relevance determination) kernels on d-dimensional
//! inputs, with analytic first and second hyperparameter derivatives.
//!
//! All three families are functions of the weighted squared distance
//!
//! ```text
//!   r² = Σ_j w_j Δx_j²,   w_j = e^{−2φ_j}   (φ_j = ln L_j)
//! ```
//!
//! * **SE-ARD** — `k = exp(−r²/2)`;
//! * **Matérn-3/2 ARD** — `k = (1+z) e^{−z}`, `z = √(3 r²)`;
//! * **Matérn-5/2 ARD** — `k = (1+z+z²/3) e^{−z}`, `z = √(5 r²)`.
//!
//! With `q_j = w_j Δx_j²` the log-derivatives are, per dimension,
//! `∂lnk/∂φ_j = q_j` for SE, and for the Matérns (writing `g_j = ν̃ q_j`
//! so `Σ_j g_j = z²`, and `f(z) = ln k`):
//! `∂lnk/∂φ_j = −f′(z)·g_j/z` — the `1/z` cancels analytically into the
//! nonsingular closed forms implemented below.
//!
//! A **tied** kernel shares one `φ` across every input dimension — the
//! isotropic-in-d parent (`se-iso`) whose trained length-scale seeds the
//! per-dimension ARD children through the warm-start lineage (the
//! parameter names overlap on `phiARD0`). At `d = 1`, tied and untied
//! coincide and both equal the classic isotropic kernels up to floating-
//! point association (the equivalence test pins this at ~1e-12).

use super::{DataSpan, PreparedKernel, StationaryKernel};

/// Which radial profile an [`ArdKernel`] applies to the weighted
/// distance r².
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArdFamily {
    /// Squared exponential `exp(−r²/2)`.
    Se,
    /// Matérn ν = 3/2.
    Matern32,
    /// Matérn ν = 5/2.
    Matern52,
}

/// A d-input ARD kernel (or its tied/isotropic-in-d parent).
#[derive(Clone, Copy, Debug)]
pub struct ArdKernel {
    family: ArdFamily,
    input_dim: usize,
    tied: bool,
}

impl ArdKernel {
    /// SE-ARD with one length-scale per input dimension.
    pub fn se(input_dim: usize) -> Self {
        Self::new(ArdFamily::Se, input_dim, false)
    }

    /// Matérn-3/2 ARD.
    pub fn m32(input_dim: usize) -> Self {
        Self::new(ArdFamily::Matern32, input_dim, false)
    }

    /// Matérn-5/2 ARD.
    pub fn m52(input_dim: usize) -> Self {
        Self::new(ArdFamily::Matern52, input_dim, false)
    }

    /// Isotropic-in-d SE: a single length-scale shared by every input
    /// dimension (the ARD warm-start parent).
    pub fn se_iso(input_dim: usize) -> Self {
        Self::new(ArdFamily::Se, input_dim, true)
    }

    pub fn new(family: ArdFamily, input_dim: usize, tied: bool) -> Self {
        assert!(input_dim >= 1, "ARD kernel needs at least one input dimension");
        Self { family, input_dim, tied }
    }
}

impl StationaryKernel for ArdKernel {
    fn dim(&self) -> usize {
        if self.tied {
            1
        } else {
            self.input_dim
        }
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn names(&self) -> Vec<String> {
        // tied parents expose exactly `phiARD0`, which the untied
        // children's dimension-0 name matches — the warm-start by-name
        // rule then seeds dimension 0 from the isotropic fit
        (0..self.dim()).map(|j| format!("phiARD{j}")).collect()
    }

    fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)> {
        vec![span.phi_bounds(); self.dim()]
    }

    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedKernel> {
        assert_eq!(theta.len(), self.dim(), "ARD theta length mismatch");
        let w: Vec<f64> = if self.tied {
            vec![(-2.0 * theta[0]).exp(); self.input_dim]
        } else {
            theta.iter().map(|&p| (-2.0 * p).exp()).collect()
        };
        Box::new(PreparedArd {
            family: self.family,
            tied: self.tied,
            w,
            q: vec![0.0; self.input_dim],
        })
    }
}

struct PreparedArd {
    family: ArdFamily,
    tied: bool,
    /// Per-input-dimension weights `w_j = e^{−2φ_j}`.
    w: Vec<f64>,
    /// Scratch for the per-dimension `q_j = w_j Δx_j²`.
    q: Vec<f64>,
}

impl PreparedArd {
    /// Fill `q_j = w_j Δx_j²` and return `r² = Σ q_j`.
    #[inline]
    fn r2(&mut self, dx: &[f64]) -> f64 {
        assert_eq!(dx.len(), self.w.len(), "ARD separation has wrong dimension");
        let mut r2 = 0.0;
        for (qj, (&wj, &dj)) in self.q.iter_mut().zip(self.w.iter().zip(dx)) {
            *qj = wj * dj * dj;
            r2 += *qj;
        }
        r2
    }

    #[inline]
    fn value_of_r2(&self, r2: f64) -> f64 {
        match self.family {
            ArdFamily::Se => (-0.5 * r2).exp(),
            ArdFamily::Matern32 => {
                let z = (3.0 * r2).sqrt();
                (1.0 + z) * (-z).exp()
            }
            ArdFamily::Matern52 => {
                let z = (5.0 * r2).sqrt();
                (1.0 + z + z * z / 3.0) * (-z).exp()
            }
        }
    }

    /// Per-dimension log-gradient `L_j = ∂lnk/∂φ_j` and the log-Hessian
    /// `M_jk = ∂²lnk/∂φ_j∂φ_k − L_j L_k` pieces, in the nonsingular
    /// closed forms (the `1/z` of the chain rule cancelled).
    ///
    /// Writes `L_j` into `l` (length d). If `m` is `Some`, writes the
    /// full `M_jk` (row-major d×d). Returns the value.
    fn log_derivs(&self, r2: f64, l: &mut [f64], mut m: Option<&mut [f64]>) -> f64 {
        let d = self.w.len();
        match self.family {
            ArdFamily::Se => {
                // lnk = −r²/2: L_j = q_j, M_jk = −2 δ_jk q_j
                l.copy_from_slice(&self.q);
                if let Some(m) = m.as_deref_mut() {
                    m.fill(0.0);
                    for j in 0..d {
                        m[j * d + j] = -2.0 * self.q[j];
                    }
                }
                (-0.5 * r2).exp()
            }
            ArdFamily::Matern32 => {
                // g_j = 3 q_j, z² = Σ g_j; L_j = g_j/(1+z),
                // M_jk = g_j g_k/(z(1+z)²) − 2 δ_jk g_j/(1+z)
                let z = (3.0 * r2).sqrt();
                let a = 1.0 / (1.0 + z);
                for j in 0..d {
                    l[j] = 3.0 * self.q[j] * a;
                }
                if let Some(m) = m.as_deref_mut() {
                    let c = if z > 0.0 { a * a / z } else { 0.0 };
                    for j in 0..d {
                        let gj = 3.0 * self.q[j];
                        for k in 0..d {
                            let gk = 3.0 * self.q[k];
                            m[j * d + k] = gj * gk * c - if j == k { 2.0 * gj * a } else { 0.0 };
                        }
                    }
                }
                (1.0 + z) * (-z).exp()
            }
            ArdFamily::Matern52 => {
                // g_j = 5 q_j, z² = Σ g_j, D = 1+z+z²/3;
                // L_j = g_j (1+z)/(3D);
                // M_jk = (g_j g_k/z²)·[f″ + (1+z)/(3D)] − 2 δ_jk g_j (1+z)/(3D)
                let z = (5.0 * r2).sqrt();
                let dd = 1.0 + z + z * z / 3.0;
                let s = (1.0 + z) / (3.0 * dd);
                for j in 0..d {
                    l[j] = 5.0 * self.q[j] * s;
                }
                if let Some(m) = m.as_deref_mut() {
                    let c = if z > 0.0 {
                        let n = -z * (1.0 + z) / 3.0;
                        let np = -(1.0 + 2.0 * z) / 3.0;
                        let dp = 1.0 + 2.0 * z / 3.0;
                        let fpp = (np * dd - n * dp) / (dd * dd);
                        (fpp + s) / (z * z)
                    } else {
                        0.0
                    };
                    for j in 0..d {
                        let gj = 5.0 * self.q[j];
                        for k in 0..d {
                            let gk = 5.0 * self.q[k];
                            m[j * d + k] = gj * gk * c - if j == k { 2.0 * gj * s } else { 0.0 };
                        }
                    }
                }
                dd * (-z).exp()
            }
        }
    }
}

impl PreparedKernel for PreparedArd {
    fn value(&mut self, dt: f64) -> f64 {
        self.value_nd(&[dt])
    }

    fn value_grad(&mut self, dt: f64, grad: &mut [f64]) -> f64 {
        self.value_grad_nd(&[dt], grad)
    }

    fn value_grad_hess(&mut self, dt: f64, grad: &mut [f64], hess: &mut [f64]) -> f64 {
        self.value_grad_hess_nd(&[dt], grad, hess)
    }

    fn value_nd(&mut self, dx: &[f64]) -> f64 {
        let r2 = self.r2(dx);
        self.value_of_r2(r2)
    }

    fn value_grad_nd(&mut self, dx: &[f64], grad: &mut [f64]) -> f64 {
        let d = self.w.len();
        let r2 = self.r2(dx);
        let mut l = [0.0; 8];
        assert!(d <= 8, "ARD supports at most 8 input dimensions");
        let v = self.log_derivs(r2, &mut l[..d], None);
        if self.tied {
            grad[0] = v * l[..d].iter().sum::<f64>();
        } else {
            for j in 0..d {
                grad[j] = v * l[j];
            }
        }
        v
    }

    fn value_grad_hess_nd(&mut self, dx: &[f64], grad: &mut [f64], hess: &mut [f64]) -> f64 {
        let d = self.w.len();
        let r2 = self.r2(dx);
        let mut l = [0.0; 8];
        let mut m = [0.0; 64];
        assert!(d <= 8, "ARD supports at most 8 input dimensions");
        let v = self.log_derivs(r2, &mut l[..d], Some(&mut m[..d * d]));
        // ∂²k/∂φ_j∂φ_k = k (L_j L_k + M_jk)
        if self.tied {
            let lsum: f64 = l[..d].iter().sum();
            let msum: f64 = m[..d * d].iter().sum();
            grad[0] = v * lsum;
            hess[0] = v * (lsum * lsum + msum);
        } else {
            for j in 0..d {
                grad[j] = v * l[j];
                for k in 0..d {
                    hess[j * d + k] = v * (l[j] * l[k] + m[j * d + k]);
                }
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::test_util::check_derivatives;

    fn fd_check_nd(kernel: &ArdKernel, dx: &[f64], theta: &[f64], tol: f64) {
        let m = kernel.dim();
        let mut grad = vec![0.0; m];
        let mut hess = vec![0.0; m * m];
        let v0 = kernel.prepare(theta).value_grad_hess_nd(dx, &mut grad, &mut hess);
        let v1 = kernel.prepare(theta).value_nd(dx);
        assert!((v0 - v1).abs() <= 1e-14 * v1.abs().max(1e-14));
        for a in 0..m {
            let h = 1e-6 * theta[a].abs().max(0.05);
            let mut tp = theta.to_vec();
            let mut tm = theta.to_vec();
            tp[a] += h;
            tm[a] -= h;
            let fd = (kernel.prepare(&tp).value_nd(dx) - kernel.prepare(&tm).value_nd(dx))
                / (2.0 * h);
            assert!(
                crate::math::rel_diff(grad[a], fd) < tol,
                "grad[{a}] at dx={dx:?}: analytic {} vs FD {fd}",
                grad[a]
            );
            let mut gp = vec![0.0; m];
            let mut gm = vec![0.0; m];
            kernel.prepare(&tp).value_grad_nd(dx, &mut gp);
            kernel.prepare(&tm).value_grad_nd(dx, &mut gm);
            for b in 0..m {
                let fd = (gp[b] - gm[b]) / (2.0 * h);
                assert!(
                    crate::math::rel_diff(hess[a * m + b], fd) < tol * 10.0,
                    "hess[{a},{b}] at dx={dx:?}: analytic {} vs FD {fd}",
                    hess[a * m + b]
                );
                assert!(
                    (hess[a * m + b] - hess[b * m + a]).abs()
                        <= 1e-10 * hess[a * m + b].abs().max(1e-10),
                    "hessian not symmetric at ({a},{b})"
                );
            }
        }
    }

    #[test]
    fn ard_d1_matches_scalar_fd_checker() {
        // the scalar check_derivatives harness exercises value/value_grad/
        // value_grad_hess consistency on the d=1 delegation path
        check_derivatives(&ArdKernel::se(1), 1.3, &[0.4], 1e-4);
        check_derivatives(&ArdKernel::m32(1), 1.3, &[0.4], 1e-4);
        check_derivatives(&ArdKernel::m52(1), 1.3, &[0.4], 1e-4);
    }

    #[test]
    fn ard_derivatives_match_fd_across_dims() {
        for d in [1usize, 2, 3, 5] {
            let dx: Vec<f64> = (0..d).map(|j| 0.7 + 0.3 * j as f64).collect();
            let theta: Vec<f64> = (0..d).map(|j| 0.2 * j as f64 - 0.1).collect();
            fd_check_nd(&ArdKernel::se(d), &dx, &theta, 1e-4);
            fd_check_nd(&ArdKernel::m32(d), &dx, &theta, 1e-4);
            fd_check_nd(&ArdKernel::m52(d), &dx, &theta, 1e-4);
            fd_check_nd(&ArdKernel::se_iso(d), &dx, &[0.3], 1e-4);
        }
    }

    #[test]
    fn zero_lag_is_unit_with_zero_derivatives() {
        for k in [ArdKernel::se(3), ArdKernel::m32(3), ArdKernel::m52(3)] {
            let theta = [0.1, -0.2, 0.5];
            let mut grad = [f64::NAN; 3];
            let mut hess = [f64::NAN; 9];
            let v = k
                .prepare(&theta)
                .value_grad_hess_nd(&[0.0, 0.0, 0.0], &mut grad, &mut hess);
            assert_eq!(v, 1.0);
            assert!(grad.iter().all(|&g| g == 0.0), "{grad:?}");
            assert!(hess.iter().all(|&h| h == 0.0), "{hess:?}");
        }
    }

    #[test]
    fn d1_ard_equals_isotropic_to_rounding() {
        use crate::kernels::{Matern32, Matern52, ProductKernel, SquaredExponential};
        let phi = 0.37;
        let iso: Vec<Box<dyn StationaryKernel>> = vec![
            Box::new(ProductKernel::new(vec![Box::new(SquaredExponential::new(0))])),
            Box::new(ProductKernel::new(vec![Box::new(Matern32::new(0))])),
            Box::new(ProductKernel::new(vec![Box::new(Matern52::new(0))])),
        ];
        let ard = [ArdKernel::se(1), ArdKernel::m32(1), ArdKernel::m52(1)];
        for (i, a) in iso.iter().zip(&ard) {
            let mut pi = i.prepare(&[phi]);
            let mut pa = a.prepare(&[phi]);
            for &dt in &[0.0, 0.2, 1.0, 3.7, -2.5] {
                let (vi, va) = (pi.value(dt), pa.value(dt));
                assert!(
                    (vi - va).abs() <= 1e-12 * vi.abs().max(1e-12),
                    "iso {vi} vs ard {va} at dt={dt}"
                );
            }
        }
    }

    #[test]
    fn tied_kernel_is_permutation_invariant() {
        let k = ArdKernel::se_iso(3);
        let mut p = k.prepare(&[0.4]);
        let a = p.value_nd(&[1.0, 2.0, 3.0]);
        let b = p.value_nd(&[3.0, 1.0, 2.0]);
        assert!((a - b).abs() < 1e-15);
    }
}
