//! The paper's covariance functions k₁ (eq. 3.1) and k₂ (eq. 3.2) and the
//! synthetic-data truth hyperparameters of §3(a) / Fig. 1.
//!
//! k₁(t,t') = σ_f² C(|Δt|/T₀) exp[−(2/l₁²) sin²(πΔt/T₁)] + σ_f² σ_n² δ
//! k₂(t,t') = σ_f² C(|Δt|/T₀) exp[−(2/l₁²) sin²(πΔt/T₁)
//!                                 −(2/l₂²) sin²(πΔt/T₂)] + σ_f² σ_n² δ
//!
//! Reduced (σ_f-profiled) hyperparameter vectors, flat-prior coordinates:
//!   k₁: ϑ = [φ₀, φ₁, ξ₁]                (m−1 = 3)
//!   k₂: ϑ = [φ₀, φ₁, ξ₁, φ₂, ξ₂]        (m−1 = 5), constraint φ₂ ≥ φ₁
//!     (the paper's `T₂ ≥ T₁` anti-double-counting constraint).

use super::{CovarianceModel, Periodic, ProductKernel, Wendland};

/// Marker for the k₁ model family (public API convenience).
pub struct PaperK1;

/// Marker for the k₂ model family (public API convenience).
pub struct PaperK2;

/// Index of φ₁ in the k₂ parameter vector (for the ordering constraint).
pub const K2_PHI1_IDX: usize = 1;
/// Index of φ₂ in the k₂ parameter vector.
pub const K2_PHI2_IDX: usize = 3;

/// Build the paper's k₁ model with fixed noise σ_n.
pub fn paper_k1(sigma_n: f64) -> CovarianceModel {
    let kernel = ProductKernel::new(vec![Box::new(Wendland), Box::new(Periodic::new(1))]);
    CovarianceModel::new("k1", Box::new(kernel), sigma_n)
}

/// Build the paper's k₂ model with fixed noise σ_n.
pub fn paper_k2(sigma_n: f64) -> CovarianceModel {
    let kernel = ProductKernel::new(vec![
        Box::new(Wendland),
        Box::new(Periodic::new(1)),
        Box::new(Periodic::new(2)),
    ])
    .with_constraints(vec![(K2_PHI1_IDX, K2_PHI2_IDX)]);
    CovarianceModel::new("k2", Box::new(kernel), sigma_n)
}

impl PaperK1 {
    /// Fig. 1 truth: σ_f = 1, φ₀ = 3.5, φ₁ = 1.5, ξ₁ = 0.
    /// (Reduced vector: σ_f is profiled out.)
    pub fn truth() -> Vec<f64> {
        vec![3.5, 1.5, 0.0]
    }
}

impl PaperK2 {
    /// Fig. 1 truth: k₁'s values plus a second periodic component.
    /// The paper's print garbles the k₂ additions; we use φ₂ = 2.5, ξ₂ = 0
    /// (T₂ ≈ 12.2 > T₁ ≈ 4.5, satisfying T₂ ≥ T₁ and visually matching the
    /// lengthscale markers of Fig. 1).
    pub fn truth() -> Vec<f64> {
        vec![3.5, 1.5, 0.0, 2.5, 0.0]
    }
}

/// The σ_n used for the synthetic-data experiments (§3(a)); the paper
/// fixes σ_n but the value is garbled in print — we use 0.1, i.e. a 10%
/// fractional error, which reproduces the Table-1 Bayes-factor ordering.
pub const SYNTHETIC_SIGMA_N: f64 = 0.1;

/// The σ_n used for the tidal experiments: "we fix σ_n = 10⁻², which is
/// the typical fractional error in the sea-level measurements" (§3(b)).
pub const TIDAL_SIGMA_N: f64 = 1e-2;

#[cfg(test)]
mod tests {
    use super::super::test_util::check_derivatives;
    use super::super::DataSpan;
    use super::*;

    #[test]
    fn k1_shape() {
        let m = paper_k1(0.1);
        assert_eq!(m.dim(), 3);
        assert_eq!(m.kernel.names(), vec!["phi0", "phi1", "xi1"]);
        assert!((m.noise_variance() - 0.01).abs() < 1e-15);
        assert!(m.kernel.ordering_constraints().is_empty());
    }

    #[test]
    fn k2_shape_and_constraint() {
        let m = paper_k2(0.1);
        assert_eq!(m.dim(), 5);
        assert_eq!(m.kernel.names(), vec!["phi0", "phi1", "xi1", "phi2", "xi2"]);
        assert_eq!(m.kernel.ordering_constraints(), vec![(1, 3)]);
    }

    #[test]
    fn truth_satisfies_constraint_and_bounds() {
        let t = PaperK2::truth();
        assert!(t[K2_PHI2_IDX] >= t[K2_PHI1_IDX]);
        // a t = 1..100 grid must contain the truth in its bounds
        let span = DataSpan { dt_min: 1.0, dt_max: 99.0 };
        let m = paper_k2(0.1);
        for (v, (lo, hi)) in t.iter().zip(m.kernel.bounds(&span)) {
            assert!(*v > lo && *v < hi, "truth {v} outside ({lo}, {hi})");
        }
    }

    #[test]
    fn k1_k2_derivatives_at_truth() {
        let k1 = paper_k1(0.1);
        let k2 = paper_k2(0.1);
        for &dt in &[0.5, 1.0, 4.3, 11.0, 25.0] {
            check_derivatives(k1.kernel.as_ref(), dt, &PaperK1::truth(), 5e-4);
            check_derivatives(k2.kernel.as_ref(), dt, &PaperK2::truth(), 5e-4);
        }
    }

    #[test]
    fn k2_reduces_to_k1_when_second_component_flat() {
        // As l₂ → ∞ (ξ₂ → ½⁻), the second periodic factor → 1 and k₂ → k₁.
        let k1 = paper_k1(0.1);
        let k2 = paper_k2(0.1);
        let t1 = PaperK1::truth();
        let mut t2 = PaperK2::truth();
        t2[4] = 0.5 - 1e-9; // l₂ huge
        let mut p1 = k1.kernel.prepare(&t1);
        let mut p2 = k2.kernel.prepare(&t2);
        for &dt in &[0.7, 3.0, 9.0] {
            assert!(
                (p1.value(dt) - p2.value(dt)).abs() < 1e-6,
                "dt={dt}: {} vs {}",
                p1.value(dt),
                p2.value(dt)
            );
        }
    }
}
