//! Product-of-factors kernel: `k̃(Δt) = Π_k F_k(Δt; ϑ_k)`.
//!
//! Converts the factors' logarithmic derivatives into the direct
//! derivatives the GP layer needs:
//! `∂V/∂α = V L_α`, `∂²V/∂α∂β = V (L_α L_β + M_αβ)` with `M_αβ = 0`
//! across factors. If any factor evaluates to exactly zero (compact
//! support), the product and *all* its derivatives are zero — valid here
//! because the Wendland factor has a 6th-order zero at its support edge.

use super::{DataSpan, Factor, PreparedFactor, PreparedKernel, StationaryKernel};

/// A product kernel over a list of factors with concatenated parameters.
pub struct ProductKernel {
    factors: Vec<Box<dyn Factor>>,
    /// Parameter offset of each factor in the concatenated vector.
    offsets: Vec<usize>,
    dim: usize,
    constraints: Vec<(usize, usize)>,
}

impl ProductKernel {
    pub fn new(factors: Vec<Box<dyn Factor>>) -> Self {
        let mut offsets = Vec::with_capacity(factors.len());
        let mut dim = 0;
        for f in &factors {
            offsets.push(dim);
            dim += f.dim();
        }
        Self { factors, offsets, dim, constraints: Vec::new() }
    }

    /// Declare ordering constraints `θ[i] ≤ θ[j]` on the concatenated
    /// parameter vector (e.g. the paper's `T₂ ≥ T₁` for k₂).
    pub fn with_constraints(mut self, constraints: Vec<(usize, usize)>) -> Self {
        for &(i, j) in &constraints {
            assert!(i < self.dim && j < self.dim, "constraint index out of range");
        }
        self.constraints = constraints;
        self
    }
}

impl StationaryKernel for ProductKernel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn names(&self) -> Vec<String> {
        self.factors.iter().flat_map(|f| f.names()).collect()
    }

    fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)> {
        self.factors.iter().flat_map(|f| f.bounds(span)).collect()
    }

    fn ordering_constraints(&self) -> Vec<(usize, usize)> {
        self.constraints.clone()
    }

    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedKernel> {
        assert_eq!(theta.len(), self.dim, "theta length mismatch");
        let prepared: Vec<(usize, usize, Box<dyn PreparedFactor>)> = self
            .factors
            .iter()
            .zip(&self.offsets)
            .map(|(f, &off)| (off, f.dim(), f.prepare(&theta[off..off + f.dim()])))
            .collect();
        let max_fdim = self.factors.iter().map(|f| f.dim()).max().unwrap_or(0);
        Box::new(PreparedProduct {
            prepared,
            dim: self.dim,
            dlog: vec![0.0; self.dim],
            fd2: vec![0.0; max_fdim * max_fdim],
        })
    }
}

struct PreparedProduct {
    prepared: Vec<(usize, usize, Box<dyn PreparedFactor>)>,
    dim: usize,
    /// Scratch: concatenated L vector.
    dlog: Vec<f64>,
    /// Scratch: per-factor M block.
    fd2: Vec<f64>,
}

impl PreparedKernel for PreparedProduct {
    fn value(&mut self, dt: f64) -> f64 {
        let mut v = 1.0;
        for (_, _, f) in &self.prepared {
            v *= f.value(dt);
            if v == 0.0 {
                return 0.0;
            }
        }
        v
    }

    fn value_grad(&mut self, dt: f64, grad: &mut [f64]) -> f64 {
        debug_assert_eq!(grad.len(), self.dim);
        let mut v = 1.0;
        for (off, fdim, f) in &self.prepared {
            let fv = f.value_dlog(dt, &mut self.dlog[*off..*off + *fdim]);
            v *= fv;
            if v == 0.0 {
                grad.fill(0.0);
                return 0.0;
            }
        }
        for i in 0..self.dim {
            grad[i] = v * self.dlog[i];
        }
        v
    }

    fn value_grad_hess(&mut self, dt: f64, grad: &mut [f64], hess: &mut [f64]) -> f64 {
        debug_assert_eq!(grad.len(), self.dim);
        debug_assert_eq!(hess.len(), self.dim * self.dim);
        let mut v = 1.0;
        // first pass: values + dlogs + per-factor d2log blocks into hess
        hess.fill(0.0);
        for (off, fdim, f) in &self.prepared {
            let (off, fdim) = (*off, *fdim);
            let block = &mut self.fd2[..fdim * fdim];
            let fv = f.value_dlog2(dt, &mut self.dlog[off..off + fdim], block);
            v *= fv;
            if v == 0.0 {
                grad.fill(0.0);
                hess.fill(0.0);
                return 0.0;
            }
            // place the M block (same-factor second log-derivatives)
            for a in 0..fdim {
                for b in 0..fdim {
                    hess[(off + a) * self.dim + (off + b)] = block[a * fdim + b];
                }
            }
        }
        // second pass: grad and the L_α L_β outer product, all scaled by V
        for i in 0..self.dim {
            grad[i] = v * self.dlog[i];
        }
        for i in 0..self.dim {
            for j in 0..self.dim {
                hess[i * self.dim + j] =
                    v * (hess[i * self.dim + j] + self.dlog[i] * self.dlog[j]);
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::check_derivatives;
    use super::super::{Periodic, SquaredExponential, Wendland};
    use super::*;

    fn k1_like() -> ProductKernel {
        ProductKernel::new(vec![Box::new(Wendland), Box::new(Periodic::new(1))])
    }

    #[test]
    fn product_value_is_product() {
        let k = k1_like();
        let w = Wendland;
        let p = Periodic::new(1);
        let theta = [2.0, 1.0, 0.1];
        let mut prep = k.prepare(&theta);
        let want = w.prepare(&[2.0]).value(1.5) * p.prepare(&[1.0, 0.1]).value(1.5);
        assert!((prep.value(1.5) - want).abs() < 1e-15);
    }

    #[test]
    fn k1_like_derivatives_fd() {
        let k = k1_like();
        for &dt in &[0.4, 1.0, 3.3, 6.0] {
            check_derivatives(&k, dt, &[2.2, 1.0, 0.12], 2e-4);
        }
    }

    #[test]
    fn k2_like_derivatives_fd() {
        let k = ProductKernel::new(vec![
            Box::new(Wendland),
            Box::new(Periodic::new(1)),
            Box::new(Periodic::new(2)),
        ])
        .with_constraints(vec![(1, 3)]);
        assert_eq!(k.dim(), 5);
        assert_eq!(k.ordering_constraints(), vec![(1, 3)]);
        for &dt in &[0.4, 2.0, 5.0] {
            check_derivatives(&k, dt, &[2.5, 1.0, 0.1, 1.8, -0.2], 2e-4);
        }
    }

    #[test]
    fn mixed_factor_product() {
        let k = ProductKernel::new(vec![
            Box::new(SquaredExponential::new(1)),
            Box::new(Periodic::new(1)),
        ]);
        for &dt in &[0.5, 2.0] {
            check_derivatives(&k, dt, &[1.3, 0.8, 0.05], 2e-4);
        }
    }

    #[test]
    fn outside_support_zero() {
        let k = k1_like();
        let theta = [0.0, 1.0, 0.0]; // T0 = 1 → support |dt| < 1
        let mut prep = k.prepare(&theta);
        let mut g = [1.0; 3];
        let mut h = [1.0; 9];
        assert_eq!(prep.value_grad_hess(5.0, &mut g, &mut h), 0.0);
        assert!(g.iter().all(|&x| x == 0.0));
        assert!(h.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn names_and_bounds_concatenate() {
        let k = k1_like();
        assert_eq!(k.names(), vec!["phi0", "phi1", "xi1"]);
        let span = DataSpan { dt_min: 1.0, dt_max: 100.0 };
        let b = k.bounds(&span);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0], (0.0, 100f64.ln()));
        assert_eq!(b[2].0, -0.5 + super::super::periodic::XI_MARGIN);
    }
}
