//! Squared-exponential factor `exp[−Δt²/(2L²)]` with flat coordinate
//! `φ = ln L` (Jeffreys prior on the lengthscale).
//!
//! With `q = Δt² e^{−2φ}/2`: `lnF = −q`, `∂lnF/∂φ = 2q`, `∂²lnF/∂φ² = −4q`.

use super::{DataSpan, Factor, PreparedFactor};

/// Squared-exponential (RBF) factor, one hyperparameter `φ = ln L`.
#[derive(Clone, Copy, Debug)]
pub struct SquaredExponential {
    pub index: usize,
}

impl SquaredExponential {
    pub fn new(index: usize) -> Self {
        Self { index }
    }
}

impl Factor for SquaredExponential {
    fn dim(&self) -> usize {
        1
    }

    fn names(&self) -> Vec<String> {
        vec![format!("phiSE{}", self.index)]
    }

    fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)> {
        vec![span.phi_bounds()]
    }

    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedFactor> {
        assert_eq!(theta.len(), 1);
        Box::new(PreparedSe { inv_2l2: 0.5 * (-2.0 * theta[0]).exp() })
    }
}

struct PreparedSe {
    inv_2l2: f64,
}

impl PreparedFactor for PreparedSe {
    fn value(&self, dt: f64) -> f64 {
        (-dt * dt * self.inv_2l2).exp()
    }

    fn value_dlog(&self, dt: f64, dlog: &mut [f64]) -> f64 {
        let q = dt * dt * self.inv_2l2;
        dlog[0] = 2.0 * q;
        (-q).exp()
    }

    fn value_dlog2(&self, dt: f64, dlog: &mut [f64], d2log: &mut [f64]) -> f64 {
        let q = dt * dt * self.inv_2l2;
        dlog[0] = 2.0 * q;
        d2log[0] = -4.0 * q;
        (-q).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_basics() {
        let k = SquaredExponential::new(1);
        let f = k.prepare(&[0.0]); // L = 1
        assert!((f.value(0.0) - 1.0).abs() < 1e-15);
        assert!((f.value(1.0) - (-0.5f64).exp()).abs() < 1e-15);
        assert!(f.value(2.0) < f.value(1.0));
    }

    #[test]
    fn log_derivs_match_fd() {
        let k = SquaredExponential::new(1);
        for &(dt, phi) in &[(0.5, 0.0), (2.0, 1.0), (7.0, 2.0)] {
            let f = k.prepare(&[phi]);
            let mut dl = [0.0];
            let mut d2 = [0.0];
            let v = f.value_dlog2(dt, &mut dl, &mut d2);
            let h = 1e-6;
            let lp = k.prepare(&[phi + h]).value(dt).ln();
            let lm = k.prepare(&[phi - h]).value(dt).ln();
            let fd1 = (lp - lm) / (2.0 * h);
            let fd2 = (lp - 2.0 * v.ln() + lm) / (h * h);
            assert!(crate::math::rel_diff(dl[0], fd1) < 1e-6);
            assert!(crate::math::rel_diff(d2[0], fd2) < 1e-3);
        }
    }
}
