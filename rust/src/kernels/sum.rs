//! Sum-of-kernels combinator: `k̃(Δt) = Σ_c k̃_c(Δt; ϑ_c)`.
//!
//! Values, gradients and Hessians add directly; the Hessian is block
//! diagonal across summands. Pair each summand (after the first) with an
//! [`super::Amplitude`] factor inside a [`super::ProductKernel`] so the
//! relative weights are learnable — the *overall* scale stays profiled
//! out through σ_f as usual.

use super::{DataSpan, PreparedKernel, StationaryKernel};

/// Sum of stationary kernels with concatenated parameter vectors.
pub struct SumKernel {
    children: Vec<Box<dyn StationaryKernel>>,
    offsets: Vec<usize>,
    dim: usize,
}

impl SumKernel {
    pub fn new(children: Vec<Box<dyn StationaryKernel>>) -> Self {
        let mut offsets = Vec::with_capacity(children.len());
        let mut dim = 0;
        for c in &children {
            offsets.push(dim);
            dim += c.dim();
        }
        Self { children, offsets, dim }
    }
}

impl StationaryKernel for SumKernel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn names(&self) -> Vec<String> {
        self.children
            .iter()
            .enumerate()
            .flat_map(|(i, c)| c.names().into_iter().map(move |n| format!("s{i}.{n}")))
            .collect()
    }

    fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)> {
        self.children.iter().flat_map(|c| c.bounds(span)).collect()
    }

    fn ordering_constraints(&self) -> Vec<(usize, usize)> {
        // shift each child's constraints by its offset
        self.children
            .iter()
            .zip(&self.offsets)
            .flat_map(|(c, &off)| {
                c.ordering_constraints().into_iter().map(move |(i, j)| (i + off, j + off))
            })
            .collect()
    }

    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedKernel> {
        assert_eq!(theta.len(), self.dim);
        let prepared: Vec<(usize, usize, Box<dyn PreparedKernel>)> = self
            .children
            .iter()
            .zip(&self.offsets)
            .map(|(c, &off)| (off, c.dim(), c.prepare(&theta[off..off + c.dim()])))
            .collect();
        let max_dim = self.children.iter().map(|c| c.dim()).max().unwrap_or(0);
        Box::new(PreparedSum {
            prepared,
            dim: self.dim,
            g_scratch: vec![0.0; max_dim],
            h_scratch: vec![0.0; max_dim * max_dim],
        })
    }
}

struct PreparedSum {
    prepared: Vec<(usize, usize, Box<dyn PreparedKernel>)>,
    dim: usize,
    g_scratch: Vec<f64>,
    h_scratch: Vec<f64>,
}

impl PreparedKernel for PreparedSum {
    fn value(&mut self, dt: f64) -> f64 {
        self.prepared.iter_mut().map(|(_, _, c)| c.value(dt)).sum()
    }

    fn value_grad(&mut self, dt: f64, grad: &mut [f64]) -> f64 {
        debug_assert_eq!(grad.len(), self.dim);
        grad.fill(0.0);
        let mut v = 0.0;
        for (off, cdim, c) in &mut self.prepared {
            let g = &mut self.g_scratch[..*cdim];
            v += c.value_grad(dt, g);
            grad[*off..*off + *cdim].copy_from_slice(g);
        }
        v
    }

    fn value_grad_hess(&mut self, dt: f64, grad: &mut [f64], hess: &mut [f64]) -> f64 {
        debug_assert_eq!(grad.len(), self.dim);
        debug_assert_eq!(hess.len(), self.dim * self.dim);
        grad.fill(0.0);
        hess.fill(0.0);
        let mut v = 0.0;
        for (off, cdim, c) in &mut self.prepared {
            let (off, cdim) = (*off, *cdim);
            let g = &mut self.g_scratch[..cdim];
            let h = &mut self.h_scratch[..cdim * cdim];
            v += c.value_grad_hess(dt, g, h);
            grad[off..off + cdim].copy_from_slice(g);
            for a in 0..cdim {
                for b in 0..cdim {
                    hess[(off + a) * self.dim + (off + b)] = h[a * cdim + b];
                }
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::check_derivatives;
    use super::super::{Amplitude, Factor, Matern32, Periodic, ProductKernel, SquaredExponential};
    use super::*;

    fn se_plus_periodic() -> SumKernel {
        SumKernel::new(vec![
            Box::new(ProductKernel::new(vec![Box::new(SquaredExponential::new(1))])),
            Box::new(ProductKernel::new(vec![
                Box::new(Amplitude::new(1)),
                Box::new(Periodic::new(1)),
            ])),
        ])
    }

    #[test]
    fn sum_value_adds() {
        let k = se_plus_periodic();
        let theta = [1.0, -0.3, 0.9, 0.05];
        let mut p = k.prepare(&theta);
        let se = SquaredExponential::new(1).prepare(&[1.0]);
        let amp = Amplitude::new(1).prepare(&[-0.3]);
        let per = Periodic::new(1).prepare(&[0.9, 0.05]);
        let dt = 1.3;
        let want = se.value(dt) + amp.value(dt) * per.value(dt);
        assert!((p.value(dt) - want).abs() < 1e-14);
    }

    #[test]
    fn sum_derivatives_fd() {
        let k = se_plus_periodic();
        assert_eq!(k.dim(), 4);
        for &dt in &[0.2, 1.0, 2.7] {
            check_derivatives(&k, dt, &[1.0, -0.3, 0.9, 0.05], 2e-4);
        }
    }

    #[test]
    fn names_have_summand_prefix() {
        let k = SumKernel::new(vec![
            Box::new(ProductKernel::new(vec![Box::new(Matern32::new(1))])),
            Box::new(ProductKernel::new(vec![Box::new(SquaredExponential::new(2))])),
        ]);
        let names = k.names();
        assert!(names[0].starts_with("s0."));
        assert!(names[1].starts_with("s1."));
    }
}
