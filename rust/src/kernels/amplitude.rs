//! Constant amplitude factor `F = e^{2ρ}` (ρ = ln amplitude).
//!
//! Within a single product kernel the overall scale is σ_f and is profiled
//! out analytically, so this factor is only useful inside **sums** of
//! kernels, where the *relative* weight of each summand must be learned.
//! `lnF = 2ρ` ⇒ `∂lnF/∂ρ = 2`, `∂²lnF/∂ρ² = 0`.

use super::{DataSpan, Factor, PreparedFactor};

/// Relative-amplitude factor, one hyperparameter `ρ = ln A`.
#[derive(Clone, Copy, Debug)]
pub struct Amplitude {
    pub index: usize,
    /// Allowed range of ρ (flat prior); amplitude ratios outside
    /// `e^{±ρ_range}` are considered unresolvable.
    pub rho_range: f64,
}

impl Amplitude {
    pub fn new(index: usize) -> Self {
        Self { index, rho_range: 6.0 }
    }
}

impl Factor for Amplitude {
    fn dim(&self) -> usize {
        1
    }

    fn names(&self) -> Vec<String> {
        vec![format!("rho{}", self.index)]
    }

    fn bounds(&self, _span: &DataSpan) -> Vec<(f64, f64)> {
        vec![(-self.rho_range, self.rho_range)]
    }

    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedFactor> {
        assert_eq!(theta.len(), 1);
        Box::new(PreparedAmp { a2: (2.0 * theta[0]).exp() })
    }
}

struct PreparedAmp {
    a2: f64,
}

impl PreparedFactor for PreparedAmp {
    fn value(&self, _dt: f64) -> f64 {
        self.a2
    }

    fn value_dlog(&self, _dt: f64, dlog: &mut [f64]) -> f64 {
        dlog[0] = 2.0;
        self.a2
    }

    fn value_dlog2(&self, _dt: f64, dlog: &mut [f64], d2log: &mut [f64]) -> f64 {
        dlog[0] = 2.0;
        d2log[0] = 0.0;
        self.a2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn amplitude_squares() {
        let a = Amplitude::new(1);
        let p = a.prepare(&[0.7]);
        assert!((p.value(3.0) - (1.4f64).exp()).abs() < 1e-12);
        let mut dl = [0.0];
        let mut d2 = [0.0];
        let v = p.value_dlog2(1.0, &mut dl, &mut d2);
        assert_eq!(dl[0], 2.0);
        assert_eq!(d2[0], 0.0);
        assert!(v > 0.0);
    }
}
