//! Covariance functions ("kernels") with analytic first and second
//! hyperparameter derivatives.
//!
//! The paper trains GPs by maximising the σ_f-profiled hyperlikelihood
//! (eq. 2.16) with a conjugate-gradient optimiser driven by the analytic
//! gradient (eq. 2.17), then compares covariance functions through the
//! Laplace evidence built from the analytic Hessian (eq. 2.19). All of
//! that needs, per point-pair lag `Δt`, the kernel value `k(Δt; ϑ)`, the
//! gradient `∂k/∂ϑ` and the Hessian `∂²k/∂ϑ∂ϑ'` — which this module
//! provides for the paper's covariance functions k₁/k₂ (eqs. 3.1–3.2) and
//! for a library of composable pieces (Wendland, periodic, squared-
//! exponential, Matérn, amplitude; products and sums).
//!
//! ## Hyperparameter coordinates
//!
//! All kernels are parametrised directly in the paper's **flat-prior
//! coordinates** (§3): timescales enter as `φ = ln T` (Jeffreys prior →
//! flat, eq. 3.4) and periodic smoothness parameters as `ξ ∈ (−½, ½)`
//! with `l = exp(μ + √2 σ_l erf⁻¹(2ξ))` (log-normal prior → flat,
//! eq. 3.5). The overall scale σ_f is **not** a kernel parameter — it is
//! profiled out analytically by the [`crate::gp`] layer (eq. 2.15), so a
//! kernel here evaluates `k̃ = k/σ_f²`.
//!
//! ## Erratum implemented
//!
//! Eq. (3.3) of the published paper prints the compact-support polynomial
//! as `(1−τ)⁵(48τ²+15τ+3)/3`, which is **not positive definite** (its
//! Gram matrices on regular grids have eigenvalues as low as −0.5; the
//! unit tests demonstrate this). It is a typo of the Wendland ψ₃,₂
//! function `(1−τ)⁶(35τ²+18τ+3)/3` [Wendland 2005, the paper's ref. 18],
//! which is what we implement. See DESIGN.md.

mod wendland;
mod periodic;
mod se;
mod matern;
mod amplitude;
mod product;
mod sum;
mod paper;
mod ard;

pub use amplitude::Amplitude;
pub use ard::{ArdFamily, ArdKernel};
pub use matern::{Matern32, Matern52};
pub use paper::{
    paper_k1, paper_k2, PaperK1, PaperK2, K2_PHI1_IDX, K2_PHI2_IDX, SYNTHETIC_SIGMA_N,
    TIDAL_SIGMA_N,
};
pub use periodic::Periodic;
pub use product::ProductKernel;
pub use se::SquaredExponential;
pub use sum::SumKernel;
pub use wendland::Wendland;

/// The sampling geometry of a dataset: smallest and largest separations
/// between input points. Defines the resolvable-timescale hyperprior range
/// `T ∈ (δt, ΔT)` (paper §3: "If there was a timescale in the problem
/// outside of this range, we would be unable to resolve it").
#[derive(Clone, Copy, Debug)]
pub struct DataSpan {
    /// δt — smallest separation between sampling points.
    pub dt_min: f64,
    /// ΔT — largest separation between sampling points.
    pub dt_max: f64,
}

impl DataSpan {
    /// Compute from a (not necessarily sorted) input vector.
    ///
    /// Errors (instead of panicking) on degenerate grids: fewer than two
    /// points, or all points coincident (no positive separation) — both
    /// reachable from the streaming observe path via duplicate
    /// timestamps, so they must surface as recoverable errors.
    pub fn from_times(t: &[f64]) -> crate::Result<Self> {
        anyhow::ensure!(t.len() >= 2, "degenerate input grid: need at least two points, got {}", t.len());
        let mut s = t.to_vec();
        s.sort_by(|a, b| crate::util::asc_nan_last(*a, *b));
        let mut dt_min = f64::INFINITY;
        for w in s.windows(2) {
            let d = w[1] - w[0];
            if d > 0.0 {
                dt_min = dt_min.min(d);
            }
        }
        let dt_max = s[s.len() - 1] - s[0];
        anyhow::ensure!(
            dt_min.is_finite() && dt_max > 0.0,
            "degenerate input grid: all {} points coincident (no positive separation)",
            t.len()
        );
        Ok(Self { dt_min, dt_max })
    }

    /// Pooled sampling geometry of a d-column input layout (column 0 is
    /// the time/first axis): δt is the smallest positive per-dimension
    /// separation over all columns, ΔT the largest per-dimension
    /// diameter. Every column must be non-degenerate on its own —
    /// a constant column makes its ARD length-scale unidentifiable.
    pub fn from_columns(cols: &[&[f64]]) -> crate::Result<Self> {
        anyhow::ensure!(!cols.is_empty(), "degenerate input grid: zero input columns");
        let mut dt_min = f64::INFINITY;
        let mut dt_max = 0.0f64;
        for (j, col) in cols.iter().enumerate() {
            let s = Self::from_times(col)
                .map_err(|e| anyhow::anyhow!("input dimension {j}: {e}"))?;
            dt_min = dt_min.min(s.dt_min);
            dt_max = dt_max.max(s.dt_max);
        }
        Ok(Self { dt_min, dt_max })
    }

    /// `ln(ΔT/δt)` — the hyperprior volume per timescale parameter.
    pub fn log_timescale_range(&self) -> f64 {
        (self.dt_max / self.dt_min).ln()
    }

    /// Flat-coordinate range for a timescale: `φ ∈ (ln δt, ln ΔT)`.
    pub fn phi_bounds(&self) -> (f64, f64) {
        (self.dt_min.ln(), self.dt_max.ln())
    }
}

/// A multiplicative stationary factor (one term of a product kernel) with
/// its own hyperparameter block, exposing *logarithmic* derivatives.
///
/// For a product kernel `V = Π_k F_k`, log-derivatives compose trivially:
/// `∂V/∂α = V·L_α` and `∂²V/∂α∂β = V·(L_α L_β + M_αβ)` where
/// `L_α = ∂ln F/∂α` and `M_αβ = ∂²ln F/∂α∂β` vanish across factors.
pub trait Factor: Send + Sync {
    /// Number of hyperparameters in this factor.
    fn dim(&self) -> usize;
    /// Hyperparameter names (flat-prior coordinates).
    fn names(&self) -> Vec<String>;
    /// Hyperparameter box bounds given the data geometry.
    fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)>;
    /// Bind hyperparameters, precomputing everything pair-independent.
    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedFactor>;
}

/// A factor with hyperparameters bound; provides fast per-pair evaluation.
///
/// Contract: if `value` returns exactly `0.0` (outside compact support),
/// the caller must treat every derivative of the *product* as zero and may
/// ignore the contents of `dlog`/`d2log`.
pub trait PreparedFactor {
    /// Factor value at lag `dt`.
    fn value(&self, dt: f64) -> f64;
    /// Value + gradient of `ln F` (length `dim`).
    fn value_dlog(&self, dt: f64, dlog: &mut [f64]) -> f64;
    /// Value + gradient + Hessian of `ln F` (row-major `dim×dim`, full).
    fn value_dlog2(&self, dt: f64, dlog: &mut [f64], d2log: &mut [f64]) -> f64;
}

/// A stationary covariance kernel `k̃(Δt; ϑ)` with direct derivatives.
pub trait StationaryKernel: Send + Sync {
    /// Number of hyperparameters `ϑ` (σ_f excluded — it is profiled).
    fn dim(&self) -> usize;
    /// Number of *input* dimensions d the kernel consumes per point.
    /// Every pre-existing kernel is a time-series kernel (d = 1); ARD
    /// kernels override. The training/serving layers validate this
    /// against the dataset's column count before any assembly.
    fn input_dim(&self) -> usize {
        1
    }
    /// Hyperparameter names, e.g. `["phi0", "phi1", "xi1"]`.
    fn names(&self) -> Vec<String>;
    /// Box bounds for each hyperparameter given the data geometry.
    fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)>;
    /// Ordering constraints `θ[i] ≤ θ[j]` (e.g. the paper's `T₂ ≥ T₁`).
    fn ordering_constraints(&self) -> Vec<(usize, usize)> {
        Vec::new()
    }
    /// Bind hyperparameters for fast per-pair evaluation.
    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedKernel>;
}

/// A kernel with hyperparameters bound.
///
/// Methods take `&mut self` so implementations can reuse interior scratch
/// buffers across the `O(n²)` per-pair calls of a matrix assembly.
pub trait PreparedKernel {
    /// `k̃(Δt)`.
    fn value(&mut self, dt: f64) -> f64;
    /// `k̃(Δt)` and `∂k̃/∂ϑ` (length `dim`).
    fn value_grad(&mut self, dt: f64, grad: &mut [f64]) -> f64;
    /// `k̃(Δt)`, gradient, and full symmetric Hessian (row-major `m×m`).
    fn value_grad_hess(&mut self, dt: f64, grad: &mut [f64], hess: &mut [f64]) -> f64;

    /// `k̃(Δx)` for a d-dimensional separation vector. The defaults
    /// delegate to the scalar lag path, so every 1-D kernel evaluates on
    /// d = 1 column layouts unchanged; ARD kernels override all three.
    fn value_nd(&mut self, dx: &[f64]) -> f64 {
        assert_eq!(dx.len(), 1, "scalar kernel given a {}-dim separation", dx.len());
        self.value(dx[0])
    }
    /// `k̃(Δx)` and `∂k̃/∂ϑ` for a d-dimensional separation.
    fn value_grad_nd(&mut self, dx: &[f64], grad: &mut [f64]) -> f64 {
        assert_eq!(dx.len(), 1, "scalar kernel given a {}-dim separation", dx.len());
        self.value_grad(dx[0], grad)
    }
    /// `k̃(Δx)`, gradient, and Hessian for a d-dimensional separation.
    fn value_grad_hess_nd(&mut self, dx: &[f64], grad: &mut [f64], hess: &mut [f64]) -> f64 {
        assert_eq!(dx.len(), 1, "scalar kernel given a {}-dim separation", dx.len());
        self.value_grad_hess(dx[0], grad, hess)
    }
}

/// A complete covariance model in the paper's sense: a stationary kernel
/// plus the fixed fractional noise σ_n (the `σ_f² σ_n² δ_tt'` term of
/// eqs. 3.1–3.2; σ_n is fixed, not learned — see §3: "fixing σ_n is
/// roughly equivalent to specifying a fixed fractional error").
pub struct CovarianceModel {
    /// Display name, e.g. `"k1"`; also the artifact lookup key.
    pub name: String,
    /// The stationary kernel.
    pub kernel: Box<dyn StationaryKernel>,
    /// Fixed noise parameter σ_n (enters the diagonal as σ_n²).
    pub sigma_n: f64,
}

impl CovarianceModel {
    pub fn new(name: impl Into<String>, kernel: Box<dyn StationaryKernel>, sigma_n: f64) -> Self {
        Self { name: name.into(), kernel, sigma_n }
    }

    /// Number of reduced hyperparameters (σ_f profiled out).
    pub fn dim(&self) -> usize {
        self.kernel.dim()
    }

    /// Number of input dimensions the kernel consumes per point.
    pub fn input_dim(&self) -> usize {
        self.kernel.input_dim()
    }

    /// σ_n² — the diagonal noise contribution in σ_f = 1 units.
    pub fn noise_variance(&self) -> f64 {
        self.sigma_n * self.sigma_n
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;

    /// Finite-difference check of a kernel's gradient and Hessian at one
    /// (dt, theta) point. Central differences, step scaled per-parameter.
    pub fn check_derivatives(kernel: &dyn StationaryKernel, dt: f64, theta: &[f64], tol: f64) {
        let m = kernel.dim();
        let mut grad = vec![0.0; m];
        let mut hess = vec![0.0; m * m];
        let v0 = kernel.prepare(theta).value_grad_hess(dt, &mut grad, &mut hess);
        // value consistency across the three entry points
        let v1 = kernel.prepare(theta).value(dt);
        let mut g2 = vec![0.0; m];
        let v2 = kernel.prepare(theta).value_grad(dt, &mut g2);
        assert!((v0 - v1).abs() <= 1e-14 * v1.abs().max(1e-14), "value mismatch: {v0} vs {v1}");
        assert!((v0 - v2).abs() <= 1e-14 * v1.abs().max(1e-14));
        for i in 0..m {
            assert!(
                (grad[i] - g2[i]).abs() <= 1e-12 * grad[i].abs().max(1e-12),
                "grad entry {i} differs between value_grad and value_grad_hess"
            );
        }
        // FD gradient
        for i in 0..m {
            let h = 1e-6 * theta[i].abs().max(0.05);
            let mut tp = theta.to_vec();
            let mut tm = theta.to_vec();
            tp[i] += h;
            tm[i] -= h;
            let fp = kernel.prepare(&tp).value(dt);
            let fm = kernel.prepare(&tm).value(dt);
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                crate::math::rel_diff(grad[i], fd) < tol,
                "grad[{i}] at dt={dt}: analytic {} vs FD {}",
                grad[i],
                fd
            );
        }
        // FD Hessian from analytic gradients (more stable than 2nd FD)
        for i in 0..m {
            let h = 1e-6 * theta[i].abs().max(0.05);
            let mut tp = theta.to_vec();
            let mut tm = theta.to_vec();
            tp[i] += h;
            tm[i] -= h;
            let mut gp = vec![0.0; m];
            let mut gm = vec![0.0; m];
            kernel.prepare(&tp).value_grad(dt, &mut gp);
            kernel.prepare(&tm).value_grad(dt, &mut gm);
            for j in 0..m {
                let fd = (gp[j] - gm[j]) / (2.0 * h);
                assert!(
                    crate::math::rel_diff(hess[i * m + j], fd) < tol,
                    "hess[{i},{j}] at dt={dt}: analytic {} vs FD {}",
                    hess[i * m + j],
                    fd
                );
                // symmetry
                assert!(
                    (hess[i * m + j] - hess[j * m + i]).abs()
                        <= 1e-10 * hess[i * m + j].abs().max(1e-10),
                    "hessian not symmetric at ({i},{j})"
                );
            }
        }
    }
}
