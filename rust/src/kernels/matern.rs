//! Matérn-3/2 and Matérn-5/2 factors with flat coordinate `φ = ln L`.
//!
//! Both are expressed through `z = √ν̃ |Δt| e^{−φ}` (ν̃ = 3 or 5) and the
//! chain rule `∂z/∂φ = −z`, giving for `f(z) = ln F`:
//!   `L_φ = −z f′(z)`, `M_φφ = z f′(z) + z² f″(z)`.

use super::{DataSpan, Factor, PreparedFactor};

/// Matérn ν = 3/2: `F = (1+z) e^{−z}`, `z = √3 |Δt|/L`.
///
/// `f′(z) = −z/(1+z)`, `f″(z) = −1/(1+z)²`.
#[derive(Clone, Copy, Debug)]
pub struct Matern32 {
    pub index: usize,
}

/// Matérn ν = 5/2: `F = (1+z+z²/3) e^{−z}`, `z = √5 |Δt|/L`.
///
/// With `D = 1+z+z²/3`: `f′ = −z(1+z)/(3D)`,
/// `f″ = (n′D − nD′)/D²` for `n = −z(1+z)/3`, `n′ = −(1+2z)/3`, `D′ = 1+2z/3`.
#[derive(Clone, Copy, Debug)]
pub struct Matern52 {
    pub index: usize,
}

impl Matern32 {
    pub fn new(index: usize) -> Self {
        Self { index }
    }
}

impl Matern52 {
    pub fn new(index: usize) -> Self {
        Self { index }
    }
}

macro_rules! matern_factor_impl {
    ($ty:ident, $prep:ident, $label:expr) => {
        impl Factor for $ty {
            fn dim(&self) -> usize {
                1
            }

            fn names(&self) -> Vec<String> {
                vec![format!(concat!("phi", $label, "{}"), self.index)]
            }

            fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)> {
                vec![span.phi_bounds()]
            }

            fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedFactor> {
                assert_eq!(theta.len(), 1);
                Box::new($prep { inv_l: (-theta[0]).exp() })
            }
        }
    };
}

matern_factor_impl!(Matern32, PreparedM32, "M32_");
matern_factor_impl!(Matern52, PreparedM52, "M52_");

struct PreparedM32 {
    inv_l: f64,
}

impl PreparedM32 {
    #[inline]
    fn z(&self, dt: f64) -> f64 {
        3f64.sqrt() * dt.abs() * self.inv_l
    }
}

impl PreparedFactor for PreparedM32 {
    fn value(&self, dt: f64) -> f64 {
        let z = self.z(dt);
        (1.0 + z) * (-z).exp()
    }

    fn value_dlog(&self, dt: f64, dlog: &mut [f64]) -> f64 {
        let z = self.z(dt);
        dlog[0] = z * z / (1.0 + z);
        (1.0 + z) * (-z).exp()
    }

    fn value_dlog2(&self, dt: f64, dlog: &mut [f64], d2log: &mut [f64]) -> f64 {
        let z = self.z(dt);
        let fp = -z / (1.0 + z);
        let fpp = -1.0 / ((1.0 + z) * (1.0 + z));
        dlog[0] = -z * fp;
        d2log[0] = z * fp + z * z * fpp;
        (1.0 + z) * (-z).exp()
    }
}

struct PreparedM52 {
    inv_l: f64,
}

impl PreparedM52 {
    #[inline]
    fn z(&self, dt: f64) -> f64 {
        5f64.sqrt() * dt.abs() * self.inv_l
    }
}

impl PreparedFactor for PreparedM52 {
    fn value(&self, dt: f64) -> f64 {
        let z = self.z(dt);
        (1.0 + z + z * z / 3.0) * (-z).exp()
    }

    fn value_dlog(&self, dt: f64, dlog: &mut [f64]) -> f64 {
        let z = self.z(dt);
        let d = 1.0 + z + z * z / 3.0;
        let fp = -z * (1.0 + z) / (3.0 * d);
        dlog[0] = -z * fp;
        d * (-z).exp()
    }

    fn value_dlog2(&self, dt: f64, dlog: &mut [f64], d2log: &mut [f64]) -> f64 {
        let z = self.z(dt);
        let d = 1.0 + z + z * z / 3.0;
        let n = -z * (1.0 + z) / 3.0;
        let np = -(1.0 + 2.0 * z) / 3.0;
        let dp = 1.0 + 2.0 * z / 3.0;
        let fp = n / d;
        let fpp = (np * d - n * dp) / (d * d);
        dlog[0] = -z * fp;
        d2log[0] = z * fp + z * z * fpp;
        d * (-z).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_factor(f: &dyn Factor, phis: &[f64], dts: &[f64]) {
        for &phi in phis {
            for &dt in dts {
                let p = f.prepare(&[phi]);
                let mut dl = [0.0];
                let mut d2 = [0.0];
                let v = p.value_dlog2(dt, &mut dl, &mut d2);
                assert!(v > 0.0 && v <= 1.0);
                let h = 1e-6;
                let lp = f.prepare(&[phi + h]).value(dt).ln();
                let lm = f.prepare(&[phi - h]).value(dt).ln();
                let fd1 = (lp - lm) / (2.0 * h);
                let fd2 = (lp - 2.0 * v.ln() + lm) / (h * h);
                assert!(
                    crate::math::rel_diff(dl[0], fd1) < 1e-5,
                    "dlog {} vs {fd1} at dt={dt} phi={phi}",
                    dl[0]
                );
                assert!(
                    crate::math::rel_diff(d2[0], fd2) < 1e-3,
                    "d2log {} vs {fd2} at dt={dt} phi={phi}",
                    d2[0]
                );
            }
        }
    }

    #[test]
    fn matern32_derivs() {
        check_factor(&Matern32::new(1), &[0.0, 1.0, 2.3], &[0.3, 1.0, 4.0]);
    }

    #[test]
    fn matern52_derivs() {
        check_factor(&Matern52::new(1), &[0.0, 1.0, 2.3], &[0.3, 1.0, 4.0]);
    }

    #[test]
    fn values_at_zero_lag() {
        assert!((Matern32::new(1).prepare(&[0.5]).value(0.0) - 1.0).abs() < 1e-15);
        assert!((Matern52::new(1).prepare(&[0.5]).value(0.0) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn m52_smoother_than_m32_at_origin() {
        // near 0 lag, M52 should decay more slowly (it is twice mean-square
        // differentiable, M32 only once)
        let m32 = Matern32::new(1).prepare(&[0.0]);
        let m52 = Matern52::new(1).prepare(&[0.0]);
        assert!(m52.value(0.05) > m32.value(0.05));
    }
}
