//! Periodic factor `exp[−(2/l²) sin²(πΔt/T)]` (MacKay 2003; the paper's
//! eqs. 3.1–3.2), in flat-prior coordinates `(φ, ξ)`:
//!
//! * `T = e^φ` — timescale with Jeffreys → flat transform (eq. 3.4);
//! * `l = exp(μ + √2 σ_l erf⁻¹(2ξ))`, `ξ ∈ (−½, ½)` — smoothness with
//!   log-normal → flat transform (eq. 3.5); paper uses μ = 1, σ_l² = 4.
//!
//! Log-derivatives (a = πΔt/T, s = sin a, c_l = 2/l²):
//!   ln F          = −c_l s²
//!   ∂lnF/∂φ       =  c_l a sin 2a
//!   ∂lnF/∂ξ       =  2 c_l s² (l′/l)
//!   ∂²lnF/∂φ²     = −c_l a (sin 2a + 2a cos 2a)
//!   ∂²lnF/∂φ∂ξ    = −2 c_l a sin 2a (l′/l)
//!   ∂²lnF/∂ξ²     =  4 s² (3 l′²/l⁴ − l″/l³) · (−1)  [see code]
//! where `l′ = dl/dξ = l σ_l √(2π) e^{w²}`, `w = erf⁻¹(2ξ)`, and
//! `l″ = l(g′² + g″)` with `g′ = σ_l√(2π)e^{w²}`, `g″ = 2√2 π σ_l w e^{2w²}`.

use super::{DataSpan, Factor, PreparedFactor};
use crate::math::erfinv;

/// Paper defaults for the log-normal prior on `l` (§3: μ = 1, σ_l² = 4).
pub const DEFAULT_MU_L: f64 = 1.0;
pub const DEFAULT_SIGMA_L: f64 = 2.0;

/// Margin keeping `ξ` away from ±½ where `erf⁻¹(2ξ)` diverges.
pub const XI_MARGIN: f64 = 1e-6;

/// A periodic factor with hyperparameters `(φ_j, ξ_j)`.
#[derive(Clone, Copy, Debug)]
pub struct Periodic {
    /// Index `j` used only for parameter naming (`phi1`, `xi1`, …).
    pub index: usize,
    /// Log-normal prior mean μ of `ln l`.
    pub mu_l: f64,
    /// Log-normal prior width σ_l of `ln l`.
    pub sigma_l: f64,
}

impl Periodic {
    pub fn new(index: usize) -> Self {
        Self { index, mu_l: DEFAULT_MU_L, sigma_l: DEFAULT_SIGMA_L }
    }

    /// The flat→physical transform `l(ξ)` of eq. (3.5).
    pub fn l_of_xi(&self, xi: f64) -> f64 {
        (self.mu_l + std::f64::consts::SQRT_2 * self.sigma_l * erfinv(2.0 * xi)).exp()
    }
}

impl Factor for Periodic {
    fn dim(&self) -> usize {
        2
    }

    fn names(&self) -> Vec<String> {
        vec![format!("phi{}", self.index), format!("xi{}", self.index)]
    }

    fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)> {
        vec![span.phi_bounds(), (-0.5 + XI_MARGIN, 0.5 - XI_MARGIN)]
    }

    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedFactor> {
        assert_eq!(theta.len(), 2);
        let (phi, xi) = (theta[0], theta[1]);
        let w = erfinv(2.0 * xi);
        let ew2 = (w * w).exp();
        let gp = self.sigma_l * (2.0 * std::f64::consts::PI).sqrt() * ew2; // g′ = dln l/dξ
        let gpp = 2.0 * std::f64::consts::SQRT_2 * std::f64::consts::PI * self.sigma_l * w
            * ew2
            * ew2; // g″
        let l = (self.mu_l + std::f64::consts::SQRT_2 * self.sigma_l * w).exp();
        Box::new(PreparedPeriodic {
            pi_inv_t: std::f64::consts::PI * (-phi).exp(),
            c_l: 2.0 / (l * l),
            dlog_l: gp,           // l′/l
            d2log_l: gp * gp + gpp, // l″/l
        })
    }
}

struct PreparedPeriodic {
    /// π/T.
    pi_inv_t: f64,
    /// 2/l².
    c_l: f64,
    /// l′/l.
    dlog_l: f64,
    /// l″/l.
    d2log_l: f64,
}

impl PreparedFactor for PreparedPeriodic {
    fn value(&self, dt: f64) -> f64 {
        let s = (dt * self.pi_inv_t).sin();
        (-self.c_l * s * s).exp()
    }

    fn value_dlog(&self, dt: f64, dlog: &mut [f64]) -> f64 {
        let a = dt * self.pi_inv_t;
        let (s, c) = a.sin_cos();
        let s2 = s * s;
        let sin2a = 2.0 * s * c;
        dlog[0] = self.c_l * a * sin2a;
        dlog[1] = 2.0 * self.c_l * s2 * self.dlog_l;
        (-self.c_l * s2).exp()
    }

    fn value_dlog2(&self, dt: f64, dlog: &mut [f64], d2log: &mut [f64]) -> f64 {
        let a = dt * self.pi_inv_t;
        let (s, c) = a.sin_cos();
        let s2 = s * s;
        let sin2a = 2.0 * s * c;
        let cos2a = 1.0 - 2.0 * s2;
        dlog[0] = self.c_l * a * sin2a;
        dlog[1] = 2.0 * self.c_l * s2 * self.dlog_l;
        // ∂²lnF/∂φ² : d(c_l a sin2a)/dφ with da/dφ = −a
        d2log[0] = -self.c_l * a * (sin2a + 2.0 * a * cos2a);
        // ∂²lnF/∂φ∂ξ : c_l depends on ξ through l: d(c_l)/dξ = −2 c_l l′/l
        let cross = -2.0 * self.c_l * a * sin2a * self.dlog_l;
        d2log[1] = cross;
        d2log[2] = cross;
        // ∂²lnF/∂ξ² : lnF = −2 s²/l² ⇒ ∂ξ lnF = 4 s² l′/l³ (=2 c_l s² l′/l)
        //   ∂²ξ lnF = 4 s² (l″/l³ − 3 l′²/l⁴) = 2 c_l s² (l″/l − 3 (l′/l)²)
        d2log[3] = 2.0 * self.c_l * s2 * (self.d2log_l - 3.0 * self.dlog_l * self.dlog_l);
        (-self.c_l * s2).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l_transform_paper_values() {
        let p = Periodic::new(1);
        // ξ = 0 → l = e^μ = e
        assert!((p.l_of_xi(0.0) - std::f64::consts::E).abs() < 1e-12);
        // transform is monotonic
        assert!(p.l_of_xi(0.2) > p.l_of_xi(0.0));
        assert!(p.l_of_xi(-0.2) < p.l_of_xi(0.0));
    }

    #[test]
    fn value_periodicity() {
        let p = Periodic::new(1);
        let f = p.prepare(&[1.2, 0.1]); // T = e^1.2
        let t = 1.2f64.exp();
        for &dt in &[0.3, 1.7, 5.0] {
            assert!((f.value(dt) - f.value(dt + t)).abs() < 1e-12);
            assert!((f.value(dt) - f.value(-dt)).abs() < 1e-15);
        }
        assert!((f.value(0.0) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn log_derivs_match_fd() {
        let p = Periodic::new(1);
        for &(dt, phi, xi) in &[
            (0.7, 1.5, 0.0),
            (3.1, 1.5, 0.23),
            (1.0, 0.4, -0.31),
            (12.0, 2.5, 0.45),
        ] {
            let f = p.prepare(&[phi, xi]);
            let mut dl = [0.0; 2];
            let mut d2 = [0.0; 4];
            let v = f.value_dlog2(dt, &mut dl, &mut d2);
            assert!(v > 0.0);
            let h = 1e-6;
            // FD of ln value w.r.t. each parameter
            for i in 0..2 {
                let mut tp = [phi, xi];
                let mut tm = [phi, xi];
                tp[i] += h;
                tm[i] -= h;
                let lp = p.prepare(&tp).value(dt).ln();
                let lm = p.prepare(&tm).value(dt).ln();
                let fd = (lp - lm) / (2.0 * h);
                assert!(
                    crate::math::rel_diff(dl[i], fd) < 1e-5,
                    "dlog[{i}] at ({dt},{phi},{xi}): {} vs {fd}",
                    dl[i]
                );
            }
            // FD of the dlog vector for the Hessian of ln F
            for i in 0..2 {
                let mut tp = [phi, xi];
                let mut tm = [phi, xi];
                tp[i] += h;
                tm[i] -= h;
                let mut glp = [0.0; 2];
                let mut glm = [0.0; 2];
                p.prepare(&tp).value_dlog(dt, &mut glp);
                p.prepare(&tm).value_dlog(dt, &mut glm);
                for j in 0..2 {
                    let fd = (glp[j] - glm[j]) / (2.0 * h);
                    assert!(
                        crate::math::rel_diff(d2[i * 2 + j], fd) < 1e-4,
                        "d2log[{i},{j}] at ({dt},{phi},{xi}): {} vs {fd}",
                        d2[i * 2 + j]
                    );
                }
            }
        }
    }

    #[test]
    fn hessian_is_symmetric() {
        let p = Periodic::new(2);
        let f = p.prepare(&[2.0, 0.17]);
        let mut dl = [0.0; 2];
        let mut d2 = [0.0; 4];
        f.value_dlog2(4.2, &mut dl, &mut d2);
        assert_eq!(d2[1], d2[2]);
    }
}
