//! Compact-support Wendland ψ₃,₂ factor — the `C(|t−t'|/T₀)` term of the
//! paper's k₁/k₂ (eqs. 3.1–3.3, with the erratum fix described in the
//! module docs of [`crate::kernels`]).
//!
//! `C(τ) = (1−τ)₊⁶ (35τ² + 18τ + 3) / 3`, `τ = |Δt| / T₀`, `T₀ = e^{φ₀}`.
//!
//! Derivatives (hand-derived, FD-validated in the tests):
//! `C'(τ)  = −(56/3) τ (5τ+1) (1−τ)⁵`
//! `C''(τ) =  (56/3) (1−τ)⁴ (35τ² − 4τ − 1)`
//! and in the flat coordinate `φ₀ = ln T₀` (so `∂τ/∂φ₀ = −τ`):
//! `L ≡ ∂lnC/∂φ₀ = −τ C'/C`,
//! `M ≡ ∂²lnC/∂φ₀² = τ u + τ² (C''/C − u²)`, `u = C'/C`.

use super::{DataSpan, Factor, PreparedFactor};

/// Wendland ψ₃,₂ compact-support factor with hyperparameter `φ₀ = ln T₀`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Wendland;

/// `C(τ)` — exposed for the data generators and the python oracle tests.
pub fn wendland_c(tau: f64) -> f64 {
    if tau >= 1.0 {
        return 0.0;
    }
    let om = 1.0 - tau;
    let om2 = om * om;
    let om6 = om2 * om2 * om2;
    om6 * (35.0 * tau * tau + 18.0 * tau + 3.0) / 3.0
}

/// `C'(τ)`.
pub fn wendland_c1(tau: f64) -> f64 {
    if tau >= 1.0 {
        return 0.0;
    }
    let om = 1.0 - tau;
    let om2 = om * om;
    let om5 = om2 * om2 * om;
    -(56.0 / 3.0) * tau * (5.0 * tau + 1.0) * om5
}

/// `C''(τ)`.
pub fn wendland_c2(tau: f64) -> f64 {
    if tau >= 1.0 {
        return 0.0;
    }
    let om = 1.0 - tau;
    let om2 = om * om;
    let om4 = om2 * om2;
    (56.0 / 3.0) * om4 * (35.0 * tau * tau - 4.0 * tau - 1.0)
}

impl Factor for Wendland {
    fn dim(&self) -> usize {
        1
    }

    fn names(&self) -> Vec<String> {
        vec!["phi0".to_string()]
    }

    fn bounds(&self, span: &DataSpan) -> Vec<(f64, f64)> {
        vec![span.phi_bounds()]
    }

    fn prepare(&self, theta: &[f64]) -> Box<dyn PreparedFactor> {
        assert_eq!(theta.len(), 1);
        Box::new(PreparedWendland { inv_t0: (-theta[0]).exp() })
    }
}

struct PreparedWendland {
    inv_t0: f64,
}

impl PreparedFactor for PreparedWendland {
    fn value(&self, dt: f64) -> f64 {
        wendland_c(dt.abs() * self.inv_t0)
    }

    fn value_dlog(&self, dt: f64, dlog: &mut [f64]) -> f64 {
        let tau = dt.abs() * self.inv_t0;
        let c = wendland_c(tau);
        if c == 0.0 {
            dlog[0] = 0.0;
            return 0.0;
        }
        dlog[0] = -tau * wendland_c1(tau) / c;
        c
    }

    fn value_dlog2(&self, dt: f64, dlog: &mut [f64], d2log: &mut [f64]) -> f64 {
        let tau = dt.abs() * self.inv_t0;
        let c = wendland_c(tau);
        if c == 0.0 {
            dlog[0] = 0.0;
            d2log[0] = 0.0;
            return 0.0;
        }
        let u = wendland_c1(tau) / c;
        dlog[0] = -tau * u;
        d2log[0] = tau * u + tau * tau * (wendland_c2(tau) / c - u * u);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_endpoints() {
        assert!((wendland_c(0.0) - 1.0).abs() < 1e-15);
        assert_eq!(wendland_c(1.0), 0.0);
        assert_eq!(wendland_c(1.5), 0.0);
        // strictly decreasing on (0, 1)
        let mut prev = 1.0;
        for i in 1..=100 {
            let v = wendland_c(i as f64 / 100.0);
            assert!(v < prev);
            prev = v;
        }
    }

    #[test]
    fn derivative_polynomials_match_fd() {
        for &tau in &[0.01, 0.1, 0.35, 0.5, 0.77, 0.95] {
            let h = 1e-7;
            let fd1 = (wendland_c(tau + h) - wendland_c(tau - h)) / (2.0 * h);
            assert!(
                crate::math::rel_diff(wendland_c1(tau), fd1) < 1e-6,
                "C' at {tau}: {} vs {fd1}",
                wendland_c1(tau)
            );
            let fd2 = (wendland_c1(tau + h) - wendland_c1(tau - h)) / (2.0 * h);
            assert!(
                crate::math::rel_diff(wendland_c2(tau), fd2) < 1e-6,
                "C'' at {tau}: {} vs {fd2}",
                wendland_c2(tau)
            );
        }
    }

    #[test]
    fn smooth_at_support_boundary() {
        // C, C', C'' all → 0 as τ → 1⁻ (6th-order zero)
        assert!(wendland_c(1.0 - 1e-8) < 1e-40);
        assert!(wendland_c1(1.0 - 1e-8).abs() < 1e-30);
        assert!(wendland_c2(1.0 - 1e-8).abs() < 1e-25);
    }

    #[test]
    fn log_derivs_match_fd_in_phi() {
        let w = Wendland;
        for &(dt, phi) in &[(1.0, 1.0), (3.0, 1.5), (0.5, 0.0), (2.0, 0.9)] {
            let h = 1e-6;
            let f0 = w.prepare(&[phi]);
            let mut dl = [0.0];
            let mut d2 = [0.0];
            let v = f0.value_dlog2(dt, &mut dl, &mut d2);
            assert!(v > 0.0, "inside support expected");
            let lp = w.prepare(&[phi + h]).value(dt).ln();
            let lm = w.prepare(&[phi - h]).value(dt).ln();
            let l0 = v.ln();
            let fd1 = (lp - lm) / (2.0 * h);
            let fd2 = (lp - 2.0 * l0 + lm) / (h * h);
            assert!(crate::math::rel_diff(dl[0], fd1) < 1e-5, "{} vs {fd1}", dl[0]);
            assert!(crate::math::rel_diff(d2[0], fd2) < 1e-3, "{} vs {fd2}", d2[0]);
        }
    }

    #[test]
    fn outside_support_returns_zero_everywhere() {
        let w = Wendland;
        let p = w.prepare(&[0.0]); // T0 = 1
        let mut dl = [9.0];
        let mut d2 = [9.0];
        assert_eq!(p.value_dlog2(2.0, &mut dl, &mut d2), 0.0);
        assert_eq!(dl[0], 0.0);
        assert_eq!(d2[0], 0.0);
    }

    /// The erratum check: the *published* polynomial (1−τ)⁵(48τ²+15τ+3)/3
    /// is not positive definite on a regular grid, while the Wendland
    /// ψ₃,₂ we implement is (smallest eigenvalue ≥ 0 up to round-off).
    #[test]
    fn published_polynomial_is_indefinite_wendland_is_not() {
        use crate::linalg::{sym_eigen, Matrix};
        let published = |tau: f64| -> f64 {
            if tau >= 1.0 {
                0.0
            } else {
                (1.0 - tau).powi(5) * (48.0 * tau * tau + 15.0 * tau + 3.0) / 3.0
            }
        };
        let n = 60;
        let t0 = 20.0;
        let build = |f: &dyn Fn(f64) -> f64| {
            let mut k = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    k[(i, j)] = f((i as f64 - j as f64).abs() / t0);
                }
            }
            k
        };
        let (ev_pub, _) = sym_eigen(&build(&published));
        let (ev_wend, _) = sym_eigen(&build(&|tau| wendland_c(tau)));
        assert!(ev_pub[0] < -1e-3, "published poly should be indefinite, min eig {}", ev_pub[0]);
        assert!(ev_wend[0] > -1e-10, "wendland should be PSD, min eig {}", ev_wend[0]);
    }
}
