//! The training coordinator — layer 3 of the stack.
//!
//! Orchestrates the paper's full workflow:
//!
//! 1. for each candidate covariance function, run a **multistart
//!    conjugate-gradient maximisation** of the profiled hyperlikelihood
//!    (§2(b), §3(a): ~10 restarts, <100 evaluations per run);
//! 2. at the best peak, evaluate the **analytic Hessian** (eq. 2.19) and
//!    assemble the **Laplace hyperevidence** (eq. 2.13);
//! 3. rank models by ln Z, reporting Bayes factors and hyperparameter
//!    error bars (inverse-Hessian diagonal);
//! 4. optionally verify with the **nested-sampling baseline** — the
//!    paper's MULTINEST comparison, at 20,000–50,000 likelihood
//!    evaluations vs ~10×100 for the fast path;
//! 5. hand the ranked [`TrainedModel`] artifacts to the **serving
//!    layer** ([`serve`]): a [`ServeSession`] routes queries across the
//!    cached factors — the evidence winner by default, optionally
//!    evidence-weighted model averaging — and absorbs streamed
//!    observations with per-model drift monitoring.
//!
//! Steps 1–4 are one call since the tournament refactor:
//! [`tournament::Tournament::run`] trains the whole [`registry::Roster`]
//! (lineage-ordered, concurrently within a generation, under one shared
//! thread budget), attaches every Laplace evidence, and returns the
//! ranked artifacts plus the Bayes-factor report.
//! [`ComparisonPipeline`] remains as a thin wrapper over it.
//!
//! Multistart restarts fan out over a [`pool::WorkerPool`]; each worker
//! owns a native backend (PJRT handles are not `Send`), while artifact-
//! accelerated assembly runs on the coordinator thread.

pub mod artifact;
pub mod artifact_v4;
pub mod faults;
pub mod fleet;
pub mod pool;
pub mod registry;
pub mod serve;
pub mod tournament;
pub mod train;
mod report;

pub use artifact_v4::{ArtifactView, FSlice, VERSION_V4};
pub use faults::{Fault, FaultPlan};
pub use fleet::{
    AlignedBlob, ArtifactStore, DiskStore, Fleet, FleetStats, MemoryStore, PredictRequest,
    ZipfWorkload,
};
pub use pool::WorkerPool;
pub use registry::{ModelSpec, Roster};
pub use report::{ComparisonReport, ModelReport, NestedReport};
pub use serve::{
    DriftOptions, DriftStatus, FactorHealth, RetrainOutcome, RouteMode, ServeSession,
    WindowPolicy, COND_RETRAIN_LIMIT,
};
pub use tournament::{Tournament, TournamentResult, TrainedModel};
pub use train::{train_model, train_model_seeded, TrainOptions, TrainResult};

use crate::data::Dataset;
use crate::nested::NestedOptions;
use crate::priors::ScalePrior;
use crate::rng::Xoshiro256;
use crate::runtime::ExecutionContext;

/// Configuration of a model-comparison pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Models to compare (default: the paper's k₁ vs k₂).
    pub models: Vec<ModelSpec>,
    /// Fixed noise σ_n.
    pub sigma_n: f64,
    /// Training options (restarts, CG tolerances).
    pub train: TrainOptions,
    /// σ_f prior for the evidence normalisation.
    pub scale_prior: ScalePrior,
    /// Also run the nested-sampling verification (expensive).
    pub run_nested: bool,
    /// Nested-sampling options.
    pub nested: NestedOptions,
    /// Worker threads for multistart fan-out.
    pub workers: usize,
    /// Thread budget for the linalg/assembly hot paths; restarts running
    /// concurrently split it (the borrowed-slots rule of
    /// [`crate::runtime::exec`]).
    pub exec: ExecutionContext,
}

impl PipelineConfig {
    /// The paper's §3(a) configuration.
    pub fn paper_synthetic() -> Self {
        Self {
            models: vec![ModelSpec::K1, ModelSpec::K2],
            sigma_n: crate::kernels::SYNTHETIC_SIGMA_N,
            train: TrainOptions::default(),
            scale_prior: ScalePrior::default(),
            run_nested: false,
            nested: NestedOptions::default(),
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            exec: ExecutionContext::from_env(),
        }
    }

    /// Cheap settings for tests/doc examples.
    pub fn fast() -> Self {
        let mut c = Self::paper_synthetic();
        c.train.multistart.restarts = 3;
        c.workers = 2;
        c
    }
}

/// The model-comparison pipeline — a thin wrapper over
/// [`tournament::Tournament`] kept for callers that only want the ranked
/// report (the tournament additionally returns the [`TrainedModel`]
/// artifacts the serving router adopts).
pub struct ComparisonPipeline {
    pub config: PipelineConfig,
}

impl ComparisonPipeline {
    pub fn new(config: PipelineConfig) -> Self {
        Self { config }
    }

    /// Run the full compare workflow on a dataset.
    pub fn run(&mut self, data: &Dataset, rng: &mut Xoshiro256) -> crate::Result<ComparisonReport> {
        Ok(Tournament::new(self.config.clone()).run(data, rng)?.report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::table1_dataset;

    #[test]
    fn pipeline_ranks_k2_on_k2_data() {
        // n=60 from k2 truth: k2 should win (the Table-1 trend) — but on
        // small n the decision can be marginal; we assert structure, not
        // the winner.
        let data = table1_dataset(60, 0.1, 12345);
        let mut pipeline = ComparisonPipeline::new(PipelineConfig::fast());
        let mut rng = Xoshiro256::seed_from_u64(99);
        let report = pipeline.run(&data, &mut rng).unwrap();
        assert_eq!(report.models.len(), 2);
        // ranked by ln_z descending
        assert!(report.models[0].ln_z >= report.models[1].ln_z);
        // both models trained: peaks are finite, σ̂_f near 1
        for m in &report.models {
            assert!(m.lnp_peak.is_finite());
            assert!(m.sigma_f_hat > 0.05 && m.sigma_f_hat < 20.0);
            assert_eq!(m.param_names.len(), m.theta_hat.len());
            assert!(m.n_evals > 0);
        }
        let lnb = report.ln_bayes("k2", "k1").unwrap();
        assert!(lnb.is_finite());
    }

    #[test]
    fn pipeline_errors_on_empty_models() {
        let mut cfg = PipelineConfig::fast();
        cfg.models.clear();
        let data = table1_dataset(20, 0.1, 1);
        let mut rng = Xoshiro256::seed_from_u64(1);
        assert!(ComparisonPipeline::new(cfg).run(&data, &mut rng).is_err());
    }
}
