//! The training coordinator — layer 3 of the stack.
//!
//! Orchestrates the paper's full workflow:
//!
//! 1. for each candidate covariance function, run a **multistart
//!    conjugate-gradient maximisation** of the profiled hyperlikelihood
//!    (§2(b), §3(a): ~10 restarts, <100 evaluations per run);
//! 2. at the best peak, evaluate the **analytic Hessian** (eq. 2.19) and
//!    assemble the **Laplace hyperevidence** (eq. 2.13);
//! 3. rank models by ln Z, reporting Bayes factors and hyperparameter
//!    error bars (inverse-Hessian diagonal);
//! 4. optionally verify with the **nested-sampling baseline** — the
//!    paper's MULTINEST comparison, at 20,000–50,000 likelihood
//!    evaluations vs ~10×100 for the fast path;
//! 5. hand the winning model to the **serving layer** ([`serve`]): a
//!    [`ServeSession`] caches the factor from training and serves batched
//!    predictions / streaming observation appends without refactorising.
//!
//! Multistart restarts fan out over a [`pool::WorkerPool`]; each worker
//! owns a native backend (PJRT handles are not `Send`), while artifact-
//! accelerated assembly runs on the coordinator thread.

pub mod pool;
pub mod registry;
pub mod serve;
pub mod train;
mod report;

pub use pool::WorkerPool;
pub use registry::ModelSpec;
pub use report::{ComparisonReport, ModelReport, NestedReport};
pub use serve::ServeSession;
pub use train::{train_model, TrainOptions, TrainResult};

use crate::data::Dataset;
use crate::evidence::laplace_evidence;
use crate::nested::{nested_sample, NestedOptions};
use crate::priors::{BoxPrior, ScalePrior};
use crate::rng::Xoshiro256;
use crate::runtime::ExecutionContext;
use crate::util::Stopwatch;

/// Configuration of a model-comparison pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Models to compare (default: the paper's k₁ vs k₂).
    pub models: Vec<ModelSpec>,
    /// Fixed noise σ_n.
    pub sigma_n: f64,
    /// Training options (restarts, CG tolerances).
    pub train: TrainOptions,
    /// σ_f prior for the evidence normalisation.
    pub scale_prior: ScalePrior,
    /// Also run the nested-sampling verification (expensive).
    pub run_nested: bool,
    /// Nested-sampling options.
    pub nested: NestedOptions,
    /// Worker threads for multistart fan-out.
    pub workers: usize,
    /// Thread budget for the linalg/assembly hot paths; restarts running
    /// concurrently split it (the borrowed-slots rule of
    /// [`crate::runtime::exec`]).
    pub exec: ExecutionContext,
}

impl PipelineConfig {
    /// The paper's §3(a) configuration.
    pub fn paper_synthetic() -> Self {
        Self {
            models: vec![ModelSpec::K1, ModelSpec::K2],
            sigma_n: crate::kernels::SYNTHETIC_SIGMA_N,
            train: TrainOptions::default(),
            scale_prior: ScalePrior::default(),
            run_nested: false,
            nested: NestedOptions::default(),
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            exec: ExecutionContext::from_env(),
        }
    }

    /// Cheap settings for tests/doc examples.
    pub fn fast() -> Self {
        let mut c = Self::paper_synthetic();
        c.train.multistart.restarts = 3;
        c.workers = 2;
        c
    }
}

/// The model-comparison pipeline.
pub struct ComparisonPipeline {
    pub config: PipelineConfig,
}

impl ComparisonPipeline {
    pub fn new(config: PipelineConfig) -> Self {
        Self { config }
    }

    /// Run the full compare workflow on a dataset.
    pub fn run(&mut self, data: &Dataset, rng: &mut Xoshiro256) -> crate::Result<ComparisonReport> {
        anyhow::ensure!(!self.config.models.is_empty(), "no models configured");
        let span = data.span();
        let mut models = Vec::with_capacity(self.config.models.len());
        // peaks of already-trained models, used to warm-start richer ones
        let mut hints: Vec<(Vec<String>, Vec<f64>)> = Vec::new();
        for spec in &self.config.models {
            let sw = Stopwatch::start();
            let model = spec.build(self.config.sigma_n);
            let prior = BoxPrior::for_model(&model, &span);
            let mut train_opts = self.config.train.clone();
            train_opts
                .extra_starts
                .extend(warm_starts(&model.kernel.names(), &prior, &hints, rng));
            let trained = train_model(
                spec,
                self.config.sigma_n,
                data,
                &train_opts,
                self.config.workers,
                &self.config.exec,
                rng,
            )?;
            // Hessian + Laplace evidence at the peak (full thread budget:
            // nothing else runs concurrently here)
            let hessian = crate::gp::profiled_hessian_with(
                &model,
                &data.t,
                &data.y,
                &trained.theta_hat,
                &self.config.exec,
            )?;
            let ev = laplace_evidence(
                data.len(),
                &prior,
                &self.config.scale_prior,
                &trained.theta_hat,
                trained.lnp_peak,
                &hessian,
            )?;
            let nested = if self.config.run_nested {
                Some(self.run_nested_for(&model, &prior, data, rng)?)
            } else {
                None
            };
            hints.push((model.kernel.names(), trained.theta_hat.clone()));
            models.push(ModelReport {
                name: model.name.clone(),
                param_names: model.kernel.names(),
                theta_hat: trained.theta_hat,
                sigma: ev.sigma.clone(),
                lnp_peak: trained.lnp_peak,
                sigma_f_hat: trained.sigma_f_hat2.sqrt(),
                ln_z: ev.ln_z,
                suspect: ev.suspect || !trained.converged,
                n_evals: trained.n_evals,
                n_modes: trained.n_modes,
                restarts: self.config.train.multistart.restarts,
                wall_secs: sw.elapsed_secs(),
                nested,
            });
        }
        Ok(ComparisonReport::ranked(data.label.clone(), data.len(), models))
    }

    /// Nested-sampling verification over the full (λ, ϑ) unit cube — the
    /// paper's ln Z_num.
    fn run_nested_for(
        &self,
        model: &crate::kernels::CovarianceModel,
        prior: &BoxPrior,
        data: &Dataset,
        rng: &mut Xoshiro256,
    ) -> crate::Result<NestedReport> {
        let sw = Stopwatch::start();
        let dim = prior.dim() + 1; // λ first
        let scale = self.config.scale_prior;
        let mut n_lnp = 0usize;
        let exec = self.config.exec.clone();
        let res = {
            let mut ln_like = |u: &[f64]| -> f64 {
                let lambda = scale.lambda_from_unit(u[0]);
                let theta = prior.from_unit_cube(&u[1..]);
                let mut full = vec![lambda];
                full.extend(theta);
                n_lnp += 1;
                crate::gp::full_lnp_with(model, &data.t, &data.y, &full, &exec)
                    .unwrap_or(f64::NEG_INFINITY)
            };
            nested_sample(dim, &mut ln_like, &self.config.nested, rng)?
        };
        Ok(NestedReport {
            ln_z: res.ln_z,
            ln_z_err: res.ln_z_err,
            n_evals: res.n_evals,
            information: res.information,
            wall_secs: sw.elapsed_secs(),
        })
    }
}

/// Build warm-start candidates for a model from previously trained peaks:
/// parameters are matched **by name** (k₂'s `phi0/phi1/xi1` inherit k₁'s
/// peak), unmatched coordinates are filled from the prior. Three random
/// fills per hint give the new components several basins to start from.
fn warm_starts(
    names: &[String],
    prior: &BoxPrior,
    hints: &[(Vec<String>, Vec<f64>)],
    rng: &mut Xoshiro256,
) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    for (hnames, htheta) in hints {
        let matched: Vec<Option<f64>> = names
            .iter()
            .map(|nm| hnames.iter().position(|h| h == nm).map(|j| htheta[j]))
            .collect();
        if matched.iter().all(Option::is_none) {
            continue;
        }
        for _ in 0..3 {
            let fill = prior.sample(rng);
            let mut start: Vec<f64> = matched
                .iter()
                .zip(&fill)
                .map(|(m, f)| m.unwrap_or(*f))
                .collect();
            prior.project(&mut start);
            out.push(start);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::table1_dataset;

    #[test]
    fn pipeline_ranks_k2_on_k2_data() {
        // n=60 from k2 truth: k2 should win (the Table-1 trend) — but on
        // small n the decision can be marginal; we assert structure, not
        // the winner.
        let data = table1_dataset(60, 0.1, 12345);
        let mut pipeline = ComparisonPipeline::new(PipelineConfig::fast());
        let mut rng = Xoshiro256::seed_from_u64(99);
        let report = pipeline.run(&data, &mut rng).unwrap();
        assert_eq!(report.models.len(), 2);
        // ranked by ln_z descending
        assert!(report.models[0].ln_z >= report.models[1].ln_z);
        // both models trained: peaks are finite, σ̂_f near 1
        for m in &report.models {
            assert!(m.lnp_peak.is_finite());
            assert!(m.sigma_f_hat > 0.05 && m.sigma_f_hat < 20.0);
            assert_eq!(m.param_names.len(), m.theta_hat.len());
            assert!(m.n_evals > 0);
        }
        let lnb = report.ln_bayes("k2", "k1").unwrap();
        assert!(lnb.is_finite());
    }

    #[test]
    fn pipeline_errors_on_empty_models() {
        let mut cfg = PipelineConfig::fast();
        cfg.models.clear();
        let data = table1_dataset(20, 0.1, 1);
        let mut rng = Xoshiro256::seed_from_u64(1);
        assert!(ComparisonPipeline::new(cfg).run(&data, &mut rng).is_err());
    }
}
