//! Artifact format **version 4** — the zero-copy, optionally compressed
//! layout behind the fleet's memory-mapped hydration path.
//!
//! Version 3 (see [`super::artifact`]) decodes every f64 through a
//! bounds-checked byte cursor: structurally safe, but a cache-miss
//! hydration re-copies the `O(n²)` factor one little-endian read at a
//! time before the `O(n²)` α adoption even starts. Version 4 moves the
//! large numeric payloads into a fixed, 8-byte-aligned block section so
//! an aligned buffer (an mmap'd file, or
//! [`super::fleet::AlignedBlob`]'s heap fallback) hydrates by
//! *reinterpreting* the bytes in place:
//!
//! ```text
//! offset   size  field
//! ------   ----  -----------------------------------------------------
//!      0      8  magic  b"GPFASTMD"
//!      8      4  version u32 = 4
//!     12      4  flags u32           (bit 0: compressed factor block)
//!     16      8  n u64               (training points)
//!     24      8  chol_dim u64        (factor dimension; = n for exact specs)
//!     32      8  rank u64            (retained spectral rank; 0 ⇔ packed)
//!     40      8  logdet f64          (maintained log-determinant)
//!     48      8  meta_len u64
//!     56      8  blocks_off u64      (= align8(64 + meta_len))
//!     64      …  meta                (v3-style field stream, small)
//!      …      …  zero padding to blocks_off
//! blocks_off  …  t f64×n | y f64×n | α f64×chol_dim | factor payload
//!      …      4  crc32 u32           (over every preceding byte)
//! ------   ----  -----------------------------------------------------
//! factor payload, rank = 0 (packed):    lower triangle f64×d(d+1)/2
//! factor payload, rank = r (spectral):  λ f64×r (descending)
//!                                     | V f64×(r·d) (row per eigvec)
//!                                     | diag f64×d
//! ```
//!
//! **Alignment contract.** The block section starts at `blocks_off ≡ 0
//! (mod 8)` and contains only consecutive raw little-endian f64s, so if
//! the *buffer base* is 8-byte aligned (mmap pages always are; `Vec<u8>`
//! is not guaranteed to be) every block reinterprets as `&[f64]` with no
//! copy and no decode loop. [`FSlice`] carries the checked-alignment
//! fallback: an unaligned or big-endian buffer still loads, through a
//! one-pass copy. Either way the CRC32 trailer is verified before any
//! field is trusted, the padding bytes must be zero, and every length
//! field is validated against the bytes actually present — corrupt
//! input is a clean `Err`, never UB.
//!
//! **Compression.** With the `compressed` flag the factor block stores a
//! truncated spectral form `K̃ ≈ V_r Λ_r V_rᵀ + diag`
//! ([`crate::linalg::spectral_truncate`]): rank `r` is picked by a
//! relative tail-energy tolerance at encode time, so the artifact goes
//! sublinear in `n²` when the kernel spectrum decays. `t`, `y`, `α` and
//! ϑ̂ are always stored exactly, so predictive **means round-trip
//! bit-identically**; only predictive variances are approximate (the
//! reconstruction is exact on the diagonal, and the variance error is
//! bounded by the discarded tail energy — `O(tol·tr K)` in the absolute
//! covariance). Hydration re-factors the reconstruction (`O(r n²)` +
//! one `O(n³)` Cholesky) — the storage-vs-cost tradeoff of
//! Chalupka/Williams/Murray (arXiv 1205.6326): compression is worth it
//! for cold archival tiers and network-limited stores, not for the hot
//! LRU path, which should persist packed v4 (or v3) factors.

use crate::data::Dataset;
use crate::evidence::LaplaceEvidence;
use crate::gp::ProfiledEval;
use crate::linalg::{spectral_reconstruct, spectral_truncate, Chol, Matrix, SpectralTrunc};

use super::artifact::{crc32, Reader, Writer, MAGIC};
use super::registry::ModelSpec;
use super::report::NestedReport;
use super::tournament::TrainedModel;
use super::train::TrainResult;

/// The version tag in bytes `[8..12)` of a v4 artifact.
pub const VERSION_V4: u32 = 4;
/// Fixed header length; also the (8-aligned) offset of the meta section.
const HEADER_LEN: usize = 64;
/// Flag bit 0: the factor payload is a truncated spectral block.
const FLAG_COMPRESSED: u32 = 1;

fn align8(x: usize) -> usize {
    (x + 7) & !7
}

// ---------------------------------------------------------------- fslice

/// A block of f64s backed either by the artifact buffer itself
/// (zero-copy reinterpretation — the aligned little-endian fast path) or
/// by an owned copy (the checked-alignment fallback). Derefs to `[f64]`
/// so downstream code is agnostic.
pub enum FSlice<'a> {
    /// Borrowed straight from the (8-aligned, little-endian) buffer.
    Borrowed(&'a [f64]),
    /// Copied out byte-by-byte (unaligned buffer or big-endian host).
    Owned(Vec<f64>),
}

impl std::ops::Deref for FSlice<'_> {
    type Target = [f64];

    fn deref(&self) -> &[f64] {
        match self {
            FSlice::Borrowed(s) => s,
            FSlice::Owned(v) => v,
        }
    }
}

impl FSlice<'_> {
    /// `true` when the zero-copy path engaged (no bytes were copied).
    pub fn is_borrowed(&self) -> bool {
        matches!(self, FSlice::Borrowed(_))
    }
}

/// Reinterpret `bytes` (exactly `count * 8` long) as f64s — borrowed
/// when the base pointer is 8-aligned on a little-endian host, copied
/// otherwise. Every bit pattern is a valid `f64`, so no value check is
/// needed for safety (finiteness is validated separately at adopt time).
fn view_f64s(bytes: &[u8], count: usize) -> FSlice<'_> {
    debug_assert_eq!(bytes.len(), count * 8);
    #[cfg(target_endian = "little")]
    {
        let ptr = bytes.as_ptr();
        if (ptr as usize) % std::mem::align_of::<f64>() == 0 {
            // SAFETY: the pointer is 8-byte aligned (checked above), the
            // length is exactly `count` f64s (asserted above), the host
            // is little-endian (cfg-gated) matching the on-disk byte
            // order, and any 8-byte pattern is a valid f64. The borrow
            // inherits `bytes`' lifetime, so the buffer outlives the view.
            let s = unsafe { std::slice::from_raw_parts(ptr as *const f64, count) };
            return FSlice::Borrowed(s);
        }
    }
    let mut out = Vec::with_capacity(count);
    for c in bytes.chunks_exact(8) {
        let mut a = [0u8; 8];
        a.copy_from_slice(c);
        out.push(f64::from_le_bytes(a));
    }
    FSlice::Owned(out)
}

// ---------------------------------------------------------------- meta

/// The decoded small-field section: everything except `t`/`y`/`α`/factor.
struct MetaV4 {
    label: String,
    spec: ModelSpec,
    sigma_n: f64,
    param_names: Vec<String>,
    theta_hat: Vec<f64>,
    lnp_peak: f64,
    sigma_f_hat2: f64,
    converged: bool,
    n_evals: usize,
    n_modes: usize,
    restart_values: Vec<f64>,
    jitter: f64,
    peak_lnp: f64,
    peak_sigma2: f64,
    evidence: LaplaceEvidence,
    nested: Option<NestedReport>,
    warm_started: bool,
    restarts: usize,
    wall_secs: f64,
    /// Scenario-tier input block: extra input columns beyond `t` (empty
    /// for 1-D) and the optional per-point noise vector. Lives in the
    /// meta stream — only the four large canonical blocks (`t`, `y`, `α`,
    /// factor) are zero-copy.
    extra: Vec<Vec<f64>>,
    noise: Option<Vec<f64>>,
}

fn encode_meta(tm: &TrainedModel, data: &Dataset) -> Vec<u8> {
    let mut w = Writer::new();
    w.str(&data.label);
    w.str(tm.spec.name());
    w.f64(tm.sigma_n);
    w.u32(tm.param_names.len() as u32);
    for nm in &tm.param_names {
        w.str(nm);
    }
    w.vec(&tm.train.theta_hat);
    w.f64(tm.train.lnp_peak);
    w.f64(tm.train.sigma_f_hat2);
    w.u8(tm.train.converged as u8);
    w.u64(tm.train.n_evals as u64);
    w.u64(tm.train.n_modes as u64);
    w.vec(&tm.train.restart_values);
    w.f64(tm.train.jitter);
    w.f64(tm.train.peak_eval.lnp);
    w.f64(tm.train.peak_eval.sigma_f_hat2);
    let ev = &tm.evidence;
    w.f64(ev.ln_z);
    w.f64(ev.ln_p_peak);
    w.f64(ev.ln_det_h);
    w.f64(ev.ln_volume);
    w.f64(ev.marg_const);
    w.vec(&ev.sigma);
    w.matrix(&ev.covariance);
    w.u8(ev.suspect as u8);
    match &tm.nested {
        None => w.u8(0),
        Some(nr) => {
            w.u8(1);
            w.f64(nr.ln_z);
            w.f64(nr.ln_z_err);
            w.u64(nr.n_evals as u64);
            w.f64(nr.information);
            w.f64(nr.wall_secs);
        }
    }
    w.u8(tm.warm_started as u8);
    w.u64(tm.restarts as u64);
    w.f64(tm.wall_secs);
    // optional scenario-tier input block — written only for
    // nd/heteroscedastic datasets, keeping 1-D homoscedastic v4 bytes
    // identical with prior builds (pinned by the golden fixtures)
    if data.d() > 1 || data.noise.is_some() {
        w.u64(data.extra.len() as u64);
        for c in &data.extra {
            w.vec(c);
        }
        match &data.noise {
            None => w.u8(0),
            Some(s) => {
                w.u8(1);
                w.vec(s);
            }
        }
    }
    w.buf
}

fn decode_meta(bytes: &[u8]) -> crate::Result<MetaV4> {
    let mut r = Reader::new(bytes);
    let label = r.str()?;
    let spec_name = r.str()?;
    let spec = ModelSpec::parse(&spec_name)
        .map_err(|e| anyhow::anyhow!("artifact names an unknown model spec: {e}"))?;
    let sigma_n = r.f64()?;
    anyhow::ensure!(sigma_n.is_finite() && sigma_n >= 0.0, "corrupt artifact: σ_n = {sigma_n}");
    let n_params = r.u32()? as usize;
    anyhow::ensure!(
        n_params <= 64,
        "corrupt artifact: implausible hyperparameter count {n_params}"
    );
    let mut param_names = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        param_names.push(r.str()?);
    }
    let model_dim = spec.build(sigma_n).dim();
    anyhow::ensure!(
        n_params == model_dim,
        "corrupt artifact: {spec_name} has {model_dim} hyperparameters, file lists {n_params}"
    );
    let theta_hat = r.vec()?;
    anyhow::ensure!(
        theta_hat.len() == model_dim && theta_hat.iter().all(|v| v.is_finite()),
        "corrupt artifact: θ̂ has {} coordinates (want {model_dim}) or non-finite entries",
        theta_hat.len()
    );
    let lnp_peak = r.f64()?;
    let sigma_f_hat2 = r.f64()?;
    let converged = r.u8()? != 0;
    let n_evals = r.u64()? as usize;
    let n_modes = r.u64()? as usize;
    let restart_values = r.vec()?;
    let jitter = r.f64()?;
    anyhow::ensure!(
        jitter.is_finite() && jitter >= 0.0,
        "corrupt artifact: recorded jitter = {jitter}"
    );
    let peak_lnp = r.f64()?;
    let peak_sigma2 = r.f64()?;
    anyhow::ensure!(peak_lnp.is_finite(), "corrupt artifact: non-finite peak lnp ({peak_lnp})");
    let evidence = LaplaceEvidence {
        ln_z: r.f64()?,
        ln_p_peak: r.f64()?,
        ln_det_h: r.f64()?,
        ln_volume: r.f64()?,
        marg_const: r.f64()?,
        sigma: r.vec()?,
        covariance: r.matrix()?,
        suspect: r.u8()? != 0,
    };
    let nested = match r.u8()? {
        0 => None,
        1 => Some(NestedReport {
            ln_z: r.f64()?,
            ln_z_err: r.f64()?,
            n_evals: r.u64()? as usize,
            information: r.f64()?,
            wall_secs: r.f64()?,
        }),
        other => anyhow::bail!("corrupt artifact: nested flag byte {other}"),
    };
    let warm_started = r.u8()? != 0;
    let restarts = r.u64()? as usize;
    let wall_secs = r.f64()?;
    // optional scenario-tier input block (absent on 1-D homoscedastic
    // artifacts, including every file an older build wrote)
    let (extra, noise) = if r.remaining() > 0 {
        let d_extra = r.len(8)?;
        anyhow::ensure!(
            d_extra < crate::gp::MAX_INPUT_DIM,
            "corrupt artifact: implausible extra-column count {d_extra}"
        );
        let mut extra = Vec::with_capacity(d_extra);
        for _ in 0..d_extra {
            extra.push(r.vec()?);
        }
        let noise = match r.u8()? {
            0 => None,
            1 => Some(r.vec()?),
            other => anyhow::bail!("corrupt artifact: noise flag byte {other}"),
        };
        (extra, noise)
    } else {
        (Vec::new(), None)
    };
    r.done()
        .map_err(|_| anyhow::anyhow!("corrupt artifact: trailing bytes in the meta section"))?;
    Ok(MetaV4 {
        label,
        spec,
        sigma_n,
        param_names,
        theta_hat,
        lnp_peak,
        sigma_f_hat2,
        converged,
        n_evals,
        n_modes,
        restart_values,
        jitter,
        peak_lnp,
        peak_sigma2,
        evidence,
        nested,
        warm_started,
        restarts,
        wall_secs,
        extra,
        noise,
    })
}

// ------------------------------------------------------------- encoding

fn push_f64s(buf: &mut Vec<u8>, v: &[f64]) {
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a v4 artifact. `compress_tol = Some(tol)` requests the
/// truncated spectral factor block with relative tail-energy tolerance
/// `tol ∈ [0, 1)`; the encoder silently falls back to the packed form
/// when truncation would not actually shrink the payload (flat spectrum,
/// tiny n), so a v4 file is never larger than its packed layout by more
/// than the fixed header.
pub fn encode_v4(
    tm: &TrainedModel,
    data: &Dataset,
    compress_tol: Option<f64>,
) -> crate::Result<Vec<u8>> {
    let n = data.len();
    let chol = &tm.train.peak_eval.chol;
    let dim = chol.dim();
    anyhow::ensure!(
        dim == tm.spec.factor_dim(n),
        "artifact factor dim {dim} does not match {} for n = {n}",
        tm.spec.factor_dim(n)
    );
    anyhow::ensure!(
        tm.train.peak_eval.alpha.len() == dim,
        "artifact α length {} does not match factor dim {dim}",
        tm.train.peak_eval.alpha.len()
    );
    let tri = dim * (dim + 1) / 2;
    let spectral = match compress_tol {
        None => None,
        Some(tol) => {
            anyhow::ensure!(
                tol.is_finite() && (0.0..1.0).contains(&tol),
                "compression tolerance {tol} must lie in [0, 1)"
            );
            let st = spectral_truncate(chol, tol)?;
            if st.stored_f64s() < tri {
                Some(st)
            } else {
                None
            }
        }
    };
    let meta = encode_meta(tm, data);
    let meta_len = meta.len();
    let blocks_off = align8(HEADER_LEN + meta_len);
    let rank = spectral.as_ref().map_or(0, SpectralTrunc::rank);
    let payload = match &spectral {
        None => tri,
        Some(st) => st.stored_f64s(),
    };
    let block_bytes = (2 * n + dim + payload) * 8;
    let mut buf = Vec::with_capacity(blocks_off + block_bytes + 4);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION_V4.to_le_bytes());
    let flags = if spectral.is_some() { FLAG_COMPRESSED } else { 0 };
    buf.extend_from_slice(&flags.to_le_bytes());
    buf.extend_from_slice(&(n as u64).to_le_bytes());
    buf.extend_from_slice(&(dim as u64).to_le_bytes());
    buf.extend_from_slice(&(rank as u64).to_le_bytes());
    buf.extend_from_slice(&chol.logdet().to_le_bytes());
    buf.extend_from_slice(&(meta_len as u64).to_le_bytes());
    buf.extend_from_slice(&(blocks_off as u64).to_le_bytes());
    debug_assert_eq!(buf.len(), HEADER_LEN);
    buf.extend_from_slice(&meta);
    buf.resize(blocks_off, 0); // zero alignment padding
    push_f64s(&mut buf, &data.t);
    push_f64s(&mut buf, &data.y);
    push_f64s(&mut buf, &tm.train.peak_eval.alpha);
    match &spectral {
        None => {
            let l = chol.factor_matrix();
            for i in 0..dim {
                push_f64s(&mut buf, &l.row(i)[..=i]);
            }
        }
        Some(st) => {
            push_f64s(&mut buf, &st.eigvals);
            for k in 0..rank {
                push_f64s(&mut buf, st.eigvecs.row(k));
            }
            push_f64s(&mut buf, &st.diag);
        }
    }
    debug_assert_eq!(buf.len(), blocks_off + block_bytes);
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    Ok(buf)
}

// -------------------------------------------------------------- parsing

/// Which form the factor payload takes.
pub enum FactorBlock<'a> {
    /// Packed lower triangle, `d(d+1)/2` f64s.
    Packed(FSlice<'a>),
    /// Truncated spectral form: `λ` (descending), eigenvector rows, diag.
    Spectral { eigvals: FSlice<'a>, eigvecs: FSlice<'a>, diag: FSlice<'a> },
}

/// A parsed-but-not-materialised v4 artifact: the header and meta fields
/// are decoded, the CRC and every structural invariant are verified, and
/// the numeric blocks are held as (ideally borrowed) [`FSlice`]s over the
/// input buffer. [`ArtifactView::adopt`] materialises the
/// [`TrainedModel`] + [`Dataset`] pair; the serving layer can instead
/// read the blocks directly ([`crate::coordinator::ServeSession`]'s
/// view-hydration path) and skip the intermediate model entirely.
pub struct ArtifactView<'a> {
    meta: MetaV4,
    n: usize,
    chol_dim: usize,
    logdet: f64,
    t: FSlice<'a>,
    y: FSlice<'a>,
    alpha: FSlice<'a>,
    factor: FactorBlock<'a>,
}

fn header_u32(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
}

fn header_u64(bytes: &[u8], off: usize) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&bytes[off..off + 8]);
    u64::from_le_bytes(a)
}

fn header_usize(bytes: &[u8], off: usize, what: &str) -> crate::Result<usize> {
    usize::try_from(header_u64(bytes, off))
        .map_err(|_| anyhow::anyhow!("corrupt artifact: {what} field overflows this platform"))
}

impl<'a> ArtifactView<'a> {
    /// Parse a v4 artifact without materialising the numeric payloads.
    ///
    /// Verifies, in order: length, magic, version, the CRC32 trailer
    /// (before *any* field is trusted), flag bits, the rank/dim contract
    /// of the compressed block, meta/padding/block-section bounds (the
    /// padding must be all-zero and `blocks_off` must equal the aligned
    /// meta end), the exact total length, the meta field stream, and the
    /// spec-vs-dimension cross-checks. Corrupt input at any layer is a
    /// clean `Err`.
    pub fn parse(bytes: &'a [u8]) -> crate::Result<Self> {
        anyhow::ensure!(
            bytes.len() >= HEADER_LEN + 4,
            "truncated artifact: {} bytes is shorter than the v4 header + trailer",
            bytes.len()
        );
        anyhow::ensure!(
            &bytes[..8] == &MAGIC[..],
            "not a gpfast model artifact: bad magic {:?}",
            &bytes[..8]
        );
        let version = header_u32(bytes, 8);
        anyhow::ensure!(version == VERSION_V4, "not a v4 artifact: version field {version}");
        let split = bytes.len() - 4;
        let stored = header_u32(bytes, split);
        let computed = crc32(&bytes[..split]);
        anyhow::ensure!(
            stored == computed,
            "corrupt artifact: CRC32 mismatch (stored {stored:#010x}, computed {computed:#010x})"
        );
        let flags = header_u32(bytes, 12);
        anyhow::ensure!(
            flags & !FLAG_COMPRESSED == 0,
            "corrupt artifact: unknown flag bits {flags:#010x}"
        );
        let compressed = flags & FLAG_COMPRESSED != 0;
        let n = header_usize(bytes, 16, "n")?;
        let chol_dim = header_usize(bytes, 24, "chol_dim")?;
        let rank = header_usize(bytes, 32, "rank")?;
        let logdet = f64::from_le_bytes(bytes[40..48].try_into().expect("8 header bytes"));
        let meta_len = header_usize(bytes, 48, "meta_len")?;
        let blocks_off = header_usize(bytes, 56, "blocks_off")?;
        anyhow::ensure!(n >= 1, "corrupt artifact: empty dataset (n = 0)");
        anyhow::ensure!(chol_dim >= 1, "corrupt artifact: empty factor (chol_dim = 0)");
        if compressed {
            anyhow::ensure!(
                (1..=chol_dim).contains(&rank),
                "corrupt artifact: compressed-block rank {rank} out of range for factor dim {chol_dim}"
            );
        } else {
            anyhow::ensure!(
                rank == 0,
                "corrupt artifact: rank {rank} set without the compressed flag"
            );
        }
        let overflow = || anyhow::anyhow!("corrupt artifact: block sizes overflow");
        let meta_end = HEADER_LEN.checked_add(meta_len).ok_or_else(overflow)?;
        anyhow::ensure!(
            meta_end <= split && blocks_off == align8(meta_end),
            "corrupt artifact: blocks_off {blocks_off} does not match the aligned meta end"
        );
        anyhow::ensure!(
            bytes[meta_end..blocks_off].iter().all(|&b| b == 0),
            "corrupt artifact: nonzero alignment padding before the block section"
        );
        // exact block-section size, all arithmetic checked
        let payload = if compressed {
            rank.checked_mul(chol_dim.checked_add(1).ok_or_else(overflow)?)
                .and_then(|v| v.checked_add(chol_dim))
                .ok_or_else(overflow)?
        } else {
            chol_dim
                .checked_mul(chol_dim.checked_add(1).ok_or_else(overflow)?)
                .map(|v| v / 2)
                .ok_or_else(overflow)?
        };
        let total_f64s = n
            .checked_mul(2)
            .and_then(|v| v.checked_add(chol_dim))
            .and_then(|v| v.checked_add(payload))
            .ok_or_else(overflow)?;
        let block_bytes = total_f64s.checked_mul(8).ok_or_else(overflow)?;
        anyhow::ensure!(
            blocks_off.checked_add(block_bytes) == Some(split),
            "corrupt artifact: block section is {} bytes, header claims {block_bytes}",
            split.saturating_sub(blocks_off)
        );
        let meta = decode_meta(&bytes[HEADER_LEN..meta_end])?;
        anyhow::ensure!(
            chol_dim == meta.spec.factor_dim(n),
            "corrupt artifact: factor dim {chol_dim} vs expected {} for {} at n = {n}",
            meta.spec.factor_dim(n),
            meta.spec.name()
        );
        anyhow::ensure!(
            meta.spec.input_dim() == 1 + meta.extra.len(),
            "corrupt artifact: {} expects d = {} inputs, file carries d = {}",
            meta.spec.name(),
            meta.spec.input_dim(),
            1 + meta.extra.len()
        );
        anyhow::ensure!(
            meta.extra.iter().all(|c| c.len() == n)
                && meta.noise.as_ref().map_or(true, |s| s.len() == n),
            "corrupt artifact: input-block column length does not match n = {n}"
        );
        let mut off = blocks_off;
        let mut block = |count: usize| {
            let s = view_f64s(&bytes[off..off + count * 8], count);
            off += count * 8;
            s
        };
        let t = block(n);
        let y = block(n);
        let alpha = block(chol_dim);
        let factor = if compressed {
            FactorBlock::Spectral {
                eigvals: block(rank),
                eigvecs: block(rank * chol_dim),
                diag: block(chol_dim),
            }
        } else {
            FactorBlock::Packed(block(payload))
        };
        Ok(Self { meta, n, chol_dim, logdet, t, y, alpha, factor })
    }

    /// Training-set size `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Factor dimension (`= n` for exact specs).
    pub fn chol_dim(&self) -> usize {
        self.chol_dim
    }

    /// Whether the factor payload is the truncated spectral form.
    pub fn compressed(&self) -> bool {
        matches!(self.factor, FactorBlock::Spectral { .. })
    }

    /// Whether the zero-copy path engaged for the numeric blocks (false
    /// on unaligned buffers and big-endian hosts — the fallback copies).
    pub fn zero_copy(&self) -> bool {
        self.t.is_borrowed() && self.alpha.is_borrowed()
    }

    /// The buildable model spec.
    pub fn spec(&self) -> &ModelSpec {
        &self.meta.spec
    }

    /// Fixed noise level σ_n.
    pub fn sigma_n(&self) -> f64 {
        self.meta.sigma_n
    }

    /// Stored Laplace evidence ln Z (slot-ranking key).
    pub fn ln_z(&self) -> f64 {
        self.meta.evidence.ln_z
    }

    /// ϑ̂ at the peak.
    pub fn theta(&self) -> &[f64] {
        &self.meta.theta_hat
    }

    /// Input points (borrowed from the buffer on the fast path).
    pub fn t(&self) -> &[f64] {
        &self.t
    }

    /// Output values.
    pub fn y(&self) -> &[f64] {
        &self.y
    }

    /// The maintained weight vector α.
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Input dimension d of the stored dataset (1 + extra columns).
    pub fn d(&self) -> usize {
        1 + self.meta.extra.len()
    }

    /// Extra input columns beyond `t` (empty for 1-D artifacts).
    pub fn extra_cols(&self) -> &[Vec<f64>] {
        &self.meta.extra
    }

    /// Per-point noise vector (`None` ⇒ homoscedastic).
    pub fn noise(&self) -> Option<&[f64]> {
        self.meta.noise.as_deref()
    }

    /// The packed lower triangle, when the factor is uncompressed.
    pub fn packed_factor(&self) -> Option<&[f64]> {
        match &self.factor {
            FactorBlock::Packed(p) => Some(p),
            FactorBlock::Spectral { .. } => None,
        }
    }

    /// Maintained log-determinant of the stored factor.
    pub fn logdet(&self) -> f64 {
        self.logdet
    }

    /// σ̂_f² at the peak evaluation.
    pub fn sigma_f_hat2(&self) -> f64 {
        self.meta.peak_sigma2
    }

    /// Jitter the factor was produced with.
    pub fn jitter(&self) -> f64 {
        self.meta.jitter
    }

    /// Validate the numeric payloads: `t`/`y`/`α` finiteness, factor
    /// diagonal positivity (packed form) or eigenvalue/diag ordering and
    /// sign (spectral form). Structure and checksum are already verified
    /// by [`ArtifactView::parse`]; callers that bypass
    /// [`ArtifactView::adopt`] (the direct view-hydration path) must
    /// call this before trusting the blocks.
    pub fn validate_payload(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.t.iter().all(|v| v.is_finite()) && self.y.iter().all(|v| v.is_finite()),
            "corrupt artifact: non-finite training point"
        );
        anyhow::ensure!(
            self.alpha.iter().all(|v| v.is_finite()),
            "corrupt artifact: non-finite α entry"
        );
        anyhow::ensure!(
            self.meta.extra.iter().all(|c| c.iter().all(|v| v.is_finite())),
            "corrupt artifact: non-finite extra-column entry"
        );
        anyhow::ensure!(
            self.meta
                .noise
                .as_ref()
                .map_or(true, |s| s.iter().all(|v| v.is_finite() && *v >= 0.0)),
            "corrupt artifact: per-point noise not finite/nonnegative"
        );
        match &self.factor {
            FactorBlock::Packed(p) => {
                let mut off = 0;
                for i in 0..self.chol_dim {
                    let d = p[off + i];
                    anyhow::ensure!(
                        d.is_finite() && d > 0.0,
                        "corrupt artifact: factor diagonal L[{i}][{i}] = {d} (must be finite and > 0)"
                    );
                    off += i + 1;
                }
                anyhow::ensure!(
                    self.logdet.is_finite(),
                    "corrupt artifact: non-finite factor logdet ({})",
                    self.logdet
                );
            }
            FactorBlock::Spectral { eigvals, eigvecs, diag } => {
                anyhow::ensure!(
                    eigvals.iter().all(|v| v.is_finite() && *v >= 0.0)
                        && eigvals.windows(2).all(|w| w[0] >= w[1]),
                    "corrupt artifact: spectral eigenvalues not finite/descending/nonnegative"
                );
                anyhow::ensure!(
                    eigvecs.iter().all(|v| v.is_finite()),
                    "corrupt artifact: non-finite spectral eigenvector entry"
                );
                anyhow::ensure!(
                    diag.iter().all(|v| v.is_finite() && *v >= 0.0),
                    "corrupt artifact: spectral diagonal correction not finite/nonnegative"
                );
            }
        }
        Ok(())
    }

    /// Rebuild the factor as a [`Chol`]: a straight packed-triangle
    /// scatter for the uncompressed form (no intermediate per-row
    /// buffers), reconstruction + re-factorisation for the spectral
    /// form. Assumes [`ArtifactView::validate_payload`] passed.
    fn rebuild_chol(&self) -> crate::Result<Chol> {
        match &self.factor {
            FactorBlock::Packed(p) => Ok(Chol::from_packed_lower(p, self.chol_dim, self.logdet)),
            FactorBlock::Spectral { eigvals, eigvecs, diag } => {
                let rank = eigvals.len();
                let st = SpectralTrunc {
                    eigvals: eigvals.to_vec(),
                    eigvecs: Matrix::from_vec(rank, self.chol_dim, eigvecs.to_vec()),
                    diag: diag.to_vec(),
                };
                let k = spectral_reconstruct(&st);
                Chol::factor_owned(k).map_err(|e| {
                    anyhow::anyhow!("corrupt artifact: compressed factor does not re-factor: {e}")
                })
            }
        }
    }

    /// Materialise the full [`TrainedModel`] + [`Dataset`] pair — the
    /// compatibility surface every v2/v3 caller already speaks. Each
    /// numeric block is copied exactly once (a memcpy off the borrowed
    /// view on the fast path); the packed factor scatters straight into
    /// the dense triangle with no intermediate per-row buffers.
    pub fn adopt(&self) -> crate::Result<(TrainedModel, Dataset)> {
        self.validate_payload()?;
        let m = &self.meta;
        let mut data = Dataset::checked(self.t.to_vec(), self.y.to_vec(), m.label.clone())
            .map_err(|e| anyhow::anyhow!("corrupt artifact: {e}"))?;
        if !m.extra.is_empty() {
            data = data
                .with_extra_cols(m.extra.clone())
                .map_err(|e| anyhow::anyhow!("corrupt artifact: {e}"))?;
        }
        if let Some(s) = &m.noise {
            data = data
                .with_noise(s.clone())
                .map_err(|e| anyhow::anyhow!("corrupt artifact: {e}"))?;
        }
        let chol = self.rebuild_chol()?;
        let peak_eval = ProfiledEval {
            lnp: m.peak_lnp,
            sigma_f_hat2: m.peak_sigma2,
            chol,
            alpha: self.alpha.to_vec(),
            jitter: m.jitter,
        };
        let tm = TrainedModel {
            spec: m.spec.clone(),
            sigma_n: m.sigma_n,
            param_names: m.param_names.clone(),
            train: TrainResult {
                theta_hat: m.theta_hat.clone(),
                lnp_peak: m.lnp_peak,
                sigma_f_hat2: m.sigma_f_hat2,
                peak_eval,
                converged: m.converged,
                n_evals: m.n_evals,
                n_modes: m.n_modes,
                restart_values: m.restart_values.clone(),
                jitter: m.jitter,
            },
            evidence: m.evidence.clone(),
            nested: m.nested.clone(),
            warm_started: m.warm_started,
            restarts: m.restarts,
            wall_secs: m.wall_secs,
        };
        Ok((tm, data))
    }
}

/// Full v4 decode — the [`super::artifact::decode`] dispatch target, so
/// `TrainedModel::from_bytes` / `load` accept v4 files transparently.
pub(super) fn decode_v4(bytes: &[u8]) -> crate::Result<(TrainedModel, Dataset)> {
    ArtifactView::parse(bytes)?.adopt()
}

impl TrainedModel {
    /// Encode this artifact in format **v4** (see the module docs):
    /// zero-copy block layout, optional truncated-spectral factor
    /// compression. [`TrainedModel::from_bytes`] reads the result back;
    /// with `compress_tol = None` the restore is bit-identical, with
    /// `Some(tol)` the predictive means are bit-identical and variances
    /// carry an `O(tol)` relative perturbation.
    pub fn to_bytes_v4(&self, data: &Dataset, compress_tol: Option<f64>) -> crate::Result<Vec<u8>> {
        encode_v4(self, data, compress_tol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align8_and_layout_constants() {
        assert_eq!(align8(64), 64);
        assert_eq!(align8(65), 72);
        assert_eq!(align8(71), 72);
        assert_eq!(HEADER_LEN % 8, 0);
    }

    #[test]
    fn view_f64s_round_trips_aligned_and_unaligned() {
        let vals = [1.5f64, -2.25, 0.0, f64::MAX];
        let mut bytes = vec![0u8; 8 * 4 + 1];
        for (i, v) in vals.iter().enumerate() {
            bytes[1 + i * 8..1 + (i + 1) * 8].copy_from_slice(&v.to_le_bytes());
        }
        // offset 1: guaranteed unaligned view of the same payload
        let off = &bytes[1..33];
        let s = view_f64s(off, 4);
        assert_eq!(&*s, &vals[..]);
        // an owned aligned copy: the borrow path must produce equal values
        let aligned: Vec<f64> = vals.to_vec();
        let raw: &[u8] = unsafe {
            std::slice::from_raw_parts(aligned.as_ptr() as *const u8, 32)
        };
        let s2 = view_f64s(raw, 4);
        assert!(s2.is_borrowed(), "8-aligned little-endian buffer must borrow");
        assert_eq!(&*s2, &vals[..]);
    }
}
