//! A small fixed-size worker pool over std threads + channels.
//!
//! No `tokio`/`rayon` in the offline build image, so the coordinator
//! carries its own: submit boxed jobs, collect results in submission
//! order, cooperative shutdown. Invariants (every job runs exactly once,
//! order-stable collection, no deadlock on drop) are property-tested.
//!
//! ## Scheduling: shared pull queue, deliberately
//!
//! Workers pull from one shared `Mutex<Receiver>` queue. The lock is held
//! only for the `recv()` handoff, so pickup serialises — but that is the
//! right trade here and was re-examined rather than "fixed":
//!
//! * This pool only ever runs **coarse, uneven** jobs (multistart CG
//!   restarts, seconds each, iteration counts varying several-fold). A
//!   work-conserving pull queue keeps every worker busy until the queue
//!   drains; static per-worker assignment (round-robin channels) would
//!   let two slow restarts colocate on one worker while the others idle —
//!   a far larger wall-clock loss than any lock handoff.
//! * Pickup contention costs ~µs per job against jobs of ~10⁶ µs, i.e.
//!   noise. The fine-grained work where handoff serialisation *would*
//!   matter — `O(n³)`/`O(n² m)` linalg row tiles — never touches this
//!   pool: it runs on the scoped [`crate::runtime::ExecutionContext`]
//!   layer, which partitions work statically up front and needs no queue
//!   at all.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("gpfast-worker-{i}"))
                    .spawn(move || loop {
                        // lock covers only the handoff; the job runs
                        // outside the critical section
                        let job = {
                            let guard = rx.lock().expect("pool receiver poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Submit a fire-and-forget job.
    pub fn submit(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("pool workers all dead");
    }

    /// Map `inputs` through `f` on the pool, collecting results in input
    /// order. `f` must be cloneable across threads.
    pub fn map<I, O, F>(&self, inputs: Vec<I>, f: F) -> Vec<O>
    where
        I: Send + 'static,
        O: Send + 'static,
        F: Fn(I) -> O + Send + Sync + Clone + 'static,
    {
        let n = inputs.len();
        let (otx, orx): (Sender<(usize, O)>, Receiver<(usize, O)>) = channel();
        for (idx, input) in inputs.into_iter().enumerate() {
            let otx = otx.clone();
            let f = f.clone();
            self.submit(Box::new(move || {
                let out = f(input);
                // receiver may have been dropped if the caller panicked
                let _ = otx.send((idx, out));
            }));
        }
        drop(otx);
        let mut results: Vec<Option<O>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (idx, out) = orx.recv().expect("worker dropped result channel");
            results[idx] = Some(out);
        }
        results.into_iter().map(|o| o.expect("missing result")).collect()
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::new(4);
        let out = pool.map((0..100).collect(), |x: usize| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let _ = pool.map((0..57).collect::<Vec<usize>>(), move |_| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 57);
    }

    #[test]
    fn drop_joins_cleanly() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..20 {
                let c = Arc::clone(&counter);
                pool.submit(Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }));
            }
            // pool dropped here: must process or discard without hanging
        }
        // all submitted jobs ran (drop closes the queue after draining)
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn size_is_clamped_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.map(vec![1, 2, 3], |x: i32| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn uneven_jobs_are_work_conserved() {
        // one deliberately slow job must not starve the remaining nine:
        // with 2 workers and a pull queue, total wall time ≈ slow job,
        // not slow + Σ(fast colocated behind it by a static scheduler).
        let pool = WorkerPool::new(2);
        let t0 = std::time::Instant::now();
        let _ = pool.map((0..10).collect::<Vec<usize>>(), |i| {
            std::thread::sleep(std::time::Duration::from_millis(if i == 0 { 80 } else { 1 }));
        });
        // pull queue: ~80 ms (slow job ∥ nine fast ones). A round-robin
        // static assignment in the worst interleaving approaches 2× that.
        // Generous bound to stay CI-safe while still catching gross
        // head-of-line blocking.
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(400),
            "work conservation lost: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn pool_invariants_property() {
        crate::propcheck::property("pool runs all jobs once, ordered", 20, |g| {
            let workers = g.usize(1..6);
            let jobs = g.usize(0..40);
            let pool = WorkerPool::new(workers);
            let out = pool.map((0..jobs).collect(), |x: usize| 2 * x + 1);
            if out.len() != jobs {
                return Err(format!("expected {jobs} results, got {}", out.len()));
            }
            for (i, v) in out.iter().enumerate() {
                if *v != 2 * i + 1 {
                    return Err(format!("slot {i} has {v}"));
                }
            }
            Ok(())
        });
    }
}
