//! Training a single covariance model: multistart CG on the profiled
//! hyperlikelihood, fanned out across the worker pool.
//!
//! Nested parallelism follows the borrowed-slots rule of
//! [`crate::runtime::exec`]: `exec` is the **total** compute-thread
//! budget. The pool width is `min(workers, restarts, exec.threads())`
//! and each concurrent restart's linalg gets `exec.split(width)`, so
//! multistart × linalg never exceeds the budget. With a single worker
//! (or one restart) the full budget flows into the linalg layer.

use std::sync::Arc;

use crate::data::Dataset;
use crate::gp::profiled;
use crate::optimize::{maximise_cg, CgOptions, FnObjective, MultistartOptions};
use crate::priors::BoxPrior;
use crate::rng::Xoshiro256;
use crate::runtime::ExecutionContext;

use super::pool::WorkerPool;
use super::registry::ModelSpec;

/// Options for a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainOptions {
    pub multistart: MultistartOptions,
    /// Deterministic extra starting points (run *in addition to* the
    /// random restarts). The comparison pipeline uses these to warm-start
    /// nested models from simpler models' peaks — e.g. k₂ from k₁'s
    /// (φ₀, φ₁, ξ₁) — which is how a practitioner following the paper
    /// would seed the richer covariance function.
    pub extra_starts: Vec<Vec<f64>>,
}

/// Result of training one model.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub theta_hat: Vec<f64>,
    pub lnp_peak: f64,
    pub sigma_f_hat2: f64,
    /// The profiled evaluation at the winning peak — factor and α
    /// included, so the serving layer ([`crate::coordinator::serve`])
    /// can adopt them without re-paying the `O(n³)` factorisation.
    pub peak_eval: profiled::ProfiledEval,
    /// Did the winning restart converge?
    pub converged: bool,
    /// Total profiled-likelihood evaluations across all restarts.
    pub n_evals: usize,
    /// Distinct modes discovered (multimodality diagnostic).
    pub n_modes: usize,
    /// Per-restart peak values, best first.
    pub restart_values: Vec<f64>,
    /// Diagonal jitter the escalation ladder applied at the winning peak
    /// (`0.0` when the peak factorised cleanly) — see
    /// [`crate::gp::profiled::ProfiledEval::jitter`].
    pub jitter: f64,
}

/// Finite penalty for hyperparameter proposals whose covariance stays
/// non-PD through the whole jitter-escalation ladder. Finite (unlike the
/// earlier −∞ sentinel) so the CG line search can compare two failed
/// proposals and back off smoothly instead of treating the whole region
/// as an absorbing wall; far below any reachable ln P_max so a failed
/// proposal can never win a restart.
pub const FAILED_EVAL_PENALTY: f64 = -1e12;

/// The training objective for one (model, dataset) pair — the profiled
/// hyperlikelihood for exact specs, the backend's surrogate
/// ([`crate::gp::approx::train_value_with`]) for approximate ones.
/// Proposals that defeat even the escalation ladder evaluate to the
/// finite [`FAILED_EVAL_PENALTY`] (rejected region) rather than erroring,
/// so the restart survives and the line search backs off gracefully.
///
/// The exact path's value closure goes through
/// [`profiled::eval_value_with`], which detects uniform time grids and
/// serves the value through the `O(n²)` Levinson recursion instead of
/// the `O(n³)` Cholesky. The CG optimiser itself consumes only
/// `value_grad`, so the fast path cannot perturb its trajectory — it
/// accelerates the value-only consumers (gradient-free probes,
/// likelihood scans) and keeps them equal to the dense path to rounding.
fn make_objective<'a>(
    approx: Option<crate::gp::ApproxKind>,
    model: &'a crate::kernels::CovarianceModel,
    data: &'a Dataset,
    ctx: &'a ExecutionContext,
) -> FnObjective<
    impl FnMut(&[f64]) -> crate::Result<f64> + 'a,
    impl FnMut(&[f64]) -> crate::Result<(f64, Vec<f64>)> + 'a,
> {
    let m = model.dim();
    FnObjective::new(
        m,
        move |theta: &[f64]| {
            Ok(match approx {
                // nd entry point: delegates to the scalar (and Toeplitz-
                // capable) path when d == 1 and the noise is homoscedastic,
                // so 1-D training trajectories are bit-identical
                None => profiled::eval_value_nd_with(
                    model,
                    &data.input_cols(),
                    data.noise.as_deref(),
                    &data.y,
                    theta,
                    ctx,
                )
                .unwrap_or(FAILED_EVAL_PENALTY),
                Some(kind) => {
                    crate::gp::approx::train_value_with(kind, model, &data.t, &data.y, theta, ctx)
                        .unwrap_or(FAILED_EVAL_PENALTY)
                }
            })
        },
        move |theta: &[f64]| {
            let res = match approx {
                None => profiled::eval_grad_nd_with(
                    model,
                    &data.input_cols(),
                    data.noise.as_deref(),
                    &data.y,
                    theta,
                    ctx,
                )
                .map(|(ev, g)| (ev.lnp, g)),
                Some(kind) => {
                    crate::gp::approx::train_grad_with(kind, model, &data.t, &data.y, theta, ctx)
                }
            };
            Ok(res.unwrap_or_else(|_| (FAILED_EVAL_PENALTY, vec![0.0; m])))
        },
    )
}

/// Train `spec` on `data`: multistart CG across `workers` threads, with
/// `exec` as the total thread budget for the linalg underneath (split
/// across concurrent restarts — see the module docs).
///
/// Each restart builds its own model instance (kernels are not `Sync`
/// across the pool) and seeds an independent RNG stream.
pub fn train_model(
    spec: &ModelSpec,
    sigma_n: f64,
    data: &Dataset,
    opts: &TrainOptions,
    workers: usize,
    exec: &ExecutionContext,
    rng: &mut Xoshiro256,
) -> crate::Result<TrainResult> {
    let restarts = opts.multistart.restarts.max(1);
    let seeds: Vec<u64> = (0..restarts).map(|_| rng.next_u64()).collect();
    train_model_seeded(spec, sigma_n, data, opts, &seeds, workers, exec)
}

/// [`train_model`] with the random-restart seeds **pre-drawn** by the
/// caller. This is the tournament's entry point: it draws every model's
/// seeds from the master RNG at schedule time (in roster order), so
/// models of one lineage generation can train concurrently while the
/// whole tournament stays deterministic — and a tournament-of-one
/// consumes exactly the RNG stream `train_model` would.
///
/// The run's starts are `opts.extra_starts` (deterministic points, e.g.
/// a parent model's peak) plus one random prior draw per seed.
pub fn train_model_seeded(
    spec: &ModelSpec,
    sigma_n: f64,
    data: &Dataset,
    opts: &TrainOptions,
    seeds: &[u64],
    workers: usize,
    exec: &ExecutionContext,
) -> crate::Result<TrainResult> {
    let restarts = seeds.len().max(1);
    let span = data.span()?;
    anyhow::ensure!(
        spec.input_dim() == data.d(),
        "model {} consumes {}-dim inputs but dataset '{}' has d = {}",
        spec.name(),
        spec.input_dim(),
        data.label,
        data.d()
    );
    anyhow::ensure!(
        spec.approx().is_none() || (data.d() == 1 && !data.is_heteroscedastic()),
        "approximate spec {} supports only 1-D homoscedastic datasets",
        spec.name()
    );
    /// A start is either a fresh RNG stream (random prior draw) or a
    /// deterministic warm-start point.
    #[derive(Clone)]
    enum Start {
        Seed(u64),
        Point(Vec<f64>),
    }
    let mut starts: Vec<Start> =
        opts.extra_starts.iter().cloned().map(Start::Point).collect();
    starts.extend(seeds.iter().map(|&s| Start::Seed(s)));
    let data = Arc::new(data.clone());
    let spec_owned = spec.clone();
    let cg: CgOptions = opts.multistart.cg;

    struct StartResult {
        theta: Vec<f64>,
        value: f64,
        converged: bool,
        evals: usize,
    }

    // borrowed-slots: concurrent restarts divide the linalg thread
    // budget, and the pool itself never exceeds it — `exec` is the total
    // compute-thread budget, so `workers` is a fan-out *request* capped
    // by it (workers=16 with a 4-thread budget runs a 4-wide pool).
    let pool_workers = if workers > 1 {
        workers.min(starts.len().max(1)).min(exec.threads())
    } else {
        1
    };
    let inner_ctx =
        if pool_workers > 1 { exec.split(pool_workers) } else { exec.clone() };

    let run_one = {
        let data = Arc::clone(&data);
        let spec = spec_owned;
        move |start: Start| -> Option<StartResult> {
            let model = spec.build(sigma_n);
            let prior = BoxPrior::for_model(&model, &span);
            let x0 = match start {
                Start::Seed(seed) => {
                    let mut local_rng = Xoshiro256::seed_from_u64(seed);
                    prior.sample(&mut local_rng)
                }
                Start::Point(mut p) => {
                    prior.project(&mut p);
                    p
                }
            };
            let mut obj = make_objective(spec.approx(), &model, &data, &inner_ctx);
            match maximise_cg(&mut obj, &prior, &x0, &cg) {
                Ok(out) if out.value.is_finite() => Some(StartResult {
                    theta: out.theta,
                    value: out.value,
                    converged: out.converged,
                    evals: obj.evals(),
                }),
                _ => None,
            }
        }
    };

    let results: Vec<Option<StartResult>> = if pool_workers > 1 {
        let pool = WorkerPool::new(pool_workers);
        let shared = Arc::new(run_one);
        let f = {
            let shared = Arc::clone(&shared);
            move |start: Start| shared(start)
        };
        pool.map(starts, f)
    } else {
        starts.into_iter().map(run_one).collect()
    };

    let mut ok: Vec<StartResult> = results.into_iter().flatten().collect();
    anyhow::ensure!(
        !ok.is_empty(),
        "all {restarts} restarts failed for model {spec:?} (covariance never PD)"
    );
    // NaN-safe: a poisoned objective (NaN peak value) ranks last instead
    // of panicking the whole train
    ok.sort_by(|a, b| crate::util::desc_nan_last(a.value, b.value));
    let n_evals: usize = ok.iter().map(|r| r.evals).sum();
    // count distinct modes
    let tol = opts.multistart.dedupe_tol;
    let mut modes: Vec<&[f64]> = Vec::new();
    for s in &ok {
        if !modes.iter().any(|m| {
            m.iter().zip(&s.theta).fold(0.0f64, |acc, (a, b)| acc.max((a - b).abs())) < tol
        }) {
            modes.push(&s.theta);
        }
    }
    let n_modes = modes.len();
    let restart_values: Vec<f64> = ok.iter().map(|r| r.value).collect();
    let best = &ok[0];
    // re-evaluate at the winning peak: σ̂_f² for the report, and the
    // factor + α for the serving layer to adopt (no refactorisation).
    // Approximate specs produce their reduced peak (subset factor for
    // SoD, K_eff factor for FITC) — dim = spec.factor_dim(n).
    let model = spec.build(sigma_n);
    let ev = match spec.approx() {
        None => profiled::eval_nd_with(
            &model,
            &data.input_cols(),
            data.noise.as_deref(),
            &data.y,
            &best.theta,
            exec,
        )?,
        Some(kind) => {
            crate::gp::approx::peak_eval_with(kind, &model, &data.t, &data.y, &best.theta, exec)?
        }
    };
    let jitter = ev.jitter;
    Ok(TrainResult {
        theta_hat: best.theta.clone(),
        lnp_peak: best.value,
        sigma_f_hat2: ev.sigma_f_hat2,
        peak_eval: ev,
        converged: best.converged,
        n_evals,
        n_modes,
        restart_values,
        jitter,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::table1_dataset;

    fn fast_opts() -> TrainOptions {
        TrainOptions {
            multistart: MultistartOptions { restarts: 4, ..Default::default() },
            extra_starts: Vec::new(),
        }
    }

    #[test]
    fn trains_k1_on_synthetic_data() {
        let data = table1_dataset(50, 0.1, 7);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let exec = ExecutionContext::seq();
        let res =
            train_model(&ModelSpec::K1, 0.1, &data, &fast_opts(), 1, &exec, &mut rng).unwrap();
        assert!(res.lnp_peak.is_finite());
        // σ_f truth is 1.0; estimate should be order-unity
        assert!(res.sigma_f_hat2 > 0.05 && res.sigma_f_hat2 < 20.0, "{}", res.sigma_f_hat2);
        assert!(res.n_evals > 0);
        assert_eq!(res.restart_values.len() <= 4, true);
        // training beats a random prior point
        let model = ModelSpec::K1.build(0.1);
        let prior = BoxPrior::for_model(&model, &data.span().unwrap());
        let mut r2 = Xoshiro256::seed_from_u64(1000);
        let random_point = prior.sample(&mut r2);
        if let Ok(ev) = profiled::eval(&model, &data.t, &data.y, &random_point) {
            assert!(res.lnp_peak >= ev.lnp - 1e-9);
        }
    }

    #[test]
    fn parallel_matches_serial_given_same_seed() {
        let data = table1_dataset(40, 0.1, 11);
        let mut rng_a = Xoshiro256::seed_from_u64(5);
        let mut rng_b = Xoshiro256::seed_from_u64(5);
        // 3-thread budget so workers=3 genuinely runs a 3-wide pool
        // (the pool width is capped at the budget)
        let exec = ExecutionContext::new(3);
        let a = train_model(&ModelSpec::K1, 0.1, &data, &fast_opts(), 1, &exec, &mut rng_a)
            .unwrap();
        let b = train_model(&ModelSpec::K1, 0.1, &data, &fast_opts(), 3, &exec, &mut rng_b)
            .unwrap();
        assert_eq!(a.theta_hat, b.theta_hat, "determinism across worker counts");
        assert!((a.lnp_peak - b.lnp_peak).abs() < 1e-12);
    }

    #[test]
    fn inner_parallelism_matches_serial_exactly() {
        // the linalg layer is bit-deterministic, so even *different*
        // thread budgets must reproduce the same training trajectory
        // (n = 150 exceeds the parallel dispatch cutoffs)
        let data = table1_dataset(150, 0.1, 19);
        let mut rng_a = Xoshiro256::seed_from_u64(9);
        let mut rng_b = Xoshiro256::seed_from_u64(9);
        let a = train_model(
            &ModelSpec::K1, 0.1, &data, &fast_opts(), 1,
            &ExecutionContext::seq(), &mut rng_a,
        )
        .unwrap();
        let b = train_model(
            &ModelSpec::K1, 0.1, &data, &fast_opts(), 1,
            &ExecutionContext::new(4), &mut rng_b,
        )
        .unwrap();
        assert_eq!(a.theta_hat, b.theta_hat, "thread budget must not change the result");
        assert_eq!(a.lnp_peak, b.lnp_peak);
    }

    #[test]
    fn trains_ard_on_3d_heteroscedastic_data() {
        let data = crate::data::synthetic::ard3_dataset(30, 0.1, true, 23);
        let mut rng = Xoshiro256::seed_from_u64(31);
        let exec = ExecutionContext::seq();
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let res = train_model(&ModelSpec::SeArd(3), 0.1, &data, &opts, 1, &exec, &mut rng)
            .unwrap();
        assert!(res.lnp_peak.is_finite());
        assert_eq!(res.theta_hat.len(), 3);
        assert!(res.sigma_f_hat2 > 0.0);
        // dimension mismatch and approx-on-nd both error cleanly
        assert!(train_model(&ModelSpec::SeArd(2), 0.1, &data, &opts, 1, &exec, &mut rng)
            .is_err());
        assert!(train_model(&ModelSpec::K1, 0.1, &data, &opts, 1, &exec, &mut rng).is_err());
        assert!(train_model(&ModelSpec::SodK2, 0.1, &data, &opts, 1, &exec, &mut rng).is_err());
    }

    #[test]
    fn peak_gradient_is_small() {
        let data = table1_dataset(40, 0.1, 13);
        let mut rng = Xoshiro256::seed_from_u64(21);
        let exec = ExecutionContext::seq();
        let res =
            train_model(&ModelSpec::K1, 0.1, &data, &fast_opts(), 1, &exec, &mut rng).unwrap();
        let model = ModelSpec::K1.build(0.1);
        let prior = BoxPrior::for_model(&model, &data.span().unwrap());
        let (_, mut g) =
            profiled::eval_grad(&model, &data.t, &data.y, &res.theta_hat).unwrap();
        crate::optimize::project_gradient(&res.theta_hat, &mut g, &prior);
        let gnorm = g.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        // CG stops on f_tol as well as grad_tol; the gradient at a peak
        // found via f-stagnation can be ~1e-3 in these units.
        assert!(gnorm < 1e-2, "projected gradient at peak: {gnorm}");
    }
}
