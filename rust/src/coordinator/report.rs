//! Comparison reports: the pipeline's structured output, renderable as a
//! paper-style table or as JSON for downstream tooling.

use crate::util::{Json, Table};

/// Nested-sampling verification attached to a model (the paper's
/// `ln Z_num` column).
#[derive(Clone, Debug)]
pub struct NestedReport {
    pub ln_z: f64,
    pub ln_z_err: f64,
    pub n_evals: usize,
    pub information: f64,
    pub wall_secs: f64,
}

/// Everything the pipeline learned about one model.
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub name: String,
    pub param_names: Vec<String>,
    pub theta_hat: Vec<f64>,
    /// 1σ error bars from the inverse Hessian (§2(a)).
    pub sigma: Vec<f64>,
    pub lnp_peak: f64,
    pub sigma_f_hat: f64,
    /// Laplace ln Z_est (eq. 2.13).
    pub ln_z: f64,
    /// `ln Z − ln Z_winner` (≤ 0; 0 for the ranked winner). Filled in by
    /// [`ComparisonReport::ranked`].
    pub ln_b: f64,
    /// Laplace approximation flagged untrustworthy (non-PD Hessian,
    /// boundary peak, or unconverged optimiser) — the paper's bold-faced
    /// (k₂, n=30) case.
    pub suspect: bool,
    /// Did this model's multistart inherit a lineage parent's peak?
    pub warm_started: bool,
    pub n_evals: usize,
    pub n_modes: usize,
    pub restarts: usize,
    pub wall_secs: f64,
    /// Diagonal jitter the escalation ladder applied at the winning peak
    /// (`0.0` for a clean factorisation) — a non-zero value means the
    /// model trained at the edge of positive definiteness.
    pub jitter: f64,
    pub nested: Option<NestedReport>,
}

/// A ranked model-comparison report.
#[derive(Clone, Debug)]
pub struct ComparisonReport {
    pub dataset: String,
    pub n: usize,
    /// Models sorted by ln Z descending.
    pub models: Vec<ModelReport>,
}

impl ComparisonReport {
    pub fn ranked(dataset: String, n: usize, mut models: Vec<ModelReport>) -> Self {
        // the shared evidence comparator: identical to the tournament's
        // and the serving router's ranking, so report order and slot
        // order can never disagree (NaN ln Z ranks last, deterministic)
        models.sort_by(|a, b| crate::util::desc_nan_last(a.ln_z, b.ln_z));
        if let Some(best) = models.first().map(|m| m.ln_z) {
            for m in &mut models {
                m.ln_b = m.ln_z - best;
            }
        }
        Self { dataset, n, models }
    }

    pub fn model(&self, name: &str) -> Option<&ModelReport> {
        self.models.iter().find(|m| m.name == name)
    }

    /// `ln B = ln Z_a − ln Z_b` (Laplace).
    pub fn ln_bayes(&self, a: &str, b: &str) -> Option<f64> {
        Some(self.model(a)?.ln_z - self.model(b)?.ln_z)
    }

    /// Paper-style ranking table (the Table-2 layout: ln Z, ln B against
    /// the winner, per-model σ error bars as a parameter block below).
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "model", "lnP_peak", "lnZ_est", "lnB", "lnZ_num", "evals", "modes", "start", "jit",
            "flag",
        ]);
        for m in &self.models {
            let (num, nev) = match &m.nested {
                Some(ns) => (
                    format!("{:.2} ± {:.2}", ns.ln_z, ns.ln_z_err),
                    format!("{}+{}", m.n_evals, ns.n_evals),
                ),
                None => ("—".to_string(), format!("{}", m.n_evals)),
            };
            t.add_row(vec![
                m.name.clone(),
                format!("{:.2}", m.lnp_peak),
                format!("{:.2}", m.ln_z),
                format!("{:.2}", m.ln_b),
                num,
                nev,
                format!("{}", m.n_modes),
                if m.warm_started { "warm".to_string() } else { "cold".to_string() },
                if m.jitter > 0.0 { format!("{:.1e}", m.jitter) } else { "0".to_string() },
                if m.suspect { "SUSPECT".to_string() } else { String::new() },
            ]);
        }
        let mut out = format!("dataset {} (n = {})\n", self.dataset, self.n);
        out.push_str(&t.render());
        // Table-2 style hyperparameter block: θ̂ ± σ (inverse-Hessian
        // error bars) per model
        for m in &self.models {
            let params: Vec<String> = m
                .param_names
                .iter()
                .zip(&m.theta_hat)
                .zip(&m.sigma)
                .map(|((nm, th), sg)| format!("{nm} = {th:.4} ± {sg:.4}"))
                .collect();
            out.push_str(&format!(
                "  {}: {}, sigma_f = {:.4}\n",
                m.name,
                params.join(", "),
                m.sigma_f_hat
            ));
        }
        if self.models.len() >= 2 {
            let b = self.models[0].ln_z - self.models[1].ln_z;
            out.push_str(&format!(
                "ln B({} over {}) = {:.2}  [{}]\n",
                self.models[0].name,
                self.models[1].name,
                b,
                crate::evidence::interpret_ln_bayes(b)
            ));
        }
        out
    }

    /// JSON form for machine consumption / EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::Str(self.dataset.clone())),
            ("n", self.n.into()),
            (
                "models",
                Json::Arr(
                    self.models
                        .iter()
                        .map(|m| {
                            let mut fields = vec![
                                ("name", Json::Str(m.name.clone())),
                                (
                                    "param_names",
                                    Json::Arr(
                                        m.param_names
                                            .iter()
                                            .map(|s| Json::Str(s.clone()))
                                            .collect(),
                                    ),
                                ),
                                ("theta_hat", Json::nums(&m.theta_hat)),
                                ("sigma", Json::nums(&m.sigma)),
                                ("lnp_peak", m.lnp_peak.into()),
                                ("sigma_f_hat", m.sigma_f_hat.into()),
                                ("ln_z", m.ln_z.into()),
                                ("ln_b", m.ln_b.into()),
                                ("suspect", m.suspect.into()),
                                ("warm_started", m.warm_started.into()),
                                ("n_evals", m.n_evals.into()),
                                ("n_modes", m.n_modes.into()),
                                ("restarts", m.restarts.into()),
                                ("wall_secs", m.wall_secs.into()),
                                ("jitter", m.jitter.into()),
                            ];
                            if let Some(ns) = &m.nested {
                                fields.push((
                                    "nested",
                                    Json::obj(vec![
                                        ("ln_z", ns.ln_z.into()),
                                        ("ln_z_err", ns.ln_z_err.into()),
                                        ("n_evals", ns.n_evals.into()),
                                        ("information", ns.information.into()),
                                        ("wall_secs", ns.wall_secs.into()),
                                    ]),
                                ));
                            }
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(name: &str, ln_z: f64) -> ModelReport {
        ModelReport {
            name: name.to_string(),
            param_names: vec!["phi0".into()],
            theta_hat: vec![1.0],
            sigma: vec![0.1],
            lnp_peak: -10.0,
            sigma_f_hat: 1.0,
            ln_z,
            ln_b: 0.0,
            suspect: false,
            warm_started: false,
            n_evals: 100,
            n_modes: 1,
            restarts: 10,
            wall_secs: 0.5,
            jitter: 0.0,
            nested: None,
        }
    }

    #[test]
    fn ranking_and_bayes() {
        let r = ComparisonReport::ranked(
            "d".into(),
            100,
            vec![dummy("k1", -20.0), dummy("k2", -19.0)],
        );
        assert_eq!(r.models[0].name, "k2");
        assert!((r.ln_bayes("k2", "k1").unwrap() - 1.0).abs() < 1e-12);
        assert!(r.ln_bayes("k2", "kX").is_none());
        // ranked() fills the per-row Bayes column against the winner
        assert_eq!(r.models[0].ln_b, 0.0);
        assert!((r.models[1].ln_b + 1.0).abs() < 1e-12);
    }

    #[test]
    fn render_contains_table_and_bayes_line() {
        let r = ComparisonReport::ranked(
            "synth".into(),
            30,
            vec![dummy("k1", -17.77), dummy("k2", -18.82)],
        );
        let text = r.render();
        assert!(text.contains("lnZ_est"));
        assert!(text.contains("lnB"));
        assert!(text.contains("ln B(k1 over k2)"));
        // Table-2 parameter block with inverse-Hessian error bars
        assert!(text.contains("phi0 = 1.0000 ± 0.1000"), "{text}");
        assert!(text.contains("cold"));
    }

    #[test]
    fn json_roundtrips() {
        let mut m = dummy("k2", -19.22);
        m.nested = Some(NestedReport {
            ln_z: -19.22,
            ln_z_err: 0.11,
            n_evals: 30000,
            information: 12.0,
            wall_secs: 60.0,
        });
        let r = ComparisonReport::ranked("synth".into(), 100, vec![m]);
        let j = r.to_json();
        let parsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(
            parsed.get("models").unwrap().as_arr().unwrap()[0]
                .get("nested")
                .unwrap()
                .get("n_evals")
                .unwrap()
                .as_usize(),
            Some(30000)
        );
    }
}
