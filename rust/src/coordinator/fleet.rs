//! Multi-tenant serving fleet: artifact store → LRU hot-factor cache →
//! cross-session batch scheduler → [`ServeSession`].
//!
//! One [`ServeSession`] is one user's model roster with live `O(n²)`
//! Cholesky factors. Production traffic means orders of magnitude more
//! sessions than fit factored in RAM, so the fleet keeps sessions in two
//! states:
//!
//! * **cold** — a list of versioned artifact blobs in an
//!   [`ArtifactStore`] (the [`TrainedModel::to_bytes`] format, CRC32
//!   checksummed): `O(artifact bytes)` on disk or in a byte map, no
//!   factors, no likelihood state;
//! * **hot** — a hydrated [`ServeSession`] in a bounded **LRU** of at
//!   most `capacity` residents. A cache miss hydrates from the store via
//!   the zero-evaluation artifact path (decode + `O(n²)` factor
//!   adoption, *never* an `O(n³)` refactorisation — asserted through
//!   [`crate::gp::profiled::CounterSnapshot`] in `rust/tests/fleet.rs`);
//!   eviction persists **dirty** sessions (mutated by
//!   [`Fleet::observe`] / [`Fleet::with_session`]) back to the store via
//!   [`ServeSession::to_artifact_bytes`] before dropping their factors,
//!   so no observation is ever lost to cache pressure.
//!
//! The **scheduler** ([`Fleet::run_batch`]) accepts a batch of
//! `(session_id, t_star)` predict requests, groups them per session in
//! **deterministic arrival order**, hydrates each wave of at most
//! `capacity` distinct sessions sequentially (so the eviction order is a
//! pure function of the request stream), concatenates every group's
//! query points into one batched predict, and drains the wave's groups
//! concurrently — each group under an [`ExecutionContext::split`] share
//! of the fleet budget, so `q` queries across `s` sessions never
//! oversubscribe the machine. Results are bit-identical for any thread
//! count and any batch split (the repo-wide linalg contract), which the
//! determinism suite checks end-to-end: predictions, eviction order and
//! final store bytes all match between 1 thread and max.
//!
//! Everything is observable through [`FleetStats`]: lookups/hits,
//! hydrations (with the wall-clock split into artifact **parse** vs
//! zero-copy **view** establishment vs factor **adoption**), evictions
//! and persisted write-backs.
//!
//! ## Hydration paths
//!
//! Stores hand blobs back as [`AlignedBlob`]s (8-byte-aligned buffers;
//! [`DiskStore`] memory-maps its files, everything else copies into an
//! aligned heap allocation). A blob whose version field is **4** takes
//! the zero-copy path: [`crate::coordinator::artifact_v4::ArtifactView`]
//! verifies the checksum and *borrows* the numeric blocks in place, and
//! [`ServeSession::from_artifact_views`] adopts the factors with one
//! memcpy each — no per-f64 decode loop, no intermediate
//! [`TrainedModel`]. v2/v3 blobs (and mixed-version blob lists) fall
//! back to the field-stream decoder. Either way the wall is split into
//! `hydrate_view_secs` (view establishment), `hydrate_parse_secs`
//! (v2/v3 field decoding) and `hydrate_adopt_secs` (the `O(n²)` factor
//! copies + conditioning probe).

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::data::Dataset;
use crate::gp::predict::Prediction;
use crate::rng::Xoshiro256;
use crate::runtime::ExecutionContext;
use crate::util::Stopwatch;

use super::artifact_v4::{ArtifactView, VERSION_V4};
use super::serve::ServeSession;
use super::tournament::TrainedModel;

// ------------------------------------------------------------ blob buffer

/// An artifact byte buffer whose base address is **8-byte aligned**, so
/// the v4 zero-copy parser can reinterpret its f64 blocks in place (see
/// [`crate::coordinator::artifact_v4`]'s alignment contract). Two
/// backings: a memory-mapped file (page-aligned by the OS; unmapped on
/// drop) and an aligned heap copy (a `u64` allocation viewed as bytes —
/// `Vec<u8>` alone does not guarantee 8-byte alignment). Derefs to
/// `&[u8]`, so v2/v3 decoding works on it unchanged.
pub struct AlignedBlob(Blob);

enum Blob {
    /// Read-only private file mapping. Unmapped on drop.
    #[cfg(unix)]
    Mmap { ptr: *mut u8, len: usize },
    /// Heap copy, 8-aligned via the `u64` backing allocation.
    Heap { buf: Vec<u64>, len: usize },
}

#[cfg(unix)]
mod mmap_sys {
    //! Minimal raw `mmap`/`munmap` bindings (std links libc on unix; no
    //! external crate needed). Constants match Linux and the BSDs.
    use core::ffi::c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;
}

impl AlignedBlob {
    /// Copy `bytes` into an 8-aligned heap buffer.
    pub fn from_slice(bytes: &[u8]) -> Self {
        let len = bytes.len();
        let mut buf = vec![0u64; (len + 7) / 8];
        if len > 0 {
            // SAFETY: the u64 allocation holds ≥ len bytes and a u64
            // buffer may always be viewed/written as raw bytes.
            unsafe {
                std::ptr::copy_nonoverlapping(bytes.as_ptr(), buf.as_mut_ptr() as *mut u8, len)
            };
        }
        Self(Blob::Heap { buf, len })
    }

    /// Memory-map `path` read-only (private mapping). Falls back to an
    /// aligned heap read if the mapping fails, so callers never have to
    /// branch. The caller must not truncate the file while the blob is
    /// alive (the usual mmap caveat).
    #[cfg(unix)]
    pub fn mmap_file(path: &std::path::Path) -> crate::Result<Self> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
        let len = file
            .metadata()
            .map_err(|e| anyhow::anyhow!("stat {}: {e}", path.display()))?
            .len();
        let len = usize::try_from(len)
            .map_err(|_| anyhow::anyhow!("{} is too large to map", path.display()))?;
        if len == 0 {
            return Ok(Self(Blob::Heap { buf: Vec::new(), len: 0 }));
        }
        // SAFETY: read-only private mapping of a freshly opened fd; the
        // kernel validates len/fd and we check for MAP_FAILED. The fd
        // may close after mmap returns — the mapping persists until
        // munmap in Drop.
        let ptr = unsafe {
            mmap_sys::mmap(
                std::ptr::null_mut(),
                len,
                mmap_sys::PROT_READ,
                mmap_sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == mmap_sys::MAP_FAILED || ptr.is_null() {
            let bytes = std::fs::read(path)
                .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
            return Ok(Self::from_slice(&bytes));
        }
        Ok(Self(Blob::Mmap { ptr: ptr as *mut u8, len }))
    }

    /// Buffer length in bytes.
    pub fn len(&self) -> usize {
        match &self.0 {
            #[cfg(unix)]
            Blob::Mmap { len, .. } => *len,
            Blob::Heap { len, .. } => *len,
        }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when backed by a file mapping (vs a heap copy).
    pub fn is_mapped(&self) -> bool {
        match &self.0 {
            #[cfg(unix)]
            Blob::Mmap { .. } => true,
            Blob::Heap { .. } => false,
        }
    }
}

impl std::ops::Deref for AlignedBlob {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match &self.0 {
            #[cfg(unix)]
            // SAFETY: the mapping is PROT_READ, ptr/len came from a
            // successful mmap, and it stays mapped until Drop.
            Blob::Mmap { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            // SAFETY: the u64 allocation holds ≥ len initialized bytes
            // and may always be viewed as raw bytes.
            Blob::Heap { buf, len } => unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len)
            },
        }
    }
}

impl Drop for AlignedBlob {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Blob::Mmap { ptr, len } = &self.0 {
            // SAFETY: exactly the region returned by mmap, unmapped once.
            unsafe { mmap_sys::munmap(*ptr as *mut core::ffi::c_void, *len) };
        }
    }
}

// ------------------------------------------------------------- the store

/// Where cold sessions live: a keyed blob store of artifact bytes, one
/// blob per roster model per session, in rank order. Backends must
/// return blobs bit-identically (`get` after `put` is the identity), so
/// hydration from any backend yields the same factors.
pub trait ArtifactStore {
    /// Persist a session's blobs, replacing anything stored under `id`.
    fn put(&mut self, id: &str, blobs: Vec<Vec<u8>>) -> crate::Result<()>;
    /// The session's blobs, or `None` if it was never persisted.
    fn get(&self, id: &str) -> crate::Result<Option<Vec<Vec<u8>>>>;
    /// The session's blobs as 8-byte-aligned buffers suitable for the v4
    /// zero-copy parser. The default copies [`ArtifactStore::get`] into
    /// aligned heap allocations; backends with mappable storage (see
    /// [`DiskStore`]) override this to avoid the copy entirely.
    fn get_view(&self, id: &str) -> crate::Result<Option<Vec<AlignedBlob>>> {
        Ok(self
            .get(id)?
            .map(|blobs| blobs.iter().map(|b| AlignedBlob::from_slice(b)).collect()))
    }
    /// Does the store hold this session?
    fn contains(&self, id: &str) -> bool;
    /// Delete a session; `true` if it existed.
    fn remove(&mut self, id: &str) -> crate::Result<bool>;
    /// Every stored session id, sorted (deterministic iteration).
    fn ids(&self) -> crate::Result<Vec<String>>;
    /// Total artifact bytes held (the cold-tier footprint).
    fn total_bytes(&self) -> crate::Result<u64>;
    /// Stored session count.
    fn len(&self) -> crate::Result<usize> {
        Ok(self.ids()?.len())
    }
    /// True when nothing is stored.
    fn is_empty(&self) -> crate::Result<bool> {
        Ok(self.len()? == 0)
    }
}

/// Session ids must be usable as file stems on the disk backend; the
/// memory backend enforces the same grammar so a workload moves between
/// backends without re-keying.
pub fn validate_session_id(id: &str) -> crate::Result<()> {
    anyhow::ensure!(
        !id.is_empty()
            && id.len() <= 128
            && !id.starts_with('.')
            && id.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
        "invalid session id {id:?}: want 1–128 chars of [A-Za-z0-9._-], not starting with '.'"
    );
    Ok(())
}

/// In-memory backend: a `BTreeMap` of blob lists. `get` clones the
/// bytes (the fleet mutates its hydrated copy independently of the
/// store).
#[derive(Clone, Debug, Default)]
pub struct MemoryStore {
    map: BTreeMap<String, Vec<Vec<u8>>>,
}

impl MemoryStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ArtifactStore for MemoryStore {
    fn put(&mut self, id: &str, blobs: Vec<Vec<u8>>) -> crate::Result<()> {
        validate_session_id(id)?;
        anyhow::ensure!(!blobs.is_empty(), "refusing to store zero blobs for session {id:?}");
        self.map.insert(id.to_string(), blobs);
        Ok(())
    }

    fn get(&self, id: &str) -> crate::Result<Option<Vec<Vec<u8>>>> {
        validate_session_id(id)?;
        Ok(self.map.get(id).cloned())
    }

    fn contains(&self, id: &str) -> bool {
        self.map.contains_key(id)
    }

    fn remove(&mut self, id: &str) -> crate::Result<bool> {
        validate_session_id(id)?;
        Ok(self.map.remove(id).is_some())
    }

    fn ids(&self) -> crate::Result<Vec<String>> {
        Ok(self.map.keys().cloned().collect()) // BTreeMap: already sorted
    }

    fn total_bytes(&self) -> crate::Result<u64> {
        Ok(self.map.values().flatten().map(|b| b.len() as u64).sum())
    }

    fn len(&self) -> crate::Result<usize> {
        Ok(self.map.len())
    }
}

/// On-disk backend: one file per blob, `<root>/<id>.<k>.gpfast` for the
/// session's `k`-th ranked model. Cold sessions cost `O(artifact bytes)`
/// of disk and **zero** RAM. `put` rewrites the session's files and
/// removes stale higher-`k` leftovers from a previous larger roster, so
/// `get` can rebuild the blob list by reading `k = 0, 1, …` until the
/// first gap.
#[derive(Clone, Debug)]
pub struct DiskStore {
    root: PathBuf,
}

impl DiskStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn new(root: impl Into<PathBuf>) -> crate::Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .map_err(|e| anyhow::anyhow!("creating artifact store {}: {e}", root.display()))?;
        Ok(Self { root })
    }

    /// The directory backing this store.
    pub fn root(&self) -> &std::path::Path {
        &self.root
    }

    fn blob_path(&self, id: &str, k: usize) -> PathBuf {
        self.root.join(format!("{id}.{k}.gpfast"))
    }
}

impl ArtifactStore for DiskStore {
    fn put(&mut self, id: &str, blobs: Vec<Vec<u8>>) -> crate::Result<()> {
        validate_session_id(id)?;
        anyhow::ensure!(!blobs.is_empty(), "refusing to store zero blobs for session {id:?}");
        for (k, blob) in blobs.iter().enumerate() {
            let path = self.blob_path(id, k);
            std::fs::write(&path, blob)
                .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        }
        // a previous persist of this session may have had a larger roster
        let mut k = blobs.len();
        while self.blob_path(id, k).exists() {
            let path = self.blob_path(id, k);
            std::fs::remove_file(&path)
                .map_err(|e| anyhow::anyhow!("removing stale {}: {e}", path.display()))?;
            k += 1;
        }
        Ok(())
    }

    fn get(&self, id: &str) -> crate::Result<Option<Vec<Vec<u8>>>> {
        validate_session_id(id)?;
        if !self.blob_path(id, 0).exists() {
            return Ok(None);
        }
        let mut blobs = Vec::new();
        let mut k = 0;
        loop {
            let path = self.blob_path(id, k);
            if !path.exists() {
                break;
            }
            let bytes = std::fs::read(&path)
                .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
            blobs.push(bytes);
            k += 1;
        }
        Ok(Some(blobs))
    }

    fn get_view(&self, id: &str) -> crate::Result<Option<Vec<AlignedBlob>>> {
        validate_session_id(id)?;
        if !self.blob_path(id, 0).exists() {
            return Ok(None);
        }
        let mut blobs = Vec::new();
        let mut k = 0;
        loop {
            let path = self.blob_path(id, k);
            if !path.exists() {
                break;
            }
            #[cfg(unix)]
            blobs.push(AlignedBlob::mmap_file(&path)?);
            #[cfg(not(unix))]
            blobs.push(AlignedBlob::from_slice(&std::fs::read(&path).map_err(|e| {
                anyhow::anyhow!("reading {}: {e}", path.display())
            })?));
            k += 1;
        }
        Ok(Some(blobs))
    }

    fn contains(&self, id: &str) -> bool {
        self.blob_path(id, 0).exists()
    }

    fn remove(&mut self, id: &str) -> crate::Result<bool> {
        validate_session_id(id)?;
        let mut k = 0;
        while self.blob_path(id, k).exists() {
            let path = self.blob_path(id, k);
            std::fs::remove_file(&path)
                .map_err(|e| anyhow::anyhow!("removing {}: {e}", path.display()))?;
            k += 1;
        }
        Ok(k > 0)
    }

    fn ids(&self) -> crate::Result<Vec<String>> {
        let mut out = Vec::new();
        let entries = std::fs::read_dir(&self.root)
            .map_err(|e| anyhow::anyhow!("listing artifact store {}: {e}", self.root.display()))?;
        for entry in entries {
            let entry = entry
                .map_err(|e| anyhow::anyhow!("listing artifact store {}: {e}", self.root.display()))?;
            if let Some(name) = entry.file_name().to_str() {
                if let Some(id) = name.strip_suffix(".0.gpfast") {
                    out.push(id.to_string());
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn total_bytes(&self) -> crate::Result<u64> {
        let mut total = 0u64;
        let entries = std::fs::read_dir(&self.root)
            .map_err(|e| anyhow::anyhow!("listing artifact store {}: {e}", self.root.display()))?;
        for entry in entries {
            let entry = entry
                .map_err(|e| anyhow::anyhow!("listing artifact store {}: {e}", self.root.display()))?;
            let is_blob =
                entry.file_name().to_str().is_some_and(|n| n.ends_with(".gpfast"));
            if is_blob {
                let meta = entry
                    .metadata()
                    .map_err(|e| anyhow::anyhow!("stat in {}: {e}", self.root.display()))?;
                total += meta.len();
            }
        }
        Ok(total)
    }
}

// ------------------------------------------------------------- the fleet

/// One predict call for the scheduler: which session, which query points.
#[derive(Clone, Debug)]
pub struct PredictRequest {
    /// Target session (must be resident or in the store).
    pub session_id: String,
    /// Query points for that session.
    pub t_star: Vec<f64>,
}

/// Fleet-level counters and hydration timings. All counts are
/// monotonic; timings accumulate wall-clock seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetStats {
    /// Session lookups (one per [`Fleet::predict`]/[`Fleet::observe`]
    /// call, one per session *group* in [`Fleet::run_batch`]).
    pub lookups: u64,
    /// Lookups answered by a resident session.
    pub hits: u64,
    /// Cold hydrations from the store (lookups − hits for ids that were
    /// stored; unknown ids error without counting here).
    pub hydrations: u64,
    /// Residents dropped by LRU pressure or [`Fleet::evict_all`].
    pub evictions: u64,
    /// Dirty sessions written back to the store (on eviction or
    /// [`Fleet::flush`]).
    pub persisted: u64,
    /// Hydration seconds spent decoding v2/v3 artifact bytes into
    /// [`TrainedModel`]s (the per-f64 field-stream walk). Stays ~0 when
    /// every blob takes the v4 zero-copy path.
    pub hydrate_parse_secs: f64,
    /// Hydration seconds spent establishing v4 zero-copy views
    /// (checksum + header/meta validation; no numeric materialisation).
    /// Stays 0 on the v2/v3 path.
    pub hydrate_view_secs: f64,
    /// Hydration seconds spent adopting factors into a live session
    /// (`O(n²)` factor copies + conditioning probe).
    pub hydrate_adopt_secs: f64,
}

impl FleetStats {
    /// Fraction of lookups served without hydration.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups as f64
    }

    /// Fraction of lookups that paid a cold hydration.
    pub fn hydration_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hydrations as f64 / self.lookups as f64
    }
}

struct Resident {
    id: String,
    session: ServeSession,
    /// Mutated since hydration/admission — must persist before dropping.
    dirty: bool,
    /// LRU clock value at last touch (monotonic, never reused).
    last_used: u64,
}

/// The shard manager: a bounded LRU of hydrated sessions over an
/// [`ArtifactStore`], plus the cross-session batch scheduler. See the
/// module docs for the design; all cache-management decisions are made
/// sequentially on the caller's thread (only the *drain* of a request
/// wave fans out), so the fleet's behaviour — hit/miss pattern, eviction
/// order, final store bytes — is a deterministic function of the call
/// sequence, independent of the thread budget.
pub struct Fleet<S: ArtifactStore> {
    store: S,
    capacity: usize,
    exec: ExecutionContext,
    residents: Vec<Resident>,
    clock: u64,
    stats: FleetStats,
    eviction_log: Vec<String>,
    /// Format every write-back ([`Fleet::put_artifacts`],
    /// [`Fleet::flush`], eviction persists) encodes with: 3 (default,
    /// field-stream) or 4 (zero-copy layout).
    artifact_version: u32,
    /// v4-only spectral-truncation tolerance (`None` = packed exact).
    compress_tol: Option<f64>,
}

impl<S: ArtifactStore> Fleet<S> {
    /// A fleet over `store` keeping at most `capacity` (clamped ≥ 1)
    /// sessions hydrated, draining predict work through `exec`. Writes
    /// artifacts in the v3 format by default; see
    /// [`Fleet::set_artifact_format`].
    pub fn new(store: S, capacity: usize, exec: ExecutionContext) -> Self {
        Self {
            store,
            capacity: capacity.max(1),
            exec,
            residents: Vec::new(),
            clock: 0,
            stats: FleetStats::default(),
            eviction_log: Vec::new(),
            artifact_version: 3,
            compress_tol: None,
        }
    }

    /// Choose the artifact format for every subsequent write-back:
    /// `version` 3 (field-stream) or 4 (zero-copy layout);
    /// `compress_tol` opts v4 into truncated-spectral factor compression
    /// (relative spectrum-mass tolerance in `[0, 1)`; see
    /// [`crate::coordinator::artifact_v4`]). Reads always auto-detect,
    /// so a store may hold mixed versions mid-migration.
    pub fn set_artifact_format(
        &mut self,
        version: u32,
        compress_tol: Option<f64>,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            version == 3 || version == 4,
            "unsupported artifact version {version} (want 3 or 4)"
        );
        anyhow::ensure!(
            compress_tol.is_none() || version == 4,
            "factor compression requires artifact version 4"
        );
        if let Some(tol) = compress_tol {
            anyhow::ensure!(
                tol.is_finite() && (0.0..1.0).contains(&tol),
                "compression tolerance {tol} out of range [0, 1)"
            );
        }
        self.artifact_version = version;
        self.compress_tol = compress_tol;
        Ok(())
    }

    /// The artifact version write-backs encode with.
    pub fn artifact_version(&self) -> u32 {
        self.artifact_version
    }

    /// The LRU capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Currently hydrated session count (≤ capacity).
    pub fn resident_count(&self) -> usize {
        self.residents.len()
    }

    /// Hydrated session ids, oldest admission first.
    pub fn resident_ids(&self) -> Vec<&str> {
        self.residents.iter().map(|r| r.id.as_str()).collect()
    }

    /// Is this session currently hydrated?
    pub fn is_resident(&self, id: &str) -> bool {
        self.position(id).is_some()
    }

    /// Counters and hydration timings so far.
    pub fn stats(&self) -> FleetStats {
        self.stats
    }

    /// Every eviction so far, in order — the determinism suite replays a
    /// workload at 1 and max threads and asserts these match exactly.
    pub fn eviction_log(&self) -> &[String] {
        &self.eviction_log
    }

    /// The backing store (read-only).
    pub fn store(&self) -> &S {
        &self.store
    }

    /// Tear down the fleet, returning the store. Call
    /// [`Fleet::evict_all`] first if dirty residents must be persisted.
    pub fn into_store(self) -> S {
        self.store
    }

    /// Seed the store with a freshly trained session's artifacts (rank
    /// order, as [`super::tournament::TournamentResult::models`] comes).
    /// Any hydrated copy of `id` is dropped un-persisted: the new bytes
    /// are the truth now.
    pub fn put_artifacts(
        &mut self,
        id: &str,
        models: &[TrainedModel],
        data: &Dataset,
    ) -> crate::Result<()> {
        anyhow::ensure!(!models.is_empty(), "no models to persist for session {id:?}");
        let mut blobs = Vec::with_capacity(models.len());
        for tm in models {
            blobs.push(if self.artifact_version == 4 {
                tm.to_bytes_v4(data, self.compress_tol)?
            } else {
                tm.to_bytes(data)?
            });
        }
        if let Some(pos) = self.position(id) {
            self.residents.remove(pos);
        }
        self.store.put(id, blobs)
    }

    /// Admit an already-hydrated session (e.g. fresh from
    /// [`ServeSession::train_and_serve`]) as a dirty resident; it will
    /// be persisted on eviction/flush. Errors if `id` is already
    /// resident.
    pub fn admit(&mut self, id: &str, session: ServeSession) -> crate::Result<()> {
        validate_session_id(id)?;
        anyhow::ensure!(self.position(id).is_none(), "session {id:?} is already resident");
        self.make_room()?;
        self.clock += 1;
        self.residents.push(Resident {
            id: id.to_string(),
            session,
            dirty: true,
            last_used: self.clock,
        });
        Ok(())
    }

    /// Serve one session's predict call (hydrating it if cold) under the
    /// fleet's full thread budget. For cross-session batches prefer
    /// [`Fleet::run_batch`], which shares the budget across sessions.
    pub fn predict(&mut self, id: &str, t_star: &[f64]) -> crate::Result<Prediction> {
        let pos = self.ensure_resident(id)?;
        Ok(self.residents[pos].session.predict(t_star))
    }

    /// Stream one observation into a session (hydrating it if cold) and
    /// mark it dirty — it will be written back to the store before its
    /// factors are dropped.
    pub fn observe(&mut self, id: &str, t: f64, y: f64) -> crate::Result<()> {
        let pos = self.ensure_resident(id)?;
        let r = &mut self.residents[pos];
        r.session.observe(t, y)?;
        r.dirty = true;
        Ok(())
    }

    /// Run arbitrary session logic (retrain, window tuning, …) against a
    /// hydrated resident, conservatively marking it dirty.
    pub fn with_session<R>(
        &mut self,
        id: &str,
        f: impl FnOnce(&mut ServeSession) -> R,
    ) -> crate::Result<R> {
        let pos = self.ensure_resident(id)?;
        let r = &mut self.residents[pos];
        r.dirty = true;
        Ok(f(&mut r.session))
    }

    /// The batch scheduler: group requests per session in arrival order,
    /// hydrate and drain them in waves of at most `capacity` distinct
    /// sessions, each wave's groups predicted concurrently under an
    /// [`ExecutionContext::split`] share. Returns one [`Prediction`] per
    /// request, in request order. See the module docs for the
    /// determinism argument.
    pub fn run_batch(&mut self, requests: &[PredictRequest]) -> crate::Result<Vec<Prediction>> {
        // group per session, preserving first-arrival order
        let mut groups: Vec<(String, Vec<usize>)> = Vec::new();
        for (i, req) in requests.iter().enumerate() {
            match groups.iter_mut().find(|(id, _)| *id == req.session_id) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((req.session_id.clone(), vec![i])),
            }
        }
        let mut out: Vec<Option<Prediction>> = (0..requests.len()).map(|_| None).collect();
        let mut g0 = 0;
        while g0 < groups.len() {
            // a wave never exceeds capacity, so hydrating its members in
            // arrival order can only evict sessions outside the wave
            // (every wave member, once touched, outranks them in the LRU)
            let wave = (groups.len() - g0).min(self.capacity);
            let wave_groups = &groups[g0..g0 + wave];
            let mut positions = Vec::with_capacity(wave);
            for (id, _) in wave_groups {
                positions.push(self.ensure_resident(id)?);
            }
            let child = self.exec.split(wave);
            let residents = &self.residents;
            let jobs: Vec<_> = wave_groups
                .iter()
                .zip(&positions)
                .map(|((_, idxs), &pos)| {
                    let session = &residents[pos].session;
                    let child = child.clone();
                    let idxs = idxs.as_slice();
                    move || {
                        // one batched predict per session: the group's
                        // query points share a single multi-RHS solve
                        let total: usize =
                            idxs.iter().map(|&i| requests[i].t_star.len()).sum();
                        let mut cat = Vec::with_capacity(total);
                        for &i in idxs {
                            cat.extend_from_slice(&requests[i].t_star);
                        }
                        let joint = session.predict_with(&cat, &child);
                        let mut outs = Vec::with_capacity(idxs.len());
                        let mut off = 0;
                        for &i in idxs {
                            let q = requests[i].t_star.len();
                            outs.push((
                                i,
                                Prediction {
                                    mean: joint.mean[off..off + q].to_vec(),
                                    sd: joint.sd[off..off + q].to_vec(),
                                },
                            ));
                            off += q;
                        }
                        outs
                    }
                })
                .collect();
            for group_out in self.exec.run_jobs_collect(jobs) {
                for (i, p) in group_out {
                    out[i] = Some(p);
                }
            }
            g0 += wave;
        }
        Ok(out.into_iter().map(|p| p.expect("every request drained")).collect())
    }

    /// Persist every dirty resident to the store (keeping it hydrated);
    /// returns how many were written.
    pub fn flush(&mut self) -> crate::Result<usize> {
        let mut written = 0;
        for pos in 0..self.residents.len() {
            if self.residents[pos].dirty {
                let blobs = self.residents[pos]
                    .session
                    .to_artifact_bytes_with(self.artifact_version, self.compress_tol)?;
                self.store.put(&self.residents[pos].id, blobs)?;
                self.residents[pos].dirty = false;
                self.stats.persisted += 1;
                written += 1;
            }
        }
        Ok(written)
    }

    /// Evict every resident in LRU order, persisting dirty ones — the
    /// clean-shutdown path.
    pub fn evict_all(&mut self) -> crate::Result<()> {
        while !self.residents.is_empty() {
            self.evict_lru()?;
        }
        Ok(())
    }

    // ----------------------------------------------------------- internals

    fn position(&self, id: &str) -> Option<usize> {
        self.residents.iter().position(|r| r.id == id)
    }

    /// Touch `id` (hit) or hydrate it from the store (miss), returning
    /// its index in `residents`.
    fn ensure_resident(&mut self, id: &str) -> crate::Result<usize> {
        self.stats.lookups += 1;
        if let Some(pos) = self.position(id) {
            self.stats.hits += 1;
            self.clock += 1;
            self.residents[pos].last_used = self.clock;
            return Ok(pos);
        }
        let blobs = self.store.get_view(id)?.ok_or_else(|| {
            anyhow::anyhow!("fleet: unknown session {id:?} (not resident, not in the store)")
        })?;
        anyhow::ensure!(!blobs.is_empty(), "fleet: session {id:?} has zero stored blobs");
        // timed in phases (the hydrate_split bench rows): v4 blobs get a
        // zero-copy view (checksum + validation, no numeric decode) then
        // one O(n²) memcpy per factor at adoption; v2/v3 blobs pay the
        // field-stream parse into TrainedModels first. A mixed-version
        // blob list takes the v2/v3 path for all blobs (from_bytes
        // dispatches v4 too, so correctness is version-independent).
        let all_v4 = blobs
            .iter()
            .all(|b| b.len() >= 12 && b[8..12] == VERSION_V4.to_le_bytes());
        let session = if all_v4 {
            let sw = Stopwatch::start();
            let mut views = Vec::with_capacity(blobs.len());
            for (k, blob) in blobs.iter().enumerate() {
                views.push(ArtifactView::parse(blob).map_err(|e| {
                    anyhow::anyhow!("hydrating session {id:?} blob {k}: {e}")
                })?);
            }
            self.stats.hydrate_view_secs += sw.elapsed_secs();
            let sw = Stopwatch::start();
            let session = ServeSession::from_artifact_views(&views, self.exec.clone())
                .map_err(|e| anyhow::anyhow!("hydrating session {id:?}: {e}"))?;
            self.stats.hydrate_adopt_secs += sw.elapsed_secs();
            session
        } else {
            let sw = Stopwatch::start();
            let mut models = Vec::with_capacity(blobs.len());
            let mut data: Option<Dataset> = None;
            for (k, blob) in blobs.iter().enumerate() {
                let (tm, d) = TrainedModel::from_bytes(blob)
                    .map_err(|e| anyhow::anyhow!("hydrating session {id:?} blob {k}: {e}"))?;
                match &data {
                    None => data = Some(d),
                    Some(d0) => anyhow::ensure!(
                        d0.t == d.t && d0.y == d.y,
                        "hydrating session {id:?}: blob {k} carries different data than blob 0"
                    ),
                }
                models.push(tm);
            }
            let data = data.expect("non-empty blob list");
            self.stats.hydrate_parse_secs += sw.elapsed_secs();
            let sw = Stopwatch::start();
            let session = ServeSession::from_tournament(&models, &data, self.exec.clone())
                .map_err(|e| anyhow::anyhow!("hydrating session {id:?}: {e}"))?;
            self.stats.hydrate_adopt_secs += sw.elapsed_secs();
            session
        };
        drop(blobs); // release mappings before the session outlives them
        self.stats.hydrations += 1;
        self.make_room()?;
        self.clock += 1;
        self.residents.push(Resident {
            id: id.to_string(),
            session,
            dirty: false,
            last_used: self.clock,
        });
        Ok(self.residents.len() - 1)
    }

    fn make_room(&mut self) -> crate::Result<()> {
        while self.residents.len() >= self.capacity {
            self.evict_lru()?;
        }
        Ok(())
    }

    fn evict_lru(&mut self) -> crate::Result<()> {
        let pos = self
            .residents
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.last_used)
            .map(|(i, _)| i)
            .expect("evict_lru on an empty fleet");
        if self.residents[pos].dirty {
            let blobs = self.residents[pos]
                .session
                .to_artifact_bytes_with(self.artifact_version, self.compress_tol)?;
            self.store.put(&self.residents[pos].id, blobs)?;
            self.stats.persisted += 1;
        }
        let r = self.residents.remove(pos);
        self.stats.evictions += 1;
        self.eviction_log.push(r.id);
        Ok(())
    }
}

// ---------------------------------------------------------- the workload

/// Deterministic Zipf-distributed session sampler for fleet benchmarks
/// and tests: session rank `i` (0-based) is drawn with probability
/// `∝ 1/(i+1)^s`, the classic heavy-tailed popularity law — a few hot
/// sessions dominate while the long tail guarantees a steady stream of
/// cold hydrations. Sampling inverts a precomputed CDF with the repo's
/// seeded [`Xoshiro256`], so a (sessions, exponent, seed) triple always
/// replays the same request stream.
pub struct ZipfWorkload {
    cdf: Vec<f64>,
    rng: Xoshiro256,
}

impl ZipfWorkload {
    /// A sampler over `n_sessions ≥ 1` ranks with exponent `s` (`s = 0`
    /// is uniform; larger `s` concentrates traffic on low ranks).
    pub fn new(n_sessions: usize, exponent: f64, seed: u64) -> Self {
        assert!(n_sessions >= 1, "ZipfWorkload needs at least one session");
        assert!(exponent.is_finite() && exponent >= 0.0, "bad Zipf exponent {exponent}");
        let mut cdf = Vec::with_capacity(n_sessions);
        let mut acc = 0.0;
        for i in 0..n_sessions {
            acc += ((i + 1) as f64).powf(-exponent);
            cdf.push(acc);
        }
        Self { cdf, rng: Xoshiro256::seed_from_u64(seed) }
    }

    /// Next session rank in `0..n_sessions`.
    pub fn next_session(&mut self) -> usize {
        let total = *self.cdf.last().expect("non-empty CDF");
        let u = self.rng.uniform() * total;
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_id_grammar() {
        for good in ["a", "s000042", "user-7_b.2", "X"] {
            assert!(validate_session_id(good).is_ok(), "{good:?} should be valid");
        }
        for bad in ["", ".hidden", "a/b", "a b", "é"] {
            assert!(validate_session_id(bad).is_err(), "{bad:?} should be invalid");
        }
        let too_long = "x".repeat(129);
        assert!(validate_session_id(&too_long).is_err());
    }

    #[test]
    fn memory_store_round_trips_and_sorts_ids() {
        let mut s = MemoryStore::new();
        assert!(s.is_empty().unwrap());
        s.put("b", vec![vec![1, 2], vec![3]]).unwrap();
        s.put("a", vec![vec![9]]).unwrap();
        assert_eq!(s.get("b").unwrap().unwrap(), vec![vec![1, 2], vec![3]]);
        assert!(s.get("missing").unwrap().is_none());
        assert!(s.contains("a") && !s.contains("c"));
        assert_eq!(s.ids().unwrap(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(s.total_bytes().unwrap(), 4);
        assert_eq!(s.len().unwrap(), 2);
        // put replaces wholesale
        s.put("b", vec![vec![7]]).unwrap();
        assert_eq!(s.get("b").unwrap().unwrap(), vec![vec![7]]);
        assert!(s.remove("a").unwrap());
        assert!(!s.remove("a").unwrap());
        assert!(s.put("x", Vec::new()).is_err());
    }

    #[test]
    fn disk_store_round_trips_and_prunes_stale_blobs() {
        let root = std::env::temp_dir()
            .join(format!("gpfast_fleet_store_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let mut s = DiskStore::new(&root).unwrap();
        s.put("sess.1", vec![vec![1, 2, 3], vec![4, 5], vec![6]]).unwrap();
        assert_eq!(
            s.get("sess.1").unwrap().unwrap(),
            vec![vec![1, 2, 3], vec![4, 5], vec![6]]
        );
        // shrinking the roster removes the stale third blob file
        s.put("sess.1", vec![vec![9, 9]]).unwrap();
        assert_eq!(s.get("sess.1").unwrap().unwrap(), vec![vec![9, 9]]);
        s.put("other", vec![vec![1]]).unwrap();
        assert_eq!(s.ids().unwrap(), vec!["other".to_string(), "sess.1".to_string()]);
        assert_eq!(s.total_bytes().unwrap(), 3);
        assert!(s.remove("sess.1").unwrap());
        assert!(!s.contains("sess.1"));
        assert!(s.get("sess.1").unwrap().is_none());
        // path traversal shapes rejected before touching the filesystem
        assert!(s.put("../escape", vec![vec![1]]).is_err());
        assert!(s.get("../escape").is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn zipf_is_deterministic_and_head_heavy() {
        let mut a = ZipfWorkload::new(1000, 1.1, 42);
        let mut b = ZipfWorkload::new(1000, 1.1, 42);
        let draws_a: Vec<usize> = (0..500).map(|_| a.next_session()).collect();
        let draws_b: Vec<usize> = (0..500).map(|_| b.next_session()).collect();
        assert_eq!(draws_a, draws_b, "same seed must replay the same stream");
        assert!(draws_a.iter().all(|&s| s < 1000));
        // heavy head: rank 0 alone should out-draw the entire back half
        let head = draws_a.iter().filter(|&&s| s == 0).count();
        let back_half = draws_a.iter().filter(|&&s| s >= 500).count();
        assert!(
            head > back_half,
            "rank 0 drew {head}, back half drew {back_half} — not Zipf-shaped"
        );
        // different seed, different stream
        let mut c = ZipfWorkload::new(1000, 1.1, 43);
        let draws_c: Vec<usize> = (0..500).map(|_| c.next_session()).collect();
        assert_ne!(draws_a, draws_c);
    }
}
