//! The model-comparison tournament — the paper's §2(a) headline workflow
//! as one pipeline over one artifact.
//!
//! Training, evidence and serving used to be three separate calls the
//! caller had to wire together (`train_model` → `laplace_evidence` →
//! `ServeSession`). The tournament unifies them around the
//! [`TrainedModel`] artifact: for every [`super::registry::Roster`]
//! member it produces the spec, the full [`TrainResult`] (including the
//! adoptable peak factor), the [`LaplaceEvidence`] with its σ error
//! bars, and the optional nested-sampling verification — then ranks the
//! roster by ln Z into a Bayes-factor [`ComparisonReport`] and hands the
//! artifacts to the serving router
//! ([`super::serve::ServeSession::from_tournament`]).
//!
//! ## Scheduling
//!
//! The roster's declared warm-start lineage
//! ([`super::registry::ModelSpec::warm_start_parent`]) orders training
//! into **generations**: parents finish before the children they seed.
//! Within a generation the models have no dependency on each other and
//! train **concurrently** in waves of at most the thread budget, each
//! wave member under `exec.split(g)` of the shared budget and a
//! proportional share of the worker fan-out — the borrowed-slots rule
//! applied across *models*, not just restarts, so models × restarts ×
//! linalg never exceeds the configured budget (a 1-thread budget trains
//! the generation serially with the full budget per model).
//!
//! Warm-started children **replace** random restarts with their parent's
//! peak (matched by hyperparameter name, unmatched coordinates filled
//! from the prior) within the same total start budget:
//! `min(WARM_FILLS, restarts)` deterministic starts plus
//! `restarts − fills` random draws — never more starts than a cold
//! model, and the warm starts begin near a peak, so children record
//! measurably fewer profiled-likelihood evaluations than a cold
//! multistart of the same model (asserted in `rust/tests/tournament.rs`,
//! measured in `benches/tournament.rs`).
//!
//! ## Determinism
//!
//! Every RNG draw (warm-start fills, restart seeds, nested sampling)
//! happens on the master RNG at schedule time in roster order; the
//! concurrent training itself is RNG-free and the linalg underneath is
//! bit-identical for any thread budget. A tournament is therefore fully
//! reproducible from its seed, and a **tournament-of-one consumes
//! exactly the RNG stream of a plain [`train_model`] call** — the old
//! single-model path is a special case, bit for bit.

use crate::data::Dataset;
use crate::evidence::{laplace_evidence, LaplaceEvidence};
use crate::gp::serve::Predictor;
use crate::nested::nested_sample;
use crate::priors::BoxPrior;
use crate::rng::Xoshiro256;
use crate::util::Stopwatch;

use super::registry::{ModelSpec, Roster};
use super::report::{ComparisonReport, ModelReport, NestedReport};
use super::train::{train_model_seeded, TrainOptions, TrainResult};
use super::PipelineConfig;

/// Random prior fills drawn per warm start, giving the child model's new
/// coordinates several basins to explore around the inherited peak.
pub const WARM_FILLS: usize = 3;

/// Everything one tournament entrant produced, in one artifact: the
/// buildable spec, the training result (with its adoptable peak factor),
/// the Laplace evidence (ln Z + error bars), and the optional
/// nested-sampling verification. This is the unit the report renders and
/// the serving router adopts.
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub spec: ModelSpec,
    /// Fixed noise level the model was trained with.
    pub sigma_n: f64,
    /// Hyperparameter names (order matches `train.theta_hat`).
    pub param_names: Vec<String>,
    /// Multistart training result; `train.peak_eval` carries the factor
    /// and `α` the serving layer adopts without refactorising.
    pub train: TrainResult,
    /// Laplace evidence at the peak (eq. 2.13) with σ error bars.
    pub evidence: LaplaceEvidence,
    /// Nested-sampling verification, when the tournament ran it.
    pub nested: Option<NestedReport>,
    /// Did this model inherit starts from a lineage parent?
    pub warm_started: bool,
    /// Configured random-restart budget (the warm-start policy may have
    /// replaced part of it — `train.restart_values.len()` has the actual
    /// start count).
    pub restarts: usize,
    /// Wall-clock: training + evidence (+ nested verification).
    pub wall_secs: f64,
}

impl TrainedModel {
    /// The spec's canonical name.
    pub fn name(&self) -> &'static str {
        self.spec.name()
    }

    /// Laplace ln Z (the ranking key).
    pub fn ln_z(&self) -> f64 {
        self.evidence.ln_z
    }

    /// Wire this artifact into a serving [`Predictor`] by **adopting**
    /// the peak evaluation — an `O(n²)` factor copy, no re-assembly and
    /// no `O(n³)` refactorisation. `data` must be the training set.
    /// Approximate specs serve through their reduced dataset
    /// ([`crate::gp::approx::serve_parts`]): the stride subset for SoD,
    /// the inducing grid with pseudo-targets for FITC — both derived
    /// deterministically from the data and the stored evaluation.
    pub fn predictor(&self, data: &Dataset) -> crate::Result<Predictor> {
        anyhow::ensure!(
            self.train.peak_eval.chol.dim() == self.spec.factor_dim(data.len()),
            "TrainedModel factor dim {} does not match {} for n = {}",
            self.train.peak_eval.chol.dim(),
            self.spec.factor_dim(data.len()),
            data.len()
        );
        if data.d() > 1 || data.is_heteroscedastic() {
            // exact specs only (train_model rejects approx specs for
            // nd/heteroscedastic data), so the full dataset serves
            anyhow::ensure!(
                self.spec.approx().is_none(),
                "approximate spec {} cannot serve nd/heteroscedastic data",
                self.name()
            );
            return Ok(Predictor::from_eval_nd(
                self.spec.build(self.sigma_n),
                data.t.clone(),
                data.extra.clone(),
                data.noise.clone(),
                data.y.clone(),
                self.train.theta_hat.clone(),
                self.train.peak_eval.clone(),
            ));
        }
        let (t_serve, y_serve) = match self.spec.approx() {
            None => (data.t.clone(), data.y.clone()),
            Some(kind) => {
                crate::gp::approx::serve_parts(kind, &data.t, &data.y, &self.train.peak_eval)
            }
        };
        Ok(Predictor::from_eval(
            self.spec.build(self.sigma_n),
            t_serve,
            y_serve,
            self.train.theta_hat.clone(),
            self.train.peak_eval.clone(),
        ))
    }

    /// The per-model row of the comparison report.
    pub fn report(&self) -> ModelReport {
        ModelReport {
            name: self.spec.name().to_string(),
            param_names: self.param_names.clone(),
            theta_hat: self.train.theta_hat.clone(),
            sigma: self.evidence.sigma.clone(),
            lnp_peak: self.train.lnp_peak,
            sigma_f_hat: self.train.sigma_f_hat2.sqrt(),
            ln_z: self.evidence.ln_z,
            ln_b: 0.0, // filled in by ComparisonReport::ranked
            suspect: self.evidence.suspect || !self.train.converged,
            warm_started: self.warm_started,
            n_evals: self.train.n_evals,
            n_modes: self.train.n_modes,
            restarts: self.restarts,
            wall_secs: self.wall_secs,
            jitter: self.train.jitter,
            nested: self.nested.clone(),
        }
    }
}

/// A finished tournament: the ranked artifacts plus the rendered-ready
/// comparison report (both ordered by ln Z, winner first).
#[derive(Clone, Debug)]
pub struct TournamentResult {
    /// Trained artifacts, ranked by Laplace ln Z descending.
    pub models: Vec<TrainedModel>,
    /// The Bayes-factor ranking table over the same models.
    pub report: ComparisonReport,
}

impl TournamentResult {
    /// The evidence winner.
    pub fn winner(&self) -> &TrainedModel {
        &self.models[0]
    }

    /// Look up an entrant by canonical name.
    pub fn model(&self, name: &str) -> Option<&TrainedModel> {
        self.models.iter().find(|m| m.name() == name)
    }
}

/// The tournament runner: trains a whole roster under one shared budget
/// and ranks it by Laplace evidence. See the module docs for the
/// scheduling and determinism contracts.
pub struct Tournament {
    pub config: PipelineConfig,
}

/// One scheduled training job (all RNG draws already done).
struct Job {
    idx: usize,
    spec: ModelSpec,
    opts: TrainOptions,
    seeds: Vec<u64>,
    warm: bool,
}

impl Tournament {
    pub fn new(config: PipelineConfig) -> Self {
        Self { config }
    }

    /// Convenience: a tournament over a single spec (the shrunken form of
    /// the old standalone training path — same RNG stream, same result,
    /// plus the evidence the artifact carries).
    pub fn single(spec: ModelSpec, mut config: PipelineConfig) -> Self {
        config.models = vec![spec];
        Self { config }
    }

    /// Train every roster model (lineage-ordered, concurrently within a
    /// generation), compute every Laplace evidence, and rank by ln Z.
    pub fn run(&self, data: &Dataset, rng: &mut Xoshiro256) -> crate::Result<TournamentResult> {
        let cfg = &self.config;
        let roster = Roster::new(cfg.models.clone())?;
        let span = data.span()?;
        let mut slots: Vec<Option<TrainedModel>> = (0..roster.len()).map(|_| None).collect();
        for gen in roster.generations() {
            // --- schedule: every RNG draw happens here, in roster order
            let mut jobs: Vec<Job> = Vec::with_capacity(gen.len());
            for &i in &gen {
                let spec = roster.specs()[i].clone();
                let model = spec.build(cfg.sigma_n);
                let prior = BoxPrior::for_model(&model, &span);
                let mut opts = cfg.train.clone();
                let restarts = cfg.train.multistart.restarts.max(1);
                let mut n_warm = 0usize;
                if let Some(p) = roster.warm_parent_index(i) {
                    let parent = slots[p]
                        .as_ref()
                        .expect("lineage schedule: parent trained in an earlier generation");
                    // warm starts REPLACE random restarts within the same
                    // total start budget (never exceed it — that is where
                    // the eval-count saving comes from): up to WARM_FILLS
                    // fills, capped at `restarts`. Only these fills count
                    // against the budget; user-configured extra_starts
                    // ride along exactly as they would on a cold model.
                    let ws = warm_starts(
                        &model.kernel.names(),
                        &prior,
                        &parent.param_names,
                        &parent.train.theta_hat,
                        WARM_FILLS.min(restarts),
                        rng,
                    );
                    n_warm = ws.len();
                    opts.extra_starts.extend(ws);
                }
                let warm = n_warm > 0;
                let seeds: Vec<u64> =
                    (0..restarts - n_warm.min(restarts)).map(|_| rng.next_u64()).collect();
                jobs.push(Job { idx: i, spec, opts, seeds, warm });
            }
            // --- train: concurrent within the generation in waves of at
            // most the thread budget, the shared budget split across the
            // wave's models (borrowed-slots rule across models — a wave
            // of g models gives each exec.split(g), so models × restarts
            // × linalg never exceeds the configured budget; with a
            // 1-thread budget the generation degrades to the serial
            // full-budget path)
            let max_conc = cfg.exec.threads().max(1);
            let mut results: Vec<(usize, bool, crate::Result<TrainResult>, f64)> =
                Vec::with_capacity(jobs.len());
            let mut queue = jobs.into_iter().peekable();
            while queue.peek().is_some() {
                let wave: Vec<Job> = queue.by_ref().take(max_conc).collect();
                let g = wave.len();
                if g == 1 {
                    let Job { idx, spec, opts, seeds, warm } =
                        wave.into_iter().next().expect("one job");
                    let sw = Stopwatch::start();
                    let r = train_model_seeded(
                        &spec, cfg.sigma_n, data, &opts, &seeds, cfg.workers, &cfg.exec,
                    );
                    results.push((idx, warm, r, sw.elapsed_secs()));
                } else {
                    let child_exec = cfg.exec.split(g);
                    let child_workers = (cfg.workers / g).max(1);
                    let sigma_n = cfg.sigma_n;
                    results.extend(std::thread::scope(|s| {
                        let handles: Vec<_> = wave
                            .into_iter()
                            .map(|job| {
                                let child_exec = child_exec.clone();
                                s.spawn(move || {
                                    let sw = Stopwatch::start();
                                    let r = train_model_seeded(
                                        &job.spec,
                                        sigma_n,
                                        data,
                                        &job.opts,
                                        &job.seeds,
                                        child_workers,
                                        &child_exec,
                                    );
                                    (job.idx, job.warm, r, sw.elapsed_secs())
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("training thread panicked"))
                            .collect::<Vec<_>>()
                    }));
                }
            }
            // --- evidence (full budget: training is done) + optional
            // nested verification, roster order
            for (idx, warm, res, train_secs) in results {
                let trained = res?;
                let sw = Stopwatch::start();
                let spec = roster.specs()[idx].clone();
                let model = spec.build(cfg.sigma_n);
                let prior = BoxPrior::for_model(&model, &span);
                // every entrant — exact or approximate — enters the
                // Laplace integral with an n-scale log-likelihood and a
                // matching Hessian, so their ln Z values share one scale:
                // exact specs use the analytic eq.-2.19 Hessian at their
                // peak value; approximate specs use their n-scale
                // evidence surrogate and its central-difference Hessian
                let (lnp_evidence, hessian) = match spec.approx() {
                    None => (
                        trained.lnp_peak,
                        crate::gp::profiled_hessian_nd_with(
                            &model,
                            &data.input_cols(),
                            data.noise.as_deref(),
                            &data.y,
                            &trained.theta_hat,
                            &cfg.exec,
                        )?,
                    ),
                    Some(kind) => (
                        crate::gp::approx::lnp_evidence_with(
                            kind,
                            &model,
                            &data.t,
                            &data.y,
                            &trained.theta_hat,
                            &cfg.exec,
                        )?,
                        crate::gp::approx::evidence_hessian_with(
                            kind,
                            &model,
                            &data.t,
                            &data.y,
                            &trained.theta_hat,
                            &cfg.exec,
                        )?,
                    ),
                };
                let evidence = laplace_evidence(
                    data.len(),
                    &prior,
                    &cfg.scale_prior,
                    &trained.theta_hat,
                    lnp_evidence,
                    &hessian,
                )?;
                let nested = if cfg.run_nested {
                    Some(run_nested_for(cfg, &model, &prior, data, rng)?)
                } else {
                    None
                };
                slots[idx] = Some(TrainedModel {
                    spec,
                    sigma_n: cfg.sigma_n,
                    param_names: model.kernel.names(),
                    train: trained,
                    evidence,
                    nested,
                    warm_started: warm,
                    restarts: cfg.train.multistart.restarts,
                    wall_secs: train_secs + sw.elapsed_secs(),
                });
            }
        }
        let mut models: Vec<TrainedModel> =
            slots.into_iter().map(|s| s.expect("every roster model trained")).collect();
        let reports: Vec<ModelReport> = models.iter().map(TrainedModel::report).collect();
        let report = ComparisonReport::ranked(data.label.clone(), data.len(), reports);
        // shared evidence comparator (NaN-last, deterministic) — the same
        // order ComparisonReport::ranked and the serving router use
        models.sort_by(|a, b| crate::util::desc_nan_last(a.evidence.ln_z, b.evidence.ln_z));
        Ok(TournamentResult { models, report })
    }
}

/// Build warm-start candidates for a child model from its parent's
/// trained peak: parameters are matched **by name** (k₂'s
/// `phi0/phi1/xi1` inherit k₁'s peak), unmatched coordinates are filled
/// from the prior — [`WARM_FILLS`] random fills give the new components
/// several basins to start from. Empty when no name matches.
fn warm_starts(
    names: &[String],
    prior: &BoxPrior,
    parent_names: &[String],
    parent_theta: &[f64],
    fills: usize,
    rng: &mut Xoshiro256,
) -> Vec<Vec<f64>> {
    let matched: Vec<Option<f64>> = names
        .iter()
        .map(|nm| parent_names.iter().position(|h| h == nm).map(|j| parent_theta[j]))
        .collect();
    if matched.iter().all(Option::is_none) {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(fills);
    for _ in 0..fills {
        let fill = prior.sample(rng);
        let mut start: Vec<f64> =
            matched.iter().zip(&fill).map(|(m, f)| m.unwrap_or(*f)).collect();
        prior.project(&mut start);
        out.push(start);
    }
    out
}

/// Nested-sampling verification over the full (λ, ϑ) unit cube — the
/// paper's ln Z_num.
fn run_nested_for(
    cfg: &PipelineConfig,
    model: &crate::kernels::CovarianceModel,
    prior: &BoxPrior,
    data: &Dataset,
    rng: &mut Xoshiro256,
) -> crate::Result<NestedReport> {
    anyhow::ensure!(
        data.d() == 1 && !data.is_heteroscedastic(),
        "nested-sampling verification supports only 1-D homoscedastic datasets \
         (got d = {}, heteroscedastic = {})",
        data.d(),
        data.is_heteroscedastic()
    );
    let sw = Stopwatch::start();
    let dim = prior.dim() + 1; // λ first
    let scale = cfg.scale_prior;
    let exec = cfg.exec.clone();
    let res = {
        let mut ln_like = |u: &[f64]| -> f64 {
            let lambda = scale.lambda_from_unit(u[0]);
            let theta = prior.from_unit_cube(&u[1..]);
            let mut full = vec![lambda];
            full.extend(theta);
            crate::gp::full_lnp_with(model, &data.t, &data.y, &full, &exec)
                .unwrap_or(f64::NEG_INFINITY)
        };
        nested_sample(dim, &mut ln_like, &cfg.nested, rng)?
    };
    Ok(NestedReport {
        ln_z: res.ln_z,
        ln_z_err: res.ln_z_err,
        n_evals: res.n_evals,
        information: res.information,
        wall_secs: sw.elapsed_secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::table1_dataset;

    fn fast_config() -> PipelineConfig {
        let mut c = PipelineConfig::fast();
        c.train.multistart.restarts = 3;
        c
    }

    #[test]
    fn tournament_of_one_matches_plain_train_model_bitwise() {
        // the old standalone path is a special case of the tournament:
        // same RNG stream, same optimum, same factor
        let data = table1_dataset(40, 0.1, 55);
        let mut cfg = fast_config();
        cfg.models = vec![ModelSpec::K1];
        cfg.workers = 1;
        cfg.exec = crate::runtime::ExecutionContext::seq();
        let mut rng_a = Xoshiro256::seed_from_u64(8);
        let result = Tournament::new(cfg.clone()).run(&data, &mut rng_a).unwrap();
        let mut rng_b = Xoshiro256::seed_from_u64(8);
        let direct = super::super::train::train_model(
            &ModelSpec::K1,
            0.1,
            &data,
            &cfg.train,
            1,
            &cfg.exec,
            &mut rng_b,
        )
        .unwrap();
        let tm = result.winner();
        assert_eq!(tm.train.theta_hat, direct.theta_hat);
        assert_eq!(tm.train.lnp_peak, direct.lnp_peak);
        assert_eq!(tm.train.n_evals, direct.n_evals);
        assert!(!tm.warm_started);
        assert!(tm.evidence.ln_z.is_finite());
    }

    #[test]
    fn lineage_orders_and_warm_starts_the_child() {
        let data = table1_dataset(50, 0.1, 77);
        let mut cfg = fast_config();
        cfg.models = vec![ModelSpec::K2, ModelSpec::K1]; // child listed first
        let mut rng = Xoshiro256::seed_from_u64(3);
        let result = Tournament::new(cfg).run(&data, &mut rng).unwrap();
        assert_eq!(result.models.len(), 2);
        let k2 = result.model("k2").unwrap();
        let k1 = result.model("k1").unwrap();
        assert!(k2.warm_started, "k2 must inherit k1's peak");
        assert!(!k1.warm_started);
        // warm starts replace random restarts within the same budget:
        // min(3, restarts=3) warm fills + 0 random = 3 starts, exactly
        // a cold model's start count
        assert!(k2.train.restart_values.len() <= 3);
        // report is ranked and carries per-model error bars
        for m in &result.report.models {
            assert_eq!(m.sigma.len(), m.theta_hat.len());
        }
        assert_eq!(result.winner().ln_z(), result.report.models[0].ln_z);
    }

    #[test]
    fn concurrent_generation_of_roots_is_deterministic() {
        // k1 and wendland-se share no lineage: one generation of two
        // models training concurrently under a split budget — the
        // scoped-thread scheduling path
        let data = table1_dataset(40, 0.1, 13);
        let mut cfg = fast_config();
        cfg.models = vec![ModelSpec::K1, ModelSpec::WendlandSe];
        cfg.train.multistart.restarts = 2;
        cfg.workers = 2;
        cfg.exec = crate::runtime::ExecutionContext::new(2);
        let run = || {
            let mut rng = Xoshiro256::seed_from_u64(21);
            Tournament::new(cfg.clone()).run(&data, &mut rng).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.models.len(), 2);
        for (ma, mb) in a.models.iter().zip(&b.models) {
            assert_eq!(ma.name(), mb.name());
            assert_eq!(ma.train.theta_hat, mb.train.theta_hat);
            assert_eq!(ma.evidence.ln_z, mb.evidence.ln_z);
            assert!(!ma.warm_started);
        }
    }

    #[test]
    fn ard_tournament_on_heteroscedastic_3d_data() {
        // the scenario tier end to end: an ARD roster with lineage
        // (se-iso3 → se-ard3) on d = 3 heteroscedastic data, served
        // through an nd predictor
        let data = crate::data::synthetic::ard3_dataset(28, 0.1, true, 9);
        let mut cfg = fast_config();
        cfg.models = vec![ModelSpec::SeArd(3), ModelSpec::SeIso(3)];
        cfg.train.multistart.restarts = 2;
        let mut rng = Xoshiro256::seed_from_u64(17);
        let result = Tournament::new(cfg.clone()).run(&data, &mut rng).unwrap();
        assert_eq!(result.models.len(), 2);
        let ard = result.model("se-ard3").unwrap();
        assert!(ard.warm_started, "se-ard3 must inherit se-iso3's peak");
        for m in &result.models {
            assert!(m.ln_z().is_finite(), "{} ln Z", m.name());
        }
        let p = result.winner().predictor(&data).unwrap();
        assert_eq!(p.d(), 3);
        assert!(p.noise().is_some());
        let q1 = [2.5, 7.5];
        let q2 = [1.0, 3.0];
        let q3 = [0.5, 2.0];
        let pred = p.predict_rows(&[&q1, &q2, &q3], &cfg.exec);
        assert!(pred.mean.iter().chain(&pred.sd).all(|v| v.is_finite()));

        // nested verification is gated off for nd/heteroscedastic data
        cfg.run_nested = true;
        let mut rng2 = Xoshiro256::seed_from_u64(17);
        let err = Tournament::new(cfg).run(&data, &mut rng2).unwrap_err();
        assert!(err.to_string().contains("nested-sampling"), "{err:#}");
    }

    #[test]
    fn empty_roster_is_an_error() {
        let mut cfg = fast_config();
        cfg.models.clear();
        let data = table1_dataset(20, 0.1, 1);
        let mut rng = Xoshiro256::seed_from_u64(1);
        assert!(Tournament::new(cfg).run(&data, &mut rng).is_err());
    }
}
