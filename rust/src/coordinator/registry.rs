//! Model registry: thread-safe, serialisable specs that workers can turn
//! into concrete [`CovarianceModel`]s (the models themselves hold
//! `Box<dyn>` kernels and are built per worker), plus the [`Roster`] —
//! the ordered, deduplicated model list a comparison tournament trains.
//!
//! Each spec declares its **warm-start lineage**
//! ([`ModelSpec::warm_start_parent`]): the simpler model whose trained
//! peak seeds this one's multistart (parameters are matched by name —
//! k₂'s `phi0/phi1/xi1` inherit k₁'s peak). The tournament scheduler
//! orders training so parents finish before their warm-started children
//! ([`Roster::generations`]).

use crate::kernels::{
    paper_k1, paper_k2, ArdKernel, CovarianceModel, Matern32, Matern52, Periodic, ProductKernel,
    SquaredExponential, Wendland,
};

/// Static name tables for the ARD specs (one entry per input dimension
/// 1..=8) — [`ModelSpec::name`] returns `&'static str`, which the
/// factor-health plumbing stores, so the names cannot be formatted on
/// the fly.
const SE_ISO_NAMES: [&str; 8] = [
    "se-iso1", "se-iso2", "se-iso3", "se-iso4", "se-iso5", "se-iso6", "se-iso7", "se-iso8",
];
const SE_ARD_NAMES: [&str; 8] = [
    "se-ard1", "se-ard2", "se-ard3", "se-ard4", "se-ard5", "se-ard6", "se-ard7", "se-ard8",
];
const M32_ARD_NAMES: [&str; 8] = [
    "m32-ard1", "m32-ard2", "m32-ard3", "m32-ard4", "m32-ard5", "m32-ard6", "m32-ard7",
    "m32-ard8",
];
const M52_ARD_NAMES: [&str; 8] = [
    "m52-ard1", "m52-ard2", "m52-ard3", "m52-ard4", "m52-ard5", "m52-ard6", "m52-ard7",
    "m52-ard8",
];

/// A buildable model description.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelSpec {
    /// The paper's k₁ (eq. 3.1).
    K1,
    /// The paper's k₂ (eq. 3.2).
    K2,
    /// Wendland × SE — an aperiodic control model.
    WendlandSe,
    /// Wendland × Matérn-3/2.
    WendlandM32,
    /// Wendland × Matérn-5/2.
    WendlandM52,
    /// k₂ plus a third periodic component (the paper's §3(b) fn. 8
    /// "three-timescale model" extension).
    K3,
    /// k₂ trained under the subset-of-data approximation
    /// ([`crate::gp::approx`]): exact machinery on a deterministic
    /// `Θ(√n)` stride subset.
    SodK2,
    /// k₂ trained under the FITC sparse approximation
    /// ([`crate::gp::approx`]): `Θ(√n)` inducing points on a uniform
    /// grid, Woodbury-form profiled likelihood.
    FitcK2,
    /// Isotropic-in-d squared exponential on d input columns (one shared
    /// length scale) — the cold-start root of the ARD lineage and the
    /// ARD-vs-isotropic lnZ-gap baseline of the scenario bench.
    SeIso(u8),
    /// Squared exponential with per-dimension (ARD) length scales.
    SeArd(u8),
    /// Matérn-3/2 with ARD length scales.
    M32Ard(u8),
    /// Matérn-5/2 with ARD length scales.
    M52Ard(u8),
}

impl ModelSpec {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "k1" => Ok(Self::K1),
            "k2" => Ok(Self::K2),
            "k3" => Ok(Self::K3),
            "wendland-se" => Ok(Self::WendlandSe),
            "wendland-m32" => Ok(Self::WendlandM32),
            "wendland-m52" => Ok(Self::WendlandM52),
            "sod-k2" => Ok(Self::SodK2),
            "fitc-k2" => Ok(Self::FitcK2),
            other => Self::parse_ard(other).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown model '{other}' \
                     (k1|k2|k3|wendland-se|wendland-m32|wendland-m52|sod-k2|fitc-k2|\
                      se-iso<d>|se-ard<d>|m32-ard<d>|m52-ard<d> for d in 1..=8)"
                )
            }),
        }
    }

    /// Parse the ARD spec family: `se-iso<d>`, `se-ard<d>`, `m32-ard<d>`,
    /// `m52-ard<d>` with `d ∈ 1..=8`.
    fn parse_ard(s: &str) -> Option<Self> {
        let ctors: [(&str, fn(u8) -> Self); 4] = [
            ("se-iso", Self::SeIso),
            ("se-ard", Self::SeArd),
            ("m32-ard", Self::M32Ard),
            ("m52-ard", Self::M52Ard),
        ];
        for (prefix, ctor) in ctors {
            if let Some(ds) = s.strip_prefix(prefix) {
                if let Ok(d) = ds.parse::<u8>() {
                    if (1..=8).contains(&d) {
                        return Some(ctor(d));
                    }
                }
            }
        }
        None
    }

    /// The canonical CLI/config name of this spec.
    pub fn name(&self) -> &'static str {
        match self {
            Self::K1 => "k1",
            Self::K2 => "k2",
            Self::K3 => "k3",
            Self::WendlandSe => "wendland-se",
            Self::WendlandM32 => "wendland-m32",
            Self::WendlandM52 => "wendland-m52",
            Self::SodK2 => "sod-k2",
            Self::FitcK2 => "fitc-k2",
            Self::SeIso(d) => SE_ISO_NAMES[*d as usize - 1],
            Self::SeArd(d) => SE_ARD_NAMES[*d as usize - 1],
            Self::M32Ard(d) => M32_ARD_NAMES[*d as usize - 1],
            Self::M52Ard(d) => M52_ARD_NAMES[*d as usize - 1],
        }
    }

    /// Number of input dimensions this spec's kernel consumes per point
    /// (1 for every time-series spec).
    pub fn input_dim(&self) -> usize {
        match self {
            Self::SeIso(d) | Self::SeArd(d) | Self::M32Ard(d) | Self::M52Ard(d) => *d as usize,
            _ => 1,
        }
    }

    /// Which sparse approximation this spec trains under, `None` for the
    /// exact `O(n³)` backends. Approximate specs share their kernel (and
    /// so their parameter names, bounds and priors) with an exact
    /// sibling; only the likelihood machinery differs.
    pub fn approx(&self) -> Option<crate::gp::ApproxKind> {
        match self {
            Self::SodK2 => Some(crate::gp::ApproxKind::Sod),
            Self::FitcK2 => Some(crate::gp::ApproxKind::Fitc),
            _ => None,
        }
    }

    /// Dimension of the Cholesky factor a trained artifact of this spec
    /// carries for an `n`-point dataset: `n` for exact specs, the
    /// backend's reduced size for approximate ones. A pure function of
    /// `n`, so artifact decode can validate it without extra fields.
    pub fn factor_dim(&self, n: usize) -> usize {
        match self.approx() {
            None => n,
            Some(kind) => kind.factor_dim(n),
        }
    }

    /// Declared warm-start lineage: the simpler model whose trained peak
    /// seeds this one's multistart (matched by parameter name, unmatched
    /// coordinates filled from the prior). `None` for root models that
    /// always cold-start. This generalises the pipeline's old ad-hoc
    /// k₁→k₂ `extra_starts` wiring: k₂ extends k₁ by a second periodic
    /// component, k₃ extends k₂ by a third, and the Wendland×Matérn
    /// controls inherit the Wendland window scale from Wendland×SE.
    pub fn warm_start_parent(&self) -> Option<ModelSpec> {
        match self {
            Self::K1 | Self::WendlandSe => None,
            Self::K2 => Some(Self::K1),
            Self::K3 => Some(Self::K2),
            Self::WendlandM32 | Self::WendlandM52 => Some(Self::WendlandSe),
            // same kernel, same parameter names — an exact k₂ peak is the
            // best imaginable seed for its approximate siblings
            Self::SodK2 | Self::FitcK2 => Some(Self::K2),
            // ARD lineage: the tied (isotropic-in-d) SE trains one shared
            // length scale, which seeds every ARD dimension's phiARD0 by
            // name; the Matérn ARD variants then inherit the full
            // per-dimension scales from the SE ARD peak
            Self::SeIso(_) => None,
            Self::SeArd(d) => Some(Self::SeIso(*d)),
            Self::M32Ard(d) | Self::M52Ard(d) => Some(Self::SeArd(*d)),
        }
    }

    /// Build a concrete model with fixed noise σ_n.
    pub fn build(&self, sigma_n: f64) -> CovarianceModel {
        match self {
            Self::K1 => paper_k1(sigma_n),
            Self::K2 => paper_k2(sigma_n),
            Self::K3 => {
                let kernel = ProductKernel::new(vec![
                    Box::new(Wendland),
                    Box::new(Periodic::new(1)),
                    Box::new(Periodic::new(2)),
                    Box::new(Periodic::new(3)),
                ])
                // T₁ ≤ T₂ ≤ T₃ (φ indices 1, 3, 5)
                .with_constraints(vec![(1, 3), (3, 5)]);
                CovarianceModel::new("k3", Box::new(kernel), sigma_n)
            }
            Self::WendlandSe => {
                let kernel = ProductKernel::new(vec![
                    Box::new(Wendland),
                    Box::new(SquaredExponential::new(1)),
                ]);
                CovarianceModel::new("wendland-se", Box::new(kernel), sigma_n)
            }
            Self::WendlandM32 => {
                let kernel =
                    ProductKernel::new(vec![Box::new(Wendland), Box::new(Matern32::new(1))]);
                CovarianceModel::new("wendland-m32", Box::new(kernel), sigma_n)
            }
            Self::WendlandM52 => {
                let kernel =
                    ProductKernel::new(vec![Box::new(Wendland), Box::new(Matern52::new(1))]);
                CovarianceModel::new("wendland-m52", Box::new(kernel), sigma_n)
            }
            // the approximate siblings carry k₂'s kernel under their own
            // name (reports, artifacts and parse round-trips key on it)
            Self::SodK2 => {
                let mut m = paper_k2(sigma_n);
                m.name = "sod-k2".into();
                m
            }
            Self::FitcK2 => {
                let mut m = paper_k2(sigma_n);
                m.name = "fitc-k2".into();
                m
            }
            Self::SeIso(d) => CovarianceModel::new(
                self.name(),
                Box::new(ArdKernel::se_iso(*d as usize)),
                sigma_n,
            ),
            Self::SeArd(d) => {
                CovarianceModel::new(self.name(), Box::new(ArdKernel::se(*d as usize)), sigma_n)
            }
            Self::M32Ard(d) => {
                CovarianceModel::new(self.name(), Box::new(ArdKernel::m32(*d as usize)), sigma_n)
            }
            Self::M52Ard(d) => {
                CovarianceModel::new(self.name(), Box::new(ArdKernel::m52(*d as usize)), sigma_n)
            }
        }
    }
}

/// The model list a comparison tournament trains: insertion-ordered,
/// deduplicated, parsed from config/CLI (`"k1,k2"` or a TOML array).
///
/// The roster also owns the **lineage schedule**: models are grouped into
/// generations such that every model's nearest trained ancestor (by
/// [`ModelSpec::warm_start_parent`], walking up until a roster member is
/// found) lands in an earlier generation. Models within one generation
/// have no warm-start dependency on each other and may train
/// concurrently under a split thread budget.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Roster {
    specs: Vec<ModelSpec>,
}

impl Roster {
    /// Build from specs: order preserved, duplicates dropped, must be
    /// non-empty.
    pub fn new(specs: Vec<ModelSpec>) -> crate::Result<Self> {
        anyhow::ensure!(!specs.is_empty(), "empty model roster");
        let mut deduped: Vec<ModelSpec> = Vec::with_capacity(specs.len());
        for s in specs {
            if !deduped.contains(&s) {
                deduped.push(s);
            }
        }
        Ok(Self { specs: deduped })
    }

    /// Parse a comma-separated CLI list, e.g. `"k1,k2,k3"`.
    pub fn parse(list: &str) -> crate::Result<Self> {
        let specs = list
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(ModelSpec::parse)
            .collect::<crate::Result<Vec<_>>>()?;
        Self::new(specs)
    }

    /// Parse from a config-file name list.
    pub fn from_names(names: &[String]) -> crate::Result<Self> {
        let specs =
            names.iter().map(|s| ModelSpec::parse(s)).collect::<crate::Result<Vec<_>>>()?;
        Self::new(specs)
    }

    pub fn specs(&self) -> &[ModelSpec] {
        &self.specs
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Index of the nearest warm-start ancestor of `specs[i]` that is
    /// itself a roster member, walking up the declared lineage; `None`
    /// for cold-started roots (or when no ancestor made the roster).
    pub fn warm_parent_index(&self, i: usize) -> Option<usize> {
        let mut cur = self.specs[i].warm_start_parent();
        while let Some(p) = cur {
            if let Some(j) = self.specs.iter().position(|s| *s == p) {
                return Some(j);
            }
            cur = p.warm_start_parent();
        }
        None
    }

    /// Lineage generations (indices into [`Roster::specs`], roster order
    /// within each): generation 0 holds the cold-started roots, and every
    /// warm-started child lands exactly one generation after its resolved
    /// parent — the tournament trains generation by generation so parents
    /// finish before the children they seed.
    pub fn generations(&self) -> Vec<Vec<usize>> {
        let n = self.specs.len();
        let mut depth = vec![0usize; n];
        for i in 0..n {
            // lineage chains are short (≤3) and acyclic by construction,
            // and parents may appear after children in roster order, so
            // resolve each depth by walking the ancestor chain directly
            let mut d = 0;
            let mut cur = i;
            while let Some(p) = self.warm_parent_index(cur) {
                d += 1;
                cur = p;
            }
            depth[i] = d;
        }
        let max_d = depth.iter().copied().max().unwrap_or(0);
        let mut gens: Vec<Vec<usize>> = vec![Vec::new(); max_d + 1];
        for i in 0..n {
            gens[depth[i]].push(i);
        }
        gens.retain(|g| !g.is_empty());
        gens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in [
            "k1",
            "k2",
            "k3",
            "wendland-se",
            "wendland-m32",
            "wendland-m52",
            "sod-k2",
            "fitc-k2",
        ] {
            let spec = ModelSpec::parse(s).unwrap();
            let model = spec.build(0.1);
            assert_eq!(model.name, s);
        }
        assert!(ModelSpec::parse("k9").is_err());
    }

    #[test]
    fn approx_specs_share_k2_shape_and_lineage() {
        for spec in [ModelSpec::SodK2, ModelSpec::FitcK2] {
            let m = spec.build(0.1);
            let k2 = ModelSpec::K2.build(0.1);
            assert_eq!(m.dim(), k2.dim());
            assert_eq!(m.kernel.names(), k2.kernel.names());
            assert_eq!(spec.warm_start_parent(), Some(ModelSpec::K2));
            assert!(spec.approx().is_some());
        }
        assert_eq!(ModelSpec::K2.approx(), None);
        // exact specs carry full-rank factors, approximate ones √n-scale
        assert_eq!(ModelSpec::K2.factor_dim(1000), 1000);
        assert_eq!(
            ModelSpec::SodK2.factor_dim(1000),
            crate::gp::approx::sod_m(1000)
        );
        assert_eq!(
            ModelSpec::FitcK2.factor_dim(1000),
            crate::gp::approx::fitc_m(1000)
        );
    }

    #[test]
    fn dims() {
        assert_eq!(ModelSpec::K1.build(0.1).dim(), 3);
        assert_eq!(ModelSpec::K2.build(0.1).dim(), 5);
        assert_eq!(ModelSpec::K3.build(0.1).dim(), 7);
        assert_eq!(ModelSpec::WendlandSe.build(0.1).dim(), 2);
    }

    #[test]
    fn k3_constraints_chain() {
        let m = ModelSpec::K3.build(0.1);
        assert_eq!(m.kernel.ordering_constraints(), vec![(1, 3), (3, 5)]);
    }

    #[test]
    fn lineage_declares_the_paper_chain() {
        assert_eq!(ModelSpec::K1.warm_start_parent(), None);
        assert_eq!(ModelSpec::K2.warm_start_parent(), Some(ModelSpec::K1));
        assert_eq!(ModelSpec::K3.warm_start_parent(), Some(ModelSpec::K2));
        assert_eq!(ModelSpec::WendlandM32.warm_start_parent(), Some(ModelSpec::WendlandSe));
        for s in [ModelSpec::K1, ModelSpec::K2, ModelSpec::K3] {
            assert_eq!(ModelSpec::parse(s.name()).unwrap(), s);
        }
    }

    #[test]
    fn ard_specs_parse_build_and_declare_lineage() {
        for d in 1..=8u8 {
            for (name, spec) in [
                (format!("se-iso{d}"), ModelSpec::SeIso(d)),
                (format!("se-ard{d}"), ModelSpec::SeArd(d)),
                (format!("m32-ard{d}"), ModelSpec::M32Ard(d)),
                (format!("m52-ard{d}"), ModelSpec::M52Ard(d)),
            ] {
                assert_eq!(ModelSpec::parse(&name).unwrap(), spec);
                assert_eq!(spec.name(), name);
                let m = spec.build(0.1);
                assert_eq!(m.name, name);
                assert_eq!(m.input_dim(), d as usize);
                assert_eq!(spec.input_dim(), d as usize);
                assert_eq!(spec.approx(), None);
                assert_eq!(spec.factor_dim(500), 500);
            }
            // tied root has one parameter, ARD has d
            assert_eq!(ModelSpec::SeIso(d).build(0.1).dim(), 1);
            assert_eq!(ModelSpec::SeArd(d).build(0.1).dim(), d as usize);
        }
        assert!(ModelSpec::parse("se-ard0").is_err());
        assert!(ModelSpec::parse("se-ard9").is_err());
        assert!(ModelSpec::parse("se-ard").is_err());
        // lineage: SeIso is root; SeArd ← SeIso; Matérns ← SeArd. The
        // shared "phiARD0" name carries the tied scale into dimension 0.
        assert_eq!(ModelSpec::SeIso(3).warm_start_parent(), None);
        assert_eq!(ModelSpec::SeArd(3).warm_start_parent(), Some(ModelSpec::SeIso(3)));
        assert_eq!(ModelSpec::M32Ard(3).warm_start_parent(), Some(ModelSpec::SeArd(3)));
        assert_eq!(ModelSpec::M52Ard(3).warm_start_parent(), Some(ModelSpec::SeArd(3)));
        let iso_names = ModelSpec::SeIso(3).build(0.1).kernel.names();
        let ard_names = ModelSpec::SeArd(3).build(0.1).kernel.names();
        assert!(ard_names.contains(&iso_names[0]));
        assert_eq!(ModelSpec::M32Ard(3).build(0.1).kernel.names(), ard_names);
        // roster schedules the ARD generation chain parent-first
        let r = Roster::parse("m52-ard3,se-ard3,se-iso3").unwrap();
        assert_eq!(r.generations(), vec![vec![2], vec![1], vec![0]]);
    }

    #[test]
    fn roster_parses_dedupes_and_schedules() {
        let r = Roster::parse("k2, k1, k2, wendland-se").unwrap();
        assert_eq!(
            r.specs(),
            &[ModelSpec::K2, ModelSpec::K1, ModelSpec::WendlandSe]
        );
        // k2's parent k1 is at index 1
        assert_eq!(r.warm_parent_index(0), Some(1));
        assert_eq!(r.warm_parent_index(1), None);
        assert_eq!(r.warm_parent_index(2), None);
        // generations: roots first, k2 after its parent
        assert_eq!(r.generations(), vec![vec![1, 2], vec![0]]);
        assert!(Roster::parse("").is_err());
        assert!(Roster::parse("k1,bogus").is_err());
    }

    #[test]
    fn roster_skips_absent_ancestors() {
        // k3 without k2 in the roster warm-starts from k1 (the nearest
        // ancestor present); without any ancestor it is a root
        let r = Roster::new(vec![ModelSpec::K1, ModelSpec::K3]).unwrap();
        assert_eq!(r.warm_parent_index(1), Some(0));
        assert_eq!(r.generations(), vec![vec![0], vec![1]]);
        let lone = Roster::new(vec![ModelSpec::K3]).unwrap();
        assert_eq!(lone.warm_parent_index(0), None);
        assert_eq!(lone.generations(), vec![vec![0]]);
    }
}
