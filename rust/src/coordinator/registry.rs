//! Model registry: thread-safe, serialisable specs that workers can turn
//! into concrete [`CovarianceModel`]s (the models themselves hold
//! `Box<dyn>` kernels and are built per worker).

use crate::kernels::{
    paper_k1, paper_k2, CovarianceModel, Matern32, Matern52, Periodic, ProductKernel,
    SquaredExponential, Wendland,
};

/// A buildable model description.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelSpec {
    /// The paper's k₁ (eq. 3.1).
    K1,
    /// The paper's k₂ (eq. 3.2).
    K2,
    /// Wendland × SE — an aperiodic control model.
    WendlandSe,
    /// Wendland × Matérn-3/2.
    WendlandM32,
    /// Wendland × Matérn-5/2.
    WendlandM52,
    /// k₂ plus a third periodic component (the paper's §3(b) fn. 8
    /// "three-timescale model" extension).
    K3,
}

impl ModelSpec {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "k1" => Ok(Self::K1),
            "k2" => Ok(Self::K2),
            "k3" => Ok(Self::K3),
            "wendland-se" => Ok(Self::WendlandSe),
            "wendland-m32" => Ok(Self::WendlandM32),
            "wendland-m52" => Ok(Self::WendlandM52),
            other => anyhow::bail!(
                "unknown model '{other}' (k1|k2|k3|wendland-se|wendland-m32|wendland-m52)"
            ),
        }
    }

    /// Build a concrete model with fixed noise σ_n.
    pub fn build(&self, sigma_n: f64) -> CovarianceModel {
        match self {
            Self::K1 => paper_k1(sigma_n),
            Self::K2 => paper_k2(sigma_n),
            Self::K3 => {
                let kernel = ProductKernel::new(vec![
                    Box::new(Wendland),
                    Box::new(Periodic::new(1)),
                    Box::new(Periodic::new(2)),
                    Box::new(Periodic::new(3)),
                ])
                // T₁ ≤ T₂ ≤ T₃ (φ indices 1, 3, 5)
                .with_constraints(vec![(1, 3), (3, 5)]);
                CovarianceModel::new("k3", Box::new(kernel), sigma_n)
            }
            Self::WendlandSe => {
                let kernel = ProductKernel::new(vec![
                    Box::new(Wendland),
                    Box::new(SquaredExponential::new(1)),
                ]);
                CovarianceModel::new("wendland-se", Box::new(kernel), sigma_n)
            }
            Self::WendlandM32 => {
                let kernel =
                    ProductKernel::new(vec![Box::new(Wendland), Box::new(Matern32::new(1))]);
                CovarianceModel::new("wendland-m32", Box::new(kernel), sigma_n)
            }
            Self::WendlandM52 => {
                let kernel =
                    ProductKernel::new(vec![Box::new(Wendland), Box::new(Matern52::new(1))]);
                CovarianceModel::new("wendland-m52", Box::new(kernel), sigma_n)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["k1", "k2", "k3", "wendland-se", "wendland-m32", "wendland-m52"] {
            let spec = ModelSpec::parse(s).unwrap();
            let model = spec.build(0.1);
            assert_eq!(model.name, s);
        }
        assert!(ModelSpec::parse("k9").is_err());
    }

    #[test]
    fn dims() {
        assert_eq!(ModelSpec::K1.build(0.1).dim(), 3);
        assert_eq!(ModelSpec::K2.build(0.1).dim(), 5);
        assert_eq!(ModelSpec::K3.build(0.1).dim(), 7);
        assert_eq!(ModelSpec::WendlandSe.build(0.1).dim(), 2);
    }

    #[test]
    fn k3_constraints_chain() {
        let m = ModelSpec::K3.build(0.1);
        assert_eq!(m.kernel.ordering_constraints(), vec![(1, 3), (3, 5)]);
    }
}
