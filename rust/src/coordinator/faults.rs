//! Deterministic fault-injection for the numerical-health soak.
//!
//! A [`FaultPlan`] turns a clean observation stream into a hostile one
//! by corrupting a fixed, reproducible subset of the points with the
//! failure classes the robustness tier must survive:
//!
//! * [`Fault::NearDuplicate`] — an input nearly coincident with its
//!   predecessor, driving the extension pivot of `K̃` toward zero (the
//!   quarantine / jitter-ladder stressor);
//! * [`Fault::Outlier`] — an observation absurdly far from the
//!   predictive mean; numerically harmless to the factor but it must
//!   flow through drift monitoring, not crash it;
//! * [`Fault::NonFinite`] — NaN/±∞ smuggled into the stream; must be
//!   rejected at the data boundary with **zero** state change.
//!
//! The schedule is a pure function of the step index — no RNG, no
//! hidden state — so a failing soak step reproduces exactly, and the
//! expected outcome of every step (absorbed, rejected, quarantined) can
//! be asserted against the plan itself.

/// One step's corruption class (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// In-distribution point, passed through untouched.
    Clean,
    /// Input nearly coincident with the previous point.
    NearDuplicate,
    /// Observation pushed many σ from the predictive mean.
    Outlier,
    /// NaN or ±∞ in the input or the observation.
    NonFinite,
}

impl Fault {
    /// Must the serving boundary reject this point outright? Only
    /// non-finite values carry a hard guarantee; a near-duplicate may
    /// be absorbed (jitter headroom permitting), rejected, or trigger a
    /// quarantine depending on the factor's state, and an outlier is
    /// always absorbable.
    pub fn must_reject(self) -> bool {
        matches!(self, Fault::NonFinite)
    }
}

/// Deterministic corruption schedule over a point stream: step `i` is
/// corrupted iff `i` hits one of the configured periods (non-finite
/// beats near-duplicate beats outlier on collisions). Step 0 is always
/// clean so every soak starts from a healthy absorb.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Every this-many steps, replace the input with a near-duplicate
    /// (`0` = never).
    pub near_dup_every: usize,
    /// Every this-many steps, blow the observation up (`0` = never).
    pub outlier_every: usize,
    /// Every this-many steps, inject a non-finite value (`0` = never).
    pub non_finite_every: usize,
    /// Magnitude of the injected outlier observations.
    pub outlier_scale: f64,
    /// Relative input offset of a near-duplicate (kept well below any
    /// realistic sampling interval).
    pub near_dup_offset: f64,
}

impl FaultPlan {
    /// The recovery soak's default mix: mutually prime periods so the
    /// fault classes interleave rather than stack, ~18% of steps
    /// corrupted overall.
    pub fn soak_default() -> Self {
        Self {
            near_dup_every: 11,
            outlier_every: 17,
            non_finite_every: 23,
            outlier_scale: 1.0e7,
            near_dup_offset: 1.0e-12,
        }
    }

    /// A plan that never corrupts anything — the clean-path control arm
    /// (used to assert bit-identical behaviour and zero applied jitter).
    pub fn clean() -> Self {
        Self {
            near_dup_every: 0,
            outlier_every: 0,
            non_finite_every: 0,
            outlier_scale: 0.0,
            near_dup_offset: 0.0,
        }
    }

    /// Classify step `i` (pure; the whole schedule is reproducible from
    /// the plan alone).
    pub fn fault_at(&self, i: usize) -> Fault {
        let hits = |every: usize| i > 0 && every > 0 && i % every == 0;
        if hits(self.non_finite_every) {
            Fault::NonFinite
        } else if hits(self.near_dup_every) {
            Fault::NearDuplicate
        } else if hits(self.outlier_every) {
            Fault::Outlier
        } else {
            Fault::Clean
        }
    }

    /// Corrupt the nominal point `(t, y)` of step `i` according to the
    /// schedule; `t_prev` is the previous input (near-duplicates sit on
    /// top of it). Returns the possibly-corrupted point and its class.
    pub fn apply(&self, i: usize, t: f64, y: f64, t_prev: f64) -> (f64, f64, Fault) {
        let fault = self.fault_at(i);
        match fault {
            Fault::Clean => (t, y, fault),
            Fault::NearDuplicate => {
                let dt = self.near_dup_offset * (1.0 + t_prev.abs());
                (t_prev + dt, y, fault)
            }
            Fault::Outlier => {
                let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
                (t, sign * self.outlier_scale, fault)
            }
            // rotate through the three non-finite flavours, hitting
            // both the input and the observation sides of the boundary
            Fault::NonFinite => match (i / self.non_finite_every.max(1)) % 3 {
                0 => (t, f64::NAN, fault),
                1 => (f64::INFINITY, y, fault),
                _ => (t, f64::NEG_INFINITY, fault),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_pure_and_starts_clean() {
        let plan = FaultPlan::soak_default();
        assert_eq!(plan.fault_at(0), Fault::Clean);
        for i in 0..200 {
            assert_eq!(plan.fault_at(i), plan.fault_at(i), "schedule must be pure");
        }
        let a = plan.apply(22, 5.0, 1.0, 4.9);
        let b = plan.apply(22, 5.0, 1.0, 4.9);
        assert_eq!(a.0.to_bits(), b.0.to_bits());
        assert_eq!(a.2, b.2);
    }

    #[test]
    fn default_mix_contains_every_class() {
        let plan = FaultPlan::soak_default();
        let mut counts = [0usize; 4];
        for i in 0..200 {
            let idx = match plan.fault_at(i) {
                Fault::Clean => 0,
                Fault::NearDuplicate => 1,
                Fault::Outlier => 2,
                Fault::NonFinite => 3,
            };
            counts[idx] += 1;
        }
        assert!(counts[0] > 150, "clean steps must dominate: {counts:?}");
        assert!(counts[1] > 0 && counts[2] > 0 && counts[3] > 0, "{counts:?}");
    }

    #[test]
    fn corruption_matches_class() {
        let plan = FaultPlan::soak_default();
        // 23 → non-finite (flavour rotates with i/23); 22 → near-dup;
        // 34 → outlier (17·2, not divisible by 11 or 23)
        let (t, y, f) = plan.apply(23, 1.0, 2.0, 0.9);
        assert_eq!(f, Fault::NonFinite);
        assert!(!t.is_finite() || !y.is_finite());
        let (t, y, f) = plan.apply(22, 5.0, 2.0, 4.9);
        assert_eq!(f, Fault::NearDuplicate);
        assert!((t - 4.9).abs() < 1e-10 && y == 2.0);
        let (t, y, f) = plan.apply(34, 5.0, 2.0, 4.9);
        assert_eq!(f, Fault::Outlier);
        assert_eq!(t, 5.0);
        assert_eq!(y, plan.outlier_scale);
        assert!(f.must_reject() == false && Fault::NonFinite.must_reject());
    }

    #[test]
    fn clean_plan_never_corrupts() {
        let plan = FaultPlan::clean();
        for i in 0..500 {
            assert_eq!(plan.fault_at(i), Fault::Clean);
            let (t, y, f) = plan.apply(i, 1.5, -0.5, 1.4);
            assert_eq!((t, y, f), (1.5, -0.5, Fault::Clean));
        }
    }
}
