//! Coordinator glue for the serving layer: a **multi-model router** over
//! cached [`Predictor`]s, fed by the tournament's [`TrainedModel`]
//! artifacts.
//!
//! [`ServeSession`] owns one live predictor per tournament entrant,
//! ranked by Laplace evidence:
//!
//! * **Routing** — queries go to the evidence winner by default
//!   ([`RouteMode::Winner`]; a single-model session is bit-identical to
//!   serving that model directly), or to the whole roster under
//!   **evidence-weighted model averaging** ([`RouteMode::Averaged`]):
//!   posterior-probability weights `w_i ∝ exp(ln Z_i)`, mixture mean
//!   `Σ w_i μ_i` and mixture variance `Σ w_i (σ_i² + μ_i²) − μ̄²`.
//! * **Streaming** — [`ServeSession::observe`] /
//!   [`ServeSession::observe_batch`] fan every arriving observation out
//!   to **all** live factors (each an `O(n²)` extension), so the ranking
//!   can be revisited and the router switched without retraining. The
//!   fan-out is all-or-nothing per point: every model's extension pivot
//!   is checked before any factor mutates, so the slots always hold the
//!   same data.
//! * **Drift** — before a point is absorbed, each model scores it with
//!   its log predictive density ([`Predictor::log_predictive`]); a
//!   per-model [`DriftMonitor`] compares the recent windowed mean
//!   log-score against the baseline established when streaming began and
//!   **flags retraining** when the score has degraded past a threshold
//!   ([`ServeSession::needs_retrain`]). Hyperparameters are frozen at
//!   ϑ̂ between retrains, so a sustained log-score deficit is exactly the
//!   signature of hyperparameter drift.
//!
//! Constructed from a finished tournament
//! ([`ServeSession::from_tournament`]), from a single training run
//! ([`ServeSession::from_training`]), or by training in place
//! ([`ServeSession::train_and_serve`]).

use crate::data::Dataset;
use crate::gp::predict::Prediction;
use crate::gp::serve::{Predictor, ServeStats};
use crate::rng::Xoshiro256;
use crate::runtime::ExecutionContext;

use super::registry::ModelSpec;
use super::tournament::TrainedModel;
use super::train::{train_model, TrainOptions, TrainResult};

/// How the session answers a predict call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouteMode {
    /// Serve the evidence winner only (the default; bit-identical to a
    /// single-model session).
    #[default]
    Winner,
    /// Evidence-weighted model averaging across the whole roster.
    Averaged,
}

/// Drift-monitor tuning.
#[derive(Clone, Copy, Debug)]
pub struct DriftOptions {
    /// Points in the baseline and in the rolling comparison window.
    pub window: usize,
    /// Flag when `baseline − recent` mean log-score exceeds this (nats
    /// per point).
    pub threshold: f64,
}

impl Default for DriftOptions {
    fn default() -> Self {
        // a sustained 2-nat per-point deficit corresponds to the data
        // sitting ~2σ from the predictive mean on average — far outside
        // streaming noise, a clear retrain signal
        Self { window: 16, threshold: 2.0 }
    }
}

/// One model's drift state, reported by [`ServeSession::drift`].
#[derive(Clone, Debug)]
pub struct DriftStatus {
    pub model: String,
    /// Mean log-score over the baseline window (`None` until filled).
    pub baseline: Option<f64>,
    /// Mean log-score over the most recent window (`None` until filled).
    pub recent: Option<f64>,
    /// `baseline − recent` when both windows are full, else 0.
    pub deficit: f64,
    /// Latched true once the deficit crossed the threshold.
    pub drifted: bool,
}

/// Windowed log-score drift detector (see the module docs). Scores are
/// pushed *before* the point is absorbed, so each one is a genuine
/// out-of-sample log predictive density.
#[derive(Clone, Debug)]
struct DriftMonitor {
    opts: DriftOptions,
    /// Sum and count of the first `window` scores.
    baseline_sum: f64,
    baseline_n: usize,
    /// Ring buffer of the most recent `window` scores (after baseline).
    recent: Vec<f64>,
    next: usize,
    filled: bool,
    drifted: bool,
}

impl DriftMonitor {
    fn new(mut opts: DriftOptions) -> Self {
        // a zero-point window would index an empty ring on the first
        // push; one point is the smallest meaningful window
        opts.window = opts.window.max(1);
        Self {
            opts,
            baseline_sum: 0.0,
            baseline_n: 0,
            recent: Vec::new(),
            next: 0,
            filled: false,
            drifted: false,
        }
    }

    fn push(&mut self, score: f64) {
        if !score.is_finite() {
            return;
        }
        if self.baseline_n < self.opts.window {
            self.baseline_sum += score;
            self.baseline_n += 1;
            return;
        }
        if self.recent.len() < self.opts.window {
            self.recent.push(score);
            self.filled = self.recent.len() == self.opts.window;
        } else {
            self.recent[self.next] = score;
            self.next = (self.next + 1) % self.opts.window;
        }
        if self.filled && self.deficit() > self.opts.threshold {
            self.drifted = true;
        }
    }

    fn baseline(&self) -> Option<f64> {
        (self.baseline_n == self.opts.window)
            .then(|| self.baseline_sum / self.baseline_n as f64)
    }

    fn recent_mean(&self) -> Option<f64> {
        self.filled
            .then(|| self.recent.iter().sum::<f64>() / self.recent.len() as f64)
    }

    fn deficit(&self) -> f64 {
        match (self.baseline(), self.recent_mean()) {
            (Some(b), Some(r)) => b - r,
            _ => 0.0,
        }
    }
}

/// One routed model: spec, cached predictor, ranking evidence, drift
/// state.
struct ModelSlot {
    spec: ModelSpec,
    predictor: Predictor,
    ln_z: f64,
    drift: DriftMonitor,
}

/// A live serving session routing over `N` trained models — see the
/// module docs. Slot 0 is always the evidence winner.
pub struct ServeSession {
    slots: Vec<ModelSlot>,
    route: RouteMode,
    exec: ExecutionContext,
}

impl ServeSession {
    /// Build the router from a finished tournament: every artifact's
    /// peak factor is **adopted** (an `O(n²)` copy each, no re-assembly,
    /// no `O(n³)` refactorisation) and the slots are ranked by ln Z —
    /// the winner serves by default. `models` is expected ranked (as
    /// [`super::tournament::TournamentResult::models`] is); the session
    /// re-ranks defensively.
    pub fn from_tournament(
        models: &[TrainedModel],
        data: &Dataset,
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!models.is_empty(), "no trained models to serve");
        let mut slots = Vec::with_capacity(models.len());
        for tm in models {
            slots.push(ModelSlot {
                spec: tm.spec.clone(),
                predictor: tm.predictor(data)?,
                ln_z: tm.ln_z(),
                drift: DriftMonitor::new(DriftOptions::default()),
            });
        }
        slots.sort_by(|a, b| b.ln_z.partial_cmp(&a.ln_z).unwrap_or(std::cmp::Ordering::Equal));
        Ok(Self { slots, route: RouteMode::Winner, exec })
    }

    /// Wire a finished single-model training run into a session by
    /// adopting the peak evaluation `train_model` already produced.
    /// Equivalent to a tournament-of-one handoff (ln Z is not known on
    /// this path; the lone slot needs no ranking).
    pub fn from_training(
        spec: &ModelSpec,
        sigma_n: f64,
        data: &Dataset,
        trained: &TrainResult,
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            trained.peak_eval.chol.dim() == data.len(),
            "TrainResult is for n = {}, dataset has n = {}",
            trained.peak_eval.chol.dim(),
            data.len()
        );
        let model = spec.build(sigma_n);
        let predictor = Predictor::from_eval(
            model,
            data.t.clone(),
            data.y.clone(),
            trained.theta_hat.clone(),
            trained.peak_eval.clone(),
        );
        Ok(Self {
            slots: vec![ModelSlot {
                spec: spec.clone(),
                predictor,
                ln_z: 0.0,
                drift: DriftMonitor::new(DriftOptions::default()),
            }],
            route: RouteMode::Winner,
            exec,
        })
    }

    /// Train (multistart CG, like the comparison pipeline) and move
    /// straight into serving.
    pub fn train_and_serve(
        spec: &ModelSpec,
        sigma_n: f64,
        data: &Dataset,
        opts: &TrainOptions,
        workers: usize,
        exec: ExecutionContext,
        rng: &mut Xoshiro256,
    ) -> crate::Result<(Self, TrainResult)> {
        let trained = train_model(spec, sigma_n, data, opts, workers, &exec, rng)?;
        let session = Self::from_training(spec, sigma_n, data, &trained, exec)?;
        Ok((session, trained))
    }

    /// Switch the routing policy (builder style).
    pub fn with_route(mut self, route: RouteMode) -> Self {
        self.route = route;
        self
    }

    /// Override the drift-monitor tuning on every slot (resets any
    /// accumulated drift state).
    pub fn with_drift_options(mut self, opts: DriftOptions) -> Self {
        for slot in &mut self.slots {
            slot.drift = DriftMonitor::new(opts);
        }
        self
    }

    /// Number of routed models.
    pub fn n_models(&self) -> usize {
        self.slots.len()
    }

    /// The spec served by default (the evidence winner).
    pub fn spec(&self) -> &ModelSpec {
        &self.slots[0].spec
    }

    /// Evidence-posterior weights over the roster, winner first
    /// (`w_i ∝ exp(ln Z_i)`, normalised).
    pub fn weights(&self) -> Vec<f64> {
        let max = self.slots.iter().map(|s| s.ln_z).fold(f64::NEG_INFINITY, f64::max);
        let mut w: Vec<f64> = self.slots.iter().map(|s| (s.ln_z - max).exp()).collect();
        let total: f64 = w.iter().sum();
        for v in &mut w {
            *v /= total;
        }
        w
    }

    /// Serve one batch of query points under the session's route mode.
    pub fn predict(&self, t_star: &[f64]) -> Prediction {
        match self.route {
            RouteMode::Winner => self.slots[0].predictor.predict_batch(t_star, &self.exec),
            RouteMode::Averaged => self.predict_averaged(t_star),
        }
    }

    /// Serve a specific roster member by name, regardless of route mode.
    pub fn predict_model(&self, name: &str, t_star: &[f64]) -> Option<Prediction> {
        self.slots
            .iter()
            .find(|s| s.spec.name() == name)
            .map(|s| s.predictor.predict_batch(t_star, &self.exec))
    }

    /// Evidence-weighted model averaging: mixture mean and mixture
    /// standard deviation across every slot. With a dominant winner
    /// (`ln B ≫ 1`) this degrades gracefully to the winner's prediction.
    fn predict_averaged(&self, t_star: &[f64]) -> Prediction {
        let w = self.weights();
        let mut mean = vec![0.0; t_star.len()];
        let mut second = vec![0.0; t_star.len()]; // Σ wᵢ (σᵢ² + μᵢ²)
        for (slot, &wi) in self.slots.iter().zip(&w) {
            let p = slot.predictor.predict_batch(t_star, &self.exec);
            for i in 0..t_star.len() {
                mean[i] += wi * p.mean[i];
                second[i] += wi * (p.sd[i] * p.sd[i] + p.mean[i] * p.mean[i]);
            }
        }
        let sd = mean
            .iter()
            .zip(&second)
            .map(|(m, s)| (s - m * m).max(0.0).sqrt())
            .collect();
        Prediction { mean, sd }
    }

    /// Append one observation to **every** live factor (`O(n²)` each),
    /// all-or-nothing: each model first scores the point and reports the
    /// pivot its factor extension would take
    /// ([`Predictor::log_predictive_and_pivot`]); if any model's
    /// extension would fail, the call errors **before any slot mutates**,
    /// so the routed factors never diverge in their data. Scores feed the
    /// per-model drift monitors only when the point is absorbed.
    pub fn observe(&mut self, t_new: f64, y_new: f64) -> crate::Result<()> {
        let mut scored = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let s = slot.predictor.score_observation(t_new, y_new);
            anyhow::ensure!(
                s.pivot > 0.0 && s.pivot.is_finite(),
                "observe(t={t_new}) would make {}'s K̃ non-PD (pivot {:.3e}); \
                 no model absorbed the point",
                slot.spec.name(),
                s.pivot
            );
            scored.push(s);
        }
        for (slot, s) in self.slots.iter_mut().zip(scored) {
            slot.drift.push(s.score);
            // reuses the pivot check's triangular solve — one O(n²) solve
            // per (point, model), and it cannot fail: the extension takes
            // exactly the pre-checked pivot
            slot.predictor.observe_scored(t_new, y_new, s)?;
        }
        Ok(())
    }

    /// Append a batch of observations **point by point**: each point is
    /// scored against factors that have already absorbed every earlier
    /// point (drift scores are independent of how the caller chunks the
    /// stream), then fanned out atomically like [`ServeSession::observe`].
    /// On a mid-batch failure the already-absorbed prefix is kept — by
    /// every model consistently — and the error propagates.
    pub fn observe_batch(&mut self, t_new: &[f64], y_new: &[f64]) -> crate::Result<()> {
        anyhow::ensure!(t_new.len() == y_new.len(), "t/y batch length mismatch");
        for (&tn, &yn) in t_new.iter().zip(y_new) {
            self.observe(tn, yn)?;
        }
        Ok(())
    }

    /// Serving counters of the **winner** slot (the factor every default
    /// query goes through).
    pub fn stats(&self) -> ServeStats {
        self.slots[0].predictor.stats()
    }

    /// The winner's predictor (e.g. for `lnp()`/`sigma_f_hat2()`).
    pub fn predictor(&self) -> &Predictor {
        &self.slots[0].predictor
    }

    /// Per-model drift status, winner first.
    pub fn drift(&self) -> Vec<DriftStatus> {
        self.slots
            .iter()
            .map(|s| DriftStatus {
                model: s.spec.name().to_string(),
                baseline: s.drift.baseline(),
                recent: s.drift.recent_mean(),
                deficit: s.drift.deficit(),
                drifted: s.drift.drifted,
            })
            .collect()
    }

    /// True when any routed model's appended-point log-score has
    /// degraded past the drift threshold — the signal to rerun the
    /// tournament on the accumulated data.
    pub fn needs_retrain(&self) -> bool {
        self.slots.iter().any(|s| s.drift.drifted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::table1_dataset;
    use crate::optimize::MultistartOptions;

    #[test]
    fn train_and_serve_round_trip() {
        let data = table1_dataset(40, 0.1, 23);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(29);
        let (mut session, trained) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        assert!(trained.lnp_peak.is_finite());
        let pred = session.predict(&[5.5, 20.25]);
        assert_eq!(pred.mean.len(), 2);
        assert!(pred.sd.iter().all(|s| s.is_finite() && *s >= 0.0));
        // stream two points and serve again — n grows, queries accumulate
        session.observe_batch(&[41.0, 42.0], &[0.1, -0.2]).unwrap();
        let s = session.stats();
        assert_eq!(s.n_train, 42);
        assert_eq!(s.observations_appended, 2);
        let pred2 = session.predict(&[41.5]);
        assert_eq!(s.queries_served + 1, session.stats().queries_served);
        assert!(pred2.mean[0].is_finite());
        assert!(!session.needs_retrain(), "two in-distribution points must not flag");
    }

    #[test]
    fn from_training_uses_trained_theta() {
        let data = table1_dataset(30, 0.1, 31);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(37);
        let exec = ExecutionContext::seq();
        let trained =
            train_model(&ModelSpec::K1, 0.1, &data, &opts, 1, &exec, &mut rng).unwrap();
        let session =
            ServeSession::from_training(&ModelSpec::K1, 0.1, &data, &trained, exec).unwrap();
        assert_eq!(session.predictor().theta(), trained.theta_hat.as_slice());
        assert_eq!(session.stats().n_train, 30);
        assert_eq!(session.n_models(), 1);
        assert_eq!(session.spec(), &ModelSpec::K1);
        assert_eq!(session.weights(), vec![1.0]);
    }

    #[test]
    fn drift_monitor_fires_on_sustained_deficit_and_not_on_noise() {
        let opts = DriftOptions { window: 4, threshold: 1.0 };
        let mut m = DriftMonitor::new(opts);
        // baseline window: scores around −1
        for s in [-1.0, -1.1, -0.9, -1.0] {
            m.push(s);
        }
        assert!((m.baseline().expect("baseline full") + 1.0).abs() < 1e-12);
        // comparable recent window: no flag
        for s in [-1.2, -0.8, -1.0, -1.0] {
            m.push(s);
        }
        assert!(!m.drifted, "in-noise scores must not latch drift");
        // degraded scores: deficit 3 nats > threshold 1 → latch
        for s in [-4.0, -4.0, -4.0, -4.0] {
            m.push(s);
        }
        assert!(m.drifted);
        assert!(m.deficit() > 1.0);
        // recovery does not unlatch (the flag is a retrain signal)
        for s in [-1.0; 8] {
            m.push(s);
        }
        assert!(m.drifted);
        // non-finite scores are ignored outright
        let mut m2 = DriftMonitor::new(opts);
        m2.push(f64::NAN);
        assert_eq!(m2.baseline_n, 0);
        // a window of 0 is clamped to 1 instead of panicking on push
        let mut m3 = DriftMonitor::new(DriftOptions { window: 0, threshold: 1.0 });
        m3.push(-1.0);
        m3.push(-1.0);
        m3.push(-5.0);
        assert!(m3.drifted, "1-point window must still detect the collapse");
    }
}
