//! Coordinator glue for the serving layer: a **multi-model router** over
//! cached [`Predictor`]s, fed by the tournament's [`TrainedModel`]
//! artifacts.
//!
//! [`ServeSession`] owns one live predictor per tournament entrant,
//! ranked by Laplace evidence:
//!
//! * **Routing** — queries go to the evidence winner by default
//!   ([`RouteMode::Winner`]; a single-model session is bit-identical to
//!   serving that model directly), or to the whole roster under
//!   **evidence-weighted model averaging** ([`RouteMode::Averaged`]):
//!   posterior-probability weights `w_i ∝ exp(ln Z_i)`, mixture mean
//!   `Σ w_i μ_i` and mixture variance `Σ w_i (σ_i² + μ_i²) − μ̄²`.
//! * **Streaming** — [`ServeSession::observe`] /
//!   [`ServeSession::observe_batch`] fan every arriving observation out
//!   to **all** live factors (each an `O(n²)` extension), so the ranking
//!   can be revisited and the router switched without retraining. The
//!   fan-out is all-or-nothing per point: every model's extension pivot
//!   is checked before any factor mutates, so the slots always hold the
//!   same data.
//! * **Drift** — before a point is absorbed, each model scores it with
//!   its log predictive density ([`Predictor::log_predictive`]); a
//!   per-model [`DriftMonitor`] compares the recent windowed mean
//!   log-score against the baseline established when streaming began and
//!   **flags retraining** when the score has degraded past a threshold
//!   ([`ServeSession::needs_retrain`]). Hyperparameters are frozen at
//!   ϑ̂ between retrains, so a sustained log-score deficit is exactly the
//!   signature of hyperparameter drift.
//!
//! ## Serving lifecycle: grow → evict → refresh → retrain → quarantine
//!
//! With a [`WindowPolicy`] attached ([`ServeSession::with_window`]) the
//! session is **self-healing and bounded-memory**:
//!
//! * **grow** — every absorbed point extends all factors in `O(n²)`;
//! * **evict** — past `max_points` the oldest observation is deleted
//!   from every slot ([`Predictor::evict`], an `O(n²)` rank-1 restore on
//!   the trailing block), so no factor ever exceeds the window — the
//!   sliding-window accuracy-for-cost trade of Chalupka et al. and of
//!   subset-based GPR;
//! * **refresh** — every `refresh_every` evictions all factors are
//!   refactorised cold from the live window, washing out accumulated
//!   `O(n²)`-maintenance rounding drift; each refreshed factor's
//!   spectral conditioning is probed ([`Chol::cond_1est`],
//!   a Hager-style 1-norm estimate costing `O(n²)`) and a slot whose
//!   estimate crosses the session's condition limit latches **degraded**
//!   into [`ServeSession::needs_retrain`];
//! * **retrain** — when the drift monitor or a health latch fires,
//!   [`ServeSession::retrain`] reruns training on the current window
//!   (every model warm-started from its incumbent ϑ̂), recomputes each
//!   Laplace evidence, and **hot-swaps** all slots, the evidence ranking
//!   and the drift baselines without dropping the session: counters
//!   carry over and queries keep being served from the new peaks;
//! * **quarantine** — a slot whose factor maintenance becomes
//!   unrecoverable (its extension pivot fails while sibling models
//!   absorb the point, its cold refit errors, or a window shrink cannot
//!   be repaired) is **frozen at its last good factor and routed
//!   around** instead of dropping the session: it stops absorbing
//!   observations, [`RouteMode::Winner`] falls to the next-ranked
//!   healthy slot, [`RouteMode::Averaged`] renormalises over the
//!   healthy roster, and `needs_retrain` latches. A successful
//!   [`ServeSession::retrain`] rebuilds every slot from a healthy
//!   window and **re-enters** quarantined models. Per-slot health is
//!   reported by [`ServeSession::health`].
//!
//! [`Chol::cond_1est`]: crate::linalg::Chol::cond_1est
//!
//! Constructed from a finished tournament
//! ([`ServeSession::from_tournament`]), from a single training run
//! ([`ServeSession::from_training`]), by training in place
//! ([`ServeSession::train_and_serve`]), or — the `O(n²)` restart path —
//! from persisted artifacts on disk ([`ServeSession::from_artifacts`],
//! reading [`TrainedModel::save`] files with zero likelihood
//! evaluations).

use std::path::Path;

use crate::data::Dataset;
use crate::evidence::{laplace_evidence, LaplaceEvidence};
use crate::gp::predict::Prediction;
use crate::gp::serve::{Predictor, ServeStats};
use crate::gp::ProfiledEval;
use crate::linalg::Matrix;
use crate::priors::{BoxPrior, ScalePrior};
use crate::rng::Xoshiro256;
use crate::runtime::ExecutionContext;

use super::artifact_v4::ArtifactView;
use super::registry::ModelSpec;
use super::tournament::TrainedModel;
use super::train::{train_model, TrainOptions, TrainResult};

/// How the session answers a predict call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouteMode {
    /// Serve the evidence winner only (the default; bit-identical to a
    /// single-model session).
    #[default]
    Winner,
    /// Evidence-weighted model averaging across the whole roster.
    Averaged,
}

/// Bounded-memory sliding-window policy (see the module docs'
/// lifecycle section).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowPolicy {
    /// Hard cap on the points behind every cached factor: observations
    /// past this evict the oldest point from all slots. Clamped to ≥ 2
    /// by [`ServeSession::with_window`] (a factor must keep at least one
    /// point and be able to absorb the next).
    pub max_points: usize,
    /// Refactorise every slot cold from the live window after this many
    /// evictions, washing out accumulated rank-1 rounding drift
    /// (`0` = never refresh).
    pub refresh_every: usize,
}

/// What [`ServeSession::retrain`] did, per model in the new rank order.
#[derive(Clone, Debug)]
pub struct RetrainOutcome {
    /// Points in the window the retrain was fitted on.
    pub window_n: usize,
    /// `(model name, previous ln Z, new ln Z)`, new-rank order (winner
    /// first).
    pub models: Vec<(String, f64, f64)>,
    /// The new evidence winner.
    pub winner: String,
    /// Did the retrain change which model serves by default?
    pub winner_changed: bool,
}

/// Drift-monitor tuning.
#[derive(Clone, Copy, Debug)]
pub struct DriftOptions {
    /// Points in the baseline and in the rolling comparison window.
    pub window: usize,
    /// Flag when `baseline − recent` mean log-score exceeds this (nats
    /// per point).
    pub threshold: f64,
}

impl Default for DriftOptions {
    fn default() -> Self {
        // a sustained 2-nat per-point deficit corresponds to the data
        // sitting ~2σ from the predictive mean on average — far outside
        // streaming noise, a clear retrain signal
        Self { window: 16, threshold: 2.0 }
    }
}

/// One model's drift state, reported by [`ServeSession::drift`].
#[derive(Clone, Debug)]
pub struct DriftStatus {
    pub model: String,
    /// Mean log-score over the baseline window (`None` until filled).
    pub baseline: Option<f64>,
    /// Mean log-score over the most recent window (`None` until filled).
    pub recent: Option<f64>,
    /// `baseline − recent` when both windows are full, else 0.
    pub deficit: f64,
    /// Latched true once the deficit crossed the threshold.
    pub drifted: bool,
}

/// Windowed log-score drift detector (see the module docs). Scores are
/// pushed *before* the point is absorbed, so each one is a genuine
/// out-of-sample log predictive density.
#[derive(Clone, Debug)]
struct DriftMonitor {
    opts: DriftOptions,
    /// Sum and count of the first `window` scores.
    baseline_sum: f64,
    baseline_n: usize,
    /// Ring buffer of the most recent `window` scores (after baseline).
    recent: Vec<f64>,
    next: usize,
    filled: bool,
    drifted: bool,
}

impl DriftMonitor {
    fn new(mut opts: DriftOptions) -> Self {
        // a zero-point window would index an empty ring on the first
        // push; one point is the smallest meaningful window
        opts.window = opts.window.max(1);
        Self {
            opts,
            baseline_sum: 0.0,
            baseline_n: 0,
            recent: Vec::new(),
            next: 0,
            filled: false,
            drifted: false,
        }
    }

    fn push(&mut self, score: f64) {
        if !score.is_finite() {
            return;
        }
        if self.baseline_n < self.opts.window {
            self.baseline_sum += score;
            self.baseline_n += 1;
            return;
        }
        if self.recent.len() < self.opts.window {
            self.recent.push(score);
            self.filled = self.recent.len() == self.opts.window;
        } else {
            self.recent[self.next] = score;
            self.next = (self.next + 1) % self.opts.window;
        }
        if self.filled && self.deficit() > self.opts.threshold {
            self.drifted = true;
        }
    }

    fn baseline(&self) -> Option<f64> {
        (self.baseline_n == self.opts.window)
            .then(|| self.baseline_sum / self.baseline_n as f64)
    }

    fn recent_mean(&self) -> Option<f64> {
        self.filled
            .then(|| self.recent.iter().sum::<f64>() / self.recent.len() as f64)
    }

    fn deficit(&self) -> f64 {
        match (self.baseline(), self.recent_mean()) {
            (Some(b), Some(r)) => b - r,
            _ => 0.0,
        }
    }
}

/// Default spectral-condition limit: a 1-norm condition estimate above
/// this latches the slot **degraded** (≈ four decimal digits of the
/// factor's accuracy left in double precision — conservative enough to
/// retrain well before the factor visibly misbehaves). Override with
/// [`ServeSession::with_cond_limit`].
pub const COND_RETRAIN_LIMIT: f64 = 1e12;

/// One slot's numerical-health record, reported by
/// [`ServeSession::health`] (winner first, like
/// [`ServeSession::drift`]).
#[derive(Clone, Copy, Debug)]
pub struct FactorHealth {
    /// Routed model name.
    pub model: &'static str,
    /// Latest Hager 1-norm condition estimate of the slot's `K̃` (probed
    /// at construction, on every cold refresh, and after retrain).
    pub cond_est: f64,
    /// Diagonal jitter the training-time escalation ladder applied to
    /// this slot's factor (`0.0` on the clean path — asserted by the
    /// fault-injection soak).
    pub jitter: f64,
    /// Lifetime window-shrink failures this slot repaired or was
    /// quarantined for.
    pub downdate_failures: u64,
    /// Lifetime cold refactorisations of this slot.
    pub refreshes: u64,
    /// Latched when `cond_est` crossed the session's condition limit.
    pub degraded: bool,
    /// Latched when factor maintenance became unrecoverable; the slot is
    /// frozen and routed around until a retrain re-enters it.
    pub quarantined: bool,
}

/// Internal per-slot health state backing [`FactorHealth`].
#[derive(Clone, Debug)]
struct SlotHealth {
    cond_est: f64,
    downdate_failures: u64,
    refreshes: u64,
    degraded: bool,
    quarantined: bool,
}

impl SlotHealth {
    /// Probe a freshly built predictor's conditioning (`O(n²)`).
    fn probe(p: &Predictor, cond_limit: f64) -> Self {
        let cond_est = p.chol().cond_1est();
        Self {
            cond_est,
            downdate_failures: 0,
            refreshes: 0,
            degraded: cond_est > cond_limit,
            quarantined: false,
        }
    }
}

/// One routed model: spec, cached predictor, ranking evidence, drift
/// state, numerical health.
struct ModelSlot {
    spec: ModelSpec,
    predictor: Predictor,
    ln_z: f64,
    drift: DriftMonitor,
    health: SlotHealth,
}

/// A live serving session routing over `N` trained models — see the
/// module docs. Slot 0 is always the evidence winner.
pub struct ServeSession {
    slots: Vec<ModelSlot>,
    route: RouteMode,
    exec: ExecutionContext,
    /// Fixed noise level the slots were trained with (needed to rebuild
    /// models on retrain).
    sigma_n: f64,
    /// σ_f prior for retrain-time evidence (must match the prior the
    /// incumbent ln Z values were computed with, or old-vs-new deltas
    /// pick up a spurious prior-volume offset). Defaults to
    /// [`ScalePrior::default`], the config pipeline's choice; override
    /// with [`ServeSession::with_scale_prior`].
    scale_prior: ScalePrior,
    /// Drift tuning applied to every (re)created monitor.
    drift_opts: DriftOptions,
    window: Option<WindowPolicy>,
    /// Condition-estimate threshold that latches a slot **degraded**
    /// (see [`COND_RETRAIN_LIMIT`]).
    cond_limit: f64,
    /// Evictions since the last cold refresh (drives `refresh_every`).
    since_refresh: usize,
    /// Lifetime window-eviction rounds (each round drops one point from
    /// every slot).
    evictions: usize,
    /// Lifetime cold refreshes (periodic + retrain hot-swaps).
    refreshes: usize,
}

impl ServeSession {
    /// Build the router from a finished tournament: every artifact's
    /// peak factor is **adopted** (an `O(n²)` copy each, no re-assembly,
    /// no `O(n³)` refactorisation) and the slots are ranked by ln Z —
    /// the winner serves by default. `models` is expected ranked (as
    /// [`super::tournament::TournamentResult::models`] is); the session
    /// re-ranks defensively.
    pub fn from_tournament(
        models: &[TrainedModel],
        data: &Dataset,
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!models.is_empty(), "no trained models to serve");
        let mut slots = Vec::with_capacity(models.len());
        for tm in models {
            anyhow::ensure!(
                tm.sigma_n == models[0].sigma_n,
                "roster noise levels disagree: {} vs {}",
                tm.sigma_n,
                models[0].sigma_n
            );
            let predictor = tm.predictor(data)?;
            let health = SlotHealth::probe(&predictor, COND_RETRAIN_LIMIT);
            slots.push(ModelSlot {
                spec: tm.spec.clone(),
                predictor,
                ln_z: tm.ln_z(),
                drift: DriftMonitor::new(DriftOptions::default()),
                health,
            });
        }
        slots.sort_by(|a, b| crate::util::desc_nan_last(a.ln_z, b.ln_z));
        Ok(Self {
            slots,
            route: RouteMode::Winner,
            exec,
            sigma_n: models[0].sigma_n,
            scale_prior: ScalePrior::default(),
            drift_opts: DriftOptions::default(),
            window: None,
            cond_limit: COND_RETRAIN_LIMIT,
            since_refresh: 0,
            evictions: 0,
            refreshes: 0,
        })
    }

    /// Restart a serving process from persisted [`TrainedModel`]
    /// artifacts ([`TrainedModel::save`] files) — the `O(n²)` path: every
    /// factor is read back bit-identically from disk, so the session
    /// reaches its first prediction with **zero** likelihood evaluations
    /// (asserted via [`crate::gp::profiled::eval_count`] in the
    /// persistence suite). All artifacts must have been trained on the
    /// same dataset; the roster is re-ranked by the stored evidence.
    pub fn from_artifacts<P: AsRef<Path>>(
        paths: &[P],
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!paths.is_empty(), "no artifact paths given");
        let mut models = Vec::with_capacity(paths.len());
        let mut data: Option<Dataset> = None;
        for p in paths {
            let (tm, d) = TrainedModel::load(p.as_ref())?;
            match &data {
                None => data = Some(d),
                Some(d0) => anyhow::ensure!(
                    d0.t == d.t && d0.y == d.y && d0.extra == d.extra && d0.noise == d.noise,
                    "artifact {} was trained on different data than the first artifact",
                    p.as_ref().display()
                ),
            }
            models.push(tm);
        }
        let data = data.expect("non-empty artifact list");
        Self::from_tournament(&models, &data, exec)
    }

    /// [`ServeSession::from_artifacts`] for artifact *bytes* instead of
    /// files — the hydration path of the multi-tenant fleet
    /// ([`crate::coordinator::fleet`]), where blobs come from an
    /// [`crate::coordinator::fleet::ArtifactStore`] that may never touch
    /// the filesystem. Same guarantees: zero likelihood evaluations,
    /// bit-identical factors, all blobs must decode to the same dataset.
    pub fn from_artifact_bytes<B: AsRef<[u8]>>(
        blobs: &[B],
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!blobs.is_empty(), "no artifact blobs given");
        let mut models = Vec::with_capacity(blobs.len());
        let mut data: Option<Dataset> = None;
        for (i, b) in blobs.iter().enumerate() {
            let (tm, d) = TrainedModel::from_bytes(b.as_ref())?;
            match &data {
                None => data = Some(d),
                Some(d0) => anyhow::ensure!(
                    d0.t == d.t && d0.y == d.y && d0.extra == d.extra && d0.noise == d.noise,
                    "artifact blob {i} was trained on different data than the first blob"
                ),
            }
            models.push(tm);
        }
        let data = data.expect("non-empty blob list");
        Self::from_tournament(&models, &data, exec)
    }

    /// Hydrate a session straight from parsed **v4 artifact views** —
    /// the zero-copy half of the fleet's hydration path
    /// ([`crate::coordinator::fleet`]). Uncompressed exact-spec views
    /// adopt their borrowed numeric blocks directly into predictors
    /// ([`Predictor::from_view_parts`]): one memcpy per block off the
    /// (possibly memory-mapped) buffer, no intermediate [`TrainedModel`]
    /// and no per-row factor `Vec`s. Compressed or approximate-spec
    /// views fall back to [`ArtifactView::adopt`] + the tournament
    /// constructor. Serves the same bits as
    /// [`ServeSession::from_artifact_bytes`] over equivalent blobs.
    pub fn from_artifact_views(
        views: &[ArtifactView<'_>],
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!views.is_empty(), "no artifact views given");
        let sigma_n = views[0].sigma_n();
        let mut slots = Vec::with_capacity(views.len());
        for (i, v) in views.iter().enumerate() {
            anyhow::ensure!(
                v.sigma_n() == sigma_n,
                "roster noise levels disagree: {} vs {sigma_n}",
                v.sigma_n()
            );
            anyhow::ensure!(
                v.t() == views[0].t()
                    && v.y() == views[0].y()
                    && v.extra_cols() == views[0].extra_cols()
                    && v.noise() == views[0].noise(),
                "artifact view {i} was trained on different data than the first view"
            );
            v.validate_payload()?;
            let predictor = match v.packed_factor() {
                Some(packed) if v.spec().approx().is_none() => {
                    let mut p = Predictor::from_view_parts(
                        v.spec().build(sigma_n),
                        v.t(),
                        v.y(),
                        v.theta(),
                        packed,
                        v.logdet(),
                        v.alpha(),
                        v.sigma_f_hat2(),
                        v.jitter(),
                    );
                    if v.d() > 1 || v.noise().is_some() {
                        p.attach_input_block(
                            v.extra_cols().to_vec(),
                            v.noise().map(|s| s.to_vec()),
                        );
                    }
                    p
                }
                // compressed or approximate-spec views materialise the
                // model first (spectral reconstruction / reduced-set
                // serving both need the full adopt path)
                _ => {
                    let (tm, data) = v.adopt()?;
                    tm.predictor(&data)?
                }
            };
            let health = SlotHealth::probe(&predictor, COND_RETRAIN_LIMIT);
            slots.push(ModelSlot {
                spec: v.spec().clone(),
                predictor,
                ln_z: v.ln_z(),
                drift: DriftMonitor::new(DriftOptions::default()),
                health,
            });
        }
        slots.sort_by(|a, b| crate::util::desc_nan_last(a.ln_z, b.ln_z));
        Ok(Self {
            slots,
            route: RouteMode::Winner,
            exec,
            sigma_n,
            scale_prior: ScalePrior::default(),
            drift_opts: DriftOptions::default(),
            window: None,
            cond_limit: COND_RETRAIN_LIMIT,
            since_refresh: 0,
            evictions: 0,
            refreshes: 0,
        })
    }

    /// Re-serialise the **live** session as artifact bytes, one blob per
    /// slot in the current rank order — the eviction path of the
    /// multi-tenant fleet: a dirty session (post-`observe`/`retrain`)
    /// persists its *current* factors, data window and evidence ranking,
    /// and a later [`ServeSession::from_artifact_bytes`] serves
    /// bit-identical predictions (factor, α, σ̂² and ϑ̂ round-trip
    /// exactly; the stored ln Z preserves the ranking and the averaging
    /// weights).
    ///
    /// What deliberately does **not** round-trip: training diagnostics
    /// (restart values, eval counts, wall-clock — re-encoded as zeros so
    /// the bytes are deterministic), evidence error bars (σ, H⁻¹ —
    /// zeroed; ln Z itself is kept), drift baselines, health latches and
    /// serving counters (a rehydrated session re-probes health from the
    /// factor it loads). Predictions are unaffected by any of these.
    ///
    /// Errors for approximate-spec slots (`sod-k2`/`fitc-k2`): their
    /// artifact format stores the *full* training set alongside a
    /// reduced factor, and a live slot only holds the reduced serving
    /// set, so a faithful re-encoding is impossible — fleets that mutate
    /// sessions should roster exact specs.
    pub fn to_artifact_bytes(&self) -> crate::Result<Vec<Vec<u8>>> {
        self.to_artifact_bytes_with(3, None)
    }

    /// [`ServeSession::to_artifact_bytes`] with an explicit artifact
    /// format: `version` is 3 (the default field-stream format) or 4
    /// (the zero-copy block layout, optionally compressed with
    /// `compress_tol` — see [`crate::coordinator::artifact_v4`]).
    /// `compress_tol` is rejected for version 3.
    pub fn to_artifact_bytes_with(
        &self,
        version: u32,
        compress_tol: Option<f64>,
    ) -> crate::Result<Vec<Vec<u8>>> {
        anyhow::ensure!(
            version == 3 || version == 4,
            "unsupported artifact encode version {version} (this build writes 3 and 4)"
        );
        anyhow::ensure!(
            compress_tol.is_none() || version == 4,
            "factor compression requires artifact version 4"
        );
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            anyhow::ensure!(
                slot.spec.approx().is_none(),
                "cannot re-serialise a live {} slot: approximate specs store the full \
                 training set, which a serving slot no longer holds",
                slot.spec.name()
            );
            let p = &slot.predictor;
            let mut data =
                Dataset::new(p.t().to_vec(), p.y().to_vec(), format!("serve-session-{}", slot.spec.name()));
            if p.d() > 1 {
                data = data.with_extra_cols(p.extra().to_vec())?;
            }
            if let Some(s) = p.noise() {
                data = data.with_noise(s.to_vec())?;
            }
            let m = p.theta().len();
            let peak_eval = ProfiledEval {
                lnp: p.lnp(),
                sigma_f_hat2: p.sigma_f_hat2(),
                chol: p.chol().clone(),
                alpha: p.alpha().to_vec(),
                jitter: p.jitter(),
            };
            let tm = TrainedModel {
                spec: slot.spec.clone(),
                sigma_n: self.sigma_n,
                param_names: p.model().kernel.names(),
                train: TrainResult {
                    theta_hat: p.theta().to_vec(),
                    lnp_peak: p.lnp(),
                    sigma_f_hat2: p.sigma_f_hat2(),
                    peak_eval,
                    converged: true,
                    n_evals: 0,
                    n_modes: 0,
                    restart_values: Vec::new(),
                    jitter: p.jitter(),
                },
                evidence: LaplaceEvidence {
                    ln_z: slot.ln_z,
                    ln_p_peak: 0.0,
                    ln_det_h: 0.0,
                    ln_volume: 0.0,
                    marg_const: 0.0,
                    sigma: vec![0.0; m],
                    covariance: Matrix::zeros(m, m),
                    suspect: false,
                },
                nested: None,
                warm_started: false,
                restarts: 0,
                wall_secs: 0.0,
            };
            out.push(if version == 4 {
                tm.to_bytes_v4(&data, compress_tol)?
            } else {
                tm.to_bytes(&data)?
            });
        }
        Ok(out)
    }

    /// Wire a finished single-model training run into a session by
    /// adopting the peak evaluation `train_model` already produced.
    /// Equivalent to a tournament-of-one handoff (ln Z is not known on
    /// this path; the lone slot needs no ranking).
    pub fn from_training(
        spec: &ModelSpec,
        sigma_n: f64,
        data: &Dataset,
        trained: &TrainResult,
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            trained.peak_eval.chol.dim() == spec.factor_dim(data.len()),
            "TrainResult factor dim {} does not match {} for n = {}",
            trained.peak_eval.chol.dim(),
            spec.factor_dim(data.len()),
            data.len()
        );
        let model = spec.build(sigma_n);
        // approximate specs serve from their reduced dataset (stride
        // subset / inducing pseudo-data), exact specs from the full one
        let (t_serve, y_serve) = match spec.approx() {
            None => (data.t.clone(), data.y.clone()),
            Some(kind) => {
                crate::gp::approx::serve_parts(kind, &data.t, &data.y, &trained.peak_eval)
            }
        };
        let predictor = Predictor::from_eval(
            model,
            t_serve,
            y_serve,
            trained.theta_hat.clone(),
            trained.peak_eval.clone(),
        );
        let health = SlotHealth::probe(&predictor, COND_RETRAIN_LIMIT);
        Ok(Self {
            slots: vec![ModelSlot {
                spec: spec.clone(),
                predictor,
                ln_z: 0.0,
                drift: DriftMonitor::new(DriftOptions::default()),
                health,
            }],
            route: RouteMode::Winner,
            exec,
            sigma_n,
            scale_prior: ScalePrior::default(),
            drift_opts: DriftOptions::default(),
            window: None,
            cond_limit: COND_RETRAIN_LIMIT,
            since_refresh: 0,
            evictions: 0,
            refreshes: 0,
        })
    }

    /// Train (multistart CG, like the comparison pipeline) and move
    /// straight into serving.
    pub fn train_and_serve(
        spec: &ModelSpec,
        sigma_n: f64,
        data: &Dataset,
        opts: &TrainOptions,
        workers: usize,
        exec: ExecutionContext,
        rng: &mut Xoshiro256,
    ) -> crate::Result<(Self, TrainResult)> {
        let trained = train_model(spec, sigma_n, data, opts, workers, &exec, rng)?;
        let session = Self::from_training(spec, sigma_n, data, &trained, exec)?;
        Ok((session, trained))
    }

    /// Switch the routing policy (builder style).
    pub fn with_route(mut self, route: RouteMode) -> Self {
        self.route = route;
        self
    }

    /// Override the drift-monitor tuning on every slot (resets any
    /// accumulated drift state; also applied to the fresh monitors a
    /// retrain hot-swap creates).
    pub fn with_drift_options(mut self, opts: DriftOptions) -> Self {
        self.drift_opts = opts;
        for slot in &mut self.slots {
            slot.drift = DriftMonitor::new(opts);
        }
        self
    }

    /// Override the σ_f prior used for retrain-time evidence (builder
    /// style). Set this when the tournament that built the session ran
    /// with a non-default [`crate::coordinator::PipelineConfig::scale_prior`],
    /// so post-retrain ln Z values stay comparable with the incumbent
    /// ones (the prior-volume constant would otherwise offset every
    /// old-vs-new delta in [`RetrainOutcome`]).
    pub fn with_scale_prior(mut self, scale: ScalePrior) -> Self {
        self.scale_prior = scale;
        self
    }

    /// Attach a bounded-memory sliding-window policy (builder style):
    /// observations past `max_points` evict the oldest point from every
    /// slot, and every `refresh_every` evictions the factors are
    /// refactorised cold from the live window. `max_points` is clamped
    /// to ≥ 2.
    pub fn with_window(mut self, mut policy: WindowPolicy) -> Self {
        policy.max_points = policy.max_points.max(2);
        self.window = Some(policy);
        self
    }

    /// Override the spectral-condition limit that latches a slot
    /// **degraded** (builder style; defaults to
    /// [`COND_RETRAIN_LIMIT`]). Non-sensical limits (≤ 1, NaN) fall
    /// back to the default. Re-evaluates the latch against every slot's
    /// current estimate.
    pub fn with_cond_limit(mut self, limit: f64) -> Self {
        self.cond_limit = if limit > 1.0 { limit } else { COND_RETRAIN_LIMIT };
        for slot in &mut self.slots {
            slot.health.degraded = slot.health.cond_est > self.cond_limit;
        }
        self
    }

    /// The attached window policy, if any.
    pub fn window(&self) -> Option<WindowPolicy> {
        self.window
    }

    /// Window-eviction rounds performed so far (each round drops one
    /// point from every slot).
    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Cold factor refreshes performed so far (periodic window refreshes
    /// plus retrain hot-swaps).
    pub fn refreshes(&self) -> usize {
        self.refreshes
    }

    /// Fixed noise level σ_n the routed models serve with.
    pub fn sigma_n(&self) -> f64 {
        self.sigma_n
    }

    /// Number of routed models.
    pub fn n_models(&self) -> usize {
        self.slots.len()
    }

    /// The spec served by default (the evidence winner).
    pub fn spec(&self) -> &ModelSpec {
        &self.slots[0].spec
    }

    /// Index of the highest-ranked slot that is **not** quarantined.
    /// Falls back to the nominal winner when the whole roster is
    /// quarantined: a frozen factor still serves finite (if stale)
    /// predictions, which beats dropping the session while the caller
    /// arranges the retrain that `needs_retrain` is demanding.
    fn first_healthy(&self) -> usize {
        self.slots.iter().position(|s| !s.health.quarantined).unwrap_or(0)
    }

    /// Evidence-posterior weights over the roster, winner first
    /// (`w_i ∝ exp(ln Z_i)`, normalised). Quarantined slots get weight
    /// 0 and the healthy roster renormalises; if **every** slot is
    /// quarantined the weights fall back to plain evidence weighting
    /// (see [`ServeSession::first_healthy`] for the rationale).
    pub fn weights(&self) -> Vec<f64> {
        let all_quarantined = self.slots.iter().all(|s| s.health.quarantined);
        let max = self
            .slots
            .iter()
            .filter(|s| all_quarantined || !s.health.quarantined)
            .map(|s| s.ln_z)
            .fold(f64::NEG_INFINITY, f64::max);
        let mut w: Vec<f64> = self
            .slots
            .iter()
            .map(|s| {
                if all_quarantined || !s.health.quarantined {
                    (s.ln_z - max).exp()
                } else {
                    0.0
                }
            })
            .collect();
        let total: f64 = w.iter().sum();
        for v in &mut w {
            *v /= total;
        }
        w
    }

    /// Serve one batch of query points under the session's route mode.
    /// Quarantined slots are routed around: `Winner` serves the
    /// highest-ranked healthy slot, `Averaged` renormalises over the
    /// healthy roster.
    pub fn predict(&self, t_star: &[f64]) -> Prediction {
        self.predict_with(t_star, &self.exec)
    }

    /// [`ServeSession::predict`] under an explicit thread budget instead
    /// of the session's own. The fleet scheduler drains several sessions
    /// concurrently and hands each a [`ExecutionContext::split`] share so
    /// the drain never oversubscribes; results are bit-identical for any
    /// budget (the linalg contract).
    pub fn predict_with(&self, t_star: &[f64], exec: &ExecutionContext) -> Prediction {
        match self.route {
            RouteMode::Winner => {
                self.slots[self.first_healthy()].predictor.predict_batch(t_star, exec)
            }
            RouteMode::Averaged => self.predict_averaged(t_star, exec),
        }
    }

    /// Serve a specific roster member by name, regardless of route mode.
    pub fn predict_model(&self, name: &str, t_star: &[f64]) -> Option<Prediction> {
        self.slots
            .iter()
            .find(|s| s.spec.name() == name)
            .map(|s| s.predictor.predict_batch(t_star, &self.exec))
    }

    /// Routed model names, winner first.
    pub fn model_names(&self) -> Vec<&'static str> {
        self.slots.iter().map(|s| s.spec.name()).collect()
    }

    /// A specific roster member's live predictor (for invariant checks —
    /// e.g. the soak suite's windowed-factor-vs-cold-refit comparison).
    pub fn model_predictor(&self, name: &str) -> Option<&Predictor> {
        self.slots.iter().find(|s| s.spec.name() == name).map(|s| &s.predictor)
    }

    /// Evidence-weighted model averaging: mixture mean and mixture
    /// standard deviation across every slot. With a dominant winner
    /// (`ln B ≫ 1`) this degrades gracefully to the winner's prediction.
    fn predict_averaged(&self, t_star: &[f64], exec: &ExecutionContext) -> Prediction {
        self.average_with(t_star.len(), |slot| slot.predictor.predict_batch(t_star, exec))
    }

    /// The mixture arithmetic shared by the scalar and the nd averaged
    /// routes: `Σ wᵢ μᵢ` and `Σ wᵢ (σᵢ² + μᵢ²) − μ̄²` over the healthy
    /// roster, `q` query points, one slot prediction per weight.
    fn average_with<F: Fn(&ModelSlot) -> Prediction>(&self, q: usize, predict: F) -> Prediction {
        let w = self.weights();
        let mut mean = vec![0.0; q];
        let mut second = vec![0.0; q]; // Σ wᵢ (σᵢ² + μᵢ²)
        for (slot, &wi) in self.slots.iter().zip(&w) {
            if wi == 0.0 {
                continue; // quarantined: excluded from the mixture
            }
            let p = predict(slot);
            for i in 0..q {
                mean[i] += wi * p.mean[i];
                second[i] += wi * (p.sd[i] * p.sd[i] + p.mean[i] * p.mean[i]);
            }
        }
        let sd = mean
            .iter()
            .zip(&second)
            .map(|(m, s)| (s - m * m).max(0.0).sqrt())
            .collect();
        Prediction { mean, sd }
    }

    /// Serve one batch of d-dimensional query points (`x_star` is d
    /// columns, the [`Predictor::input_cols`] layout) under the
    /// session's route mode — the scenario-tier twin of
    /// [`ServeSession::predict`]. For a 1-D roster this delegates to the
    /// scalar predict path bit-identically.
    pub fn predict_rows(&self, x_star: &[&[f64]]) -> Prediction {
        self.predict_rows_with(x_star, &self.exec)
    }

    /// [`ServeSession::predict_rows`] under an explicit thread budget
    /// (see [`ServeSession::predict_with`]).
    pub fn predict_rows_with(&self, x_star: &[&[f64]], exec: &ExecutionContext) -> Prediction {
        match self.route {
            RouteMode::Winner => {
                self.slots[self.first_healthy()].predictor.predict_rows(x_star, exec)
            }
            RouteMode::Averaged => self.average_with(x_star.first().map_or(0, |c| c.len()), |slot| {
                slot.predictor.predict_rows(x_star, exec)
            }),
        }
    }

    /// Append one observation to **every** healthy live factor (`O(n²)`
    /// each): each model first scores the point and reports the pivot
    /// its factor extension would take
    /// ([`Predictor::log_predictive_and_pivot`]), and nothing mutates
    /// until the verdicts are in. Three outcomes:
    ///
    /// * every healthy model's pivot is viable — the point fans out to
    ///   all of them (the PR-5 all-or-nothing path, bit-identical on
    ///   clean data);
    /// * **no** healthy model can absorb it — the point itself is the
    ///   problem (e.g. an exact duplicate input), so the call errors
    ///   with **zero** state change rather than wrecking the roster;
    /// * *some* models fail while siblings absorb — that is a
    ///   slot-specific conditioning collapse, so the failing slots are
    ///   **quarantined** (frozen at their last good factor, routed
    ///   around, `needs_retrain` latched) and serving continues.
    ///
    /// Non-finite observations are rejected at the boundary before any
    /// scoring. Scores feed the per-model drift monitors only when the
    /// point is absorbed; quarantined slots neither score nor absorb.
    pub fn observe(&mut self, t_new: f64, y_new: f64) -> crate::Result<()> {
        {
            let p0 = &self.slots[0].predictor;
            anyhow::ensure!(
                p0.d() == 1 && p0.noise().is_none(),
                "scalar observe on an nd/heteroscedastic session — use observe_row"
            );
        }
        anyhow::ensure!(
            t_new.is_finite() && y_new.is_finite(),
            "non-finite observation (t = {t_new}, y = {y_new}) rejected at the data boundary"
        );
        let mut scored = Vec::with_capacity(self.slots.len());
        let mut absorbable = 0usize;
        for slot in &self.slots {
            if slot.health.quarantined {
                scored.push(None);
                continue;
            }
            let s = slot.predictor.score_observation(t_new, y_new);
            let viable = s.pivot > 0.0 && s.pivot.is_finite();
            absorbable += viable as usize;
            scored.push(Some((s, viable)));
        }
        anyhow::ensure!(
            absorbable > 0,
            "observe(t={t_new}) would make every healthy model's K̃ non-PD; \
             the point was rejected and no slot mutated"
        );
        for (slot, s) in self.slots.iter_mut().zip(scored) {
            match s {
                None => {} // quarantined: frozen
                Some((s, true)) => {
                    slot.drift.push(s.score);
                    // reuses the pivot check's triangular solve — one O(n²)
                    // solve per (point, model), and it cannot fail: the
                    // extension takes exactly the pre-checked pivot. The
                    // α/σ̂² refresh is deferred until after the window
                    // policy ran, so an absorb that immediately evicts
                    // pays it once, not twice.
                    slot.predictor.observe_scored_deferred(t_new, y_new, s)?;
                }
                Some((_, false)) => {
                    // siblings can take the point but this factor cannot:
                    // quarantine the slot instead of failing the session
                    slot.health.quarantined = true;
                }
            }
        }
        // refresh the deferred caches even when the window enforcement
        // errors (e.g. a failed periodic refit), so the session keeps
        // serving a consistent α for whatever factors it now holds; a
        // completed cold refresh already installed fresh caches
        match self.enforce_window() {
            Ok(true) => Ok(()),
            other => {
                for slot in &mut self.slots {
                    slot.predictor.refresh_cache();
                }
                other.map(|_| ())
            }
        }
    }

    /// [`ServeSession::observe`] for a d-dimensional observation row,
    /// with an optional per-point noise level — the scenario-tier
    /// streaming path. The noise contract follows
    /// [`Predictor::observe_row`]: a heteroscedastic roster requires
    /// `Some(σ_n,new)`, a homoscedastic one requires `None`. Same
    /// all-or-nothing fan-out, drift scoring and quarantine semantics as
    /// the scalar path.
    pub fn observe_row(
        &mut self,
        x_new: &[f64],
        y_new: f64,
        sigma_n_new: Option<f64>,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            x_new.iter().all(|v| v.is_finite())
                && y_new.is_finite()
                && sigma_n_new.map_or(true, |s| s.is_finite() && s >= 0.0),
            "non-finite observation row (x = {x_new:?}, y = {y_new}, σ_n = {sigma_n_new:?}) \
             rejected at the data boundary"
        );
        let mut scored = Vec::with_capacity(self.slots.len());
        let mut absorbable = 0usize;
        for slot in &self.slots {
            if slot.health.quarantined {
                scored.push(None);
                continue;
            }
            // dimension/noise-contract violations are caller errors, not
            // factor failures: propagate before anything mutates
            let s = slot.predictor.score_observation_row(x_new, y_new, sigma_n_new)?;
            let viable = s.pivot > 0.0 && s.pivot.is_finite();
            absorbable += viable as usize;
            scored.push(Some((s, viable)));
        }
        anyhow::ensure!(
            absorbable > 0,
            "observe_row(x={x_new:?}) would make every healthy model's K̃ non-PD; \
             the point was rejected and no slot mutated"
        );
        for (slot, s) in self.slots.iter_mut().zip(scored) {
            match s {
                None => {} // quarantined: frozen
                Some((s, true)) => {
                    slot.drift.push(s.score);
                    slot.predictor.observe_scored_row_deferred(x_new, y_new, sigma_n_new, s)?;
                }
                Some((_, false)) => {
                    slot.health.quarantined = true;
                }
            }
        }
        match self.enforce_window() {
            Ok(true) => Ok(()),
            other => {
                for slot in &mut self.slots {
                    slot.predictor.refresh_cache();
                }
                other.map(|_| ())
            }
        }
    }

    /// Apply the window policy after an absorption: evict everything
    /// over capacity from every healthy slot in one oldest-first bulk
    /// shrink (one `O(n²)` storage copy regardless of how far over
    /// capacity the window is, e.g. after attaching a small window to a
    /// large restored session), then run the periodic cold refresh when
    /// due. A slot whose shrink fails counts a **downdate failure** and
    /// is repaired by a cold refit-and-retry; if even that fails it is
    /// quarantined, so the healthy roster always stays in lockstep.
    /// Returns whether a cold refresh ran (in which case every healthy
    /// slot's serving cache is already fresh and the caller must not
    /// redo the `O(n²)` refresh).
    fn enforce_window(&mut self) -> crate::Result<bool> {
        let Some(policy) = self.window else { return Ok(false) };
        let n = self.slots[self.first_healthy()].predictor.n();
        if n > policy.max_points {
            let k = n - policy.max_points;
            for slot in &mut self.slots {
                if slot.health.quarantined {
                    continue;
                }
                if slot.predictor.evict_front_deferred(k).is_err() {
                    slot.health.downdate_failures += 1;
                    // repair: wash the factor with a cold refit of the
                    // pre-shrink window, then retry the shrink once
                    let repaired = slot
                        .predictor
                        .refit_eval(&self.exec)
                        .map(|ev| slot.predictor.adopt_eval(ev))
                        .and_then(|()| slot.predictor.evict_front_deferred(k));
                    if repaired.is_err() {
                        slot.health.quarantined = true;
                    }
                }
            }
            self.evictions += k;
            self.since_refresh += k;
        }
        if policy.refresh_every > 0 && self.since_refresh >= policy.refresh_every {
            self.refresh_factors()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Refactorise every **healthy** slot cold from the live window at
    /// its current ϑ̂: the `O(n³)` evaluations are computed first
    /// ([`Predictor::refit_eval`]) and only committed per slot on
    /// success ([`Predictor::adopt_eval`]), so a failed refit never
    /// leaves a half-updated factor — the failing slot keeps its old
    /// factor and is **quarantined**. Each refreshed factor's spectral
    /// conditioning is re-probed ([`crate::linalg::Chol::cond_1est`])
    /// and compared against the session's condition limit, latching
    /// **degraded** on a crossing. Resets the periodic-refresh
    /// countdown. Errors only when the refresh leaves **no** healthy
    /// slot.
    pub fn refresh_factors(&mut self) -> crate::Result<()> {
        let limit = self.cond_limit;
        let evals: Vec<_> = self
            .slots
            .iter()
            .map(|s| (!s.health.quarantined).then(|| s.predictor.refit_eval(&self.exec)))
            .collect();
        for (slot, ev) in self.slots.iter_mut().zip(evals) {
            match ev {
                None => {} // quarantined: frozen
                Some(Ok(ev)) => {
                    slot.predictor.adopt_eval(ev);
                    slot.health.refreshes += 1;
                    slot.health.cond_est = slot.predictor.chol().cond_1est();
                    if slot.health.cond_est > limit {
                        slot.health.degraded = true;
                    }
                }
                Some(Err(_)) => {
                    // the live window no longer factorises for this model
                    // even through the jitter ladder: freeze and reroute
                    slot.health.quarantined = true;
                }
            }
        }
        self.refreshes += 1;
        self.since_refresh = 0;
        anyhow::ensure!(
            self.slots.iter().any(|s| !s.health.quarantined),
            "cold refresh failed for every routed model; the whole roster is quarantined \
             and serving continues from frozen factors — retrain required"
        );
        Ok(())
    }

    /// Retrain **in place** on the current window — the self-healing
    /// answer to a latched [`ServeSession::needs_retrain`]. Every slot's
    /// spec is retrained on the live window data (multistart plus one
    /// deterministic warm start at the incumbent ϑ̂, so a still-good peak
    /// is never lost), its Laplace evidence recomputed, and then — only
    /// after every model trained successfully — all router slots, the
    /// evidence ranking and the drift baselines are **hot-swapped**
    /// atomically: an error leaves the old session fully serviceable,
    /// and on success serving continues without dropping the session
    /// (lifetime counters carry over). The σ_f prior for the evidence is
    /// the session's ([`ServeSession::with_scale_prior`]; defaults to
    /// the config pipeline's [`ScalePrior::default`]).
    ///
    /// This is also the **quarantine re-entry point**: the window is
    /// taken from the highest-ranked *healthy* slot (a quarantined
    /// winner's frozen window is stale), every spec — quarantined or
    /// not — is retrained on it, and a successful hot-swap clears all
    /// quarantine and degradation latches (lifetime health counters
    /// carry over).
    pub fn retrain(
        &mut self,
        opts: &TrainOptions,
        workers: usize,
        rng: &mut Xoshiro256,
    ) -> crate::Result<RetrainOutcome> {
        // prefer an exact slot's window: approximate slots serve reduced
        // datasets (a stride subset, or FITC pseudo-targets that are not
        // real observations), so an exact window is the ground truth
        // whenever one is healthy
        let lead = self
            .slots
            .iter()
            .position(|s| !s.health.quarantined && s.spec.approx().is_none())
            .unwrap_or_else(|| self.first_healthy());
        let mut window = Dataset::new(
            self.slots[lead].predictor.t().to_vec(),
            self.slots[lead].predictor.y().to_vec(),
            "serve-window",
        );
        if self.slots[lead].predictor.d() > 1 {
            window = window.with_extra_cols(self.slots[lead].predictor.extra().to_vec())?;
        }
        if let Some(s) = self.slots[lead].predictor.noise() {
            window = window.with_noise(s.to_vec())?;
        }
        // a degenerate window (e.g. duplicate timestamps absorbed under a
        // tiny window policy) is a recoverable error, not a panic: the
        // old session stays fully serviceable
        let span = window.span()?;
        let scale = self.scale_prior;
        // train every slot first; nothing is swapped until all succeed
        let mut rebuilt: Vec<(ModelSlot, f64)> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let spec = slot.spec.clone();
            let model = spec.build(self.sigma_n);
            let prior = BoxPrior::for_model(&model, &span);
            let mut o = opts.clone();
            let mut incumbent = slot.predictor.theta().to_vec();
            prior.project(&mut incumbent);
            o.extra_starts.push(incumbent);
            let trained =
                train_model(&spec, self.sigma_n, &window, &o, workers, &self.exec, rng)?;
            // same evidence routing as the tournament: n-scale surrogate
            // + FD Hessian for approximate specs, analytic for exact
            let (lnp_evidence, hessian) = match spec.approx() {
                None => (
                    trained.lnp_peak,
                    crate::gp::profiled_hessian_nd_with(
                        &model,
                        &window.input_cols(),
                        window.noise.as_deref(),
                        &window.y,
                        &trained.theta_hat,
                        &self.exec,
                    )?,
                ),
                Some(kind) => (
                    crate::gp::approx::lnp_evidence_with(
                        kind,
                        &model,
                        &window.t,
                        &window.y,
                        &trained.theta_hat,
                        &self.exec,
                    )?,
                    crate::gp::approx::evidence_hessian_with(
                        kind,
                        &model,
                        &window.t,
                        &window.y,
                        &trained.theta_hat,
                        &self.exec,
                    )?,
                ),
            };
            let evidence = laplace_evidence(
                window.len(),
                &prior,
                &scale,
                &trained.theta_hat,
                lnp_evidence,
                &hessian,
            )?;
            let predictor = if window.d() > 1 || window.is_heteroscedastic() {
                // train_model already rejected approximate specs for
                // nd/heteroscedastic windows, so this is the exact path
                Predictor::from_eval_nd(
                    spec.build(self.sigma_n),
                    window.t.clone(),
                    window.extra.clone(),
                    window.noise.clone(),
                    window.y.clone(),
                    trained.theta_hat.clone(),
                    trained.peak_eval,
                )
            } else {
                let (t_serve, y_serve) = match spec.approx() {
                    None => (window.t.clone(), window.y.clone()),
                    Some(kind) => crate::gp::approx::serve_parts(
                        kind,
                        &window.t,
                        &window.y,
                        &trained.peak_eval,
                    ),
                };
                Predictor::from_eval(
                    spec.build(self.sigma_n),
                    t_serve,
                    y_serve,
                    trained.theta_hat.clone(),
                    trained.peak_eval,
                )
            };
            predictor.carry_counters_from(&slot.predictor);
            // fresh factor ⇒ fresh conditioning probe; quarantine and
            // degradation clear (re-entry), lifetime counters carry over
            let cond_est = predictor.chol().cond_1est();
            let health = SlotHealth {
                cond_est,
                downdate_failures: slot.health.downdate_failures,
                refreshes: slot.health.refreshes,
                degraded: cond_est > self.cond_limit,
                quarantined: false,
            };
            let new_slot = ModelSlot {
                spec,
                predictor,
                ln_z: evidence.ln_z,
                drift: DriftMonitor::new(self.drift_opts),
                health,
            };
            rebuilt.push((new_slot, slot.ln_z));
        }
        // hot swap: new slots, new ranking, fresh drift baselines
        let old_winner = self.slots[0].spec.name().to_string();
        rebuilt.sort_by(|a, b| crate::util::desc_nan_last(a.0.ln_z, b.0.ln_z));
        let models: Vec<(String, f64, f64)> = rebuilt
            .iter()
            .map(|(s, old_ln_z)| (s.spec.name().to_string(), *old_ln_z, s.ln_z))
            .collect();
        self.slots = rebuilt.into_iter().map(|(s, _)| s).collect();
        self.since_refresh = 0;
        self.refreshes += 1;
        let winner = self.slots[0].spec.name().to_string();
        Ok(RetrainOutcome {
            window_n: window.len(),
            models,
            winner_changed: winner != old_winner,
            winner,
        })
    }

    /// Append a batch of observations **point by point**: each point is
    /// scored against factors that have already absorbed every earlier
    /// point (drift scores are independent of how the caller chunks the
    /// stream), then fanned out atomically like [`ServeSession::observe`].
    /// On a mid-batch failure the already-absorbed prefix is kept — by
    /// every model consistently — and the error propagates.
    pub fn observe_batch(&mut self, t_new: &[f64], y_new: &[f64]) -> crate::Result<()> {
        anyhow::ensure!(t_new.len() == y_new.len(), "t/y batch length mismatch");
        for (&tn, &yn) in t_new.iter().zip(y_new) {
            self.observe(tn, yn)?;
        }
        Ok(())
    }

    /// Serving counters of the **winner** slot (the factor every default
    /// query goes through). Numerical-health state lives in
    /// [`ServeSession::health`] — `ServeStats` is an exact-comparison
    /// (`Eq`) counter record and cannot carry condition estimates.
    pub fn stats(&self) -> ServeStats {
        self.slots[0].predictor.stats()
    }

    /// Per-slot numerical health, winner first: latest condition
    /// estimate, training-time jitter, downdate-failure and refresh
    /// counters, and the degraded/quarantined latches.
    pub fn health(&self) -> Vec<FactorHealth> {
        self.slots
            .iter()
            .map(|s| FactorHealth {
                model: s.spec.name(),
                cond_est: s.health.cond_est,
                jitter: s.predictor.jitter(),
                downdate_failures: s.health.downdate_failures,
                refreshes: s.health.refreshes,
                degraded: s.health.degraded,
                quarantined: s.health.quarantined,
            })
            .collect()
    }

    /// Number of currently quarantined slots.
    pub fn n_quarantined(&self) -> usize {
        self.slots.iter().filter(|s| s.health.quarantined).count()
    }

    /// Manually quarantine a routed model (operator override — e.g. a
    /// model known to be misbehaving for reasons the automatic latches
    /// cannot see yet). The slot freezes at its current factor and is
    /// routed around exactly like an automatic quarantine; a successful
    /// [`ServeSession::retrain`] re-enters it. Returns false when no
    /// routed model has that name.
    pub fn quarantine_model(&mut self, name: &str) -> bool {
        match self.slots.iter_mut().find(|s| s.spec.name() == name) {
            Some(slot) => {
                slot.health.quarantined = true;
                true
            }
            None => false,
        }
    }

    /// The winner's predictor (e.g. for `lnp()`/`sigma_f_hat2()`).
    pub fn predictor(&self) -> &Predictor {
        &self.slots[0].predictor
    }

    /// Per-model drift status, winner first.
    pub fn drift(&self) -> Vec<DriftStatus> {
        self.slots
            .iter()
            .map(|s| DriftStatus {
                model: s.spec.name().to_string(),
                baseline: s.drift.baseline(),
                recent: s.drift.recent_mean(),
                deficit: s.drift.deficit(),
                drifted: s.drift.drifted,
            })
            .collect()
    }

    /// True when any routed model's appended-point log-score has
    /// degraded past the drift threshold, **or** a factor-health latch
    /// fired (conditioning past the limit, or a quarantined slot
    /// waiting for re-entry) — the signal to rerun training on the
    /// accumulated data ([`ServeSession::retrain`]).
    pub fn needs_retrain(&self) -> bool {
        self.slots
            .iter()
            .any(|s| s.drift.drifted || s.health.degraded || s.health.quarantined)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::table1_dataset;
    use crate::optimize::MultistartOptions;

    #[test]
    fn train_and_serve_round_trip() {
        let data = table1_dataset(40, 0.1, 23);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(29);
        let (mut session, trained) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        assert!(trained.lnp_peak.is_finite());
        let pred = session.predict(&[5.5, 20.25]);
        assert_eq!(pred.mean.len(), 2);
        assert!(pred.sd.iter().all(|s| s.is_finite() && *s >= 0.0));
        // stream two points and serve again — n grows, queries accumulate
        session.observe_batch(&[41.0, 42.0], &[0.1, -0.2]).unwrap();
        let s = session.stats();
        assert_eq!(s.n_train, 42);
        assert_eq!(s.observations_appended, 2);
        let pred2 = session.predict(&[41.5]);
        assert_eq!(s.queries_served + 1, session.stats().queries_served);
        assert!(pred2.mean[0].is_finite());
        assert!(!session.needs_retrain(), "two in-distribution points must not flag");
    }

    #[test]
    fn from_training_uses_trained_theta() {
        let data = table1_dataset(30, 0.1, 31);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(37);
        let exec = ExecutionContext::seq();
        let trained =
            train_model(&ModelSpec::K1, 0.1, &data, &opts, 1, &exec, &mut rng).unwrap();
        let session =
            ServeSession::from_training(&ModelSpec::K1, 0.1, &data, &trained, exec).unwrap();
        assert_eq!(session.predictor().theta(), trained.theta_hat.as_slice());
        assert_eq!(session.stats().n_train, 30);
        assert_eq!(session.n_models(), 1);
        assert_eq!(session.spec(), &ModelSpec::K1);
        assert_eq!(session.weights(), vec![1.0]);
    }

    #[test]
    fn window_policy_bounds_memory_and_refreshes_periodically() {
        let data = table1_dataset(30, 0.1, 41);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(43);
        let (mut session, _) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        session = session.with_window(WindowPolicy { max_points: 32, refresh_every: 4 });
        assert_eq!(
            session.window(),
            Some(WindowPolicy { max_points: 32, refresh_every: 4 })
        );
        // stream 10 points: n grows to 32 then slides; 8 evictions, and
        // the cold refresh fires at evictions 4 and 8
        for i in 0..10 {
            session.observe(31.0 + i as f64, 0.05 * i as f64).unwrap();
            assert!(session.stats().n_train <= 32, "window exceeded at i={i}");
        }
        assert_eq!(session.stats().n_train, 32);
        assert_eq!(session.evictions(), 8);
        assert_eq!(session.refreshes(), 2);
        let s = session.stats();
        assert_eq!(s.observations_appended, 10);
        assert_eq!(s.observations_evicted, 8);
        // the oldest points are gone, the newest are present
        let p = session.predictor();
        assert_eq!(p.t()[0], data.t[8]);
        assert_eq!(*p.t().last().unwrap(), 40.0);
        let q = session.predict(&[40.5]);
        assert!(q.mean[0].is_finite() && q.sd[0].is_finite());
    }

    #[test]
    fn retrain_in_place_hot_swaps_and_preserves_counters() {
        let data = table1_dataset(30, 0.1, 47);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(53);
        let (mut session, trained) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        let _ = session.predict(&[3.5, 7.5]);
        session.observe(31.0, 0.1).unwrap();
        let before = session.stats();
        let lnp_before = session.predictor().lnp();
        let outcome = session.retrain(&opts, 1, &mut rng).unwrap();
        assert_eq!(outcome.window_n, 31);
        assert_eq!(outcome.models.len(), 1);
        assert_eq!(outcome.winner, "k1");
        assert!(!outcome.winner_changed);
        assert!(outcome.models[0].2.is_finite());
        // the session kept its lifetime counters and its data…
        let after = session.stats();
        assert_eq!(after.n_train, 31);
        assert_eq!(after.queries_served, before.queries_served);
        assert_eq!(after.observations_appended, before.observations_appended);
        // …serves from the new peak: the retrain warm-starts from the
        // incumbent ϑ̂, so on the same window it can only match or beat it
        let _ = trained;
        assert!(session.predictor().lnp().is_finite());
        assert!(
            session.predictor().lnp() >= lnp_before - 1e-6 * lnp_before.abs().max(1.0),
            "retrained peak regressed: {} vs incumbent {}",
            session.predictor().lnp(),
            lnp_before
        );
        // …and the drift baselines were reset
        for d in session.drift() {
            assert!(d.baseline.is_none() && !d.drifted);
        }
        assert!(!session.needs_retrain());
        let q = session.predict(&[31.5]);
        assert!(q.mean[0].is_finite());
    }

    #[test]
    fn nd_session_routes_rows_and_retrains_with_extras_and_noise() {
        // the scenario tier through the router: a d = 3 heteroscedastic
        // roster must stream via the row API, reject the scalar API, and
        // retrain from a window that still carries its extra columns and
        // noise vector
        let data = crate::data::synthetic::ard3_dataset(22, 0.1, true, 31);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut cfg = crate::coordinator::PipelineConfig::fast();
        cfg.models = vec![ModelSpec::SeArd(3)];
        cfg.train = opts.clone();
        let mut rng = Xoshiro256::seed_from_u64(41);
        let result = crate::coordinator::Tournament::new(cfg.clone())
            .run(&data, &mut rng)
            .unwrap();
        let mut session =
            ServeSession::from_tournament(&result.models, &data, ExecutionContext::seq())
                .unwrap();
        // scalar APIs are rejected up front, with zero state change
        let n0 = session.stats().n_train;
        assert!(session.observe(23.0, 0.1).is_err());
        assert_eq!(session.stats().n_train, n0);
        // the noise contract propagates: a hetero roster needs Some(σ)
        assert!(session.observe_row(&[23.0, 1.0, 2.0], 0.1, None).is_err());
        session.observe_row(&[23.0, 1.0, 2.0], 0.1, Some(0.12)).unwrap();
        session.observe_row(&[24.0, 4.0, 0.5], -0.2, Some(0.08)).unwrap();
        assert_eq!(session.stats().n_train, 24);
        let q1 = [5.5, 23.5];
        let q2 = [2.0, 1.0];
        let q3 = [1.0, 2.0];
        let pred = session.predict_rows(&[&q1, &q2, &q3]);
        assert!(pred.mean.iter().chain(&pred.sd).all(|v| v.is_finite()));
        // retrain rebuilds from the nd window: extras and noise survive
        let outcome = session.retrain(&opts, 1, &mut rng).unwrap();
        assert_eq!(outcome.window_n, 24);
        let p = session.predictor();
        assert_eq!(p.d(), 3);
        assert_eq!(p.noise().map(|s| s.len()), Some(24));
        let pred2 = session.predict_rows(&[&q1, &q2, &q3]);
        assert!(pred2.mean.iter().chain(&pred2.sd).all(|v| v.is_finite()));
    }

    #[test]
    fn drift_monitor_fires_on_sustained_deficit_and_not_on_noise() {
        let opts = DriftOptions { window: 4, threshold: 1.0 };
        let mut m = DriftMonitor::new(opts);
        // baseline window: scores around −1
        for s in [-1.0, -1.1, -0.9, -1.0] {
            m.push(s);
        }
        assert!((m.baseline().expect("baseline full") + 1.0).abs() < 1e-12);
        // comparable recent window: no flag
        for s in [-1.2, -0.8, -1.0, -1.0] {
            m.push(s);
        }
        assert!(!m.drifted, "in-noise scores must not latch drift");
        // degraded scores: deficit 3 nats > threshold 1 → latch
        for s in [-4.0, -4.0, -4.0, -4.0] {
            m.push(s);
        }
        assert!(m.drifted);
        assert!(m.deficit() > 1.0);
        // recovery does not unlatch (the flag is a retrain signal)
        for s in [-1.0; 8] {
            m.push(s);
        }
        assert!(m.drifted);
        // non-finite scores are ignored outright
        let mut m2 = DriftMonitor::new(opts);
        m2.push(f64::NAN);
        assert_eq!(m2.baseline_n, 0);
        // a window of 0 is clamped to 1 instead of panicking on push
        let mut m3 = DriftMonitor::new(DriftOptions { window: 0, threshold: 1.0 });
        m3.push(-1.0);
        m3.push(-1.0);
        m3.push(-5.0);
        assert!(m3.drifted, "1-point window must still detect the collapse");
    }

    #[test]
    fn health_reports_and_quarantine_reroutes_then_reenters() {
        let data = table1_dataset(30, 0.1, 59);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(61);
        let (mut session, _) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        // clean training: health probed at construction, no latches, no
        // jitter ladder rungs
        let h = &session.health()[0];
        assert_eq!(h.model, "k1");
        assert!(h.cond_est.is_finite() && h.cond_est >= 1.0, "cond est {}", h.cond_est);
        assert_eq!(h.jitter, 0.0, "clean data must take zero ladder rungs");
        assert_eq!(h.downdate_failures, 0);
        assert!(!h.degraded && !h.quarantined);
        assert!(!session.needs_retrain());
        // force-quarantine the lone slot: routing falls back to the
        // frozen factor (finite predictions), weights fall back to
        // evidence weighting, the retrain latch fires, observes freeze
        session.slots[0].health.quarantined = true;
        assert_eq!(session.n_quarantined(), 1);
        assert!(session.needs_retrain());
        assert_eq!(session.weights(), vec![1.0]);
        let q = session.predict(&[5.5]);
        assert!(q.mean[0].is_finite() && q.sd[0].is_finite());
        let n_before = session.stats().n_train;
        assert!(session.observe(31.0, 0.1).is_err(), "no healthy slot can absorb");
        assert_eq!(session.stats().n_train, n_before, "quarantined slot must stay frozen");
        // retrain re-enters the slot and clears every latch
        let outcome = session.retrain(&opts, 1, &mut rng).unwrap();
        assert_eq!(outcome.window_n, 30);
        assert_eq!(session.n_quarantined(), 0);
        assert!(!session.needs_retrain());
        session.observe(31.0, 0.1).unwrap();
        assert_eq!(session.stats().n_train, 31);
    }

    #[test]
    fn cond_limit_latches_degraded_and_retrain_is_flagged() {
        let data = table1_dataset(25, 0.1, 67);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(71);
        let (session, _) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        let cond = session.health()[0].cond_est;
        assert!(cond > 1.0, "a real K̃ is never perfectly conditioned (got {cond})");
        // a limit just below the measured estimate must latch; a huge
        // one must not; garbage limits fall back to the default
        let session = session.with_cond_limit((cond * 0.5).max(1.0 + 1e-9));
        assert!(session.health()[0].degraded);
        assert!(session.needs_retrain());
        let session = session.with_cond_limit(cond * 1e6);
        assert!(!session.health()[0].degraded);
        assert!(!session.needs_retrain());
        let session = session.with_cond_limit(f64::NAN);
        assert_eq!(session.cond_limit, COND_RETRAIN_LIMIT);
    }
}
