//! Coordinator glue for the serving layer: a **multi-model router** over
//! cached [`Predictor`]s, fed by the tournament's [`TrainedModel`]
//! artifacts.
//!
//! [`ServeSession`] owns one live predictor per tournament entrant,
//! ranked by Laplace evidence:
//!
//! * **Routing** — queries go to the evidence winner by default
//!   ([`RouteMode::Winner`]; a single-model session is bit-identical to
//!   serving that model directly), or to the whole roster under
//!   **evidence-weighted model averaging** ([`RouteMode::Averaged`]):
//!   posterior-probability weights `w_i ∝ exp(ln Z_i)`, mixture mean
//!   `Σ w_i μ_i` and mixture variance `Σ w_i (σ_i² + μ_i²) − μ̄²`.
//! * **Streaming** — [`ServeSession::observe`] /
//!   [`ServeSession::observe_batch`] fan every arriving observation out
//!   to **all** live factors (each an `O(n²)` extension), so the ranking
//!   can be revisited and the router switched without retraining. The
//!   fan-out is all-or-nothing per point: every model's extension pivot
//!   is checked before any factor mutates, so the slots always hold the
//!   same data.
//! * **Drift** — before a point is absorbed, each model scores it with
//!   its log predictive density ([`Predictor::log_predictive`]); a
//!   per-model [`DriftMonitor`] compares the recent windowed mean
//!   log-score against the baseline established when streaming began and
//!   **flags retraining** when the score has degraded past a threshold
//!   ([`ServeSession::needs_retrain`]). Hyperparameters are frozen at
//!   ϑ̂ between retrains, so a sustained log-score deficit is exactly the
//!   signature of hyperparameter drift.
//!
//! ## Serving lifecycle: grow → evict → refresh → retrain
//!
//! With a [`WindowPolicy`] attached ([`ServeSession::with_window`]) the
//! session is **self-healing and bounded-memory**:
//!
//! * **grow** — every absorbed point extends all factors in `O(n²)`;
//! * **evict** — past `max_points` the oldest observation is deleted
//!   from every slot ([`Predictor::evict`], an `O(n²)` rank-1 restore on
//!   the trailing block), so no factor ever exceeds the window — the
//!   sliding-window accuracy-for-cost trade of Chalupka et al. and of
//!   subset-based GPR;
//! * **refresh** — every `refresh_every` evictions all factors are
//!   refactorised cold from the live window (compute-then-commit, so the
//!   refresh is all-or-nothing across slots), washing out accumulated
//!   `O(n²)`-maintenance rounding drift;
//! * **retrain** — when the drift monitor latches,
//!   [`ServeSession::retrain`] reruns training on the current window
//!   (every model warm-started from its incumbent ϑ̂), recomputes each
//!   Laplace evidence, and **hot-swaps** all slots, the evidence ranking
//!   and the drift baselines without dropping the session: counters
//!   carry over and queries keep being served from the new peaks.
//!
//! Constructed from a finished tournament
//! ([`ServeSession::from_tournament`]), from a single training run
//! ([`ServeSession::from_training`]), by training in place
//! ([`ServeSession::train_and_serve`]), or — the `O(n²)` restart path —
//! from persisted artifacts on disk ([`ServeSession::from_artifacts`],
//! reading [`TrainedModel::save`] files with zero likelihood
//! evaluations).

use std::path::Path;

use crate::data::Dataset;
use crate::evidence::laplace_evidence;
use crate::gp::predict::Prediction;
use crate::gp::serve::{Predictor, ServeStats};
use crate::priors::{BoxPrior, ScalePrior};
use crate::rng::Xoshiro256;
use crate::runtime::ExecutionContext;

use super::registry::ModelSpec;
use super::tournament::TrainedModel;
use super::train::{train_model, TrainOptions, TrainResult};

/// How the session answers a predict call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouteMode {
    /// Serve the evidence winner only (the default; bit-identical to a
    /// single-model session).
    #[default]
    Winner,
    /// Evidence-weighted model averaging across the whole roster.
    Averaged,
}

/// Bounded-memory sliding-window policy (see the module docs'
/// lifecycle section).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowPolicy {
    /// Hard cap on the points behind every cached factor: observations
    /// past this evict the oldest point from all slots. Clamped to ≥ 2
    /// by [`ServeSession::with_window`] (a factor must keep at least one
    /// point and be able to absorb the next).
    pub max_points: usize,
    /// Refactorise every slot cold from the live window after this many
    /// evictions, washing out accumulated rank-1 rounding drift
    /// (`0` = never refresh).
    pub refresh_every: usize,
}

/// What [`ServeSession::retrain`] did, per model in the new rank order.
#[derive(Clone, Debug)]
pub struct RetrainOutcome {
    /// Points in the window the retrain was fitted on.
    pub window_n: usize,
    /// `(model name, previous ln Z, new ln Z)`, new-rank order (winner
    /// first).
    pub models: Vec<(String, f64, f64)>,
    /// The new evidence winner.
    pub winner: String,
    /// Did the retrain change which model serves by default?
    pub winner_changed: bool,
}

/// Drift-monitor tuning.
#[derive(Clone, Copy, Debug)]
pub struct DriftOptions {
    /// Points in the baseline and in the rolling comparison window.
    pub window: usize,
    /// Flag when `baseline − recent` mean log-score exceeds this (nats
    /// per point).
    pub threshold: f64,
}

impl Default for DriftOptions {
    fn default() -> Self {
        // a sustained 2-nat per-point deficit corresponds to the data
        // sitting ~2σ from the predictive mean on average — far outside
        // streaming noise, a clear retrain signal
        Self { window: 16, threshold: 2.0 }
    }
}

/// One model's drift state, reported by [`ServeSession::drift`].
#[derive(Clone, Debug)]
pub struct DriftStatus {
    pub model: String,
    /// Mean log-score over the baseline window (`None` until filled).
    pub baseline: Option<f64>,
    /// Mean log-score over the most recent window (`None` until filled).
    pub recent: Option<f64>,
    /// `baseline − recent` when both windows are full, else 0.
    pub deficit: f64,
    /// Latched true once the deficit crossed the threshold.
    pub drifted: bool,
}

/// Windowed log-score drift detector (see the module docs). Scores are
/// pushed *before* the point is absorbed, so each one is a genuine
/// out-of-sample log predictive density.
#[derive(Clone, Debug)]
struct DriftMonitor {
    opts: DriftOptions,
    /// Sum and count of the first `window` scores.
    baseline_sum: f64,
    baseline_n: usize,
    /// Ring buffer of the most recent `window` scores (after baseline).
    recent: Vec<f64>,
    next: usize,
    filled: bool,
    drifted: bool,
}

impl DriftMonitor {
    fn new(mut opts: DriftOptions) -> Self {
        // a zero-point window would index an empty ring on the first
        // push; one point is the smallest meaningful window
        opts.window = opts.window.max(1);
        Self {
            opts,
            baseline_sum: 0.0,
            baseline_n: 0,
            recent: Vec::new(),
            next: 0,
            filled: false,
            drifted: false,
        }
    }

    fn push(&mut self, score: f64) {
        if !score.is_finite() {
            return;
        }
        if self.baseline_n < self.opts.window {
            self.baseline_sum += score;
            self.baseline_n += 1;
            return;
        }
        if self.recent.len() < self.opts.window {
            self.recent.push(score);
            self.filled = self.recent.len() == self.opts.window;
        } else {
            self.recent[self.next] = score;
            self.next = (self.next + 1) % self.opts.window;
        }
        if self.filled && self.deficit() > self.opts.threshold {
            self.drifted = true;
        }
    }

    fn baseline(&self) -> Option<f64> {
        (self.baseline_n == self.opts.window)
            .then(|| self.baseline_sum / self.baseline_n as f64)
    }

    fn recent_mean(&self) -> Option<f64> {
        self.filled
            .then(|| self.recent.iter().sum::<f64>() / self.recent.len() as f64)
    }

    fn deficit(&self) -> f64 {
        match (self.baseline(), self.recent_mean()) {
            (Some(b), Some(r)) => b - r,
            _ => 0.0,
        }
    }
}

/// One routed model: spec, cached predictor, ranking evidence, drift
/// state.
struct ModelSlot {
    spec: ModelSpec,
    predictor: Predictor,
    ln_z: f64,
    drift: DriftMonitor,
}

/// A live serving session routing over `N` trained models — see the
/// module docs. Slot 0 is always the evidence winner.
pub struct ServeSession {
    slots: Vec<ModelSlot>,
    route: RouteMode,
    exec: ExecutionContext,
    /// Fixed noise level the slots were trained with (needed to rebuild
    /// models on retrain).
    sigma_n: f64,
    /// σ_f prior for retrain-time evidence (must match the prior the
    /// incumbent ln Z values were computed with, or old-vs-new deltas
    /// pick up a spurious prior-volume offset). Defaults to
    /// [`ScalePrior::default`], the config pipeline's choice; override
    /// with [`ServeSession::with_scale_prior`].
    scale_prior: ScalePrior,
    /// Drift tuning applied to every (re)created monitor.
    drift_opts: DriftOptions,
    window: Option<WindowPolicy>,
    /// Evictions since the last cold refresh (drives `refresh_every`).
    since_refresh: usize,
    /// Lifetime window-eviction rounds (each round drops one point from
    /// every slot).
    evictions: usize,
    /// Lifetime cold refreshes (periodic + retrain hot-swaps).
    refreshes: usize,
}

impl ServeSession {
    /// Build the router from a finished tournament: every artifact's
    /// peak factor is **adopted** (an `O(n²)` copy each, no re-assembly,
    /// no `O(n³)` refactorisation) and the slots are ranked by ln Z —
    /// the winner serves by default. `models` is expected ranked (as
    /// [`super::tournament::TournamentResult::models`] is); the session
    /// re-ranks defensively.
    pub fn from_tournament(
        models: &[TrainedModel],
        data: &Dataset,
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!models.is_empty(), "no trained models to serve");
        let mut slots = Vec::with_capacity(models.len());
        for tm in models {
            anyhow::ensure!(
                tm.sigma_n == models[0].sigma_n,
                "roster noise levels disagree: {} vs {}",
                tm.sigma_n,
                models[0].sigma_n
            );
            slots.push(ModelSlot {
                spec: tm.spec.clone(),
                predictor: tm.predictor(data)?,
                ln_z: tm.ln_z(),
                drift: DriftMonitor::new(DriftOptions::default()),
            });
        }
        slots.sort_by(|a, b| b.ln_z.partial_cmp(&a.ln_z).unwrap_or(std::cmp::Ordering::Equal));
        Ok(Self {
            slots,
            route: RouteMode::Winner,
            exec,
            sigma_n: models[0].sigma_n,
            scale_prior: ScalePrior::default(),
            drift_opts: DriftOptions::default(),
            window: None,
            since_refresh: 0,
            evictions: 0,
            refreshes: 0,
        })
    }

    /// Restart a serving process from persisted [`TrainedModel`]
    /// artifacts ([`TrainedModel::save`] files) — the `O(n²)` path: every
    /// factor is read back bit-identically from disk, so the session
    /// reaches its first prediction with **zero** likelihood evaluations
    /// (asserted via [`crate::gp::profiled::eval_count`] in the
    /// persistence suite). All artifacts must have been trained on the
    /// same dataset; the roster is re-ranked by the stored evidence.
    pub fn from_artifacts<P: AsRef<Path>>(
        paths: &[P],
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!paths.is_empty(), "no artifact paths given");
        let mut models = Vec::with_capacity(paths.len());
        let mut data: Option<Dataset> = None;
        for p in paths {
            let (tm, d) = TrainedModel::load(p.as_ref())?;
            match &data {
                None => data = Some(d),
                Some(d0) => anyhow::ensure!(
                    d0.t == d.t && d0.y == d.y,
                    "artifact {} was trained on different data than the first artifact",
                    p.as_ref().display()
                ),
            }
            models.push(tm);
        }
        let data = data.expect("non-empty artifact list");
        Self::from_tournament(&models, &data, exec)
    }

    /// Wire a finished single-model training run into a session by
    /// adopting the peak evaluation `train_model` already produced.
    /// Equivalent to a tournament-of-one handoff (ln Z is not known on
    /// this path; the lone slot needs no ranking).
    pub fn from_training(
        spec: &ModelSpec,
        sigma_n: f64,
        data: &Dataset,
        trained: &TrainResult,
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            trained.peak_eval.chol.dim() == data.len(),
            "TrainResult is for n = {}, dataset has n = {}",
            trained.peak_eval.chol.dim(),
            data.len()
        );
        let model = spec.build(sigma_n);
        let predictor = Predictor::from_eval(
            model,
            data.t.clone(),
            data.y.clone(),
            trained.theta_hat.clone(),
            trained.peak_eval.clone(),
        );
        Ok(Self {
            slots: vec![ModelSlot {
                spec: spec.clone(),
                predictor,
                ln_z: 0.0,
                drift: DriftMonitor::new(DriftOptions::default()),
            }],
            route: RouteMode::Winner,
            exec,
            sigma_n,
            scale_prior: ScalePrior::default(),
            drift_opts: DriftOptions::default(),
            window: None,
            since_refresh: 0,
            evictions: 0,
            refreshes: 0,
        })
    }

    /// Train (multistart CG, like the comparison pipeline) and move
    /// straight into serving.
    pub fn train_and_serve(
        spec: &ModelSpec,
        sigma_n: f64,
        data: &Dataset,
        opts: &TrainOptions,
        workers: usize,
        exec: ExecutionContext,
        rng: &mut Xoshiro256,
    ) -> crate::Result<(Self, TrainResult)> {
        let trained = train_model(spec, sigma_n, data, opts, workers, &exec, rng)?;
        let session = Self::from_training(spec, sigma_n, data, &trained, exec)?;
        Ok((session, trained))
    }

    /// Switch the routing policy (builder style).
    pub fn with_route(mut self, route: RouteMode) -> Self {
        self.route = route;
        self
    }

    /// Override the drift-monitor tuning on every slot (resets any
    /// accumulated drift state; also applied to the fresh monitors a
    /// retrain hot-swap creates).
    pub fn with_drift_options(mut self, opts: DriftOptions) -> Self {
        self.drift_opts = opts;
        for slot in &mut self.slots {
            slot.drift = DriftMonitor::new(opts);
        }
        self
    }

    /// Override the σ_f prior used for retrain-time evidence (builder
    /// style). Set this when the tournament that built the session ran
    /// with a non-default [`crate::coordinator::PipelineConfig::scale_prior`],
    /// so post-retrain ln Z values stay comparable with the incumbent
    /// ones (the prior-volume constant would otherwise offset every
    /// old-vs-new delta in [`RetrainOutcome`]).
    pub fn with_scale_prior(mut self, scale: ScalePrior) -> Self {
        self.scale_prior = scale;
        self
    }

    /// Attach a bounded-memory sliding-window policy (builder style):
    /// observations past `max_points` evict the oldest point from every
    /// slot, and every `refresh_every` evictions the factors are
    /// refactorised cold from the live window. `max_points` is clamped
    /// to ≥ 2.
    pub fn with_window(mut self, mut policy: WindowPolicy) -> Self {
        policy.max_points = policy.max_points.max(2);
        self.window = Some(policy);
        self
    }

    /// The attached window policy, if any.
    pub fn window(&self) -> Option<WindowPolicy> {
        self.window
    }

    /// Window-eviction rounds performed so far (each round drops one
    /// point from every slot).
    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Cold factor refreshes performed so far (periodic window refreshes
    /// plus retrain hot-swaps).
    pub fn refreshes(&self) -> usize {
        self.refreshes
    }

    /// Fixed noise level σ_n the routed models serve with.
    pub fn sigma_n(&self) -> f64 {
        self.sigma_n
    }

    /// Number of routed models.
    pub fn n_models(&self) -> usize {
        self.slots.len()
    }

    /// The spec served by default (the evidence winner).
    pub fn spec(&self) -> &ModelSpec {
        &self.slots[0].spec
    }

    /// Evidence-posterior weights over the roster, winner first
    /// (`w_i ∝ exp(ln Z_i)`, normalised).
    pub fn weights(&self) -> Vec<f64> {
        let max = self.slots.iter().map(|s| s.ln_z).fold(f64::NEG_INFINITY, f64::max);
        let mut w: Vec<f64> = self.slots.iter().map(|s| (s.ln_z - max).exp()).collect();
        let total: f64 = w.iter().sum();
        for v in &mut w {
            *v /= total;
        }
        w
    }

    /// Serve one batch of query points under the session's route mode.
    pub fn predict(&self, t_star: &[f64]) -> Prediction {
        match self.route {
            RouteMode::Winner => self.slots[0].predictor.predict_batch(t_star, &self.exec),
            RouteMode::Averaged => self.predict_averaged(t_star),
        }
    }

    /// Serve a specific roster member by name, regardless of route mode.
    pub fn predict_model(&self, name: &str, t_star: &[f64]) -> Option<Prediction> {
        self.slots
            .iter()
            .find(|s| s.spec.name() == name)
            .map(|s| s.predictor.predict_batch(t_star, &self.exec))
    }

    /// Routed model names, winner first.
    pub fn model_names(&self) -> Vec<&'static str> {
        self.slots.iter().map(|s| s.spec.name()).collect()
    }

    /// A specific roster member's live predictor (for invariant checks —
    /// e.g. the soak suite's windowed-factor-vs-cold-refit comparison).
    pub fn model_predictor(&self, name: &str) -> Option<&Predictor> {
        self.slots.iter().find(|s| s.spec.name() == name).map(|s| &s.predictor)
    }

    /// Evidence-weighted model averaging: mixture mean and mixture
    /// standard deviation across every slot. With a dominant winner
    /// (`ln B ≫ 1`) this degrades gracefully to the winner's prediction.
    fn predict_averaged(&self, t_star: &[f64]) -> Prediction {
        let w = self.weights();
        let mut mean = vec![0.0; t_star.len()];
        let mut second = vec![0.0; t_star.len()]; // Σ wᵢ (σᵢ² + μᵢ²)
        for (slot, &wi) in self.slots.iter().zip(&w) {
            let p = slot.predictor.predict_batch(t_star, &self.exec);
            for i in 0..t_star.len() {
                mean[i] += wi * p.mean[i];
                second[i] += wi * (p.sd[i] * p.sd[i] + p.mean[i] * p.mean[i]);
            }
        }
        let sd = mean
            .iter()
            .zip(&second)
            .map(|(m, s)| (s - m * m).max(0.0).sqrt())
            .collect();
        Prediction { mean, sd }
    }

    /// Append one observation to **every** live factor (`O(n²)` each),
    /// all-or-nothing: each model first scores the point and reports the
    /// pivot its factor extension would take
    /// ([`Predictor::log_predictive_and_pivot`]); if any model's
    /// extension would fail, the call errors **before any slot mutates**,
    /// so the routed factors never diverge in their data. Scores feed the
    /// per-model drift monitors only when the point is absorbed.
    pub fn observe(&mut self, t_new: f64, y_new: f64) -> crate::Result<()> {
        let mut scored = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let s = slot.predictor.score_observation(t_new, y_new);
            anyhow::ensure!(
                s.pivot > 0.0 && s.pivot.is_finite(),
                "observe(t={t_new}) would make {}'s K̃ non-PD (pivot {:.3e}); \
                 no model absorbed the point",
                slot.spec.name(),
                s.pivot
            );
            scored.push(s);
        }
        for (slot, s) in self.slots.iter_mut().zip(scored) {
            slot.drift.push(s.score);
            // reuses the pivot check's triangular solve — one O(n²) solve
            // per (point, model), and it cannot fail: the extension takes
            // exactly the pre-checked pivot. The α/σ̂² refresh is deferred
            // until after the window policy ran, so an absorb that
            // immediately evicts pays it once, not twice.
            slot.predictor.observe_scored_deferred(t_new, y_new, s)?;
        }
        // refresh the deferred caches even when the window enforcement
        // errors (e.g. a failed periodic refit), so the session keeps
        // serving a consistent α for whatever factors it now holds; a
        // completed cold refresh already installed fresh caches
        match self.enforce_window() {
            Ok(true) => Ok(()),
            other => {
                for slot in &mut self.slots {
                    slot.predictor.refresh_cache();
                }
                other.map(|_| ())
            }
        }
    }

    /// Apply the window policy after an absorption: evict everything
    /// over capacity from every slot in one oldest-first bulk shrink
    /// (deletion is a rank-1 update sweep — it cannot fail, so the slots
    /// stay in lockstep; one `O(n²)` storage copy regardless of how far
    /// over capacity the window is, e.g. after attaching a small window
    /// to a large restored session), then run the periodic cold refresh
    /// when due. Returns whether a cold refresh ran (in which case every
    /// slot's serving cache is already fresh and the caller must not
    /// redo the `O(n²)` refresh).
    fn enforce_window(&mut self) -> crate::Result<bool> {
        let Some(policy) = self.window else { return Ok(false) };
        let n = self.slots[0].predictor.n();
        if n > policy.max_points {
            let k = n - policy.max_points;
            for slot in &mut self.slots {
                slot.predictor.evict_front_deferred(k)?;
            }
            self.evictions += k;
            self.since_refresh += k;
        }
        if policy.refresh_every > 0 && self.since_refresh >= policy.refresh_every {
            self.refresh_factors()?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Refactorise **every** slot cold from the live window at its
    /// current ϑ̂, all-or-nothing: the `O(n³)` evaluations are computed
    /// first ([`Predictor::refit_eval`]) and only then committed
    /// ([`Predictor::adopt_eval`]), so an assembly/factorisation failure
    /// leaves the session exactly as it was. Resets the periodic-refresh
    /// countdown.
    pub fn refresh_factors(&mut self) -> crate::Result<()> {
        let evals = self
            .slots
            .iter()
            .map(|s| s.predictor.refit_eval(&self.exec))
            .collect::<crate::Result<Vec<_>>>()?;
        for (slot, ev) in self.slots.iter_mut().zip(evals) {
            slot.predictor.adopt_eval(ev);
        }
        self.refreshes += 1;
        self.since_refresh = 0;
        Ok(())
    }

    /// Retrain **in place** on the current window — the self-healing
    /// answer to a latched [`ServeSession::needs_retrain`]. Every slot's
    /// spec is retrained on the live window data (multistart plus one
    /// deterministic warm start at the incumbent ϑ̂, so a still-good peak
    /// is never lost), its Laplace evidence recomputed, and then — only
    /// after every model trained successfully — all router slots, the
    /// evidence ranking and the drift baselines are **hot-swapped**
    /// atomically: an error leaves the old session fully serviceable,
    /// and on success serving continues without dropping the session
    /// (lifetime counters carry over). The σ_f prior for the evidence is
    /// the session's ([`ServeSession::with_scale_prior`]; defaults to
    /// the config pipeline's [`ScalePrior::default`]).
    pub fn retrain(
        &mut self,
        opts: &TrainOptions,
        workers: usize,
        rng: &mut Xoshiro256,
    ) -> crate::Result<RetrainOutcome> {
        let window = Dataset::new(
            self.slots[0].predictor.t().to_vec(),
            self.slots[0].predictor.y().to_vec(),
            "serve-window",
        );
        let span = window.span();
        let scale = self.scale_prior;
        // train every slot first; nothing is swapped until all succeed
        let mut rebuilt: Vec<(ModelSlot, f64)> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let spec = slot.spec.clone();
            let model = spec.build(self.sigma_n);
            let prior = BoxPrior::for_model(&model, &span);
            let mut o = opts.clone();
            let mut incumbent = slot.predictor.theta().to_vec();
            prior.project(&mut incumbent);
            o.extra_starts.push(incumbent);
            let trained =
                train_model(&spec, self.sigma_n, &window, &o, workers, &self.exec, rng)?;
            let hessian = crate::gp::profiled_hessian_with(
                &model,
                &window.t,
                &window.y,
                &trained.theta_hat,
                &self.exec,
            )?;
            let evidence = laplace_evidence(
                window.len(),
                &prior,
                &scale,
                &trained.theta_hat,
                trained.lnp_peak,
                &hessian,
            )?;
            let predictor = Predictor::from_eval(
                spec.build(self.sigma_n),
                window.t.clone(),
                window.y.clone(),
                trained.theta_hat.clone(),
                trained.peak_eval,
            );
            predictor.carry_counters_from(&slot.predictor);
            let new_slot = ModelSlot {
                spec,
                predictor,
                ln_z: evidence.ln_z,
                drift: DriftMonitor::new(self.drift_opts),
            };
            rebuilt.push((new_slot, slot.ln_z));
        }
        // hot swap: new slots, new ranking, fresh drift baselines
        let old_winner = self.slots[0].spec.name().to_string();
        rebuilt.sort_by(|a, b| {
            b.0.ln_z.partial_cmp(&a.0.ln_z).unwrap_or(std::cmp::Ordering::Equal)
        });
        let models: Vec<(String, f64, f64)> = rebuilt
            .iter()
            .map(|(s, old_ln_z)| (s.spec.name().to_string(), *old_ln_z, s.ln_z))
            .collect();
        self.slots = rebuilt.into_iter().map(|(s, _)| s).collect();
        self.since_refresh = 0;
        self.refreshes += 1;
        let winner = self.slots[0].spec.name().to_string();
        Ok(RetrainOutcome {
            window_n: window.len(),
            models,
            winner_changed: winner != old_winner,
            winner,
        })
    }

    /// Append a batch of observations **point by point**: each point is
    /// scored against factors that have already absorbed every earlier
    /// point (drift scores are independent of how the caller chunks the
    /// stream), then fanned out atomically like [`ServeSession::observe`].
    /// On a mid-batch failure the already-absorbed prefix is kept — by
    /// every model consistently — and the error propagates.
    pub fn observe_batch(&mut self, t_new: &[f64], y_new: &[f64]) -> crate::Result<()> {
        anyhow::ensure!(t_new.len() == y_new.len(), "t/y batch length mismatch");
        for (&tn, &yn) in t_new.iter().zip(y_new) {
            self.observe(tn, yn)?;
        }
        Ok(())
    }

    /// Serving counters of the **winner** slot (the factor every default
    /// query goes through).
    pub fn stats(&self) -> ServeStats {
        self.slots[0].predictor.stats()
    }

    /// The winner's predictor (e.g. for `lnp()`/`sigma_f_hat2()`).
    pub fn predictor(&self) -> &Predictor {
        &self.slots[0].predictor
    }

    /// Per-model drift status, winner first.
    pub fn drift(&self) -> Vec<DriftStatus> {
        self.slots
            .iter()
            .map(|s| DriftStatus {
                model: s.spec.name().to_string(),
                baseline: s.drift.baseline(),
                recent: s.drift.recent_mean(),
                deficit: s.drift.deficit(),
                drifted: s.drift.drifted,
            })
            .collect()
    }

    /// True when any routed model's appended-point log-score has
    /// degraded past the drift threshold — the signal to rerun the
    /// tournament on the accumulated data.
    pub fn needs_retrain(&self) -> bool {
        self.slots.iter().any(|s| s.drift.drifted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::table1_dataset;
    use crate::optimize::MultistartOptions;

    #[test]
    fn train_and_serve_round_trip() {
        let data = table1_dataset(40, 0.1, 23);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(29);
        let (mut session, trained) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        assert!(trained.lnp_peak.is_finite());
        let pred = session.predict(&[5.5, 20.25]);
        assert_eq!(pred.mean.len(), 2);
        assert!(pred.sd.iter().all(|s| s.is_finite() && *s >= 0.0));
        // stream two points and serve again — n grows, queries accumulate
        session.observe_batch(&[41.0, 42.0], &[0.1, -0.2]).unwrap();
        let s = session.stats();
        assert_eq!(s.n_train, 42);
        assert_eq!(s.observations_appended, 2);
        let pred2 = session.predict(&[41.5]);
        assert_eq!(s.queries_served + 1, session.stats().queries_served);
        assert!(pred2.mean[0].is_finite());
        assert!(!session.needs_retrain(), "two in-distribution points must not flag");
    }

    #[test]
    fn from_training_uses_trained_theta() {
        let data = table1_dataset(30, 0.1, 31);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(37);
        let exec = ExecutionContext::seq();
        let trained =
            train_model(&ModelSpec::K1, 0.1, &data, &opts, 1, &exec, &mut rng).unwrap();
        let session =
            ServeSession::from_training(&ModelSpec::K1, 0.1, &data, &trained, exec).unwrap();
        assert_eq!(session.predictor().theta(), trained.theta_hat.as_slice());
        assert_eq!(session.stats().n_train, 30);
        assert_eq!(session.n_models(), 1);
        assert_eq!(session.spec(), &ModelSpec::K1);
        assert_eq!(session.weights(), vec![1.0]);
    }

    #[test]
    fn window_policy_bounds_memory_and_refreshes_periodically() {
        let data = table1_dataset(30, 0.1, 41);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(43);
        let (mut session, _) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        session = session.with_window(WindowPolicy { max_points: 32, refresh_every: 4 });
        assert_eq!(
            session.window(),
            Some(WindowPolicy { max_points: 32, refresh_every: 4 })
        );
        // stream 10 points: n grows to 32 then slides; 8 evictions, and
        // the cold refresh fires at evictions 4 and 8
        for i in 0..10 {
            session.observe(31.0 + i as f64, 0.05 * i as f64).unwrap();
            assert!(session.stats().n_train <= 32, "window exceeded at i={i}");
        }
        assert_eq!(session.stats().n_train, 32);
        assert_eq!(session.evictions(), 8);
        assert_eq!(session.refreshes(), 2);
        let s = session.stats();
        assert_eq!(s.observations_appended, 10);
        assert_eq!(s.observations_evicted, 8);
        // the oldest points are gone, the newest are present
        let p = session.predictor();
        assert_eq!(p.t()[0], data.t[8]);
        assert_eq!(*p.t().last().unwrap(), 40.0);
        let q = session.predict(&[40.5]);
        assert!(q.mean[0].is_finite() && q.sd[0].is_finite());
    }

    #[test]
    fn retrain_in_place_hot_swaps_and_preserves_counters() {
        let data = table1_dataset(30, 0.1, 47);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(53);
        let (mut session, trained) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        let _ = session.predict(&[3.5, 7.5]);
        session.observe(31.0, 0.1).unwrap();
        let before = session.stats();
        let lnp_before = session.predictor().lnp();
        let outcome = session.retrain(&opts, 1, &mut rng).unwrap();
        assert_eq!(outcome.window_n, 31);
        assert_eq!(outcome.models.len(), 1);
        assert_eq!(outcome.winner, "k1");
        assert!(!outcome.winner_changed);
        assert!(outcome.models[0].2.is_finite());
        // the session kept its lifetime counters and its data…
        let after = session.stats();
        assert_eq!(after.n_train, 31);
        assert_eq!(after.queries_served, before.queries_served);
        assert_eq!(after.observations_appended, before.observations_appended);
        // …serves from the new peak: the retrain warm-starts from the
        // incumbent ϑ̂, so on the same window it can only match or beat it
        let _ = trained;
        assert!(session.predictor().lnp().is_finite());
        assert!(
            session.predictor().lnp() >= lnp_before - 1e-6 * lnp_before.abs().max(1.0),
            "retrained peak regressed: {} vs incumbent {}",
            session.predictor().lnp(),
            lnp_before
        );
        // …and the drift baselines were reset
        for d in session.drift() {
            assert!(d.baseline.is_none() && !d.drifted);
        }
        assert!(!session.needs_retrain());
        let q = session.predict(&[31.5]);
        assert!(q.mean[0].is_finite());
    }

    #[test]
    fn drift_monitor_fires_on_sustained_deficit_and_not_on_noise() {
        let opts = DriftOptions { window: 4, threshold: 1.0 };
        let mut m = DriftMonitor::new(opts);
        // baseline window: scores around −1
        for s in [-1.0, -1.1, -0.9, -1.0] {
            m.push(s);
        }
        assert!((m.baseline().expect("baseline full") + 1.0).abs() < 1e-12);
        // comparable recent window: no flag
        for s in [-1.2, -0.8, -1.0, -1.0] {
            m.push(s);
        }
        assert!(!m.drifted, "in-noise scores must not latch drift");
        // degraded scores: deficit 3 nats > threshold 1 → latch
        for s in [-4.0, -4.0, -4.0, -4.0] {
            m.push(s);
        }
        assert!(m.drifted);
        assert!(m.deficit() > 1.0);
        // recovery does not unlatch (the flag is a retrain signal)
        for s in [-1.0; 8] {
            m.push(s);
        }
        assert!(m.drifted);
        // non-finite scores are ignored outright
        let mut m2 = DriftMonitor::new(opts);
        m2.push(f64::NAN);
        assert_eq!(m2.baseline_n, 0);
        // a window of 0 is clamped to 1 instead of panicking on push
        let mut m3 = DriftMonitor::new(DriftOptions { window: 0, threshold: 1.0 });
        m3.push(-1.0);
        m3.push(-1.0);
        m3.push(-5.0);
        assert!(m3.drifted, "1-point window must still detect the collapse");
    }
}
