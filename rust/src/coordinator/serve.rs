//! Coordinator glue for the serving layer: turn a training run into a
//! live, streaming [`Predictor`] session.
//!
//! [`ServeSession`] owns the predictor plus the spec/context bookkeeping a
//! deployment needs: it is constructed either from an existing
//! [`TrainResult`] ([`ServeSession::from_training`]) or by training
//! in-place ([`ServeSession::train_and_serve`]), carries the
//! [`ExecutionContext`] so callers don't thread it through every query,
//! and exposes the observe → predict streaming loop of
//! `examples/streaming_tidal.rs`.

use crate::data::Dataset;
use crate::gp::predict::Prediction;
use crate::gp::serve::{Predictor, ServeStats};
use crate::rng::Xoshiro256;
use crate::runtime::ExecutionContext;

use super::registry::ModelSpec;
use super::train::{train_model, TrainOptions, TrainResult};

/// A live serving session: trained hyperparameters + cached factor +
/// thread budget, answering batched queries and absorbing a stream of
/// new observations.
pub struct ServeSession {
    /// The model spec this session serves (kept for reporting/rebuilds).
    pub spec: ModelSpec,
    predictor: Predictor,
    exec: ExecutionContext,
}

impl ServeSession {
    /// Wire a finished training run into a predictor by **adopting** the
    /// peak evaluation `train_model` already produced — an `O(n²)` factor
    /// copy, no re-assembly and no `O(n³)` refactorisation. `exec`
    /// parallelises the queries.
    pub fn from_training(
        spec: &ModelSpec,
        sigma_n: f64,
        data: &Dataset,
        trained: &TrainResult,
        exec: ExecutionContext,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            trained.peak_eval.chol.dim() == data.len(),
            "TrainResult is for n = {}, dataset has n = {}",
            trained.peak_eval.chol.dim(),
            data.len()
        );
        let model = spec.build(sigma_n);
        let predictor = Predictor::from_eval(
            model,
            data.t.clone(),
            data.y.clone(),
            trained.theta_hat.clone(),
            trained.peak_eval.clone(),
        );
        Ok(Self { spec: spec.clone(), predictor, exec })
    }

    /// Train (multistart CG, like the comparison pipeline) and move
    /// straight into serving.
    pub fn train_and_serve(
        spec: &ModelSpec,
        sigma_n: f64,
        data: &Dataset,
        opts: &TrainOptions,
        workers: usize,
        exec: ExecutionContext,
        rng: &mut Xoshiro256,
    ) -> crate::Result<(Self, TrainResult)> {
        let trained = train_model(spec, sigma_n, data, opts, workers, &exec, rng)?;
        let session = Self::from_training(spec, sigma_n, data, &trained, exec)?;
        Ok((session, trained))
    }

    /// Serve one batch of query points through the cached factor.
    pub fn predict(&self, t_star: &[f64]) -> Prediction {
        self.predictor.predict_batch(t_star, &self.exec)
    }

    /// Append one observation (`O(n²)` factor extension).
    pub fn observe(&mut self, t_new: f64, y_new: f64) -> crate::Result<()> {
        self.predictor.observe(t_new, y_new)
    }

    /// Append a batch of observations, refreshing `α`/`σ̂_f²` once.
    pub fn observe_batch(&mut self, t_new: &[f64], y_new: &[f64]) -> crate::Result<()> {
        self.predictor.observe_batch(t_new, y_new)
    }

    /// Serving counters.
    pub fn stats(&self) -> ServeStats {
        self.predictor.stats()
    }

    /// The underlying predictor (e.g. for `lnp()`/`sigma_f_hat2()`).
    pub fn predictor(&self) -> &Predictor {
        &self.predictor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::table1_dataset;
    use crate::optimize::MultistartOptions;

    #[test]
    fn train_and_serve_round_trip() {
        let data = table1_dataset(40, 0.1, 23);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(29);
        let (mut session, trained) = ServeSession::train_and_serve(
            &ModelSpec::K1,
            0.1,
            &data,
            &opts,
            1,
            ExecutionContext::seq(),
            &mut rng,
        )
        .unwrap();
        assert!(trained.lnp_peak.is_finite());
        let pred = session.predict(&[5.5, 20.25]);
        assert_eq!(pred.mean.len(), 2);
        assert!(pred.sd.iter().all(|s| s.is_finite() && *s >= 0.0));
        // stream two points and serve again — n grows, queries accumulate
        session.observe_batch(&[41.0, 42.0], &[0.1, -0.2]).unwrap();
        let s = session.stats();
        assert_eq!(s.n_train, 42);
        assert_eq!(s.observations_appended, 2);
        let pred2 = session.predict(&[41.5]);
        assert_eq!(s.queries_served + 1, session.stats().queries_served);
        assert!(pred2.mean[0].is_finite());
    }

    #[test]
    fn from_training_uses_trained_theta() {
        let data = table1_dataset(30, 0.1, 31);
        let opts = TrainOptions {
            multistart: MultistartOptions { restarts: 2, ..Default::default() },
            extra_starts: Vec::new(),
        };
        let mut rng = Xoshiro256::seed_from_u64(37);
        let exec = ExecutionContext::seq();
        let trained =
            train_model(&ModelSpec::K1, 0.1, &data, &opts, 1, &exec, &mut rng).unwrap();
        let session =
            ServeSession::from_training(&ModelSpec::K1, 0.1, &data, &trained, exec).unwrap();
        assert_eq!(session.predictor().theta(), trained.theta_hat.as_slice());
        assert_eq!(session.stats().n_train, 30);
    }
}
