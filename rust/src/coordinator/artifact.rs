//! On-disk [`TrainedModel`] artifacts — the persistence third of the
//! serving lifecycle.
//!
//! Training costs `O(restarts · evals · n³)`; adopting a persisted
//! artifact costs one `O(n²)` file read. [`TrainedModel::save`] writes a
//! **versioned little-endian binary** of everything a serving process
//! needs to restart without retraining — the buildable spec name, the
//! training data, ϑ̂ with its full [`TrainResult`], the peak factor `L`
//! (lower triangle packed) with its *maintained* log-determinant, `α`,
//! and the Laplace evidence (so a restored multi-model router re-ranks
//! exactly) — and [`TrainedModel::load`] restores it **bit-identically**:
//! a reloaded predictor's first prediction equals the in-memory one to
//! the last bit, with zero profiled-likelihood evaluations (asserted via
//! [`crate::gp::profiled::eval_count`] in `rust/tests/persistence.rs`).
//!
//! No serde, no external crates (the build image has no registry): the
//! format is a flat field-by-field encoding behind a bounds-checked
//! reader, so corrupt, truncated or version-mismatched files surface as
//! clean `Err`s — never panics, never unbounded allocations (every
//! length field is validated against the bytes actually remaining).
//!
//! Format (version 3), all integers/floats little-endian:
//!
//! ```text
//! magic  b"GPFASTMD"  | version u32
//! dataset: label str | n u64 | t f64×n | y f64×n
//! spec name str | sigma_n f64 | param_names str-list
//! train: theta_hat vec | lnp_peak | sigma_f_hat2 | converged u8
//!        | n_evals u64 | n_modes u64 | restart_values vec | jitter f64
//! peak:  lnp | sigma_f_hat2 | alpha vec
//!        | factor dim u64 | logdet | packed lower triangle f64×n(n+1)/2
//! evidence: ln_z | ln_p_peak | ln_det_h | ln_volume | marg_const
//!        | sigma vec | covariance matrix | suspect u8
//! nested: u8 flag [| ln_z | ln_z_err | n_evals u64 | information
//!        | wall_secs]
//! warm_started u8 | restarts u64 | wall_secs f64
//! crc32 u32   (IEEE/zlib polynomial, over every preceding byte)
//! ```
//!
//! `str` = u32 length + UTF-8 bytes; `vec` = u64 length + f64s; `matrix`
//! = u64 rows + u64 cols + row-major f64s.
//!
//! Version 3 appends the CRC32 trailer so a disk-backed artifact store
//! detects *silent* corruption — a flipped bit inside an f64 payload is
//! still a structurally valid file, and before the checksum it would
//! hydrate a poisoned factor whenever the flip kept every number finite.
//! Version-2 files (no trailer) are still read for compatibility with
//! artifacts persisted by older builds.
//!
//! **Version 4** (the zero-copy format — see [`super::artifact_v4`])
//! moves the large numeric payloads (`t`, `y`, `α`, the factor) into
//! 8-byte-aligned raw blocks behind a fixed header, so an mmap'd or
//! aligned buffer hydrates by *reinterpreting* the bytes in place
//! instead of re-decoding f64s one at a time, and optionally stores the
//! factor as a truncated spectral form (`K̃ ≈ V_r Λ_r V_rᵀ + diag`).
//! [`decode`] dispatches on the version field, so every reader in the
//! crate accepts versions 2–4; the v3 encoder here remains the default
//! writer (byte-stable with prior builds).

use std::path::Path;

use crate::data::Dataset;
use crate::evidence::LaplaceEvidence;
use crate::gp::ProfiledEval;
use crate::linalg::{Chol, Matrix};

use super::registry::ModelSpec;
use super::report::NestedReport;
use super::tournament::TrainedModel;
use super::train::TrainResult;

pub(super) const MAGIC: &[u8; 8] = b"GPFASTMD";
const VERSION: u32 = 3;
/// Newest trailer-less version still accepted by [`decode`].
const COMPAT_VERSION: u32 = 2;

// ------------------------------------------------------------------ crc32

/// IEEE/zlib-polynomial CRC32 lookup table, built at compile time.
const fn make_crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = make_crc32_table();

/// CRC32 (IEEE 802.3 / zlib polynomial, reflected, init and final xor
/// `0xFFFF_FFFF`) — the standard checksum, hand-rolled because the build
/// image has no crate registry. Pinned to the `"123456789"` test vector
/// below.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------- writer

pub(super) struct Writer {
    pub(super) buf: Vec<u8>,
}

impl Writer {
    pub(super) fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub(super) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(super) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(super) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(super) fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(super) fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub(super) fn f64s_raw(&mut self, v: &[f64]) {
        for &x in v {
            self.f64(x);
        }
    }

    pub(super) fn vec(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        self.f64s_raw(v);
    }

    pub(super) fn matrix(&mut self, m: &Matrix) {
        self.u64(m.rows() as u64);
        self.u64(m.cols() as u64);
        self.f64s_raw(m.as_slice());
    }
}

// ---------------------------------------------------------------- reader

/// Bounds-checked cursor: every read validates the remaining length
/// first, and every element count is validated against the bytes that
/// could possibly back it before any allocation happens.
pub(super) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(super) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub(super) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(super) fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.remaining(),
            "truncated artifact: wanted {n} bytes at offset {}, {} remain",
            self.pos,
            self.remaining()
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub(super) fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(super) fn u32(&mut self) -> crate::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(super) fn u64(&mut self) -> crate::Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub(super) fn f64(&mut self) -> crate::Result<f64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }

    /// A length field counting `elem_bytes`-sized elements, validated
    /// against the remaining buffer before any allocation.
    pub(super) fn len(&mut self, elem_bytes: usize) -> crate::Result<usize> {
        let raw = self.u64()?;
        let n = usize::try_from(raw)
            .map_err(|_| anyhow::anyhow!("corrupt artifact: length field {raw} overflows"))?;
        anyhow::ensure!(
            n.checked_mul(elem_bytes).is_some_and(|b| b <= self.remaining()),
            "corrupt artifact: length field {n} exceeds remaining {} bytes",
            self.remaining()
        );
        Ok(n)
    }

    pub(super) fn str(&mut self) -> crate::Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| anyhow::anyhow!("corrupt artifact: invalid UTF-8 string: {e}"))
    }

    pub(super) fn f64s_raw(&mut self, n: usize) -> crate::Result<Vec<f64>> {
        let bytes = self.take(n * 8)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(8) {
            let mut a = [0u8; 8];
            a.copy_from_slice(c);
            out.push(f64::from_le_bytes(a));
        }
        Ok(out)
    }

    pub(super) fn vec(&mut self) -> crate::Result<Vec<f64>> {
        let n = self.len(8)?;
        self.f64s_raw(n)
    }

    pub(super) fn matrix(&mut self) -> crate::Result<Matrix> {
        let rows = self.len(1)?;
        let cols = self.len(1)?;
        anyhow::ensure!(
            rows.checked_mul(cols)
                .and_then(|n| n.checked_mul(8))
                .is_some_and(|b| b <= self.remaining()),
            "corrupt artifact: {rows}×{cols} matrix exceeds remaining {} bytes",
            self.remaining()
        );
        Ok(Matrix::from_vec(rows, cols, self.f64s_raw(rows * cols)?))
    }

    pub(super) fn done(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.remaining() == 0,
            "corrupt artifact: {} trailing bytes after the last field",
            self.remaining()
        );
        Ok(())
    }
}

// ------------------------------------------------------------- encoding

fn encode(tm: &TrainedModel, data: &Dataset) -> Vec<u8> {
    let mut w = Writer::new();
    w.buf.extend_from_slice(MAGIC);
    w.u32(VERSION);
    // dataset
    w.str(&data.label);
    w.u64(data.len() as u64);
    w.f64s_raw(&data.t);
    w.f64s_raw(&data.y);
    // spec
    w.str(tm.spec.name());
    w.f64(tm.sigma_n);
    w.u32(tm.param_names.len() as u32);
    for nm in &tm.param_names {
        w.str(nm);
    }
    // train result
    w.vec(&tm.train.theta_hat);
    w.f64(tm.train.lnp_peak);
    w.f64(tm.train.sigma_f_hat2);
    w.u8(tm.train.converged as u8);
    w.u64(tm.train.n_evals as u64);
    w.u64(tm.train.n_modes as u64);
    w.vec(&tm.train.restart_values);
    w.f64(tm.train.jitter);
    // peak evaluation: lnp, σ̂², α, factor (packed lower triangle)
    w.f64(tm.train.peak_eval.lnp);
    w.f64(tm.train.peak_eval.sigma_f_hat2);
    w.vec(&tm.train.peak_eval.alpha);
    let chol = &tm.train.peak_eval.chol;
    let n = chol.dim();
    w.u64(n as u64);
    w.f64(chol.logdet());
    let l = chol.factor_matrix();
    for i in 0..n {
        w.f64s_raw(&l.row(i)[..=i]);
    }
    // evidence
    let ev = &tm.evidence;
    w.f64(ev.ln_z);
    w.f64(ev.ln_p_peak);
    w.f64(ev.ln_det_h);
    w.f64(ev.ln_volume);
    w.f64(ev.marg_const);
    w.vec(&ev.sigma);
    w.matrix(&ev.covariance);
    w.u8(ev.suspect as u8);
    // nested verification
    match &tm.nested {
        None => w.u8(0),
        Some(nr) => {
            w.u8(1);
            w.f64(nr.ln_z);
            w.f64(nr.ln_z_err);
            w.u64(nr.n_evals as u64);
            w.f64(nr.information);
            w.f64(nr.wall_secs);
        }
    }
    w.u8(tm.warm_started as u8);
    w.u64(tm.restarts as u64);
    w.f64(tm.wall_secs);
    // optional scenario-tier input block (extra input columns beyond t,
    // per-point noise). Written ONLY for nd/heteroscedastic datasets, so
    // 1-D homoscedastic artifacts stay byte-identical with prior builds
    // (the golden persistence fixtures pin this).
    if data.d() > 1 || data.noise.is_some() {
        w.u64(data.extra.len() as u64);
        for c in &data.extra {
            w.f64s_raw(c);
        }
        match &data.noise {
            None => w.u8(0),
            Some(s) => {
                w.u8(1);
                w.f64s_raw(s);
            }
        }
    }
    // version-3 trailer: checksum of every byte written so far
    let crc = crc32(&w.buf);
    w.u32(crc);
    w.buf
}

fn decode(bytes: &[u8]) -> crate::Result<(TrainedModel, Dataset)> {
    anyhow::ensure!(
        bytes.len() >= 12,
        "not a gpfast model artifact: file shorter than the header"
    );
    anyhow::ensure!(
        &bytes[..8] == &MAGIC[..],
        "not a gpfast model artifact: bad magic {:?}",
        &bytes[..8]
    );
    let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
    // Version 3 carries a CRC32 trailer over everything before it; verify
    // it *before* field-level decoding so a silently flipped payload byte
    // (structurally valid, possibly still finite) never hydrates. The
    // body handed to the field reader excludes the trailer. Version-2
    // files have no trailer and decode as-is (read-compat).
    let body = match version {
        // Version 4 is the zero-copy fixed-layout format; its parser
        // lives in the sibling module and owns its own CRC handling.
        super::artifact_v4::VERSION_V4 => return super::artifact_v4::decode_v4(bytes),
        COMPAT_VERSION => bytes,
        VERSION => {
            anyhow::ensure!(
                bytes.len() >= 16,
                "truncated artifact: version {VERSION} file too short for its checksum trailer"
            );
            let split = bytes.len() - 4;
            let stored = u32::from_le_bytes([
                bytes[split],
                bytes[split + 1],
                bytes[split + 2],
                bytes[split + 3],
            ]);
            let computed = crc32(&bytes[..split]);
            anyhow::ensure!(
                stored == computed,
                "corrupt artifact: CRC32 mismatch (stored {stored:#010x}, computed {computed:#010x})"
            );
            &bytes[..split]
        }
        other => anyhow::bail!(
            "unsupported artifact version {other} (this build reads versions {COMPAT_VERSION} through {})",
            super::artifact_v4::VERSION_V4
        ),
    };
    let mut r = Reader::new(body);
    let _magic = r.take(8)?;
    let _version = r.u32()?;
    // dataset
    let label = r.str()?;
    let n = r.len(16)?; // t and y each back n f64s
    anyhow::ensure!(n >= 1, "corrupt artifact: empty dataset (n = 0)");
    let t = r.f64s_raw(n)?;
    let y = r.f64s_raw(n)?;
    let data = Dataset::checked(t, y, label)
        .map_err(|e| anyhow::anyhow!("corrupt artifact: {e}"))?;
    // spec
    let spec_name = r.str()?;
    let spec = ModelSpec::parse(&spec_name)
        .map_err(|e| anyhow::anyhow!("artifact names an unknown model spec: {e}"))?;
    let sigma_n = r.f64()?;
    anyhow::ensure!(sigma_n.is_finite() && sigma_n >= 0.0, "corrupt artifact: σ_n = {sigma_n}");
    let n_params = r.u32()? as usize;
    anyhow::ensure!(
        n_params <= 64,
        "corrupt artifact: implausible hyperparameter count {n_params}"
    );
    let mut param_names = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        param_names.push(r.str()?);
    }
    let model_dim = spec.build(sigma_n).dim();
    anyhow::ensure!(
        n_params == model_dim,
        "corrupt artifact: {spec_name} has {model_dim} hyperparameters, file lists {n_params}"
    );
    // train result
    let theta_hat = r.vec()?;
    anyhow::ensure!(
        theta_hat.len() == model_dim,
        "corrupt artifact: θ̂ has {} coordinates, {spec_name} needs {model_dim}",
        theta_hat.len()
    );
    let lnp_peak = r.f64()?;
    let sigma_f_hat2 = r.f64()?;
    let converged = r.u8()? != 0;
    let n_evals = r.u64()? as usize;
    let n_modes = r.u64()? as usize;
    let restart_values = r.vec()?;
    let jitter = r.f64()?;
    anyhow::ensure!(
        jitter.is_finite() && jitter >= 0.0,
        "corrupt artifact: recorded jitter = {jitter}"
    );
    // peak evaluation
    let peak_lnp = r.f64()?;
    let peak_sigma2 = r.f64()?;
    let alpha = r.vec()?;
    // exact specs carry an n-point factor; approximate specs carry their
    // reduced factor, whose size is a pure function of the spec and n
    let chol_dim = r.len(8)?;
    let want_dim = spec.factor_dim(n);
    anyhow::ensure!(
        chol_dim == want_dim && alpha.len() == chol_dim,
        "corrupt artifact: factor dim {chol_dim} / α length {} vs expected {want_dim} \
         for {spec_name} at n = {n}",
        alpha.len()
    );
    let logdet = r.f64()?;
    let mut l = Matrix::zeros(chol_dim, chol_dim);
    for i in 0..chol_dim {
        let row = r.f64s_raw(i + 1)?;
        l.row_mut(i)[..=i].copy_from_slice(&row);
    }
    // payload finiteness: corrupt bytes can carry valid length fields but
    // poison the numbers — a hydrated factor must be usable as-is
    anyhow::ensure!(
        theta_hat.iter().all(|v| v.is_finite()),
        "corrupt artifact: non-finite θ̂ coordinate"
    );
    anyhow::ensure!(
        alpha.iter().all(|v| v.is_finite()),
        "corrupt artifact: non-finite α entry"
    );
    anyhow::ensure!(
        logdet.is_finite() && peak_lnp.is_finite(),
        "corrupt artifact: non-finite factor logdet ({logdet}) or peak lnp ({peak_lnp})"
    );
    for i in 0..chol_dim {
        let d = l[(i, i)];
        anyhow::ensure!(
            d.is_finite() && d > 0.0,
            "corrupt artifact: factor diagonal L[{i}][{i}] = {d} (must be finite and > 0)"
        );
    }
    let chol = Chol::from_parts(l, logdet);
    let peak_eval =
        ProfiledEval { lnp: peak_lnp, sigma_f_hat2: peak_sigma2, chol, alpha, jitter };
    // evidence
    let ln_z = r.f64()?;
    let ln_p_peak = r.f64()?;
    let ln_det_h = r.f64()?;
    let ln_volume = r.f64()?;
    let marg_const = r.f64()?;
    let sigma = r.vec()?;
    let covariance = r.matrix()?;
    let suspect = r.u8()? != 0;
    let evidence = LaplaceEvidence {
        ln_z,
        ln_p_peak,
        ln_det_h,
        ln_volume,
        marg_const,
        sigma,
        covariance,
        suspect,
    };
    // nested verification
    let nested = match r.u8()? {
        0 => None,
        1 => Some(NestedReport {
            ln_z: r.f64()?,
            ln_z_err: r.f64()?,
            n_evals: r.u64()? as usize,
            information: r.f64()?,
            wall_secs: r.f64()?,
        }),
        other => anyhow::bail!("corrupt artifact: nested flag byte {other}"),
    };
    let warm_started = r.u8()? != 0;
    let restarts = r.u64()? as usize;
    let wall_secs = r.f64()?;
    // optional scenario-tier input block: absent on 1-D homoscedastic
    // artifacts (including every file an older build wrote), present —
    // guarded by remaining() — when the dataset carried extra input
    // columns and/or a per-point noise vector
    let data = if r.remaining() > 0 {
        let d_extra = r.len(8)?;
        anyhow::ensure!(
            d_extra < crate::gp::MAX_INPUT_DIM,
            "corrupt artifact: implausible extra-column count {d_extra}"
        );
        let mut extra = Vec::with_capacity(d_extra);
        for _ in 0..d_extra {
            extra.push(r.f64s_raw(n)?);
        }
        let mut d = if extra.is_empty() {
            data
        } else {
            data.with_extra_cols(extra)
                .map_err(|e| anyhow::anyhow!("corrupt artifact: {e}"))?
        };
        match r.u8()? {
            0 => {}
            1 => {
                let s = r.f64s_raw(n)?;
                d = d
                    .with_noise(s)
                    .map_err(|e| anyhow::anyhow!("corrupt artifact: {e}"))?;
            }
            other => anyhow::bail!("corrupt artifact: noise flag byte {other}"),
        }
        d
    } else {
        data
    };
    anyhow::ensure!(
        spec.input_dim() == data.d(),
        "corrupt artifact: {spec_name} expects d = {} inputs, file carries d = {}",
        spec.input_dim(),
        data.d()
    );
    r.done()?;
    let tm = TrainedModel {
        spec,
        sigma_n,
        param_names,
        train: TrainResult {
            theta_hat,
            lnp_peak,
            sigma_f_hat2,
            peak_eval,
            converged,
            n_evals,
            n_modes,
            restart_values,
            jitter,
        },
        evidence,
        nested,
        warm_started,
        restarts,
        wall_secs,
    };
    Ok((tm, data))
}

impl TrainedModel {
    /// Encode this artifact (plus the training data it factored) to the
    /// versioned binary format, without touching the filesystem — the
    /// byte-level half of [`TrainedModel::save`], used directly by
    /// in-memory artifact stores ([`crate::coordinator::fleet`]).
    pub fn to_bytes(&self, data: &Dataset) -> crate::Result<Vec<u8>> {
        anyhow::ensure!(
            self.train.peak_eval.chol.dim() == self.spec.factor_dim(data.len()),
            "artifact factor dim {} does not match {} for n = {}",
            self.train.peak_eval.chol.dim(),
            self.spec.factor_dim(data.len()),
            data.len()
        );
        Ok(encode(self, data))
    }

    /// Decode an artifact encoded by [`TrainedModel::to_bytes`] (or read
    /// from a [`TrainedModel::save`] file). Bit-identical restore, zero
    /// likelihood evaluations; corrupt, truncated, checksum-mismatched
    /// and version-unknown byte strings return errors (never panic).
    pub fn from_bytes(bytes: &[u8]) -> crate::Result<(TrainedModel, Dataset)> {
        decode(bytes)
    }

    /// Persist this artifact (plus the training data it factored) to
    /// `path`. See the module docs for the format; the write is
    /// all-at-once, so a crashed save leaves either the old file or a
    /// truncated one that [`TrainedModel::load`] will cleanly reject.
    pub fn save(&self, path: &Path, data: &Dataset) -> crate::Result<()> {
        let bytes = self.to_bytes(data)?;
        std::fs::write(path, bytes)
            .map_err(|e| anyhow::anyhow!("writing model artifact {}: {e}", path.display()))
    }

    /// Load an artifact saved by [`TrainedModel::save`]. The restore is
    /// bit-identical — factor, `α`, σ̂² and the maintained log-determinant
    /// come back exactly, so a predictor adopted from the result serves
    /// the same bits as the one that was saved, with **zero** likelihood
    /// evaluations. Corrupt, truncated and version-mismatched files
    /// return errors (never panic).
    pub fn load(path: &Path) -> crate::Result<(TrainedModel, Dataset)> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading model artifact {}: {e}", path.display()))?;
        decode(&bytes).map_err(|e| anyhow::anyhow!("loading {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reader_rejects_short_and_oversized_fields() {
        let mut r = Reader::new(&[1, 2, 3]);
        assert!(r.u64().is_err());
        // a length field claiming more elements than bytes remain must
        // fail before allocating
        let mut buf = Vec::new();
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut r = Reader::new(&buf);
        assert!(r.vec().is_err());
        // trailing garbage detected
        let r = Reader::new(&[0u8; 4]);
        assert!(r.done().is_err());
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // the universal IEEE/zlib check value, plus the empty-input and
        // single-byte identities any table-driven implementation must hit
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"\x00"), 0xD202_EF8D);
        // one flipped bit anywhere changes the checksum
        let a = crc32(b"gpfast artifact payload");
        let b = crc32(b"gpfast artifact pazload");
        assert_ne!(a, b);
    }
}
