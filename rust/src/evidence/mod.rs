//! Laplace-approximation hyperevidence and Bayes factors — §2(a),
//! eqs. (2.10)–(2.13), in the σ_f-profiled formulation the paper actually
//! computes with (§2(b)).
//!
//! With flat priors over the reduced coordinates ϑ (volume `V_ϑ`) and a
//! truncated Jeffreys prior on σ_f, the hyperevidence factorises as
//!
//! `Z ≈ [marg const (eq. 2.18)] · (1/V_ϑ) · P_max(ϑ̂) ·
//!      √((2π)^{m−1} / det H)`
//!
//! where `H = −∂²ln P_max` at the peak (eq. 2.19). The inverse Hessian is
//! simultaneously the covariance of the maximum-hyperlikelihood estimator
//! — the hyperparameter error bars quoted in §3(b).

use crate::linalg::{Lu, Matrix};
use crate::math::LN_2PI;
use crate::priors::{BoxPrior, ScalePrior};

/// A Laplace evidence estimate and its ingredients.
#[derive(Clone, Debug)]
pub struct LaplaceEvidence {
    /// ln Z — the paper's `ln Z_est`.
    pub ln_z: f64,
    /// ln P_max(ϑ̂).
    pub ln_p_peak: f64,
    /// ln det H.
    pub ln_det_h: f64,
    /// ln V_ϑ (Occam volume factor actually subtracted).
    pub ln_volume: f64,
    /// σ_f-marginalisation constant (eq. 2.18).
    pub marg_const: f64,
    /// Per-parameter 1σ error bars from diag(H⁻¹).
    pub sigma: Vec<f64>,
    /// H⁻¹ — the estimator covariance (Fig. 2 Gaussian overlay).
    pub covariance: Matrix,
    /// True when H was not positive definite and the estimate should not
    /// be trusted (the paper's flagged (k₂, n = 30) failure mode).
    pub suspect: bool,
}

/// Assemble the Laplace evidence from a located peak and its Hessian.
///
/// `n` is the dataset size (for the eq.-2.18 constant), `theta_hat` the
/// peak in reduced coordinates, `ln_p_peak = ln P_max(ϑ̂)`, `hessian`
/// `H = −∂²ln P_max|_ϑ̂`.
pub fn laplace_evidence(
    n: usize,
    prior: &BoxPrior,
    scale: &ScalePrior,
    theta_hat: &[f64],
    ln_p_peak: f64,
    hessian: &Matrix,
) -> crate::Result<LaplaceEvidence> {
    let m = prior.dim();
    anyhow::ensure!(hessian.rows() == m && hessian.cols() == m, "Hessian shape mismatch");
    let lu = Lu::factor(hessian)?;
    let (ln_det_abs, sign) = lu.logdet_abs();
    let covariance = lu.inverse();
    let mut suspect = sign <= 0.0;
    let mut sigma = Vec::with_capacity(m);
    for i in 0..m {
        let v = covariance[(i, i)];
        if v <= 0.0 {
            suspect = true;
            sigma.push(f64::NAN);
        } else {
            sigma.push(v.sqrt());
        }
    }
    // peak on the prior boundary also invalidates the Gaussian integral
    for (i, (&th, (lo, hi))) in theta_hat.iter().zip(&prior.bounds).enumerate() {
        let w = (hi - lo).abs().max(1e-300);
        if (th - lo).abs() < 1e-6 * w || (th - hi).abs() < 1e-6 * w {
            let _ = i;
            suspect = true;
        }
    }
    let ln_volume = prior.ln_volume_at(theta_hat);
    let marg_const = crate::gp::marg_constant(n, scale.sigma_lo, scale.sigma_hi);
    let ln_z = marg_const + ln_p_peak - ln_volume + 0.5 * (m as f64) * LN_2PI
        - 0.5 * ln_det_abs;
    Ok(LaplaceEvidence {
        ln_z,
        ln_p_peak,
        ln_det_h: ln_det_abs,
        ln_volume,
        marg_const,
        sigma,
        covariance,
        suspect,
    })
}

/// `ln B = ln Z_a − ln Z_b` with the paper's reading aid.
pub fn log_bayes_factor(a: &LaplaceEvidence, b: &LaplaceEvidence) -> f64 {
    a.ln_z - b.ln_z
}

/// Jeffreys-scale interpretation of a log Bayes factor (for reports).
pub fn interpret_ln_bayes(ln_b: f64) -> &'static str {
    let b = ln_b.abs();
    if b < 1.0 {
        "inconclusive"
    } else if b < 2.5 {
        "weak"
    } else if b < 5.0 {
        "moderate"
    } else {
        "decisive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::priors::BoxPrior;

    fn flat_prior(m: usize, lo: f64, hi: f64) -> BoxPrior {
        BoxPrior { bounds: vec![(lo, hi); m], constraints: vec![] }
    }

    /// For an exactly Gaussian ln P the Laplace "approximation" is exact:
    /// Z = ∫ (1/V) e^{lnP̂ − ½Δᵀ H Δ} dϑ (peak well inside the box).
    #[test]
    fn exact_on_gaussian_integrand() {
        let prior = flat_prior(2, -50.0, 50.0);
        let scale = ScalePrior::default();
        let h = Matrix::from_rows(&[&[2.0, 0.3], &[0.3, 1.0]]);
        let ln_p_peak = -5.0;
        let ev = laplace_evidence(10, &prior, &scale, &[0.0, 0.0], ln_p_peak, &h).unwrap();
        // analytic: marg + lnP̂ − ln V + ln(2π/√det H)
        let det: f64 = 2.0 * 1.0 - 0.09;
        let want = ev.marg_const + ln_p_peak - (100f64.ln() * 2.0) + LN_2PI - 0.5 * det.ln();
        assert!((ev.ln_z - want).abs() < 1e-12, "{} vs {want}", ev.ln_z);
        assert!(!ev.suspect);
        // error bars are sqrt of H⁻¹ diagonal
        let hinv = Lu::factor(&h).unwrap().inverse();
        assert!((ev.sigma[0] - hinv[(0, 0)].sqrt()).abs() < 1e-12);
    }

    #[test]
    fn occam_penalty_grows_with_volume() {
        let scale = ScalePrior::default();
        let h = Matrix::eye(1);
        let small = laplace_evidence(10, &flat_prior(1, 0.0, 1.0), &scale, &[0.5], 0.0, &h)
            .unwrap();
        let large = laplace_evidence(10, &flat_prior(1, -50.0, 50.0), &scale, &[0.5], 0.0, &h)
            .unwrap();
        assert!(small.ln_z > large.ln_z, "wider prior must be Occam-penalised");
        assert!((small.ln_z - large.ln_z - 100f64.ln()).abs() < 1e-10);
    }

    #[test]
    fn non_pd_hessian_is_flagged() {
        let prior = flat_prior(2, -10.0, 10.0);
        let h = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, -1.0]]); // saddle
        let ev = laplace_evidence(10, &prior, &ScalePrior::default(), &[0.0, 0.0], 0.0, &h)
            .unwrap();
        assert!(ev.suspect);
    }

    #[test]
    fn boundary_peak_is_flagged() {
        let prior = flat_prior(1, 0.0, 1.0);
        let h = Matrix::eye(1);
        let ev = laplace_evidence(10, &prior, &ScalePrior::default(), &[1.0], 0.0, &h).unwrap();
        assert!(ev.suspect);
    }

    #[test]
    fn bayes_factor_and_interpretation() {
        let prior = flat_prior(1, -10.0, 10.0);
        let scale = ScalePrior::default();
        let h = Matrix::eye(1);
        let a = laplace_evidence(10, &prior, &scale, &[0.0], -3.0, &h).unwrap();
        let b = laplace_evidence(10, &prior, &scale, &[0.0], -9.0, &h).unwrap();
        assert!((log_bayes_factor(&a, &b) - 6.0).abs() < 1e-12);
        assert_eq!(interpret_ln_bayes(6.0), "decisive");
        assert_eq!(interpret_ln_bayes(0.3), "inconclusive");
    }
}
