//! Dataset handling: synthetic GP draws (§3(a)), the Woods-Hole tidal
//! simulator (§3(b) substitute — see DESIGN.md §Substitutions), and CSV
//! import/export.
//!
//! ## Input layout (scenario tier)
//!
//! A [`Dataset`] is an n×d input block plus observations. Column 0 is
//! `t` (the time axis of every pre-existing 1-D pipeline); columns
//! 1..d live in `extra`, so a d = 1 dataset is bit-identical to the
//! old `{t, y}` layout (`extra` empty). An optional per-point noise
//! vector `noise` (σ_n,i, in σ_f = 1 units, replacing the model's
//! scalar σ_n on the diagonal) makes the likelihood heteroscedastic.

pub mod synthetic;
pub mod tidal;
pub mod csv;

/// A regression dataset `{(x_i, y_i)}` — the paper's `D = {x, y}` —
/// with `x_i ∈ ℝ^d` stored column-major (`t` is column 0).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// First input column (time axis for d = 1 series).
    pub t: Vec<f64>,
    /// Input columns 1..d (empty for classic 1-D datasets).
    pub extra: Vec<Vec<f64>>,
    /// Output vector.
    pub y: Vec<f64>,
    /// Optional per-point noise σ_n,i (heteroscedastic diagonal); `None`
    /// means the model's scalar σ_n applies to every point.
    pub noise: Option<Vec<f64>>,
    /// Human-readable provenance tag carried into reports.
    pub label: String,
}

impl Dataset {
    pub fn new(t: Vec<f64>, y: Vec<f64>, label: impl Into<String>) -> Self {
        assert_eq!(t.len(), y.len(), "t/y length mismatch");
        Self { t, extra: Vec::new(), y, noise: None, label: label.into() }
    }

    /// Fallible constructor enforcing the data-boundary contract: every
    /// input and observation must be finite. A NaN/±∞ that slips past
    /// this boundary poisons a covariance factor irrecoverably, so the
    /// external entry points (CSV import, artifact hydration) reject it
    /// here with a clean error instead.
    pub fn checked(t: Vec<f64>, y: Vec<f64>, label: impl Into<String>) -> crate::Result<Self> {
        anyhow::ensure!(t.len() == y.len(), "t/y length mismatch: {} vs {}", t.len(), y.len());
        for (i, &v) in t.iter().enumerate() {
            anyhow::ensure!(v.is_finite(), "non-finite input t[{i}] = {v}");
        }
        for (i, &v) in y.iter().enumerate() {
            anyhow::ensure!(v.is_finite(), "non-finite observation y[{i}] = {v}");
        }
        Ok(Self { t, extra: Vec::new(), y, noise: None, label: label.into() })
    }

    /// Attach input columns 1..d (builder style). Each column must match
    /// `len()` and be finite everywhere.
    pub fn with_extra_cols(mut self, extra: Vec<Vec<f64>>) -> crate::Result<Self> {
        for (j, col) in extra.iter().enumerate() {
            anyhow::ensure!(
                col.len() == self.t.len(),
                "input column {} length mismatch: {} vs {}",
                j + 1,
                col.len(),
                self.t.len()
            );
            for (i, &v) in col.iter().enumerate() {
                anyhow::ensure!(v.is_finite(), "non-finite input x{}[{i}] = {v}", j + 1);
            }
        }
        self.extra = extra;
        Ok(self)
    }

    /// Attach a per-point noise vector σ_n,i (builder style). Must match
    /// `len()`; every entry finite and non-negative.
    pub fn with_noise(mut self, noise: Vec<f64>) -> crate::Result<Self> {
        anyhow::ensure!(
            noise.len() == self.t.len(),
            "noise length mismatch: {} vs {}",
            noise.len(),
            self.t.len()
        );
        for (i, &v) in noise.iter().enumerate() {
            anyhow::ensure!(v.is_finite() && v >= 0.0, "bad noise sigma_n[{i}] = {v}");
        }
        self.noise = Some(noise);
        Ok(self)
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// Number of input dimensions d (≥ 1).
    pub fn d(&self) -> usize {
        1 + self.extra.len()
    }

    /// All d input columns, `t` first — the borrowed layout the nd
    /// assembly/likelihood entry points consume.
    pub fn input_cols(&self) -> Vec<&[f64]> {
        let mut cols: Vec<&[f64]> = Vec::with_capacity(self.d());
        cols.push(&self.t);
        for c in &self.extra {
            cols.push(c);
        }
        cols
    }

    /// Does this dataset carry a per-point (heteroscedastic) noise
    /// vector?
    pub fn is_heteroscedastic(&self) -> bool {
        self.noise.is_some()
    }

    /// First `n` points (the paper's "first lunar month" style
    /// subsetting). Safe for any `n`, including `n = 0` and `n > len()`
    /// — the result is simply clamped (an empty head is a valid empty
    /// dataset; downstream `span()` reports it as a recoverable error).
    pub fn head(&self, n: usize) -> Dataset {
        let k = n.min(self.len());
        Dataset {
            t: self.t[..k].to_vec(),
            extra: self.extra.iter().map(|c| c[..k].to_vec()).collect(),
            y: self.y[..k].to_vec(),
            noise: self.noise.as_ref().map(|s| s[..k].to_vec()),
            label: format!("{}[..{}]", self.label, k),
        }
    }

    /// Subtract the mean of `y` (the paper assumes zero-mean GPs).
    /// Empty-safe: an empty dataset passes through unchanged instead of
    /// producing a 0/0 NaN mean.
    pub fn demean(mut self) -> Dataset {
        if self.y.is_empty() {
            return self;
        }
        let m = self.y.iter().sum::<f64>() / self.len() as f64;
        for v in &mut self.y {
            *v -= m;
        }
        self
    }

    /// The sampling geometry (δt, ΔT), pooled over all d input columns.
    /// Errors on degenerate grids (fewer than two points, or a
    /// dimension with no positive separation) instead of panicking.
    pub fn span(&self) -> crate::Result<crate::kernels::DataSpan> {
        if self.extra.is_empty() {
            crate::kernels::DataSpan::from_times(&self.t)
        } else {
            crate::kernels::DataSpan::from_columns(&self.input_cols())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checked_rejects_non_finite() {
        assert!(Dataset::checked(vec![0.0, 1.0], vec![1.0, 2.0], "ok").is_ok());
        let e = Dataset::checked(vec![0.0, f64::NAN], vec![1.0, 2.0], "bad").unwrap_err();
        assert!(e.to_string().contains("t[1]"), "{e}");
        let e = Dataset::checked(vec![0.0, 1.0], vec![f64::INFINITY, 2.0], "bad").unwrap_err();
        assert!(e.to_string().contains("y[0]"), "{e}");
        assert!(Dataset::checked(vec![0.0], vec![1.0, 2.0], "len").is_err());
    }

    #[test]
    fn head_and_demean() {
        let d = Dataset::new(vec![0.0, 1.0, 2.0, 3.0], vec![1.0, 3.0, 5.0, 7.0], "x");
        let h = d.head(2);
        assert_eq!(h.len(), 2);
        assert_eq!(h.y, vec![1.0, 3.0]);
        let dm = d.demean();
        assert!((dm.y.iter().sum::<f64>()).abs() < 1e-12);
    }

    #[test]
    fn head_zero_and_empty_demean_are_safe() {
        let d = Dataset::new(vec![0.0, 1.0, 2.0], vec![1.0, 3.0, 5.0], "x");
        let h = d.head(0);
        assert_eq!(h.len(), 0);
        assert!(h.is_empty());
        // span on the empty head is a clean error, not a panic
        assert!(h.span().is_err());
        // demean on an empty dataset must not manufacture NaNs
        let dm = h.demean();
        assert!(dm.y.is_empty());
        // head past the end clamps
        let d = Dataset::new(vec![0.0, 1.0], vec![1.0, 2.0], "x");
        assert_eq!(d.head(10).len(), 2);
    }

    #[test]
    fn span_errors_on_duplicate_times() {
        let d = Dataset::new(vec![5.0, 5.0, 5.0], vec![1.0, 2.0, 3.0], "dup");
        let e = d.span().unwrap_err();
        assert!(e.to_string().contains("degenerate"), "{e}");
        let one = Dataset::new(vec![5.0], vec![1.0], "one");
        assert!(one.span().is_err());
    }

    #[test]
    fn multi_column_layout() {
        let d = Dataset::new(vec![0.0, 1.0, 2.0], vec![1.0, 2.0, 3.0], "nd")
            .with_extra_cols(vec![vec![5.0, 6.0, 8.0], vec![-1.0, 0.5, 0.0]])
            .unwrap()
            .with_noise(vec![0.1, 0.2, 0.3])
            .unwrap();
        assert_eq!(d.d(), 3);
        assert!(d.is_heteroscedastic());
        let cols = d.input_cols();
        assert_eq!(cols.len(), 3);
        assert_eq!(cols[1][2], 8.0);
        let span = d.span().unwrap();
        assert!(span.dt_min > 0.0 && span.dt_max >= 2.0);
        let h = d.head(2);
        assert_eq!(h.extra[0], vec![5.0, 6.0]);
        assert_eq!(h.noise.as_deref(), Some(&[0.1, 0.2][..]));
        // ragged/non-finite extras rejected
        assert!(Dataset::new(vec![0.0, 1.0], vec![1.0, 2.0], "bad")
            .with_extra_cols(vec![vec![1.0]])
            .is_err());
        assert!(Dataset::new(vec![0.0, 1.0], vec![1.0, 2.0], "bad")
            .with_noise(vec![0.1, -0.2])
            .is_err());
        // a constant extra column is a degenerate dimension
        let flat = Dataset::new(vec![0.0, 1.0, 2.0], vec![1.0, 2.0, 3.0], "flat")
            .with_extra_cols(vec![vec![7.0, 7.0, 7.0]])
            .unwrap();
        let e = flat.span().unwrap_err();
        assert!(e.to_string().contains("dimension 1"), "{e}");
    }
}
