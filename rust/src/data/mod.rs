//! Dataset handling: synthetic GP draws (§3(a)), the Woods-Hole tidal
//! simulator (§3(b) substitute — see DESIGN.md §Substitutions), and CSV
//! import/export.

pub mod synthetic;
pub mod tidal;
pub mod csv;

/// A 1-D regression dataset `{(t_i, y_i)}` — the paper's `D = {x, y}`.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Input (time) vector.
    pub t: Vec<f64>,
    /// Output vector.
    pub y: Vec<f64>,
    /// Human-readable provenance tag carried into reports.
    pub label: String,
}

impl Dataset {
    pub fn new(t: Vec<f64>, y: Vec<f64>, label: impl Into<String>) -> Self {
        assert_eq!(t.len(), y.len(), "t/y length mismatch");
        Self { t, y, label: label.into() }
    }

    /// Fallible constructor enforcing the data-boundary contract: every
    /// input and observation must be finite. A NaN/±∞ that slips past
    /// this boundary poisons a covariance factor irrecoverably, so the
    /// external entry points (CSV import, artifact hydration) reject it
    /// here with a clean error instead.
    pub fn checked(t: Vec<f64>, y: Vec<f64>, label: impl Into<String>) -> crate::Result<Self> {
        anyhow::ensure!(t.len() == y.len(), "t/y length mismatch: {} vs {}", t.len(), y.len());
        for (i, &v) in t.iter().enumerate() {
            anyhow::ensure!(v.is_finite(), "non-finite input t[{i}] = {v}");
        }
        for (i, &v) in y.iter().enumerate() {
            anyhow::ensure!(v.is_finite(), "non-finite observation y[{i}] = {v}");
        }
        Ok(Self { t, y, label: label.into() })
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// First `n` points (the paper's "first lunar month" style subsetting).
    pub fn head(&self, n: usize) -> Dataset {
        Dataset {
            t: self.t[..n.min(self.len())].to_vec(),
            y: self.y[..n.min(self.len())].to_vec(),
            label: format!("{}[..{}]", self.label, n.min(self.len())),
        }
    }

    /// Subtract the mean of `y` (the paper assumes zero-mean GPs).
    pub fn demean(mut self) -> Dataset {
        let m = self.y.iter().sum::<f64>() / self.len() as f64;
        for v in &mut self.y {
            *v -= m;
        }
        self
    }

    /// The sampling geometry (δt, ΔT).
    pub fn span(&self) -> crate::kernels::DataSpan {
        crate::kernels::DataSpan::from_times(&self.t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checked_rejects_non_finite() {
        assert!(Dataset::checked(vec![0.0, 1.0], vec![1.0, 2.0], "ok").is_ok());
        let e = Dataset::checked(vec![0.0, f64::NAN], vec![1.0, 2.0], "bad").unwrap_err();
        assert!(e.to_string().contains("t[1]"), "{e}");
        let e = Dataset::checked(vec![0.0, 1.0], vec![f64::INFINITY, 2.0], "bad").unwrap_err();
        assert!(e.to_string().contains("y[0]"), "{e}");
        assert!(Dataset::checked(vec![0.0], vec![1.0, 2.0], "len").is_err());
    }

    #[test]
    fn head_and_demean() {
        let d = Dataset::new(vec![0.0, 1.0, 2.0, 3.0], vec![1.0, 3.0, 5.0, 7.0], "x");
        let h = d.head(2);
        assert_eq!(h.len(), 2);
        assert_eq!(h.y, vec![1.0, 3.0]);
        let dm = d.demean();
        assert!((dm.y.iter().sum::<f64>()).abs() < 1e-12);
    }
}
