//! Woods-Hole tidal simulator — the §3(b) data substitute.
//!
//! The paper analyses NOAA tide-gauge mean-sea-level data from Woods Hole,
//! MA (3 Jan – 15 Jun 2014, 2-hour cadence, n = 1968; first lunar month
//! n = 328). That feed is not available offline, so we synthesise a series
//! with the same physical content (DESIGN.md §Substitutions):
//!
//! * the principal **semidiurnal** constituents — M2 (12.4206 h), S2
//!   (12.0000 h), N2 (12.6583 h) — whose M2/S2 beat produces the
//!   spring–neap (≈ lunar-month) modulation visible in the paper's Fig. 3;
//! * the principal **diurnal** constituents — K1 (23.9345 h), O1
//!   (25.8193 h) — which create the height difference between the two
//!   daily tides (the paper's T₂ ≈ 24 h detection);
//! * a weather-band **red-noise** surge component (AR(1) over the sample
//!   cadence), plus white measurement noise at the paper's σ_n = 10⁻²
//!   fractional level.
//!
//! Amplitude ratios follow the NOAA harmonic constants for station
//! 8447930 (Woods Hole): M2 is dominant; the diurnals are ≈ ⅓ of M2.
//! What matters for reproduction is not the exact amplitudes but that the
//! data contain exactly one strong ~12.4 h line plus weaker ~24 h
//! structure — which is what drives the paper's k₂-over-k₁ preference.

use crate::rng::Xoshiro256;

use super::Dataset;

/// One harmonic constituent: period (hours), amplitude (m), phase (rad).
#[derive(Clone, Copy, Debug)]
pub struct Constituent {
    pub name: &'static str,
    pub period_h: f64,
    pub amplitude: f64,
    pub phase: f64,
}

/// Woods-Hole-like constituent set (NOAA station 8447930 ratios).
pub const WOODS_HOLE: [Constituent; 5] = [
    Constituent { name: "M2", period_h: 12.4206, amplitude: 0.262, phase: 0.00 },
    Constituent { name: "S2", period_h: 12.0000, amplitude: 0.055, phase: 1.10 },
    Constituent { name: "N2", period_h: 12.6583, amplitude: 0.062, phase: 2.30 },
    Constituent { name: "K1", period_h: 23.9345, amplitude: 0.070, phase: 0.70 },
    Constituent { name: "O1", period_h: 25.8193, amplitude: 0.055, phase: 3.50 },
];

/// Configuration of the simulator.
#[derive(Clone, Debug)]
pub struct TidalConfig {
    /// Sample interval in hours (paper: 2 h).
    pub cadence_h: f64,
    /// Number of samples (paper: 1968 for six lunar months, 328 for one).
    pub n: usize,
    /// AR(1) weather-surge amplitude (m).
    pub surge_amplitude: f64,
    /// AR(1) correlation time (hours).
    pub surge_corr_h: f64,
    /// White measurement-noise sd as a fraction of signal sd (paper σ_n).
    pub noise_fraction: f64,
    pub seed: u64,
}

impl TidalConfig {
    /// Paper's "six lunar months" series: n = 1968 at 2-hour cadence.
    pub fn six_lunar_months(seed: u64) -> Self {
        Self {
            cadence_h: 2.0,
            n: 1968,
            surge_amplitude: 0.04,
            surge_corr_h: 36.0,
            noise_fraction: 1e-2,
            seed,
        }
    }

    /// Paper's "first lunar month" subset size.
    pub const LUNAR_MONTH_N: usize = 328;
}

/// Generate the tidal series. Times are reported in **hours** so the
/// recovered timescales read directly in the paper's units
/// (T₁ ≈ 12.4 h, T₂ ≈ 24 h).
pub fn generate_tidal(cfg: &TidalConfig) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let mut t = Vec::with_capacity(cfg.n);
    let mut y = Vec::with_capacity(cfg.n);
    // AR(1) surge: x_{k+1} = ρ x_k + √(1−ρ²) ε
    let rho = (-cfg.cadence_h / cfg.surge_corr_h).exp();
    let innov = (1.0 - rho * rho).sqrt();
    let mut surge = 0.0;
    for k in 0..cfg.n {
        let tk = k as f64 * cfg.cadence_h;
        let mut h = 0.0;
        for c in &WOODS_HOLE {
            h += c.amplitude * (2.0 * std::f64::consts::PI * tk / c.period_h + c.phase).cos();
        }
        surge = rho * surge + innov * rng.normal();
        h += cfg.surge_amplitude * surge;
        t.push(tk);
        y.push(h);
    }
    // add fractional white measurement noise
    let sd = {
        let m = y.iter().sum::<f64>() / y.len() as f64;
        (y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / y.len() as f64).sqrt()
    };
    let noise_sd = cfg.noise_fraction * sd;
    for v in &mut y {
        *v += noise_sd * rng.normal();
    }
    Dataset::new(t, y, format!("tidal-woods-hole-sim-n{}", cfg.n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_paper_shape() {
        let d = generate_tidal(&TidalConfig::six_lunar_months(1));
        assert_eq!(d.len(), 1968);
        assert_eq!(d.t[1] - d.t[0], 2.0);
        // six lunar months ≈ 164 days
        assert!((d.t.last().unwrap() - 1967.0 * 2.0).abs() < 1e-9);
    }

    #[test]
    fn dominant_period_is_semidiurnal() {
        // crude periodogram over candidate periods: the strongest response
        // must be near M2 = 12.42 h, not near 24 h
        let d = generate_tidal(&TidalConfig::six_lunar_months(2));
        let power = |period: f64| -> f64 {
            let (mut c, mut s) = (0.0, 0.0);
            for (tk, yk) in d.t.iter().zip(&d.y) {
                let w = 2.0 * std::f64::consts::PI * tk / period;
                c += yk * w.cos();
                s += yk * w.sin();
            }
            c * c + s * s
        };
        let p_m2 = power(12.4206);
        let p_24 = power(23.9345);
        let p_off = power(17.3);
        assert!(p_m2 > p_24, "M2 must dominate diurnal");
        assert!(p_24 > 20.0 * p_off, "diurnal must beat a non-tidal period");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_tidal(&TidalConfig::six_lunar_months(7));
        let b = generate_tidal(&TidalConfig::six_lunar_months(7));
        assert_eq!(a.y, b.y);
        let c = generate_tidal(&TidalConfig::six_lunar_months(8));
        assert!(a.y.iter().zip(&c.y).any(|(x, y)| (x - y).abs() > 1e-9));
    }

    #[test]
    fn spring_neap_modulation_present() {
        // envelope of the semidiurnal signal should vary over a lunar month
        // (M2+S2 beat, period ≈ 14.77 d = 354.4 h)
        let cfg = TidalConfig {
            surge_amplitude: 0.0,
            noise_fraction: 0.0,
            ..TidalConfig::six_lunar_months(3)
        };
        let d = generate_tidal(&cfg);
        // daily max over first and eighth days of a spring-neap cycle differ
        let day = (24.0 / cfg.cadence_h) as usize;
        let max_abs = |lo: usize| d.y[lo..lo + day].iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let spring = (0..14).map(|k| max_abs(k * day)).fold(0.0f64, f64::max);
        let neap = (0..14).map(|k| max_abs(k * day)).fold(f64::INFINITY, f64::min);
        assert!(spring / neap > 1.15, "spring/neap ratio {}", spring / neap);
    }
}
