//! Synthetic data generation — paper §3(a): "a realisation of the k₂ GP
//! with n points was drawn and analysed using both the k₁ and k₂
//! covariance functions."

use crate::gp::sample::draw_realisation;
use crate::kernels::CovarianceModel;
use crate::rng::Xoshiro256;

use super::Dataset;

/// Draw an `n`-point realisation of `model` on the grid `t = 1, 2, …, n`
/// (the paper's Fig.-1 grid) with amplitude `sigma_f`.
pub fn draw_gp_dataset(
    model: &CovarianceModel,
    sigma_f: f64,
    theta: &[f64],
    n: usize,
    rng: &mut Xoshiro256,
) -> Dataset {
    let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
    let y = draw_realisation(model, sigma_f, theta, &t, rng)
        .expect("truth covariance must be positive definite");
    Dataset::new(t, y, format!("synthetic-{}-n{}", model.name, n))
}

/// The paper's Table-1 setup: data always drawn from the **k₂** truth.
pub fn table1_dataset(n: usize, sigma_n: f64, seed: u64) -> Dataset {
    let model = crate::kernels::paper_k2(sigma_n);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    draw_gp_dataset(&model, 1.0, &crate::kernels::PaperK2::truth(), n, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{paper_k2, PaperK2};

    #[test]
    fn grid_is_one_to_n() {
        let d = table1_dataset(30, 0.1, 1);
        assert_eq!(d.len(), 30);
        assert_eq!(d.t[0], 1.0);
        assert_eq!(d.t[29], 30.0);
    }

    #[test]
    fn different_seeds_different_data() {
        let a = table1_dataset(50, 0.1, 1);
        let b = table1_dataset(50, 0.1, 2);
        assert!(a.y.iter().zip(&b.y).any(|(x, y)| (x - y).abs() > 1e-6));
    }

    #[test]
    fn amplitude_tracks_sigma_f() {
        let model = paper_k2(0.1);
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut var_sum = 0.0;
        let reps = 100;
        for _ in 0..reps {
            let d = draw_gp_dataset(&model, 2.0, &PaperK2::truth(), 40, &mut rng);
            var_sum += d.y.iter().map(|v| v * v).sum::<f64>() / 40.0;
        }
        let var = var_sum / reps as f64;
        // σ_f² (k(0) + σ_n²) = 4 × 1.01
        assert!((var - 4.04).abs() < 0.8, "sample variance {var}");
    }
}
