//! Synthetic data generation — paper §3(a): "a realisation of the k₂ GP
//! with n points was drawn and analysed using both the k₁ and k₂
//! covariance functions."

use crate::gp::sample::draw_realisation;
use crate::kernels::CovarianceModel;
use crate::rng::Xoshiro256;

use super::Dataset;

/// Draw an `n`-point realisation of `model` on the grid `t = 1, 2, …, n`
/// (the paper's Fig.-1 grid) with amplitude `sigma_f`.
pub fn draw_gp_dataset(
    model: &CovarianceModel,
    sigma_f: f64,
    theta: &[f64],
    n: usize,
    rng: &mut Xoshiro256,
) -> Dataset {
    let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
    let y = draw_realisation(model, sigma_f, theta, &t, rng)
        .expect("truth covariance must be positive definite");
    Dataset::new(t, y, format!("synthetic-{}-n{}", model.name, n))
}

/// The paper's Table-1 setup: data always drawn from the **k₂** truth.
pub fn table1_dataset(n: usize, sigma_n: f64, seed: u64) -> Dataset {
    let model = crate::kernels::paper_k2(sigma_n);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    draw_gp_dataset(&model, 1.0, &crate::kernels::PaperK2::truth(), n, &mut rng)
}

/// Truth hyperparameters of the d = 3 ARD scenario: distinct
/// per-dimension log length scales `φ = [0.8, 0.0, −0.5]`
/// (ℓ ≈ 2.2, 1.0, 0.6) — far enough apart that an isotropic fit pays a
/// visible evidence penalty, which is what the `scenario` bench measures.
pub fn ard3_truth() -> Vec<f64> {
    vec![0.8, 0.0, -0.5]
}

/// A d = 3 ARD scenario dataset, drawn from the `se-ard3` truth
/// ([`ard3_truth`]): column 0 is the grid `t = 1..n` (keeping the
/// time-axis convention), columns 1–2 are uniform draws on scales
/// comparable to the truth length scales. With `heteroscedastic` the
/// dataset carries a per-point noise vector `σ_n,i ∈ σ_n·[0.5, 2.0)`
/// (and the realisation is drawn under it); otherwise the model's scalar
/// σ_n applies.
pub fn ard3_dataset(n: usize, sigma_n: f64, heteroscedastic: bool, seed: u64) -> Dataset {
    let model = crate::kernels::CovarianceModel::new(
        "se-ard3",
        Box::new(crate::kernels::ArdKernel::se(3)),
        sigma_n,
    );
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let t: Vec<f64> = (1..=n).map(|i| i as f64).collect();
    let x2: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.0, 8.0)).collect();
    let x3: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.0, 4.0)).collect();
    let noise: Option<Vec<f64>> = heteroscedastic
        .then(|| (0..n).map(|_| sigma_n * rng.uniform_in(0.5, 2.0)).collect());
    let y = crate::gp::sample::draw_realisation_nd(
        &model,
        1.0,
        &ard3_truth(),
        &[&t, &x2, &x3],
        noise.as_deref(),
        &mut rng,
    )
    .expect("ARD truth covariance must be positive definite");
    let label = format!("ard3-n{n}{}", if heteroscedastic { "-hetero" } else { "" });
    let mut data = Dataset::new(t, y, label)
        .with_extra_cols(vec![x2, x3])
        .expect("generated columns are finite and aligned");
    if let Some(s) = noise {
        data = data.with_noise(s).expect("generated noise is finite and non-negative");
    }
    data
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{paper_k2, PaperK2};

    #[test]
    fn grid_is_one_to_n() {
        let d = table1_dataset(30, 0.1, 1);
        assert_eq!(d.len(), 30);
        assert_eq!(d.t[0], 1.0);
        assert_eq!(d.t[29], 30.0);
    }

    #[test]
    fn different_seeds_different_data() {
        let a = table1_dataset(50, 0.1, 1);
        let b = table1_dataset(50, 0.1, 2);
        assert!(a.y.iter().zip(&b.y).any(|(x, y)| (x - y).abs() > 1e-6));
    }

    #[test]
    fn ard3_dataset_has_three_columns_and_optional_noise() {
        let d = ard3_dataset(25, 0.1, false, 5);
        assert_eq!(d.d(), 3);
        assert_eq!(d.len(), 25);
        assert!(d.noise.is_none());
        assert!(d.span().is_ok());
        let h = ard3_dataset(25, 0.1, true, 5);
        assert!(h.is_heteroscedastic());
        let s = h.noise.as_ref().unwrap();
        assert!(s.iter().all(|&v| v >= 0.05 - 1e-12 && v < 0.2 + 1e-12));
        // deterministic given the seed
        let h2 = ard3_dataset(25, 0.1, true, 5);
        assert_eq!(h.y, h2.y);
        assert_eq!(h.extra, h2.extra);
    }

    #[test]
    fn amplitude_tracks_sigma_f() {
        let model = paper_k2(0.1);
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut var_sum = 0.0;
        let reps = 100;
        for _ in 0..reps {
            let d = draw_gp_dataset(&model, 2.0, &PaperK2::truth(), 40, &mut rng);
            var_sum += d.y.iter().map(|v| v * v).sum::<f64>() / 40.0;
        }
        let var = var_sum / reps as f64;
        // σ_f² (k(0) + σ_n²) = 4 × 1.01
        assert!((var - 4.04).abs() < 0.8, "sample variance {var}");
    }
}
