//! CSV import/export for datasets and report series.
//!
//! Layouts: the classic two-column `t,y` (d = 1, unchanged), and the
//! scenario tier's multi-column `t1,…,td,y` with an optional trailing
//! `noise` column carrying per-point σ_n,i. Line 0 is treated as a
//! header **only** when it contains no parsable float at all — a typo'd
//! first *data* row is a hard error, never a silent drop.

use std::io::Write as _;
use std::path::Path;

use super::Dataset;

/// Write a dataset as CSV with a header line: `t,y` for d = 1 (the
/// pre-existing layout, byte-identical), `t1,…,td,y` for d > 1, plus a
/// trailing `noise` column when the dataset is heteroscedastic.
pub fn write_dataset(path: &Path, data: &Dataset) -> crate::Result<()> {
    if data.d() == 1 && data.noise.is_none() {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "t,y")?;
        for (t, y) in data.t.iter().zip(&data.y) {
            writeln!(f, "{t},{y}")?;
        }
        return Ok(());
    }
    let d = data.d();
    let mut names: Vec<String> = (1..=d).map(|j| format!("t{j}")).collect();
    names.push("y".into());
    let mut cols: Vec<&[f64]> = data.input_cols();
    cols.push(&data.y);
    if let Some(noise) = &data.noise {
        names.push("noise".into());
        cols.push(noise);
    }
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    write_columns(path, &name_refs, &cols)
}

/// Write arbitrary named columns (all same length).
pub fn write_columns(path: &Path, names: &[&str], cols: &[&[f64]]) -> crate::Result<()> {
    anyhow::ensure!(names.len() == cols.len(), "names/cols mismatch");
    if let Some(first) = cols.first() {
        anyhow::ensure!(
            cols.iter().all(|c| c.len() == first.len()),
            "ragged columns"
        );
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", names.join(","))?;
    let rows = cols.first().map_or(0, |c| c.len());
    for r in 0..rows {
        let line: Vec<String> = cols.iter().map(|c| format!("{}", c[r])).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Read a dataset CSV.
///
/// * **Header detection:** line 0 is skipped as a header only when *no*
///   field parses as a float (i.e. it looks like column names). A first
///   row with any parsable float must parse *fully* as data — a typo
///   there is an error, not a silently dropped point.
/// * **With a header** the column names drive the layout: `y` is the
///   observation column (last column if none is named `y`), a column
///   named `noise` carries per-point σ_n,i, and every other column is
///   an input dimension in file order.
/// * **Without a header** the file is the classic layout: first column
///   `t`, second `y`, extra columns ignored.
pub fn read_dataset(path: &Path) -> crate::Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());

    // peek at line 0 to classify header vs data
    let first = lines.next();
    let mut header: Option<Vec<String>> = None;
    let mut pending_row: Option<(usize, &str)> = None;
    if let Some((lineno, line)) = first {
        let fields: Vec<&str> = line.trim().split(',').map(|s| s.trim()).collect();
        let any_float = fields.iter().any(|f| f.parse::<f64>().is_ok());
        if any_float {
            pending_row = Some((lineno, line));
        } else {
            header = Some(fields.iter().map(|s| s.to_string()).collect());
        }
    }

    // resolve the column layout from the header (or the classic default)
    let (input_idx, y_idx, noise_idx) = match &header {
        Some(names) => {
            let y_idx = names
                .iter()
                .position(|n| n == "y")
                .unwrap_or_else(|| names.len().saturating_sub(1));
            let noise_idx = names.iter().position(|n| n == "noise");
            let input_idx: Vec<usize> = (0..names.len())
                .filter(|&i| i != y_idx && Some(i) != noise_idx)
                .collect();
            anyhow::ensure!(
                !input_idx.is_empty(),
                "CSV {}: header {:?} has no input column",
                path.display(),
                names
            );
            (input_idx, y_idx, noise_idx)
        }
        None => (vec![0usize], 1usize, None),
    };
    // headerless files keep the historic "extra columns ignored" rule;
    // with a header every named column is meaningful and required
    let strict_width = header.is_some();
    let min_width = input_idx
        .iter()
        .chain(std::iter::once(&y_idx))
        .chain(noise_idx.iter())
        .max()
        .copied()
        .unwrap_or(1)
        + 1;

    let mut inputs: Vec<Vec<f64>> = vec![Vec::new(); input_idx.len()];
    let mut y = Vec::new();
    let mut noise: Vec<f64> = Vec::new();
    let rows = pending_row.into_iter().chain(lines);
    for (lineno, line) in rows {
        let line = line.trim();
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        let wide_enough =
            fields.len() >= min_width && (!strict_width || fields.len() == min_width);
        let parse = |i: usize| fields[i].parse::<f64>();
        let parsed: Option<(Vec<f64>, f64, Option<f64>)> = if wide_enough {
            let mut xs = Vec::with_capacity(input_idx.len());
            let mut ok = true;
            for &i in &input_idx {
                match parse(i) {
                    Ok(v) => xs.push(v),
                    Err(_) => {
                        ok = false;
                        break;
                    }
                }
            }
            let yv = parse(y_idx);
            let nv = noise_idx.map(parse);
            match (ok, yv, nv) {
                (true, Ok(yv), None) => Some((xs, yv, None)),
                (true, Ok(yv), Some(Ok(nv))) => Some((xs, yv, Some(nv))),
                _ => None,
            }
        } else {
            None
        };
        match parsed {
            Some((xs, yv, nv)) => {
                for (col, v) in inputs.iter_mut().zip(xs) {
                    col.push(v);
                }
                y.push(yv);
                if let Some(nv) = nv {
                    noise.push(nv);
                }
            }
            None => anyhow::bail!("bad CSV line {} in {}: '{line}'", lineno + 1, path.display()),
        }
    }
    anyhow::ensure!(y.len() >= 2, "CSV {} has fewer than 2 data rows", path.display());
    let label = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    // `parse::<f64>` happily accepts "NaN"/"inf" tokens — the data
    // boundary rejects them before they can poison a covariance factor
    let t = inputs.remove(0);
    let mut data = Dataset::checked(t, y, label)
        .map_err(|e| anyhow::anyhow!("CSV {}: {e}", path.display()))?;
    if !inputs.is_empty() {
        data = data
            .with_extra_cols(inputs)
            .map_err(|e| anyhow::anyhow!("CSV {}: {e}", path.display()))?;
    }
    if noise_idx.is_some() {
        data = data
            .with_noise(noise)
            .map_err(|e| anyhow::anyhow!("CSV {}: {e}", path.display()))?;
    }
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("gpfast_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let p = tmp("d.csv");
        let d = Dataset::new(vec![0.0, 0.5, 1.0], vec![1.0, -1.0, 2.5], "x");
        write_dataset(&p, &d).unwrap();
        let back = read_dataset(&p).unwrap();
        assert_eq!(back.t, d.t);
        assert_eq!(back.y, d.y);
        assert_eq!(back.d(), 1);
        assert!(back.noise.is_none());
        // the d = 1 on-disk layout is the historic two-column file
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("t,y\n"), "{text}");
    }

    #[test]
    fn multi_column_roundtrip() {
        let p = tmp("nd.csv");
        let d = Dataset::new(vec![0.0, 0.5, 1.0], vec![1.0, -1.0, 2.5], "x")
            .with_extra_cols(vec![vec![3.0, 4.0, 5.5], vec![-1.0, 0.0, 1.0]])
            .unwrap()
            .with_noise(vec![0.1, 0.2, 0.15])
            .unwrap();
        write_dataset(&p, &d).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("t1,t2,t3,y,noise\n"), "{text}");
        let back = read_dataset(&p).unwrap();
        assert_eq!(back.d(), 3);
        assert_eq!(back.t, d.t);
        assert_eq!(back.extra, d.extra);
        assert_eq!(back.y, d.y);
        assert_eq!(back.noise, d.noise);
    }

    #[test]
    fn rejects_garbage_row() {
        let p = tmp("bad.csv");
        std::fs::write(&p, "t,y\n1,2\nnope,3\n").unwrap();
        assert!(read_dataset(&p).is_err());
    }

    #[test]
    fn malformed_first_data_row_is_an_error_not_a_header() {
        // regression: "1.5,oops" has a parsable float, so it is a typo'd
        // data row — the old reader silently dropped it as a "header"
        let p = tmp("typo.csv");
        std::fs::write(&p, "1.5,oops\n2,3\n4,5\n").unwrap();
        let e = read_dataset(&p).unwrap_err();
        assert!(e.to_string().contains("line 1"), "{e}");
        // the same tokens in the other order are a typo too
        std::fs::write(&p, "oops,1.5\n2,3\n4,5\n").unwrap();
        let e = read_dataset(&p).unwrap_err();
        assert!(e.to_string().contains("line 1"), "{e}");
        // while a float-free line 0 is still a header
        std::fs::write(&p, "time,value\n2,3\n4,5\n").unwrap();
        let back = read_dataset(&p).unwrap();
        assert_eq!(back.t, vec![2.0, 4.0]);
        // and a fully numeric line 0 is data
        std::fs::write(&p, "1,2\n3,4\n").unwrap();
        let back = read_dataset(&p).unwrap();
        assert_eq!(back.t, vec![1.0, 3.0]);
    }

    #[test]
    fn headerless_extra_columns_still_ignored() {
        let p = tmp("wide.csv");
        std::fs::write(&p, "1,2,99\n3,4,99\n").unwrap();
        let back = read_dataset(&p).unwrap();
        assert_eq!(back.t, vec![1.0, 3.0]);
        assert_eq!(back.y, vec![2.0, 4.0]);
        assert_eq!(back.d(), 1);
    }

    #[test]
    fn rejects_non_finite_tokens() {
        let p = tmp("nan.csv");
        std::fs::write(&p, "t,y\n1,2\n2,NaN\n3,4\n").unwrap();
        let e = read_dataset(&p).unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
        std::fs::write(&p, "t,y\n1,2\ninf,3\n").unwrap();
        assert!(read_dataset(&p).is_err());
    }

    #[test]
    fn columns_writer() {
        let p = tmp("c.csv");
        write_columns(&p, &["a", "b"], &[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b"));
    }
}
