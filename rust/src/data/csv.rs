//! Two-column CSV import/export for datasets and report series.

use std::io::Write as _;
use std::path::Path;

use super::Dataset;

/// Write a dataset as `t,y` CSV with a header line.
pub fn write_dataset(path: &Path, data: &Dataset) -> crate::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "t,y")?;
    for (t, y) in data.t.iter().zip(&data.y) {
        writeln!(f, "{t},{y}")?;
    }
    Ok(())
}

/// Write arbitrary named columns (all same length).
pub fn write_columns(path: &Path, names: &[&str], cols: &[&[f64]]) -> crate::Result<()> {
    anyhow::ensure!(names.len() == cols.len(), "names/cols mismatch");
    if let Some(first) = cols.first() {
        anyhow::ensure!(
            cols.iter().all(|c| c.len() == first.len()),
            "ragged columns"
        );
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", names.join(","))?;
    let rows = cols.first().map_or(0, |c| c.len());
    for r in 0..rows {
        let line: Vec<String> = cols.iter().map(|c| format!("{}", c[r])).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Read a `t,y` CSV (header optional; extra columns ignored).
pub fn read_dataset(path: &Path) -> crate::Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let mut t = Vec::new();
    let mut y = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split(',');
        let a = parts.next().unwrap_or("");
        let b = parts.next().unwrap_or("");
        match (a.trim().parse::<f64>(), b.trim().parse::<f64>()) {
            (Ok(tv), Ok(yv)) => {
                t.push(tv);
                y.push(yv);
            }
            _ if lineno == 0 => continue, // header
            _ => anyhow::bail!("bad CSV line {} in {}: '{line}'", lineno + 1, path.display()),
        }
    }
    anyhow::ensure!(t.len() >= 2, "CSV {} has fewer than 2 data rows", path.display());
    let label = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    // `parse::<f64>` happily accepts "NaN"/"inf" tokens — the data
    // boundary rejects them before they can poison a covariance factor
    Dataset::checked(t, y, label)
        .map_err(|e| anyhow::anyhow!("CSV {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("gpfast_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.csv");
        let d = Dataset::new(vec![0.0, 0.5, 1.0], vec![1.0, -1.0, 2.5], "x");
        write_dataset(&p, &d).unwrap();
        let back = read_dataset(&p).unwrap();
        assert_eq!(back.t, d.t);
        assert_eq!(back.y, d.y);
    }

    #[test]
    fn rejects_garbage_row() {
        let dir = std::env::temp_dir().join("gpfast_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "t,y\n1,2\nnope,3\n").unwrap();
        assert!(read_dataset(&p).is_err());
    }

    #[test]
    fn rejects_non_finite_tokens() {
        let dir = std::env::temp_dir().join("gpfast_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("nan.csv");
        std::fs::write(&p, "t,y\n1,2\n2,NaN\n3,4\n").unwrap();
        let e = read_dataset(&p).unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
        std::fs::write(&p, "t,y\n1,2\ninf,3\n").unwrap();
        assert!(read_dataset(&p).is_err());
    }

    #[test]
    fn columns_writer() {
        let dir = std::env::temp_dir().join("gpfast_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.csv");
        write_columns(&p, &["a", "b"], &[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b"));
    }
}
