//! Dense row-major matrix.

use std::ops::{Index, IndexMut};

/// A dense, row-major, heap-allocated `f64` matrix.
///
/// This is deliberately minimal: the GP hot paths index raw rows and call
/// the free-function kernels in this module's siblings, so the type mostly
/// provides storage, shape checking and a few whole-matrix conveniences.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// Build from row slices (panics if rows are ragged).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Build from a flat row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw row-major storage.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Two disjoint mutable rows (i != j).
    pub fn rows_mut2(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        assert_ne!(i, j);
        let c = self.cols;
        if i < j {
            let (a, b) = self.data.split_at_mut(j * c);
            (&mut a[i * c..(i + 1) * c], &mut b[..c])
        } else {
            let (a, b) = self.data.split_at_mut(i * c);
            let bj = &mut a[j * c..(j + 1) * c];
            (&mut b[..c], bj)
        }
    }

    /// Matrix–vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = super::dot(self.row(i), x);
        }
        y
    }

    /// Transposed matrix–vector product `Aᵀ x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            let row = self.row(i);
            for j in 0..self.cols {
                y[j] += row[j] * xi;
            }
        }
        y
    }

    /// Dense matmul (serial).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        self.matmul_with(other, &crate::runtime::ExecutionContext::seq())
    }

    /// Dense matmul through the packed [`super::micro`] GEMM, with output
    /// row stripes distributed over the context's threads. Each stripe
    /// runs the full cache-blocked kernel; per-entry accumulation order
    /// depends only on the global `KC` grid, so the product is
    /// bit-identical for any thread count. Used by the `O(m n³)` Hessian
    /// trace products `W·∂K̃`.
    pub fn matmul_with(&self, other: &Matrix, ctx: &crate::runtime::ExecutionContext) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let (m, n, k) = (self.rows, other.cols, self.cols);
        let mut out = Matrix::zeros(m, n);
        if m == 0 || n == 0 || k == 0 {
            return out;
        }
        // one job per ≥32-row stripe: tiny products stay on the caller
        let jobs = ctx.threads().min((m / 32).max(1));
        let bounds = crate::runtime::exec::even_bounds(0, m, jobs);
        let a_data = self.as_slice();
        let b_data = other.as_slice();
        crate::runtime::exec::for_row_chunks(out.as_mut_slice(), n, &bounds, ctx, |chunk, r0, r1| {
            super::micro::gemm_nn(
                chunk,
                n,
                r1 - r0,
                n,
                k,
                &a_data[r0 * k..],
                k,
                b_data,
                n,
                1.0,
                super::micro::Clip::None,
            );
        });
        out
    }

    /// Transpose, in cache-sized blocks so both the source rows and the
    /// destination rows of a block stay resident (the naive double loop
    /// strides a full row per store — ~8× slower at n ≈ 2000). Sits on
    /// the `solve_mat` column-major path and the Hessian trace products.
    pub fn transpose(&self) -> Matrix {
        const B: usize = 32;
        let (r, c) = (self.rows, self.cols);
        let mut out = Matrix::zeros(c, r);
        let src = self.as_slice();
        let dst = out.as_mut_slice();
        let mut bi = 0;
        while bi < r {
            let i_end = (bi + B).min(r);
            let mut bj = 0;
            while bj < c {
                let j_end = (bj + B).min(c);
                for i in bi..i_end {
                    let row = &src[i * c + bj..i * c + j_end];
                    for (j, &v) in row.iter().enumerate() {
                        dst[(bj + j) * r + i] = v;
                    }
                }
                bj += B;
            }
            bi += B;
        }
        out
    }

    /// Symmetrise in place: `A ← (A + Aᵀ)/2`. Hessians assembled from
    /// independently computed (θ, θ′) pairs are symmetrised before use.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Copy the strict upper triangle onto the lower one, in `B×B` blocks
    /// so both source rows and destination rows stay cache-resident.
    /// Shared by covariance assembly and the Cholesky inverse, which
    /// compute one triangle and mirror.
    pub fn mirror_upper_to_lower(&mut self) {
        const B: usize = 64;
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let data = self.as_mut_slice();
        let mut bi = 0;
        while bi < n {
            let i_end = (bi + B).min(n);
            let mut bj = bi;
            while bj < n {
                let j_end = (bj + B).min(n);
                for i in bi..i_end {
                    let j0 = bj.max(i + 1);
                    for j in j0..j_end {
                        data[j * n + i] = data[i * n + j];
                    }
                }
                bj += B;
            }
            bi += B;
        }
    }

    /// Max |A - B| entry — test helper metric.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m[(1, 0)], 3.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        let e = Matrix::eye(3);
        assert_eq!(e[(2, 2)], 1.0);
        assert_eq!(e[(0, 2)], 0.0);
    }

    #[test]
    fn matvec_and_transpose() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(m.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(m.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn matmul_small() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[2.0, 1.0], &[4.0, 3.0]]));
    }

    #[test]
    fn symmetrize_averages() {
        let mut m = Matrix::from_rows(&[&[1.0, 2.0], &[4.0, 1.0]]);
        m.symmetrize();
        assert_eq!(m[(0, 1)], 3.0);
        assert_eq!(m[(1, 0)], 3.0);
    }

    #[test]
    fn rows_mut2_disjoint() {
        let mut m = Matrix::from_rows(&[&[1.0, 1.0], &[2.0, 2.0], &[3.0, 3.0]]);
        let (a, b) = m.rows_mut2(0, 2);
        a[0] = 9.0;
        b[1] = 8.0;
        assert_eq!(m[(0, 0)], 9.0);
        assert_eq!(m[(2, 1)], 8.0);
        let (a, b) = m.rows_mut2(2, 0); // reversed order
        a[0] = 7.0;
        b[0] = 6.0;
        assert_eq!(m[(2, 0)], 7.0);
        assert_eq!(m[(0, 0)], 6.0);
    }
}
