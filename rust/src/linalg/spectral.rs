//! Truncated spectral compression of SPD factors — the storage side of
//! the reduced-rank tradeoff (Chalupka/Williams/Murray, arXiv 1205.6326).
//!
//! A persisted Cholesky factor is `n(n+1)/2` doubles; for smooth kernels
//! the spectrum of `K = L Lᵀ` decays fast, so a truncated eigenexpansion
//!
//! ```text
//! K̃  =  V_r Λ_r V_rᵀ  +  diag(d)
//! ```
//!
//! with `r ≪ n` stores `r(n+1) + n` doubles instead. The rank is chosen
//! by a **relative tail-energy tolerance**: the smallest `r` with
//! `Σ_{i>r} λ_i ≤ tol · Σ_i λ_i` (eigenvalues clamped at zero, sorted
//! descending). The diagonal correction `d_i = K_ii − Σ_{k≤r} λ_k V_ik²`
//! (clamped at zero) makes the reconstruction **exact on the diagonal**,
//! which keeps predictive variances honest at the training points and —
//! crucially — keeps `K̃` positive definite so it re-factors cleanly on
//! hydration ([`crate::coordinator::artifact`] format v4).
//!
//! Compression runs at *encode* time (a one-off `O(n³)` Jacobi
//! eigensolve on an already-trained factor); the serve path only pays
//! the `O(r n²)` reconstruction plus one re-factorisation.

use super::{sym_eigen_checked, Chol, Matrix};

/// A rank-`r` spectral truncation of an SPD matrix plus its exact
/// diagonal correction. Produced by [`spectral_truncate`], rebuilt by
/// [`spectral_reconstruct`].
#[derive(Debug, Clone)]
pub struct SpectralTrunc {
    /// Retained eigenvalues, descending, all `≥ 0`, length `r ≥ 1`.
    pub eigvals: Vec<f64>,
    /// Retained eigenvectors as the **rows** of an `r × n` matrix
    /// (row `k` pairs with `eigvals[k]`).
    pub eigvecs: Matrix,
    /// Diagonal correction `d`, length `n`, all `≥ 0`, chosen so the
    /// reconstruction matches `K` exactly on the diagonal.
    pub diag: Vec<f64>,
}

impl SpectralTrunc {
    /// Retained rank `r`.
    pub fn rank(&self) -> usize {
        self.eigvals.len()
    }

    /// Original dimension `n`.
    pub fn dim(&self) -> usize {
        self.eigvecs.cols()
    }

    /// Doubles stored by this form: `r(n+1) + n` vs the packed
    /// triangle's `n(n+1)/2`.
    pub fn stored_f64s(&self) -> usize {
        self.rank() * (self.dim() + 1) + self.dim()
    }
}

/// Compress the SPD matrix behind a Cholesky factor to a truncated
/// spectral form whose relative tail energy is at most `tol`.
///
/// `tol` is clamped into `[0, 1)`; `tol = 0` keeps every positive
/// eigenvalue (lossless up to the eigensolve's round-off). The rank is
/// always at least 1 and at most `n`. Errors if the eigensolver fails
/// to converge (pathological input) — callers should fall back to the
/// uncompressed encoding in that case.
pub fn spectral_truncate(chol: &Chol, tol: f64) -> crate::Result<SpectralTrunc> {
    let n = chol.dim();
    anyhow::ensure!(n >= 1, "cannot compress an empty factor");
    let tol = tol.clamp(0.0, 1.0 - f64::EPSILON);
    // Reconstitute K = L·Lᵀ (lower triangle only is read from L).
    let l = chol.factor_matrix();
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // dot of row i and row j over the first min(i,j)+1 entries
            let m = j + 1;
            let mut s = 0.0;
            for p in 0..m {
                s += l.row(i)[p] * l.row(j)[p];
            }
            k[(i, j)] = s;
            k[(j, i)] = s;
        }
    }
    let (mut vals, vecs) = sym_eigen_checked(&k)?;
    // sym_eigen returns ascending eigenvalues with eigenvectors in the
    // *columns*; flip to descending and clamp the (round-off) negatives.
    vals.reverse();
    for v in &mut vals {
        if !v.is_finite() {
            anyhow::bail!("eigensolver produced a non-finite eigenvalue");
        }
        *v = v.max(0.0);
    }
    let total: f64 = vals.iter().sum();
    anyhow::ensure!(
        total.is_finite() && total > 0.0,
        "degenerate spectrum: trace {total} not positive"
    );
    // Smallest r ≥ 1 with tail energy Σ_{i>r} λ ≤ tol·total.
    let mut rank = n;
    let mut tail = 0.0;
    for r in (1..n).rev() {
        tail += vals[r];
        if tail > tol * total {
            break;
        }
        rank = r;
    }
    // Copy the retained eigenvectors out as rows. Column n-1 of `vecs`
    // is the largest eigenvalue's vector after the reversal above.
    let mut eigvecs = Matrix::zeros(rank, n);
    for kk in 0..rank {
        let col = n - 1 - kk;
        for i in 0..n {
            eigvecs[(kk, i)] = vecs[(i, col)];
        }
    }
    let eigvals = vals[..rank].to_vec();
    // Exact-diagonal correction, clamped at zero so K̃ stays SPD-friendly.
    let mut diag = Vec::with_capacity(n);
    for i in 0..n {
        let mut approx = 0.0;
        for kk in 0..rank {
            let v = eigvecs[(kk, i)];
            approx += eigvals[kk] * v * v;
        }
        diag.push((k[(i, i)] - approx).max(0.0));
    }
    Ok(SpectralTrunc { eigvals, eigvecs, diag })
}

/// Rebuild the dense approximation `K̃ = V_r Λ_r V_rᵀ + diag(d)`.
///
/// `O(r n²)` — the hydration-side cost of the compressed artifact path.
pub fn spectral_reconstruct(st: &SpectralTrunc) -> Matrix {
    let n = st.dim();
    let r = st.rank();
    let mut k = Matrix::zeros(n, n);
    for kk in 0..r {
        let lam = st.eigvals[kk];
        let row = st.eigvecs.row(kk);
        for i in 0..n {
            let li = lam * row[i];
            let out = k.row_mut(i);
            for j in 0..n {
                out[j] += li * row[j];
            }
        }
    }
    for i in 0..n {
        k[(i, i)] += st.diag[i];
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize) -> Matrix {
        // A well-conditioned SPD matrix with decaying off-diagonals —
        // kernel-matrix-like so truncation is meaningful.
        let mut k = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let d = (i as f64 - j as f64).abs();
                k[(i, j)] = (-0.5 * d * d / 9.0).exp();
            }
            k[(i, i)] += 0.1;
        }
        k
    }

    #[test]
    fn lossless_tolerance_round_trips() {
        let k = spd(12);
        let chol = Chol::factor(&k).unwrap();
        let st = spectral_truncate(&chol, 0.0).unwrap();
        let kk = spectral_reconstruct(&st);
        for i in 0..12 {
            for j in 0..12 {
                assert!(
                    (kk[(i, j)] - k[(i, j)]).abs() < 1e-8,
                    "K̃[{i}][{j}] = {} vs {}",
                    kk[(i, j)],
                    k[(i, j)]
                );
            }
        }
    }

    #[test]
    fn loose_tolerance_truncates_and_stays_factorable() {
        let n = 24;
        let k = spd(n);
        let chol = Chol::factor(&k).unwrap();
        let st = spectral_truncate(&chol, 1e-3).unwrap();
        assert!(st.rank() < n, "smooth spectrum should truncate, rank = {}", st.rank());
        assert!(st.stored_f64s() < n * (n + 1) / 2);
        // descending, non-negative
        for w in st.eigvals.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(st.eigvals.iter().all(|&v| v >= 0.0));
        // exact on the diagonal by construction
        let kk = spectral_reconstruct(&st);
        for i in 0..n {
            assert!((kk[(i, i)] - k[(i, i)]).abs() < 1e-10);
        }
        // and the reconstruction re-factors
        let re = Chol::factor(&kk).unwrap();
        assert!(re.logdet().is_finite());
    }

    #[test]
    fn rank_bounds_are_respected() {
        let k = spd(6);
        let chol = Chol::factor(&k).unwrap();
        // tol ≈ 1 still keeps rank ≥ 1
        let st = spectral_truncate(&chol, 0.999_999).unwrap();
        assert!(st.rank() >= 1 && st.rank() <= 6);
    }
}
