//! Cyclic Jacobi eigensolver for small symmetric matrices.
//!
//! Used by the nested sampler's bounding-ellipsoid proposal (the
//! MULTINEST-style baseline) and by the Fig. 2 corner-plot diagnostics,
//! where matrices are `m×m` with m ≤ ~10 — Jacobi is simple, provably
//! convergent, and plenty fast at that size.

use super::Matrix;

/// Eigendecomposition `A = V diag(λ) Vᵀ` of a symmetric matrix.
///
/// Returns `(eigenvalues, eigenvectors)` with eigenvalues ascending and
/// eigenvectors in the *columns* of the returned matrix.
pub fn sym_eigen(a: &Matrix) -> (Vec<f64>, Matrix) {
    assert_eq!(a.rows(), a.cols(), "sym_eigen needs a square matrix");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Matrix::eye(n);
    const MAX_SWEEPS: usize = 64;
    for _ in 0..MAX_SWEEPS {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 * m.fro_norm().max(1e-300) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // stable tan rotation
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // apply rotation J(p,q,θ): M ← JᵀMJ, V ← VJ
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // extract and sort ascending
    let mut idx: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| evals[a].partial_cmp(&evals[b]).unwrap());
    let sorted_vals: Vec<f64> = idx.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = Matrix::zeros(n, n);
    for (new_col, &old_col) in idx.iter().enumerate() {
        for r in 0..n {
            sorted_vecs[(r, new_col)] = v[(r, old_col)];
        }
    }
    (sorted_vals, sorted_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let (vals, _) = sym_eigen(&a);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → λ = 1, 3
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (vals, vecs) = sym_eigen(&a);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
        // eigenvector for λ=3 is (1,1)/√2 up to sign
        let v = (vecs[(0, 1)], vecs[(1, 1)]);
        assert!((v.0.abs() - (0.5f64).sqrt()).abs() < 1e-10);
        assert!((v.0 - v.1).abs() < 1e-10 || (v.0 + v.1).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_random() {
        let mut rng = Xoshiro256::seed_from_u64(47);
        for &n in &[2usize, 4, 7, 10] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    let v = rng.normal();
                    a[(i, j)] = v;
                    a[(j, i)] = v;
                }
            }
            let (vals, vecs) = sym_eigen(&a);
            // A V = V diag(λ)
            for c in 0..n {
                let vc: Vec<f64> = (0..n).map(|r| vecs[(r, c)]).collect();
                let av = a.matvec(&vc);
                for r in 0..n {
                    assert!(
                        (av[r] - vals[c] * vc[r]).abs() < 1e-9,
                        "n={n} col={c} row={r}"
                    );
                }
            }
            // orthonormality
            for c1 in 0..n {
                for c2 in 0..n {
                    let d: f64 = (0..n).map(|r| vecs[(r, c1)] * vecs[(r, c2)]).sum();
                    let want = if c1 == c2 { 1.0 } else { 0.0 };
                    assert!((d - want).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn trace_and_det_preserved() {
        let a = Matrix::from_rows(&[&[4.0, 1.0, 0.5], &[1.0, 3.0, 0.2], &[0.5, 0.2, 5.0]]);
        let (vals, _) = sym_eigen(&a);
        let tr: f64 = vals.iter().sum();
        assert!((tr - 12.0).abs() < 1e-10);
    }
}
