//! Symmetric eigensolvers and spectral diagnostics.
//!
//! Two tiers:
//!
//! * [`sym_eigen`] — cyclic Jacobi with eigenvectors, for the small
//!   (`m ≤ ~10`) matrices of the nested sampler's bounding-ellipsoid
//!   proposal and the Fig. 2 corner-plot diagnostics. Jacobi is simple,
//!   provably convergent, and plenty fast at that size.
//! * [`sym_eigenvalues_with`] — eigenvalues of an `n`-sized symmetric
//!   matrix via Householder tridiagonalisation (row-parallel through the
//!   [`ExecutionContext`], bit-identical for any thread count) followed
//!   by implicit-shift symmetric QL on the tridiagonal. This is the
//!   spectral back-end of the numerical health tier: it prices the exact
//!   `λ_max/λ_min` that [`sym_one_norm_est`]-based condition estimates
//!   (see [`super::Chol::cond_1est`]) approximate in `O(n²)`.
//!
//! Both refuse to return garbage: the Jacobi sweep cap and the QL
//! iteration cap are *checked*, surfacing non-convergence as an explicit
//! error instead of silently handing back a half-rotated matrix.

use super::Matrix;
use crate::runtime::exec::{even_bounds, for_row_chunks, ExecutionContext, PAR_MIN_WORK};

/// Eigendecomposition `A = V diag(λ) Vᵀ` of a symmetric matrix.
///
/// Returns `(eigenvalues, eigenvectors)` with eigenvalues ascending and
/// eigenvectors in the *columns* of the returned matrix.
///
/// Panics if the Jacobi iteration fails to converge within the sweep cap
/// (see [`sym_eigen_checked`] for the fallible form) — previously this
/// case silently returned whatever the 64th sweep left behind.
pub fn sym_eigen(a: &Matrix) -> (Vec<f64>, Matrix) {
    sym_eigen_checked(a).expect("Jacobi eigensolver did not converge")
}

/// [`sym_eigen`], surfacing non-convergence as an `Err` carrying the
/// residual off-diagonal norm instead of panicking.
pub fn sym_eigen_checked(a: &Matrix) -> crate::Result<(Vec<f64>, Matrix)> {
    assert_eq!(a.rows(), a.cols(), "sym_eigen needs a square matrix");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Matrix::eye(n);
    const MAX_SWEEPS: usize = 64;
    let off_norm = |m: &Matrix| -> f64 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        off.sqrt()
    };
    let mut converged = false;
    for _ in 0..MAX_SWEEPS {
        if off_norm(&m) < 1e-14 * m.fro_norm().max(1e-300) {
            converged = true;
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // stable tan rotation
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // apply rotation J(p,q,θ): M ← JᵀMJ, V ← VJ
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    if !converged {
        // one more residual check: the final sweep may have finished the
        // job without the loop head seeing it
        let off = off_norm(&m);
        anyhow::ensure!(
            off < 1e-14 * m.fro_norm().max(1e-300),
            "Jacobi eigensolver did not converge in {MAX_SWEEPS} sweeps \
             (residual off-diagonal norm {off:.3e})"
        );
    }
    // extract and sort ascending
    let mut idx: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| crate::util::asc_nan_last(evals[a], evals[b]));
    let sorted_vals: Vec<f64> = idx.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = Matrix::zeros(n, n);
    for (new_col, &old_col) in idx.iter().enumerate() {
        for r in 0..n {
            sorted_vecs[(r, new_col)] = v[(r, old_col)];
        }
    }
    Ok((sorted_vals, sorted_vecs))
}

/// Eigenvalues (ascending) of an `n×n` symmetric matrix — serial form of
/// [`sym_eigenvalues_with`].
pub fn sym_eigenvalues(a: &Matrix) -> crate::Result<Vec<f64>> {
    sym_eigenvalues_with(a, &ExecutionContext::seq())
}

/// Eigenvalues (ascending) of an `n×n` symmetric matrix: Householder
/// tridiagonalisation + implicit-shift symmetric QL.
///
/// The `O(n³)` reduction partitions its trailing matvec and rank-2
/// update over row tiles of the context; per-row arithmetic is
/// independent of the partition, so the result is bit-identical for any
/// thread count. The `O(n²)` QL phase is scalar. Errors if an eigenvalue
/// fails to converge within the iteration cap.
pub fn sym_eigenvalues_with(a: &Matrix, ctx: &ExecutionContext) -> crate::Result<Vec<f64>> {
    assert_eq!(a.rows(), a.cols(), "sym_eigenvalues needs a square matrix");
    let n = a.rows();
    if n == 0 {
        return Ok(Vec::new());
    }
    let mut m = a.clone();
    m.symmetrize();
    let (mut d, mut e) = tridiagonalize(&mut m, ctx);
    tql_eigenvalues(&mut d, &mut e)?;
    d.sort_by(|x, y| crate::util::asc_nan_last(*x, *y));
    Ok(d)
}

/// Householder reduction of a fully-stored symmetric matrix to
/// tridiagonal form. Returns `(d, e)`: the diagonal and the `n-1`
/// subdiagonal entries. `m` is clobbered.
fn tridiagonalize(m: &mut Matrix, ctx: &ExecutionContext) -> (Vec<f64>, Vec<f64>) {
    let n = m.rows();
    let mut e = vec![0.0; n.saturating_sub(1)];
    let mut v = vec![0.0; n];
    let mut w = vec![0.0; n];
    for k in 0..n.saturating_sub(2) {
        let rows = n - k - 1; // trailing rows k+1..n
        // x = column k below the subdiagonal head
        let mut norm2 = 0.0;
        for i in (k + 1)..n {
            let xi = m[(i, k)];
            v[i] = xi;
            norm2 += xi * xi;
        }
        let xnorm = norm2.sqrt();
        let x0 = v[k + 1];
        // already tridiagonal in this column?
        if norm2 - x0 * x0 <= 0.0 || xnorm == 0.0 {
            e[k] = x0;
            continue;
        }
        let alpha = -xnorm.copysign(x0);
        e[k] = alpha;
        v[k + 1] -= alpha;
        let vtv = norm2 - 2.0 * alpha * x0 + alpha * alpha;
        if vtv <= 0.0 {
            continue;
        }
        let tau = 2.0 / vtv;
        // p = τ·B·v over the trailing block B = m[k+1.., k+1..]
        let jobs = if rows * rows >= PAR_MIN_WORK { ctx.threads() } else { 1 };
        let bounds = even_bounds(k + 1, n, jobs);
        {
            let mslice: &[f64] = m.as_slice();
            let vref: &[f64] = &v;
            for_row_chunks(&mut w[(k + 1)..n], 1, &bounds, ctx, |chunk, r0, r1| {
                for r in r0..r1 {
                    let row = &mslice[r * n + k + 1..r * n + n];
                    chunk[r - r0] = tau * super::dot(row, &vref[(k + 1)..n]);
                }
            });
        }
        // w = p − (τ/2)(pᵀv)·v
        let pv = super::dot(&w[(k + 1)..n], &v[(k + 1)..n]);
        let half = 0.5 * tau * pv;
        for i in (k + 1)..n {
            w[i] -= half * v[i];
        }
        // B ← B − v·wᵀ − w·vᵀ, row-parallel (each row independent)
        {
            let tail = &mut m.as_mut_slice()[(k + 1) * n..];
            let vref: &[f64] = &v;
            let wref: &[f64] = &w;
            for_row_chunks(tail, n, &bounds, ctx, |chunk, r0, r1| {
                for r in r0..r1 {
                    let lr = r - r0;
                    let row = &mut chunk[lr * n + k + 1..lr * n + n];
                    super::axpy(-vref[r], &wref[(k + 1)..n], row);
                    super::axpy(-wref[r], &vref[(k + 1)..n], row);
                }
            });
        }
    }
    if n >= 2 {
        e[n - 2] = m[(n - 1, n - 2)];
    }
    let d: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    (d, e)
}

/// Implicit-shift symmetric QL on a tridiagonal `(d, e)`, eigenvalues
/// only. `d` holds the diagonal (overwritten with unsorted eigenvalues);
/// `e` the `n-1` subdiagonal entries (clobbered). Errors if any
/// eigenvalue needs more than the iteration cap.
fn tql_eigenvalues(d: &mut [f64], e: &mut [f64]) -> crate::Result<()> {
    let n = d.len();
    if n <= 1 {
        return Ok(());
    }
    const MAX_ITER: usize = 50;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // locate a negligible subdiagonal element
            let mut mm = l;
            while mm + 1 < n {
                let dd = d[mm].abs() + d[mm + 1].abs();
                if e[mm].abs() <= f64::EPSILON * dd {
                    break;
                }
                mm += 1;
            }
            if mm == l {
                break;
            }
            iter += 1;
            anyhow::ensure!(
                iter <= MAX_ITER,
                "tridiagonal QL failed to converge on eigenvalue {l} \
                 after {MAX_ITER} implicit-shift iterations"
            );
            // Wilkinson shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[mm] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..mm).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    // rotation annihilated early: deflate and restart
                    d[i + 1] -= p;
                    e[mm] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                f = (d[i] - g) * s + 2.0 * c * b;
                p = s * f;
                d[i + 1] = g + p;
                g = c * f - b;
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[mm] = 0.0;
        }
    }
    Ok(())
}

/// Hager-style estimate of the 1-norm of a symmetric operator, given only
/// matrix–vector products `x ↦ A·x` — `O(a few)` applications, each
/// `O(n²)` for a dense factor. Used with `A = K̃` and `A = K̃⁻¹` (through
/// the cached Cholesky solve) to price a condition estimate per window
/// refresh without an `O(n³)` eigendecomposition; see
/// [`super::Chol::cond_1est`].
///
/// Returns `f64::INFINITY` when an application produces non-finite
/// values — the conservative answer for health monitoring.
pub fn sym_one_norm_est<F: FnMut(&[f64]) -> Vec<f64>>(n: usize, mut apply: F) -> f64 {
    if n == 0 {
        return 0.0;
    }
    let mut x = vec![1.0 / n as f64; n];
    let mut est = 0.0f64;
    const MAX_ITER: usize = 5;
    for iter in 0..MAX_ITER {
        let y = apply(&x);
        debug_assert_eq!(y.len(), n);
        let y1: f64 = y.iter().map(|v| v.abs()).sum();
        if !y1.is_finite() {
            return f64::INFINITY;
        }
        if iter > 0 && y1 <= est {
            break; // no longer improving
        }
        est = est.max(y1);
        // ξ = sign(y); z = Aᵀξ = Aξ (symmetric)
        let xi: Vec<f64> = y.iter().map(|v| if *v >= 0.0 { 1.0 } else { -1.0 }).collect();
        let z = apply(&xi);
        let mut j = 0;
        let mut zmax = 0.0f64;
        let mut zdotx = 0.0;
        for (i, &zi) in z.iter().enumerate() {
            if !zi.is_finite() {
                return f64::INFINITY;
            }
            zdotx += zi * x[i];
            if zi.abs() > zmax {
                zmax = zi.abs();
                j = i;
            }
        }
        if zmax <= zdotx.abs() {
            break; // Hager's optimality condition: eⱼ won't improve
        }
        x.iter_mut().for_each(|v| *v = 0.0);
        x[j] = 1.0;
    }
    est
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Chol;
    use crate::rng::Xoshiro256;

    fn random_sym(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let (vals, _) = sym_eigen(&a);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → λ = 1, 3
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (vals, vecs) = sym_eigen(&a);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
        // eigenvector for λ=3 is (1,1)/√2 up to sign
        let v = (vecs[(0, 1)], vecs[(1, 1)]);
        assert!((v.0.abs() - (0.5f64).sqrt()).abs() < 1e-10);
        assert!((v.0 - v.1).abs() < 1e-10 || (v.0 + v.1).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_random() {
        let mut rng = Xoshiro256::seed_from_u64(47);
        for &n in &[2usize, 4, 7, 10] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    let v = rng.normal();
                    a[(i, j)] = v;
                    a[(j, i)] = v;
                }
            }
            let (vals, vecs) = sym_eigen(&a);
            // A V = V diag(λ)
            for c in 0..n {
                let vc: Vec<f64> = (0..n).map(|r| vecs[(r, c)]).collect();
                let av = a.matvec(&vc);
                for r in 0..n {
                    assert!(
                        (av[r] - vals[c] * vc[r]).abs() < 1e-9,
                        "n={n} col={c} row={r}"
                    );
                }
            }
            // orthonormality
            for c1 in 0..n {
                for c2 in 0..n {
                    let d: f64 = (0..n).map(|r| vecs[(r, c1)] * vecs[(r, c2)]).sum();
                    let want = if c1 == c2 { 1.0 } else { 0.0 };
                    assert!((d - want).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn trace_and_det_preserved() {
        let a = Matrix::from_rows(&[&[4.0, 1.0, 0.5], &[1.0, 3.0, 0.2], &[0.5, 0.2, 5.0]]);
        let (vals, _) = sym_eigen(&a);
        let tr: f64 = vals.iter().sum();
        assert!((tr - 12.0).abs() < 1e-10);
    }

    #[test]
    fn tridiag_qr_matches_jacobi_small() {
        for &(n, seed) in &[(2usize, 11u64), (3, 12), (5, 13), (8, 14), (10, 15)] {
            let a = random_sym(n, seed);
            let (jac, _) = sym_eigen(&a);
            let qr = sym_eigenvalues(&a).unwrap();
            assert_eq!(qr.len(), n);
            let scale = jac.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for i in 0..n {
                assert!(
                    (jac[i] - qr[i]).abs() <= 1e-10 * scale,
                    "n={n} i={i}: jacobi {} vs qr {}",
                    jac[i],
                    qr[i]
                );
            }
        }
    }

    #[test]
    fn tridiag_qr_parallel_bit_identical() {
        let a = random_sym(80, 21);
        let seq = sym_eigenvalues(&a).unwrap();
        let par = sym_eigenvalues_with(&a, &ExecutionContext::new(4)).unwrap();
        assert_eq!(seq, par, "eigenvalues must be bit-identical across thread counts");
        // and match Jacobi to rounding
        let (jac, _) = sym_eigen(&a);
        let scale = jac.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for i in 0..80 {
            assert!((jac[i] - seq[i]).abs() <= 1e-8 * scale, "i={i}");
        }
    }

    #[test]
    fn one_norm_est_exact_on_small() {
        // ||A||₁ of a known matrix; the estimator is exact on matrices
        // whose maximising column is found by the power step
        let a = Matrix::from_rows(&[&[4.0, -1.0, 0.0], &[-1.0, 3.0, 2.0], &[0.0, 2.0, 5.0]]);
        let est = sym_one_norm_est(3, |x| a.matvec(x));
        let true_norm = 7.0; // max column abs-sum: |0|+|2|+|5| = 7
        assert!(est <= true_norm + 1e-12);
        assert!(est >= 0.5 * true_norm, "est {est} too far below {true_norm}");
    }

    #[test]
    fn cond_est_brackets_true_condition() {
        // SPD with known spectrum: diag(λ) rotated by a random orthogonal
        for &(n, lo, hi) in &[(12usize, 1e-3f64, 1.0f64), (24, 1e-6, 10.0)] {
            let base = random_sym(n, 31 + n as u64);
            let (_, v) = sym_eigen(&base);
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        // geometric spread of eigenvalues in [lo, hi]
                        let lam = lo * (hi / lo).powf(k as f64 / (n - 1) as f64);
                        acc += v[(i, k)] * lam * v[(j, k)];
                    }
                    a[(i, j)] = acc;
                }
            }
            a.symmetrize();
            let chol = Chol::factor(&a).unwrap();
            let est = chol.cond_1est();
            let true_cond = hi / lo;
            // 1-norm vs 2-norm condition differ by at most a factor n on
            // either side; the estimator is a lower bound on κ₁
            assert!(
                est >= true_cond / (10.0 * n as f64) && est <= true_cond * (10.0 * n as f64),
                "n={n}: est {est:.3e} vs true κ₂ {true_cond:.3e}"
            );
        }
    }

    #[test]
    fn non_finite_application_reports_infinite_norm() {
        let est = sym_one_norm_est(3, |_| vec![f64::NAN, 1.0, 2.0]);
        assert!(est.is_infinite());
    }
}
